"""Layer-2 checks: block-program shapes, batching semantics, AOT lowering.

Verifies that (i) each AOT variant lowers to HLO text the xla_extension
parser accepts structurally (non-empty, ENTRY present, f32 only); (ii) the
batched programs equal per-block loops; (iii) goldens round-trip.
"""

import json
import os

import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def test_variant_table_is_consistent():
    for name, (fn, specs) in aot.VARIANTS.items():
        assert callable(fn), name
        for s in specs:
            assert str(s.dtype) == "float32", f"{name}: non-f32 input {s}"


@pytest.mark.parametrize("name", sorted(aot.VARIANTS))
def test_lowering_produces_hlo_text(name):
    text = aot.to_hlo_text(aot.lower_variant(name))
    assert "ENTRY" in text
    assert "HloModule" in text
    # 64-bit-id proto issue is bypassed by text; sanity: text parses as ASCII
    text.encode("ascii")


def test_batched_tsne_equals_loop():
    rng = np.random.default_rng(3)
    B, M, N, d = 4, 32, 32, 2
    Yt = rng.normal(size=(B, M, d)).astype(np.float32)
    Ys = rng.normal(size=(B, N, d)).astype(np.float32)
    P = rng.random((B, M, N)).astype(np.float32)
    tv = np.ones((B, M), np.float32)
    sv = np.ones((B, N), np.float32)
    (Fb,) = model.tsne_block_batch(Yt, Ys, P, tv, sv)
    for b in range(B):
        want = ref.tsne_attr_block(Yt[b], Ys[b], P[b], tv[b], sv[b])
        np.testing.assert_allclose(np.asarray(Fb[b]), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_batched_gauss_equals_loop():
    rng = np.random.default_rng(4)
    B, M, N, d = 3, 24, 40, 3
    T = rng.normal(size=(B, M, d)).astype(np.float32)
    S = rng.normal(size=(B, N, d)).astype(np.float32)
    x = rng.normal(size=(B, N)).astype(np.float32)
    tv = np.ones((B, M), np.float32)
    sv = np.ones((B, N), np.float32)
    (yb,) = model.gauss_block_batch(T, S, x, tv, sv, 0.5)
    for b in range(B):
        want = ref.gauss_block_matvec(T[b], S[b], x[b], tv[b], sv[b], 0.5)
        np.testing.assert_allclose(np.asarray(yb[b]), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_batched_meanshift_equals_loop():
    rng = np.random.default_rng(5)
    B, M, N, d = 3, 16, 16, 3
    T = rng.normal(size=(B, M, d)).astype(np.float32)
    S = rng.normal(size=(B, N, d)).astype(np.float32)
    tv = np.ones((B, M), np.float32)
    sv = np.ones((B, N), np.float32)
    num, den = model.meanshift_block_batch(T, S, tv, sv, 0.3)
    for b in range(B):
        wn, wd = ref.meanshift_block(T[b], S[b], tv[b], sv[b], 0.3)
        np.testing.assert_allclose(np.asarray(num[b]), np.asarray(wn),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(den[b]), np.asarray(wd),
                                   rtol=2e-5, atol=2e-5)


def test_tsne_with_norm_consistent():
    rng = np.random.default_rng(6)
    M, N, d = 48, 48, 2
    Yt = rng.normal(size=(M, d)).astype(np.float32)
    Ys = rng.normal(size=(N, d)).astype(np.float32)
    P = rng.random((M, N)).astype(np.float32)
    tv = np.ones(M, np.float32)
    sv = np.ones(N, np.float32)
    F, n2 = model.tsne_block_with_norm(Yt, Ys, P, tv, sv)
    assert float(n2[0]) == pytest.approx(float(np.sum(np.asarray(F) ** 2)), rel=1e-4)


ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_goldens_match_oracle_recompute():
    """Golden outputs on disk == recomputing the block program now."""
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    for name, entry in sorted(manifest["variants"].items()):
        g = entry.get("golden")
        if g is None:
            continue
        fn, specs = aot.VARIANTS[name]
        args = []
        for spec, meta in zip(specs, g["inputs"]):
            a = np.fromfile(os.path.join(ART, "golden", meta["file"]),
                            dtype=np.float32)
            args.append(a.reshape(meta["shape"]) if meta["shape"] else a[()])
        outs = fn(*args)
        for o, meta in zip(outs, g["outputs"]):
            want = np.fromfile(os.path.join(ART, "golden", meta["file"]),
                               dtype=np.float32).reshape(meta["shape"])
            np.testing.assert_allclose(np.asarray(o), want, rtol=1e-5, atol=1e-5)
