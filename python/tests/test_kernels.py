"""Kernel-vs-oracle correctness: hypothesis sweeps shapes, tiles, masks.

This is the CORE correctness signal for Layer 1: every Pallas kernel must
match its pure-jnp oracle to float32 tolerance on arbitrary shapes (padding
paths included), arbitrary tile sizes, and arbitrary validity masks.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    gauss_block_matvec,
    tsne_attr_block,
    meanshift_block,
    gamma_pairs,
    ref,
)

# Keep hypothesis deadlines off: interpret-mode pallas first-call tracing is
# slow and variable.
COMMON = dict(deadline=None, max_examples=25)

dims = st.sampled_from([1, 2, 3, 5, 8])
sizes = st.integers(min_value=1, max_value=70)
tiles = st.sampled_from([8, 16, 32])
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def _mk(rng, m, n, d):
    T = rng.normal(size=(m, d)).astype(np.float32)
    S = rng.normal(size=(n, d)).astype(np.float32)
    tv = (rng.random(m) < 0.85).astype(np.float32)
    sv = (rng.random(n) < 0.85).astype(np.float32)
    return T, S, tv, sv


@settings(**COMMON)
@given(m=sizes, n=sizes, d=dims, tm=tiles, tn=tiles, seed=seeds)
def test_gauss_matches_ref(m, n, d, tm, tn, seed):
    rng = np.random.default_rng(seed)
    T, S, tv, sv = _mk(rng, m, n, d)
    x = rng.normal(size=(n,)).astype(np.float32)
    got = np.asarray(gauss_block_matvec(T, S, x, tv, sv, 0.37, tm=tm, tn=tn))
    want = np.asarray(ref.gauss_block_matvec(T, S, x, tv, sv, 0.37))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(**COMMON)
@given(m=sizes, n=sizes, d=st.sampled_from([2, 3]), tm=tiles, tn=tiles, seed=seeds)
def test_tsne_matches_ref(m, n, d, tm, tn, seed):
    rng = np.random.default_rng(seed)
    Yt, Ys, tv, sv = _mk(rng, m, n, d)
    P = rng.random((m, n)).astype(np.float32)
    got = np.asarray(tsne_attr_block(Yt, Ys, P, tv, sv, tm=tm, tn=tn))
    want = np.asarray(ref.tsne_attr_block(Yt, Ys, P, tv, sv))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(**COMMON)
@given(m=sizes, n=sizes, d=dims, tm=tiles, tn=tiles, seed=seeds)
def test_meanshift_matches_ref(m, n, d, tm, tn, seed):
    rng = np.random.default_rng(seed)
    T, S, tv, sv = _mk(rng, m, n, d)
    gn, gd = meanshift_block(T, S, tv, sv, 0.21, tm=tm, tn=tn)
    wn, wd = ref.meanshift_block(T, S, tv, sv, 0.21)
    np.testing.assert_allclose(np.asarray(gn), np.asarray(wn), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd), rtol=2e-5, atol=2e-5)


@settings(**COMMON)
@given(m=sizes, n=sizes, tm=tiles, tn=tiles, seed=seeds)
def test_gamma_matches_ref(m, n, tm, tn, seed):
    rng = np.random.default_rng(seed)
    P = rng.integers(0, 200, size=(m, 2)).astype(np.float32)
    Q = rng.integers(0, 200, size=(n, 2)).astype(np.float32)
    pv = (rng.random(m) < 0.85).astype(np.float32)
    qv = (rng.random(n) < 0.85).astype(np.float32)
    got = float(gamma_pairs(P, Q, pv, qv, 1.0 / 25.0, tm=tm, tn=tn))
    want = float(ref.gamma_pairs(P, Q, pv, qv, 1.0 / 25.0))
    assert got == pytest.approx(want, rel=2e-4, abs=2e-4)


# ---------------------------------------------------------------------------
# Directed edge cases
# ---------------------------------------------------------------------------

def test_gauss_all_invalid_sources_is_zero():
    rng = np.random.default_rng(7)
    T, S, tv, _ = _mk(rng, 20, 30, 3)
    x = rng.normal(size=(30,)).astype(np.float32)
    sv = np.zeros(30, np.float32)
    y = np.asarray(gauss_block_matvec(T, S, x, tv, sv, 1.0, tm=16, tn=16))
    assert np.all(y == 0.0)


def test_gauss_identical_points_weight_one():
    # Coincident target/source: weight exp(0) = 1 regardless of bandwidth.
    P = np.zeros((1, 2), np.float32)
    one = np.ones(1, np.float32)
    y = np.asarray(gauss_block_matvec(P, P, 3.0 * one, one, one, 123.0, tm=8, tn=8))
    np.testing.assert_allclose(y, [3.0], rtol=1e-6)


def test_tsne_zero_p_gives_zero_force():
    rng = np.random.default_rng(8)
    Yt, Ys, tv, sv = _mk(rng, 17, 19, 2)
    P = np.zeros((17, 19), np.float32)
    F = np.asarray(tsne_attr_block(Yt, Ys, P, tv, sv, tm=8, tn=8))
    assert np.all(F == 0.0)


def test_tsne_force_is_attractive_pairwise():
    # Two points, P=1: force on y0 points toward y1.
    Yt = np.array([[0.0, 0.0]], np.float32)
    Ys = np.array([[1.0, 0.0]], np.float32)
    one = np.ones(1, np.float32)
    P = np.ones((1, 1), np.float32)
    F = np.asarray(tsne_attr_block(Yt, Ys, P, one, one, tm=8, tn=8))
    # F = p*q*(y_t - y_s) = 0.5 * (-1, 0): gradient *descent* direction is -F,
    # i.e. toward the source.
    np.testing.assert_allclose(F, [[-0.5, 0.0]], rtol=1e-6)


def test_meanshift_mean_of_identical_sources():
    # All sources at the same location: shifted mean must be that location.
    T = np.zeros((5, 3), np.float32)
    S = np.tile(np.array([[1.0, 2.0, 3.0]], np.float32), (11, 1))
    tv = np.ones(5, np.float32)
    sv = np.ones(11, np.float32)
    num, den = meanshift_block(T, S, tv, sv, 0.05, tm=8, tn=8)
    m = np.asarray(num) / np.asarray(den)[:, None]
    np.testing.assert_allclose(m, np.tile([[1, 2, 3]], (5, 1)), rtol=1e-5)


def test_gamma_single_pair_known_value():
    P = np.array([[0.0, 0.0]], np.float32)
    Q = np.array([[3.0, 4.0]], np.float32)  # dist^2 = 25
    one = np.ones(1, np.float32)
    g = float(gamma_pairs(P, Q, one, one, 1.0 / 25.0, tm=8, tn=8))
    assert g == pytest.approx(np.exp(-1.0), rel=1e-5)
