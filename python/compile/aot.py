"""AOT lowering: Layer-2 block programs → HLO text artifacts + manifest.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, under ``--out-dir`` (default ``../artifacts`` relative to this
package's parent, i.e. the repo's ``artifacts/``):

* ``<variant>.hlo.txt``  — one per entry in :data:`VARIANTS`;
* ``manifest.json``      — variant name → file, input shapes/dtypes, output
  shapes/dtypes; parsed by ``rust/src/runtime/artifact.rs``;
* ``golden/``            — deterministic input/output ``.bin`` tensors (raw
  little-endian f32) per variant, regenerated from the pure-jnp oracles via
  the block programs themselves; consumed by the Rust integration tests.

Run via ``make artifacts`` (incremental: skipped when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# Variant table: name -> (function, example args).
# M = N = 256 single blocks match the default CSB leaf cap in Rust
# (csb::hier::LEAF_POINTS); the *_b8 batched variants match the
# coordinator's default batch size.
def _variants():
    v = {}
    for d in (2, 3, 8):
        v[f"gauss_d{d}_m256"] = (
            model.gauss_block,
            (_spec(256, d), _spec(256, d), _spec(256), _spec(256), _spec(256), _spec()),
        )
        v[f"meanshift_d{d}_m256"] = (
            model.meanshift_blk,
            (_spec(256, d), _spec(256, d), _spec(256), _spec(256), _spec()),
        )
    for d in (2, 3):
        v[f"tsne_d{d}_m256"] = (
            model.tsne_block,
            (_spec(256, d), _spec(256, d), _spec(256, 256), _spec(256), _spec(256)),
        )
        v[f"tsne_d{d}_m128_b8"] = (
            model.tsne_block_batch,
            (
                _spec(8, 128, d), _spec(8, 128, d), _spec(8, 128, 128),
                _spec(8, 128), _spec(8, 128),
            ),
        )
        v[f"tsne_norm_d{d}_m256"] = (
            model.tsne_block_with_norm,
            (_spec(256, d), _spec(256, d), _spec(256, 256), _spec(256), _spec(256)),
        )
    v["gamma_m512"] = (
        model.gamma_block,
        (_spec(512, 2), _spec(512, 2), _spec(512), _spec(512), _spec()),
    )
    return v


VARIANTS = _variants()


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(name):
    fn, specs = VARIANTS[name]
    return jax.jit(fn).lower(*specs)


def _input_entry(spec):
    return {"shape": list(spec.shape), "dtype": str(spec.dtype)}


def _golden_inputs(specs, seed):
    """Deterministic dense inputs: scalars → 0.37; masks stay all-ones so the
    golden exercises the full block; coordinates/charges ~ U(-1, 1)."""
    rng = np.random.default_rng(seed)
    args = []
    for spec in specs:
        if len(spec.shape) == 0:
            args.append(np.float32(0.37))
        elif len(spec.shape) >= 2:
            args.append(
                rng.uniform(-1.0, 1.0, size=spec.shape).astype(np.float32)
            )
        else:
            # 1-D: charge or mask — use positive values; masks being
            # non-binary is fine (kernels multiply by them linearly).
            args.append(rng.uniform(0.1, 1.0, size=spec.shape).astype(np.float32))
    return args


def write_goldens(out_dir, name, specs, fn):
    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    seed = int.from_bytes(hashlib.sha256(name.encode()).digest()[:4], "little")
    args = _golden_inputs(specs, seed)
    outs = fn(*args)
    meta = {"inputs": [], "outputs": []}
    for i, a in enumerate(args):
        p = f"{name}.in{i}.bin"
        np.asarray(a, dtype=np.float32).tofile(os.path.join(gdir, p))
        meta["inputs"].append({"file": p, "shape": list(np.shape(a))})
    for i, o in enumerate(outs):
        p = f"{name}.out{i}.bin"
        np.asarray(o, dtype=np.float32).tofile(os.path.join(gdir, p))
        meta["outputs"].append({"file": p, "shape": list(np.shape(o))})
    return meta


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=None, help="artifacts directory")
    ap.add_argument("--out", default=None, help="(compat) ignored marker path")
    ap.add_argument("--only", default=None, help="lower a single variant")
    ap.add_argument("--no-goldens", action="store_true")
    args = ap.parse_args(argv)

    out_dir = args.out_dir
    if out_dir is None:
        here = os.path.dirname(os.path.abspath(__file__))
        out_dir = os.path.join(os.path.dirname(os.path.dirname(here)), "artifacts")
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "variants": {}}
    names = [args.only] if args.only else list(VARIANTS)
    for name in names:
        fn, specs = VARIANTS[name]
        text = to_hlo_text(lower_variant(name))
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry = {
            "file": fname,
            "inputs": [_input_entry(s) for s in specs],
        }
        if not args.no_goldens:
            entry["golden"] = write_goldens(out_dir, name, specs, fn)
        manifest["variants"][name] = entry
        print(f"lowered {name}: {len(text)} chars", file=sys.stderr)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    # Stamp file so `make artifacts` is incremental.
    with open(os.path.join(out_dir, ".stamp"), "w") as f:
        f.write("ok\n")
    print(f"wrote {len(names)} artifacts to {out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
