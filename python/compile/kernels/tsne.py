"""Pallas kernel: t-SNE attractive force for one dense cluster-pair block.

The paper's first case study (§3.1): at every t-SNE iteration the attractive
term of the KL gradient is a near-neighbor interaction whose *values* depend
on the current embedding coordinates:

    q~_ij = 1 / (1 + |y_i - y_j|^2)
    F_i   = sum_j P_ij * q~_ij * (y_i - y_j)
          = (sum_j w_ij) * y_i - sum_j w_ij * y_j ,   w_ij = P_ij * q~_ij .

The sparsity profile of P is fixed across iterations (the kNN graph of the
*original* feature-space data), so the hierarchical ordering is computed
once; the per-iteration work is exactly this kernel over the dense blocks of
the reordered matrix.  Fusing the value refresh (q~ from coordinates) with
the multiply is the non-stationary analogue of SpMV — and on TPU it makes
the block computation two MXU matmuls (Y_t·Y_sᵀ for distances, w·Y_s for the
force) plus VPU element-wise work.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common
from .common import INTERPRET, TILE_M, TILE_N


def _kernel(yt_ref, ys_ref, p_ref, tv_ref, sv_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    yt = yt_ref[...]
    ys = ys_ref[...]
    d2 = common.tile_sqdist(yt, ys)
    w = p_ref[...] / (1.0 + d2)
    w = w * tv_ref[...][:, None] * sv_ref[...][None, :]
    row = jnp.sum(w, axis=1, keepdims=True)
    o_ref[...] += row * yt - jnp.dot(w, ys, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tm", "tn"))
def tsne_attr_block(Yt, Ys, P, t_valid, s_valid, *, tm=TILE_M, tn=TILE_N):
    """Attractive-force block F (M, d) for embedding tiles Yt (M, d),
    Ys (N, d) and densified joint probabilities P (M, N).

    Padding to tile multiples is handled here; padded rows/cols are masked.
    """
    M, d = Yt.shape
    N = Ys.shape[0]
    mp, np_ = common.round_up(M, tm), common.round_up(N, tn)

    Ytp = common.pad_axis(Yt.astype(jnp.float32), 0, mp)
    Ysp = common.pad_axis(Ys.astype(jnp.float32), 0, np_)
    Pp = common.pad_axis(common.pad_axis(P.astype(jnp.float32), 0, mp), 1, np_)
    tvp = common.pad_mask(t_valid.astype(jnp.float32), mp)
    svp = common.pad_mask(s_valid.astype(jnp.float32), np_)

    grid = (mp // tm, np_ // tn)
    F = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
            pl.BlockSpec((tm,), lambda i, j: (i,)),
            pl.BlockSpec((tn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, d), jnp.float32),
        interpret=INTERPRET,
    )(Ytp, Ysp, Pp, tvp, svp)
    return F[:M]
