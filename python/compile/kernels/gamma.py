"""Pallas kernel: gamma-score (Eq. 4) partial pair sums.

The paper's numerical estimate of the patch-density measure is a Gaussian
sum over all pairs of *nonzero index positions* of the matrix:

    gamma(A; sigma) = 1/(sigma nnz) * sum_{p,q in Inz(A)}
                        exp(-|p - q|^2 / sigma^2) .

Treating the nonzero positions as 2-D points, the double sum is itself a
dense all-pairs interaction — so it reuses the same tiling scheme as the
coordinate kernels, with d = 2 and a scalar accumulator.  (The Rust side
also has a grid-truncated O(nnz) estimator for production use; this kernel
is the exact tile-sum used for cross-validation and for the Fig. 1 numbers.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common
from .common import INTERPRET, TILE_M, TILE_N


def _kernel(p_ref, q_ref, pv_ref, qv_ref, s_ref, o_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d2 = common.tile_sqdist(p_ref[...], q_ref[...])
    w = jnp.exp(-d2 * s_ref[0])
    w = w * pv_ref[...][:, None] * qv_ref[...][None, :]
    o_ref[0] += jnp.sum(w)


@functools.partial(jax.jit, static_argnames=("tm", "tn"))
def gamma_pairs(P, Q, p_valid, q_valid, inv_s2, *, tm=TILE_M, tn=TILE_N):
    """Σ_{i,j} exp(−‖P[i]−Q[j]‖²·inv_s2) over valid pairs (scalar, f32).

    P (M, 2), Q (N, 2) are nonzero index positions as floats;
    inv_s2 = 1/σ².  Caller normalizes by 1/(σ·nnz) and sums tile pairs.
    """
    M = P.shape[0]
    N = Q.shape[0]
    mp, np_ = common.round_up(M, tm), common.round_up(N, tn)

    Pp = common.pad_axis(P.astype(jnp.float32), 0, mp)
    Qp = common.pad_axis(Q.astype(jnp.float32), 0, np_)
    pvp = common.pad_mask(p_valid.astype(jnp.float32), mp)
    qvp = common.pad_mask(q_valid.astype(jnp.float32), np_)
    s = jnp.asarray(inv_s2, jnp.float32).reshape((1,))

    grid = (mp // tm, np_ // tn)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, 2), lambda i, j: (j, 0)),
            pl.BlockSpec((tm,), lambda i, j: (i,)),
            pl.BlockSpec((tn,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, j: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=INTERPRET,
    )(Pp, Qp, pvp, qvp, s)
    return out[0]
