"""Pallas kernel: mean-shift numerator/denominator for one cluster pair.

The paper's second case study (§3.2): one mean-shift iteration moves each
target (current mean estimate) t_i to

    m_i = ( sum_j w_ij s_j ) / ( sum_j w_ij ),
    w_ij = exp(-|t_i - s_j|^2 * inv_h2)

over its near-neighbor sources.  Sources are stationary; targets migrate, so
the interaction matrix profile *and* values change across iterations — the
target-side clustering is refreshed at a lower cadence by the coordinator.

This kernel computes the per-block partial numerator (M, d) and denominator
(M,); the L3 engine reduces across all source blocks touching a target
cluster and performs the division.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common
from .common import INTERPRET, TILE_M, TILE_N


def _kernel(t_ref, s_ref, tv_ref, sv_ref, h_ref, num_ref, den_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        num_ref[...] = jnp.zeros_like(num_ref)
        den_ref[...] = jnp.zeros_like(den_ref)

    s = s_ref[...]
    d2 = common.tile_sqdist(t_ref[...], s)
    w = jnp.exp(-d2 * h_ref[0])
    w = w * tv_ref[...][:, None] * sv_ref[...][None, :]
    num_ref[...] += jnp.dot(w, s, preferred_element_type=jnp.float32)
    den_ref[...] += jnp.sum(w, axis=1)


@functools.partial(jax.jit, static_argnames=("tm", "tn"))
def meanshift_block(T, S, t_valid, s_valid, inv_h2, *, tm=TILE_M, tn=TILE_N):
    """Partial mean-shift sums for targets T (M, d) against sources S (N, d).

    Returns (num (M, d), den (M,)) float32, padded entries zero.
    """
    M, d = T.shape
    N = S.shape[0]
    mp, np_ = common.round_up(M, tm), common.round_up(N, tn)

    Tp = common.pad_axis(T.astype(jnp.float32), 0, mp)
    Sp = common.pad_axis(S.astype(jnp.float32), 0, np_)
    tvp = common.pad_mask(t_valid.astype(jnp.float32), mp)
    svp = common.pad_mask(s_valid.astype(jnp.float32), np_)
    h = jnp.asarray(inv_h2, jnp.float32).reshape((1,))

    grid = (mp // tm, np_ // tn)
    num, den = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tm,), lambda i, j: (i,)),
            pl.BlockSpec((tn,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tm,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, d), jnp.float32),
            jax.ShapeDtypeStruct((mp,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(Tp, Sp, tvp, svp, h)
    return num[:M], den[:M]
