"""Layer-1 Pallas kernels: the dense cluster-pair interaction hot-spots.

Each module exposes one jitted, padded, masked block primitive; ``ref.py``
holds the pure-jnp oracles.  See DESIGN.md §Hardware-Adaptation for the
CPU-cache → TPU-VMEM mapping rationale.
"""

from .gauss import gauss_block_matvec
from .tsne import tsne_attr_block
from .meanshift import meanshift_block
from .gamma import gamma_pairs

__all__ = [
    "gauss_block_matvec",
    "tsne_attr_block",
    "meanshift_block",
    "gamma_pairs",
]
