"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: each function computes, with plain
``jax.numpy`` ops and no tiling tricks, exactly what the corresponding Pallas
kernel is supposed to compute.  ``python/tests`` sweeps shapes and dtypes
with hypothesis and asserts ``allclose`` between kernel and oracle; the AOT
goldens consumed by the Rust integration tests are also generated from these
functions.

Conventions
-----------
* ``T``: target coordinates, shape (M, d) — the *rows* of the interaction
  block (response points).
* ``S``: source coordinates, shape (N, d) — the *columns* (reference
  points).
* ``x``: charge vector over the sources, shape (N,) or (N, c).
* Masks: blocks are padded to fixed tile shapes for AOT; ``t_valid`` /
  ``s_valid`` are 0/1 float masks of shape (M,) / (N,).  Padded entries
  contribute nothing.
"""

from __future__ import annotations

import jax.numpy as jnp


def pairwise_sqdist(T, S):
    """Squared Euclidean distances, shape (M, N).

    Uses the expanded form ``|t|^2 + |s|^2 - 2 t.s`` — the same algebra the
    Pallas kernels use so that floating-point behaviour matches — clamped at
    zero against negative round-off.
    """
    t2 = jnp.sum(T * T, axis=1, keepdims=True)  # (M, 1)
    s2 = jnp.sum(S * S, axis=1, keepdims=True).T  # (1, N)
    d2 = t2 + s2 - 2.0 * (T @ S.T)
    return jnp.maximum(d2, 0.0)


def gauss_block_matvec(T, S, x, t_valid, s_valid, inv_h2):
    """Gaussian near-neighbor interaction of one cluster pair.

    y_i = sum_j exp(-|t_i - s_j|^2 * inv_h2) * x_j   over valid j,
    returned only for valid i (invalid rows are zero).

    ``inv_h2`` is ``1 / (2 h^2)`` for bandwidth h (scalar, folded by caller).
    """
    d2 = pairwise_sqdist(T, S)
    w = jnp.exp(-d2 * inv_h2)
    w = w * t_valid[:, None] * s_valid[None, :]
    return w @ x


def tsne_attr_block(Yt, Ys, P, t_valid, s_valid):
    """t-SNE attractive force contribution of one cluster pair.

    Given embedding coordinates Yt (M, d), Ys (N, d) and the (sparse, here
    densified per block) joint probabilities P (M, N):

        q~_ij = 1 / (1 + |y_i - y_j|^2)          (Student-t numerator)
        F_i  += sum_j P_ij * q~_ij * (y_i - y_j)

    Returns F of shape (M, d).  This is the non-stationary kernel: values
    q~_ij are recomputed from coordinates at every t-SNE iteration while the
    sparsity profile (which P entries are nonzero) stays fixed.
    """
    d2 = pairwise_sqdist(Yt, Ys)
    qn = 1.0 / (1.0 + d2)
    w = P * qn * t_valid[:, None] * s_valid[None, :]
    # F_i = (sum_j w_ij) * y_i - sum_j w_ij y_j
    row = jnp.sum(w, axis=1, keepdims=True)  # (M, 1)
    return row * Yt - w @ Ys


def meanshift_block(T, S, t_valid, s_valid, inv_h2):
    """Mean-shift numerator and denominator of one cluster pair.

    w_ij  = exp(-|t_i - s_j|^2 * inv_h2)
    num_i = sum_j w_ij * s_j        (M, d)
    den_i = sum_j w_ij              (M,)

    The caller forms the shifted mean  m_i = num_i / den_i  after reducing
    over all source clusters interacting with target cluster i.
    """
    d2 = pairwise_sqdist(T, S)
    w = jnp.exp(-d2 * inv_h2)
    w = w * t_valid[:, None] * s_valid[None, :]
    num = w @ S
    den = jnp.sum(w, axis=1)
    return num, den


def gamma_pairs(P, Q, p_valid, q_valid, inv_s2):
    """Partial gamma-score sum (Eq. 4) over two tiles of nonzero positions.

    P: (M, 2) float — (row, col) index positions of nonzeros (tile A)
    Q: (N, 2) float — positions (tile B)
    returns  sum_{i,j} exp(-|p_i - q_j|^2 * inv_s2)  over valid pairs,
    where inv_s2 = 1 / sigma^2.

    The full gamma score is  (1 / (sigma * nnz)) * sum over all tile pairs.
    """
    d2 = pairwise_sqdist(P, Q)
    w = jnp.exp(-d2 * inv_s2)
    w = w * p_valid[:, None] * q_valid[None, :]
    return jnp.sum(w)
