"""Shared tiling helpers for the Pallas kernels.

All kernels in this package follow the same scheme, which is the TPU
translation of the paper's block-by-block CPU traversal (DESIGN.md
§Hardware-Adaptation):

* one *dense cluster-pair block* = one (target-tile × source-tile) step of a
  Pallas grid;
* the BlockSpec index maps express the HBM→VMEM streaming schedule that the
  paper expressed with its multi-level compressed-sparse-block traversal;
* pairwise distances inside a tile use the expanded ``|t|² + |s|² − 2·T·Sᵀ``
  form so the bulk of the FLOPs are a matmul (MXU-shaped), not elementwise.

Everything is lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode is the execution path and real-TPU
performance is assessed analytically (EXPERIMENTS.md §Perf-L1).
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

# Default tile sizes.  (128, 128) keeps the VMEM working set of the largest
# kernel (tsne_attr: two coord tiles + one P tile + one F tile) at
# 128·128·4 B ≈ 64 KiB for the P tile plus a few KiB of vectors — far under
# the ≈16 MiB VMEM of a modern TPU core, leaving room for double-buffering.
TILE_M = 128
TILE_N = 128

INTERPRET = True  # CPU PJRT: interpret-mode Pallas only (see module doc).


def round_up(n: int, t: int) -> int:
    """Smallest multiple of ``t`` that is >= ``n`` (and >= t)."""
    if n <= 0:
        return t
    return ((n + t - 1) // t) * t


def pad_axis(a, axis: int, to: int):
    """Zero-pad array ``a`` along ``axis`` up to length ``to``."""
    n = a.shape[axis]
    if n == to:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, to - n)
    return jnp.pad(a, widths)


def pad_mask(mask, to: int):
    """Pad a 0/1 validity mask with zeros (padded entries are invalid)."""
    return pad_axis(mask, 0, to)


def tile_sqdist(t_tile, s_tile):
    """Pairwise squared distances between two coordinate tiles.

    Shapes: t_tile (TM, d), s_tile (TN, d) → (TM, TN).  The ``T @ Sᵀ``
    contraction is the MXU-shaped bulk of the work; the rank-1 corrections
    are VPU element-wise ops.  Clamped at zero against round-off.
    """
    t2 = jnp.sum(t_tile * t_tile, axis=1, keepdims=True)
    s2 = jnp.sum(s_tile * s_tile, axis=1, keepdims=True).T
    d2 = t2 + s2 - 2.0 * jnp.dot(
        t_tile, s_tile.T, preferred_element_type=jnp.float32
    )
    return jnp.maximum(d2, 0.0)


def static_kernel(fn):
    """functools.partial-with-kwargs helper kept for symmetry/readability."""
    return functools.partial(fn)
