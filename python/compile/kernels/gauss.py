"""Pallas kernel: Gaussian cluster-pair interaction matvec.

The stationary near-neighbor interaction hot-spot (paper Eq. 1 with a
Gaussian kernel, the mean-shift / SNE workhorse): for one *dense block* of
the reordered interaction matrix — a target cluster T against a source
cluster S — compute

    y_i = sum_j exp(-|t_i - s_j|^2 * inv_h2) * x_j .

The Pallas grid is (target tiles × source tiles); each step loads a
(TILE_M, d) coordinate tile and a (TILE_N, d) coordinate tile into VMEM,
forms pairwise distances via one MXU matmul, applies the kernel on the VPU,
and accumulates the tile matvec into the output segment.  Grid iteration
order is row-major, so for a fixed target tile all source tiles stream
through VMEM while the y segment stays resident — the TPU image of the
paper's "access the nonzero elements block by block; the charge and
potential vectors, segment by segment".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common
from .common import INTERPRET, TILE_M, TILE_N


def _kernel(t_ref, s_ref, x_ref, tv_ref, sv_ref, h_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d2 = common.tile_sqdist(t_ref[...], s_ref[...])
    w = jnp.exp(-d2 * h_ref[0])
    w = w * tv_ref[...][:, None] * sv_ref[...][None, :]
    o_ref[...] += jnp.dot(w, x_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tm", "tn"))
def gauss_block_matvec(T, S, x, t_valid, s_valid, inv_h2, *, tm=TILE_M, tn=TILE_N):
    """y[i] = Σ_j exp(−‖T[i]−S[j]‖²·inv_h2)·x[j] over valid i, j.

    Shapes: T (M, d), S (N, d), x (N,), t_valid (M,), s_valid (N,),
    inv_h2 scalar (≡ 1/(2h²)).  Returns y (M,) float32.  Arbitrary M, N —
    inputs are zero-padded to tile multiples, padding masked out.
    """
    M, d = T.shape
    N = S.shape[0]
    mp, np_ = common.round_up(M, tm), common.round_up(N, tn)

    Tp = common.pad_axis(T.astype(jnp.float32), 0, mp)
    Sp = common.pad_axis(S.astype(jnp.float32), 0, np_)
    xp = common.pad_axis(x.astype(jnp.float32), 0, np_)
    tvp = common.pad_mask(t_valid.astype(jnp.float32), mp)
    svp = common.pad_mask(s_valid.astype(jnp.float32), np_)
    h = jnp.asarray(inv_h2, jnp.float32).reshape((1,))

    grid = (mp // tm, np_ // tn)
    y = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tn,), lambda i, j: (j,)),
            pl.BlockSpec((tm,), lambda i, j: (i,)),
            pl.BlockSpec((tn,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.float32),
        interpret=INTERPRET,
    )(Tp, Sp, xp, tvp, svp, h)
    return y[:M]
