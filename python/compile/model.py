"""Layer-2: fixed-shape block programs composed from the Pallas kernels.

Every function here is a *block program*: a jax function over concrete,
AOT-friendly shapes that the Rust coordinator executes via PJRT on the dense
blocks produced by the hierarchical reordering.  The contract with Layer 3:

* shapes are fixed per artifact variant (see ``aot.VARIANTS``); the Rust
  side pads a cluster-pair block to the variant's (M, N) with zeroed
  validity masks;
* all inputs/outputs are float32, row-major, and the lowered computation
  returns a tuple (``return_tuple=True`` in the HLO conversion) which the
  Rust runtime unpacks;
* Python is never on the request path — these functions are lowered once by
  ``aot.py`` into ``artifacts/*.hlo.txt``.

The batched variants (leading axis B) amortize PJRT dispatch overhead: the
coordinator's batcher groups B leaf blocks and issues one execution — the
TPU analogue of the paper's observation that blocks must be large enough to
amortize the per-block indirection cost.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import (
    gauss_block_matvec,
    tsne_attr_block,
    meanshift_block,
    gamma_pairs,
)


# --------------------------------------------------------------------------
# Single-block programs
# --------------------------------------------------------------------------

def gauss_block(T, S, x, t_valid, s_valid, inv_h2):
    """One Gaussian cluster-pair matvec block.  Returns (y,)."""
    return (gauss_block_matvec(T, S, x, t_valid, s_valid, inv_h2),)


def tsne_block(Yt, Ys, P, t_valid, s_valid):
    """One t-SNE attractive-force block.  Returns (F,)."""
    return (tsne_attr_block(Yt, Ys, P, t_valid, s_valid),)


def meanshift_blk(T, S, t_valid, s_valid, inv_h2):
    """One mean-shift partial-sums block.  Returns (num, den)."""
    num, den = meanshift_block(T, S, t_valid, s_valid, inv_h2)
    return (num, den)


def gamma_block(P, Q, p_valid, q_valid, inv_s2):
    """One gamma-score tile-pair partial sum.  Returns (partial,) shape (1,)."""
    return (gamma_pairs(P, Q, p_valid, q_valid, inv_s2).reshape((1,)),)


# --------------------------------------------------------------------------
# Batched programs (vmapped over a leading block axis)
# --------------------------------------------------------------------------

def tsne_block_batch(Yt, Ys, P, t_valid, s_valid):
    """B independent t-SNE attractive blocks in one dispatch.

    Shapes: Yt (B, M, d), Ys (B, N, d), P (B, M, N), masks (B, M)/(B, N).
    Returns (F,) with F (B, M, d).
    """
    f = jax.vmap(tsne_attr_block, in_axes=(0, 0, 0, 0, 0))
    return (f(Yt, Ys, P, t_valid, s_valid),)


def gauss_block_batch(T, S, x, t_valid, s_valid, inv_h2):
    """B independent Gaussian matvec blocks in one dispatch.

    inv_h2 is shared across the batch (scalar).  Returns (y,) with (B, M).
    """
    f = jax.vmap(gauss_block_matvec, in_axes=(0, 0, 0, 0, 0, None))
    return (f(T, S, x, t_valid, s_valid, inv_h2),)


def meanshift_block_batch(T, S, t_valid, s_valid, inv_h2):
    """B independent mean-shift partial-sum blocks.  Returns (num, den)."""
    f = jax.vmap(meanshift_block, in_axes=(0, 0, 0, 0, None))
    num, den = f(T, S, t_valid, s_valid, inv_h2)
    return (num, den)


# --------------------------------------------------------------------------
# Whole-iteration fused programs (used by the end-to-end example): one
# dispatch computes the full dense attractive force of a *single* cluster
# pair plus the Frobenius norm used for convergence monitoring.
# --------------------------------------------------------------------------

def tsne_block_with_norm(Yt, Ys, P, t_valid, s_valid):
    """t-SNE attractive block + squared force norm (convergence metric)."""
    (F,) = tsne_block(Yt, Ys, P, t_valid, s_valid)
    return (F, jnp.sum(F * F).reshape((1,)))
