//! Minimal, dependency-free stand-in for the `anyhow` error crate.
//!
//! The build environment is fully offline (no crates.io access), so this
//! vendored shim provides exactly the subset the `nni` runtime layer uses:
//! [`Error`], [`Result`], the [`anyhow!`] macro, and the [`Context`]
//! extension trait.  Semantics match `anyhow` where the callers rely on
//! them: `{}` formats the outermost message only, `{:#}` prints the whole
//! context chain (`outer: inner: root`), and any `std::error::Error` value
//! converts into [`Error`] via `?`.

use std::fmt;

/// An error message with an optional chained cause.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error {
            msg: c.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The innermost message of the chain.
    pub fn root_cause(&self) -> &str {
        let mut e = self;
        while let Some(s) = &e.source {
            e = s;
        }
        &e.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = &self.source;
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = &e.source;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:#}")
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the std error's source chain into our layered form.
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(Error {
                msg,
                source: err.map(Box::new),
            });
        }
        err.unwrap()
    }
}

/// `anyhow`-style result alias (error type defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Attach context to the error branch of a result.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_plain_vs_alternate() {
        let e = Error::msg("root").context("mid").context("top");
        assert_eq!(format!("{e}"), "top");
        assert_eq!(format!("{e:#}"), "top: mid: root");
        assert_eq!(e.root_cause(), "root");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn io_fail() -> Result<()> {
            std::fs::read("/nonexistent-path-xyz/f")?;
            Ok(())
        }
        let err = io_fail().unwrap_err();
        assert!(!format!("{err}").is_empty());
    }

    #[test]
    fn context_on_io_result() {
        let r: std::io::Result<()> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let err = r.with_context(|| format!("reading {}", "x")).unwrap_err();
        assert_eq!(format!("{err}"), "reading x");
        assert!(format!("{err:#}").contains("gone"));
    }

    #[test]
    fn macro_formats() {
        let e = anyhow!("bad {} at {}", "value", 3);
        assert_eq!(format!("{e}"), "bad value at 3");
    }
}
