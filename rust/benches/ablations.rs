//! **Ablations** for the design claims of §2.4 / §4.3:
//!
//! 1. *multi-level vs single-level (flat) traversal* of the same blocked
//!    matrix ("multi-level computation of interactions outperforms its
//!    single-level counterpart");
//! 2. *multi-dimensional vs 1-D embedding* for the same hierarchical
//!    method (γ across embedding dimension 1/2/3);
//! 3. *hierarchical vs lexical* ordering in the same embedding space;
//! 4. *block capacity sweep* — the perf-pass finding that blocking
//!    granularity trades PJRT tile fit against row shredding;
//! 5. *dense-storage threshold sweep* — dense blocks trade wasted flops
//!    for streaming access.

use nni::bench::{pipeline_for, print_header, Table, Workload};
use nni::csb::hier::HierCsb;
use nni::order::OrderingKind;
use nni::profile::gamma;
use nni::spmv;
use nni::util::cli::Args;
use nni::util::timer::bench_default;

fn main() {
    let a = Args::new("ablations over the design choices of §2.4")
        .opt("n", "8192", "points")
        .opt("seed", "42", "rng seed")
        .parse();
    let n = a.get_usize("n");
    print_header("ablations", "§2.4 design-choice ablations");
    let wl = Workload::Sift;
    let (ds, m) = wl.make(n, a.get_u64("seed"), 0);
    let sigma = wl.k() as f64 / 2.0;

    // --- 1. multilevel vs flat traversal -------------------------------
    let dt = pipeline_for(&OrderingKind::DualTree { d: 3 }, a.get_u64("seed")).run(&ds, &m);
    let tree = dt.tree.as_ref().unwrap();
    let csb = HierCsb::build(&dt.reordered, tree, tree, 2048);
    let x = vec![1.0f32; n];
    let mut y = vec![0.0f32; n];
    let t_ml = bench_default(|| spmv::multilevel::spmv_ml_seq(&csb, &x, &mut y));
    let flat = csb.flat_order();
    let t_flat = bench_default(|| csb.spmv_ordered(&flat, &x, &mut y));
    let mut t1 = Table::new("ablation_traversal", &["schedule", "ms", "vs_flat"]);
    t1.row(vec![
        "multi-level".into(),
        format!("{:.3}", t_ml.robust_min_s * 1e3),
        format!("{:.2}", t_flat.robust_min_s / t_ml.robust_min_s),
    ]);
    t1.row(vec![
        "flat (CSB-like)".into(),
        format!("{:.3}", t_flat.robust_min_s * 1e3),
        "1.00".into(),
    ]);
    t1.finish();

    // --- 2+3. embedding dimension × ordering style ----------------------
    let mut t2 = Table::new(
        "ablation_embedding",
        &["ordering", "dim", "gamma", "bandwidth"],
    );
    for d in [1usize, 2, 3] {
        for (style, kind) in [
            ("lexical", OrderingKind::Lex { d }),
            ("dual-tree", OrderingKind::DualTree { d }),
        ] {
            let kind = if d == 1 && style == "lexical" {
                OrderingKind::Pca1d
            } else {
                kind
            };
            let r = pipeline_for(&kind, a.get_u64("seed")).run(&ds, &m);
            t2.row(vec![
                style.into(),
                d.to_string(),
                format!("{:.2}", gamma::gamma_fast(&r.reordered, sigma)),
                r.reordered.bandwidth().to_string(),
            ]);
        }
    }
    t2.finish();
    println!("expected: gamma grows with dim; dual-tree >= lexical per dim\n");

    // --- 4. block capacity sweep ----------------------------------------
    let mut t3 = Table::new("ablation_block_cap", &["block_cap", "blocks", "ms"]);
    for cap in [128usize, 256, 512, 1024, 2048, 4096] {
        let c = HierCsb::build(&dt.reordered, tree, tree, cap);
        let t = bench_default(|| spmv::multilevel::spmv_ml_seq(&c, &x, &mut y));
        t3.row(vec![
            cap.to_string(),
            c.blocks.len().to_string(),
            format!("{:.3}", t.robust_min_s * 1e3),
        ]);
    }
    t3.finish();
    println!("expected: small caps shred rows (per-block-row overhead); large caps");
    println!("lose blocking; sweet spot ~64x nnz/row (EXPERIMENTS.md §Perf)\n");

    // --- 5. dense threshold sweep ---------------------------------------
    let mut t4 = Table::new(
        "ablation_dense_threshold",
        &["threshold", "dense_frac", "ms"],
    );
    for thr in [0.1f64, 0.25, 0.5, 0.75, 1.01] {
        let c = HierCsb::build_with(&dt.reordered, tree, tree, 256, thr);
        let t = bench_default(|| spmv::multilevel::spmv_ml_seq(&c, &x, &mut y));
        t4.row(vec![
            format!("{thr}"),
            format!("{:.2}", c.dense_fraction()),
            format!("{:.3}", t.robust_min_s * 1e3),
        ]);
    }
    t4.finish();
    println!("expected: low thresholds waste flops on zeros in SpMV (they exist for");
    println!("the PJRT artifact path, where padded dense tiles are free on the MXU)");
}
