//! **Fig. 3 reproduction**: t-SNE attractive-force execution time under the
//! six orderings, sequential (top plots) and parallel (bottom plots),
//! across problem sizes — normalized to the scattered-sequential time, the
//! paper's own reference.  The dotted-gray roofline of the paper (the
//! banded/scattered MKL SpMV ratio from the §4.1 micro-benchmark) is
//! reported alongside, computed on this machine.
//!
//! Output: one row per (workload, n): the time ratio (scattered-seq /
//! ordering-time) per ordering — higher is better, 1.0 = reference.
//!
//! Testbed note (EXPERIMENTS.md): on a single-core container with a 260 MB
//! LLC the roofline ratio is ≈1.0 and parallel speedups cannot exceed 1 —
//! the *ranking* across orderings and the γ-consistency are the
//! reproducible shape here.

use nni::bench::{pipeline_for, print_header, Table, Workload};
use nni::csb::hier::HierCsb;
use nni::interact::engine::Engine;
use nni::order::OrderingKind;
use nni::par::pool::default_threads;
use nni::sparse::gen;
use nni::spmv;
use nni::util::cli::Args;
use nni::util::rng::Rng;
use nni::util::timer::bench_default;

fn main() {
    let a = Args::new("Fig. 3: attractive-force time ratios per ordering")
        .opt("sizes", "2048,4096,8192", "problem sizes (paper: 2^11..2^17)")
        .opt("seed", "42", "rng seed")
        .opt("threads", "0", "0 = all cores")
        .opt("block-cap", "2048", "CSB block capacity")
        .flag("gist", "also run the GIST-like workload (slow kNN at D=960)")
        .parse();
    let threads = if a.get_usize("threads") == 0 {
        default_threads()
    } else {
        a.get_usize("threads")
    };
    print_header(
        "fig3_throughput",
        "Fig. 3 — t-SNE attractive force, seq + parallel, normalized to scattered-seq",
    );

    let kinds = OrderingKind::table1_set();
    let mut cols: Vec<String> = vec!["set".into(), "n".into(), "roofline".into()];
    for k in &kinds {
        cols.push(format!("{}(seq)", k.label()));
    }
    for k in &kinds {
        cols.push(format!("{}(par{threads})", k.label()));
    }
    let colrefs: Vec<&str> = cols.iter().map(String::as_str).collect();
    let mut table = Table::new("fig3_throughput", &colrefs);

    let workloads: Vec<Workload> = if a.get_flag("gist") {
        vec![Workload::Sift, Workload::Gist]
    } else {
        vec![Workload::Sift]
    };
    for wl in workloads {
        for &n in &a.get_usize_list("sizes") {
            let (ds, m) = wl.make(n, a.get_u64("seed"), threads);
            // Roofline: banded vs scattered CSR SpMV at matched sparsity
            // (the paper's dotted gray line, measured on this machine).
            let per_row = (m.nnz() / n).max(1);
            let banded = gen::banded(n, per_row, 7);
            let scat_m = gen::scattered(n, per_row, 7);
            let x = vec![1.0f32; n];
            let mut yv = vec![0.0f32; n];
            let t_band = bench_default(|| spmv::csr::spmv_seq(&banded, &x, &mut yv));
            let t_scat_m = bench_default(|| spmv::csr::spmv_seq(&scat_m, &x, &mut yv));
            let roofline = t_scat_m.robust_min_s / t_band.robust_min_s;

            // Embedding coordinates for the force evaluation (tree order
            // per ordering; d=2 like the paper's visual case).
            let d = 2;
            let mut rng = Rng::new(9);
            let y0: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();

            // Reference: scattered ordering, sequential.
            let mut times_seq = Vec::new();
            let mut times_par = Vec::new();
            for kind in &kinds {
                let r = pipeline_for(kind, a.get_u64("seed")).run(&ds, &m);
                // CSB requires a tree; non-tree orderings get one from a
                // boxtree over the permuted embedding when available, else
                // a trivial 1-D tree over positions (flat blocking), which
                // is exactly what a non-hierarchical ordering offers.
                let engine = match (&r.tree, &r.embedded) {
                    (Some(tree), _) => {
                        let csb = HierCsb::build(&r.reordered, tree, tree, a.get_usize("block-cap"));
                        Engine::new(csb, threads)
                    }
                    (None, _) => {
                        // position tree: balanced intervals over 0..n
                        let pos_ds = nni::data::dataset::Dataset::new(
                            n,
                            1,
                            (0..n).map(|i| i as f32).collect(),
                        );
                        let tree = nni::tree::boxtree::BoxTree::build(&pos_ds, 16, 32);
                        // tree.perm is identity for sorted 1-D data
                        let csb =
                            HierCsb::build(&r.reordered, &tree, &tree, a.get_usize("block-cap"));
                        Engine::new(csb, threads)
                    }
                };
                let yt: Vec<f32> = {
                    // tree order of the embedding coordinates
                    let mut v = vec![0.0f32; n * d];
                    for (k, &p) in r.perm.iter().enumerate() {
                        v[k * d..(k + 1) * d].copy_from_slice(&y0[p * d..(p + 1) * d]);
                    }
                    v
                };
                let mut force = vec![0.0f32; n * d];
                let eng_seq = Engine::new(engine.csb.clone(), 1);
                let t_seq = bench_default(|| eng_seq.tsne_attr(&yt, d, &mut force));
                let t_par = bench_default(|| engine.tsne_attr(&yt, d, &mut force));
                times_seq.push(t_seq.robust_min_s);
                times_par.push(t_par.robust_min_s);
            }
            let reference = times_seq[0]; // scattered sequential
            let mut cells = vec![
                wl.name().to_string(),
                n.to_string(),
                format!("{roofline:.2}"),
            ];
            for t in &times_seq {
                cells.push(format!("{:.2}", reference / t));
            }
            for t in &times_par {
                cells.push(format!("{:.2}", reference / t));
            }
            table.row(cells);
        }
    }
    table.finish();
    println!("\nvalues are speedups over scattered-sequential (paper's reference line).");
    println!("expected shape: 3D DT highest among orderings; sequential DT approaches");
    println!("the roofline column; parallel values scale with available cores.");
}
