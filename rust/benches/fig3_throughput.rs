//! **Fig. 3 reproduction**: t-SNE attractive-force execution time under the
//! six orderings, sequential (top plots) and parallel (bottom plots),
//! across problem sizes — normalized to the scattered-sequential time, the
//! paper's own reference.  The dotted-gray roofline of the paper (the
//! banded/scattered MKL SpMV ratio from the §4.1 micro-benchmark) is
//! reported alongside, computed on this machine.
//!
//! Output: one row per (workload, n): the time ratio (scattered-seq /
//! ordering-time) per ordering — higher is better, 1.0 = reference.
//!
//! Testbed note (EXPERIMENTS.md): on a single-core container with a 260 MB
//! LLC the roofline ratio is ≈1.0 and parallel speedups cannot exceed 1 —
//! the *ranking* across orderings and the γ-consistency are the
//! reproducible shape here.

use nni::bench::{counters_json, pipeline_for, print_header, repo_root_out, Table, Workload};
use nni::csb::hier::HierCsb;
use nni::csb::kernel::{detect, KernelKind};
use nni::interact::engine::Engine;
use nni::order::OrderingKind;
use nni::par::pool::default_threads;
use nni::sparse::gen;
use nni::spmv;
use nni::util::cli::Args;
use nni::util::json::{arr, num, obj, s, Json};
use nni::util::rng::Rng;
use nni::util::timer::{bench_default, machine_summary};
use std::io::Write;

fn main() {
    let a = Args::new("Fig. 3: attractive-force time ratios per ordering")
        .opt("sizes", "2048,4096,8192", "problem sizes (paper: 2^11..2^17)")
        .opt_u64("seed", 42, "rng seed")
        .opt_usize("threads", 0, "0 = all cores")
        .opt_usize_min("block-cap", 2048, 1, "CSB block capacity")
        .opt("rhs", "1,2,4,8", "multi-RHS sweep batch widths")
        .opt_usize_min("rhs-n", 4096, 1, "problem size of the multi-RHS sweep")
        .opt(
            "interact-out",
            "BENCH_interact.json",
            "multi-RHS sweep json record (relative = repo root)",
        )
        .opt("kernel", "both", "multi-RHS sweep kernels: both|auto|simd|scalar")
        .flag("gist", "also run the GIST-like workload (slow kNN at D=960)")
        .flag("smoke", "CI smoke mode: tiny sizes, same code paths")
        .parse();
    let threads = if a.get_usize("threads") == 0 {
        default_threads()
    } else {
        a.get_usize("threads")
    };
    let smoke = a.get_flag("smoke");
    print_header(
        "fig3_throughput",
        "Fig. 3 — t-SNE attractive force, seq + parallel, normalized to scattered-seq",
    );

    let kinds = OrderingKind::table1_set();
    let mut cols: Vec<String> = vec!["set".into(), "n".into(), "roofline".into()];
    for k in &kinds {
        cols.push(format!("{}(seq)", k.label()));
    }
    for k in &kinds {
        cols.push(format!("{}(par{threads})", k.label()));
    }
    let colrefs: Vec<&str> = cols.iter().map(String::as_str).collect();
    let mut table = Table::new("fig3_throughput", &colrefs);

    let workloads: Vec<Workload> = if a.get_flag("gist") && !smoke {
        vec![Workload::Sift, Workload::Gist]
    } else {
        vec![Workload::Sift]
    };
    let sizes = if smoke { vec![512] } else { a.get_usize_list("sizes") };
    for wl in workloads {
        for &n in &sizes {
            let (ds, m) = wl.make(n, a.get_u64("seed"), threads);
            // Roofline: banded vs scattered CSR SpMV at matched sparsity
            // (the paper's dotted gray line, measured on this machine).
            let per_row = (m.nnz() / n).max(1);
            let banded = gen::banded(n, per_row, 7);
            let scat_m = gen::scattered(n, per_row, 7);
            let x = vec![1.0f32; n];
            let mut yv = vec![0.0f32; n];
            let t_band = bench_default(|| spmv::csr::spmv_seq(&banded, &x, &mut yv));
            let t_scat_m = bench_default(|| spmv::csr::spmv_seq(&scat_m, &x, &mut yv));
            let roofline = t_scat_m.robust_min_s / t_band.robust_min_s;

            // Embedding coordinates for the force evaluation (tree order
            // per ordering; d=2 like the paper's visual case).
            let d = 2;
            let mut rng = Rng::new(9);
            let y0: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();

            // Reference: scattered ordering, sequential.
            let mut times_seq = Vec::new();
            let mut times_par = Vec::new();
            for kind in &kinds {
                let r = pipeline_for(kind, a.get_u64("seed")).run(&ds, &m);
                // CSB requires a tree; non-tree orderings get one from a
                // boxtree over the permuted embedding when available, else
                // a trivial 1-D tree over positions (flat blocking), which
                // is exactly what a non-hierarchical ordering offers.
                let engine = match (&r.tree, &r.embedded) {
                    (Some(tree), _) => {
                        let csb = HierCsb::build_par(
                            &r.reordered,
                            tree,
                            tree,
                            a.get_usize("block-cap"),
                            threads,
                        );
                        Engine::new(csb, threads)
                    }
                    (None, _) => {
                        // position tree: balanced intervals over 0..n
                        let pos_ds = nni::data::dataset::Dataset::new(
                            n,
                            1,
                            (0..n).map(|i| i as f32).collect(),
                        );
                        let tree = nni::tree::boxtree::BoxTree::build(&pos_ds, 16, 32);
                        // tree.perm is identity for sorted 1-D data
                        let csb =
                            HierCsb::build(&r.reordered, &tree, &tree, a.get_usize("block-cap"));
                        Engine::new(csb, threads)
                    }
                };
                let yt: Vec<f32> = {
                    // tree order of the embedding coordinates
                    let mut v = vec![0.0f32; n * d];
                    for (k, &p) in r.perm.iter().enumerate() {
                        v[k * d..(k + 1) * d].copy_from_slice(&y0[p * d..(p + 1) * d]);
                    }
                    v
                };
                let mut force = vec![0.0f32; n * d];
                let eng_seq = Engine::new(engine.csb.clone(), 1);
                let t_seq = bench_default(|| eng_seq.tsne_attr(&yt, d, &mut force));
                let t_par = bench_default(|| engine.tsne_attr(&yt, d, &mut force));
                times_seq.push(t_seq.robust_min_s);
                times_par.push(t_par.robust_min_s);
            }
            let reference = times_seq[0]; // scattered sequential
            let mut cells = vec![
                wl.name().to_string(),
                n.to_string(),
                format!("{roofline:.2}"),
            ];
            for t in &times_seq {
                cells.push(format!("{:.2}", reference / t));
            }
            for t in &times_par {
                cells.push(format!("{:.2}", reference / t));
            }
            table.row(cells);
        }
    }
    table.finish();
    println!("\nvalues are speedups over scattered-sequential (paper's reference line).");
    println!("expected shape: 3D DT highest among orderings; sequential DT approaches");
    println!("the roofline column; parallel values scale with available cores.");

    let rhs_n = if smoke { 512 } else { a.get_usize("rhs-n") };
    multi_rhs_sweep(
        rhs_n,
        &a.get_usize_list("rhs"),
        a.get_u64("seed"),
        threads,
        &a.get("interact-out"),
        &a.get("kernel"),
    );
}

/// Multi-RHS sweep (EXPERIMENTS.md §Multi-RHS, §Kernel dispatch): per-RHS
/// throughput of the batched block kernels vs the k-fold scalar path on
/// the clustered SIFT-like dataset, for the structural SpMM and the fused
/// Gaussian kernel, swept over the apply micro-kernel (`scalar` reference
/// vs the runtime-dispatched `simd` path).  Writes the
/// `BENCH_interact.json` record, whose schema names the kernel and
/// resolved dispatch per point (and the fallback reason when a SIMD
/// request resolved to scalar), so the perf trajectory attributes wins to
/// the right layer.  Before anything is recorded, the scalar path is
/// asserted bit-identical across worker counts {1, 2, 8}.
fn multi_rhs_sweep(
    n: usize,
    ks: &[usize],
    seed: u64,
    threads: usize,
    out_path: &str,
    kernel_req: &str,
) {
    println!("\n# multi-RHS sweep — n={n} clustered SIFT-like, 3D dual-tree ordering");
    let wl = Workload::Sift;
    let (ds, m) = wl.make(n, seed, threads);
    let r = pipeline_for(&OrderingKind::DualTree { d: 3 }, seed).run(&ds, &m);
    let tree = r.tree.as_ref().unwrap();
    // PJRT-path dense threshold: the micro-GEMM wants dense blocks — this
    // is the dense-fraction-heavy case of the kernel comparison.
    let csb = HierCsb::build_with_par(&r.reordered, tree, tree, 256, 0.25, threads);
    println!("# {}", csb.describe());
    let coords = ds.permuted(&r.perm).raw().to_vec();
    let d = ds.d();
    let inv_h2 = 0.5f32;
    let kmax = ks.iter().copied().max().unwrap_or(1);

    // Scalar bit-exactness smoke (the determinism gate CI relies on): the
    // scalar kernel must produce bit-identical results at 1/2/8 workers.
    {
        let mut rng = Rng::new(seed ^ 0x5ca1a);
        let xk: Vec<f32> = (0..n * kmax).map(|_| rng.f32() - 0.5).collect();
        let mut y_seq = vec![0.0f32; n * kmax];
        spmv::multilevel::spmm_ml_seq(&csb, &xk, &mut y_seq, kmax);
        let mut y_par = vec![0.0f32; n * kmax];
        for t in [1usize, 2, 8] {
            spmv::multilevel::spmm_ml_par(&csb, &xk, &mut y_par, kmax, t);
            assert!(
                y_seq.iter().zip(&y_par).all(|(a, b)| a.to_bits() == b.to_bits()),
                "scalar spmm not bit-identical at {t} threads"
            );
        }
        println!("# scalar kernel bit-identical across threads {{1,2,8}} at k={kmax}");
    }

    let kernel_rows: Vec<KernelKind> = match kernel_req {
        "both" => vec![KernelKind::Scalar, KernelKind::Simd],
        "scalar" => vec![KernelKind::Scalar],
        "simd" => vec![KernelKind::Simd],
        "auto" => vec![KernelKind::Auto],
        other => {
            eprintln!("unknown --kernel '{other}' (both|auto|simd|scalar)");
            std::process::exit(2);
        }
    };

    let mut table = Table::new(
        "fig3_multirhs",
        &[
            "kernel",
            "dispatch",
            "n",
            "k",
            "scalar_ms",
            "batched_ms",
            "per_rhs_speedup",
            "par_batched_ms",
        ],
    );
    let mut records: Vec<Json> = Vec::new();
    let mut spmm_kmax_scalar: Option<f64> = None;
    let mut spmm_kmax_simd: Option<f64> = None;
    let mut simd_fallback: Option<&'static str> = None;
    for &kind in &kernel_rows {
        let (dispatch, fallback) = kind.resolve();
        if kind != KernelKind::Scalar {
            simd_fallback = simd_fallback.or(fallback);
        }
        let engine_par = Engine::with_kernel(csb.clone(), threads, kind);
        let engine_seq = Engine::with_kernel(csb.clone(), 1, kind);
        // Same RNG stream per kernel row → identical inputs across rows.
        let mut rng = Rng::new(seed ^ 0xbeef);
        for &k in ks {
            let x1: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let mut y1 = vec![0.0f32; n];
            let xk: Vec<f32> = (0..n * k).map(|_| rng.f32()).collect();
            let mut yk = vec![0.0f32; n * k];

            // Structural SpMM vs k scalar SpMVs (both under this kernel).
            // Each recorded point embeds the counters drained over just its
            // own measurement window.
            nni::obs::reset();
            let t_scalar = bench_default(|| {
                for _ in 0..k {
                    spmv::multilevel::spmm_ml_seq_with(&csb, &x1, &mut y1, 1, dispatch);
                }
            });
            let t_batched = bench_default(|| {
                spmv::multilevel::spmm_ml_seq_with(&csb, &xk, &mut yk, k, dispatch)
            });
            let t_par = bench_default(|| {
                spmv::multilevel::spmm_ml_par_with(&csb, &xk, &mut yk, k, threads, dispatch)
            });
            push_point(
                &mut table,
                &mut records,
                Point {
                    kernel: kind.label(),
                    dispatch: dispatch.label(),
                    fallback,
                    op: "spmm",
                    n,
                    k,
                    scalar_s: t_scalar.robust_min_s,
                    batched_s: t_batched.robust_min_s,
                    par_s: t_par.robust_min_s,
                },
            );
            if k == kmax {
                match kind {
                    KernelKind::Scalar => spmm_kmax_scalar = Some(t_batched.robust_min_s),
                    _ => spmm_kmax_simd = Some(t_batched.robust_min_s),
                }
            }

            // Fused Gaussian: k queries, weights computed once per entry.
            nni::obs::reset();
            let t_gscalar = bench_default(|| {
                for _ in 0..k {
                    engine_seq.gauss_apply(&coords, &coords, d, inv_h2, &x1, &mut y1);
                }
            });
            let t_gbatched = bench_default(|| {
                engine_seq.gauss_apply_multi(&coords, &coords, d, inv_h2, &xk, k, &mut yk)
            });
            let t_gpar = bench_default(|| {
                engine_par.gauss_apply_multi(&coords, &coords, d, inv_h2, &xk, k, &mut yk)
            });
            push_point(
                &mut table,
                &mut records,
                Point {
                    kernel: kind.label(),
                    dispatch: dispatch.label(),
                    fallback,
                    op: "gauss",
                    n,
                    k,
                    scalar_s: t_gscalar.robust_min_s,
                    batched_s: t_gbatched.robust_min_s,
                    par_s: t_gpar.robust_min_s,
                },
            );
        }
    }
    table.finish();

    let mut top: Vec<(&str, Json)> = vec![
        ("bench", s("fig3_multirhs")),
        ("workload", s(wl.name())),
        ("n", num(n as f64)),
        ("status", s("measured")),
        ("testbed", s(&machine_summary())),
        ("kernel_requested", s(kernel_req)),
        ("kernel_detected", s(detect().label())),
        ("dense_fraction", num(csb.dense_fraction())),
        ("scalar_bitexact_threads", s("1,2,8")),
        (
            "expected_shape",
            s("per_rhs_speedup grows with k; acceptance bar: gauss k=8 >= 2x (spmm merely > 1) on the clustered dataset; k=1 rows are the parity check; simd batched_seconds <= scalar batched_seconds on the dense-heavy spmm rows unless simd_fallback_reason is set"),
        ),
    ];
    if let (Some(sc), Some(sv)) = (spmm_kmax_scalar, spmm_kmax_simd) {
        // >1 ⇔ the SIMD path beats the scalar path on the dense-heavy
        // structural case at the widest RHS block.
        top.push(("simd_speedup_spmm_kmax", num(sc / sv)));
        println!("# simd vs scalar, spmm k={kmax}: {:.2}x", sc / sv);
    }
    if let Some(why) = simd_fallback {
        top.push(("simd_fallback_reason", s(why)));
        println!("# simd dispatch fell back to scalar: {why}");
    }
    top.push(("points", arr(records)));
    let doc = obj(top);
    let out_path = repo_root_out(out_path);
    let mut f = std::fs::File::create(&out_path).expect("write interact json");
    writeln!(f, "{doc}").expect("write interact json");
    println!("\n[saved {}]", out_path.display());
    println!("per_rhs_speedup = (k x scalar time) / batched time; k=1 rows are the parity check.");
}

/// One sweep point (kernel row × op × k).
struct Point {
    kernel: &'static str,
    dispatch: &'static str,
    fallback: Option<&'static str>,
    op: &'static str,
    n: usize,
    k: usize,
    scalar_s: f64,
    batched_s: f64,
    par_s: f64,
}

/// One sweep row + json record.
fn push_point(table: &mut Table, records: &mut Vec<Json>, p: Point) {
    let speedup = p.scalar_s / p.batched_s;
    table.row(vec![
        format!("{}:{}", p.kernel, p.op),
        p.dispatch.to_string(),
        p.n.to_string(),
        p.k.to_string(),
        format!("{:.3}", p.scalar_s * 1e3),
        format!("{:.3}", p.batched_s * 1e3),
        format!("{speedup:.2}"),
        format!("{:.3}", p.par_s * 1e3),
    ]);
    let mut rec = vec![
        ("kernel", s(p.kernel)),
        ("dispatch", s(p.dispatch)),
        ("op", s(p.op)),
        ("n", num(p.n as f64)),
        ("k", num(p.k as f64)),
        ("scalar_seconds", num(p.scalar_s)),
        ("batched_seconds", num(p.batched_s)),
        ("par_batched_seconds", num(p.par_s)),
        ("per_rhs_speedup", num(speedup)),
    ];
    if let Some(why) = p.fallback {
        rec.push(("dispatch_fallback", s(why)));
    }
    rec.push(("counters", counters_json()));
    records.push(obj(rec));
}
