//! **Table 1 reproduction**: kernel-based patch-density estimates
//! γ(A(π_t, π_s); σ = k/2) for the SIFT (k=30) and GIST (k=90) interaction
//! matrices under the six orderings of Fig. 2: rand, rCM, 1D, 2D lex,
//! 3D lex, 3D DT.
//!
//! Paper's values (2^14 points): SIFT 2.3 / 14.3 / 6.1 / 12.1 / 12.1 / 20.0;
//! GIST 71.2 / 243.6 / 286.7 / 352.1 / 361.3 / 409.6.  Expected *shape*:
//! rand lowest, dual-tree highest, multi-dimensional lexical above 1D.
//!
//! Size defaults to 2^12 (exact kNN at D=960 is the cost driver; pass
//! `--n 16384` for the paper's full 2^14).

use nni::bench::{pipeline_for, print_header, Table, Workload};
use nni::profile::gamma;
use nni::util::cli::Args;
use nni::util::timer::time_once;

fn main() {
    let a = Args::new("Table 1: gamma per ordering")
        .opt("n", "4096", "points per dataset (paper: 16384)")
        .opt("seed", "42", "rng seed")
        .opt("threads", "0", "0 = all cores")
        .parse();
    let n = a.get_usize("n");
    print_header(
        "table1_gamma",
        "Table 1 — gamma(A; sigma=k/2) across orderings, SIFT k=30 / GIST k=90",
    );

    let mut table = Table::new(
        "table1_gamma",
        &["set", "k", "rand", "rCM", "1D", "2D lex", "3D lex", "3D DT"],
    );
    for wl in [Workload::Sift, Workload::Gist] {
        let ((ds, m), t_build) =
            time_once(|| wl.make(n, a.get_u64("seed"), a.get_usize("threads")));
        eprintln!("# {} built in {t_build:.1}s (nnz={})", wl.name(), m.nnz());
        let sigma = wl.k() as f64 / 2.0;
        let mut cells = vec![wl.name().to_string(), wl.k().to_string()];
        for kind in nni::order::OrderingKind::table1_set() {
            let r = pipeline_for(&kind, a.get_u64("seed")).run(&ds, &m);
            let g = gamma::gamma_fast(&r.reordered, sigma);
            cells.push(format!("{g:.1}"));
        }
        table.row(cells);
    }
    table.finish();
    println!("\npaper (2^14): SIFT 2.3/14.3/6.1/12.1/12.1/20.0 | GIST 71.2/243.6/286.7/352.1/361.3/409.6");
    println!("expected shape: rand lowest; 3D DT highest; 2D/3D lex > 1D");
}
