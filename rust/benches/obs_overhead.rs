//! **Tracing overhead guard**: steady-state engine spmm throughput with
//! span tracing off vs on, interleaved A/B over several rounds (robust
//! minimum per mode).  The observability layer's contract is that spans
//! are cheap enough to leave on — the overhead ratio is **asserted below
//! the tolerance (default 3%) before anything is recorded**, so a
//! regression in the span hot path fails the bench instead of silently
//! taxing every traced run.
//!
//! A second round prices the serve tier: request round-trips through a
//! live daemon with the whole deep-observability layer (spans + stage
//! histograms + flight recorder) off vs on, under the same tolerance —
//! the serve instrumentation sits on the request hot path and carries
//! the same leave-it-on contract.
//!
//! Writes `BENCH_obs_overhead.json` at the repo root; `--smoke` shrinks n
//! for the CI refresh (same code paths).

use nni::bench::{counters_json, print_header, repo_root_out, Workload};
use nni::csb::hier::HierCsb;
use nni::csb::kernel::KernelKind;
use nni::interact::engine::Engine;
use nni::obs;
use nni::order::OrderingKind;
use nni::util::cli::Args;
use nni::util::json::{arr, num, obj, s};
use nni::util::rng::Rng;
use nni::util::timer::{bench_default, machine_summary};
use std::io::Write;

fn main() {
    let a = Args::new("span-tracing overhead guard: engine spmm, tracing off vs on")
        .opt_usize_min("n", 4096, 64, "problem size")
        .opt_usize_min("rhs", 8, 1, "multi-RHS width")
        .opt_usize_min("block-cap", 256, 1, "CSB block capacity")
        .opt_usize_min("rounds", 5, 1, "interleaved A/B rounds")
        .opt_f64("tolerance", 0.03, "max allowed overhead ratio (0.03 = 3%)")
        .opt_u64("seed", 42, "rng seed")
        .opt_usize("threads", 0, "0 = all cores")
        .opt("out", "BENCH_obs_overhead.json", "json record path (relative = repo root)")
        .flag("smoke", "CI smoke mode: small n, same code paths")
        .parse();
    let smoke = a.get_flag("smoke");
    let n = if smoke { 2048 } else { a.get_usize("n") };
    let k = a.get_usize("rhs");
    let threads = a.get_usize("threads");
    let seed = a.get_u64("seed");
    let tolerance = a.get_f64("tolerance");
    print_header(
        "obs_overhead",
        "observability span overhead on the steady-state apply path",
    );

    let wl = Workload::Sift;
    let (ds, m) = wl.make(n, seed, threads);
    let r = nni::bench::pipeline_for(&OrderingKind::DualTree { d: 3 }, seed).run(&ds, &m);
    let tree = r.tree.as_ref().expect("dual-tree ordering carries a tree");
    let csb =
        HierCsb::build_with_par(&r.reordered, tree, tree, a.get_usize("block-cap"), 0.25, threads);
    println!("# n={n} rhs={k} {}", csb.describe());
    let eng = Engine::with_kernel(csb, threads, KernelKind::Auto);

    let mut rng = Rng::new(seed ^ 0x0b5);
    let xk: Vec<f32> = (0..n * k).map(|_| rng.f32() - 0.5).collect();
    let mut yk = vec![0.0f32; n * k];

    // Interleaved A/B: the two modes see the same thermal/cache environment;
    // the robust minimum per mode is the comparison.  The slabs are drained
    // before every traced round so spans take the recording path (the full-
    // slab drop path is cheaper — measuring it would flatter the ratio).
    obs::install(nni::par::pool::default_threads(), obs::DEFAULT_SPAN_CAP);
    let rounds = a.get_usize("rounds");
    let (mut best_off, mut best_on) = (f64::INFINITY, f64::INFINITY);
    for _ in 0..rounds {
        obs::set_enabled(false);
        best_off = best_off.min(bench_default(|| eng.spmm(&xk, &mut yk, k)).robust_min_s);
        obs::reset();
        obs::set_enabled(true);
        best_on = best_on.min(bench_default(|| eng.spmm(&xk, &mut yk, k)).robust_min_s);
    }
    obs::set_enabled(false);
    let ratio = best_on / best_off;
    println!(
        "# spmm off {:.3} ms | on {:.3} ms | overhead {:+.2}%",
        best_off * 1e3,
        best_on * 1e3,
        (ratio - 1.0) * 100.0
    );
    // The guard: fail before recording anything.
    assert!(
        ratio < 1.0 + tolerance,
        "tracing overhead {:.2}% exceeds the {:.0}% budget \
         (off {best_off:.6}s, on {best_on:.6}s)",
        (ratio - 1.0) * 100.0,
        tolerance * 100.0
    );

    // Serve round: a request round-trip through admission, dispatch,
    // shard compute, and merge on a live daemon, with the entire
    // deep-observability layer toggled as one (spans + histograms +
    // flight recorder) — same interleaving, same tolerance.
    let serve_n = if smoke { 512 } else { 1024 };
    let sds = nni::data::synth::SynthSpec::blobs(serve_n, 3, 4, seed).generate();
    let scfg = nni::interact::epoch::UpdateCfg {
        leaf_cap: 32,
        block_cap: 64,
        build_threads: 1,
        threads: 1,
        kernel: KernelKind::Auto,
        ..nni::interact::epoch::UpdateCfg::default()
    };
    let upd = std::sync::Arc::new(nni::interact::epoch::UpdatableKernelEngine::build(
        sds,
        scfg,
        nni::hmat::FullKernelConfig::new(0.8),
    ));
    let server = nni::serve::Server::start(
        upd,
        nni::serve::ServeConfig { shards: 2, ..nni::serve::ServeConfig::default() },
        nni::serve::FaultPlan::new(seed),
    );
    let charges: Vec<f32> = (0..serve_n).map(|_| rng.f32() - 0.5).collect();
    let round_trip = || {
        let pending = server
            .submit(nni::serve::Query::Gauss { charges: charges.clone() })
            .expect("bench request admitted");
        pending
            .wait_timeout(std::time::Duration::from_secs(30))
            .expect("bench request answered");
    };
    let set_all = |on: bool| {
        obs::set_enabled(on);
        obs::hist::set_enabled(on);
        obs::flight::set_enabled(on);
    };
    let (mut srv_off, mut srv_on) = (f64::INFINITY, f64::INFINITY);
    for _ in 0..rounds {
        set_all(false);
        srv_off = srv_off.min(bench_default(round_trip).robust_min_s);
        obs::reset(); // drain slabs + ring: the on-path must record, not drop
        set_all(true);
        srv_on = srv_on.min(bench_default(round_trip).robust_min_s);
    }
    set_all(true); // instrumentation is on by default; leave it that way
    obs::set_enabled(false);
    server.shutdown();
    let serve_ratio = srv_on / srv_off;
    println!(
        "# serve off {:.3} ms | on {:.3} ms | overhead {:+.2}%",
        srv_off * 1e3,
        srv_on * 1e3,
        (serve_ratio - 1.0) * 100.0
    );
    assert!(
        serve_ratio < 1.0 + tolerance,
        "serve observability overhead {:.2}% exceeds the {:.0}% budget \
         (off {srv_off:.6}s, on {srv_on:.6}s)",
        (serve_ratio - 1.0) * 100.0,
        tolerance * 100.0
    );

    let point = obj(vec![
        ("n", num(n as f64)),
        ("rhs", num(k as f64)),
        ("threads", num(threads as f64)),
        ("off_seconds", num(best_off)),
        ("on_seconds", num(best_on)),
        ("overhead_ratio", num(ratio)),
        ("serve_n", num(serve_n as f64)),
        ("serve_off_seconds", num(srv_off)),
        ("serve_on_seconds", num(srv_on)),
        ("serve_overhead_ratio", num(serve_ratio)),
        ("counters", counters_json()),
    ]);
    let doc = obj(vec![
        ("bench", s("obs_overhead")),
        ("workload", s(wl.name())),
        ("n", num(n as f64)),
        ("status", s("measured")),
        ("testbed", s(&machine_summary())),
        (
            "expected_shape",
            s("overhead_ratio and serve_overhead_ratio stay below 1 + tolerance \
               (default 1.03); both asserts run before the record is written, so a \
               present record implies a pass"),
        ),
        ("points", arr(vec![point])),
    ]);
    let out = repo_root_out(&a.get("out"));
    let mut f = std::fs::File::create(&out).expect("write obs_overhead json");
    writeln!(f, "{doc}").expect("write obs_overhead json");
    println!("\n[saved {}]", out.display());
    println!(
        "expected shape: overhead under {:.0}%; asserted before recording.",
        tolerance * 100.0
    );
}
