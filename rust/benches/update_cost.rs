//! **Incremental update cost**: wall time of a localized delete/insert
//! batch applied through the epoch layer (`UpdatableKernelEngine::update`
//! — subtree patch + near-row memcpy + far-factor lift) versus a
//! from-scratch build over the same post-update data.  The batch touches a
//! fixed-size neighborhood of one anchor point, so as `n` grows the
//! touched fraction shrinks and the update/rebuild ratio should fall —
//! the sublinearity claim of the incremental subsystem.
//!
//! The cheaper-than-rebuild bar is asserted **before** the record is
//! written: non-smoke points must come in under 0.8x the rebuild time
//! (smoke runs on shared CI runners get a 1.5x sanity bound instead).
//! Correctness is not re-proved here — the differential fuzz harness
//! (`tests/update_fuzz.rs`) owns bit-identity; this record owns cost.
//!
//! Writes `BENCH_update.json` (relative paths resolve against the repo
//! root via `bench::repo_root_out`).  `--smoke` runs one small size for
//! CI.  Methodology: EXPERIMENTS.md §Update methodology.

use nni::bench::{counters_json, print_header, repo_root_out, Table};
use nni::csb::kernel::KernelKind;
use nni::data::dataset::Dataset;
use nni::data::synth::SynthSpec;
use nni::hmat::FullKernelConfig;
use nni::interact::epoch::{UpdatableKernelEngine, UpdateCfg};
use nni::tree::update::UpdateBatch;
use nni::util::cli::Args;
use nni::util::json::{arr, num, obj, s, Json};
use nni::util::timer::{machine_summary, time_once};
use std::io::Write;

/// Deterministic localized batch: delete the `m` interior points nearest
/// to the first interior point (the anchor) and insert the anchor/deleted
/// midpoints.  Everything stays strictly inside the hull, so the root box
/// persists and the update exercises the subtree-patch path, and all the
/// churn lands in one neighborhood — the case incremental updates are for.
fn localized_batch(ds: &Dataset, m: usize) -> UpdateBatch {
    let d = ds.d();
    let mut lo = vec![f32::INFINITY; d];
    let mut hi = vec![f32::NEG_INFINITY; d];
    for i in 0..ds.n() {
        for (a, &x) in ds.row(i).iter().enumerate() {
            lo[a] = lo[a].min(x);
            hi[a] = hi[a].max(x);
        }
    }
    let on_hull = |row: &[f32]| row.iter().enumerate().any(|(a, &x)| x == lo[a] || x == hi[a]);
    let anchor = (0..ds.n()).find(|&i| !on_hull(ds.row(i))).expect("interior anchor");
    let ar = ds.row(anchor);
    let mut cand: Vec<(f32, usize)> = (0..ds.n())
        .filter(|&i| i != anchor && !on_hull(ds.row(i)))
        .map(|i| {
            let d2: f32 = ds.row(i).iter().zip(ar).map(|(x, y)| (x - y) * (x - y)).sum();
            (d2, i)
        })
        .collect();
    cand.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    cand.truncate(m);
    let deletes: Vec<usize> = cand.iter().map(|&(_, i)| i).collect();
    let mut inserts = Vec::with_capacity(deletes.len() * d);
    for &i in &deletes {
        for (x, y) in ds.row(i).iter().zip(ar) {
            inserts.push(0.5 * (x + y));
        }
    }
    UpdateBatch { deletes, inserts }
}

fn main() {
    let a = Args::new("incremental update cost vs from-scratch rebuild (full-kernel operator)")
        .opt("sizes", "2048,4096,8192", "problem sizes to sweep")
        .opt_usize_min("batch", 16, 1, "localized batch size (deletes = inserts)")
        .opt_usize_min("block-cap", 128, 1, "tree-cut block capacity")
        .opt_usize_min("reps", 3, 1, "repetitions per point (minimum reported)")
        .opt_f64("factor", 0.8, "bar: update must cost < factor x rebuild")
        .opt_u64("seed", 42, "rng seed")
        .opt_usize("threads", 0, "0 = all cores")
        .opt("out", "BENCH_update.json", "json record path (relative = repo root)")
        .flag("smoke", "CI smoke mode: one small size, sanity bar 1.5x")
        .parse();
    let smoke = a.get_flag("smoke");
    let sizes: Vec<usize> = if smoke { vec![1024] } else { a.get_usize_list("sizes") };
    let m = if smoke { 8 } else { a.get_usize("batch") };
    let factor = if smoke { 1.5 } else { a.get_f64("factor") };
    let reps = a.get_usize("reps");
    let seed = a.get_u64("seed");
    let ucfg = UpdateCfg {
        leaf_cap: 16,
        block_cap: a.get_usize("block-cap"),
        build_threads: a.get_usize("threads"),
        threads: a.get_usize("threads"),
        kernel: KernelKind::Auto,
        ..UpdateCfg::default()
    };
    let kcfg = FullKernelConfig::new(0.8);
    print_header(
        "update_cost",
        "localized epoch update vs from-scratch full-kernel build",
    );
    println!("# batch=-{m}/+{m} bar: update < {factor:.2}x rebuild");

    let mut table = Table::new(
        "update_cost",
        &["n", "update_ms", "rebuild_ms", "ratio", "touched"],
    );
    let mut records: Vec<Json> = Vec::new();
    for &n in &sizes {
        // per-point observability window (same discipline as build_scaling)
        nni::obs::reset();
        let ds = SynthSpec::blobs(n, 3, 8, seed).generate();
        let upd = UpdatableKernelEngine::build(ds, ucfg, kcfg.clone());
        let (mut upd_s, mut reb_s) = (f64::INFINITY, f64::INFINITY);
        for _ in 0..reps {
            let cur = upd.acquire();
            let batch = localized_batch(&cur.value.ds, m);
            drop(cur);
            let (e, dt) = time_once(|| upd.update(&batch));
            upd_s = upd_s.min(dt);
            let post = e.value.ds.clone();
            let (_fresh, dt) = time_once(|| UpdatableKernelEngine::build(post, ucfg, kcfg.clone()));
            reb_s = reb_s.min(dt);
        }
        let ratio = upd_s / reb_s;
        // the bar, gated BEFORE anything is recorded: an "incremental"
        // path that costs as much as a rebuild is a regression, not a result
        assert!(
            ratio < factor,
            "update_cost bar failed at n={n}: update {:.3} ms vs rebuild {:.3} ms \
             (ratio {ratio:.2} >= {factor:.2})",
            upd_s * 1e3,
            reb_s * 1e3
        );
        let touched = 2 * m;
        table.row(vec![
            n.to_string(),
            format!("{:.3}", upd_s * 1e3),
            format!("{:.3}", reb_s * 1e3),
            format!("{ratio:.3}"),
            touched.to_string(),
        ]);
        records.push(obj(vec![
            ("n", num(n as f64)),
            ("batch", num(m as f64)),
            ("update_seconds", num(upd_s)),
            ("rebuild_seconds", num(reb_s)),
            ("ratio", num(ratio)),
            ("counters", counters_json()),
        ]));
    }
    table.finish();

    let doc = obj(vec![
        ("bench", s("update_cost")),
        ("n_sweep", arr(sizes.iter().map(|&n| num(n as f64)).collect())),
        ("batch", num(m as f64)),
        ("bar_factor", num(factor)),
        ("status", s("measured")),
        ("testbed", s(&machine_summary())),
        (
            "expected_shape",
            s("ratio = update/rebuild stays below the bar at every n and falls as n grows \
               (fixed-size localized batch -> shrinking touched fraction); the update.* \
               counters embedded per point show leaves/rows/factors reused vs rebuilt"),
        ),
        ("points", arr(records)),
    ]);
    let out = repo_root_out(&a.get("out"));
    let mut f = std::fs::File::create(&out).expect("write update json");
    writeln!(f, "{doc}").expect("write update json");
    println!("\n[saved {}]", out.display());
    println!("expected shape: update/rebuild ratio below the bar, falling with n.");
}
