//! **Build-side scaling**: wall time of the three ordering-pipeline build
//! stages — PCA Gram accumulation (`embed::pca_par`), adaptive tree
//! construction (`BoxTree::build_par`), and HierCsb assembly
//! (`HierCsb::build_par`) — across worker counts.  This is the path a
//! per-batch profile refresh pays on every iteration (mean shift rebuilds
//! the target tree + CSB each `refresh_every`), so the record tracks the
//! claim that the build side, not just the apply side, scales with cores.
//!
//! Every parallel point is checked **bit-identical** against the
//! single-thread reference before its timing is recorded — the bench
//! doubles as a determinism canary on real workload shapes.
//!
//! Writes `BENCH_build.json` (relative paths resolve against the repo root
//! via `bench::repo_root_out`).  `--smoke` runs tiny sizes over threads
//! {1, 2} for CI.  Methodology: EXPERIMENTS.md §Build-scaling.

use nni::bench::{counters_json, print_header, repo_root_out, Table, Workload};
use nni::csb::hier::HierCsb;
use nni::embed::pca::pca_par;
use nni::knn::KnnBackend;
use nni::order::invert;
use nni::tree::boxtree::BoxTree;
use nni::util::cli::Args;
use nni::util::json::{arr, num, obj, s, Json};
use nni::util::timer::{machine_summary, time_once};
use std::io::Write;

fn main() {
    let a = Args::new("build-side scaling: PCA + tree + CSB assembly across thread counts")
        .opt_usize_min("n", 16384, 64, "problem size")
        .opt("threads-list", "1,2,4,8", "worker counts to sweep")
        .opt_usize_min("embed-d", 3, 1, "embedding dimension")
        .opt_usize_min("k", 16, 1, "profile neighbors")
        .opt_usize_min("leaf-cap", 16, 1, "ordering-tree leaf capacity")
        .opt_usize_min("block-cap", 256, 1, "CSB block capacity")
        .opt_usize_min("reps", 3, 1, "repetitions per point (minimum reported)")
        .opt_u64("seed", 42, "rng seed")
        .opt("out", "BENCH_build.json", "json record path (relative = repo root)")
        .flag("smoke", "CI smoke mode: small n, threads {1,2}, same code paths")
        .parse();
    let smoke = a.get_flag("smoke");
    let n = if smoke { 2048 } else { a.get_usize("n") };
    let threads_list: Vec<usize> = if smoke {
        vec![1, 2]
    } else {
        a.get_usize_list("threads-list")
    };
    let ed = a.get_usize("embed-d");
    let k = a.get_usize("k").min(n - 1);
    let leaf_cap = a.get_usize("leaf-cap");
    let block_cap = a.get_usize("block-cap");
    let reps = a.get_usize("reps");
    let seed = a.get_u64("seed");
    print_header(
        "build_scaling",
        "ordering-pipeline build path (PCA Gram, BoxTree, HierCsb) vs worker count",
    );

    // Fixed inputs shared by every thread count: the clustered SIFT-like
    // surrogate and its symmetrized kNN profile (ANN backend past the
    // exact-build comfort zone — the profile is an *input* here).
    let wl = Workload::Sift;
    let ds = wl.make_dataset(n, seed);
    let backend = if n > 4096 {
        KnnBackend::ann_default()
    } else {
        KnnBackend::Exact
    };
    let (g, t_knn) = time_once(|| backend.build(&ds, k, 0));
    let m = nni::sparse::csr::Csr::from_knn(&g, n).symmetrized();
    println!("# n={n} k={k} nnz={} (knn [{}] {t_knn:.2}s)", m.nnz(), backend.label());

    // Single-thread references for the bit-identity checks.
    let pca_ref = pca_par(&ds, ed, 10, seed, 1);
    let embedded_ref = pca_ref.project(&ds, ed);
    let tree_ref = BoxTree::build(&embedded_ref, leaf_cap, 32);
    let pos_ref = invert(&tree_ref.perm);
    let b_ref = m.permuted(&pos_ref, &pos_ref);
    let csb_ref = HierCsb::build(&b_ref, &tree_ref, &tree_ref, block_cap);
    println!("# csb: {}", csb_ref.describe());

    let mut points: Vec<(usize, f64, f64, f64)> = Vec::new();
    let mut counter_snaps: Vec<Json> = Vec::new();
    for &t in &threads_list {
        // per-point observability window: the embedded counters cover just
        // this thread count's builds
        nni::obs::reset();
        let (mut pca_s, mut tree_s, mut csb_s) = (f64::INFINITY, f64::INFINITY, f64::INFINITY);
        for _ in 0..reps {
            let (p, dt) = time_once(|| pca_par(&ds, ed, 10, seed, t));
            pca_s = pca_s.min(dt);
            assert!(
                p.axes.iter().zip(&pca_ref.axes).all(|(x, y)| x.to_bits() == y.to_bits()),
                "pca not bit-identical at threads={t}"
            );
            let (tree, dt) = time_once(|| BoxTree::build_par(&embedded_ref, leaf_cap, 32, t));
            tree_s = tree_s.min(dt);
            assert_eq!(tree.perm, tree_ref.perm, "tree perm differs at threads={t}");
            assert_eq!(tree.leaf_at, tree_ref.leaf_at, "leaf_at differs at threads={t}");
            assert_eq!(tree.nodes.len(), tree_ref.nodes.len());
            let (csb, dt) = time_once(|| HierCsb::build_par(&b_ref, &tree, &tree, block_cap, t));
            csb_s = csb_s.min(dt);
            assert_eq!(csb.blocks, csb_ref.blocks, "block layout differs at threads={t}");
            let dense_eq =
                csb.dense.iter().zip(&csb_ref.dense).all(|(x, y)| x.to_bits() == y.to_bits());
            let val_eq =
                csb.sp_val.iter().zip(&csb_ref.sp_val).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(
                dense_eq
                    && val_eq
                    && csb.sp_rows == csb_ref.sp_rows
                    && csb.sp_ptr == csb_ref.sp_ptr
                    && csb.sp_col == csb_ref.sp_col,
                "csb arenas differ at threads={t}"
            );
        }
        // regression guard on the per-point window: exactly this thread
        // count's `reps` tree builds land in the snapshot — if the
        // `obs::reset` above ever disappears, earlier windows leak in here
        // and the embedded counters stop being per-point
        let snap = nni::obs::counters::snapshot();
        assert_eq!(
            snap.get("tree.builds"),
            reps as u64,
            "counter window at threads={t} not isolated (expected {reps} tree builds)"
        );
        points.push((t, pca_s, tree_s, csb_s));
        counter_snaps.push(counters_json());
    }

    // Speedup baseline: the measured single-thread point when the sweep
    // includes one (whatever its position), else the smallest thread count.
    let baseline = points
        .iter()
        .find(|p| p.0 == 1)
        .or_else(|| points.iter().min_by_key(|p| p.0))
        .map(|&(_, p, tr, c)| p + tr + c)
        .unwrap_or(f64::NAN);
    let mut table = Table::new(
        "build_scaling",
        &["threads", "pca_ms", "tree_ms", "csb_ms", "total_ms", "speedup_vs_1"],
    );
    let mut records: Vec<Json> = Vec::new();
    for (i, &(t, pca_s, tree_s, csb_s)) in points.iter().enumerate() {
        let total = pca_s + tree_s + csb_s;
        let speedup = baseline / total;
        table.row(vec![
            t.to_string(),
            format!("{:.3}", pca_s * 1e3),
            format!("{:.3}", tree_s * 1e3),
            format!("{:.3}", csb_s * 1e3),
            format!("{:.3}", total * 1e3),
            format!("{speedup:.2}"),
        ]);
        records.push(obj(vec![
            ("threads", num(t as f64)),
            ("pca_seconds", num(pca_s)),
            ("tree_seconds", num(tree_s)),
            ("csb_seconds", num(csb_s)),
            ("total_seconds", num(total)),
            ("speedup_vs_1", num(speedup)),
            ("counters", counter_snaps[i].clone()),
        ]));
    }
    table.finish();

    let doc = obj(vec![
        ("bench", s("build_scaling")),
        ("workload", s(wl.name())),
        ("n", num(n as f64)),
        ("k", num(k as f64)),
        ("block_cap", num(block_cap as f64)),
        ("status", s("measured")),
        ("testbed", s(&machine_summary())),
        (
            "expected_shape",
            s("total_seconds decreases (speedup_vs_1 grows) as threads grow, up to the \
               core count; every point is asserted bit-identical to the single-thread build"),
        ),
        ("points", arr(records)),
    ]);
    let out = repo_root_out(&a.get("out"));
    let mut f = std::fs::File::create(&out).expect("write build json");
    writeln!(f, "{doc}").expect("write build json");
    println!("\n[saved {}]", out.display());
    println!("expected shape: build wall-time decreases as threads grow; identity asserted.");
}
