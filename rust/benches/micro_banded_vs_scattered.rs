//! **§4.1 micro-benchmark reproduction**: best-case (banded, 1-D
//! interaction) vs base-case (randomly scattered) SpMV at fixed nnz/row,
//! across sizes — the machine-specific reference ratio the paper uses as
//! the "maximum expected improvement" line in Fig. 3.  Our CSR SpMV stands
//! in for MKL_CSC_MV (DESIGN.md §5).

use nni::bench::{print_header, Table};
use nni::par::pool::default_threads;
use nni::sparse::gen;
use nni::spmv::csr::{spmv_par, spmv_seq};
use nni::util::cli::Args;
use nni::util::timer::bench_default;

fn main() {
    let a = Args::new("§4.1 micro-benchmark: banded vs scattered SpMV")
        .opt(
            "sizes",
            "8192,16384,32768,65536,131072",
            "matrix sizes",
        )
        .opt("threads", "0", "0 = all cores")
        .parse();
    let threads = if a.get_usize("threads") == 0 {
        default_threads()
    } else {
        a.get_usize("threads")
    };
    print_header(
        "micro_banded_vs_scattered",
        "§4.1 — banded (best) vs scattered (base) SpMV ratio, k=30 (SIFT) and k=90 (GIST)",
    );
    let mut table = Table::new(
        "micro_banded_vs_scattered",
        &[
            "n", "k", "banded_ms", "scattered_ms", "ratio_seq",
            "banded_par_ms", "scattered_par_ms", "ratio_par",
        ],
    );
    for &n in &a.get_usize_list("sizes") {
        for per_row in [30usize, 90] {
            let banded = gen::banded(n, per_row, 1);
            let scattered = gen::scattered(n, per_row, 1);
            let x = vec![1.0f32; n];
            let mut y = vec![0.0f32; n];
            let tb = bench_default(|| spmv_seq(&banded, &x, &mut y));
            let ts = bench_default(|| spmv_seq(&scattered, &x, &mut y));
            let tbp = bench_default(|| spmv_par(&banded, &x, &mut y, threads));
            let tsp = bench_default(|| spmv_par(&scattered, &x, &mut y, threads));
            table.row(vec![
                n.to_string(),
                per_row.to_string(),
                format!("{:.3}", tb.robust_min_s * 1e3),
                format!("{:.3}", ts.robust_min_s * 1e3),
                format!("{:.2}", ts.robust_min_s / tb.robust_min_s),
                format!("{:.3}", tbp.robust_min_s * 1e3),
                format!("{:.3}", tsp.robust_min_s * 1e3),
                format!("{:.2}", tsp.robust_min_s / tbp.robust_min_s),
            ]);
        }
    }
    table.finish();
    println!("\nratio_seq is the paper's dotted-gray reference line (machine roofline");
    println!("for reordering gains). On deep-LLC machines it approaches 1.0 until the");
    println!("working set (x + matrix stream) exceeds the cache hierarchy.");
}
