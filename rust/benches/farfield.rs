//! **Far-field compression sweep**: storage, accuracy, and apply time of
//! the `hmat` full-kernel operator across representations × precisions ×
//! ACA tolerances tol ∈ {1e-2, 1e-3, 1e-4}.
//!
//! Rows are (format, precision) pairs — per-block ACA in f32, nested-basis
//! H² in f32, and H² with tolerance-gated bf16 factor storage.  Per row
//! the bench measures, on a clustered SIFT-like surrogate:
//!
//! * compressed far-field bytes vs what the same blocks would cost dense
//!   (the acceptance bars at tol = 1e-3: ACA `storage_ratio < 0.3` and
//!   H²(f32) bytes strictly below ACA bytes);
//! * basis/block rank statistics (η/tol methodology:
//!   EXPERIMENTS.md §Far-field compression & KRR);
//! * sampled relative error of the full spmv against a streamed f64
//!   dense Gaussian oracle (must stay ≤ 10·tol);
//! * build and apply wall time.
//!
//! Before anything is recorded, the far apply is asserted
//! **bit-identical across thread counts {1, 2, 8}** (scalar dispatch) —
//! the same determinism discipline as BENCH_build/BENCH_interact.
//!
//! Writes `BENCH_farfield.json` at the repo root; `--smoke` shrinks n for
//! the CI refresh (same code paths).

use nni::apps::krr::suggest_bandwidth;
use nni::bench::{counters_json, print_header, repo_root_out, Table, Workload};
use nni::csb::kernel::{Dispatch, KernelKind};
use nni::hmat::aca::GaussGen;
use nni::hmat::apply::worker_scratch;
use nni::hmat::repr::{FarFieldRepr, FarFieldStore};
use nni::hmat::{FarFieldMode, FullKernelConfig, FullKernelEngine, Precision};
use nni::order::dualtree;
use nni::par::pool::ThreadPool;
use nni::util::cli::Args;
use nni::util::json::{arr, num, obj, s, Json};
use nni::util::rng::Rng;
use nni::util::timer::{bench_default, machine_summary, time_once};
use std::io::Write;

/// Rank statistics of either representation, for the shared table/record
/// shape: (mean rank, max rank, histogram, format-specific extras).
fn far_stats(far: &FarFieldStore) -> (f64, usize, Vec<(usize, usize)>, Vec<(&'static str, Json)>) {
    match far {
        FarFieldStore::Aca(f) => (
            f.mean_rank(),
            f.max_rank() as usize,
            f.rank_histogram().into_iter().map(|(r, c)| (r as usize, c as usize)).collect(),
            vec![
                ("low_rank_blocks", num(f.low_rank_blocks() as f64)),
                ("dense_fallback_blocks", num(f.dense_fallback_blocks() as f64)),
            ],
        ),
        FarFieldStore::H2(f) => (
            f.mean_basis_rank(),
            f.max_basis_rank(),
            f.rank_histogram(),
            vec![
                ("src_nodes", num(f.src_node_count() as f64)),
                ("bf16_factors", num(f.bf16_factors() as f64)),
            ],
        ),
    }
}

fn main() {
    let a = Args::new("far-field compression sweep: format x precision x tolerance")
        .opt_usize_min("n", 8192, 64, "problem size")
        .opt("tol-list", "1e-2,1e-3,1e-4", "ACA tolerances to sweep")
        .opt_f64("eta", 1.0, "admissibility parameter")
        .opt_f64("bandwidth", 0.0, "gaussian bandwidth h (0 = median auto)")
        .opt_usize_min("block-cap", 256, 1, "tree-cut block capacity")
        .opt_usize_min("leaf-cap", 16, 1, "ordering-tree leaf capacity")
        .opt_usize_min("sample-rows", 256, 1, "oracle rows sampled for the error estimate")
        .opt_u64("seed", 42, "rng seed")
        .opt("out", "BENCH_farfield.json", "json record path (relative = repo root)")
        .flag("smoke", "CI smoke mode: small n, same code paths")
        .parse();
    let smoke = a.get_flag("smoke");
    let n = if smoke { 2048 } else { a.get_usize("n") };
    let block_cap = if smoke { 128 } else { a.get_usize("block-cap") };
    let tols: Vec<f64> = a
        .get("tol-list")
        .split(',')
        .filter(|t| !t.is_empty())
        .map(|t| t.trim().parse().unwrap_or_else(|_| panic!("--tol-list: bad float '{t}'")))
        .collect();
    let eta = a.get_f64("eta") as f32;
    let seed = a.get_u64("seed");
    print_header(
        "farfield",
        "hmat far-field compression: ACA vs nested-basis H2, f32 vs bf16 factors",
    );

    // Fixed inputs: clustered surrogate, 3-D PCA embedding, dual tree.
    let wl = Workload::Sift;
    let ds = wl.make_dataset(n, seed);
    let h = if a.get_f64("bandwidth") > 0.0 {
        a.get_f64("bandwidth")
    } else {
        suggest_bandwidth(&ds, seed)
    };
    let inv_h2 = (1.0 / (h * h)) as f32;
    let embedded = nni::embed::pca::pca_par(&ds, 3, 10, seed, 0).project(&ds, 3);
    let (perm, tree) = dualtree::order_par(&embedded, a.get_usize("leaf-cap"), 0);
    let coords = ds.permuted(&perm);
    println!("# n={n} d={} h={h:.4} eta={eta} block_cap={block_cap}", ds.d());

    // Shared probe vector + sampled f64 oracle rows.
    let mut rng = Rng::new(seed ^ 0xFA2);
    let x: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
    let m = a.get_usize("sample-rows").min(n);
    let sample: Vec<usize> = rng.sample_distinct(n, m);
    let gen = GaussGen {
        coords: coords.raw(),
        d: ds.d(),
        inv_h2,
    };
    let oracle: Vec<f64> = sample
        .iter()
        .map(|&i| (0..n).map(|j| gen.entry_f64(i, j) * x[j] as f64).sum())
        .collect();
    let oracle_norm: f64 = oracle.iter().map(|v| v * v).sum::<f64>().sqrt();

    let variants: [(FarFieldMode, Precision); 3] = [
        (FarFieldMode::Aca, Precision::F32),
        (FarFieldMode::H2, Precision::F32),
        (FarFieldMode::H2, Precision::Bf16),
    ];
    let mut table = Table::new(
        "farfield",
        &[
            "tol", "format", "prec", "far_blocks", "mean_rank", "max_rank", "storage_ratio",
            "rel_err", "build_s", "spmv_ms",
        ],
    );
    let mut records: Vec<Json> = Vec::new();
    for &tol in &tols {
        // per-tolerance byte accounting for the cross-format acceptance bar
        let mut aca_bytes = 0u64;
        let mut h2_f32_bytes = 0u64;
        for &(format, precision) in &variants {
            // per-point observability window: the embedded counters cover
            // just this variant's build + applies
            nni::obs::reset();
            let cfg = FullKernelConfig::new(inv_h2)
                .with_eta(eta)
                .with_tol(tol as f32)
                .with_block_cap(block_cap)
                .with_far(format)
                .with_precision(precision);
            let (eng, t_build) = time_once(|| {
                FullKernelEngine::build(&tree, coords.raw(), ds.d(), &cfg, 0, 0, KernelKind::Auto)
            });
            let far = &eng.far;

            // Determinism gate: far apply bit-identical across threads
            // {1,2,8} under the scalar dispatch before anything is recorded.
            let mut y_ref: Vec<f32> = Vec::new();
            for threads in [1usize, 2, 8] {
                let pool = ThreadPool::new(threads);
                let scratch = worker_scratch(pool.threads);
                let mut y = vec![0.0f32; n];
                far.apply_acc(&x, 1, &mut y, &pool, Dispatch::Scalar, &scratch);
                if y_ref.is_empty() {
                    y_ref = y;
                } else {
                    assert!(
                        y.iter().zip(&y_ref).all(|(p, q)| p.to_bits() == q.to_bits()),
                        "far apply not bit-identical at threads={threads} \
                         (format={} tol={tol})",
                        format.label()
                    );
                }
            }

            // Accuracy: full spmv vs the sampled f64 oracle.
            let mut y = vec![0.0f32; n];
            eng.spmv(&x, &mut y);
            let err: f64 = sample
                .iter()
                .zip(&oracle)
                .map(|(&i, &w)| (y[i] as f64 - w) * (y[i] as f64 - w))
                .sum::<f64>()
                .sqrt();
            let rel_err = err / oracle_norm.max(1e-300);
            assert!(
                rel_err <= 10.0 * tol,
                "full-kernel spmv rel err {rel_err:.3e} exceeds 10·tol \
                 (format={} precision={} tol={tol})",
                format.label(),
                precision.label()
            );

            let ratio = far.far_bytes() as f64 / far.dense_far_bytes().max(1) as f64;
            match (format, precision) {
                (FarFieldMode::Aca, _) => aca_bytes = far.far_bytes(),
                (FarFieldMode::H2, Precision::F32) => h2_f32_bytes = far.far_bytes(),
                _ => {}
            }
            if (tol - 1e-3).abs() < 1e-12 {
                if format == FarFieldMode::Aca {
                    assert!(
                        ratio < 0.3,
                        "acceptance: far storage ratio {ratio:.3} must be < 0.3 \
                         at tol=1e-3 ({})",
                        far.describe()
                    );
                }
                if format == FarFieldMode::H2 && precision == Precision::F32 {
                    assert!(
                        h2_f32_bytes < aca_bytes,
                        "acceptance: H2 factors {h2_f32_bytes} bytes must be < \
                         ACA {aca_bytes} bytes at tol=1e-3 ({})",
                        far.describe()
                    );
                }
            }
            let m_spmv = bench_default(|| eng.spmv(&x, &mut y));
            println!(
                "# tol={tol:.0e} format={} precision={}: {}",
                format.label(),
                precision.label(),
                far.describe()
            );

            let (mean_rank, max_rank, hist, extras) = far_stats(far);
            table.row(vec![
                format!("{tol:.0e}"),
                format.label().to_string(),
                precision.label().to_string(),
                far.block_count().to_string(),
                format!("{mean_rank:.1}"),
                max_rank.to_string(),
                format!("{ratio:.4}"),
                format!("{rel_err:.3e}"),
                format!("{t_build:.3}"),
                format!("{:.3}", m_spmv.robust_min_s * 1e3),
            ]);
            let hist: Vec<Json> = hist
                .into_iter()
                .map(|(r, c)| obj(vec![("rank", num(r as f64)), ("blocks", num(c as f64))]))
                .collect();
            let mut fields = vec![
                ("tol", num(tol)),
                ("format", s(format.label())),
                ("precision", s(precision.label())),
                ("far_blocks", num(far.block_count() as f64)),
                ("mean_rank", num(mean_rank)),
                ("max_rank", num(max_rank as f64)),
                ("rank_histogram", arr(hist)),
                ("far_bytes", num(far.far_bytes() as f64)),
                ("dense_far_bytes", num(far.dense_far_bytes() as f64)),
                ("storage_ratio", num(ratio)),
                ("near_covered_entries", num(eng.near.csb.coverage().0 as f64)),
                ("rel_err_sample", num(rel_err)),
                ("build_seconds", num(t_build)),
                ("spmv_seconds", num(m_spmv.robust_min_s)),
                ("counters", counters_json()),
            ];
            fields.extend(extras);
            records.push(obj(fields));
        }
    }
    table.finish();

    let doc = obj(vec![
        ("bench", s("farfield")),
        ("workload", s(wl.name())),
        ("n", num(n as f64)),
        ("d", num(ds.d() as f64)),
        ("bandwidth", num(h)),
        ("eta", num(eta as f64)),
        ("block_cap", num(block_cap as f64)),
        ("status", s("measured")),
        ("testbed", s(&machine_summary())),
        (
            "expected_shape",
            s("per format: storage_ratio grows and rel_err_sample shrinks as tol \
               tightens; rel_err_sample <= 10*tol always; at tol=1e-3 ACA \
               storage_ratio < 0.3 and H2(f32) far_bytes < ACA far_bytes; \
               bf16 shrinks H2 bytes further where the tolerance gate allows; \
               far-apply bit-identity across threads {1,2,8} asserted before \
               recording"),
        ),
        ("points", arr(records)),
    ]);
    let out = repo_root_out(&a.get("out"));
    let mut f = std::fs::File::create(&out).expect("write farfield json");
    writeln!(f, "{doc}").expect("write farfield json");
    println!("\n[saved {}]", out.display());
    println!("expected shape: tighter tol → higher rank/storage, lower error; h2 < aca bytes.");
}
