//! **ANN vs exact kNN construction**: build time and recall trajectory
//! across problem sizes — the scaling argument for the `knn::ann`
//! subsystem.  The exact backend is O(n²·d); the forest + NN-descent
//! backend is near-linear, so the speedup column should grow roughly
//! linearly in n while recall@k stays ≳ 0.95 on the clustered surrogates.
//!
//! Recall is measured against a subsampled exact oracle
//! (`knn::ann::recall`), so it stays cheap even at sizes where the full
//! exact build dominates the run.  Writes a JSON trajectory record
//! (`--out`, default `BENCH_knn.json`; relative paths resolve against the
//! **repo root** via `bench::repo_root_out`, so the record lands in the
//! same place no matter which directory cargo runs the bench from) with
//! per-size build seconds for both backends and ANN recall@k.

use nni::bench::{counters_json, print_header, repo_root_out, Table, Workload};
use nni::knn::ann::recall::recall_at_k;
use nni::knn::ann::AnnParams;
use nni::knn::exact::knn_graph;
use nni::knn::KnnBackend;
use nni::par::pool::default_threads;
use nni::util::cli::Args;
use nni::util::json::{arr, num, obj, s, Json};
use nni::util::timer::{machine_summary, time_once};
use std::io::Write;

fn main() {
    let a = Args::new("ANN vs exact kNN build: time + recall trajectory")
        .opt("sizes", "4096,16384,65536", "problem sizes (2^12, 2^14, 2^16)")
        .opt_usize_min("k", 10, 1, "neighbors")
        .opt("workload", "sift", "sift|gist")
        .opt_u64("seed", 42, "rng seed")
        .opt_usize("threads", 0, "0 = all cores")
        .opt_usize("recall-sample", 512, "recall queries per size")
        .opt("out", "BENCH_knn.json", "json record path (relative = repo root)")
        .flag("skip-exact", "skip the exact build timing (recall still measured)")
        .parse();
    let threads = if a.get_usize("threads") == 0 {
        default_threads()
    } else {
        a.get_usize("threads")
    };
    let wl = match a.get("workload").to_ascii_lowercase().as_str() {
        "gist" => Workload::Gist,
        _ => Workload::Sift,
    };
    print_header(
        "ann_vs_exact",
        "knn::ann trajectory — PCA-forest + NN-descent vs exact brute force",
    );

    let mut table = Table::new(
        "ann_vs_exact",
        &["n", "k", "exact_s", "ann_s", "speedup", "recall@k"],
    );
    let mut records: Vec<Json> = Vec::new();
    for &n in &a.get_usize_list("sizes") {
        // per-point observability window: the embedded counters cover just
        // this size's builds
        nni::obs::reset();
        let ds = wl.make_dataset(n, a.get_u64("seed"));
        let k = a.get_usize("k").min(n - 1);
        let params = AnnParams::default();
        let backend = KnnBackend::Ann(params);
        let (g_ann, t_ann) = time_once(|| backend.build(&ds, k, threads));
        let rep = recall_at_k(
            &ds,
            &g_ann,
            a.get_usize("recall-sample"),
            a.get_u64("seed"),
            threads,
        );
        let (exact_cell, speedup_cell, exact_json) = if a.get_flag("skip-exact") {
            ("-".to_string(), "-".to_string(), Json::Null)
        } else {
            let (_, t_exact) = time_once(|| knn_graph(&ds, k, threads));
            (
                format!("{t_exact:.2}"),
                format!("{:.1}x", t_exact / t_ann.max(1e-9)),
                num(t_exact),
            )
        };
        table.row(vec![
            n.to_string(),
            k.to_string(),
            exact_cell,
            format!("{t_ann:.2}"),
            speedup_cell,
            format!("{:.4}", rep.recall),
        ]);
        records.push(obj(vec![
            ("n", num(n as f64)),
            ("k", num(k as f64)),
            ("exact_seconds", exact_json),
            ("ann_seconds", num(t_ann)),
            ("recall_at_k", num(rep.recall)),
            ("kth_dist_ratio", num(rep.dist_ratio)),
            ("counters", counters_json()),
        ]));
    }
    table.finish();

    let doc = obj(vec![
        ("bench", s("ann_vs_exact")),
        ("workload", s(wl.name())),
        ("status", s("measured")),
        ("testbed", s(&machine_summary())),
        ("points", arr(records)),
    ]);
    let out = repo_root_out(&a.get("out"));
    let mut f = std::fs::File::create(&out).expect("write trajectory json");
    writeln!(f, "{doc}").expect("write trajectory json");
    println!("\n[saved {}]", out.display());
    println!("expected shape: speedup grows ~linearly in n; recall stays >= 0.95");
}
