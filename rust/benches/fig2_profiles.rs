//! **Fig. 2 reproduction**: sparsity-profile visuals of the interaction
//! matrices under the six orderings — full-matrix raster plus a zoomed
//! region-of-interest detail, written as PGM images + CSV grids to
//! `bench_out/`, with summary statistics per ordering.

use nni::bench::{out_dir, pipeline_for, print_header, Table, Workload};
use nni::profile::render;
use nni::util::cli::Args;

fn main() {
    let a = Args::new("Fig. 2: profile rasters per ordering")
        .opt("n", "4096", "points per dataset (paper: 16384)")
        .opt("seed", "42", "rng seed")
        .opt("grid", "512", "raster cells per side")
        .opt("threads", "0", "0 = all cores")
        .parse();
    let n = a.get_usize("n");
    let g = a.get_usize("grid").min(n);
    print_header("fig2_profiles", "Fig. 2 — sparse profiles + ROI details");

    let mut table = Table::new(
        "fig2_profiles",
        &["set", "ordering", "bandwidth", "occupied_cells", "raster"],
    );
    for wl in [Workload::Sift, Workload::Gist] {
        let (ds, m) = wl.make(n, a.get_u64("seed"), a.get_usize("threads"));
        for kind in nni::order::OrderingKind::table1_set() {
            let r = pipeline_for(&kind, a.get_u64("seed")).run(&ds, &m);
            let grid = render::density_grid(&r.reordered, g);
            let occupied = grid.iter().filter(|&&c| c > 0).count();
            let slug = format!(
                "fig2_{}_{}",
                wl.name().to_lowercase(),
                kind.label().replace(' ', "_").to_lowercase()
            );
            render::write_pgm(&grid, g, &out_dir().join(format!("{slug}.pgm"))).unwrap();
            render::write_csv(&grid, g, &out_dir().join(format!("{slug}.csv"))).unwrap();
            // ROI: top-left 1/8th of the matrix at full grid resolution —
            // the paper's zoomed sub-matrix detail.
            let roi_rows = n / 8;
            let mut roi = nni::sparse::coo::Coo::new(roi_rows, roi_rows);
            for i in 0..roi_rows {
                let (cols, vals) = r.reordered.row(i);
                for (&j, &v) in cols.iter().zip(vals) {
                    if (j as usize) < roi_rows {
                        roi.push(i, j as usize, v);
                    }
                }
            }
            let roi_csr = roi.to_csr();
            let gg = g.min(roi_rows);
            let roi_grid = render::density_grid(&roi_csr, gg);
            render::write_pgm(&roi_grid, gg, &out_dir().join(format!("{slug}_roi.pgm")))
                .unwrap();
            table.row(vec![
                wl.name().into(),
                kind.label(),
                r.reordered.bandwidth().to_string(),
                occupied.to_string(),
                format!("{slug}.pgm"),
            ]);
        }
    }
    table.finish();
    println!("\nrasters + ROI details in {}/ (dark = dense)", out_dir().display());
    println!("expected shape: rand = uniform gray; rCM = band; 1D = thick band;");
    println!("2D/3D lex = banded block texture; 3D DT = block-sparse with dense blocks");
}
