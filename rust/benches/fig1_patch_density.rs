//! **Fig. 1 reproduction**: four sparsity profiles of the same 500×500
//! matrix — (a) block arrowhead with full 20×20 blocks, (b) random
//! block-row/column permutation of (a), (c) + random row permutation,
//! (d) + random column permutation — with the patch-density estimate β̂
//! and the γ-score (σ=10) for each.
//!
//! Paper's expected shape: β and γ maximal and ~equal for (a) and (b),
//! reduced for (c), further dropped for (d); γ monotone with β.

use nni::bench::{print_header, Table};
use nni::profile::{beta, gamma};
use nni::sparse::gen;
use nni::util::rng::Rng;

fn main() {
    print_header(
        "fig1_patch_density",
        "Fig. 1 — 500x500 block-arrowhead profiles, beta and gamma scores",
    );
    let n = 500;
    let b = 20;
    let sigma = 10.0;

    let a = gen::block_arrowhead(n, b, 1);
    let bperm = gen::permute_blocks(&a, b, 2);
    let mut rng = Rng::new(3);
    let id: Vec<usize> = (0..n).collect();
    let rp = rng.permutation(n);
    let c = bperm.permuted(&rp, &id);
    let cp = rng.permutation(n);
    let d = c.permuted(&id, &cp);

    let mut table = Table::new(
        "fig1_patch_density",
        &["ordering", "nnz", "beta_hat", "patches", "gamma_s10", "gamma_exact"],
    );
    for (label, m) in [
        ("(a) arrowhead", &a),
        ("(b) block-perm", &bperm),
        ("(c) row-perm", &c),
        ("(d) col-perm", &d),
    ] {
        let cov = beta::beta_estimate(m);
        let gf = gamma::gamma_fast(m, sigma);
        let ge = gamma::gamma_exact(m, sigma);
        table.row(vec![
            label.into(),
            m.nnz().to_string(),
            format!("{:.5}", cov.beta),
            cov.count.to_string(),
            format!("{gf:.2}"),
            format!("{ge:.2}"),
        ]);
    }
    table.finish();
    println!(
        "\nexpected shape: beta/gamma (a) ~= (b) > (c) > (d); gamma tracks beta"
    );
}
