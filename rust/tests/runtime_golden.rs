//! Integration: every AOT artifact, loaded through the PJRT runtime, must
//! reproduce the golden outputs computed by the Python oracles at
//! `make artifacts` time.  This is the end-to-end correctness proof of the
//! L1(Pallas) → L2(JAX/HLO) → L3(Rust/PJRT) chain.

use nni::runtime::ArtifactRegistry;

fn registry() -> Option<ArtifactRegistry> {
    match ArtifactRegistry::open_default() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping PJRT golden tests: {e:#}");
            None
        }
    }
}

#[test]
fn all_variant_goldens_roundtrip() {
    let Some(reg) = registry() else { return };
    let mut names: Vec<String> = reg.variants.keys().cloned().collect();
    names.sort();
    assert!(!names.is_empty(), "manifest has no variants");
    let mut checked = 0usize;
    for name in &names {
        let meta = &reg.variants[name];
        let Some(g) = &meta.golden else { continue };
        let inputs: Vec<_> = g
            .inputs
            .iter()
            .map(|(p, s)| ArtifactRegistry::load_golden_tensor(p, s).unwrap())
            .collect();
        let outs = reg.run(name, &inputs).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(outs.len(), g.outputs.len(), "{name}: output arity");
        for (k, ((path, shape), got)) in g.outputs.iter().zip(&outs).enumerate() {
            let want = ArtifactRegistry::load_golden_tensor(path, shape).unwrap();
            assert_eq!(got.shape, want.shape, "{name} out{k} shape");
            let mut max_err = 0.0f32;
            for (a, b) in got.data.iter().zip(&want.data) {
                max_err = max_err.max((a - b).abs() / (1.0 + b.abs()));
            }
            assert!(max_err < 1e-4, "{name} out{k}: max rel err {max_err}");
        }
        checked += 1;
    }
    assert!(checked >= 10, "only {checked} goldens checked");
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some(reg) = registry() else { return };
    let name = "tsne_d2_m256";
    if !reg.variants.contains_key(name) {
        return;
    }
    use nni::runtime::Tensor;
    // wrong arity
    assert!(reg.run(name, &[]).is_err());
    // wrong shape on first input
    let bad = vec![
        Tensor::zeros(vec![128, 2]),
        Tensor::zeros(vec![256, 2]),
        Tensor::zeros(vec![256, 256]),
        Tensor::zeros(vec![256]),
        Tensor::zeros(vec![256]),
    ];
    assert!(reg.run(name, &bad).is_err());
}

#[test]
fn unknown_variant_is_error() {
    let Some(reg) = registry() else { return };
    assert!(reg.get("no_such_variant").is_err());
}
