//! Debug-mode allocation counter: once the engine's per-worker scratch is
//! warm, steady-state applies perform **zero** heap allocations (the
//! scratch-hoisting contract of the apply engine; EXPERIMENTS.md §Kernel
//! dispatch & panel layout).
//!
//! Measured at `threads = 1`: the scoped-thread pool spawns OS threads per
//! *call* (not per block) at higher counts, and those spawns allocate —
//! that is pool overhead, already amortized over multi-ms applies, not the
//! per-block allocation regression this test guards against.
//!
//! Tracing is **enabled** for the steady-state round: spans record into
//! the per-worker slabs pre-sized by `obs::install`, so the zero-allocation
//! contract must hold with instrumentation on, not just off.  The serve
//! tier's histogram and flight-recorder record paths are exercised inside
//! the counted round too: both write into fixed static atomic arrays and
//! must be allocation-free by construction.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn steady_state_applies_are_allocation_free() {
    use nni::csb::hier::HierCsb;
    use nni::data::synth::SynthSpec;
    use nni::interact::engine::Engine;
    use nni::knn::exact::knn_graph;
    use nni::order::Pipeline;
    use nni::sparse::csr::Csr;
    use nni::util::rng::Rng;

    // Build phase allocates freely.
    let n = 900;
    let d = 3;
    let ds = SynthSpec::blobs(n, d, 4, 17).generate();
    let g = knn_graph(&ds, 6, 1);
    let a = Csr::from_knn(&g, n).symmetrized();
    let r = Pipeline::dual_tree(d).run(&ds, &a);
    let tree = r.tree.as_ref().unwrap();
    // low threshold → dense blocks exist, so the panel/GEMM paths run
    let csb = HierCsb::build_with(&r.reordered, tree, tree, 32, 0.25);
    assert!(csb.blocks.len() > 16, "needs a non-trivial schedule: {}", csb.describe());
    let eng = Engine::new(csb, 1);
    let coords = ds.permuted(&r.perm).raw().to_vec();
    let mut rng = Rng::new(7);
    let k = 4;
    let x: Vec<f32> = (0..n * k).map(|_| rng.f32() - 0.5).collect();
    let y: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let mut force = vec![0.0f32; n * d];
    let mut out_k = vec![0.0f32; n * k];
    let mut num = Vec::new();
    let mut den = Vec::new();

    // Tracing on for the whole exercise: span records must land in the
    // slab capacity reserved here, never in a fresh allocation.
    nni::obs::install(1, nni::obs::DEFAULT_SPAN_CAP);
    nni::obs::set_enabled(true);

    // Warm-up: two rounds reach every buffer's high-water mark (each
    // round visits every block, so per-worker scratch sees the largest
    // block of every shape).
    for _ in 0..2 {
        eng.tsne_attr(&y, d, &mut force);
        eng.gauss_apply_multi(&coords, &coords, d, 0.6, &x, k, &mut out_k);
        eng.meanshift_step_into(&coords, &coords, d, 0.5, &mut num, &mut den);
        eng.spmm(&x, &mut out_k, k);
    }

    // Steady state: one more round of every apply — zero allocations.
    let before = allocs();
    eng.tsne_attr(&y, d, &mut force);
    eng.gauss_apply_multi(&coords, &coords, d, 0.6, &x, k, &mut out_k);
    eng.meanshift_step_into(&coords, &coords, d, 0.5, &mut num, &mut den);
    eng.spmm(&x, &mut out_k, k);
    // The serve-tier observability record paths share the contract:
    // static bucket arrays and a static lock-free ring, no heap.
    nni::obs::hist::record(nni::obs::hist::Stage::EndToEnd, 250);
    nni::obs::hist::record_shard(0, 125);
    nni::obs::flight::record(nni::obs::flight::Kind::Admit, -1, 1, 0);
    // Expected 0: schedule precompiled, scratch engine-owned at its
    // high-water mark, output buffers caller-owned — and span, histogram,
    // and flight recording all stayed inside static pre-sized storage.
    let delta = allocs() - before;
    assert_eq!(delta, 0, "steady-state applies allocated {delta} times (tracing on)");

    // The guard above would pass trivially if tracing had been off; prove
    // the traced round actually recorded apply spans.
    nni::obs::set_enabled(false);
    let spans = nni::obs::trace::drain();
    assert!(
        spans.iter().any(|sp| sp.name == "apply.spmm"),
        "no apply spans recorded ({} spans total)",
        spans.len()
    );
    // Same for the histogram and flight-recorder writes in the counted
    // round (snapshotting allocates, which is why it happens only here).
    assert!(nni::obs::hist::stage_snapshot(nni::obs::hist::Stage::EndToEnd).count >= 1);
    assert!(
        nni::obs::flight::snapshot().iter().any(|e| e.kind == nni::obs::flight::Kind::Admit),
        "flight event not recorded"
    );
}
