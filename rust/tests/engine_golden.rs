//! Golden-reference tests for the three interaction-engine kernels: every
//! kernel is checked against a naive O(n²) dense oracle computed from first
//! principles (dense matrix + coordinates, f64 accumulation) on small
//! clustered datasets.  The engines are built with the PJRT-path dense
//! threshold so both the batched micro-GEMM (dense blocks) and the fused
//! scalar path (sparse blocklets) are exercised.

use nni::csb::hier::HierCsb;
use nni::data::synth::SynthSpec;
use nni::interact::engine::Engine;
use nni::knn::exact::knn_graph;
use nni::order::Pipeline;
use nni::sparse::csr::Csr;
use nni::util::rng::Rng;

/// Reordered profile (values = stored matrix), engine with dense blocks,
/// and tree-ordered coordinates.
fn setup(n: usize, d: usize, seed: u64) -> (Csr, Engine, Vec<f32>) {
    let ds = SynthSpec::blobs(n, d, 4, seed).generate();
    let g = knn_graph(&ds, 6, 2);
    let a = Csr::from_knn(&g, n).symmetrized();
    let r = Pipeline::dual_tree(d).run(&ds, &a);
    let tree = r.tree.as_ref().unwrap();
    let csb = HierCsb::build_with(&r.reordered, tree, tree, 32, 0.25);
    assert!(
        csb.dense_fraction() > 0.0,
        "oracle tests must exercise the batched dense path: {}",
        csb.describe()
    );
    let coords = ds.permuted(&r.perm).raw().to_vec();
    (r.reordered, Engine::new(csb, 4), coords)
}

/// Densify the profile (duplicates coalesce additively, as in the CSB).
fn densify(a: &Csr) -> Vec<f32> {
    let n = a.rows;
    let mut dm = vec![0.0f32; n * n];
    for i in 0..a.rows {
        let (cols, vals) = a.row(i);
        for (&j, &v) in cols.iter().zip(vals) {
            dm[i * n + j as usize] += v;
        }
    }
    dm
}

fn assert_close(got: f32, want: f64, ctx: &str) {
    assert!(
        (got as f64 - want).abs() <= 1e-4 * (1.0 + want.abs()),
        "{ctx}: {got} vs oracle {want}"
    );
}

#[test]
fn tsne_attr_matches_dense_oracle() {
    let n = 320;
    let d = 2;
    let (a, eng, _) = setup(n, d, 41);
    let p = densify(&a);
    let mut rng = Rng::new(7);
    let y: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let mut got = vec![0.0f32; n * d];
    eng.tsne_attr(&y, d, &mut got);
    for i in 0..n {
        for k in 0..d {
            let mut want = 0.0f64;
            for j in 0..n {
                let pij = p[i * n + j] as f64;
                if pij == 0.0 {
                    continue;
                }
                let mut d2 = 0.0f64;
                for t in 0..d {
                    let dv = (y[i * d + t] - y[j * d + t]) as f64;
                    d2 += dv * dv;
                }
                want += pij / (1.0 + d2) * (y[i * d + k] - y[j * d + k]) as f64;
            }
            assert_close(got[i * d + k], want, &format!("force[{i},{k}]"));
        }
    }
}

#[test]
fn gauss_apply_matches_dense_oracle() {
    let n = 280;
    let d = 3;
    let (a, eng, coords) = setup(n, d, 43);
    let p = densify(&a);
    let inv_h2 = 0.7f32;
    let mut rng = Rng::new(8);
    let x: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
    let mut got = vec![0.0f32; n];
    eng.gauss_apply(&coords, &coords, d, inv_h2, &x, &mut got);
    for i in 0..n {
        let mut want = 0.0f64;
        for j in 0..n {
            if p[i * n + j] == 0.0 {
                continue;
            }
            let mut d2 = 0.0f64;
            for t in 0..d {
                let dv = (coords[i * d + t] - coords[j * d + t]) as f64;
                d2 += dv * dv;
            }
            want += (-d2 * inv_h2 as f64).exp() * x[j] as f64;
        }
        assert_close(got[i], want, &format!("potential[{i}]"));
    }
}

#[test]
fn gauss_apply_multi_matches_dense_oracle_per_column() {
    let n = 240;
    let d = 3;
    let (a, eng, coords) = setup(n, d, 47);
    let p = densify(&a);
    let inv_h2 = 0.5f32;
    let k = 6;
    let mut rng = Rng::new(9);
    let x: Vec<f32> = (0..n * k).map(|_| rng.f32() - 0.5).collect();
    let mut got = vec![0.0f32; n * k];
    eng.gauss_apply_multi(&coords, &coords, d, inv_h2, &x, k, &mut got);
    for q in 0..k {
        for i in 0..n {
            let mut want = 0.0f64;
            for j in 0..n {
                if p[i * n + j] == 0.0 {
                    continue;
                }
                let mut d2 = 0.0f64;
                for t in 0..d {
                    let dv = (coords[i * d + t] - coords[j * d + t]) as f64;
                    d2 += dv * dv;
                }
                want += (-d2 * inv_h2 as f64).exp() * x[j * k + q] as f64;
            }
            assert_close(got[i * k + q], want, &format!("query {q} potential[{i}]"));
        }
    }
}

#[test]
fn meanshift_step_matches_dense_oracle() {
    let n = 260;
    let d = 3;
    let (a, eng, coords) = setup(n, d, 53);
    let p = densify(&a);
    let inv_h2 = 0.6f32;
    let (num, den) = eng.meanshift_step(&coords, &coords, d, inv_h2);
    for i in 0..n {
        let mut wn = vec![0.0f64; d];
        let mut wd = 0.0f64;
        for j in 0..n {
            if p[i * n + j] == 0.0 {
                continue;
            }
            let mut d2 = 0.0f64;
            for t in 0..d {
                let dv = (coords[i * d + t] - coords[j * d + t]) as f64;
                d2 += dv * dv;
            }
            let w = (-d2 * inv_h2 as f64).exp();
            for (t, wnt) in wn.iter_mut().enumerate() {
                *wnt += w * coords[j * d + t] as f64;
            }
            wd += w;
        }
        assert_close(den[i], wd, &format!("den[{i}]"));
        for (t, &wnt) in wn.iter().enumerate() {
            assert_close(num[i * d + t], wnt, &format!("num[{i},{t}]"));
        }
    }
}

#[test]
fn batched_kernels_thread_count_invariant_and_repeatable() {
    // Target-leaf ownership: identical results across thread counts and
    // across repeated runs, for all three batched kernels.
    let n = 300;
    let d = 2;
    let (_, eng1, coords) = setup(n, d, 59);
    let mut rng = Rng::new(10);
    let y: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let x: Vec<f32> = (0..n * 4).map(|_| rng.f32()).collect();
    let mut f_ref = vec![0.0f32; n * d];
    eng1.tsne_attr(&y, d, &mut f_ref);
    let mut g_ref = vec![0.0f32; n * 4];
    eng1.gauss_apply_multi(&coords, &coords, d, 0.8, &x, 4, &mut g_ref);
    let (num_ref, den_ref) = eng1.meanshift_step(&coords, &coords, d, 0.8);
    for threads in [1usize, 2, 8] {
        let eng = Engine::new(eng1.csb.clone(), threads);
        for _rep in 0..2 {
            let mut f = vec![0.0f32; n * d];
            eng.tsne_attr(&y, d, &mut f);
            assert_eq!(f, f_ref, "tsne threads={threads}");
            let mut g = vec![0.0f32; n * 4];
            eng.gauss_apply_multi(&coords, &coords, d, 0.8, &x, 4, &mut g);
            assert_eq!(g, g_ref, "gauss threads={threads}");
            let (num, den) = eng.meanshift_step(&coords, &coords, d, 0.8);
            assert_eq!(num, num_ref, "ms num threads={threads}");
            assert_eq!(den, den_ref, "ms den threads={threads}");
        }
    }
}
