//! End-to-end acceptance of the `hmat` full-kernel subsystem
//! (ISSUE 5 criteria):
//!
//! * at n = 4096 on synthetic clustered data, `FullKernelEngine::spmv`
//!   matches a streamed O(n²) f64 dense Gaussian oracle to ≤ 10·tol
//!   relative error;
//! * `apps::krr` conjugate gradients converge to the f64 dense-oracle
//!   solution within tolerance;
//! * the fused apply (near + far) is bit-identical across thread counts
//!   under the scalar kernel.
//!
//! Plus the ISSUE 8 (H² far field) criteria: the nested-basis `H2Field`
//! matches the same oracle at n = 4096, stores strictly fewer factor
//! bytes than per-block ACA at the same tolerance, is bit-identical
//! across thread counts, and its skeleton Nyström preconditioner cuts
//! KRR CG iterations without leaving the 2%-of-dense accuracy bar.
//!
//! (The < 30% far-field storage bar at tol = 1e-3 is asserted by
//! `benches/farfield.rs` before its record is written.)

use nni::apps::krr::{self, KrrConfig};
use nni::csb::kernel::KernelKind;
use nni::data::synth::SynthSpec;
use nni::hmat::aca::GaussGen;
use nni::hmat::repr::FarFieldRepr;
use nni::hmat::{FarFieldMode, FullKernelConfig, FullKernelEngine};
use nni::order::dualtree;
use nni::util::rng::Rng;

#[test]
fn full_kernel_spmv_matches_dense_oracle_at_4096() {
    let n = 4096;
    let tol = 1e-3f32;
    let ds = SynthSpec::blobs(n, 3, 6, 99).generate();
    let (perm, tree) = dualtree::order_par(&ds, 16, 0);
    let coords = ds.permuted(&perm);
    let h = krr::suggest_bandwidth(&ds, 1);
    let inv_h2 = (1.0 / (h * h)) as f32;
    let cfg = FullKernelConfig::new(inv_h2)
        .with_tol(tol)
        .with_block_cap(128);
    let eng = FullKernelEngine::build(&tree, coords.raw(), 3, &cfg, 0, 0, KernelKind::Scalar);
    assert!(!eng.far.is_empty(), "clustered data must produce far blocks");

    let mut rng = Rng::new(3);
    let x: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
    let mut y = vec![0.0f32; n];
    eng.spmv(&x, &mut y);

    // Streamed f64 oracle: never materializes the n x n matrix.
    let gen = GaussGen {
        coords: coords.raw(),
        d: 3,
        inv_h2,
    };
    let mut err2 = 0.0f64;
    let mut norm2 = 0.0f64;
    for i in 0..n {
        let mut want = 0.0f64;
        for j in 0..n {
            want += gen.entry_f64(i, j) * x[j] as f64;
        }
        let diff = y[i] as f64 - want;
        err2 += diff * diff;
        norm2 += want * want;
    }
    let rel = (err2 / norm2).sqrt();
    assert!(
        rel <= 10.0 * tol as f64,
        "full-kernel spmv rel err {rel:.3e} > 10*tol at n={n} ({})",
        eng.describe()
    );
    // Compression sanity at scale: the operator must be far below dense.
    let dense_bytes = n as u64 * n as u64 * 4;
    assert!(
        eng.stored_bytes() * 2 < dense_bytes,
        "stored {} bytes not < half of dense {}",
        eng.stored_bytes(),
        dense_bytes
    );
}

/// ISSUE 8 acceptance: the nested-basis H² far field at n = 4096 must
/// (a) apply within 10·tol of the streamed f64 dense oracle, (b) store
/// strictly fewer far-field factor bytes than per-block ACA at the same
/// tolerance, and (c) be bit-identical across thread counts {1, 2, 8} —
/// both the built factors and the fused apply.
#[test]
fn h2_spmv_matches_dense_oracle_and_beats_aca_storage_at_4096() {
    let n = 4096;
    let tol = 1e-3f32;
    let ds = SynthSpec::blobs(n, 3, 6, 99).generate();
    let (perm, tree) = dualtree::order_par(&ds, 16, 0);
    let coords = ds.permuted(&perm);
    let h = krr::suggest_bandwidth(&ds, 1);
    let inv_h2 = (1.0 / (h * h)) as f32;
    let cfg = FullKernelConfig::new(inv_h2)
        .with_tol(tol)
        .with_block_cap(128)
        .with_far(FarFieldMode::H2);
    let eng = FullKernelEngine::build(&tree, coords.raw(), 3, &cfg, 0, 0, KernelKind::Scalar);
    assert!(!eng.far.is_empty(), "clustered data must produce an H2 far field");

    let mut rng = Rng::new(3);
    let x: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
    let mut y = vec![0.0f32; n];
    eng.spmv(&x, &mut y);

    // (a) streamed f64 oracle — never materializes the n x n matrix.
    let gen = GaussGen {
        coords: coords.raw(),
        d: 3,
        inv_h2,
    };
    let mut err2 = 0.0f64;
    let mut norm2 = 0.0f64;
    for i in 0..n {
        let mut want = 0.0f64;
        for j in 0..n {
            want += gen.entry_f64(i, j) * x[j] as f64;
        }
        let diff = y[i] as f64 - want;
        err2 += diff * diff;
        norm2 += want * want;
    }
    let rel = (err2 / norm2).sqrt();
    assert!(
        rel <= 10.0 * tol as f64,
        "h2 spmv rel err {rel:.3e} > 10*tol at n={n} ({})",
        eng.describe()
    );

    // (b) the nested representation must store strictly less than the
    // per-block ACA factors it replaces (same tree, same tolerance).
    let aca_cfg = cfg.clone().with_far(FarFieldMode::Aca);
    let aca = FullKernelEngine::build(&tree, coords.raw(), 3, &aca_cfg, 0, 0, KernelKind::Scalar);
    assert!(
        eng.far.far_bytes() < aca.far.far_bytes(),
        "h2 factors {} bytes not < aca {} bytes at tol {tol}",
        eng.far.far_bytes(),
        aca.far.far_bytes()
    );

    // (c) build + apply bit-identity across thread counts.
    for threads in [1usize, 2, 8] {
        let e =
            FullKernelEngine::build(&tree, coords.raw(), 3, &cfg, threads, threads, KernelKind::Scalar);
        assert!(e.far.bits_eq(&eng.far), "h2 factors differ at threads={threads}");
        let mut yt = vec![0.0f32; n];
        e.spmv(&x, &mut yt);
        assert!(
            yt.iter().zip(&y).all(|(a, b)| a.to_bits() == b.to_bits()),
            "h2 apply differs at threads={threads}"
        );
    }
}

#[test]
fn krr_cg_matches_f64_dense_oracle() {
    // Small n so the f64 dense oracle solve stays cheap in debug builds;
    // the tolerance budget is dominated by the ACA perturbation:
    // ‖δα‖ ≲ (1/λ)·‖δK‖·‖α‖ with ‖δK‖ ≲ tol·‖K‖_F.
    let n = 600;
    let ds = SynthSpec::blobs(n, 3, 4, 7).generate();
    let y = krr::synthetic_targets(&ds, 11);
    let lambda = 1.0f64;
    let cfg = KrrConfig {
        lambda,
        tol: 1e-5,
        block_cap: 64,
        // f32 CG: the recursive residual reaches ~1e-7·κ reliably; don't
        // demand more than single precision can certify.
        cg_tol: 1e-7,
        cg_max_iters: 2000,
        threads: 2,
        kernel: KernelKind::Scalar,
        ..KrrConfig::default()
    };
    let res = krr::run(&ds, &y, &cfg);
    assert!(res.rel_residual < 1e-5, "CG residual {}", res.rel_residual);

    // f64 dense oracle: assemble K, solve (K + λI)α = y by f64 CG.
    let h = res.bandwidth;
    let inv_h2 = 1.0 / (h * h);
    let mut k_dense = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut d2 = 0.0f64;
            for a in 0..3 {
                let t = ds.row(i)[a] as f64 - ds.row(j)[a] as f64;
                d2 += t * t;
            }
            k_dense[i * n + j] = (-d2 * inv_h2).exp();
        }
    }
    let b: Vec<f64> = y.iter().map(|&v| v as f64).collect();
    let alpha_ref = dense_cg(&k_dense, n, lambda, &b, 1e-12, 4000);

    let num: f64 = res
        .alpha
        .iter()
        .zip(&alpha_ref)
        .map(|(&a, &r)| (a as f64 - r) * (a as f64 - r))
        .sum::<f64>()
        .sqrt();
    let den: f64 = alpha_ref.iter().map(|r| r * r).sum::<f64>().sqrt();
    assert!(
        num <= 2e-2 * den.max(1e-12),
        "krr solution deviates from dense oracle: rel {:.3e} ({})",
        num / den.max(1e-12),
        res.summary
    );
}

/// ISSUE 8 acceptance: CG preconditioned by the H²-skeleton Nyström
/// operator must converge in strictly fewer iterations than plain CG on
/// the same H² operator, while still landing within 2% of the f64 dense
/// oracle solution.
#[test]
fn krr_h2_preconditioner_fewer_iterations() {
    let n = 600;
    let ds = SynthSpec::blobs(n, 3, 4, 7).generate();
    let y = krr::synthetic_targets(&ds, 11);
    let lambda = 1.0f64;
    let base = KrrConfig {
        lambda,
        tol: 1e-4,
        block_cap: 64,
        cg_tol: 1e-6,
        cg_max_iters: 2000,
        threads: 2,
        kernel: KernelKind::Scalar,
        far: FarFieldMode::H2,
        ..KrrConfig::default()
    };
    let plain = krr::run(&ds, &y, &base);
    let pre = krr::run(
        &ds,
        &y,
        &KrrConfig {
            precond: true,
            ..base
        },
    );
    assert!(plain.iterations > 0 && pre.iterations > 0);
    assert!(
        pre.iterations < plain.iterations,
        "H2 Nystrom preconditioner did not reduce CG iterations: {} vs {}",
        pre.iterations,
        plain.iterations
    );

    // f64 dense oracle solve, same accuracy bar as the plain-CG test.
    let h = pre.bandwidth;
    let inv_h2 = 1.0 / (h * h);
    let mut k_dense = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut d2 = 0.0f64;
            for a in 0..3 {
                let t = ds.row(i)[a] as f64 - ds.row(j)[a] as f64;
                d2 += t * t;
            }
            k_dense[i * n + j] = (-d2 * inv_h2).exp();
        }
    }
    let b: Vec<f64> = y.iter().map(|&v| v as f64).collect();
    let alpha_ref = dense_cg(&k_dense, n, lambda, &b, 1e-12, 4000);
    let num: f64 = pre
        .alpha
        .iter()
        .zip(&alpha_ref)
        .map(|(&a, &r)| (a as f64 - r) * (a as f64 - r))
        .sum::<f64>()
        .sqrt();
    let den: f64 = alpha_ref.iter().map(|r| r * r).sum::<f64>().sqrt();
    assert!(
        num <= 2e-2 * den.max(1e-12),
        "preconditioned krr deviates from dense oracle: rel {:.3e} ({})",
        num / den.max(1e-12),
        pre.summary
    );
}

/// f64 dense CG on (K + λI)x = b.
fn dense_cg(k: &[f64], n: usize, lambda: f64, b: &[f64], tol: f64, max_iters: usize) -> Vec<f64> {
    let matvec = |p: &[f64], out: &mut [f64]| {
        for i in 0..n {
            let row = &k[i * n..(i + 1) * n];
            let mut acc = 0.0f64;
            for (rv, pv) in row.iter().zip(p) {
                acc += rv * pv;
            }
            out[i] = acc + lambda * p[i];
        }
    };
    let mut x = vec![0.0f64; n];
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut ap = vec![0.0f64; n];
    let bnorm: f64 = b.iter().map(|v| v * v).sum::<f64>().sqrt();
    if bnorm == 0.0 {
        return x;
    }
    let mut rs: f64 = r.iter().map(|v| v * v).sum();
    for _ in 0..max_iters {
        if rs.sqrt() <= tol * bnorm {
            break;
        }
        matvec(&p, &mut ap);
        let pap: f64 = p.iter().zip(&ap).map(|(a, c)| a * c).sum();
        if pap <= 0.0 {
            break;
        }
        let step = rs / pap;
        for i in 0..n {
            x[i] += step * p[i];
            r[i] -= step * ap[i];
        }
        let rs_new: f64 = r.iter().map(|v| v * v).sum();
        let beta = rs_new / rs;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs = rs_new;
    }
    x
}

#[test]
fn fused_apply_bitidentical_across_thread_counts() {
    let n = 1500;
    let ds = SynthSpec::blobs(n, 3, 5, 23).generate();
    let (perm, tree) = dualtree::order_par(&ds, 16, 0);
    let coords = ds.permuted(&perm);
    let cfg = FullKernelConfig::new(0.7).with_block_cap(64);
    let mut rng = Rng::new(31);
    let x: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
    let mut reference: Vec<f32> = Vec::new();
    for threads in [1usize, 2, 8] {
        let k = KernelKind::Scalar;
        let eng = FullKernelEngine::build(&tree, coords.raw(), 3, &cfg, threads, threads, k);
        let mut y = vec![0.0f32; n];
        eng.spmv(&x, &mut y);
        if reference.is_empty() {
            reference = y;
        } else {
            assert!(
                y.iter().zip(&reference).all(|(a, b)| a.to_bits() == b.to_bits()),
                "fused apply differs at threads={threads}"
            );
        }
    }
}
