//! Cross-module integration: full ordering pipelines on the paper's
//! surrogate workloads, checking (i) numerical equivalence of every SpMV
//! engine under every ordering, and (ii) the paper's qualitative claims —
//! γ ranks dual-tree above lexical above scattered, and γ agrees with β̂.

use nni::bench::Workload;
use nni::csb::hier::HierCsb;
use nni::order::{OrderingKind, Pipeline};
use nni::profile::{beta, gamma};
use nni::spmv;
use nni::util::rng::Rng;

#[test]
fn all_orderings_preserve_spmv_semantics() {
    let (ds, a) = Workload::Sift.make(1024, 7, 4);
    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..ds.n()).map(|_| rng.f32()).collect();
    let y_ref = a.matvec_ref(&x);
    for kind in OrderingKind::table1_set() {
        let r = Pipeline::new(kind.clone()).run(&ds, &a);
        let xp: Vec<f32> = r.perm.iter().map(|&p| x[p]).collect();
        let mut yp = vec![0.0f32; ds.n()];
        spmv::csr::spmv_seq(&r.reordered, &xp, &mut yp);
        for i in 0..ds.n() {
            let got = yp[r.pos[i]];
            assert!(
                (got - y_ref[i]).abs() < 1e-3 * (1.0 + y_ref[i].abs()),
                "{kind:?} row {i}: {got} vs {}",
                y_ref[i]
            );
        }
    }
}

#[test]
fn csb_engines_agree_across_thread_counts() {
    let (ds, a) = Workload::Sift.make(2048, 9, 4);
    let r = Pipeline::dual_tree(3).run(&ds, &a);
    let tree = r.tree.as_ref().unwrap();
    let csb = HierCsb::build(&r.reordered, tree, tree, 256);
    let mut rng = Rng::new(2);
    let x: Vec<f32> = (0..ds.n()).map(|_| rng.f32()).collect();
    let mut y_csr = vec![0.0f32; ds.n()];
    spmv::csr::spmv_seq(&r.reordered, &x, &mut y_csr);
    let mut y = vec![0.0f32; ds.n()];
    spmv::multilevel::spmv_ml_seq(&csb, &x, &mut y);
    for (g, w) in y.iter().zip(&y_csr) {
        assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()));
    }
    let seq = y.clone();
    for threads in [2, 4, 8] {
        spmv::multilevel::spmv_ml_par(&csb, &x, &mut y, threads);
        assert_eq!(seq, y, "threads={threads}");
    }
}

#[test]
fn gamma_ranks_orderings_as_paper_table1() {
    // Table 1's qualitative ranking on the SIFT surrogate:
    //   rand < 1D < {2D lex, 3D lex} < 3D DT, with rCM > rand.
    let (ds, a) = Workload::Sift.make(2048, 5, 4);
    let sigma = Workload::Sift.k() as f64 / 2.0;
    let score = |kind: OrderingKind| {
        let r = Pipeline::new(kind).run(&ds, &a);
        gamma::gamma_fast(&r.reordered, sigma)
    };
    let rand = score(OrderingKind::Scattered);
    let rcm = score(OrderingKind::Rcm);
    let d1 = score(OrderingKind::Pca1d);
    let lex3 = score(OrderingKind::Lex { d: 3 });
    let dt3 = score(OrderingKind::DualTree { d: 3 });
    println!("gamma: rand={rand:.1} rcm={rcm:.1} 1d={d1:.1} lex3={lex3:.1} dt3={dt3:.1}");
    assert!(rcm > rand, "rCM {rcm} !> rand {rand}");
    assert!(d1 > rand, "1D {d1} !> rand {rand}");
    assert!(lex3 > d1 * 0.9, "3D lex {lex3} !>~ 1D {d1}");
    assert!(dt3 > lex3, "3D DT {dt3} !> 3D lex {lex3}");
    assert!(dt3 > rand * 2.0, "DT should be far above scattered");
}

#[test]
fn beta_and_gamma_agree_on_ranking() {
    let (ds, a) = Workload::Sift.make(1024, 11, 4);
    let kinds = [
        OrderingKind::Scattered,
        OrderingKind::Lex { d: 3 },
        OrderingKind::DualTree { d: 3 },
    ];
    let mut scores = Vec::new();
    for kind in kinds {
        let r = Pipeline::new(kind.clone()).run(&ds, &a);
        let g = gamma::gamma_fast(&r.reordered, 15.0);
        let b = beta::beta_estimate(&r.reordered).beta;
        scores.push((kind, g, b));
    }
    // both measures should order: scattered < lex3 <= dt3
    assert!(scores[0].1 < scores[1].1 && scores[1].1 <= scores[2].1 * 1.05,
        "gamma ranking violated: {scores:?}");
    assert!(scores[0].2 <= scores[2].2,
        "beta ranking violated: {scores:?}");
}

#[test]
fn dual_tree_ml_spmv_is_competitive_and_gamma_predicts_locality() {
    // Testbed note (EXPERIMENTS.md §Testbed): this container has a 260 MB
    // LLC, so the paper's banded-vs-scattered SpMV roofline ratio is ~1.0
    // at CI sizes — by the paper's own normalization, time parity is the
    // expected outcome here, and the locality improvement is asserted on
    // the machine-independent gamma-score instead.  The micro-bench and
    // fig3 harnesses report the measured ratios against that roofline.
    let n = 1 << 13;
    let (ds, a) = Workload::Sift.make(n, 3, 0);
    let scat = Pipeline::new(OrderingKind::Scattered).run(&ds, &a);
    let dt = Pipeline::dual_tree(3).run(&ds, &a);
    let tree = dt.tree.as_ref().unwrap();
    // block cap 2048: SpMV-oriented blocking (perf log: smaller caps
    // shred rows to ~1.5 entries per block-row; EXPERIMENTS.md §Perf)
    let csb = HierCsb::build(&dt.reordered, tree, tree, 2048);
    let x = vec![1.0f32; n];
    let mut y = vec![0.0f32; n];
    let t_scat = nni::util::timer::bench_default(|| {
        spmv::csr::spmv_seq(&scat.reordered, &x, &mut y)
    });
    let t_dt = nni::util::timer::bench_default(|| {
        spmv::multilevel::spmv_ml_seq(&csb, &x, &mut y)
    });
    println!(
        "scattered csr: {:.3} ms, dual-tree ml: {:.3} ms",
        t_scat.robust_min_s * 1e3,
        t_dt.robust_min_s * 1e3
    );
    // L3 criterion from DESIGN §8: the multilevel machinery must not
    // become the bottleneck — within 1.3x of raw CSR streaming.
    assert!(
        t_dt.robust_min_s < 1.3 * t_scat.robust_min_s,
        "multilevel overhead too high: {:.3} ms vs {:.3} ms",
        t_dt.robust_min_s * 1e3,
        t_scat.robust_min_s * 1e3
    );
    // The machine-independent claim: the dual-tree ordering's locality is
    // far better, as measured by the gamma-score.
    let sigma = Workload::Sift.k() as f64 / 2.0;
    let g_scat = nni::profile::gamma::gamma_fast(&scat.reordered, sigma);
    let g_dt = nni::profile::gamma::gamma_fast(&dt.reordered, sigma);
    assert!(g_dt > 3.0 * g_scat, "gamma: dt {g_dt} vs scattered {g_scat}");
}
