//! Property-based invariants across the system (in-tree `util::prop`
//! harness; DESIGN.md §6).  Every property runs 64 seeded random cases with
//! shrinking-on-failure; reproduce any failure with `NNI_PROP_SEED=<seed>`.

use nni::csb::hier::HierCsb;
use nni::data::dataset::Dataset;
use nni::order::{compose, invert, is_permutation};
use nni::prop_assert;
use nni::sparse::csr::Csr;
use nni::tree::boxtree::BoxTree;
use nni::util::prop::check;
use nni::util::rng::Rng;

fn random_csr(rng: &mut Rng, n: usize, per_row: usize) -> Csr {
    let mut r = Vec::new();
    let mut c = Vec::new();
    let mut v = Vec::new();
    for i in 0..n {
        for j in rng.sample_distinct(n, per_row.min(n)) {
            r.push(i as u32);
            c.push(j as u32);
            v.push(rng.f32() + 0.05);
        }
    }
    Csr::from_triplets(n, n, &r, &c, &v)
}

fn random_points(rng: &mut Rng, n: usize, d: usize) -> Dataset {
    Dataset::new(n, d, (0..n * d).map(|_| rng.normal() as f32).collect())
}

#[test]
fn permutation_inverse_composes_to_identity() {
    check("perm-inv", |rng, size| {
        let n = 1 + rng.below(size);
        let p = rng.permutation(n);
        let q = invert(&p);
        prop_assert!(is_permutation(&p) && is_permutation(&q));
        let id = compose(&p, &q);
        prop_assert!(id.iter().enumerate().all(|(k, &v)| k == v));
        Ok(())
    });
}

#[test]
fn permuting_matrix_preserves_nnz_and_values_multiset() {
    check("perm-nnz", |rng, size| {
        let n = 2 + rng.below(size / 2 + 2);
        let pr = 1 + rng.below(4);
        let a = random_csr(rng, n, pr);
        let rp = rng.permutation(n);
        let cp = rng.permutation(n);
        let b = a.permuted(&rp, &cp);
        prop_assert!(b.nnz() == a.nnz());
        let mut va: Vec<u32> = a.val.iter().map(|v| v.to_bits()).collect();
        let mut vb: Vec<u32> = b.val.iter().map(|v| v.to_bits()).collect();
        va.sort_unstable();
        vb.sort_unstable();
        prop_assert!(va == vb, "value multiset changed");
        Ok(())
    });
}

#[test]
fn tree_leaves_partition_any_point_set() {
    check("tree-partition", |rng, size| {
        let n = 1 + rng.below(size);
        let d = 1 + rng.below(3);
        let ds = random_points(rng, n, d);
        let cap = 1 + rng.below(32);
        let t = BoxTree::build(&ds, cap, 20);
        prop_assert!(is_permutation(&t.perm));
        let leaves = t.leaves();
        let mut expect = 0u32;
        for &l in &leaves {
            let nd = &t.nodes[l as usize];
            prop_assert!(nd.lo == expect, "leaf gap at {expect}");
            expect = nd.hi;
        }
        prop_assert!(expect as usize == n);
        Ok(())
    });
}

#[test]
fn csb_spmv_equals_csr_on_random_matrices() {
    check("csb-spmv", |rng, size| {
        let n = 8 + rng.below(size);
        let d = 2 + rng.below(2);
        let ds = random_points(rng, n, d);
        let pr = 1 + rng.below(6);
        let a = random_csr(rng, n, pr);
        // build trees over the data, reorder, compare products
        let tree = BoxTree::build(&ds, 1 + rng.below(40), 20);
        let pos = invert(&tree.perm);
        let b = a.permuted(&pos, &pos);
        let csb = HierCsb::build(&b, &tree, &tree, 0);
        let x: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let want = b.matvec_ref(&x);
        let mut got = vec![0.0f32; n];
        csb.spmv(&x, &mut got);
        for (g, w) in got.iter().zip(&want) {
            prop_assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "{g} vs {w}");
        }
        // nnz conservation
        let total: u64 = csb.blocks.iter().map(|bl| bl.nnz as u64).sum();
        prop_assert!(total as usize == b.nnz());
        Ok(())
    });
}

#[test]
fn parallel_spmv_deterministic_across_threads() {
    check("par-deterministic", |rng, size| {
        let n = 16 + rng.below(size);
        let ds = random_points(rng, n, 2);
        let a = random_csr(rng, n, 3);
        let tree = BoxTree::build(&ds, 24, 20);
        let pos = invert(&tree.perm);
        let b = a.permuted(&pos, &pos);
        let csb = HierCsb::build(&b, &tree, &tree, 0);
        let x: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let mut y1 = vec![0.0f32; n];
        let mut y2 = vec![0.0f32; n];
        nni::spmv::multilevel::spmv_ml_par(&csb, &x, &mut y1, 2);
        nni::spmv::multilevel::spmv_ml_par(&csb, &x, &mut y2, 7);
        prop_assert!(y1 == y2, "thread-count nondeterminism");
        Ok(())
    });
}

#[test]
fn gamma_fast_tracks_exact_on_random_profiles() {
    check("gamma-fast", |rng, size| {
        let n = 8 + rng.below(size / 2 + 8);
        let pr = 1 + rng.below(4);
        let a = random_csr(rng, n, pr);
        let sigma = 2.0 + rng.f64() * 6.0;
        let exact = nni::profile::gamma::gamma_exact(&a, sigma);
        let fast = nni::profile::gamma::gamma_fast(&a, sigma);
        prop_assert!(
            (exact - fast).abs() <= 0.08 * exact.max(1e-12),
            "sigma {sigma}: exact {exact} vs fast {fast}"
        );
        Ok(())
    });
}

#[test]
fn vector_layout_roundtrips() {
    check("layout-roundtrip", |rng, size| {
        let n = 1 + rng.below(size);
        let d = 1 + rng.below(4);
        let perm = rng.permutation(n);
        let x: Vec<f32> = (0..n * d).map(|_| rng.f32()).collect();
        let xt = nni::csb::layout::rows_to_tree_order(&x, d, &perm);
        let back = nni::csb::layout::rows_from_tree_order(&xt, d, &perm);
        prop_assert!(back == x);
        Ok(())
    });
}

#[test]
fn coordinator_plan_partitions_blocks() {
    use nni::coordinator::batcher::{BatchPlan, BatchPolicy};
    check("plan-partition", |rng, size| {
        let n = 32 + rng.below(size * 2);
        let ds = random_points(rng, n, 2);
        let pr = 2 + rng.below(6);
        let a = random_csr(rng, n, pr);
        let tree = BoxTree::build(&ds, 16 + rng.below(100), 20);
        let pos = invert(&tree.perm);
        let b = a.permuted(&pos, &pos);
        let csb = HierCsb::build(&b, &tree, &tree, 0);
        let policy = BatchPolicy {
            min_nnz: rng.below(64) as u32,
            pjrt_enabled: rng.f32() < 0.8,
            ..Default::default()
        };
        let plan = BatchPlan::build(&csb, &policy);
        prop_assert!(plan.total_blocks() == csb.blocks.len());
        let mut seen = vec![false; csb.blocks.len()];
        let mut mark = |t: u32| -> Result<(), String> {
            if seen[t as usize] {
                return Err(format!("block {t} routed twice"));
            }
            seen[t as usize] = true;
            Ok(())
        };
        for &t in &plan.rust {
            mark(t)?;
        }
        for &t in &plan.pjrt_single {
            mark(t)?;
        }
        for g in &plan.pjrt_batches {
            for &t in g {
                mark(t)?;
            }
        }
        prop_assert!(seen.iter().all(|&s| s));
        Ok(())
    });
}
