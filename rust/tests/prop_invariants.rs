//! Property-based invariants across the system (in-tree `util::prop`
//! harness; DESIGN.md §6).  Every property runs 64 seeded random cases with
//! shrinking-on-failure; reproduce any failure with `NNI_PROP_SEED=<seed>`.

use nni::csb::hier::HierCsb;
use nni::data::dataset::Dataset;
use nni::order::{compose, invert, is_permutation};
use nni::prop_assert;
use nni::sparse::csr::Csr;
use nni::tree::boxtree::BoxTree;
use nni::util::prop::check;
use nni::util::rng::Rng;

fn random_csr(rng: &mut Rng, n: usize, per_row: usize) -> Csr {
    let mut r = Vec::new();
    let mut c = Vec::new();
    let mut v = Vec::new();
    for i in 0..n {
        for j in rng.sample_distinct(n, per_row.min(n)) {
            r.push(i as u32);
            c.push(j as u32);
            v.push(rng.f32() + 0.05);
        }
    }
    Csr::from_triplets(n, n, &r, &c, &v)
}

fn random_points(rng: &mut Rng, n: usize, d: usize) -> Dataset {
    Dataset::new(n, d, (0..n * d).map(|_| rng.normal() as f32).collect())
}

#[test]
fn permutation_inverse_composes_to_identity() {
    check("perm-inv", |rng, size| {
        let n = 1 + rng.below(size);
        let p = rng.permutation(n);
        let q = invert(&p);
        prop_assert!(is_permutation(&p) && is_permutation(&q));
        let id = compose(&p, &q);
        prop_assert!(id.iter().enumerate().all(|(k, &v)| k == v));
        Ok(())
    });
}

#[test]
fn permuting_matrix_preserves_nnz_and_values_multiset() {
    check("perm-nnz", |rng, size| {
        let n = 2 + rng.below(size / 2 + 2);
        let pr = 1 + rng.below(4);
        let a = random_csr(rng, n, pr);
        let rp = rng.permutation(n);
        let cp = rng.permutation(n);
        let b = a.permuted(&rp, &cp);
        prop_assert!(b.nnz() == a.nnz());
        let mut va: Vec<u32> = a.val.iter().map(|v| v.to_bits()).collect();
        let mut vb: Vec<u32> = b.val.iter().map(|v| v.to_bits()).collect();
        va.sort_unstable();
        vb.sort_unstable();
        prop_assert!(va == vb, "value multiset changed");
        Ok(())
    });
}

#[test]
fn tree_leaves_partition_any_point_set() {
    check("tree-partition", |rng, size| {
        let n = 1 + rng.below(size);
        let d = 1 + rng.below(3);
        let ds = random_points(rng, n, d);
        let cap = 1 + rng.below(32);
        let t = BoxTree::build(&ds, cap, 20);
        prop_assert!(is_permutation(&t.perm));
        let leaves = t.leaves();
        let mut expect = 0u32;
        for &l in &leaves {
            let nd = &t.nodes[l as usize];
            prop_assert!(nd.lo == expect, "leaf gap at {expect}");
            expect = nd.hi;
        }
        prop_assert!(expect as usize == n);
        Ok(())
    });
}

#[test]
fn csb_spmv_equals_csr_on_random_matrices() {
    check("csb-spmv", |rng, size| {
        let n = 8 + rng.below(size);
        let d = 2 + rng.below(2);
        let ds = random_points(rng, n, d);
        let pr = 1 + rng.below(6);
        let a = random_csr(rng, n, pr);
        // build trees over the data, reorder, compare products
        let tree = BoxTree::build(&ds, 1 + rng.below(40), 20);
        let pos = invert(&tree.perm);
        let b = a.permuted(&pos, &pos);
        let csb = HierCsb::build(&b, &tree, &tree, 0);
        let x: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let want = b.matvec_ref(&x);
        let mut got = vec![0.0f32; n];
        csb.spmv(&x, &mut got);
        for (g, w) in got.iter().zip(&want) {
            prop_assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "{g} vs {w}");
        }
        // nnz conservation
        let total: u64 = csb.blocks.iter().map(|bl| bl.nnz as u64).sum();
        prop_assert!(total as usize == b.nnz());
        Ok(())
    });
}

#[test]
fn parallel_spmv_deterministic_across_threads() {
    check("par-deterministic", |rng, size| {
        let n = 16 + rng.below(size);
        let ds = random_points(rng, n, 2);
        let a = random_csr(rng, n, 3);
        let tree = BoxTree::build(&ds, 24, 20);
        let pos = invert(&tree.perm);
        let b = a.permuted(&pos, &pos);
        let csb = HierCsb::build(&b, &tree, &tree, 0);
        let x: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let mut y1 = vec![0.0f32; n];
        let mut y2 = vec![0.0f32; n];
        nni::spmv::multilevel::spmv_ml_par(&csb, &x, &mut y1, 2);
        nni::spmv::multilevel::spmv_ml_par(&csb, &x, &mut y2, 7);
        prop_assert!(y1 == y2, "thread-count nondeterminism");
        Ok(())
    });
}

/// Random reordered CSB over random points/profile (shared by the HierCsb
/// invariant properties below).
fn random_csb(rng: &mut Rng, size: usize) -> (Csr, HierCsb) {
    let n = 8 + rng.below(size);
    let d = 1 + rng.below(3);
    let ds = random_points(rng, n, d);
    let pr = 1 + rng.below(6);
    let a = random_csr(rng, n, pr);
    let tree = BoxTree::build(&ds, 1 + rng.below(40), 20);
    let pos = invert(&tree.perm);
    let b = a.permuted(&pos, &pos);
    // random dense threshold: exercise all-dense, mixed, and all-sparse
    let thr = rng.f64() * 1.2;
    let csb = HierCsb::build_with(&b, &tree, &tree, 0, thr);
    (b, csb)
}

#[test]
fn every_nonzero_lands_in_exactly_one_block() {
    check("block-partition", |rng, size| {
        let (b, csb) = random_csb(rng, size);
        // Collect (row, col, value-bits) from the blocks, checking span
        // membership; multiset equality with the CSR triplets proves each
        // nonzero lands in exactly one block with its value intact.
        let mut from_blocks: Vec<(u32, u32, u32)> = Vec::with_capacity(b.nnz());
        let mut in_span = true;
        for t in 0..csb.blocks.len() {
            let blk = csb.blocks[t].clone();
            csb.for_each_nz(t, |r, c, v| {
                in_span &= r < blk.rows.len() && c < blk.cols.len();
                from_blocks.push((blk.rows.lo + r as u32, blk.cols.lo + c as u32, v.to_bits()));
            });
        }
        prop_assert!(in_span, "nonzero outside its block's spans");
        let mut from_csr: Vec<(u32, u32, u32)> = Vec::with_capacity(b.nnz());
        for i in 0..b.rows {
            let (cols, vals) = b.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                from_csr.push((i as u32, j, v.to_bits()));
            }
        }
        from_blocks.sort_unstable();
        from_csr.sort_unstable();
        prop_assert!(
            from_blocks == from_csr,
            "block nonzeros != csr nonzeros ({} vs {})",
            from_blocks.len(),
            from_csr.len()
        );
        Ok(())
    });
}

#[test]
fn arena_offsets_in_bounds_and_non_overlapping() {
    use nni::csb::hier::BlockKind;
    check("arena-bounds", |rng, size| {
        let (_, csb) = random_csb(rng, size);
        let mut dense_iv: Vec<(usize, usize)> = Vec::new();
        let mut row_iv: Vec<(usize, usize)> = Vec::new();
        let mut ent_iv: Vec<(usize, usize)> = Vec::new();
        for b in &csb.blocks {
            match b.kind {
                BlockKind::Dense { off } => {
                    let lo = off as usize;
                    let hi = lo + b.rows.len() * b.cols.len();
                    prop_assert!(hi <= csb.dense.len(), "dense arena overflow");
                    dense_iv.push((lo, hi));
                }
                BlockKind::Sparse {
                    row_off,
                    row_cnt,
                    ptr_off,
                } => {
                    let rlo = row_off as usize;
                    let rhi = rlo + row_cnt as usize;
                    prop_assert!(rhi <= csb.sp_rows.len(), "sp_rows overflow");
                    prop_assert!(row_cnt as usize <= b.rows.len(), "more occupied rows than span");
                    row_iv.push((rlo, rhi));
                    let plo = ptr_off as usize;
                    let phi = plo + row_cnt as usize + 1;
                    prop_assert!(phi <= csb.sp_ptr.len(), "sp_ptr overflow");
                    // entry pointers: monotone, in-bounds
                    for w in csb.sp_ptr[plo..phi].windows(2) {
                        prop_assert!(w[0] <= w[1], "sp_ptr not monotone");
                    }
                    let elo = csb.sp_ptr[plo] as usize;
                    let ehi = csb.sp_ptr[phi - 1] as usize;
                    prop_assert!(ehi <= csb.sp_val.len(), "entry arena overflow");
                    prop_assert!(ehi - elo == b.nnz as usize, "entry count != block nnz");
                    ent_iv.push((elo, ehi));
                }
            }
        }
        // non-overlap per arena (empty intervals are trivially fine)
        for iv in [&mut dense_iv, &mut row_iv, &mut ent_iv] {
            iv.sort_unstable();
            for w in iv.windows(2) {
                prop_assert!(w[0].1 <= w[1].0, "overlapping arena intervals {w:?}");
            }
        }
        Ok(())
    });
}

#[test]
fn panels_mirror_dense_arena_and_stay_disjoint() {
    use nni::csb::hier::BlockKind;
    use nni::csb::panel::{panel_len, NO_PANEL, PANEL_MR};
    check("panel-mirror", |rng, size| {
        let (_, csb) = random_csb(rng, size);
        prop_assert!(csb.panels.off.len() == csb.blocks.len());
        let data = csb.panels.data.as_slice();
        prop_assert!(data.as_ptr() as usize % 32 == 0, "panel arena not 32-byte aligned");
        let mut iv: Vec<(usize, usize)> = Vec::new();
        for (t, b) in csb.blocks.iter().enumerate() {
            let (rn, cn) = (b.rows.len(), b.cols.len());
            match b.kind {
                BlockKind::Dense { off } => {
                    let po = csb.panels.off[t];
                    prop_assert!(po != NO_PANEL, "dense block without panel");
                    prop_assert!(po as usize % 8 == 0, "panel offset breaks 32-byte alignment");
                    let lo = po as usize;
                    let hi = lo + panel_len(rn, cn);
                    prop_assert!(hi <= data.len(), "panel arena overflow");
                    iv.push((lo, hi));
                    // every value lands at its tile-major position, bit-equal
                    let p = &data[lo..hi];
                    for r in 0..rn {
                        for c in 0..cn {
                            let got =
                                p[(r / PANEL_MR) * cn * PANEL_MR + c * PANEL_MR + (r % PANEL_MR)];
                            let want = csb.dense[off as usize + r * cn + c];
                            prop_assert!(
                                got.to_bits() == want.to_bits(),
                                "panel mismatch at block {t} ({r},{c})"
                            );
                        }
                    }
                }
                BlockKind::Sparse { .. } => {
                    prop_assert!(csb.panels.off[t] == NO_PANEL, "sparse block with panel");
                }
            }
        }
        iv.sort_unstable();
        for w in iv.windows(2) {
            prop_assert!(w[0].1 <= w[1].0, "overlapping panel intervals {w:?}");
        }
        Ok(())
    });
}

#[test]
fn dispatched_spmm_tracks_scalar_within_tolerance() {
    use nni::csb::kernel::KernelKind;
    check("dispatch-parity", |rng, size| {
        let (b, csb) = random_csb(rng, size);
        let (d, _) = KernelKind::Simd.resolve();
        let k = 1 + rng.below(9);
        let x: Vec<f32> = (0..b.cols * k).map(|_| rng.f32() - 0.5).collect();
        let mut y_ref = vec![0.0f32; b.rows * k];
        nni::spmv::multilevel::spmm_ml_seq(&csb, &x, &mut y_ref, k);
        let mut y = vec![0.0f32; b.rows * k];
        nni::spmv::multilevel::spmm_ml_seq_with(&csb, &x, &mut y, k, d);
        for (g, w) in y.iter().zip(&y_ref) {
            prop_assert!((g - w).abs() < 1e-5 * (1.0 + w.abs()), "k={k}: {g} vs {w}");
        }
        Ok(())
    });
}

#[test]
fn flat_and_multilevel_schedules_visit_same_blocks() {
    check("schedule-cover", |rng, size| {
        let (_, csb) = random_csb(rng, size);
        // flat_order is a permutation of the stored (multi-level) order …
        let flat = csb.flat_order();
        let mut sorted = flat.clone();
        sorted.sort_unstable();
        prop_assert!(
            sorted.iter().enumerate().all(|(i, &t)| i == t as usize),
            "flat order is not a permutation of the block set"
        );
        // … sorted row-major by (tleaf, sleaf) …
        let keys: Vec<(u32, u32)> = flat
            .iter()
            .map(|&t| (csb.blocks[t as usize].tleaf, csb.blocks[t as usize].sleaf))
            .collect();
        prop_assert!(keys.windows(2).all(|w| w[0] < w[1]), "flat order not row-major");
        // … and the stored traversal holds each (tleaf, sleaf) pair once.
        let mut stored: Vec<(u32, u32)> =
            csb.blocks.iter().map(|b| (b.tleaf, b.sleaf)).collect();
        stored.sort_unstable();
        prop_assert!(
            stored.windows(2).all(|w| w[0] != w[1]),
            "duplicate block key in the multi-level schedule"
        );
        // keys (flat order) is strictly increasing, stored is sorted:
        // equality ⇔ both schedules visit exactly the same block set.
        prop_assert!(
            stored == keys,
            "flat and multi-level schedules visit different block sets"
        );
        Ok(())
    });
}

#[test]
fn spmm_columns_bitexact_with_spmv() {
    check("spmm-bitexact", |rng, size| {
        let (b, csb) = random_csb(rng, size);
        let k = 1 + rng.below(5);
        let x: Vec<f32> = (0..b.cols * k).map(|_| rng.f32() - 0.5).collect();
        let mut y = vec![0.0f32; b.rows * k];
        nni::spmv::multilevel::spmm_ml_seq(&csb, &x, &mut y, k);
        for j in 0..k {
            let xj: Vec<f32> = (0..b.cols).map(|i| x[i * k + j]).collect();
            let mut yj = vec![0.0f32; b.rows];
            nni::spmv::multilevel::spmv_ml_seq(&csb, &xj, &mut yj);
            for i in 0..b.rows {
                prop_assert!(
                    y[i * k + j].to_bits() == yj[i].to_bits(),
                    "spmm col {j} differs from spmv at row {i}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn parallel_spmm_deterministic_across_threads_and_runs() {
    // The target-leaf-ownership guarantee at the multi-RHS level: par is
    // bit-exact equal to seq for thread counts {1, 2, 8}, and repeated
    // runs are stable.
    check("spmm-par-deterministic", |rng, size| {
        let (b, csb) = random_csb(rng, size);
        let k = 1 + rng.below(4);
        let x: Vec<f32> = (0..b.cols * k).map(|_| rng.f32()).collect();
        let mut y_seq = vec![0.0f32; b.rows * k];
        nni::spmv::multilevel::spmm_ml_seq(&csb, &x, &mut y_seq, k);
        let mut y_par = vec![0.0f32; b.rows * k];
        for threads in [1usize, 2, 8] {
            for _rep in 0..2 {
                nni::spmv::multilevel::spmm_ml_par(&csb, &x, &mut y_par, k, threads);
                prop_assert!(y_par == y_seq, "spmm k={k} threads={threads} nondeterminism");
            }
        }
        // and the k=1 matvec path across the same thread set
        let x1: Vec<f32> = (0..b.cols).map(|_| rng.f32()).collect();
        let mut y1_seq = vec![0.0f32; b.rows];
        nni::spmv::multilevel::spmv_ml_seq(&csb, &x1, &mut y1_seq);
        let mut y1_par = vec![0.0f32; b.rows];
        for threads in [1usize, 2, 8] {
            for _rep in 0..2 {
                nni::spmv::multilevel::spmv_ml_par(&csb, &x1, &mut y1_par, threads);
                prop_assert!(y1_par == y1_seq, "spmv threads={threads} nondeterminism");
            }
        }
        Ok(())
    });
}

/// Bit-level tree equality: node layout (levels, spans, topology, box
/// geometry), permutation, inverse, and leaf map.
fn trees_bit_identical(a: &BoxTree, b: &BoxTree) -> bool {
    a.d == b.d
        && a.perm == b.perm
        && a.pos == b.pos
        && a.leaf_at == b.leaf_at
        && a.nodes.len() == b.nodes.len()
        && a.nodes.iter().zip(&b.nodes).all(|(x, y)| {
            x.level == y.level
                && x.lo == y.lo
                && x.hi == y.hi
                && x.children == y.children
                && x.parent == y.parent
                && x.half.to_bits() == y.half.to_bits()
                && x.center.len() == y.center.len()
                && x.center.iter().zip(&y.center).all(|(p, q)| p.to_bits() == q.to_bits())
        })
}

#[test]
fn parallel_tree_build_bitidentical_across_threads() {
    // The build-side determinism contract: the task-parallel construction
    // must reproduce the sequential build exactly — node layout, perm, and
    // leaf map — for every worker count (NNI_THREADS equivalents 1/2/8 are
    // exercised through the explicit-thread entry point the env knob feeds).
    check("tree-par-deterministic", |rng, size| {
        let n = 1 + rng.below(size * 4);
        let d = 1 + rng.below(3);
        let ds = random_points(rng, n, d);
        let cap = 1 + rng.below(24);
        let seq = BoxTree::build(&ds, cap, 20);
        for threads in [1usize, 2, 8] {
            let par = BoxTree::build_par(&ds, cap, 20, threads);
            prop_assert!(
                trees_bit_identical(&seq, &par),
                "tree differs at threads={threads} (n={n} d={d} cap={cap})"
            );
        }
        Ok(())
    });
}

#[test]
fn parallel_hiercsb_build_bitidentical_across_threads() {
    // Full-arena determinism of the count→scan→fill assembly: block
    // metadata, schedules, and all four value arenas bit-equal to the
    // sequential build at every worker count.
    check("csb-par-deterministic", |rng, size| {
        let n = 8 + rng.below(size);
        let d = 1 + rng.below(3);
        let ds = random_points(rng, n, d);
        let pr = 1 + rng.below(6);
        let a = random_csr(rng, n, pr);
        let tree = BoxTree::build(&ds, 1 + rng.below(40), 20);
        let pos = invert(&tree.perm);
        let b = a.permuted(&pos, &pos);
        let thr = rng.f64() * 1.2;
        let seq = HierCsb::build_with(&b, &tree, &tree, 0, thr);
        for threads in [1usize, 2, 8] {
            let par = HierCsb::build_with_par(&b, &tree, &tree, 0, thr, threads);
            prop_assert!(seq.tgt_leaves == par.tgt_leaves && seq.src_leaves == par.src_leaves);
            prop_assert!(seq.blocks == par.blocks, "block layout differs at threads={threads}");
            prop_assert!(seq.by_target == par.by_target);
            prop_assert!(seq.sp_rows == par.sp_rows && seq.sp_ptr == par.sp_ptr);
            prop_assert!(seq.sp_col == par.sp_col);
            prop_assert!(
                seq.dense.len() == par.dense.len()
                    && seq
                        .dense
                        .iter()
                        .zip(&par.dense)
                        .all(|(x, y)| x.to_bits() == y.to_bits()),
                "dense arena differs at threads={threads}"
            );
            prop_assert!(
                seq.sp_val.len() == par.sp_val.len()
                    && seq
                        .sp_val
                        .iter()
                        .zip(&par.sp_val)
                        .all(|(x, y)| x.to_bits() == y.to_bits()),
                "sp_val arena differs at threads={threads}"
            );
            prop_assert!(
                seq.panels.off == par.panels.off,
                "panel offsets differ at threads={threads}"
            );
            let sp = seq.panels.data.as_slice();
            let pp = par.panels.data.as_slice();
            prop_assert!(sp.len() == pp.len(), "panel arena length at threads={threads}");
            prop_assert!(
                sp.iter().zip(pp).all(|(x, y)| x.to_bits() == y.to_bits()),
                "panel arena differs at threads={threads}"
            );
        }
        Ok(())
    });
}

#[test]
fn parallel_pca_bitidentical_across_threads() {
    // Fixed-chunk Gram accumulation: axes and eigenvalues must not depend
    // on the worker count.
    check("pca-par-deterministic", |rng, size| {
        // sizes past PCA_CHUNK so the fixed-chunk reduction actually spans
        // several partials
        let n = 8 + rng.below(size * 3);
        let dim = 4 + rng.below(12);
        let ds = random_points(rng, n, dim);
        let d = 1 + rng.below(3);
        let seq = nni::embed::pca::pca_par(&ds, d, 6, 11, 1);
        for threads in [2usize, 8] {
            let par = nni::embed::pca::pca_par(&ds, d, 6, 11, threads);
            prop_assert!(
                seq.total_variance.to_bits() == par.total_variance.to_bits(),
                "variance differs at threads={threads}"
            );
            prop_assert!(
                seq.axes.len() == par.axes.len()
                    && seq
                        .axes
                        .iter()
                        .zip(&par.axes)
                        .all(|(x, y)| x.to_bits() == y.to_bits()),
                "axes differ at threads={threads}"
            );
            prop_assert!(seq
                .eigenvalues
                .iter()
                .zip(&par.eigenvalues)
                .all(|(x, y)| x.to_bits() == y.to_bits()));
        }
        Ok(())
    });
}

#[test]
fn gamma_fast_tracks_exact_on_random_profiles() {
    check("gamma-fast", |rng, size| {
        let n = 8 + rng.below(size / 2 + 8);
        let pr = 1 + rng.below(4);
        let a = random_csr(rng, n, pr);
        let sigma = 2.0 + rng.f64() * 6.0;
        let exact = nni::profile::gamma::gamma_exact(&a, sigma);
        let fast = nni::profile::gamma::gamma_fast(&a, sigma);
        prop_assert!(
            (exact - fast).abs() <= 0.08 * exact.max(1e-12),
            "sigma {sigma}: exact {exact} vs fast {fast}"
        );
        Ok(())
    });
}

#[test]
fn vector_layout_roundtrips() {
    check("layout-roundtrip", |rng, size| {
        let n = 1 + rng.below(size);
        let d = 1 + rng.below(4);
        let perm = rng.permutation(n);
        let x: Vec<f32> = (0..n * d).map(|_| rng.f32()).collect();
        let xt = nni::csb::layout::rows_to_tree_order(&x, d, &perm);
        let back = nni::csb::layout::rows_from_tree_order(&xt, d, &perm);
        prop_assert!(back == x);
        Ok(())
    });
}

#[test]
fn coordinator_plan_partitions_blocks() {
    use nni::coordinator::batcher::{BatchPlan, BatchPolicy};
    check("plan-partition", |rng, size| {
        let n = 32 + rng.below(size * 2);
        let ds = random_points(rng, n, 2);
        let pr = 2 + rng.below(6);
        let a = random_csr(rng, n, pr);
        let tree = BoxTree::build(&ds, 16 + rng.below(100), 20);
        let pos = invert(&tree.perm);
        let b = a.permuted(&pos, &pos);
        let csb = HierCsb::build(&b, &tree, &tree, 0);
        let policy = BatchPolicy {
            min_nnz: rng.below(64) as u32,
            pjrt_enabled: rng.f32() < 0.8,
            ..Default::default()
        };
        let plan = BatchPlan::build(&csb, &policy);
        prop_assert!(plan.total_blocks() == csb.blocks.len());
        let mut seen = vec![false; csb.blocks.len()];
        let mut mark = |t: u32| -> Result<(), String> {
            if seen[t as usize] {
                return Err(format!("block {t} routed twice"));
            }
            seen[t as usize] = true;
            Ok(())
        };
        for &t in &plan.rust {
            mark(t)?;
        }
        for &t in &plan.pjrt_single {
            mark(t)?;
        }
        for g in &plan.pjrt_batches {
            for &t in g {
                mark(t)?;
            }
        }
        prop_assert!(seen.iter().all(|&s| s));
        Ok(())
    });
}

// ---- hmat: admissibility partition + ACA compression -------------------

/// Skewed synthetic clusters: a few blobs with random per-axis anisotropy
/// and offsets (the hmat properties must hold on ugly geometry, not just
/// isotropic blobs).
fn skewed_clusters(rng: &mut Rng, n: usize, d: usize) -> Dataset {
    let k = 1 + rng.below(4);
    let centers: Vec<Vec<f32>> = (0..k)
        .map(|_| (0..d).map(|_| 8.0 * (rng.f32() - 0.5)).collect())
        .collect();
    let scales: Vec<Vec<f32>> = (0..k)
        .map(|_| (0..d).map(|_| 0.02 + 1.2 * rng.f32()).collect())
        .collect();
    let mut xs = Vec::with_capacity(n * d);
    for _ in 0..n {
        let c = rng.below(k);
        for a in 0..d {
            xs.push(centers[c][a] + scales[c][a] * rng.normal() as f32);
        }
    }
    Dataset::new(n, d, xs)
}

#[test]
fn hmat_partition_tiles_index_space_exactly() {
    // Acceptance property (a): admissible far blocks + near pairs cover
    // every (i, j) exactly once, whatever the geometry, cut, or eta.
    check("hmat-tiling", |rng, size| {
        let n = 2 + rng.below(size.min(120));
        let d = 1 + rng.below(3);
        let ds = skewed_clusters(rng, n, d);
        let tree = BoxTree::build(&ds, 1 + rng.below(8), 24);
        let cap = 1 + rng.below(32);
        let eta = 0.3 + 2.0 * rng.f32();
        let part = nni::hmat::admissible::partition(&tree, cap, eta);
        prop_assert!(part.n == n);
        let mut cover = vec![0u32; n * n];
        for &(tl, sl) in &part.near {
            let (r, c) = (part.leaves[tl as usize], part.leaves[sl as usize]);
            for i in r.lo..r.hi {
                for j in c.lo..c.hi {
                    cover[i as usize * n + j as usize] += 1;
                }
            }
        }
        for fb in &part.far {
            prop_assert!(
                fb.rows == part.leaves[fb.tleaf as usize],
                "far block rows must equal its target leaf span"
            );
            for i in fb.rows.lo..fb.rows.hi {
                for j in fb.cols.lo..fb.cols.hi {
                    cover[i as usize * n + j as usize] += 1;
                }
            }
        }
        prop_assert!(
            cover.iter().all(|&c| c == 1),
            "partition gap/overlap: {} cells != 1 (n={n} cap={cap} eta={eta})",
            cover.iter().filter(|&&c| c != 1).count()
        );
        prop_assert!(part.near_area() + part.far_area() == (n as u64) * (n as u64));
        Ok(())
    });
}

#[test]
fn hmat_aca_reconstruction_error_within_tol() {
    // Acceptance property (b): each factorization — low-rank or dense
    // fallback — reconstructs its block to <= tol relative Frobenius
    // error against an f64 dense oracle, on skewed cluster pairs of any
    // separation (the absolute slack covers blocks whose every entry
    // underflows f32).
    use nni::csb::hier::Span;
    use nni::hmat::aca::{aca_gauss, AcaFactor, GaussGen};
    check("hmat-aca", |rng, size| {
        let rn = 1 + rng.below(size.min(48));
        let cn = 1 + rng.below(size.min(48));
        let d = 1 + rng.below(4);
        let gap = 4.0 * rng.f32(); // 0 (overlapping) .. 4 (well separated)
        let mut coords = Vec::with_capacity((rn + cn) * d);
        let scales: Vec<f32> = (0..d).map(|_| 0.02 + 0.6 * rng.f32()).collect();
        for i in 0..rn + cn {
            for (a, &sc) in scales.iter().enumerate() {
                let mut v = sc * rng.normal() as f32;
                if i >= rn && a == 0 {
                    v += gap;
                }
                coords.push(v);
            }
        }
        let gen = GaussGen {
            coords: &coords,
            d,
            inv_h2: 0.1 + 4.0 * rng.f32(),
        };
        let rows = Span { lo: 0, hi: rn as u32 };
        let cols = Span {
            lo: rn as u32,
            hi: (rn + cn) as u32,
        };
        let tol = [1e-2f32, 1e-3, 1e-4][rng.below(3)];
        let f = aca_gauss(&gen, rows, cols, tol);
        if let AcaFactor::LowRank { rank, u, vt } = &f {
            prop_assert!(*rank <= rn.min(cn) / 2 || *rank == 0, "rank cap violated: {rank}");
            prop_assert!(u.len() == rn * rank && vt.len() == rank * cn);
        }
        let mut err2 = 0.0f64;
        let mut norm2 = 0.0f64;
        for i in 0..rn {
            for j in 0..cn {
                let exact = gen.entry_f64(i, rn + j);
                let approx = match &f {
                    AcaFactor::LowRank { u, vt, rank } => (0..*rank)
                        .map(|k| u[i * rank + k] as f64 * vt[k * cn + j] as f64)
                        .sum::<f64>(),
                    AcaFactor::Dense(v) => v[i * cn + j] as f64,
                };
                err2 += (exact - approx) * (exact - approx);
                norm2 += exact * exact;
            }
        }
        let (err, norm) = (err2.sqrt(), norm2.sqrt());
        prop_assert!(
            err <= tol as f64 * norm + 1e-25,
            "aca err {err:.3e} > tol {tol:.0e} * norm {norm:.3e} (rn={rn} cn={cn} gap={gap})"
        );
        Ok(())
    });
}
