//! Integration: the coordinator's hybrid (Rust workers + PJRT leader) t-SNE
//! attractive force must equal the pure-Rust path to float tolerance, and
//! the routing metrics must show that PJRT actually executed blocks.

use nni::coordinator::batcher::BatchPolicy;
use nni::coordinator::Coordinator;
use nni::csb::hier::HierCsb;
use nni::data::synth::SynthSpec;
use nni::interact::engine::Engine;
use nni::knn::exact::knn_graph;
use nni::order::Pipeline;
use nni::runtime::ArtifactRegistry;
use nni::sparse::csr::Csr;
use nni::util::rng::Rng;

fn setup(n: usize, d: usize, leaf: usize) -> Engine {
    let ds = SynthSpec::blobs(n, d, 4, 99).generate();
    let g = knn_graph(&ds, 12, 4);
    let a = Csr::from_knn(&g, n).symmetrized();
    let r = Pipeline::dual_tree(d).run(&ds, &a);
    let tree = r.tree.as_ref().unwrap();
    // PJRT-path dense threshold (artifacts eat zero-padding for free)
    let csb = HierCsb::build_with(&r.reordered, tree, tree, leaf, 0.1);
    Engine::new(csb, 4)
}

#[test]
fn hybrid_equals_rust_only() {
    if ArtifactRegistry::open_default().is_err() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    for d in [2usize, 3] {
        // leaf cap 200 (< 256 tile) with dense clusters → dense blocks
        let engine = setup(900, d, 200);
        let engine2 = Engine::new(engine.csb.clone(), 4);
        let policy = BatchPolicy {
            min_nnz: 64,
            ..Default::default()
        };
        let reg_d = ArtifactRegistry::open_default().unwrap();
        let mut hybrid = Coordinator::new(engine, Some(reg_d), policy);
        let mut rust_only = Coordinator::rust_only(engine2);
        assert!(
            hybrid.plan().pjrt_block_count() > 0,
            "d={d}: no blocks routed to PJRT ({})",
            hybrid.csb().describe()
        );

        let n = hybrid.csb().rows;
        let mut rng = Rng::new(3);
        let y: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let mut f_hybrid = vec![0.0f32; n * d];
        let mut f_rust = vec![0.0f32; n * d];
        hybrid.tsne_attr(&y, d, &mut f_hybrid);
        rust_only.tsne_attr(&y, d, &mut f_rust);

        let mut max_err = 0.0f32;
        for (a, b) in f_hybrid.iter().zip(&f_rust) {
            max_err = max_err.max((a - b).abs() / (1.0 + b.abs()));
        }
        assert!(max_err < 5e-4, "d={d}: hybrid vs rust max rel err {max_err}");
        assert!(
            hybrid.metrics.pjrt_blocks > 0,
            "d={d}: metrics show no PJRT blocks: {}",
            hybrid.metrics.summary()
        );
    }
}

#[test]
fn batched_route_is_exercised() {
    let Ok(reg) = ArtifactRegistry::open_default() else {
        return;
    };
    // small leaves (<=128) force the batched route
    let engine = setup(1200, 2, 100);
    let policy = BatchPolicy {
        min_nnz: 32,
        ..Default::default()
    };
    let mut co = Coordinator::new(engine, Some(reg), policy);
    if co.plan().pjrt_batches.is_empty() {
        eprintln!(
            "no batched groups formed on this structure; plan: rust={} single={}",
            co.plan().rust.len(),
            co.plan().pjrt_single.len()
        );
        return;
    }
    let n = co.csb().rows;
    let y: Vec<f32> = (0..n * 2).map(|i| (i as f32 * 0.01).sin()).collect();
    let mut f = vec![0.0f32; n * 2];
    co.tsne_attr(&y, 2, &mut f);
    assert!(co.metrics.pjrt_batched_calls > 0, "{}", co.metrics.summary());
}
