//! Fault-injection drills for the serving tier (DESIGN: `nni serve`).
//!
//! Every scenario replays a fixed seeded request stream against a fresh
//! daemon at shard widths {1, 2, 8} and checks the three-part serving
//! contract:
//!
//! 1. **no request is lost or hung** — every submitted request gets
//!    exactly one response (or a synchronous typed admission rejection)
//!    within the wait bound;
//! 2. **non-shed responses are bit-identical** to the fault-free run on
//!    the same epoch, at every shard width — faults may shed or degrade,
//!    never silently corrupt;
//! 3. **the shed/retried/contained counters match the fault plan
//!    exactly** — containment is accounted, not approximate.
//!
//! Scenarios: fault-free baseline, contained worker panics (retry
//! ladder), repeated panics (shard poisoning + scalar-fallback
//! degradation), artificial shard latency against deadlines (typed
//! deadline sheds + virtual-time accounting), malformed/oversized client
//! queries, and a mid-stream epoch update (snapshot isolation + heal).
//!
//! Determinism: scalar kernel, single-threaded build, virtual time, and
//! serial submit-then-wait clients — so the dispatcher's slate sequence
//! numbers equal request indices and worker faults keyed on `(shard,
//! seq)` fire identically at every width.  Worker-side faults are only
//! scripted on apply slates (which fan out to *every* shard) so the
//! plans stay width-independent.

use nni::csb::kernel::KernelKind;
use nni::data::synth::SynthSpec;
use nni::hmat::FullKernelConfig;
use nni::interact::epoch::{UpdatableKernelEngine, UpdateCfg};
use nni::obs::{flight, hist};
use nni::serve::server::StatsSnapshot;
use nni::serve::wire::{Payload, Query, RejectReason, Response};
use nni::serve::{FaultPlan, ServeConfig, Server};
use nni::tree::update::UpdateBatch;
use nni::util::json::{self, Json};
use nni::util::rng::Rng;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

const WIDTHS: [usize; 3] = [1, 2, 8];
const N: usize = 300;
const REQUESTS: usize = 9;
/// Generous wall-clock bound per request: expiry means a hung request,
/// which is precisely the bug this harness exists to catch.
const WAIT: Duration = Duration::from_secs(30);

/// The flight recorder and stage histograms are process-global, and
/// [`drive`] resets both so each run's forensics are exact — so every
/// test in this file holds this gate for its whole body.  Poison-
/// tolerant: a failed sibling must not cascade.
fn forensics_guard() -> std::sync::MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Count events per kind name in a parsed flight dump.
fn dump_kind_counts(dump: &Json) -> BTreeMap<String, u64> {
    let mut counts = BTreeMap::new();
    let events = dump.get("events").and_then(Json::as_arr).expect("dump has an events array");
    for ev in events {
        let kind = ev.get("kind").and_then(Json::as_str).expect("event has a kind");
        *counts.entry(kind.to_string()).or_insert(0) += 1;
    }
    counts
}

/// Flight timestamps must be monotone *per shard track*: each shard's
/// events are causally ordered (worker thread, or the dispatcher acting
/// on that shard), as are the dispatcher/admission events on track -1.
/// Cross-shard interleaving carries no order guarantee.
fn assert_shard_times_monotone(evs: &[flight::Event], width: usize) {
    let mut last: BTreeMap<i64, u64> = BTreeMap::new();
    for e in evs {
        let prev = last.insert(e.shard, e.t_us).unwrap_or(0);
        assert!(
            prev <= e.t_us,
            "width {width}: shard {} flight timestamps regressed ({prev} -> {})",
            e.shard,
            e.t_us
        );
    }
}

/// Fresh deterministic engine — rebuilt per drive so mid-stream epoch
/// updates in one run can never leak into the next.
fn engine() -> Arc<UpdatableKernelEngine> {
    let ds = SynthSpec::blobs(N, 3, 4, 19).generate();
    let cfg = UpdateCfg {
        leaf_cap: 8,
        block_cap: 32,
        build_threads: 1,
        threads: 1,
        kernel: KernelKind::Scalar,
        ..UpdateCfg::default()
    };
    Arc::new(UpdatableKernelEngine::build(ds, cfg, FullKernelConfig::new(0.8)))
}

fn config(shards: usize) -> ServeConfig {
    ServeConfig { shards, real_time: false, ..ServeConfig::default() }
}

/// The fixed request stream: i%3==0 Gauss, i%3==1 KRR, i%3==2 kNN, all
/// seeded per index so every drive submits byte-identical queries.
fn stream(n: usize) -> Vec<Query> {
    let mut rng = Rng::new(0xfa17);
    (0..REQUESTS)
        .map(|i| match i % 3 {
            0 => Query::Gauss { charges: (0..n).map(|_| rng.f32() - 0.5).collect() },
            1 => Query::Krr { alpha: (0..n).map(|_| rng.f32() - 0.5).collect() },
            _ => Query::Knn { point: rng.below(n) as u32, k: 5 },
        })
        .collect()
}

struct Outcome {
    /// One slot per request: the response, or the synchronous admission
    /// rejection.  `panic!` on a lost/hung request — contract part 1.
    responses: Vec<Result<Response, RejectReason>>,
    stats: StatsSnapshot,
}

/// Serial submit-then-wait drive: slate seq == request index, so the
/// plan's `(shard, seq)` worker faults address the same task at every
/// width.  Client-side faults (malformed/oversized/update) are executed
/// here, at their scripted request indices.
fn drive(shards: usize, plan: &FaultPlan, cfg: ServeConfig) -> Outcome {
    // Start each run with a clean forensic slate: the flight ring and
    // the stage histograms then cover exactly this drive, so the
    // per-scenario event-count assertions can be exact.  Callers hold
    // `forensics_guard`, so concurrent tests can't clobber each other.
    flight::reset();
    hist::reset();
    let upd = engine();
    let queries = stream(upd.acquire().value.engine.n());
    let server = Server::start(upd, ServeConfig { shards, ..cfg }, plan.clone());
    let mut responses = Vec::with_capacity(queries.len());
    for (i, q) in queries.iter().enumerate() {
        let mut q = q.clone();
        for f in plan.client_faults_at(i) {
            use nni::serve::faults::Fault;
            let (n, _) = server.shape();
            match f {
                Fault::MalformedQuery { .. } => q = Query::Gauss { charges: vec![0.0; n + 1] },
                Fault::OversizedQuery { .. } => {
                    q = Query::Gauss { charges: vec![0.0; n * server.config().oversize_factor + 1] }
                }
                _ => {}
            }
        }
        let out = match server.submit(q) {
            Err(reason) => Err(reason),
            Ok(pending) => match pending.wait_timeout(WAIT) {
                Ok(resp) => Ok(resp),
                Err(_) => panic!("request {i} lost/hung at shards={shards} — contract broken"),
            },
        };
        responses.push(out);
        for f in plan.client_faults_at(i) {
            use nni::serve::faults::Fault;
            if let Fault::EpochUpdate { n_del, n_ins, .. } = f {
                let (n, d) = server.shape();
                let mut rng = Rng::new(plan.seed ^ i as u64);
                let deletes: Vec<usize> = (0..(*n_del).min(n / 4)).collect();
                let inserts: Vec<f32> =
                    (0..n_ins * d).map(|_| rng.f32() * 2.0 - 1.0).collect();
                server.update(&UpdateBatch { deletes, inserts });
            }
        }
    }
    let stats = server.shutdown();
    Outcome { responses, stats }
}

/// Bit-exact equality of two answered payloads.
fn payload_bits_eq(a: &Payload, b: &Payload) -> bool {
    match (a, b) {
        (Payload::Potentials(x), Payload::Potentials(y)) => {
            x.len() == y.len() && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
        }
        (Payload::Knn(x), Payload::Knn(y)) => {
            x.len() == y.len()
                && x.iter().zip(y).all(|(p, q)| p.0 == q.0 && p.1.to_bits() == q.1.to_bits())
        }
        _ => false,
    }
}

/// Contract part 2: every non-shed response of `got` must be bit-identical
/// to the baseline's answer for the same request on the same epoch.
fn assert_bit_identical(got: &Outcome, baseline: &Outcome, label: &str) {
    for (i, (g, b)) in got.responses.iter().zip(&baseline.responses).enumerate() {
        let (Ok(g), Ok(b)) = (g, b) else { continue };
        let (Ok(gp), Ok(bp)) = (&g.result, &b.result) else { continue };
        if g.epoch != b.epoch {
            continue; // different epochs answer different operators
        }
        assert!(
            payload_bits_eq(gp, bp),
            "{label}: request {i} diverged from the fault-free baseline"
        );
    }
}

#[test]
fn fault_free_baseline_is_width_invariant() {
    let _forensics = forensics_guard();
    let plan = FaultPlan::new(7);
    let base = drive(1, &plan, config(1));
    assert_eq!(base.stats.admitted, REQUESTS as u64);
    assert_eq!(base.stats.responded_ok, REQUESTS as u64);
    assert_eq!(base.stats.shed_total(), 0);
    assert_eq!(base.stats.retried, 0);
    assert_eq!(base.stats.panics_contained, 0);
    for w in WIDTHS {
        let got = drive(w, &plan, config(w));
        assert_eq!(got.stats.responded_ok, REQUESTS as u64, "width {w}");
        assert_bit_identical(&got, &base, &format!("baseline width {w}"));
        for (i, r) in got.responses.iter().enumerate() {
            let r = r.as_ref().expect("admitted");
            assert!(r.result.is_ok(), "request {i} shed on a fault-free run");
            assert!(!r.degraded);
            assert_eq!(r.retries, 0);
        }
        // Forensics of a clean run: one admit and one single-job slate
        // per request (serial clients), no shed events, no auto-dump.
        let evs = flight::snapshot();
        let count = |k: flight::Kind| evs.iter().filter(|e| e.kind == k).count();
        assert_eq!(count(flight::Kind::Admit), REQUESTS, "width {w}: one admit per request");
        assert_eq!(count(flight::Kind::Slate), REQUESTS, "width {w}: serial one-job slates");
        assert!(
            evs.iter().filter(|e| e.kind == flight::Kind::Slate).all(|e| e.aux == 1),
            "width {w}: slate size recorded in aux"
        );
        assert_eq!(count(flight::Kind::Shed), 0, "width {w}");
        assert!(flight::last_dump().is_none(), "width {w}: clean runs never dump");
        assert_shard_times_monotone(&evs, w);
        // Every answered request lands in the end-to-end histogram.
        let e2e = hist::stage_snapshot(hist::Stage::EndToEnd);
        assert_eq!(e2e.count, REQUESTS as u64, "width {w}: every answer histogrammed");
        assert!(e2e.quantile(50.0) <= e2e.quantile(99.0), "width {w}");
        assert!(e2e.quantile(99.0) <= e2e.max, "width {w}");
    }
}

#[test]
fn contained_panics_are_retried_and_invisible() {
    let _forensics = forensics_guard();
    // Requests 0 and 3 are Gauss applies: the slate fans to every shard,
    // so shard 0's scripted panics fire at every width.
    let plan = FaultPlan::parse(7, "panic:0:0, panic:0:3").expect("spec");
    let base = drive(1, &FaultPlan::new(7), config(1));
    for w in WIDTHS {
        let got = drive(w, &plan, config(w));
        assert_eq!(got.stats.panics_contained, 2, "width {w}: exactly the scripted panics");
        assert_eq!(got.stats.retried, 2, "width {w}: one retry per contained panic");
        assert_eq!(got.stats.shed_total(), 0, "width {w}: retries succeed, nothing shed");
        assert_eq!(got.stats.responded_ok, REQUESTS as u64, "width {w}");
        assert_bit_identical(&got, &base, &format!("panic width {w}"));
        // The two panicked requests report their retry; the rest don't.
        for (i, r) in got.responses.iter().enumerate() {
            let r = r.as_ref().expect("admitted");
            let want = u32::from(i == 0 || i == 3);
            assert_eq!(r.retries, want, "width {w} request {i}");
        }
        // The flight ring accounts for both injections and containments,
        // and the second containment's auto-dump is the one that's kept.
        let evs = flight::snapshot();
        let count = |k: flight::Kind| evs.iter().filter(|e| e.kind == k).count();
        assert_eq!(count(flight::Kind::Fault), 2, "width {w}: one fault event per injection");
        assert_eq!(count(flight::Kind::Panic), 2, "width {w}: one panic event per containment");
        assert_eq!(count(flight::Kind::Poison), 0, "width {w}: contained, never poisoned");
        let dump = flight::last_dump().expect("panic containment auto-dumps");
        assert!(dump.contains("\"trigger\": \"panic\""), "width {w}");
        let parsed = json::parse(&dump).expect("dump is valid JSON");
        let kinds = dump_kind_counts(&parsed);
        assert_eq!(kinds.get("panic").copied().unwrap_or(0), 2, "width {w}: both in the dump");
    }
}

#[test]
fn repeated_panics_poison_the_shard_into_scalar_fallback() {
    let _forensics = forensics_guard();
    let plan = FaultPlan::parse(7, "panic:0:0, panic:0:3").expect("spec");
    let mut cfg = config(1);
    cfg.poison_after = 2; // second contained panic poisons shard 0
    for w in WIDTHS {
        let base = drive(w, &FaultPlan::new(7), config(w));
        let got = drive(w, &plan, ServeConfig { shards: w, ..cfg });
        assert_eq!(got.stats.panics_contained, 2, "width {w}");
        assert_eq!(got.stats.shed_total(), 0, "width {w}: degraded, not shed");
        assert_eq!(got.stats.responded_ok, REQUESTS as u64, "width {w}");
        // Poisoning forces the scalar fallback — with a scalar-dispatch
        // engine the answers stay bit-identical, only the flag changes.
        assert_bit_identical(&got, &base, &format!("poison width {w}"));
        // Request 3's rescue attempt and every later apply touching
        // shard 0 runs the fallback: apply slates fan to all shards, so
        // requests 3, 4, 6, 7 (the applies from the poisoning on) must
        // be flagged degraded.
        for i in [3usize, 4, 6, 7] {
            let r = got.responses[i].as_ref().expect("admitted");
            assert!(r.degraded, "width {w} request {i}: poisoned shard must flag degraded");
        }
        assert!(got.stats.degraded_responses >= 4, "width {w}");
        // The poison dump supersedes the first containment's panic dump,
        // and pins the poisoned shard and its containment count.
        let dump = flight::last_dump().expect("poisoning auto-dumps");
        assert!(dump.contains("\"trigger\": \"poison\""), "width {w}: poison dump kept last");
        let kinds = dump_kind_counts(&json::parse(&dump).expect("dump is valid JSON"));
        assert_eq!(kinds.get("panic").copied().unwrap_or(0), 2, "width {w}");
        assert_eq!(kinds.get("poison").copied().unwrap_or(0), 1, "width {w}: one poisoning");
        let poison = flight::snapshot()
            .into_iter()
            .find(|e| e.kind == flight::Kind::Poison)
            .expect("poison event recorded");
        assert_eq!(poison.shard, 0, "width {w}: shard 0 was the poisoned one");
        assert_eq!(poison.aux, 2, "width {w}: poisoned at the second containment");
    }
}

#[test]
fn slow_shard_sheds_on_deadline_with_typed_reason() {
    // Slate 1 (a KRR apply): 60ms of injected latency against the 50ms
    // default budget — the worker skips the compute and every request in
    // the slate sheds typed.  Slate 4 (also an apply): 1ms of latency,
    // under budget — answered, with the latency charged to elapsed_us.
    let _forensics = forensics_guard();
    let plan = FaultPlan::parse(7, "slow:0:60000:1:1, slow:0:1000:4:1").expect("spec");
    let base = drive(1, &FaultPlan::new(7), config(1));
    for w in WIDTHS {
        let got = drive(w, &plan, config(w));
        assert_eq!(got.stats.shed_deadline, 1, "width {w}: exactly the over-budget slate");
        assert_eq!(got.stats.shed_total(), 1, "width {w}");
        assert_eq!(got.stats.responded_ok, REQUESTS as u64 - 1, "width {w}");
        assert_eq!(got.stats.retried, 0, "width {w}");
        assert_eq!(got.stats.panics_contained, 0, "width {w}");
        assert_bit_identical(&got, &base, &format!("slow width {w}"));
        let shed = got.responses[1].as_ref().expect("admitted");
        match &shed.result {
            Err(RejectReason::DeadlineExceeded { budget_us, elapsed_us }) => {
                assert_eq!(*budget_us, 50_000);
                assert_eq!(*elapsed_us, 60_000, "virtual time charges the injected latency");
            }
            other => panic!("width {w}: expected a typed deadline shed, got {other:?}"),
        }
        let slowed = got.responses[4].as_ref().expect("admitted");
        assert!(slowed.result.is_ok());
        assert_eq!(slowed.elapsed_us, 1_000, "width {w}: under-budget latency is charged");
        // The deadline shed auto-dumped with a typed reason, and the
        // shed event carries the deadline reject-reason code.
        let dump = flight::last_dump().expect("deadline shed auto-dumps");
        assert!(dump.contains("\"trigger\": \"deadline_shed\""), "width {w}");
        assert!(dump.contains("\"reason\": \"deadline\""), "width {w}");
        let kinds = dump_kind_counts(&json::parse(&dump).expect("dump is valid JSON"));
        assert_eq!(kinds.get("shed").copied().unwrap_or(0), 1, "width {w}: one shed dumped");
        let shed_ev = flight::snapshot()
            .into_iter()
            .find(|e| e.kind == flight::Kind::Shed)
            .expect("shed event recorded");
        assert_eq!(flight::reason_name(shed_ev.aux), "deadline", "width {w}");
        assert_eq!(shed_ev.seq, 1, "width {w}: the shed request's id");
    }
}

#[test]
fn malformed_and_oversized_queries_shed_at_admission() {
    let _forensics = forensics_guard();
    let plan = FaultPlan::parse(7, "malformed:2, oversized:5").expect("spec");
    let base = drive(1, &FaultPlan::new(7), config(1));
    for w in WIDTHS {
        let got = drive(w, &plan, config(w));
        assert_eq!(got.stats.shed_malformed, 1, "width {w}");
        assert_eq!(got.stats.shed_oversized, 1, "width {w}");
        assert_eq!(got.stats.shed_total(), 2, "width {w}");
        assert_eq!(got.stats.responded_ok, REQUESTS as u64 - 2, "width {w}");
        assert_bit_identical(&got, &base, &format!("badquery width {w}"));
        assert!(matches!(got.responses[2], Err(RejectReason::Malformed(_))), "width {w}");
        assert!(matches!(got.responses[5], Err(RejectReason::Oversized { .. })), "width {w}");
        // Admission sheds are recorded but do not auto-dump (only
        // deadline sheds, panics, and poisonings do); the on-demand dump
        // — what the serve stdin `dump` command renders — shows both
        // sheds with their typed reasons, and neither request admitted.
        assert!(flight::last_dump().is_none(), "width {w}: admission sheds don't auto-dump");
        let dump = flight::dump_json("test");
        assert!(dump.contains("\"reason\": \"malformed\""), "width {w}");
        assert!(dump.contains("\"reason\": \"oversized\""), "width {w}");
        let kinds = dump_kind_counts(&json::parse(&dump).expect("dump is valid JSON"));
        assert_eq!(kinds.get("shed").copied().unwrap_or(0), 2, "width {w}");
        assert_eq!(kinds.get("admit").copied().unwrap_or(0), REQUESTS as u64 - 2, "width {w}");
    }
}

#[test]
fn mid_stream_epoch_update_keeps_serving_and_heals() {
    let _forensics = forensics_guard();
    let plan = FaultPlan::parse(7, "update:3:16:16").expect("spec");
    // The update is a client-side event, so the "fault-free" baseline for
    // bit-identity is the same stream with the same update at width 1.
    let base = drive(1, &plan, config(1));
    assert_eq!(base.stats.epoch_switches, 1);
    for w in WIDTHS {
        let got = drive(w, &plan, config(w));
        assert_eq!(got.stats.epoch_switches, 1, "width {w}");
        assert_eq!(got.stats.shed_total(), 0, "width {w}: updates never shed requests");
        assert_eq!(got.stats.responded_ok, REQUESTS as u64, "width {w}");
        assert_bit_identical(&got, &base, &format!("update width {w}"));
        for (i, r) in got.responses.iter().enumerate() {
            let r = r.as_ref().expect("admitted");
            let want_epoch = u64::from(i > 3);
            assert_eq!(r.epoch, want_epoch, "width {w} request {i}: snapshot isolation");
        }
        // Exactly the one published epoch in the flight ring (the
        // initial build is not an epoch *switch*), carrying the new
        // version in aux; no shard had to be healed.
        let evs = flight::snapshot();
        let switches: Vec<_> =
            evs.iter().filter(|e| e.kind == flight::Kind::EpochSwitch).collect();
        assert_eq!(switches.len(), 1, "width {w}: one epoch-switch event");
        assert_eq!(switches[0].aux, 1, "width {w}: version 1 published");
        assert!(
            !evs.iter().any(|e| e.kind == flight::Kind::Restart),
            "width {w}: nothing poisoned, nothing restarted"
        );
    }
}

#[test]
fn combined_plan_accounts_for_every_fault_exactly() {
    // Everything at once: a contained panic, a deadline-blowing slow
    // shard, a malformed query, an oversized query, and a mid-stream
    // epoch update — the daemon must account for all of it, exactly.
    let plan = FaultPlan::parse(
        7,
        "panic:0:0, slow:0:60000:1:1, malformed:2, oversized:5, update:6:16:16",
    )
    .expect("spec");
    for w in WIDTHS {
        let got = drive(w, &plan, config(w));
        assert_eq!(got.stats.panics_contained, 1, "width {w}");
        assert_eq!(got.stats.retried, 1, "width {w}");
        assert_eq!(got.stats.shed_deadline, 1, "width {w}");
        assert_eq!(got.stats.shed_malformed, 1, "width {w}");
        assert_eq!(got.stats.shed_oversized, 1, "width {w}");
        assert_eq!(got.stats.shed_total(), 3, "width {w}");
        assert_eq!(got.stats.epoch_switches, 1, "width {w}");
        assert_eq!(
            got.stats.responded_ok + got.stats.shed_total(),
            REQUESTS as u64,
            "width {w}: every request accounted"
        );
        // The flight ring mirrors the instance stats event for event:
        // containment, typed sheds, the epoch switch, and the admits.
        let evs = flight::snapshot();
        let count = |k: flight::Kind| evs.iter().filter(|e| e.kind == k).count() as u64;
        assert_eq!(count(flight::Kind::Panic), got.stats.panics_contained, "width {w}");
        assert_eq!(count(flight::Kind::Shed), got.stats.shed_total(), "width {w}");
        assert_eq!(count(flight::Kind::EpochSwitch), got.stats.epoch_switches, "width {w}");
        assert_eq!(count(flight::Kind::Admit), REQUESTS as u64 - 2, "width {w}");
        assert_shard_times_monotone(&evs, w);
        let dump = flight::last_dump().expect("a faulted run leaves a dump behind");
        json::parse(&dump).expect("dump is valid JSON");
        let e2e = hist::stage_snapshot(hist::Stage::EndToEnd);
        assert_eq!(e2e.count, got.stats.responded_ok, "width {w}: every answer histogrammed");
    }
}
