//! SIMD-vs-scalar kernel parity and per-kernel determinism.
//!
//! Contract (EXPERIMENTS.md §Kernel dispatch): the scalar kernel is the
//! golden reference — bit-identical across thread counts and machines.
//! The SIMD kernels keep the same per-output accumulation-chain *order*
//! but contract multiply-add pairs (FMA), so they match scalar to a
//! relative tolerance of 1e-5, and are themselves bit-identical across
//! thread counts (target-leaf ownership fixes the op sequence per leaf
//! regardless of the worker count).
//!
//! Shapes deliberately straddle the kernel boundaries: leaf caps around
//! the panel tile (PANEL_MR = 4) and the 4x reduction unroll, RHS widths
//! around the register block (GEMM_KC = 8): k ∈ {1, 3, 8, 17}.
//!
//! On CPUs without AVX2+FMA the Simd request resolves to the scalar
//! kernel (recorded via `dispatch_fallback`) and these tests degrade to
//! scalar-vs-scalar identity — still valid, just not exercising the SIMD
//! path (CI's `-C target-cpu=native` leg runs them on AVX2 hardware).

use nni::csb::hier::HierCsb;
use nni::csb::kernel::KernelKind;
use nni::data::synth::SynthSpec;
use nni::interact::engine::Engine;
use nni::knn::exact::knn_graph;
use nni::order::Pipeline;
use nni::util::rng::Rng;

const KS: [usize; 4] = [1, 3, 8, 17];

/// Mixed dense/sparse CSB over clustered data + tree-ordered coords.
fn setup(n: usize, leaf: usize, thr: f64) -> (HierCsb, Vec<f32>, usize) {
    let d = 3;
    let ds = SynthSpec::blobs(n, d, 4, 17).generate();
    let g = knn_graph(&ds, 6, 2);
    let a = nni::sparse::csr::Csr::from_knn(&g, n).symmetrized();
    let r = Pipeline::dual_tree(d).run(&ds, &a);
    let tree = r.tree.as_ref().unwrap();
    let csb = HierCsb::build_with(&r.reordered, tree, tree, leaf, thr);
    let coords = ds.permuted(&r.perm).raw().to_vec();
    (csb, coords, d)
}

fn assert_close(got: &[f32], want: &[f32], tag: &str) {
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!((g - w).abs() < 1e-5 * (1.0 + w.abs()), "{tag} at {i}: {g} vs {w}");
    }
}

#[test]
fn simd_spmm_matches_scalar_reference_on_odd_shapes() {
    // leaf caps around PANEL_MR and the unroll: tail tiles and short
    // reductions are the bug-prone paths.
    for &(n, leaf) in &[(389usize, 5usize), (515, 9), (700, 33)] {
        // thr 0.3: mixed storage so both micro-kernels run.
        let (csb, _, _) = setup(n, leaf, 0.3);
        let scalar = Engine::with_kernel(csb.clone(), 1, KernelKind::Scalar);
        let simd = Engine::with_kernel(csb.clone(), 1, KernelKind::Simd);
        let mut rng = Rng::new(41);
        for k in KS {
            let x: Vec<f32> = (0..csb.cols * k).map(|_| rng.f32() - 0.5).collect();
            let mut y_s = vec![0.0f32; csb.rows * k];
            let mut y_v = vec![0.0f32; csb.rows * k];
            scalar.spmm(&x, &mut y_s, k);
            simd.spmm(&x, &mut y_v, k);
            assert_close(&y_v, &y_s, &format!("spmm n={n} leaf={leaf} k={k}"));
        }
    }
}

#[test]
fn simd_gauss_matches_scalar_reference() {
    let (csb, coords, d) = setup(450, 32, 0.25);
    assert!(csb.dense_fraction() > 0.0, "needs dense blocks: {}", csb.describe());
    let scalar = Engine::with_kernel(csb.clone(), 1, KernelKind::Scalar);
    let simd = Engine::with_kernel(csb.clone(), 1, KernelKind::Simd);
    let mut rng = Rng::new(42);
    for k in KS {
        let x: Vec<f32> = (0..csb.cols * k).map(|_| rng.f32() - 0.5).collect();
        let mut y_s = vec![0.0f32; csb.rows * k];
        let mut y_v = vec![0.0f32; csb.rows * k];
        scalar.gauss_apply_multi(&coords, &coords, d, 0.6, &x, k, &mut y_s);
        simd.gauss_apply_multi(&coords, &coords, d, 0.6, &x, k, &mut y_v);
        assert_close(&y_v, &y_s, &format!("gauss k={k}"));
    }
}

#[test]
fn simd_tsne_and_meanshift_match_scalar_reference() {
    let (csb, coords, d) = setup(400, 32, 0.25);
    let scalar = Engine::with_kernel(csb.clone(), 1, KernelKind::Scalar);
    let simd = Engine::with_kernel(csb.clone(), 1, KernelKind::Simd);
    let mut rng = Rng::new(43);
    let y: Vec<f32> = (0..csb.rows * d).map(|_| rng.normal() as f32).collect();
    let mut f_s = vec![0.0f32; csb.rows * d];
    let mut f_v = vec![0.0f32; csb.rows * d];
    scalar.tsne_attr(&y, d, &mut f_s);
    simd.tsne_attr(&y, d, &mut f_v);
    assert_close(&f_v, &f_s, "tsne_attr");
    let (num_s, den_s) = scalar.meanshift_step(&coords, &coords, d, 0.5);
    let (num_v, den_v) = simd.meanshift_step(&coords, &coords, d, 0.5);
    assert_close(&num_v, &num_s, "meanshift num");
    assert_close(&den_v, &den_s, "meanshift den");
}

#[test]
fn each_kernel_is_bit_identical_across_thread_counts() {
    let (csb, coords, d) = setup(500, 16, 0.3);
    let mut rng = Rng::new(44);
    let k = 5;
    let x: Vec<f32> = (0..csb.cols * k).map(|_| rng.f32() - 0.5).collect();
    let y: Vec<f32> = (0..csb.rows * d).map(|_| rng.normal() as f32).collect();
    for kind in [KernelKind::Scalar, KernelKind::Simd] {
        let ref_eng = Engine::with_kernel(csb.clone(), 1, kind);
        let mut spmm_ref = vec![0.0f32; csb.rows * k];
        ref_eng.spmm(&x, &mut spmm_ref, k);
        let mut gauss_ref = vec![0.0f32; csb.rows * k];
        ref_eng.gauss_apply_multi(&coords, &coords, d, 0.7, &x, k, &mut gauss_ref);
        let mut tsne_ref = vec![0.0f32; csb.rows * d];
        ref_eng.tsne_attr(&y, d, &mut tsne_ref);
        for threads in [2usize, 8] {
            let eng = Engine::with_kernel(csb.clone(), threads, kind);
            let mut got = vec![0.0f32; csb.rows * k];
            eng.spmm(&x, &mut got, k);
            assert_eq!(got, spmm_ref, "spmm {:?} threads={threads}", kind);
            eng.gauss_apply_multi(&coords, &coords, d, 0.7, &x, k, &mut got);
            assert_eq!(got, gauss_ref, "gauss {:?} threads={threads}", kind);
            let mut gf = vec![0.0f32; csb.rows * d];
            eng.tsne_attr(&y, d, &mut gf);
            assert_eq!(gf, tsne_ref, "tsne {:?} threads={threads}", kind);
        }
    }
}

#[test]
fn scalar_engine_reproduces_pre_dispatch_reference() {
    // The scalar-pinned engine must equal the HierCsb scalar traversal
    // bit-for-bit — the "pin --kernel scalar for determinism" contract.
    let (csb, _, _) = setup(350, 32, 0.3);
    let eng = Engine::with_kernel(csb.clone(), 4, KernelKind::Scalar);
    assert!(eng.dispatch_fallback.is_none());
    let mut rng = Rng::new(45);
    for k in [1usize, 4] {
        let x: Vec<f32> = (0..csb.cols * k).map(|_| rng.f32()).collect();
        let mut want = vec![0.0f32; csb.rows * k];
        csb.spmm(&x, &mut want, k);
        let mut got = vec![0.0f32; csb.rows * k];
        eng.spmm(&x, &mut got, k);
        assert_eq!(got, want, "k={k}");
    }
}
