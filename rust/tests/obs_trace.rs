//! Observability integration tests, isolated in their own process so the
//! global counter registry and span slabs can be asserted **exactly**
//! (the in-crate unit tests share a process with the whole suite and must
//! stay monotonic).  Everything runs in one `#[test]` so no second test
//! thread races the global state.

use nni::obs::{self, counters, Counter};

#[test]
fn observability_end_to_end() {
    exact_counter_semantics();
    metrics_mirror_into_registry();
    serve_counter_family_is_registered();
    serve_daemon_mirrors_global_counters();
    span_nesting_and_monotonic_drain();
    request_flow_events_round_trip();
    slab_overflow_drops_without_recording();
    pipeline_trace_covers_subsystems();
}

/// The serving tier's full counter family is registered for export —
/// the name list is the contract `nni stats` and the flat metrics JSON
/// surface to dashboards.
fn serve_counter_family_is_registered() {
    const SERVE: &[&str] = &[
        "serve.queue_depth_max",
        "serve.batch_slots",
        "serve.batch_occupied",
        "serve.admitted",
        "serve.shed",
        "serve.retried",
        "serve.deadline_missed",
        "serve.panics_contained",
        "serve.shard_restarts",
        "serve.degraded",
        "serve.epoch_switches",
        "serve.shard_busy_ns",
        "serve.shard_busy_ns_max",
        "serve.shard_workers",
        "deadline.miss.admission",
        "deadline.miss.compute",
        "deadline.miss.far",
        "deadline.miss.merge",
        "flight.events",
        "flight.dumps",
    ];
    for name in SERVE {
        assert!(
            counters::COUNTER_NAMES.contains(name),
            "counter {name} missing from the export registry"
        );
    }
}

/// A daemon round-trip (one contained panic, one typed admission shed)
/// mirrors the instance stats into the global `serve.*` counters exactly
/// — this file runs in its own process, so the registry is clean.
fn serve_daemon_mirrors_global_counters() {
    use nni::csb::kernel::KernelKind;
    use nni::data::synth::SynthSpec;
    use nni::hmat::FullKernelConfig;
    use nni::interact::epoch::{UpdatableKernelEngine, UpdateCfg};
    use nni::serve::{loadgen, FaultPlan, ServeConfig, Server};
    use std::sync::Arc;

    obs::reset();
    let ds = SynthSpec::blobs(300, 3, 4, 19).generate();
    let cfg = UpdateCfg {
        leaf_cap: 8,
        block_cap: 32,
        build_threads: 1,
        threads: 1,
        kernel: KernelKind::Scalar,
        ..UpdateCfg::default()
    };
    let upd = Arc::new(UpdatableKernelEngine::build(ds, cfg, FullKernelConfig::new(0.8)));
    let plan = FaultPlan::parse(7, "panic:0:0, malformed:2").expect("static fault spec");
    let server = Server::start(
        upd,
        ServeConfig { shards: 2, real_time: false, ..ServeConfig::default() },
        plan.clone(),
    );
    let rep = loadgen::run(
        &server,
        &plan,
        &loadgen::LoadGenCfg { requests: 8, ..loadgen::LoadGenCfg::default() },
    );
    let stats = server.shutdown();
    assert_eq!(rep.lost, 0, "no request lost");
    assert_eq!(stats.panics_contained, 1);
    assert_eq!(stats.shed_malformed, 1);
    let snap = counters::snapshot();
    assert_eq!(snap.get("serve.admitted"), stats.admitted);
    assert_eq!(snap.get("serve.shed"), stats.shed_total());
    assert_eq!(snap.get("serve.retried"), stats.retried);
    assert_eq!(snap.get("serve.panics_contained"), stats.panics_contained);
    assert_eq!(
        snap.get("serve.shard_restarts"),
        stats.panics_contained,
        "one snapshot restart per contained panic"
    );
    assert!(snap.get("serve.shard_busy_ns") > 0, "workers account busy time");
    // The deep-observability layer saw the same run: the worker gauge,
    // the flight ring (one admit per admitted request, one restart per
    // containment), and the end-to-end latency histogram all agree with
    // the instance stats.
    assert_eq!(snap.get("serve.shard_workers"), 2, "shard worker gauge");
    assert!(snap.get("flight.events") > 0, "flight recorder captured the run");
    assert!(snap.get("flight.dumps") > 0, "the contained panic auto-dumped");
    let evs = obs::flight::snapshot();
    let count = |k: obs::flight::Kind| evs.iter().filter(|e| e.kind == k).count() as u64;
    assert_eq!(count(obs::flight::Kind::Admit), stats.admitted, "one admit event each");
    assert_eq!(count(obs::flight::Kind::Panic), stats.panics_contained);
    assert_eq!(count(obs::flight::Kind::Restart), stats.panics_contained);
    let e2e = obs::hist::stage_snapshot(obs::hist::Stage::EndToEnd);
    assert_eq!(e2e.count, stats.responded_ok, "every answer in the e2e histogram");
}

/// Exact add/raise/level arithmetic through a snapshot.
fn exact_counter_semantics() {
    obs::reset();
    counters::add(Counter::CgIterations, 5);
    counters::add(Counter::CgIterations, 2);
    counters::raise(Counter::ServeQueueDepthMax, 9);
    counters::raise(Counter::ServeQueueDepthMax, 4);
    counters::level_add(counters::LevelStat::Blocks, 2, 3);
    counters::level_add(counters::LevelStat::DenseBlocks, 2, 1);
    counters::level_add(counters::LevelStat::Nnz, 2, 30);
    counters::level_add(counters::LevelStat::Cells, 2, 60);
    let snap = counters::snapshot();
    assert_eq!(snap.get("cg.iterations"), 7);
    assert_eq!(snap.get("serve.queue_depth_max"), 9, "raise keeps the high-water mark");
    let row = snap.levels.iter().find(|r| r.level == 2).expect("level 2 occupied");
    assert_eq!((row.blocks, row.dense_blocks, row.nnz, row.cells), (3, 1, 30, 60));
    assert!((row.fill_ratio() - 0.5).abs() < 1e-12);
}

/// `coordinator::Metrics` note_* helpers mirror exactly into `coord.*`.
fn metrics_mirror_into_registry() {
    obs::reset();
    let mut m = nni::coordinator::metrics::Metrics::new();
    m.note_iteration(10);
    m.note_rust(3, 0.5);
    m.note_pjrt(1, 2, 17, 0.25);
    m.note_serve(8, 1, 80, 0.125);
    let snap = counters::snapshot();
    assert_eq!(snap.get("coord.nnz_processed"), 90);
    assert_eq!(snap.get("coord.rust_blocks"), 3);
    assert_eq!(snap.get("coord.pjrt_single_calls"), 1);
    assert_eq!(snap.get("coord.pjrt_batched_calls"), 2);
    assert_eq!(snap.get("coord.pjrt_blocks"), 17);
    assert_eq!(snap.get("coord.batched_queries"), 8);
    assert_eq!(snap.get("coord.serve_calls"), 1);
    assert_eq!(snap.get("coord.rust_ns"), 500_000_000 + 125_000_000);
    assert_eq!(snap.get("coord.pjrt_ns"), 250_000_000);
}

/// Nested spans on two workers drain to a well-formed, monotonic Chrome
/// trace: sorted by (worker, start), children contained in parents.
fn span_nesting_and_monotonic_drain() {
    obs::reset();
    obs::install(2, 2048);
    obs::set_enabled(true);
    obs::trace::set_worker(0);
    {
        let _outer = obs::trace::SpanGuard::enter("csb.build");
        std::thread::sleep(std::time::Duration::from_millis(2));
        {
            obs::span!("csb.build.fill");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }
    std::thread::spawn(|| {
        obs::trace::set_worker(1);
        obs::span!("apply.task");
        std::thread::sleep(std::time::Duration::from_millis(1));
    })
    .join()
    .unwrap();
    obs::set_enabled(false);

    let spans = obs::trace::drain();
    assert_eq!(spans.len(), 3, "{spans:?}");
    for pair in spans.windows(2) {
        assert!(
            (pair[0].worker, pair[0].t0_us) <= (pair[1].worker, pair[1].t0_us),
            "drain not sorted by (worker, start): {spans:?}"
        );
    }
    for sp in &spans {
        assert!(sp.t1_us >= sp.t0_us, "negative duration: {sp:?}");
    }
    let outer = spans.iter().find(|s| s.name == "csb.build").unwrap();
    let inner = spans.iter().find(|s| s.name == "csb.build.fill").unwrap();
    let task = spans.iter().find(|s| s.name == "apply.task").unwrap();
    assert_eq!((outer.depth, outer.worker), (0, 0));
    assert_eq!((inner.depth, inner.worker), (1, 0));
    assert_eq!(task.worker, 1);
    // child strictly inside the parent (the sleeps guarantee real widths)
    assert!(inner.t0_us >= outer.t0_us && inner.t1_us <= outer.t1_us);
    assert!(outer.t1_us - outer.t0_us >= inner.t1_us - inner.t0_us);

    // the exporter round-trips and the checker accepts it
    let text = obs::export::chrome_trace(&spans).to_string();
    assert_eq!(obs::export::check_trace(&text, &["csb", "apply"]), Ok(3));
    assert!(obs::export::check_trace(&text, &["hmat"]).is_err());

    // a second drain is empty (records moved out, capacity kept)
    assert!(obs::trace::drain().is_empty());
}

/// Request-scoped spans export as a Chrome flow chain (`ph` `"s"`/`"t"`/
/// `"f"`, shared `id`) tying the request's stages across tracks; a
/// request with a single span emits no chain, and the checker accepts
/// the mixed trace.
fn request_flow_events_round_trip() {
    use nni::util::json::{self, Json};

    obs::reset();
    obs::install(3, 256);
    obs::set_enabled(true);
    let t0 = obs::trace::now_us();
    // Request 42's three stages land on three tracks (dispatcher, one
    // shard, dispatcher again) — the same shape the serve tier records.
    obs::trace::set_worker(0);
    obs::trace::record_closed("serve.slate", t0, t0 + 5, 42);
    obs::trace::set_worker(1);
    obs::trace::record_closed("serve.shard.compute", t0 + 5, t0 + 9, 42);
    obs::trace::set_worker(2);
    obs::trace::record_closed("serve.merge", t0 + 9, t0 + 11, 42);
    // Request 7 has one span only: below the two-stage flow threshold.
    obs::trace::record_closed("serve.slate", t0 + 11, t0 + 12, 7);
    obs::set_enabled(false);

    let spans = obs::trace::drain();
    assert_eq!(spans.len(), 4);
    let text = obs::export::chrome_trace(&spans).to_string();
    // 4 complete events + the 3-stage flow chain for request 42.
    assert_eq!(obs::export::check_trace(&text, &["serve"]), Ok(7));
    let parsed = json::parse(&text).expect("trace is valid JSON");
    let flows: Vec<&Json> = parsed
        .as_arr()
        .expect("trace is an array")
        .iter()
        .filter(|e| e.get("name").and_then(Json::as_str) == Some("serve.request"))
        .collect();
    let phases: Vec<&str> =
        flows.iter().map(|e| e.get("ph").and_then(Json::as_str).unwrap()).collect();
    assert_eq!(phases, ["s", "t", "f"], "start, step, finish — in stage order");
    for e in &flows {
        assert_eq!(e.get("id").and_then(Json::as_f64), Some(42.0), "one id per request");
    }
    let finish = flows.last().unwrap();
    assert_eq!(
        finish.get("bp").and_then(Json::as_str),
        Some("e"),
        "flow end binds to its enclosing slice"
    );
}

/// A full slab drops spans (counted, allocation-free) instead of growing.
fn slab_overflow_drops_without_recording() {
    obs::reset();
    obs::set_enabled(true);
    obs::trace::set_worker(0);
    const ATTEMPTS: usize = 50_000; // far beyond any reserved capacity here
    for _ in 0..ATTEMPTS {
        obs::span!("apply.task");
    }
    obs::set_enabled(false);
    assert!(obs::trace::dropped() > 0, "slab never filled");
    assert!(counters::get(Counter::SpansDropped) > 0);
    let spans = obs::trace::drain();
    assert!(!spans.is_empty() && spans.len() < ATTEMPTS, "{} recorded", spans.len());
}

/// End-to-end: a small build + apply traces every near-field subsystem and
/// publishes exact apply counters.
fn pipeline_trace_covers_subsystems() {
    use nni::csb::kernel::KernelKind;
    use nni::data::synth::SynthSpec;
    use nni::knn::exact::knn_graph;
    use nni::order::Pipeline;
    use nni::sparse::csr::Csr;

    obs::reset();
    obs::install(1, obs::DEFAULT_SPAN_CAP);
    obs::set_enabled(true);
    let n = 400;
    // d = 8 > embed dim 3, so the PCA embedding step actually runs
    // (the pipeline skips it for already-low-dimensional data).
    let ds = SynthSpec::blobs(n, 8, 3, 11).generate();
    let g = knn_graph(&ds, 6, 1);
    let a = Csr::from_knn(&g, n).symmetrized();
    let r = Pipeline::dual_tree(3).run(&ds, &a);
    let eng = r.engine_with(64, 0.6, 1, 1, KernelKind::Auto).expect("tree ordering");
    let k = 4;
    let x = vec![1.0f32; n * k];
    let mut y = vec![0.0f32; n * k];
    eng.spmm(&x, &mut y, k);
    eng.spmm(&x, &mut y, k);
    obs::set_enabled(false);

    let snap = counters::snapshot();
    assert_eq!(snap.get("apply.calls"), 2);
    assert!(snap.get("tree.builds") >= 1);
    assert!(snap.get("embed.pca_runs") >= 1);
    assert!(snap.get("csb.nnz") > 0);
    assert!(snap.get("apply.gemm_flops") > 0);
    assert!(snap.covered_fraction() > 0.0);
    assert!(!snap.levels.is_empty(), "per-level fill table published");
    // flops are schedule-static: every call adds the same amount, so the
    // two calls account for exactly twice the per-call tally
    assert_eq!(snap.get("apply.tasks") % 2, 0);

    let spans = obs::trace::drain();
    let text = obs::export::chrome_trace(&spans).to_string();
    obs::export::check_trace(&text, &["tree", "embed", "csb", "apply"])
        .expect("trace covers the near-field subsystems");
}
