//! Smoke tests of the `nni` CLI binary: every subcommand runs on a tiny
//! workload and produces the expected output shape.

use std::process::Command;

fn nni() -> Command {
    Command::new(env!("CARGO_BIN_EXE_nni"))
}

#[test]
fn help_lists_subcommands() {
    let out = nni().output().unwrap();
    let text = String::from_utf8_lossy(&out.stderr) + String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("tsne"));
    assert!(text.contains("meanshift"));
    assert!(text.contains("knn"));
}

#[test]
fn info_prints_testbed() {
    let out = nni().arg("info").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("testbed:"), "{text}");
}

#[test]
fn synth_reorder_roundtrip() {
    let dir = std::env::temp_dir();
    let path = dir.join("nni_cli_smoke.nnid");
    let out = nni()
        .args([
            "synth",
            "--workload",
            "sift",
            "--n",
            "256",
            "--out",
            path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = nni()
        .args([
            "reorder",
            "--input",
            path.to_str().unwrap(),
            "--k",
            "8",
            "--ordering",
            "3ddt",
            "--leaf-cap",
            "64",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("gamma"), "{text}");
    assert!(text.contains("csb:"), "{text}");
    std::fs::remove_file(path).ok();
}

#[test]
fn knn_subcommand_reports_recall() {
    let out = nni()
        .args([
            "knn", "--n", "400", "--k", "5", "--knn", "ann", "--recall-sample", "64",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("backend=ann"), "{text}");
    assert!(text.contains("recall@5"), "{text}");
}

#[test]
fn reorder_accepts_ann_backend() {
    let out = nni()
        .args([
            "reorder", "--n", "512", "--k", "8", "--knn", "ann", "--ordering", "3ddt",
            "--leaf-cap", "64",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("knn=ann"), "{text}");
    assert!(text.contains("gamma"), "{text}");
}

#[test]
fn invalid_flag_values_are_usage_errors() {
    // nonsensical values die at parse time with a one-line error naming
    // the flag — not a raw panic from a downstream assert
    let out = nni()
        .args(["reorder", "--n", "64", "--rhs", "0"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("--rhs"), "{text}");
    assert!(!text.contains("panicked"), "{text}");
    let out = nni()
        .args(["spmv", "--n", "64", "--leaf-cap", "0"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("--leaf-cap"), "{text}");
    let out = nni()
        .args(["reorder", "--n", "sixty-four"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--n"));
}

#[test]
fn kernel_knob_parses_and_reports_dispatch() {
    // pinned scalar: reported as such, no fallback line
    let out = nni()
        .args([
            "spmv", "--n", "256", "--leaf-cap", "64", "--kernel", "scalar",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("requested=scalar dispatch=scalar"), "{text}");
    // auto: dispatch resolves to whatever the CPU offers
    let out = nni()
        .args(["reorder", "--n", "256", "--k", "6", "--leaf-cap", "64", "--rhs", "4"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("kernel: requested=auto dispatch="), "{text}");
    // bad value → one-line usage error naming the choices
    let out = nni()
        .args(["spmv", "--n", "64", "--kernel", "mkl"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("auto|simd|scalar"), "{text}");
}

#[test]
fn reorder_accepts_build_threads_knob() {
    let out = nni()
        .args([
            "reorder", "--n", "256", "--k", "6", "--leaf-cap", "64", "--build-threads", "2",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("csb:"));
}

#[test]
fn krr_converges_and_reports_engine() {
    let out = nni()
        .args([
            "krr", "--n", "512", "--block-cap", "64", "--lambda", "1.0", "--tol", "1e-3",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("far=aca"), "{text}");
    assert!(text.contains("far_blocks="), "{text}");
    assert!(text.contains("cg:"), "{text}");
    // --far off degrades to the truncated baseline
    let out = nni()
        .args(["krr", "--n", "256", "--block-cap", "64", "--far", "off"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("far=off"));
    // bad far mode is a usage error naming the flag and the choices
    let out = nni().args(["krr", "--n", "64", "--far", "fmm"]).output().unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("--far"), "{text}");
    assert!(text.contains("off|aca|h2"), "{text}");
}

#[test]
fn krr_h2_mode_and_precision_knobs() {
    // --far h2 routes the far field through the nested-basis representation
    let out = nni()
        .args([
            "krr", "--n", "512", "--block-cap", "64", "--far", "h2", "--tol", "1e-3",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("far=h2"), "{text}");
    assert!(text.contains("precision=f32"), "{text}");
    assert!(text.contains("cg:"), "{text}");
    // bf16 factor storage is accepted and reported
    let out = nni()
        .args([
            "krr", "--n", "512", "--block-cap", "64", "--far", "h2", "--precision", "bf16",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("precision=bf16"));
    // --verify solves plain + preconditioned and checks agreement
    let out = nni()
        .args([
            "krr", "--n", "512", "--block-cap", "64", "--far", "h2", "--verify",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("verify OK"));
    // bad precision is a one-line usage error naming the flag
    let out = nni()
        .args(["krr", "--n", "64", "--precision", "f64"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("--precision"), "{text}");
    assert!(text.contains("f32|bf16"), "{text}");
    assert!(!text.contains("panicked"), "{text}");
    // --verify without --far h2 is a usage error, not a silent no-op
    let out = nni()
        .args(["krr", "--n", "64", "--far", "aca", "--verify"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--verify"));
}

#[test]
fn reorder_reports_coverage_and_far_field() {
    let out = nni()
        .args([
            "reorder", "--n", "400", "--k", "8", "--leaf-cap", "64", "--far", "aca",
            "--tol", "1e-2",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("coverage: stored blocks span"), "{text}");
    assert!(text.contains("full-kernel"), "{text}");
    assert!(text.contains("far_blocks="), "{text}");
}

#[test]
fn meanshift_finds_modes() {
    let out = nni()
        .args([
            "meanshift",
            "--n",
            "300",
            "--blobs",
            "3",
            "--k",
            "16",
            "--iters",
            "30",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("3 modes"), "{text}");
}

#[test]
fn reorder_emits_trace_and_metrics_files() {
    let dir = std::env::temp_dir();
    let trace = dir.join("nni_cli_smoke_trace.json");
    let metrics = dir.join("nni_cli_smoke_metrics.json");
    let out = nni()
        .args([
            "reorder", "--n", "400", "--k", "6", "--leaf-cap", "64", "--rhs", "4",
            "--far", "aca", "--tol", "1e-2",
            "--trace-out", trace.to_str().unwrap(),
            "--metrics-out", metrics.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("trace ->"), "{text}");
    assert!(text.contains("metrics ->"), "{text}");

    // the emitted trace passes the binary's own validator for every
    // subsystem a reorder run touches (the full default additionally
    // requires serve — see the stats smoke below for that one)
    let out = nni()
        .args([
            "trace-check", "--require", "tree,csb,hmat,apply,interact",
            trace.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains(": ok ("));
    // ... but demanding a subsystem the run never touched fails
    let out = nni()
        .args(["trace-check", "--require", "warp", trace.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("warp"));

    // the metrics snapshot is JSON with the expected top-level sections
    let mtext = std::fs::read_to_string(&metrics).unwrap();
    for key in ["\"counters\"", "\"derived\"", "\"levels\"", "csb.covered_fraction"] {
        assert!(mtext.contains(key), "metrics missing {key}: {mtext}");
    }
    std::fs::remove_file(trace).ok();
    std::fs::remove_file(metrics).ok();
}

#[test]
fn stats_prints_counter_report() {
    let out = nni()
        // --far off so the apply.calls tally is exactly the --applies
        // count (the full-kernel spmv routes through the same engine)
        .args([
            "stats", "--n", "256", "--rhs", "2", "--applies", "2", "--leaf-cap", "64",
            "--far", "off",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("nni stats"), "{text}");
    assert!(text.contains("== counters =="), "{text}");
    assert!(text.contains("apply.calls = 2"), "{text}");
    assert!(text.contains("== derived =="), "{text}");
    assert!(text.contains("csb.covered_fraction"), "{text}");
    assert!(text.contains("== levels"), "{text}");
}

#[test]
fn stats_serve_round_satisfies_full_default_require() {
    let dir = std::env::temp_dir();
    let trace = dir.join("nni_cli_smoke_stats_trace.json");
    let metrics = dir.join("nni_cli_smoke_stats_metrics.json");
    let out = nni()
        .args([
            "stats", "--n", "256", "--rhs", "2", "--applies", "2", "--leaf-cap", "64",
            "--trace-out", trace.to_str().unwrap(),
            "--metrics-out", metrics.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    // stats runs the full pipeline *and* a serve round, so its trace is
    // the artifact that satisfies trace-check's complete default require
    // list (tree,csb,hmat,apply,interact,serve) with no flags.
    let out = nni().args(["trace-check", trace.to_str().unwrap()]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains(": ok ("));
    // the metrics JSON carries the stage latency histograms and the
    // derived shard-imbalance gauge next to the flat counters
    let mtext = std::fs::read_to_string(&metrics).unwrap();
    for key in ["\"hists\"", "serve.e2e", "serve.shard_imbalance"] {
        assert!(mtext.contains(key), "metrics missing {key}: {mtext}");
    }
    std::fs::remove_file(trace).ok();
    std::fs::remove_file(metrics).ok();
}

#[test]
fn trace_check_rejects_garbage() {
    let dir = std::env::temp_dir();
    let bad = dir.join("nni_cli_smoke_bad_trace.json");
    std::fs::write(&bad, "this is not json").unwrap();
    let out = nni().args(["trace-check", bad.to_str().unwrap()]).output().unwrap();
    assert!(!out.status.success());
    std::fs::remove_file(bad).ok();
}

#[test]
fn bench_check_gates_pending_records() {
    let dir = std::env::temp_dir();
    let rec = dir.join("nni_cli_smoke_bench.json");
    std::fs::write(
        &rec,
        r#"{"bench":"x","status":"pending: needs hardware","points":[]}"#,
    )
    .unwrap();
    // schema-valid pending record: ok by default...
    let out = nni().args(["bench-check", rec.to_str().unwrap()]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("status=pending"));
    // ...rejected under --no-pending (the CI honesty gate)
    let out = nni()
        .args(["bench-check", "--no-pending", rec.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("pending"));
    // a measured record with points passes either way
    std::fs::write(
        &rec,
        r#"{"bench":"x","status":"measured","points":[{"n":64,"seconds":0.5}]}"#,
    )
    .unwrap();
    let out = nni()
        .args(["bench-check", "--no-pending", rec.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    std::fs::remove_file(rec).ok();
}

#[test]
fn tsne_short_run_logs_kl() {
    let out = nni()
        .args([
            "tsne", "--n", "300", "--iters", "60", "--k", "20",
            "--perplexity", "8",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("KL"), "{text}");
}
