//! Differential update-fuzz harness (DESIGN: incremental engine updates).
//!
//! Every test here checks the same invariant from a different angle: an
//! *incrementally* updated structure must be **byte-identical** to a
//! from-scratch build over the post-update data — same tree layout, same
//! CSB block list, same dense/sparse arena bits — and the full-kernel
//! operator must additionally apply within the ACA tolerance.  The fuzz
//! tests replay identical seeded batch streams at thread counts {1, 2, 8}
//! and require the replicas to agree with each other as well.
//!
//! Batch shapes covered: uniform deletes + box-uniform inserts, cluster-
//! skewed placement, duplicate inserts (including exact copies of existing
//! points), insert-only and delete-only rounds, delete-to-empty-leaf
//! collapse, and leaf-capacity-overflow resplits.

use nni::csb::hier::HierCsb;
use nni::csb::kernel::KernelKind;
use nni::csb::update::{update_par, SideDelta};
use nni::data::dataset::Dataset;
use nni::data::synth::SynthSpec;
use nni::hmat::{FarFieldMode, FullKernelConfig, Precision};
use nni::interact::epoch::{UpdatableEngine, UpdatableKernelEngine, UpdateCfg};
use nni::knn::exact::knn_graph;
use nni::sparse::csr::Csr;
use nni::tree::boxtree::BoxTree;
use nni::tree::update::{update_tree, TreeUpdate, UpdateBatch};
use nni::util::rng::Rng;

const THREADS: [usize; 3] = [1, 2, 8];
const LEAF_CAP: usize = 8;
const MAX_DEPTH: u32 = 24;
const BLOCK_CAP: usize = 32;

/// Deterministic tree-ordered profile (symmetrized kNN).  The fixed inner
/// thread count keeps the closure a pure function of the dataset.
fn profile(ds: &Dataset, _t: &BoxTree) -> Csr {
    let k = 6usize.min(ds.n().saturating_sub(1)).max(1);
    Csr::from_knn(&knn_graph(ds, k, 2), ds.n()).symmetrized()
}

fn cfg(build_threads: usize) -> UpdateCfg {
    UpdateCfg {
        leaf_cap: LEAF_CAP,
        max_depth: MAX_DEPTH,
        block_cap: BLOCK_CAP,
        build_threads,
        threads: build_threads,
        kernel: KernelKind::Scalar,
        ..UpdateCfg::default()
    }
}

/// Byte-level arena equality — the differential oracle.
fn assert_arenas_eq(want: &HierCsb, got: &HierCsb, ctx: &str) {
    assert_eq!(want.rows, got.rows, "{ctx}: rows");
    assert_eq!(want.cols, got.cols, "{ctx}: cols");
    assert_eq!(want.blocks, got.blocks, "{ctx}: block list");
    assert_eq!(want.by_target, got.by_target, "{ctx}: by_target");
    assert_eq!(want.sp_rows, got.sp_rows, "{ctx}: sp_rows");
    assert_eq!(want.sp_ptr, got.sp_ptr, "{ctx}: sp_ptr");
    assert_eq!(want.sp_col, got.sp_col, "{ctx}: sp_col");
    assert_eq!(want.dense.len(), got.dense.len(), "{ctx}: dense arena length");
    assert_eq!(want.sp_val.len(), got.sp_val.len(), "{ctx}: sp_val arena length");
    assert!(
        want.dense.iter().zip(&got.dense).all(|(a, b)| a.to_bits() == b.to_bits()),
        "{ctx}: dense arena bits differ"
    );
    assert!(
        want.sp_val.iter().zip(&got.sp_val).all(|(a, b)| a.to_bits() == b.to_bits()),
        "{ctx}: sp_val arena bits differ"
    );
}

fn bbox(ds: &Dataset) -> (Vec<f32>, Vec<f32>) {
    let d = ds.d();
    let mut lo = vec![f32::INFINITY; d];
    let mut hi = vec![f32::NEG_INFINITY; d];
    for i in 0..ds.n() {
        for (a, &x) in ds.row(i).iter().enumerate() {
            lo[a] = lo[a].min(x);
            hi[a] = hi[a].max(x);
        }
    }
    (lo, hi)
}

/// Seeded batch generator cycling through shapes: uniform mixed, cluster-
/// skewed, duplicate-heavy (repeated delete indices + exact-copy inserts),
/// insert-only, delete-only.  Deletes may hit the hull — the full-rebuild
/// fallback is a correct path and the differential oracle covers it too.
fn gen_batch(ds: &Dataset, rng: &mut Rng, round: usize) -> UpdateBatch {
    let (n, d) = (ds.n(), ds.d());
    let (lo, hi) = bbox(ds);
    let mut deletes = Vec::new();
    let mut inserts = Vec::new();
    match round % 5 {
        0 => {
            // uniform mixed batch, size varies with the rng
            for _ in 0..1 + rng.below(20) {
                deletes.push(rng.below(n));
            }
            for _ in 0..1 + rng.below(20) {
                for (l, h) in lo.iter().zip(&hi) {
                    inserts.push(l + rng.f32() * (h - l));
                }
            }
        }
        1 => {
            // cluster-skewed: all inserts jitter one anchor point
            let anchor = rng.below(n);
            let scale: Vec<f32> = (0..d).map(|a| 0.02 * (hi[a] - lo[a])).collect();
            for _ in 0..4 + rng.below(12) {
                for (a, &x) in ds.row(anchor).iter().enumerate() {
                    inserts.push(x + (rng.f32() - 0.5) * scale[a]);
                }
            }
            for _ in 0..rng.below(6) {
                deletes.push(rng.below(n));
            }
        }
        2 => {
            // duplicate-heavy: repeated delete indices (deduped by the tree
            // layer) and exact copies of an existing point
            let i = rng.below(n);
            deletes.push(i);
            deletes.push(i);
            deletes.push(rng.below(n));
            let p = rng.below(n);
            for _ in 0..3 + rng.below(5) {
                inserts.extend_from_slice(ds.row(p));
            }
        }
        3 => {
            // insert-only, larger
            for _ in 0..8 + rng.below(24) {
                for (l, h) in lo.iter().zip(&hi) {
                    inserts.push(l + rng.f32() * (h - l));
                }
            }
        }
        _ => {
            // delete-only, larger (bounded to keep the set nonempty)
            for _ in 0..(8 + rng.below(24)).min(n / 4) {
                deletes.push(rng.below(n));
            }
        }
    }
    UpdateBatch { deletes, inserts }
}

/// The tentpole invariant: replay an identical seeded batch stream through
/// the epoch layer at thread counts {1, 2, 8}; after every publish the
/// incremental CSB arenas must be byte-identical to a from-scratch build
/// over the same post-update data, and the final replicas must agree with
/// each other bit-for-bit across thread counts.
#[test]
fn fuzz_incremental_matches_from_scratch_across_threads() {
    for &seed in &[101u64, 202, 303] {
        let ds0 = SynthSpec::blobs(400, 3, 4, seed).generate();
        let mut replicas: Vec<HierCsb> = Vec::new();
        for &t in &THREADS {
            let upd = UpdatableEngine::build(ds0.clone(), cfg(t), profile);
            let mut rng = Rng::new(seed.wrapping_mul(7).wrapping_add(1));
            for round in 0..5 {
                let cur = upd.acquire();
                let b = gen_batch(&cur.value.ds, &mut rng, round);
                drop(cur);
                let e = upd.update(&b);
                let fresh = UpdatableEngine::build(e.value.ds.clone(), cfg(t), profile);
                assert_arenas_eq(
                    &fresh.acquire().value.engine.csb,
                    &e.value.engine.csb,
                    &format!("seed {seed} threads {t} round {round}"),
                );
            }
            replicas.push(upd.acquire().value.engine.csb.clone());
        }
        for (i, r) in replicas.iter().enumerate().skip(1) {
            assert_arenas_eq(
                &replicas[0],
                r,
                &format!("seed {seed}: thread-count replay {} vs {}", THREADS[0], THREADS[i]),
            );
        }
    }
}

/// Full-kernel operator: near arenas byte-identical, far-field application
/// within the ACA tolerance (scalar kernel — the comparison is exact in
/// practice because untouched factors are lifted bit-for-bit).
#[test]
fn fuzz_kernel_engine_spmv_within_tol_across_threads() {
    let seed = 505u64;
    let ds0 = SynthSpec::blobs(300, 3, 4, seed).generate();
    let kcfg = FullKernelConfig::new(0.8);
    for &t in &THREADS {
        let mut c = cfg(t);
        c.block_cap = 64;
        let upd = UpdatableKernelEngine::build(ds0.clone(), c, kcfg.clone());
        let mut rng = Rng::new(seed);
        for round in 0..3 {
            let cur = upd.acquire();
            let b = gen_batch(&cur.value.ds, &mut rng, round);
            drop(cur);
            let e = upd.update(&b);
            let fresh = UpdatableKernelEngine::build(e.value.ds.clone(), c, kcfg.clone());
            let f = fresh.acquire();
            let ctx = format!("threads {t} round {round}");
            assert_arenas_eq(&f.value.engine.near.csb, &e.value.engine.near.csb, &ctx);
            assert!(f.value.engine.far.bits_eq(&e.value.engine.far), "{ctx}: far field differs");
            let n = e.value.engine.n();
            let x: Vec<f32> = (0..n).map(|i| (i * 37 % 101) as f32 / 101.0 - 0.5).collect();
            let mut ya = vec![0.0f32; n];
            let mut yb = vec![0.0f32; n];
            e.value.engine.spmv(&x, &mut ya);
            f.value.engine.spmv(&x, &mut yb);
            let scale = yb.iter().fold(1.0f32, |m, v| m.max(v.abs()));
            for (i, (a, b)) in ya.iter().zip(&yb).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-4 * scale,
                    "{ctx}: spmv row {i}: incremental {a} vs fresh {b} (scale {scale})"
                );
            }
        }
    }
}

/// H² far field through the same differential harness: replay identical
/// seeded batch streams at thread counts {1, 2, 8} with `--far h2`
/// semantics (nested bases + transfer/coupling factors) and require every
/// published epoch to be **fully bit-identical** to a from-scratch
/// `H2Field` over the post-update data — skeletons, arenas, and layout,
/// not just application accuracy.  Covers both storage precisions.
#[test]
fn fuzz_h2_kernel_engine_matches_fresh_across_threads() {
    let seed = 707u64;
    let ds0 = SynthSpec::blobs(300, 3, 4, seed).generate();
    for precision in [Precision::F32, Precision::Bf16] {
        let kcfg = FullKernelConfig::new(0.8)
            .with_far(FarFieldMode::H2)
            .with_precision(precision);
        for &t in &THREADS {
            let mut c = cfg(t);
            c.block_cap = 64;
            let upd = UpdatableKernelEngine::build(ds0.clone(), c, kcfg.clone());
            let mut rng = Rng::new(seed);
            for round in 0..3 {
                let cur = upd.acquire();
                let b = gen_batch(&cur.value.ds, &mut rng, round);
                drop(cur);
                let e = upd.update(&b);
                let fresh = UpdatableKernelEngine::build(e.value.ds.clone(), c, kcfg.clone());
                let f = fresh.acquire();
                let ctx = format!("precision {precision:?} threads {t} round {round}");
                assert_arenas_eq(&f.value.engine.near.csb, &e.value.engine.near.csb, &ctx);
                assert!(
                    f.value.engine.far.bits_eq(&e.value.engine.far),
                    "{ctx}: h2 far field differs from from-scratch"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Edge cases, via the layered API (full visibility into the fallback flag).
// ---------------------------------------------------------------------------

/// Run one batch through the tree → CSB incremental chain and check the
/// result against a from-scratch build.  `expect_fallback` pins whether the
/// tree layer must (not) have taken the full-rebuild path.
fn layered_roundtrip(ds: &Dataset, batch: &UpdateBatch, expect_fallback: Option<bool>, ctx: &str) -> TreeUpdate {
    let tree = BoxTree::build_par(ds, LEAF_CAP, MAX_DEPTH, 2);
    let a = profile(&ds.permuted(&tree.perm), &tree);
    let csb = HierCsb::build_with_par(&a, &tree, &tree, BLOCK_CAP, 0.6, 2);
    let tu = update_tree(&tree, ds, batch, MAX_DEPTH, 2);
    if let Some(fb) = expect_fallback {
        assert_eq!(tu.full_rebuild, fb, "{ctx}: full-rebuild fallback");
    }
    let a_new = profile(&tu.ds.permuted(&tu.tree.perm), &tu.tree);
    let inc = if tu.full_rebuild {
        HierCsb::build_with_par(&a_new, &tu.tree, &tu.tree, BLOCK_CAP, 0.6, 2)
    } else {
        let delta = SideDelta::from_update(&tree, &tu);
        update_par(&csb, &a, &a_new, &tu.tree, &delta, &tu.tree, &delta, BLOCK_CAP, 2)
    };
    let ftree = BoxTree::build_par(&tu.ds, LEAF_CAP, MAX_DEPTH, 2);
    let fa = profile(&tu.ds.permuted(&ftree.perm), &ftree);
    let fresh = HierCsb::build_with_par(&fa, &ftree, &ftree, BLOCK_CAP, 0.6, 2);
    assert_arenas_eq(&fresh, &inc, ctx);
    tu
}

/// External indices of the first leaf whose points all avoid the data hull
/// (deleting or crowding it cannot move the root box → no fallback).
fn interior_leaf_members(tree: &BoxTree, ds: &Dataset) -> Vec<usize> {
    let (lo, hi) = bbox(ds);
    let on_hull =
        |row: &[f32]| row.iter().enumerate().any(|(a, &x)| x == lo[a] || x == hi[a]);
    for l in tree.leaves() {
        let nd = &tree.nodes[l as usize];
        let members: Vec<usize> =
            (nd.lo..nd.hi).map(|p| tree.perm[p as usize]).collect();
        if !members.is_empty() && members.iter().all(|&e| !on_hull(ds.row(e))) {
            return members;
        }
    }
    panic!("no interior leaf in the test dataset");
}

/// Deleting every member of a leaf empties it; the subtree above collapses
/// and the incremental result must still match from-scratch byte-for-byte.
#[test]
fn delete_to_empty_leaf_collapses_subtree() {
    let ds = SynthSpec::blobs(400, 3, 4, 601).generate();
    let tree = BoxTree::build_par(&ds, LEAF_CAP, MAX_DEPTH, 2);
    let deletes = interior_leaf_members(&tree, &ds);
    let batch = UpdateBatch { deletes: deletes.clone(), inserts: Vec::new() };
    let tu = layered_roundtrip(&ds, &batch, Some(false), "empty-leaf collapse");
    assert_eq!(tu.ds.n(), ds.n() - deletes.len());
}

/// Deleting an entire planted cluster (by label) collapses a whole region
/// of the tree at once.
#[test]
fn delete_entire_cluster_matches_from_scratch() {
    let ds = SynthSpec::blobs(400, 3, 4, 607).generate();
    let labels = ds.labels.clone().expect("blobs carry labels");
    let deletes: Vec<usize> =
        (0..ds.n()).filter(|&i| labels[i] == 0).collect();
    assert!(!deletes.is_empty());
    let batch = UpdateBatch { deletes, inserts: Vec::new() };
    // a cluster usually touches the hull — no fallback expectation either way
    layered_roundtrip(&ds, &batch, None, "whole-cluster delete");
}

/// Crowding one interior leaf with more than `leaf_cap` new points forces
/// the leaf to resplit; the resplit subtree must reproduce the from-scratch
/// layout bit-for-bit.
#[test]
fn insert_overflow_forces_leaf_resplit() {
    let ds = SynthSpec::blobs(400, 3, 4, 611).generate();
    let tree = BoxTree::build_par(&ds, LEAF_CAP, MAX_DEPTH, 2);
    let members = interior_leaf_members(&tree, &ds);
    let anchor = ds.row(members[0]).to_vec();
    let mut inserts = Vec::new();
    let mut rng = Rng::new(613);
    for _ in 0..2 * LEAF_CAP {
        for &x in &anchor {
            inserts.push(x + (rng.f32() - 0.5) * 1e-3);
        }
    }
    let batch = UpdateBatch { deletes: Vec::new(), inserts };
    layered_roundtrip(&ds, &batch, Some(false), "leaf-cap overflow resplit");
}

/// An all-duplicate insert batch (identical coordinates, repeated) must not
/// diverge — unsplittable piles stop at the depth cap on both sides.
#[test]
fn all_duplicate_insert_batch_matches_from_scratch() {
    let ds = SynthSpec::blobs(400, 3, 4, 617).generate();
    let tree = BoxTree::build_par(&ds, LEAF_CAP, MAX_DEPTH, 2);
    let members = interior_leaf_members(&tree, &ds);
    let anchor = ds.row(members[0]).to_vec();
    let mut inserts = Vec::new();
    for _ in 0..10 {
        inserts.extend_from_slice(&anchor);
    }
    let batch = UpdateBatch { deletes: Vec::new(), inserts };
    layered_roundtrip(&ds, &batch, Some(false), "all-duplicate inserts");
}

/// Update-then-query on a stale epoch handle: the snapshot keeps answering
/// bit-for-bit after later publishes replace it.
#[test]
fn stale_epoch_handle_is_bit_stable_across_publishes() {
    let ds = SynthSpec::blobs(400, 3, 4, 619).generate();
    let upd = UpdatableEngine::build(ds.clone(), cfg(2), profile);
    let stale = upd.acquire();
    let n0 = stale.value.engine.csb.rows;
    let mut rng = Rng::new(620);
    let x: Vec<f32> = (0..n0).map(|_| rng.f32() - 0.5).collect();
    let mut y0 = vec![0.0f32; n0];
    stale.value.engine.spmv(&x, &mut y0);
    for round in 0..3 {
        let cur = upd.acquire();
        let b = gen_batch(&cur.value.ds, &mut rng, round);
        drop(cur);
        upd.update(&b);
    }
    assert_eq!(stale.version, 0);
    let mut y1 = vec![0.0f32; n0];
    stale.value.engine.spmv(&x, &mut y1);
    assert!(
        y0.iter().zip(&y1).all(|(a, b)| a.to_bits() == b.to_bits()),
        "stale handle drifted from its snapshot"
    );
}
