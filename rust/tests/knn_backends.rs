//! kNN backend coverage: `KnnGraph` invariants for both backends
//! (property-tested), ANN recall vs exact on the clustered 4k-point
//! acceptance dataset, thread-count determinism, and the ordering-pipeline
//! acceptance check (an ANN-built profile must score within 10% of the
//! exact backend's γ on the same dataset).

use nni::data::synth::SynthSpec;
use nni::knn::ann::{knn_graph_ann, AnnParams};
use nni::knn::exact::{knn_graph, KnnGraph};
use nni::knn::KnnBackend;
use nni::order::Pipeline;
use nni::prelude::Dataset;
use nni::profile::gamma;
use nni::prop_assert;
use nni::util::prop::check_with;

/// The KnnGraph contract: bounds, no self loops, no duplicates, ascending
/// distances that match the data.
fn graph_invariants(ds: &Dataset, g: &KnnGraph, n: usize, k: usize) -> Result<(), String> {
    prop_assert!(g.n == n && g.k == k, "shape {}x{} != {n}x{k}", g.n, g.k);
    for i in 0..n {
        let nb = g.neighbors(i);
        let dd = g.distances(i);
        let mut sorted = nb.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        prop_assert!(sorted.len() == k, "row {i}: duplicate neighbors");
        for (&j, &d) in nb.iter().zip(dd) {
            prop_assert!((j as usize) < n, "row {i}: index {j} out of bounds");
            prop_assert!(j as usize != i, "row {i}: self neighbor");
            let want = ds.sqdist(i, j as usize);
            prop_assert!(
                (d - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "row {i}: stored dist {d} != computed {want}"
            );
        }
        for w in dd.windows(2) {
            prop_assert!(w[0] <= w[1], "row {i}: distances not ascending");
        }
    }
    Ok(())
}

#[test]
fn knn_graph_invariants_hold_for_both_backends() {
    check_with("knn-invariants", 24, 160, |rng, size| {
        let n = 16 + rng.below(size);
        let d = 2 + rng.below(6);
        let k = 1 + rng.below(8);
        let ds = SynthSpec::blobs(n, d, 3, rng.next_u64()).generate();
        let ann = KnnBackend::Ann(AnnParams {
            trees: 4,
            leaf_cap: 16,
            descent_iters: 4,
            ..AnnParams::default()
        });
        for backend in [KnnBackend::Exact, ann] {
            let g = backend.build(&ds, k, 2);
            graph_invariants(&ds, &g, n, k)?;
        }
        Ok(())
    });
}

#[test]
fn ann_threads_do_not_change_the_graph() {
    let ds = SynthSpec::sift_like(1500, 3).generate();
    let p = AnnParams::default();
    let a = knn_graph_ann(&ds, 8, &p, 1);
    let b = knn_graph_ann(&ds, 8, &p, 8);
    assert_eq!(a.idx, b.idx);
    assert_eq!(a.dist2, b.dist2);
}

/// Acceptance: recall@10 ≥ 0.90 vs exact on a 4k-point clustered dataset
/// (default AnnParams land ≈ 0.97; the margin absorbs seed variation).
#[test]
fn ann_recall_at_10_exceeds_090_on_clustered_4k() {
    let ds = SynthSpec::sift_like(4096, 7).generate();
    let k = 10;
    let approx = knn_graph_ann(&ds, k, &AnnParams::default(), 0);
    let exact = knn_graph(&ds, k, 0);
    let mut hits = 0usize;
    for i in 0..ds.n() {
        let mut truth = exact.neighbors(i).to_vec();
        truth.sort_unstable();
        for &j in approx.neighbors(i) {
            if truth.binary_search(&j).is_ok() {
                hits += 1;
            }
        }
    }
    let recall = hits as f64 / (ds.n() * k) as f64;
    assert!(recall >= 0.90, "ann recall@10 = {recall:.4} < 0.90");
}

/// Acceptance: the ANN-built profile must order essentially as well as the
/// exact one — γ within 10% on the same dataset (the embedding, tree, and
/// permutation are identical; only profile edges differ).
#[test]
fn ann_ordering_gamma_within_10pct_of_exact() {
    let ds = SynthSpec::sift_like(4096, 11).generate();
    let k = 10;
    let sigma = k as f64 / 2.0;
    let score = |backend: KnnBackend| {
        let r = Pipeline::dual_tree(3).with_knn(backend).run_points(&ds, k, 0);
        gamma::gamma_fast(&r.reordered, sigma)
    };
    let g_exact = score(KnnBackend::Exact);
    let g_ann = score(KnnBackend::ann_default());
    let rel = (g_exact - g_ann).abs() / g_exact;
    assert!(
        rel <= 0.10,
        "gamma exact {g_exact:.2} vs ann {g_ann:.2} (rel diff {rel:.3})"
    );
}
