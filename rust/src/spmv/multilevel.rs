//! Multi-level blocked SpMV/SpMM over [`HierCsb`], sequential and parallel.
//!
//! Parallel discipline (§2.4 "multi-core environments"): each **target
//! leaf** is owned by exactly one task — all blocks writing a given
//! potential segment run on one worker, so no atomics or locks are needed
//! on `y`, and per-target block order is fixed → results are deterministic
//! regardless of thread count.  Tasks are claimed dynamically in chunks to
//! balance the irregular per-leaf work.

use crate::csb::hier::HierCsb;
use crate::par::pool::{SendPtr, ThreadPool};

/// Sequential multi-level SpMV (delegates to the stored traversal order).
pub fn spmv_ml_seq(m: &HierCsb, x: &[f32], y: &mut [f32]) {
    m.spmv(x, y);
}

/// Parallel multi-level SpMV with target-leaf ownership.
pub fn spmv_ml_par(m: &HierCsb, x: &[f32], y: &mut [f32], threads: usize) {
    assert_eq!(x.len(), m.cols);
    assert_eq!(y.len(), m.rows);
    y.fill(0.0);
    let pool = ThreadPool::new(threads);
    let yp = SendPtr(y.as_mut_ptr());
    let ylen = y.len();
    let ypr = &yp;
    pool.for_each_chunked(m.by_target.len(), 4, |tl| {
        // SAFETY: this task exclusively owns the row span of target leaf
        // `tl`; all blocks below write only inside that span.
        let yall: &mut [f32] = unsafe { std::slice::from_raw_parts_mut(ypr.0, ylen) };
        for &t in &m.by_target[tl] {
            m.block_matvec(t as usize, x, yall);
        }
    });
}

/// Sequential multi-level SpMM: `Y = A X` with `k` RHS columns (`x`:
/// `cols x k` row-major, `y`: `rows x k`).  At `k = 1` this is bit-exact
/// with [`spmv_ml_seq`] (see [`HierCsb::block_matmul`]).
pub fn spmm_ml_seq(m: &HierCsb, x: &[f32], y: &mut [f32], k: usize) {
    m.spmm(x, y, k);
}

/// Parallel multi-level SpMM under the same target-leaf ownership
/// discipline as [`spmv_ml_par`]: each task owns a whole `leaf_rows x k`
/// output panel, per-target block order is fixed, so results are bit-exact
/// equal to [`spmm_ml_seq`] regardless of thread count.
pub fn spmm_ml_par(m: &HierCsb, x: &[f32], y: &mut [f32], k: usize, threads: usize) {
    assert!(k >= 1, "spmm needs at least one RHS column");
    assert_eq!(x.len(), m.cols * k);
    assert_eq!(y.len(), m.rows * k);
    y.fill(0.0);
    let pool = ThreadPool::new(threads);
    let yp = SendPtr(y.as_mut_ptr());
    let ylen = y.len();
    let ypr = &yp;
    pool.for_each_chunked(m.by_target.len(), 4, |tl| {
        // SAFETY: this task exclusively owns the row panel of target leaf
        // `tl`; all blocks below write only inside rows.lo*k..rows.hi*k.
        let yall: &mut [f32] = unsafe { std::slice::from_raw_parts_mut(ypr.0, ylen) };
        for &t in &m.by_target[tl] {
            m.block_matmul(t as usize, x, yall, k);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::knn::exact::knn_graph;
    use crate::order::Pipeline;
    use crate::sparse::csr::Csr;
    use crate::util::rng::Rng;

    fn setup(n: usize) -> (Csr, HierCsb) {
        let ds = SynthSpec::blobs(n, 3, 5, 13).generate();
        let g = knn_graph(&ds, 8, 2);
        let a = Csr::from_knn(&g, n).symmetrized();
        let r = Pipeline::dual_tree(3).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        let csb = HierCsb::build(&r.reordered, tree, tree, 32);
        (r.reordered, csb)
    }

    #[test]
    fn parallel_matches_sequential_exactly() {
        let (a, m) = setup(700);
        let mut rng = Rng::new(8);
        let x: Vec<f32> = (0..a.cols).map(|_| rng.f32()).collect();
        let mut y1 = vec![0.0f32; a.rows];
        let mut y2 = vec![0.0f32; a.rows];
        spmv_ml_seq(&m, &x, &mut y1);
        for threads in [1, 2, 4, 8] {
            spmv_ml_par(&m, &x, &mut y2, threads);
            assert_eq!(y1, y2, "threads={threads}");
        }
    }

    #[test]
    fn matches_csr_reference() {
        let (a, m) = setup(400);
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..a.cols).map(|_| rng.f32()).collect();
        let want = a.matvec_ref(&x);
        let mut got = vec![0.0f32; a.rows];
        spmv_ml_par(&m, &x, &mut got, 4);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()));
        }
    }

    #[test]
    fn spmm_parallel_matches_sequential_exactly() {
        let (a, m) = setup(600);
        let mut rng = Rng::new(10);
        for k in [1usize, 3, 8] {
            let x: Vec<f32> = (0..a.cols * k).map(|_| rng.f32()).collect();
            let mut y1 = vec![0.0f32; a.rows * k];
            let mut y2 = vec![0.0f32; a.rows * k];
            spmm_ml_seq(&m, &x, &mut y1, k);
            for threads in [1, 2, 4, 8] {
                spmm_ml_par(&m, &x, &mut y2, k, threads);
                assert_eq!(y1, y2, "k={k} threads={threads}");
            }
        }
    }

    #[test]
    fn spmm_k1_bitexact_with_spmv() {
        let (a, m) = setup(500);
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..a.cols).map(|_| rng.f32()).collect();
        let mut y1 = vec![0.0f32; a.rows];
        let mut y2 = vec![0.0f32; a.rows];
        spmv_ml_seq(&m, &x, &mut y1);
        spmm_ml_seq(&m, &x, &mut y2, 1);
        assert_eq!(y1, y2);
    }

    #[test]
    fn spmm_matches_csr_reference_per_column() {
        let (a, m) = setup(350);
        let mut rng = Rng::new(12);
        let k = 5;
        let x: Vec<f32> = (0..a.cols * k).map(|_| rng.f32()).collect();
        let mut y = vec![0.0f32; a.rows * k];
        spmm_ml_par(&m, &x, &mut y, k, 4);
        for j in 0..k {
            let xj: Vec<f32> = (0..a.cols).map(|i| x[i * k + j]).collect();
            let want = a.matvec_ref(&xj);
            for i in 0..a.rows {
                let g = y[i * k + j];
                let w = want[i];
                assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "col {j}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn repeated_calls_reuse_buffers() {
        let (a, m) = setup(300);
        let x = vec![1.0f32; a.cols];
        let mut y = vec![0.0f32; a.rows];
        spmv_ml_par(&m, &x, &mut y, 4);
        let first = y.clone();
        spmv_ml_par(&m, &x, &mut y, 4);
        assert_eq!(first, y); // y is overwritten, not accumulated
    }
}
