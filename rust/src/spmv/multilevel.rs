//! Multi-level blocked SpMV/SpMM over [`HierCsb`], sequential and parallel.
//!
//! Parallel discipline (§2.4 "multi-core environments"): each **target
//! leaf** is owned by exactly one task — all blocks writing a given
//! potential segment run on one worker, so no atomics or locks are needed
//! on `y`, and per-target block order is fixed → results are deterministic
//! regardless of thread count.  Tasks are claimed dynamically in chunks to
//! balance the irregular per-leaf work.

use crate::csb::hier::HierCsb;
use crate::csb::kernel::Dispatch;
use crate::par::pool::{SendPtr, ThreadPool};

/// The multilevel traversal precompiled into target-leaf-owned flat task
/// lists — the apply-side schedule the engine stores and reuses, instead
/// of re-deriving per-apply state (nested `by_target` walks, per-task
/// scratch setup) on every `spmm`/kernel call.
///
/// * one task per non-empty target leaf = the ownership coloring (all
///   writes to a leaf's output rows happen on the task that owns it), so
///   results are bit-identical for any worker count *within* a kernel
///   choice;
/// * `block_ids` is one flat array, grouped per task in multilevel
///   traversal order — no per-leaf `Vec` indirection on the hot path;
/// * tasks are ordered heaviest-first (by nnz, ties by leaf ordinal), so
///   the dynamic chunk claim schedules the long poles early.
#[derive(Clone, Debug)]
pub struct ApplySchedule {
    /// Block indices, grouped per task, multilevel order within each task.
    pub block_ids: Vec<u32>,
    pub tasks: Vec<ApplyTask>,
    /// Schedule-static profile totals, precomputed once so an apply call
    /// feeds the `obs` counters with one `fetch_add` per quantity instead
    /// of one per block: dense cells touched (`Σ rows·cols` over dense
    /// blocks), stored sparse nnz, and packed panel bytes per RHS sweep.
    pub dense_cells: u64,
    pub sparse_nnz: u64,
    pub panel_bytes: u64,
}

/// One schedule task: a target leaf and its span into
/// [`ApplySchedule::block_ids`].
#[derive(Clone, Copy, Debug)]
pub struct ApplyTask {
    /// Target-leaf ordinal (owner of the output row span).
    pub tleaf: u32,
    pub lo: u32,
    pub hi: u32,
}

impl ApplySchedule {
    pub fn build(m: &HierCsb) -> ApplySchedule {
        let work: Vec<u64> = m
            .by_target
            .iter()
            .map(|list| list.iter().map(|&t| m.blocks[t as usize].nnz as u64).sum())
            .collect();
        let mut order: Vec<usize> = (0..m.by_target.len()).collect();
        order.sort_by_key(|&tl| (std::cmp::Reverse(work[tl]), tl));
        let mut block_ids = Vec::with_capacity(m.blocks.len());
        let mut tasks = Vec::new();
        for &tl in &order {
            if m.by_target[tl].is_empty() {
                continue;
            }
            let lo = block_ids.len() as u32;
            block_ids.extend_from_slice(&m.by_target[tl]);
            tasks.push(ApplyTask {
                tleaf: tl as u32,
                lo,
                hi: block_ids.len() as u32,
            });
        }
        let (mut dense_cells, mut sparse_nnz, mut panel_bytes) = (0u64, 0u64, 0u64);
        for b in &m.blocks {
            if b.is_dense() {
                dense_cells += b.rows.len() as u64 * b.cols.len() as u64;
                panel_bytes +=
                    crate::csb::panel::panel_len(b.rows.len(), b.cols.len()) as u64 * 4;
            } else {
                sparse_nnz += b.nnz as u64;
            }
        }
        ApplySchedule {
            block_ids,
            tasks,
            dense_cells,
            sparse_nnz,
            panel_bytes,
        }
    }

    /// Fused-multiply-add flop count of one apply sweep with `k` RHS
    /// columns over this schedule (2 flops per stored cell/nnz per column).
    #[inline]
    pub fn flops(&self, k: usize) -> u64 {
        2 * (self.dense_cells + self.sparse_nnz) * k as u64
    }

    /// The block list of one task.
    #[inline]
    pub fn blocks_of(&self, task: &ApplyTask) -> &[u32] {
        &self.block_ids[task.lo as usize..task.hi as usize]
    }
}

/// Sequential multi-level SpMV (delegates to the stored traversal order).
pub fn spmv_ml_seq(m: &HierCsb, x: &[f32], y: &mut [f32]) {
    m.spmv(x, y);
}

/// Parallel multi-level SpMV with target-leaf ownership.
pub fn spmv_ml_par(m: &HierCsb, x: &[f32], y: &mut [f32], threads: usize) {
    assert_eq!(x.len(), m.cols);
    assert_eq!(y.len(), m.rows);
    y.fill(0.0);
    let pool = ThreadPool::new(threads);
    let yp = SendPtr(y.as_mut_ptr());
    let ylen = y.len();
    let ypr = &yp;
    pool.for_each_chunked(m.by_target.len(), 4, |tl| {
        // SAFETY: this task exclusively owns the row span of target leaf
        // `tl`; all blocks below write only inside that span.
        let yall: &mut [f32] = unsafe { std::slice::from_raw_parts_mut(ypr.0, ylen) };
        for &t in &m.by_target[tl] {
            m.block_matvec(t as usize, x, yall);
        }
    });
}

/// Sequential multi-level SpMM: `Y = A X` with `k` RHS columns (`x`:
/// `cols x k` row-major, `y`: `rows x k`).  At `k = 1` this is bit-exact
/// with [`spmv_ml_seq`] (see [`HierCsb::block_matmul`]).
pub fn spmm_ml_seq(m: &HierCsb, x: &[f32], y: &mut [f32], k: usize) {
    m.spmm(x, y, k);
}

/// [`spmm_ml_seq`] under an explicit kernel dispatch (`Scalar` reproduces
/// it bit-for-bit; `Avx2` runs the SIMD micro-kernels).
pub fn spmm_ml_seq_with(m: &HierCsb, x: &[f32], y: &mut [f32], k: usize, d: Dispatch) {
    assert!(k >= 1, "spmm needs at least one RHS column");
    assert_eq!(x.len(), m.cols * k);
    assert_eq!(y.len(), m.rows * k);
    y.fill(0.0);
    for t in 0..m.blocks.len() {
        m.block_matmul_with(t, x, y, k, d);
    }
}

/// Parallel multi-level SpMM under the same target-leaf ownership
/// discipline as [`spmv_ml_par`]: each task owns a whole `leaf_rows x k`
/// output panel, per-target block order is fixed, so results are bit-exact
/// equal to [`spmm_ml_seq`] regardless of thread count.
pub fn spmm_ml_par(m: &HierCsb, x: &[f32], y: &mut [f32], k: usize, threads: usize) {
    spmm_ml_par_with(m, x, y, k, threads, Dispatch::Scalar)
}

/// [`spmm_ml_par`] under an explicit kernel dispatch.  Thread-count
/// bit-identity holds *within* a dispatch choice (per-leaf block order is
/// fixed either way); the Avx2 path matches the scalar path to relative
/// tolerance only (FMA contraction — see `csb::kernel`).
pub fn spmm_ml_par_with(
    m: &HierCsb,
    x: &[f32],
    y: &mut [f32],
    k: usize,
    threads: usize,
    d: Dispatch,
) {
    assert!(k >= 1, "spmm needs at least one RHS column");
    assert_eq!(x.len(), m.cols * k);
    assert_eq!(y.len(), m.rows * k);
    y.fill(0.0);
    let pool = ThreadPool::new(threads);
    let yp = SendPtr(y.as_mut_ptr());
    let ypr = &yp;
    pool.for_each_chunked(m.by_target.len(), 4, |tl| {
        let sp = m.tgt_leaves[tl];
        // SAFETY: this task exclusively owns the row panel of target leaf
        // `tl`; the slice covers only that disjoint span.
        let seg: &mut [f32] = unsafe {
            std::slice::from_raw_parts_mut(ypr.0.add(sp.lo as usize * k), sp.len() * k)
        };
        for &t in &m.by_target[tl] {
            m.block_matmul_seg_with(t as usize, x, seg, k, d);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::knn::exact::knn_graph;
    use crate::order::Pipeline;
    use crate::sparse::csr::Csr;
    use crate::util::rng::Rng;

    fn setup(n: usize) -> (Csr, HierCsb) {
        let ds = SynthSpec::blobs(n, 3, 5, 13).generate();
        let g = knn_graph(&ds, 8, 2);
        let a = Csr::from_knn(&g, n).symmetrized();
        let r = Pipeline::dual_tree(3).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        let csb = HierCsb::build(&r.reordered, tree, tree, 32);
        (r.reordered, csb)
    }

    #[test]
    fn parallel_matches_sequential_exactly() {
        let (a, m) = setup(700);
        let mut rng = Rng::new(8);
        let x: Vec<f32> = (0..a.cols).map(|_| rng.f32()).collect();
        let mut y1 = vec![0.0f32; a.rows];
        let mut y2 = vec![0.0f32; a.rows];
        spmv_ml_seq(&m, &x, &mut y1);
        for threads in [1, 2, 4, 8] {
            spmv_ml_par(&m, &x, &mut y2, threads);
            assert_eq!(y1, y2, "threads={threads}");
        }
    }

    #[test]
    fn matches_csr_reference() {
        let (a, m) = setup(400);
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..a.cols).map(|_| rng.f32()).collect();
        let want = a.matvec_ref(&x);
        let mut got = vec![0.0f32; a.rows];
        spmv_ml_par(&m, &x, &mut got, 4);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()));
        }
    }

    #[test]
    fn spmm_parallel_matches_sequential_exactly() {
        let (a, m) = setup(600);
        let mut rng = Rng::new(10);
        for k in [1usize, 3, 8] {
            let x: Vec<f32> = (0..a.cols * k).map(|_| rng.f32()).collect();
            let mut y1 = vec![0.0f32; a.rows * k];
            let mut y2 = vec![0.0f32; a.rows * k];
            spmm_ml_seq(&m, &x, &mut y1, k);
            for threads in [1, 2, 4, 8] {
                spmm_ml_par(&m, &x, &mut y2, k, threads);
                assert_eq!(y1, y2, "k={k} threads={threads}");
            }
        }
    }

    #[test]
    fn spmm_k1_bitexact_with_spmv() {
        let (a, m) = setup(500);
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..a.cols).map(|_| rng.f32()).collect();
        let mut y1 = vec![0.0f32; a.rows];
        let mut y2 = vec![0.0f32; a.rows];
        spmv_ml_seq(&m, &x, &mut y1);
        spmm_ml_seq(&m, &x, &mut y2, 1);
        assert_eq!(y1, y2);
    }

    #[test]
    fn spmm_matches_csr_reference_per_column() {
        let (a, m) = setup(350);
        let mut rng = Rng::new(12);
        let k = 5;
        let x: Vec<f32> = (0..a.cols * k).map(|_| rng.f32()).collect();
        let mut y = vec![0.0f32; a.rows * k];
        spmm_ml_par(&m, &x, &mut y, k, 4);
        for j in 0..k {
            let xj: Vec<f32> = (0..a.cols).map(|i| x[i * k + j]).collect();
            let want = a.matvec_ref(&xj);
            for i in 0..a.rows {
                let g = y[i * k + j];
                let w = want[i];
                assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "col {j}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn apply_schedule_covers_all_blocks_heaviest_first() {
        let (_, m) = setup(600);
        let sched = ApplySchedule::build(&m);
        // every block appears exactly once, under its owning target leaf
        let mut seen = vec![false; m.blocks.len()];
        for task in &sched.tasks {
            for &t in sched.blocks_of(task) {
                assert!(!seen[t as usize], "block {t} scheduled twice");
                seen[t as usize] = true;
                assert_eq!(m.blocks[t as usize].tleaf, task.tleaf);
            }
        }
        assert!(seen.iter().all(|&s| s), "schedule missed a block");
        // heaviest-first task order (ties by leaf ordinal)
        let work = |task: &ApplyTask| -> u64 {
            sched.blocks_of(task).iter().map(|&t| m.blocks[t as usize].nnz as u64).sum()
        };
        for w in sched.tasks.windows(2) {
            let (a, b) = (work(&w[0]), work(&w[1]));
            assert!(a > b || (a == b && w[0].tleaf < w[1].tleaf), "{a} then {b}");
        }
    }

    #[test]
    fn apply_schedule_static_totals_match_blocks() {
        let (_, m) = setup(500);
        let sched = ApplySchedule::build(&m);
        let dense: u64 = m
            .blocks
            .iter()
            .filter(|b| b.is_dense())
            .map(|b| b.rows.len() as u64 * b.cols.len() as u64)
            .sum();
        let sparse: u64 = m
            .blocks
            .iter()
            .filter(|b| !b.is_dense())
            .map(|b| b.nnz as u64)
            .sum();
        assert_eq!(sched.dense_cells, dense);
        assert_eq!(sched.sparse_nnz, sparse);
        assert_eq!(sched.flops(3), 2 * (dense + sparse) * 3);
        // the packed panel arena is exactly the dense blocks' panels
        assert_eq!(sched.panel_bytes, m.panels.data.as_slice().len() as u64 * 4);
    }

    #[test]
    fn dispatch_variants_agree_with_scalar_reference() {
        use crate::csb::kernel::KernelKind;
        let (a, m) = setup(500);
        let mut rng = Rng::new(13);
        let k = 5;
        let x: Vec<f32> = (0..a.cols * k).map(|_| rng.f32() - 0.5).collect();
        let mut y_ref = vec![0.0f32; a.rows * k];
        spmm_ml_seq(&m, &x, &mut y_ref, k);
        // Scalar dispatch is the same code path bit-for-bit.
        let mut y = vec![0.0f32; a.rows * k];
        spmm_ml_seq_with(&m, &x, &mut y, k, Dispatch::Scalar);
        assert_eq!(y, y_ref);
        // Whatever Auto resolves to on this CPU: tolerance parity, and
        // bit-identical across thread counts within the choice.
        let (d, _) = KernelKind::Auto.resolve();
        spmm_ml_seq_with(&m, &x, &mut y, k, d);
        for (g, w) in y.iter().zip(&y_ref) {
            assert!((g - w).abs() < 1e-5 * (1.0 + w.abs()), "{g} vs {w}");
        }
        let seq = y.clone();
        for threads in [1, 2, 8] {
            spmm_ml_par_with(&m, &x, &mut y, k, threads, d);
            assert_eq!(y, seq, "threads={threads}");
        }
    }

    #[test]
    fn repeated_calls_reuse_buffers() {
        let (a, m) = setup(300);
        let x = vec![1.0f32; a.cols];
        let mut y = vec![0.0f32; a.rows];
        spmv_ml_par(&m, &x, &mut y, 4);
        let first = y.clone();
        spmv_ml_par(&m, &x, &mut y, 4);
        assert_eq!(first, y); // y is overwritten, not accumulated
    }
}
