//! Baseline CSR SpMV, sequential and parallel — the stand-in for the
//! paper's MKL_CSC_MV reference (§4.1).  Written for the hot path: no
//! allocation per call, 4-way unrolled accumulation, static row split in
//! parallel mode.

use crate::par::pool::{parallel_for, SendPtr};
use crate::sparse::csr::Csr;

/// y = A x, sequential.
pub fn spmv_seq(a: &Csr, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), a.cols);
    assert_eq!(y.len(), a.rows);
    for i in 0..a.rows {
        let lo = a.ptr[i] as usize;
        let hi = a.ptr[i + 1] as usize;
        y[i] = row_dot(&a.col[lo..hi], &a.val[lo..hi], x);
    }
}

/// y = A x, parallel over a static row split.
pub fn spmv_par(a: &Csr, x: &[f32], y: &mut [f32], threads: usize) {
    assert_eq!(x.len(), a.cols);
    assert_eq!(y.len(), a.rows);
    // SAFETY-free approach: share y through a raw pointer wrapper; the row
    // ranges are disjoint so writes never alias.
    let yp = SendPtr(y.as_mut_ptr());
    parallel_for(threads, a.rows, |range| {
        let base = &yp;
        for i in range {
            let lo = a.ptr[i] as usize;
            let hi = a.ptr[i + 1] as usize;
            let v = row_dot(&a.col[lo..hi], &a.val[lo..hi], x);
            // disjoint by construction
            unsafe { *base.0.add(i) = v };
        }
    });
}

#[inline]
fn row_dot(cols: &[u32], vals: &[f32], x: &[f32]) -> f32 {
    let n = cols.len();
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let mut t = 0;
    while t + 4 <= n {
        acc0 += vals[t] * x[cols[t] as usize];
        acc1 += vals[t + 1] * x[cols[t + 1] as usize];
        acc2 += vals[t + 2] * x[cols[t + 2] as usize];
        acc3 += vals[t + 3] * x[cols[t + 3] as usize];
        t += 4;
    }
    let mut acc = (acc0 + acc1) + (acc2 + acc3);
    while t < n {
        acc += vals[t] * x[cols[t] as usize];
        t += 1;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::util::rng::Rng;

    #[test]
    fn seq_matches_reference() {
        let a = gen::scattered(200, 7, 1);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..200).map(|_| rng.f32()).collect();
        let want = a.matvec_ref(&x);
        let mut got = vec![0.0f32; 200];
        spmv_seq(&a, &x, &mut got);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()));
        }
    }

    #[test]
    fn par_matches_seq() {
        let a = gen::banded(500, 9, 3);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..500).map(|_| rng.f32()).collect();
        let mut y1 = vec![0.0f32; 500];
        let mut y2 = vec![0.0f32; 500];
        spmv_seq(&a, &x, &mut y1);
        spmv_par(&a, &x, &mut y2, 8);
        assert_eq!(y1, y2); // identical row computations → bit-equal
    }

    #[test]
    fn empty_rows_are_zero() {
        let a = Csr::from_triplets(3, 3, &[0], &[0], &[5.0]);
        let mut y = vec![9.0f32; 3];
        spmv_seq(&a, &[1.0, 1.0, 1.0], &mut y);
        assert_eq!(y, vec![5.0, 0.0, 0.0]);
    }

    use crate::sparse::csr::Csr;
}
