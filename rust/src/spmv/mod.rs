//! Sparse matrix-vector multiplication engines: baseline CSR (the MKL
//! stand-in of §4.1) and the multi-level blocked engine over [`HierCsb`].
//!
//! [`HierCsb`]: crate::csb::hier::HierCsb

pub mod csr;
pub mod multilevel;
