//! Lock-free log-linear latency histograms (HDR-style) for the serving
//! tier: fixed arrays of relaxed atomics, mergeable snapshots, and
//! bounded-relative-error quantile queries.
//!
//! **Bucket layout.**  Values below `2^LOW_BITS` (= [`SUB_BUCKETS`]) get
//! one bucket each (exact).  Above that, every power-of-two octave is
//! split into [`SUB_BUCKETS`] linear sub-buckets, so a bucket spanning
//! `[lo, lo + w)` always has `w / lo <= 1/SUB_BUCKETS` — the quantile
//! error bound: a reported quantile lies in the same bucket as the exact
//! nearest-rank sample, hence within one bucket width (relative error
//! `<= 1/32` ≈ 3.1%) of it.  The one exception is the saturated top
//! octave — values `>= 2^63` (~292k years in µs) clamp into the last
//! bucket, see [`NBUCKETS`].  `rust/src/serve/loadgen.rs` pins the bound
//! against the exact nearest-rank oracle on seeded workloads.
//!
//! **Cost model.**  Recording is one enabled load, one bucket-index
//! computation (a `leading_zeros` and two shifts), and four relaxed
//! atomic RMWs — no locks, no allocation, safe on the steady-state
//! serve path (the `obs_overhead` bench keeps the serve round under its
//! 1.03 ratio with recording on).
//!
//! **Registry.**  One static histogram per serve stage — admission wait,
//! slate coalesce, per-shard compute ([`MAX_SHARD_HISTS`] slots, higher
//! shard ids fold in modulo), far apply, merge, end-to-end — surfaced by
//! `nni stats`, the metrics JSON, and `nni serve --stats-interval`.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// log2 of the per-octave sub-bucket count.
const LOW_BITS: u32 = 5;

/// Linear sub-buckets per octave; also the identity range `[0, 32)` where
/// buckets are exact.  `1/SUB_BUCKETS` is the relative quantile error
/// bound.
pub const SUB_BUCKETS: u64 = 1 << LOW_BITS;

/// Total buckets: the identity range plus 58 sub-divided octaves covers
/// `[0, 2^63)`; the top octave `[2^63, u64::MAX]` saturates into the
/// last bucket (its exact upper bound would overflow `u64`).
pub const NBUCKETS: usize = (64 - LOW_BITS as usize) * SUB_BUCKETS as usize;

/// Bucket index of a value (monotone non-decreasing in `v`, total over
/// `u64`: values at or above `2^63` clamp into the last bucket, so the
/// relative-error bound holds for all values below `2^63` — ~292k years
/// in µs — and degrades only in the saturated top octave).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < SUB_BUCKETS {
        return v as usize;
    }
    let top = 63 - v.leading_zeros(); // >= LOW_BITS
    let octave = top - LOW_BITS;
    let sub = (v >> (top - LOW_BITS)) & (SUB_BUCKETS - 1);
    (((octave as usize + 1) << LOW_BITS) + sub as usize).min(NBUCKETS - 1)
}

/// Half-open value range `[lo, hi)` of a bucket.
#[inline]
pub fn bucket_bounds(idx: usize) -> (u64, u64) {
    if idx < SUB_BUCKETS as usize {
        return (idx as u64, idx as u64 + 1);
    }
    let octave = (idx >> LOW_BITS) as u32 - 1;
    let sub = (idx as u64) & (SUB_BUCKETS - 1);
    let lo = (SUB_BUCKETS + sub) << octave;
    (lo, lo + (1u64 << octave))
}

/// One lock-free histogram: fixed bucket array of relaxed atomics plus
/// exact count/sum/max.  `new()` is const so stage histograms live in
/// static storage; local instances (the load generator) box one.
pub struct Hist {
    counts: [AtomicU64; NBUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Hist {
    pub const fn new() -> Hist {
        Hist {
            counts: [const { AtomicU64::new(0) }; NBUCKETS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value (relaxed; never allocates).
    #[inline]
    pub fn record(&self, v: u64) {
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Point-in-time copy (concurrent recording may make count/sum lag
    /// the buckets by in-flight updates; merges stay consistent).
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
            counts: self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
        }
    }

    /// Zero every bucket and the aggregates.
    pub fn clear(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

/// Mergeable plain-value copy of a [`Hist`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub max: u64,
    counts: Vec<u64>,
}

impl HistSnapshot {
    /// Fold another snapshot in (bucketwise add; max of maxes).
    pub fn merge(&mut self, other: &HistSnapshot) {
        if self.counts.is_empty() {
            self.counts = vec![0; NBUCKETS];
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    /// Nearest-rank quantile (`p` in percent): the midpoint of the bucket
    /// containing the rank-`ceil(p/100·count)` sample — the same bucket
    /// the exact sample falls in, so the estimate is within one bucket
    /// width (relative error `<= 1/SUB_BUCKETS`) of the exact value.
    /// Returns 0 when empty.
    pub fn quantile(&self, p: f64) -> u64 {
        let total: u64 = self.counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let rank = rank.min(total);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                let (lo, hi) = bucket_bounds(i);
                return lo + (hi - lo - 1) / 2;
            }
        }
        self.max
    }

    /// Mean of the recorded values (exact: sum/count).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

/// Serve-tier stages with a registered histogram (shard compute is
/// per-shard; see [`record_shard`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Submission → dispatcher pickup (queue wait).
    AdmissionWait,
    /// Dispatcher slate coalescing (first recv → slate dispatched).
    SlateCoalesce,
    /// Far-field apply over the merged buffer.
    FarApply,
    /// Row merge + per-request de-interleave and delivery.
    Merge,
    /// Request end-to-end as reported in `Response::elapsed_us`
    /// (virtual under `real_time: false`).
    EndToEnd,
}

const NSTAGES: usize = 5;

/// Per-shard compute histogram slots; shard ids fold in modulo.
pub const MAX_SHARD_HISTS: usize = 8;

static STAGE_NAMES: [&str; NSTAGES] = [
    "serve.admission_wait",
    "serve.slate_coalesce",
    "serve.far_apply",
    "serve.merge",
    "serve.e2e",
];

static SHARD_NAMES: [&str; MAX_SHARD_HISTS] = [
    "serve.shard_compute.0",
    "serve.shard_compute.1",
    "serve.shard_compute.2",
    "serve.shard_compute.3",
    "serve.shard_compute.4",
    "serve.shard_compute.5",
    "serve.shard_compute.6",
    "serve.shard_compute.7",
];

static STAGE_HISTS: [Hist; NSTAGES] = [const { Hist::new() }; NSTAGES];
static SHARD_HISTS: [Hist; MAX_SHARD_HISTS] = [const { Hist::new() }; MAX_SHARD_HISTS];
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Turn stage-histogram recording on or off (on by default; the
/// `obs_overhead` bench toggles it to price the instrumented path).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether stage histograms are currently recording.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Record a stage latency in µs.
#[inline]
pub fn record(stage: Stage, us: u64) {
    if ENABLED.load(Ordering::Relaxed) {
        STAGE_HISTS[stage as usize].record(us);
    }
}

/// Record one shard's compute latency in µs (slots fold modulo
/// [`MAX_SHARD_HISTS`]).
#[inline]
pub fn record_shard(shard: usize, us: u64) {
    if ENABLED.load(Ordering::Relaxed) {
        SHARD_HISTS[shard % MAX_SHARD_HISTS].record(us);
    }
}

/// Snapshot one stage histogram.
pub fn stage_snapshot(stage: Stage) -> HistSnapshot {
    STAGE_HISTS[stage as usize].snapshot()
}

/// Snapshot every registered histogram as `(export name, snapshot)`,
/// stage histograms first, then the occupied shard-compute slots.
pub fn snapshot_all() -> Vec<(&'static str, HistSnapshot)> {
    let mut out: Vec<(&'static str, HistSnapshot)> = STAGE_NAMES
        .iter()
        .zip(&STAGE_HISTS)
        .map(|(&n, h)| (n, h.snapshot()))
        .collect();
    for (&n, h) in SHARD_NAMES.iter().zip(&SHARD_HISTS) {
        let s = h.snapshot();
        if s.count > 0 {
            out.push((n, s));
        }
    }
    out
}

/// Zero every registered histogram (tests and CLI phase boundaries;
/// the enabled flag is left as-is).
pub fn reset() {
    for h in STAGE_HISTS.iter().chain(SHARD_HISTS.iter()) {
        h.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_monotone_contiguous_and_bounded() {
        // exact identity range
        for v in 0..SUB_BUCKETS {
            assert_eq!(bucket_index(v), v as usize);
            assert_eq!(bucket_bounds(v as usize), (v, v + 1));
        }
        // every bucket's bounds contain exactly the values that map to it
        let mut prev_hi = 0u64;
        for idx in 0..NBUCKETS {
            let (lo, hi) = bucket_bounds(idx);
            assert_eq!(lo, prev_hi, "buckets must tile without gaps at {idx}");
            assert_eq!(bucket_index(lo), idx);
            assert_eq!(bucket_index(hi - 1), idx);
            if idx < SUB_BUCKETS as usize {
                // identity range: unit-width, exact
                assert_eq!(hi - lo, 1, "identity bucket at {idx}");
            } else {
                // relative width bound: w/lo <= 1/SUB_BUCKETS
                assert!((hi - lo) * SUB_BUCKETS <= lo, "width bound at {idx}");
            }
            prev_hi = hi;
        }
        // the table tiles [0, 2^63) exactly
        assert_eq!(prev_hi, 1u64 << 63);
        // the top octave saturates into the last bucket
        assert_eq!(bucket_index((1u64 << 63) - 1), NBUCKETS - 1);
        assert_eq!(bucket_index(1u64 << 63), NBUCKETS - 1);
        assert_eq!(bucket_index(u64::MAX), NBUCKETS - 1);
        // recording extreme values must not panic
        let h = Box::new(Hist::new());
        h.record(u64::MAX);
        assert_eq!(h.snapshot().max, u64::MAX);
    }

    #[test]
    fn record_snapshot_quantile_merge() {
        let h = Box::new(Hist::new());
        for v in [0u64, 1, 1, 5, 40, 41, 1000, 100_000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 8);
        assert_eq!(s.max, 100_000);
        assert_eq!(s.sum, 101_088);
        // small values land in exact buckets: the quantile is exact
        assert_eq!(s.quantile(25.0), 1);
        // large values: within the bucket of the exact sample
        let q = s.quantile(100.0);
        let (lo, hi) = bucket_bounds(bucket_index(100_000));
        assert!(q >= lo && q < hi, "{q} not in [{lo},{hi})");
        // merging doubles every count
        let mut m = s.clone();
        m.merge(&h.snapshot());
        assert_eq!(m.count, 16);
        assert_eq!(m.quantile(25.0), 1);
        h.clear();
        assert_eq!(h.snapshot().count, 0);
        assert_eq!(h.snapshot().quantile(50.0), 0);
    }

    #[test]
    fn stage_registry_records_and_resets() {
        // global registry: other tests may record concurrently, so
        // assertions are monotonic on a private-ish stage pair
        let before = stage_snapshot(Stage::FarApply).count;
        record(Stage::FarApply, 17);
        record_shard(3, 250);
        record_shard(MAX_SHARD_HISTS + 3, 250); // folds into slot 3
        assert!(stage_snapshot(Stage::FarApply).count >= before + 1);
        let all = snapshot_all();
        assert!(all.iter().any(|(n, _)| *n == "serve.far_apply"));
        let shard3 = all
            .iter()
            .find(|(n, _)| *n == "serve.shard_compute.3")
            .expect("occupied shard slot exported");
        assert!(shard3.1.count >= 2);
    }

    #[test]
    fn disabled_recording_is_inert() {
        set_enabled(false);
        let before = stage_snapshot(Stage::SlateCoalesce).count;
        record(Stage::SlateCoalesce, 9);
        assert_eq!(stage_snapshot(Stage::SlateCoalesce).count, before);
        set_enabled(true);
    }
}
