//! Exporters over the observability registry: Chrome trace-event JSON
//! (loadable in `chrome://tracing` / Perfetto), a flat metrics snapshot,
//! and the human `nni stats` report.

use crate::obs::counters::Snapshot;
use crate::obs::trace::SpanRec;
use crate::util::json::{self, num, obj, s, Json};

/// Spans → Chrome trace-event JSON: one `"ph": "X"` *complete* event per
/// span (start + duration in µs), worker slot as the `tid` — the form both
/// `chrome://tracing` and Perfetto load without a metadata preamble.
///
/// Request-scoped spans (`req != 0`, the serve tier) additionally emit
/// Chrome **flow events** (`ph` `"s"`/`"t"`/`"f"`, one shared `id` per
/// request) tying a request's stages together across the dispatcher and
/// shard tracks, so a deadline miss reads as one connected arrow chain.
pub fn chrome_trace(spans: &[SpanRec]) -> Json {
    let mut events: Vec<Json> = spans
        .iter()
        .map(|sp| {
            obj(vec![
                ("name", s(sp.name)),
                ("ph", s("X")),
                ("ts", num(sp.t0_us as f64)),
                ("dur", num(sp.t1_us.saturating_sub(sp.t0_us) as f64)),
                ("pid", num(1.0)),
                ("tid", num(sp.worker as f64)),
            ])
        })
        .collect();
    let mut flows: std::collections::BTreeMap<u64, Vec<&SpanRec>> =
        std::collections::BTreeMap::new();
    for sp in spans.iter().filter(|sp| sp.req != 0) {
        flows.entry(sp.req).or_default().push(sp);
    }
    for (req, mut stages) in flows {
        if stages.len() < 2 {
            continue; // a flow needs at least a start and an end
        }
        stages.sort_by_key(|sp| sp.t0_us);
        let last = stages.len() - 1;
        for (i, sp) in stages.iter().enumerate() {
            let ph = if i == 0 {
                "s"
            } else if i == last {
                "f"
            } else {
                "t"
            };
            let mut fields = vec![
                ("name", s("serve.request")),
                ("cat", s("serve")),
                ("ph", s(ph)),
                ("ts", num(sp.t0_us as f64)),
                ("pid", num(1.0)),
                ("tid", num(sp.worker as f64)),
                ("id", num(req as f64)),
            ];
            if ph == "f" {
                // bind the flow end to the enclosing slice, not the next one
                fields.push(("bp", s("e")));
            }
            events.push(obj(fields));
        }
    }
    Json::Arr(events)
}

/// Counter snapshot → flat metrics JSON: raw counters, derived ratios
/// (the paper's profile measure), the per-level fill table, and a
/// summary of every occupied serve-stage latency histogram (count /
/// p50 / p99 / max / mean in µs; the histograms are process-global, so
/// this section reflects the live registry, not `snap`).
pub fn metrics_json(snap: &Snapshot) -> Json {
    let counters = obj(snap
        .counters
        .iter()
        .map(|&(name, v)| (name, num(v as f64)))
        .collect());
    let derived = obj(vec![
        ("apply.worker_imbalance", num(snap.worker_imbalance())),
        ("serve.shard_imbalance", num(snap.shard_imbalance())),
        ("aca.mean_rank", num(snap.mean_aca_rank())),
        ("csb.covered_fraction", num(snap.covered_fraction())),
        ("csb.dense_fill_ratio", num(snap.dense_fill_ratio())),
    ]);
    let hists = obj(crate::obs::hist::snapshot_all()
        .into_iter()
        .filter(|(_, h)| h.count > 0)
        .map(|(name, h)| {
            (
                name,
                obj(vec![
                    ("count", num(h.count as f64)),
                    ("p50_us", num(h.quantile(50.0) as f64)),
                    ("p99_us", num(h.quantile(99.0) as f64)),
                    ("max_us", num(h.max as f64)),
                    ("mean_us", num(h.mean())),
                ]),
            )
        })
        .collect());
    let levels = Json::Arr(
        snap.levels
            .iter()
            .map(|r| {
                obj(vec![
                    ("level", num(r.level as f64)),
                    ("blocks", num(r.blocks as f64)),
                    ("dense_blocks", num(r.dense_blocks as f64)),
                    ("nnz", num(r.nnz as f64)),
                    ("cells", num(r.cells as f64)),
                    ("fill_ratio", num(r.fill_ratio())),
                ])
            })
            .collect(),
    );
    obj(vec![
        ("counters", counters),
        ("derived", derived),
        ("hists", hists),
        ("levels", levels),
    ])
}

/// Drain all closed spans and write the Chrome trace to `path`.
pub fn write_trace(path: &str) -> std::io::Result<()> {
    let spans = crate::obs::trace::drain();
    std::fs::write(path, chrome_trace(&spans).to_string())
}

/// Snapshot the counters and write the metrics JSON to `path`.
pub fn write_metrics(path: &str) -> std::io::Result<()> {
    std::fs::write(path, metrics_json(&crate::obs::counters::snapshot()).to_string())
}

/// Human-readable counter report (the `nni stats` body): non-zero counters
/// grouped by subsystem, derived ratios, and the per-level fill table.
pub fn human_report(snap: &Snapshot) -> String {
    let mut out = String::new();
    out.push_str("== counters ==\n");
    let mut group = "";
    for &(name, v) in &snap.counters {
        if v == 0 {
            continue;
        }
        let sub = name.split('.').next().unwrap_or(name);
        if sub != group {
            group = sub;
            out.push_str(&format!("[{group}]\n"));
        }
        out.push_str(&format!("  {name} = {v}\n"));
    }
    out.push_str("== derived ==\n");
    out.push_str(&format!(
        "  apply.worker_imbalance = {:.3}\n  serve.shard_imbalance = {:.3}\n  \
         aca.mean_rank = {:.2}\n  \
         csb.covered_fraction = {:.4}\n  csb.dense_fill_ratio = {:.4}\n",
        snap.worker_imbalance(),
        snap.shard_imbalance(),
        snap.mean_aca_rank(),
        snap.covered_fraction(),
        snap.dense_fill_ratio()
    ));
    let hists: Vec<_> = crate::obs::hist::snapshot_all()
        .into_iter()
        .filter(|(_, h)| h.count > 0)
        .collect();
    if !hists.is_empty() {
        out.push_str("== latency µs (count p50 p99 max) ==\n");
        for (name, h) in &hists {
            out.push_str(&format!(
                "  {name:<22} {:>8} {:>8} {:>8} {:>8}\n",
                h.count,
                h.quantile(50.0),
                h.quantile(99.0),
                h.max
            ));
        }
    }
    if !snap.levels.is_empty() {
        out.push_str("== levels (level blocks dense nnz cells fill) ==\n");
        for r in &snap.levels {
            out.push_str(&format!(
                "  L{:<2} {:>6} {:>6} {:>10} {:>12} {:.3}\n",
                r.level,
                r.blocks,
                r.dense_blocks,
                r.nnz,
                r.cells,
                r.fill_ratio()
            ));
        }
    }
    out
}

/// Validate an emitted Chrome trace: it must parse, every event must be
/// well-formed for its phase — complete events (`ph` `"X"`, the default)
/// need `name`/`ts`/`dur`, flow events (`"s"`/`"t"`/`"f"`) need
/// `name`/`ts`/`id`, anything else is rejected — and at least one event
/// must come from each required subsystem prefix (the text before the
/// first `.` of an event name).  Returns the event count.
pub fn check_trace(text: &str, required_subsystems: &[&str]) -> Result<usize, String> {
    let v = json::parse(text)?;
    let events = v.as_arr().ok_or("trace is not a JSON array")?;
    for (i, e) in events.iter().enumerate() {
        let o = e.as_obj().ok_or_else(|| format!("event {i} is not an object"))?;
        let ph = e.get("ph").and_then(|p| p.as_str()).unwrap_or("X");
        let keys: &[&str] = match ph {
            "X" => &["name", "ts", "dur"],
            "s" | "t" | "f" => &["name", "ts", "id"],
            other => return Err(format!("event {i} has unsupported phase \"{other}\"")),
        };
        for key in keys {
            if !o.contains_key(*key) {
                return Err(format!("event {i} missing \"{key}\""));
            }
        }
    }
    for want in required_subsystems {
        let hit = events.iter().any(|e| {
            e.get("name")
                .and_then(|n| n.as_str())
                .map(|n| n.split('.').next() == Some(*want))
                .unwrap_or(false)
        });
        if !hit {
            return Err(format!("no spans from subsystem \"{want}\""));
        }
    }
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::counters::LevelRow;

    fn spans() -> Vec<SpanRec> {
        vec![
            SpanRec {
                name: "tree.build",
                t0_us: 0,
                t1_us: 50,
                depth: 0,
                worker: 0,
                req: 0,
            },
            SpanRec {
                name: "csb.build.fill",
                t0_us: 10,
                t1_us: 30,
                depth: 1,
                worker: 0,
                req: 0,
            },
        ]
    }

    fn request_spans() -> Vec<SpanRec> {
        vec![
            SpanRec {
                name: "serve.admit",
                t0_us: 0,
                t1_us: 5,
                depth: 0,
                worker: 31,
                req: 7,
            },
            SpanRec {
                name: "serve.shard.compute",
                t0_us: 6,
                t1_us: 20,
                depth: 0,
                worker: 32,
                req: 7,
            },
            SpanRec {
                name: "serve.merge",
                t0_us: 21,
                t1_us: 25,
                depth: 0,
                worker: 31,
                req: 7,
            },
        ]
    }

    #[test]
    fn chrome_trace_emits_complete_events() {
        let j = chrome_trace(&spans());
        let text = j.to_string();
        let back = json::parse(&text).unwrap();
        let evs = back.as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(evs[1].get("dur").unwrap().as_f64(), Some(20.0));
        assert_eq!(evs[1].get("tid").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn check_trace_accepts_and_rejects() {
        let text = chrome_trace(&spans()).to_string();
        assert_eq!(check_trace(&text, &["tree", "csb"]), Ok(2));
        assert!(check_trace(&text, &["hmat"]).is_err());
        assert!(check_trace("not json", &[]).is_err());
        assert!(check_trace("{\"a\":1}", &[]).is_err());
        // flow events validate by their own key set; bad phases reject
        assert!(check_trace(r#"[{"name":"a","ts":1,"id":2,"ph":"s"}]"#, &[]).is_ok());
        assert!(check_trace(r#"[{"name":"a","ts":1,"ph":"s"}]"#, &[]).is_err());
        assert!(check_trace(r#"[{"name":"a","ts":1,"dur":2,"ph":"Q"}]"#, &[]).is_err());
    }

    #[test]
    fn request_spans_emit_connected_flow_events() {
        let text = chrome_trace(&request_spans()).to_string();
        // 3 complete events + a 3-step flow (s, t, f) sharing the request id
        assert_eq!(check_trace(&text, &["serve"]), Ok(6));
        let evs = json::parse(&text).unwrap();
        let evs = evs.as_arr().unwrap().to_vec();
        let flow: Vec<_> = evs
            .iter()
            .filter(|e| {
                matches!(e.get("ph").and_then(|p| p.as_str()), Some("s" | "t" | "f"))
            })
            .collect();
        assert_eq!(flow.len(), 3);
        assert!(flow.iter().all(|e| e.get("id").unwrap().as_f64() == Some(7.0)));
        assert_eq!(flow[0].get("ph").unwrap().as_str(), Some("s"));
        assert_eq!(flow[2].get("ph").unwrap().as_str(), Some("f"));
        assert_eq!(flow[2].get("bp").unwrap().as_str(), Some("e"));
        // flow steps ride the track (tid) of the span they annotate
        assert_eq!(flow[1].get("tid").unwrap().as_f64(), Some(32.0));
    }

    #[test]
    fn metrics_json_has_counters_derived_levels() {
        let snap = Snapshot {
            counters: vec![("apply.gemm_flops", 128), ("aca.factor_bytes", 64)],
            levels: vec![LevelRow {
                level: 2,
                blocks: 4,
                dense_blocks: 1,
                nnz: 50,
                cells: 100,
            }],
            shard_busy_ns: vec![],
        };
        let j = metrics_json(&snap);
        assert_eq!(
            j.get("counters").unwrap().get("apply.gemm_flops").unwrap().as_f64(),
            Some(128.0)
        );
        assert!(j.get("derived").unwrap().get("apply.worker_imbalance").is_some());
        let lv = j.get("levels").unwrap().as_arr().unwrap();
        assert_eq!(lv[0].get("fill_ratio").unwrap().as_f64(), Some(0.5));
        // round-trips through the parser
        assert!(json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn human_report_sections() {
        let snap = Snapshot {
            counters: vec![("cg.iterations", 7), ("csb.nnz", 0)],
            levels: vec![],
            shard_busy_ns: vec![],
        };
        let rep = human_report(&snap);
        assert!(rep.contains("cg.iterations = 7"));
        assert!(!rep.contains("csb.nnz"), "zero counters omitted");
        assert!(rep.contains("== derived =="));
    }
}
