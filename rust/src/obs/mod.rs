//! Observability: hierarchical tracing spans, global profile counters,
//! and Chrome-trace / JSON metrics exporters.
//!
//! The layer is **global and feature-light** by design:
//!
//! * [`counters`] — always-on relaxed atomics for the quantities the
//!   paper's profile measure is made of (block fill, panel bytes, GEMM
//!   flops, ACA ranks, schedule imbalance, CG iterations, serve
//!   occupancy).  One registry; `coordinator::Metrics` mirrors into it.
//! * [`trace`] — opt-in spans (`obs::span!("csb.build.fill")`) recorded
//!   into per-worker fixed-capacity slabs pre-sized at engine build, so
//!   steady-state applies allocate nothing even while traced.
//! * [`export`] — Chrome trace-event JSON (`--trace-out`, Perfetto-
//!   loadable), a flat metrics snapshot (`--metrics-out`), and the human
//!   `nni stats` report.
//! * [`hist`] — always-on lock-free log-linear latency histograms for
//!   the serve tier (per-stage, bounded-error quantiles).
//! * [`flight`] — always-on fixed-capacity flight recorder of compact
//!   serve events, auto-dumped as JSON on faults.

pub mod counters;
pub mod export;
pub mod flight;
pub mod hist;
pub mod trace;

pub use counters::{Counter, LevelStat, Snapshot};
pub use trace::{set_worker, SpanGuard};

/// Re-export so call sites read `obs::span!("...")`.
pub use crate::obs_span as span;

/// Record a hierarchical span over the enclosing scope (inert unless
/// tracing is enabled; see [`crate::obs::trace`] for the cost model).
#[macro_export]
macro_rules! obs_span {
    ($name:expr) => {
        let _obs_span_guard = $crate::obs::trace::SpanGuard::enter($name);
    };
}

/// Default per-worker span-slab capacity: 32k records (~1.3 MB/worker),
/// comfortably above a full pipeline run plus thousands of traced applies.
pub const DEFAULT_SPAN_CAP: usize = 1 << 15;

/// Pre-size the per-worker span slabs (idempotent; capacity only grows).
/// Called at engine build and by the CLI before enabling tracing.
pub fn install(workers: usize, cap_per_worker: usize) {
    trace::install(workers, cap_per_worker);
}

/// Turn span recording on or off.  Counters are unconditional.
pub fn set_enabled(on: bool) {
    trace::set_enabled(on);
}

/// Whether spans are currently being recorded.
#[inline]
pub fn enabled() -> bool {
    trace::enabled()
}

/// Time `f` and record a span around it: returns `(value, seconds)`.
/// The timing is unconditional (callers fold it into their own
/// accumulators); the span is recorded only while tracing is enabled.
pub fn timed<T>(name: &'static str, f: impl FnOnce() -> T) -> (T, f64) {
    let _g = trace::SpanGuard::enter(name);
    let t0 = std::time::Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

/// Reset spans, counters, histograms, and the flight recorder (tests
/// and CLI phase boundaries).  Enabled flags are left as-is.
pub fn reset() {
    trace::reset();
    counters::reset();
    hist::reset();
    flight::reset();
}
