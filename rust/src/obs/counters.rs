//! Global profile counters — the runtime side of the paper's profile
//! measure: block fill, panel traffic, GEMM flops, ACA ranks, schedule
//! imbalance, and serving occupancy, as process-global relaxed atomics.
//!
//! Counters are always on: one relaxed `fetch_add` per update, no
//! allocation, no locks.  Hot paths (the apply engine) amortize further by
//! adding *schedule-static* totals once per call instead of once per block
//! (see `spmv::multilevel::ApplySchedule`).  Spans — the opt-in, heavier
//! half of the observability layer — live in [`crate::obs::trace`].

use std::sync::atomic::{AtomicU64, Ordering};

macro_rules! counters {
    ($($variant:ident => $name:literal),+ $(,)?) => {
        /// Counter identifiers; snapshot/export names are dotted
        /// `subsystem.quantity` strings (see [`COUNTER_NAMES`]).
        #[derive(Clone, Copy, Debug, PartialEq, Eq)]
        pub enum Counter { $($variant),+ }

        /// Export names, index-aligned with the [`Counter`] discriminants.
        pub const COUNTER_NAMES: &[&str] = &[$($name),+];
    };
}

counters! {
    // csb build (published once per HierCsb::build_with_par)
    CsbDenseBlocks => "csb.dense_blocks",
    CsbSparseBlocks => "csb.sparse_blocks",
    CsbDenseCells => "csb.dense_cells",
    CsbDenseNnz => "csb.dense_nnz",
    CsbNnz => "csb.nnz",
    CsbCoveredArea => "csb.covered_area",
    CsbTotalArea => "csb.total_area",
    CsbPanelBytes => "csb.panel_bytes",
    // tree / embed builds
    TreeBuilds => "tree.builds",
    TreeNodes => "tree.nodes",
    TreeLeaves => "tree.leaves",
    PcaRuns => "embed.pca_runs",
    // apply engine (near field)
    ApplyCalls => "apply.calls",
    ApplyTasks => "apply.tasks",
    ApplyGemmFlops => "apply.gemm_flops",
    ApplyPanelBytes => "apply.panel_bytes",
    ApplySparseNnz => "apply.sparse_nnz",
    ApplyWorkerNsTotal => "apply.worker_ns_total",
    ApplyWorkerNsMax => "apply.worker_ns_max",
    ApplyWorkers => "apply.workers",
    // hmat far field
    AcaBlocks => "aca.blocks",
    AcaRankSum => "aca.rank_sum",
    AcaRankMax => "aca.rank_max",
    AcaFactorBytes => "aca.factor_bytes",
    AcaDenseFallbacks => "aca.dense_fallbacks",
    FarApplyCalls => "far.apply_calls",
    FarGemmFlops => "far.gemm_flops",
    // solvers / apps
    CgIterations => "cg.iterations",
    TsneIterations => "tsne.iterations",
    MeanshiftIterations => "meanshift.iterations",
    // coordinator (global mirror of the per-instance coordinator::Metrics)
    CoordRustNs => "coord.rust_ns",
    CoordPjrtNs => "coord.pjrt_ns",
    CoordRustBlocks => "coord.rust_blocks",
    CoordPjrtSingleCalls => "coord.pjrt_single_calls",
    CoordPjrtBatchedCalls => "coord.pjrt_batched_calls",
    CoordPjrtBlocks => "coord.pjrt_blocks",
    CoordBatchedQueries => "coord.batched_queries",
    CoordServeCalls => "coord.serve_calls",
    CoordNnzProcessed => "coord.nnz_processed",
    // serve path
    ServeQueueDepthMax => "serve.queue_depth_max",
    ServeBatchSlots => "serve.batch_slots",
    ServeBatchOccupied => "serve.batch_occupied",
    // serve daemon (admission, deadlines, fault containment)
    ServeAdmitted => "serve.admitted",
    ServeShed => "serve.shed",
    ServeRetried => "serve.retried",
    ServeDeadlineMissed => "serve.deadline_missed",
    ServePanicsContained => "serve.panics_contained",
    ServeShardRestarts => "serve.shard_restarts",
    ServeDegraded => "serve.degraded",
    ServeEpochSwitches => "serve.epoch_switches",
    ServeShardBusyNs => "serve.shard_busy_ns",
    ServeShardBusyNsMax => "serve.shard_busy_ns_max",
    ServeShardWorkers => "serve.shard_workers",
    // serve deadline attribution: which stage ate a missed budget
    DeadlineMissAdmission => "deadline.miss.admission",
    DeadlineMissCompute => "deadline.miss.compute",
    DeadlineMissFar => "deadline.miss.far",
    DeadlineMissMerge => "deadline.miss.merge",
    // flight recorder bookkeeping
    FlightEvents => "flight.events",
    FlightDumps => "flight.dumps",
    // incremental updates (tree/csb/hmat patching + epoch lifecycle)
    UpdateBatches => "update.batches",
    UpdateInserts => "update.inserts",
    UpdateDeletes => "update.deletes",
    UpdateFullRebuilds => "update.full_rebuilds",
    UpdateSubtreesRebuilt => "update.subtrees_rebuilt",
    UpdatePointsRebuilt => "update.points_rebuilt",
    UpdateLeavesReused => "update.leaves_reused",
    UpdateLeavesRebuilt => "update.leaves_rebuilt",
    UpdateNearRowsReused => "update.near_rows_reused",
    UpdateFarBlocksReused => "update.far_blocks_reused",
    UpdateFarBlocksRefactored => "update.far_blocks_refactored",
    UpdateEpochsPublished => "update.epochs_published",
    UpdateEpochsReclaimed => "update.epochs_reclaimed",
    UpdateH2LeavesReused => "update.h2_leaves_reused",
    UpdateH2LeavesRefactored => "update.h2_leaves_refactored",
    // hmat H² nested-basis far field
    H2BasisRanks => "hmat.h2.basis_ranks",
    H2TransferBytes => "hmat.h2.transfer_bytes",
    H2CouplingBlocks => "hmat.h2.coupling_blocks",
    H2F32Bytes => "hmat.h2.f32_bytes",
    H2Bf16Bytes => "hmat.h2.bf16_bytes",
    // the tracing layer's own bookkeeping
    SpansDropped => "trace.spans_dropped",
}

const N: usize = COUNTER_NAMES.len();
static CELLS: [AtomicU64; N] = [const { AtomicU64::new(0) }; N];

/// Add `v` to a counter (relaxed; never allocates).
#[inline]
pub fn add(c: Counter, v: u64) {
    CELLS[c as usize].fetch_add(v, Ordering::Relaxed);
}

/// Raise a high-water-mark counter to at least `v` (relaxed `fetch_max`).
#[inline]
pub fn raise(c: Counter, v: u64) {
    CELLS[c as usize].fetch_max(v, Ordering::Relaxed);
}

/// Current value of one counter.
#[inline]
pub fn get(c: Counter) -> u64 {
    CELLS[c as usize].load(Ordering::Relaxed)
}

/// Per-tree-level block statistics (level = depth of the block's target
/// leaf in the ordering tree); levels at/past [`MAX_LEVELS`] fold into the
/// last bucket.
pub const MAX_LEVELS: usize = 32;

/// Which per-level statistic to update.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LevelStat {
    Blocks,
    DenseBlocks,
    Nnz,
    Cells,
}

static LEVEL_BLOCKS: [AtomicU64; MAX_LEVELS] = [const { AtomicU64::new(0) }; MAX_LEVELS];
static LEVEL_DENSE: [AtomicU64; MAX_LEVELS] = [const { AtomicU64::new(0) }; MAX_LEVELS];
static LEVEL_NNZ: [AtomicU64; MAX_LEVELS] = [const { AtomicU64::new(0) }; MAX_LEVELS];
static LEVEL_CELLS: [AtomicU64; MAX_LEVELS] = [const { AtomicU64::new(0) }; MAX_LEVELS];

fn level_array(stat: LevelStat) -> &'static [AtomicU64; MAX_LEVELS] {
    match stat {
        LevelStat::Blocks => &LEVEL_BLOCKS,
        LevelStat::DenseBlocks => &LEVEL_DENSE,
        LevelStat::Nnz => &LEVEL_NNZ,
        LevelStat::Cells => &LEVEL_CELLS,
    }
}

/// Add `v` to one per-level statistic.
#[inline]
pub fn level_add(stat: LevelStat, level: usize, v: u64) {
    level_array(stat)[level.min(MAX_LEVELS - 1)].fetch_add(v, Ordering::Relaxed);
}

/// Per-shard cumulative busy-time slots for the serve tier; shards
/// at/past [`MAX_SHARD_SLOTS`] fold in modulo (matching
/// `obs::hist::MAX_SHARD_HISTS`).
pub const MAX_SHARD_SLOTS: usize = 8;

static SHARD_BUSY_NS: [AtomicU64; MAX_SHARD_SLOTS] = [const { AtomicU64::new(0) }; MAX_SHARD_SLOTS];

/// Add one shard worker's busy nanoseconds to its cumulative slot
/// (feeds [`Snapshot::shard_imbalance`]).
#[inline]
pub fn shard_busy_add(shard: usize, ns: u64) {
    SHARD_BUSY_NS[shard % MAX_SHARD_SLOTS].fetch_add(ns, Ordering::Relaxed);
}

/// One occupied level of the snapshot's per-level table.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LevelRow {
    pub level: usize,
    pub blocks: u64,
    pub dense_blocks: u64,
    pub nnz: u64,
    pub cells: u64,
}

impl LevelRow {
    /// Fill ratio of the level's stored blocks: nnz over covered cells.
    pub fn fill_ratio(&self) -> f64 {
        if self.cells == 0 {
            0.0
        } else {
            self.nnz as f64 / self.cells as f64
        }
    }
}

/// A point-in-time copy of every counter plus the occupied level rows.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    /// `(export name, value)`, in [`COUNTER_NAMES`] order.
    pub counters: Vec<(&'static str, u64)>,
    /// Occupied per-level rows (empty levels omitted), ascending level.
    pub levels: Vec<LevelRow>,
    /// Cumulative serve-shard busy ns, one entry per occupied slot
    /// (empty when the serve tier never ran).
    pub shard_busy_ns: Vec<u64>,
}

impl Snapshot {
    /// Value of a counter by export name (0 when absent).
    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(n, _)| *n == name)
            .map(|&(_, v)| v)
            .unwrap_or(0)
    }

    /// Schedule imbalance: max over mean of per-worker busy time across
    /// apply calls (1.0 = perfectly balanced, 0.0 = never measured —
    /// per-task timing runs only while tracing is enabled).
    pub fn worker_imbalance(&self) -> f64 {
        let total = self.get("apply.worker_ns_total");
        let max = self.get("apply.worker_ns_max");
        let workers = self.get("apply.workers");
        if total == 0 || workers == 0 {
            return 0.0;
        }
        max as f64 * workers as f64 / total as f64
    }

    /// Serve-tier analog of [`Self::worker_imbalance`]: max over mean of
    /// cumulative per-shard busy time (1.0 = balanced, 0.0 = serve tier
    /// never ran).  Shards past [`MAX_SHARD_SLOTS`] fold modulo, so with
    /// more shards than slots this is a slot-level approximation.
    pub fn shard_imbalance(&self) -> f64 {
        let total: u64 = self.shard_busy_ns.iter().sum();
        let max = self.shard_busy_ns.iter().copied().max().unwrap_or(0);
        let shards = (self.get("serve.shard_workers") as usize).min(MAX_SHARD_SLOTS);
        if total == 0 || shards == 0 {
            return 0.0;
        }
        max as f64 * shards as f64 / total as f64
    }

    /// Mean ACA rank over compressed far-field blocks.
    pub fn mean_aca_rank(&self) -> f64 {
        let blocks = self.get("aca.blocks");
        if blocks == 0 {
            0.0
        } else {
            self.get("aca.rank_sum") as f64 / blocks as f64
        }
    }

    /// Near-field index-space coverage: covered block area over `rows·cols`.
    pub fn covered_fraction(&self) -> f64 {
        let total = self.get("csb.total_area");
        if total == 0 {
            0.0
        } else {
            self.get("csb.covered_area") as f64 / total as f64
        }
    }

    /// Fill ratio of the dense-stored blocks: their nnz over their cells.
    pub fn dense_fill_ratio(&self) -> f64 {
        let cells = self.get("csb.dense_cells");
        if cells == 0 {
            0.0
        } else {
            self.get("csb.dense_nnz") as f64 / cells as f64
        }
    }
}

/// Copy every counter and the occupied level rows.
pub fn snapshot() -> Snapshot {
    let counters = COUNTER_NAMES
        .iter()
        .enumerate()
        .map(|(i, &n)| (n, CELLS[i].load(Ordering::Relaxed)))
        .collect();
    let mut levels = Vec::new();
    for l in 0..MAX_LEVELS {
        let row = LevelRow {
            level: l,
            blocks: LEVEL_BLOCKS[l].load(Ordering::Relaxed),
            dense_blocks: LEVEL_DENSE[l].load(Ordering::Relaxed),
            nnz: LEVEL_NNZ[l].load(Ordering::Relaxed),
            cells: LEVEL_CELLS[l].load(Ordering::Relaxed),
        };
        if row.blocks != 0 || row.nnz != 0 {
            levels.push(row);
        }
    }
    let mut shard_busy_ns: Vec<u64> =
        SHARD_BUSY_NS.iter().map(|c| c.load(Ordering::Relaxed)).collect();
    while shard_busy_ns.last() == Some(&0) {
        shard_busy_ns.pop();
    }
    Snapshot { counters, levels, shard_busy_ns }
}

/// Zero every counter, level row, and shard-busy slot (tests and CLI
/// phase boundaries).
pub fn reset() {
    for c in &CELLS {
        c.store(0, Ordering::Relaxed);
    }
    for arr in [&LEVEL_BLOCKS, &LEVEL_DENSE, &LEVEL_NNZ, &LEVEL_CELLS] {
        for c in arr.iter() {
            c.store(0, Ordering::Relaxed);
        }
    }
    for c in &SHARD_BUSY_NS {
        c.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global and the test harness runs tests
    // concurrently, so assertions are monotonic (>=), never exact.

    #[test]
    fn add_is_monotonic() {
        let before = get(Counter::CgIterations);
        add(Counter::CgIterations, 3);
        assert!(get(Counter::CgIterations) >= before + 3);
    }

    #[test]
    fn raise_sets_high_water_mark() {
        raise(Counter::ServeQueueDepthMax, 11);
        assert!(get(Counter::ServeQueueDepthMax) >= 11);
    }

    #[test]
    fn names_align_with_variants() {
        assert_eq!(COUNTER_NAMES.len(), N);
        assert_eq!(COUNTER_NAMES[Counter::CsbDenseBlocks as usize], "csb.dense_blocks");
        assert_eq!(COUNTER_NAMES[Counter::SpansDropped as usize], "trace.spans_dropped");
    }

    #[test]
    fn snapshot_reads_levels() {
        level_add(LevelStat::Blocks, 3, 2);
        level_add(LevelStat::Nnz, 3, 40);
        level_add(LevelStat::Cells, 3, 100);
        let snap = snapshot();
        let row = snap.levels.iter().find(|r| r.level == 3).expect("level 3 occupied");
        assert!(row.blocks >= 2);
        assert!(row.fill_ratio() > 0.0);
    }

    #[test]
    fn derived_ratios_handle_zero_denominators() {
        let empty = Snapshot::default();
        assert_eq!(empty.worker_imbalance(), 0.0);
        assert_eq!(empty.shard_imbalance(), 0.0);
        assert_eq!(empty.mean_aca_rank(), 0.0);
        assert_eq!(empty.covered_fraction(), 0.0);
        assert_eq!(empty.dense_fill_ratio(), 0.0);
        assert_eq!(empty.get("no.such.counter"), 0);
    }

    #[test]
    fn shard_imbalance_is_max_over_mean() {
        let snap = Snapshot {
            counters: vec![("serve.shard_workers", 2)],
            levels: Vec::new(),
            shard_busy_ns: vec![300, 100],
        };
        // max 300 · 2 shards / 400 total = 1.5
        assert!((snap.shard_imbalance() - 1.5).abs() < 1e-12);
        shard_busy_add(0, 7);
        shard_busy_add(MAX_SHARD_SLOTS, 7); // folds into slot 0
        assert!(snapshot().shard_busy_ns.first().copied().unwrap_or(0) >= 14);
    }
}
