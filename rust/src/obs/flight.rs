//! Fault flight recorder: a fixed-capacity lock-free ring buffer that
//! always records compact serve-tier events (admit / shed / slate /
//! panic / restart / poison / epoch-switch / fault-injection) and dumps
//! the most recent [`CAP`] of them as JSON when something goes wrong.
//!
//! **Dump triggers.**  A dump is taken automatically on panic
//! containment (`trigger = "panic"`), shard poisoning (`"poison"`), and
//! deadline sheds (`"deadline_shed"`, at most once per slate — the
//! dispatcher dumps after responding, not per shed response, so a
//! slate full of misses under overload costs one ring render, not B);
//! the serve stdin protocol's `dump` command and tests take on-demand
//! dumps.  Each dump is stored
//! in [`last_dump`] (and written to the `--flight-out` path when the
//! CLI set one) so the forensic trail survives the triggering request.
//!
//! **Recording protocol.**  A writer claims a ticket from a global
//! head counter, zeroes the slot's stamp, stores the event fields, then
//! publishes `ticket + 1` into the stamp with release ordering.  A
//! reader accepts a slot only when the stamp matches the expected
//! ticket before *and* after reading the fields, so a slot being
//! overwritten concurrently is skipped rather than read torn.  The
//! record path is a handful of relaxed atomic stores — no locks, no
//! allocation — and is priced by the `obs_overhead` serve round.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::obs::counters::{self, Counter};
use crate::obs::trace::now_us;

/// Ring capacity: the forensic window is the last `CAP` events.
pub const CAP: usize = 1024;

/// Compact event kinds; `aux` semantics depend on the kind (see
/// [`record`] call sites in `serve/` and `interact/epoch.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Request admitted into the queue (`seq` = request id).
    Admit = 0,
    /// Request shed (`aux` = reject-reason code, see [`reason_name`]).
    Shed = 1,
    /// Slate dispatched (`seq` = first request id, `aux` = slate size).
    Slate = 2,
    /// Shard panic contained by the retry ladder (`aux` = attempt).
    Panic = 3,
    /// Shard worker restarted after a contained panic.
    Restart = 4,
    /// Shard poisoned into scalar fallback (`aux` = contained count).
    Poison = 5,
    /// New engine epoch published (`aux` = version).
    EpochSwitch = 6,
    /// Scripted fault injection fired (`aux` = kind-specific detail).
    Fault = 7,
}

const KIND_NAMES: [&str; 8] = [
    "admit",
    "shed",
    "slate",
    "panic",
    "restart",
    "poison",
    "epoch_switch",
    "fault",
];

impl Kind {
    pub fn name(self) -> &'static str {
        KIND_NAMES[self as usize]
    }

    fn from_u64(v: u64) -> Option<Kind> {
        Some(match v {
            0 => Kind::Admit,
            1 => Kind::Shed,
            2 => Kind::Slate,
            3 => Kind::Panic,
            4 => Kind::Restart,
            5 => Kind::Poison,
            6 => Kind::EpochSwitch,
            7 => Kind::Fault,
            _ => return None,
        })
    }
}

/// Reject-reason codes carried in `aux` of [`Kind::Shed`] events; the
/// mapping from `serve::wire::RejectReason` lives next to that enum.
pub fn reason_name(code: u64) -> &'static str {
    match code {
        1 => "queue_full",
        2 => "malformed",
        3 => "oversized",
        4 => "bad_point",
        5 => "deadline",
        6 => "shard_failed",
        7 => "shutdown",
        _ => "unknown",
    }
}

/// One decoded flight event (timestamps share the span timebase of
/// `obs::trace`, so dumps line up with Chrome traces).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub t_us: u64,
    pub kind: Kind,
    /// Shard id, or -1 for dispatcher/admission-level events.
    pub shard: i64,
    /// Request id or task sequence number (kind-dependent).
    pub seq: u64,
    /// Kind-specific detail (reason code, slate size, attempt, version).
    pub aux: u64,
}

struct Slot {
    stamp: AtomicU64,
    t_us: AtomicU64,
    kind: AtomicU64,
    shard: AtomicU64,
    seq: AtomicU64,
    aux: AtomicU64,
}

#[allow(clippy::declare_interior_mutable_const)]
const EMPTY_SLOT: Slot = Slot {
    stamp: AtomicU64::new(0),
    t_us: AtomicU64::new(0),
    kind: AtomicU64::new(0),
    shard: AtomicU64::new(0),
    seq: AtomicU64::new(0),
    aux: AtomicU64::new(0),
};

static RING: [Slot; CAP] = [EMPTY_SLOT; CAP];
static HEAD: AtomicU64 = AtomicU64::new(0);
static ENABLED: AtomicBool = AtomicBool::new(true);
static LAST_DUMP: Mutex<Option<String>> = Mutex::new(None);
static DUMP_PATH: Mutex<Option<String>> = Mutex::new(None);

/// Turn event recording on or off (on by default; the `obs_overhead`
/// bench toggles it to price the instrumented path).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether the recorder is currently capturing events.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Record one event (lock-free, allocation-free).
#[inline]
pub fn record(kind: Kind, shard: i64, seq: u64, aux: u64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let ticket = HEAD.fetch_add(1, Ordering::Relaxed);
    let slot = &RING[(ticket % CAP as u64) as usize];
    slot.stamp.store(0, Ordering::Release);
    slot.t_us.store(now_us(), Ordering::Relaxed);
    slot.kind.store(kind as u64, Ordering::Relaxed);
    slot.shard.store(shard as u64, Ordering::Relaxed);
    slot.seq.store(seq, Ordering::Relaxed);
    slot.aux.store(aux, Ordering::Relaxed);
    slot.stamp.store(ticket + 1, Ordering::Release);
    counters::add(Counter::FlightEvents, 1);
}

/// Decode the ring, oldest first, skipping slots caught mid-overwrite.
pub fn snapshot() -> Vec<Event> {
    let head = HEAD.load(Ordering::Acquire);
    let start = head.saturating_sub(CAP as u64);
    let mut out = Vec::with_capacity((head - start) as usize);
    for ticket in start..head {
        let slot = &RING[(ticket % CAP as u64) as usize];
        let expect = ticket + 1;
        if slot.stamp.load(Ordering::Acquire) != expect {
            continue;
        }
        let ev = Event {
            t_us: slot.t_us.load(Ordering::Relaxed),
            kind: match Kind::from_u64(slot.kind.load(Ordering::Relaxed)) {
                Some(k) => k,
                None => continue,
            },
            shard: slot.shard.load(Ordering::Relaxed) as i64,
            seq: slot.seq.load(Ordering::Relaxed),
            aux: slot.aux.load(Ordering::Relaxed),
        };
        if slot.stamp.load(Ordering::Acquire) != expect {
            continue; // overwritten while reading
        }
        out.push(ev);
    }
    out
}

/// Render the current ring as a JSON dump (does not store it).
pub fn dump_json(trigger: &str) -> String {
    let events = snapshot();
    let mut s = String::with_capacity(64 + events.len() * 80);
    s.push_str("{\n  \"trigger\": \"");
    s.push_str(trigger);
    s.push_str("\",\n  \"dumped_at_us\": ");
    s.push_str(&now_us().to_string());
    s.push_str(",\n  \"events\": [");
    for (i, ev) in events.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str("\n    {\"t_us\": ");
        s.push_str(&ev.t_us.to_string());
        s.push_str(", \"kind\": \"");
        s.push_str(ev.kind.name());
        s.push_str("\", \"shard\": ");
        s.push_str(&ev.shard.to_string());
        s.push_str(", \"seq\": ");
        s.push_str(&ev.seq.to_string());
        s.push_str(", \"aux\": ");
        s.push_str(&ev.aux.to_string());
        if ev.kind == Kind::Shed {
            s.push_str(", \"reason\": \"");
            s.push_str(reason_name(ev.aux));
            s.push('"');
        }
        s.push('}');
    }
    s.push_str("\n  ]\n}\n");
    s
}

/// Take a dump: render the ring, remember it in [`last_dump`], write it
/// to the configured dump path (if any), and count it.  Called
/// automatically on panic containment, poison, and deadline sheds.
pub fn trigger_dump(trigger: &str) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let dump = dump_json(trigger);
    counters::add(Counter::FlightDumps, 1);
    if let Some(path) = DUMP_PATH.lock().unwrap_or_else(|e| e.into_inner()).as_deref() {
        let _ = std::fs::write(path, &dump);
    }
    *LAST_DUMP.lock().unwrap_or_else(|e| e.into_inner()) = Some(dump);
}

/// The most recent dump taken by [`trigger_dump`], if any.
pub fn last_dump() -> Option<String> {
    LAST_DUMP.lock().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Set (or clear) a file path that every future dump is also written to.
pub fn set_dump_path(path: Option<String>) {
    *DUMP_PATH.lock().unwrap_or_else(|e| e.into_inner()) = path;
}

/// Clear the ring and the stored dump (the enabled flag and dump path
/// are configuration and survive).
pub fn reset() {
    HEAD.store(0, Ordering::Release);
    for slot in RING.iter() {
        slot.stamp.store(0, Ordering::Release);
    }
    *LAST_DUMP.lock().unwrap_or_else(|e| e.into_inner()) = None;
}

#[cfg(test)]
mod tests {
    use super::*;

    // The ring is global; serialize the in-file tests against each other.
    fn lock() -> std::sync::MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        GATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn records_in_order_and_dumps_json() {
        let _g = lock();
        reset();
        record(Kind::Admit, -1, 0, 0);
        record(Kind::Shed, -1, 1, 5);
        record(Kind::Panic, 2, 7, 1);
        let evs = snapshot();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].kind, Kind::Admit);
        assert_eq!(evs[1].aux, 5);
        assert_eq!(evs[2].shard, 2);
        assert!(evs.windows(2).all(|w| w[0].t_us <= w[1].t_us));
        assert!(last_dump().is_none());
        trigger_dump("test");
        let dump = last_dump().expect("dump stored");
        assert!(dump.contains("\"trigger\": \"test\""));
        assert!(dump.contains("\"kind\": \"panic\""));
        assert!(dump.contains("\"reason\": \"deadline\""));
        crate::util::json::parse(&dump).expect("dump is valid JSON");
    }

    #[test]
    fn ring_keeps_only_the_last_cap_events() {
        let _g = lock();
        reset();
        for i in 0..(CAP as u64 + 10) {
            record(Kind::Slate, -1, i, 1);
        }
        let evs = snapshot();
        assert_eq!(evs.len(), CAP);
        assert_eq!(evs.first().unwrap().seq, 10);
        assert_eq!(evs.last().unwrap().seq, CAP as u64 + 9);
    }

    #[test]
    fn disabled_recording_is_inert() {
        let _g = lock();
        reset();
        set_enabled(false);
        record(Kind::Admit, -1, 0, 0);
        trigger_dump("ignored");
        assert!(snapshot().is_empty());
        assert!(last_dump().is_none());
        set_enabled(true);
    }
}
