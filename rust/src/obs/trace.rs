//! Zero-allocation hierarchical tracing spans.
//!
//! Every worker owns a fixed-capacity **slab** of span records, pre-sized
//! at engine build ([`install`]); recording a span is a relaxed enabled
//! check, one `Instant` read, and a short slab-mutex hold — no allocation
//! once the slab capacity is reserved, so the steady-state apply path
//! stays allocation-free with tracing enabled (asserted by
//! `rust/tests/alloc_steady_state.rs`).
//!
//! Workers identify themselves through a thread-local slot set by the
//! thread pool ([`set_worker`]); the calling thread defaults to slot 0.
//! Nesting depth is tracked per slab via an open-span stack, and
//! [`drain`] yields closed records sorted by `(worker, start)` — the
//! order the Chrome-trace exporter wants.
//!
//! Scope semantics: `obs::span!("name")` records until the end of the
//! enclosing scope.  Two `span!`s in one scope shadow (both close at
//! scope end); for sequential phases use nested blocks or
//! [`crate::obs::timed`].

use crate::obs::counters::{self, Counter};
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// Upper bound on distinct worker slots (slabs are statically allocated).
pub const MAX_WORKERS: usize = 64;

/// One closed (or still-open) span.
#[derive(Clone, Copy, Debug)]
pub struct SpanRec {
    pub name: &'static str,
    /// Start/end, microseconds since the process trace epoch.  `t1_us ==
    /// u64::MAX` marks a still-open span.
    pub t0_us: u64,
    pub t1_us: u64,
    /// Nesting depth on this worker at entry (0 = top level).
    pub depth: u32,
    /// Worker slot the span was recorded on.
    pub worker: u32,
    /// Request flow id (serve tier: request id + 1), 0 = not
    /// request-scoped.  The exporter ties same-`req` spans together with
    /// Chrome flow events.
    pub req: u64,
}

struct Slab {
    recs: Vec<SpanRec>,
    /// Indices into `recs` of currently-open spans (LIFO).
    open: Vec<u32>,
    dropped: u64,
}

impl Slab {
    const fn new() -> Slab {
        Slab {
            recs: Vec::new(),
            open: Vec::new(),
            dropped: 0,
        }
    }
}

static SLABS: [Mutex<Slab>; MAX_WORKERS] = [const { Mutex::new(Slab::new()) }; MAX_WORKERS];
static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();

thread_local! {
    static WORKER: Cell<usize> = const { Cell::new(0) };
}

fn lock(w: usize) -> MutexGuard<'static, Slab> {
    lock_of(&SLABS[w])
}

fn lock_of(slab: &'static Mutex<Slab>) -> MutexGuard<'static, Slab> {
    // A panic while holding a slab lock poisons it; tracing must keep
    // working (tests assert on panics elsewhere), so poisoning is ignored.
    slab.lock().unwrap_or_else(|e| e.into_inner())
}

/// Microseconds since the process trace epoch — the shared timebase of
/// spans, flight-recorder events, and [`record_closed`] timestamps.
#[inline]
pub fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Bind the current thread to a worker slot (called by the thread pool;
/// out-of-range slots fold into the last slab).
#[inline]
pub fn set_worker(w: usize) {
    WORKER.with(|c| c.set(w.min(MAX_WORKERS - 1)));
}

/// The current thread's worker slot.
#[inline]
pub fn worker() -> usize {
    WORKER.with(|c| c.get())
}

/// Reserve slab capacity for `workers` slots at `cap_per_worker` records
/// each (idempotent and monotonic: capacity only grows).  Also pins the
/// trace epoch so the first span does not pay the `OnceLock` init.
pub fn install(workers: usize, cap_per_worker: usize) {
    now_us();
    for slab in SLABS.iter().take(workers.clamp(1, MAX_WORKERS)) {
        let mut s = lock_of(slab);
        if s.recs.capacity() < cap_per_worker {
            let need = cap_per_worker - s.recs.len();
            s.recs.reserve(need);
        }
        if s.open.capacity() < 64 {
            let need = 64 - s.open.len();
            s.open.reserve(need);
        }
    }
}

/// Turn span recording on or off (counters stay on either way).
pub fn set_enabled(on: bool) {
    if on {
        now_us();
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether spans are currently recorded.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// RAII span: records on construction, closes on drop.  When tracing is
/// disabled (or the slab is full) the guard is inert.
pub struct SpanGuard {
    worker: u32,
    idx: u32,
    active: bool,
}

impl SpanGuard {
    /// Open a span on the current worker's slab.
    #[inline]
    pub fn enter(name: &'static str) -> SpanGuard {
        if !ENABLED.load(Ordering::Relaxed) {
            return SpanGuard {
                worker: 0,
                idx: 0,
                active: false,
            };
        }
        Self::enter_enabled(name, 0)
    }

    /// Open a request-scoped span: like [`Self::enter`] but tagged with a
    /// flow id (`req` = request id + 1; 0 means not request-scoped).
    #[inline]
    pub fn enter_req(name: &'static str, req: u64) -> SpanGuard {
        if !ENABLED.load(Ordering::Relaxed) {
            return SpanGuard {
                worker: 0,
                idx: 0,
                active: false,
            };
        }
        Self::enter_enabled(name, req)
    }

    fn enter_enabled(name: &'static str, req: u64) -> SpanGuard {
        let w = worker();
        let t0 = now_us();
        let mut slab = lock(w);
        if slab.recs.len() == slab.recs.capacity() {
            // Full (or never installed): count the drop, record nothing —
            // still allocation-free.
            slab.dropped += 1;
            drop(slab);
            counters::add(Counter::SpansDropped, 1);
            return SpanGuard {
                worker: 0,
                idx: 0,
                active: false,
            };
        }
        let idx = slab.recs.len() as u32;
        let depth = slab.open.len() as u32;
        slab.recs.push(SpanRec {
            name,
            t0_us: t0,
            t1_us: u64::MAX,
            depth,
            worker: w as u32,
            req,
        });
        slab.open.push(idx);
        SpanGuard {
            worker: w as u32,
            idx,
            active: true,
        }
    }
}

/// Record an already-closed span retroactively on the current worker's
/// slab (e.g. the dispatcher stamping a request's admission wait from
/// its submit timestamp).  Timestamps are µs on the [`now_us`] timebase;
/// inert when tracing is disabled, drop-counted when the slab is full.
pub fn record_closed(name: &'static str, t0_us: u64, t1_us: u64, req: u64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let w = worker();
    let mut slab = lock(w);
    if slab.recs.len() == slab.recs.capacity() {
        slab.dropped += 1;
        drop(slab);
        counters::add(Counter::SpansDropped, 1);
        return;
    }
    let depth = slab.open.len() as u32;
    slab.recs.push(SpanRec {
        name,
        t0_us,
        t1_us: t1_us.max(t0_us),
        depth,
        worker: w as u32,
        req,
    });
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let t1 = now_us();
        let mut slab = lock(self.worker as usize);
        slab.recs[self.idx as usize].t1_us = t1;
        // Normal drops are LIFO; tolerate out-of-order (e.g. a guard moved
        // out of its scope) by popping through to this span's entry.
        while let Some(top) = slab.open.pop() {
            if top == self.idx {
                break;
            }
        }
    }
}

/// Move every closed span out of the slabs, sorted by `(worker, start,
/// depth)`.  Slabs with spans still open are left untouched (their records
/// surface on a later drain once closed); drained slabs keep their
/// reserved capacity.
pub fn drain() -> Vec<SpanRec> {
    let mut out = Vec::new();
    for slab in SLABS.iter() {
        let mut s = lock_of(slab);
        if !s.open.is_empty() {
            continue;
        }
        out.extend(s.recs.drain(..).filter(|r| r.t1_us != u64::MAX));
    }
    out.sort_by_key(|r| (r.worker, r.t0_us, r.depth));
    out
}

/// Total spans dropped because a slab was full.
pub fn dropped() -> u64 {
    SLABS.iter().map(|s| lock_of(s).dropped).sum()
}

/// Clear every slab (records, open stacks, drop counts), keeping capacity.
pub fn reset() {
    for slab in SLABS.iter() {
        let mut s = lock_of(slab);
        s.recs.clear();
        s.open.clear();
        s.dropped = 0;
    }
}
