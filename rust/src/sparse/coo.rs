//! Coordinate (triplet) format — the assembly/permutation interchange form.

use crate::sparse::csr::Csr;

/// COO sparse matrix: unordered (row, col, val) triplets.
#[derive(Clone, Debug, PartialEq)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub r: Vec<u32>,
    pub c: Vec<u32>,
    pub v: Vec<f32>,
}

impl Coo {
    pub fn new(rows: usize, cols: usize) -> Self {
        Coo {
            rows,
            cols,
            r: Vec::new(),
            c: Vec::new(),
            v: Vec::new(),
        }
    }

    pub fn push(&mut self, i: usize, j: usize, x: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.r.push(i as u32);
        self.c.push(j as u32);
        self.v.push(x);
    }

    pub fn nnz(&self) -> usize {
        self.v.len()
    }

    /// Convert to CSR (duplicates summed, columns sorted per row).
    pub fn to_csr(&self) -> Csr {
        Csr::from_triplets(self.rows, self.cols, &self.r, &self.c, &self.v)
    }

    /// Apply row/column permutations: entry (i, j) moves to
    /// (row_pos[i], col_pos[j]) where `*_pos` maps old index -> new position.
    pub fn permuted(&self, row_pos: &[usize], col_pos: &[usize]) -> Coo {
        assert_eq!(row_pos.len(), self.rows);
        assert_eq!(col_pos.len(), self.cols);
        Coo {
            rows: self.rows,
            cols: self.cols,
            r: self.r.iter().map(|&i| row_pos[i as usize] as u32).collect(),
            c: self.c.iter().map(|&j| col_pos[j as usize] as u32).collect(),
            v: self.v.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_convert() {
        let mut m = Coo::new(3, 3);
        m.push(0, 1, 2.0);
        m.push(2, 0, 3.0);
        m.push(0, 1, 1.0); // duplicate: summed in CSR
        let csr = m.to_csr();
        assert_eq!(csr.nnz(), 2);
        assert_eq!(csr.get(0, 1), 3.0);
        assert_eq!(csr.get(2, 0), 3.0);
        assert_eq!(csr.get(1, 1), 0.0);
    }

    #[test]
    fn permuted_moves_entries() {
        let mut m = Coo::new(2, 2);
        m.push(0, 0, 1.0);
        m.push(1, 1, 2.0);
        // swap rows and columns
        let p = m.permuted(&[1, 0], &[1, 0]);
        let csr = p.to_csr();
        assert_eq!(csr.get(1, 1), 1.0);
        assert_eq!(csr.get(0, 0), 2.0);
    }
}
