//! Synthetic sparsity-profile generators for the micro-benchmarks (§4.1)
//! and Fig. 1's block-arrowhead construction.

use crate::sparse::csr::Csr;
use crate::util::rng::Rng;

/// Banded matrix: `per_row` nonzeros per row packed around the diagonal —
//  the paper's best-case profile (1D interaction).
pub fn banded(n: usize, per_row: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let half = per_row / 2;
    let mut r = Vec::with_capacity(n * per_row);
    let mut c = Vec::with_capacity(n * per_row);
    let mut v = Vec::with_capacity(n * per_row);
    for i in 0..n {
        let lo = i.saturating_sub(half);
        let hi = (lo + per_row).min(n);
        let lo = hi.saturating_sub(per_row);
        for j in lo..hi {
            r.push(i as u32);
            c.push(j as u32);
            v.push(rng.f32() + 0.1);
        }
    }
    Csr::from_triplets(n, n, &r, &c, &v)
}

/// Scattered matrix: `per_row` nonzeros per row placed uniformly at random —
/// the paper's base-case profile.
pub fn scattered(n: usize, per_row: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut r = Vec::with_capacity(n * per_row);
    let mut c = Vec::with_capacity(n * per_row);
    let mut v = Vec::with_capacity(n * per_row);
    for i in 0..n {
        for j in rng.sample_distinct(n, per_row) {
            r.push(i as u32);
            c.push(j as u32);
            v.push(rng.f32() + 0.1);
        }
    }
    Csr::from_triplets(n, n, &r, &c, &v)
}

/// Fig. 1(a): block-arrowhead with full `b x b` blocks on a matrix of size
/// `n` — full diagonal blocks plus full first block-row and block-column.
pub fn block_arrowhead(n: usize, b: usize, seed: u64) -> Csr {
    assert!(n % b == 0, "n must be a multiple of b");
    let mut rng = Rng::new(seed);
    let nb = n / b;
    let mut r = Vec::new();
    let mut c = Vec::new();
    let mut v = Vec::new();
    let dense_block = |bi: usize, bj: usize, r: &mut Vec<u32>, c: &mut Vec<u32>, v: &mut Vec<f32>, rng: &mut Rng| {
        for i in 0..b {
            for j in 0..b {
                r.push((bi * b + i) as u32);
                c.push((bj * b + j) as u32);
                v.push(rng.f32() + 0.1);
            }
        }
    };
    for k in 0..nb {
        dense_block(k, k, &mut r, &mut c, &mut v, &mut rng); // diagonal
        if k > 0 {
            dense_block(0, k, &mut r, &mut c, &mut v, &mut rng); // first row
            dense_block(k, 0, &mut r, &mut c, &mut v, &mut rng); // first col
        }
    }
    Csr::from_triplets(n, n, &r, &c, &v)
}

/// Fig. 1(b): permute whole block rows/columns of a block-partitioned
/// matrix (block size `b`), keeping intra-block order.
pub fn permute_blocks(m: &Csr, b: usize, seed: u64) -> Csr {
    assert!(m.rows % b == 0 && m.cols % b == 0);
    let mut rng = Rng::new(seed);
    let bperm_r = rng.permutation(m.rows / b);
    let bperm_c = rng.permutation(m.cols / b);
    let mut row_pos = vec![0usize; m.rows];
    let mut col_pos = vec![0usize; m.cols];
    for (bi, &tb) in bperm_r.iter().enumerate() {
        for i in 0..b {
            row_pos[bi * b + i] = tb * b + i;
        }
    }
    for (bj, &tb) in bperm_c.iter().enumerate() {
        for j in 0..b {
            col_pos[bj * b + j] = tb * b + j;
        }
    }
    m.permuted(&row_pos, &col_pos)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn banded_profile() {
        let m = banded(100, 8, 1);
        assert_eq!(m.nnz(), 800);
        assert!(m.bandwidth() <= 8);
    }

    #[test]
    fn scattered_profile() {
        let m = scattered(100, 8, 1);
        assert_eq!(m.nnz(), 800);
        // overwhelmingly likely to have large bandwidth
        assert!(m.bandwidth() > 50);
    }

    #[test]
    fn arrowhead_counts() {
        // paper: 500x500 with 20x20 full blocks
        let m = block_arrowhead(500, 20, 1);
        let nb = 25;
        let expect = (nb + 2 * (nb - 1)) * 20 * 20;
        assert_eq!(m.nnz(), expect);
        // first block row fully dense
        for j in 0..500 {
            assert!(m.get(0, j) > 0.0);
        }
    }

    #[test]
    fn block_permutation_preserves_nnz_and_blocks() {
        let m = block_arrowhead(200, 20, 2);
        let p = permute_blocks(&m, 20, 3);
        assert_eq!(p.nnz(), m.nnz());
        // each 20x20 block of p is either entirely zero or entirely nonzero
        for bi in 0..10 {
            for bj in 0..10 {
                let mut cnt = 0;
                for i in 0..20 {
                    for j in 0..20 {
                        if p.get(bi * 20 + i, bj * 20 + j) != 0.0 {
                            cnt += 1;
                        }
                    }
                }
                assert!(cnt == 0 || cnt == 400, "partial block ({bi},{bj}): {cnt}");
            }
        }
    }
}
