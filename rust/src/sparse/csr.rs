//! Compressed sparse row — the baseline format (the paper's CSC/CSR
//! MKL reference operates on the same indirect-addressing structure).

use crate::knn::exact::KnnGraph;

/// CSR sparse matrix, f32 values, u32 indices.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// Row pointers, length rows+1.
    pub ptr: Vec<u32>,
    /// Column indices, sorted within each row.
    pub col: Vec<u32>,
    pub val: Vec<f32>,
}

impl Csr {
    pub fn nnz(&self) -> usize {
        self.val.len()
    }

    /// Assemble from triplets: duplicates are summed, columns sorted.
    pub fn from_triplets(rows: usize, cols: usize, r: &[u32], c: &[u32], v: &[f32]) -> Csr {
        assert_eq!(r.len(), c.len());
        assert_eq!(r.len(), v.len());
        // Counting sort by row.
        let mut counts = vec![0u32; rows + 1];
        for &i in r {
            counts[i as usize + 1] += 1;
        }
        for i in 0..rows {
            counts[i + 1] += counts[i];
        }
        let mut order: Vec<u32> = vec![0; r.len()];
        let mut cursor = counts.clone();
        for (t, &i) in r.iter().enumerate() {
            order[cursor[i as usize] as usize] = t as u32;
            cursor[i as usize] += 1;
        }
        // Per row: sort by column, merge duplicates.
        let mut ptr = vec![0u32; rows + 1];
        let mut col = Vec::with_capacity(r.len());
        let mut val = Vec::with_capacity(r.len());
        let mut rowbuf: Vec<(u32, f32)> = Vec::new();
        for i in 0..rows {
            rowbuf.clear();
            for t in counts[i] as usize..counts[i + 1] as usize {
                let e = order[t] as usize;
                rowbuf.push((c[e], v[e]));
            }
            rowbuf.sort_unstable_by_key(|&(cj, _)| cj);
            let mut last: Option<u32> = None;
            for &(cj, x) in rowbuf.iter() {
                assert!((cj as usize) < cols, "column out of range");
                if last == Some(cj) {
                    let lv = val.last_mut().unwrap();
                    *lv += x;
                } else {
                    col.push(cj);
                    val.push(x);
                    last = Some(cj);
                }
            }
            ptr[i + 1] = col.len() as u32;
        }
        Csr {
            rows,
            cols,
            ptr,
            col,
            val,
        }
    }

    /// Interaction profile of a kNN graph: row i has the k neighbors of
    /// target i, all values 1.0 (values are refreshed by the engine).
    pub fn from_knn(g: &KnnGraph, cols: usize) -> Csr {
        let mut ptr = vec![0u32; g.n + 1];
        let mut col = Vec::with_capacity(g.n * g.k);
        let mut val = Vec::with_capacity(g.n * g.k);
        for i in 0..g.n {
            let mut nb: Vec<u32> = g.neighbors(i).to_vec();
            nb.sort_unstable();
            for j in nb {
                col.push(j);
                val.push(1.0);
            }
            ptr[i + 1] = col.len() as u32;
        }
        Csr {
            rows: g.n,
            cols,
            ptr,
            col,
            val,
        }
    }

    /// Entry accessor (O(log k) within the row).
    pub fn get(&self, i: usize, j: usize) -> f32 {
        let lo = self.ptr[i] as usize;
        let hi = self.ptr[i + 1] as usize;
        match self.col[lo..hi].binary_search(&(j as u32)) {
            Ok(p) => self.val[lo + p],
            Err(_) => 0.0,
        }
    }

    /// Row slice (columns, values).
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let lo = self.ptr[i] as usize;
        let hi = self.ptr[i + 1] as usize;
        (&self.col[lo..hi], &self.val[lo..hi])
    }

    /// Symmetrize the profile: A ∪ Aᵀ with values summed (the paper's Fig. 2
    /// matrices are "symmetrized interactions").
    pub fn symmetrized(&self) -> Csr {
        assert_eq!(self.rows, self.cols);
        let mut r = Vec::with_capacity(self.nnz() * 2);
        let mut c = Vec::with_capacity(self.nnz() * 2);
        let mut v = Vec::with_capacity(self.nnz() * 2);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&j, &x) in cols.iter().zip(vals) {
                r.push(i as u32);
                c.push(j);
                v.push(x * 0.5);
                r.push(j);
                c.push(i as u32);
                v.push(x * 0.5);
            }
        }
        Csr::from_triplets(self.rows, self.cols, &r, &c, &v)
    }

    /// Permute rows and columns: entry (i, j) -> (row_pos[i], col_pos[j]).
    pub fn permuted(&self, row_pos: &[usize], col_pos: &[usize]) -> Csr {
        assert_eq!(row_pos.len(), self.rows);
        assert_eq!(col_pos.len(), self.cols);
        let mut r = Vec::with_capacity(self.nnz());
        let mut c = Vec::with_capacity(self.nnz());
        let mut v = Vec::with_capacity(self.nnz());
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&j, &x) in cols.iter().zip(vals) {
                r.push(row_pos[i] as u32);
                c.push(col_pos[j as usize] as u32);
                v.push(x);
            }
        }
        Csr::from_triplets(self.rows, self.cols, &r, &c, &v)
    }

    /// Nonzero index positions as (row, col) pairs — the set Inz(A) of §2.3.
    pub fn nonzero_positions(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.nnz());
        for i in 0..self.rows {
            let (cols, _) = self.row(i);
            for &j in cols {
                out.push((i as u32, j));
            }
        }
        out
    }

    /// Dense y = A x (reference for tests; O(rows*cols) memory-free).
    pub fn matvec_ref(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0f32; self.rows];
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let mut acc = 0.0f64;
            for (&j, &v) in cols.iter().zip(vals) {
                acc += v as f64 * x[j as usize] as f64;
            }
            y[i] = acc as f32;
        }
        y
    }

    /// Bandwidth: max |i - j| over nonzeros (the classic envelope measure
    /// that rCM minimizes — reported for comparison in the benches).
    pub fn bandwidth(&self) -> usize {
        let mut bw = 0usize;
        for i in 0..self.rows {
            let (cols, _) = self.row(i);
            for &j in cols {
                bw = bw.max((i as i64 - j as i64).unsigned_abs() as usize);
            }
        }
        bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    pub fn random_csr(rows: usize, cols: usize, per_row: usize, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut r = Vec::new();
        let mut c = Vec::new();
        let mut v = Vec::new();
        for i in 0..rows {
            for j in rng.sample_distinct(cols, per_row.min(cols)) {
                r.push(i as u32);
                c.push(j as u32);
                v.push(rng.f32() + 0.1);
            }
        }
        Csr::from_triplets(rows, cols, &r, &c, &v)
    }

    #[test]
    fn triplets_sorted_and_summed() {
        let m = Csr::from_triplets(2, 3, &[0, 0, 1, 0], &[2, 0, 1, 2], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.nnz(), 3);
        let (cols, vals) = m.row(0);
        assert_eq!(cols, &[0, 2]);
        assert_eq!(vals, &[2.0, 5.0]);
        assert_eq!(m.get(1, 1), 3.0);
    }

    #[test]
    fn symmetrize_makes_symmetric() {
        let m = random_csr(50, 50, 5, 3);
        let s = m.symmetrized();
        for i in 0..50 {
            let (cols, _) = s.row(i);
            for &j in cols {
                assert!(
                    (s.get(i, j as usize) - s.get(j as usize, i)).abs() < 1e-6,
                    "asymmetric at ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn permutation_preserves_matvec() {
        let m = random_csr(40, 40, 6, 7);
        let mut rng = Rng::new(9);
        let rp = rng.permutation(40);
        let cp = rng.permutation(40);
        let pm = m.permuted(&rp, &cp);
        // y'[rp[i]] == y[i] when x'[cp[j]] == x[j].
        let x: Vec<f32> = (0..40).map(|_| rng.f32()).collect();
        let mut xp = vec![0.0f32; 40];
        for j in 0..40 {
            xp[cp[j]] = x[j];
        }
        let y = m.matvec_ref(&x);
        let yp = pm.matvec_ref(&xp);
        for i in 0..40 {
            assert!((yp[rp[i]] - y[i]).abs() < 1e-4, "row {i}");
        }
    }

    #[test]
    fn knn_to_csr_profile() {
        use crate::data::synth::SynthSpec;
        let ds = SynthSpec::blobs(60, 3, 3, 2).generate();
        let g = crate::knn::exact::knn_graph(&ds, 4, 1);
        let a = Csr::from_knn(&g, 60);
        assert_eq!(a.rows, 60);
        assert_eq!(a.nnz(), 60 * 4);
        for i in 0..60 {
            let (cols, _) = a.row(i);
            assert_eq!(cols.len(), 4);
            for w in cols.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }

    #[test]
    fn bandwidth_of_diagonal() {
        let m = Csr::from_triplets(4, 4, &[0, 1, 2, 3], &[0, 1, 2, 3], &[1.0; 4]);
        assert_eq!(m.bandwidth(), 0);
        let m2 = Csr::from_triplets(4, 4, &[0, 3], &[3, 0], &[1.0, 1.0]);
        assert_eq!(m2.bandwidth(), 3);
    }

    #[test]
    fn nonzero_positions_count() {
        let m = random_csr(30, 30, 4, 1);
        assert_eq!(m.nonzero_positions().len(), m.nnz());
    }
}
