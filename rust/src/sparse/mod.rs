//! Sparse matrix formats and synthetic profile generators.

pub mod coo;
pub mod csr;
pub mod gen;
