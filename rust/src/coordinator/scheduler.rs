//! The coordinator proper: hybrid execution of one interaction iteration.
//!
//! Phase 1 — workers: all Rust-routed blocks in parallel under target-leaf
//! ownership (the multi-level schedule).  Phase 2 — leader: PJRT-routed
//! dense blocks, batched where the policy allows, executed on the AOT block
//! programs.  The phases are serialized, so both can accumulate into the
//! same force/potential buffer without synchronization on the segments.
//!
//! If an artifact is unavailable (e.g. `make artifacts` not run, or an
//! embedding dimension with no lowered variant) the coordinator degrades to
//! the pure-Rust path and records it in [`Metrics`].

use crate::coordinator::batcher::{BatchPlan, BatchPolicy, QueryBatcher, Route};
use crate::coordinator::metrics::Metrics;
use crate::obs;
use crate::par::pool::SendPtr;
use crate::csb::hier::{HierCsb, LeafBlock};
use crate::interact::engine::{tsne_block, Engine};
use crate::runtime::{ArtifactRegistry, Tensor};

/// Hybrid Rust + PJRT interaction coordinator.
pub struct Coordinator {
    pub engine: Engine,
    registry: Option<ArtifactRegistry>,
    pub policy: BatchPolicy,
    plan: BatchPlan,
    /// Rust-routed blocks grouped by target leaf (parallel phase input).
    rust_by_target: Vec<Vec<u32>>,
    pub metrics: Metrics,
}

impl Coordinator {
    /// Build over an engine; `registry` enables the PJRT path.
    pub fn new(engine: Engine, registry: Option<ArtifactRegistry>, policy: BatchPolicy) -> Self {
        let effective = BatchPolicy {
            pjrt_enabled: policy.pjrt_enabled && registry.is_some(),
            ..policy
        };
        let plan = BatchPlan::build(&engine.csb, &effective);
        let mut rust_by_target = vec![Vec::new(); engine.csb.tgt_leaves.len()];
        for &t in &plan.rust {
            let b = &engine.csb.blocks[t as usize];
            rust_by_target[b.tleaf as usize].push(t);
        }
        Coordinator {
            engine,
            registry,
            policy: effective,
            plan,
            rust_by_target,
            metrics: Metrics::new(),
        }
    }

    /// Pure-Rust coordinator (no PJRT).
    pub fn rust_only(engine: Engine) -> Self {
        Self::new(
            engine,
            None,
            BatchPolicy {
                pjrt_enabled: false,
                ..Default::default()
            },
        )
    }

    pub fn csb(&self) -> &HierCsb {
        &self.engine.csb
    }

    pub fn plan(&self) -> &BatchPlan {
        &self.plan
    }

    /// Route of a given block index under the current plan.
    pub fn route_of(&self, block: u32) -> Route {
        if self.plan.rust.contains(&block) {
            Route::Rust
        } else if self.plan.pjrt_single.contains(&block) {
            Route::PjrtSingle
        } else {
            Route::PjrtBatched
        }
    }

    /// One t-SNE attractive-force iteration (hybrid).
    ///
    /// `y`: tree-ordered embedding `n x d`; `force`: output `n x d`.
    pub fn tsne_attr(&mut self, y: &[f32], d: usize, force: &mut [f32]) {
        let n = self.engine.csb.rows;
        assert_eq!(y.len(), n * d);
        assert_eq!(force.len(), n * d);
        force.fill(0.0);
        self.metrics.note_iteration(self.engine.csb.nnz as u64);

        // ---- Phase 1: workers on the Rust-routed blocks -------------------
        let csb = &self.engine.csb;
        let dispatch = self.engine.dispatch();
        let rust_by_target = &self.rust_by_target;
        let ((), rust_secs) = obs::timed("coord.rust_phase", || {
            let fp = SendPtr(force.as_mut_ptr());
            let fpr = &fp;
            let engine = &self.engine;
            engine.pool.for_each_chunked_worker(rust_by_target.len(), 4, |w, tl| {
                let sp = csb.tgt_leaves[tl];
                // SAFETY: disjoint target-leaf row spans.
                let seg: &mut [f32] = unsafe {
                    std::slice::from_raw_parts_mut(
                        fpr.0.add(sp.lo as usize * d),
                        sp.len() * d,
                    )
                };
                let mut scratch = engine.worker_scratch(w);
                for &t in &rust_by_target[tl] {
                    tsne_block(csb, t as usize, y, d, dispatch, &mut scratch, seg);
                }
            });
        });
        self.metrics.note_rust(self.plan.rust.len() as u64, rust_secs);

        // ---- Phase 2: leader drains the PJRT routes -----------------------
        if self.registry.is_none() || (self.plan.pjrt_single.is_empty() && self.plan.pjrt_batches.is_empty()) {
            return;
        }
        let single_name = format!("tsne_d{d}_m256");
        let batch_name = format!("tsne_d{d}_m128_b8");
        let registry = self.registry.as_ref().expect(
            "PJRT phase entered without an artifact registry — BatchPlan must route \
             every block to Rust when the Coordinator is built with registry=None",
        );
        let have_single = registry.variants.contains_key(&single_name);
        let have_batch = registry.variants.contains_key(&batch_name);

        // Count into locals inside the timed closure, fold into metrics
        // after — the closure already borrows engine/plan fields.
        let ((single_calls, batched_calls, pjrt_blocks, fallback_blocks), pjrt_secs) =
            obs::timed("coord.pjrt_phase", || {
                let (mut sc, mut bc, mut pb, mut fb) = (0u64, 0u64, 0u64, 0u64);
                // Leader phase runs after the workers drained; slot 0 is free.
                let mut scratch = self.engine.worker_scratch(0);
                for &t in &self.plan.pjrt_single {
                    let b = &csb.blocks[t as usize];
                    if have_single {
                        match run_tsne_single(registry, &single_name, csb, t as usize, y, d, 256) {
                            Ok(f_block) => {
                                accumulate_force(b, &f_block, d, force);
                                sc += 1;
                                pb += 1;
                                continue;
                            }
                            Err(e) => {
                                eprintln!("pjrt single fallback: {e:#}");
                            }
                        }
                    }
                    // fallback: rust
                    let sp = b.rows;
                    let seg = &mut force[sp.lo as usize * d..sp.hi as usize * d];
                    tsne_block(csb, t as usize, y, d, dispatch, &mut scratch, seg);
                    fb += 1;
                }
                for group in &self.plan.pjrt_batches {
                    if have_batch {
                        match run_tsne_batch(registry, &batch_name, group, csb, y, d, 128, 8) {
                            Ok(outs) => {
                                for (&t, f_block) in group.iter().zip(outs.iter()) {
                                    let b = &csb.blocks[t as usize];
                                    accumulate_force(b, f_block, d, force);
                                }
                                bc += 1;
                                pb += group.len() as u64;
                                continue;
                            }
                            Err(e) => {
                                eprintln!("pjrt batch fallback: {e:#}");
                            }
                        }
                    }
                    for &t in group {
                        let sp = csb.blocks[t as usize].rows;
                        let seg = &mut force[sp.lo as usize * d..sp.hi as usize * d];
                        tsne_block(csb, t as usize, y, d, dispatch, &mut scratch, seg);
                        fb += 1;
                    }
                }
                (sc, bc, pb, fb)
            });
        self.metrics.note_pjrt(single_calls, batched_calls, pjrt_blocks, pjrt_secs);
        if fallback_blocks > 0 {
            // Fallback blocks count as Rust work; their time already landed
            // in the PJRT leader phase (as before the refactor).
            self.metrics.note_rust(fallback_blocks, 0.0);
        }
    }

    /// Serve a slate of Gaussian queries through the engine's multi-RHS
    /// kernel: queries are grouped `policy.batch` at a time (the same knob
    /// that sizes the PJRT b8 artifacts) and each group runs as **one**
    /// batched interaction — the engine sees whole query batches, never
    /// singletons.  Returns one potential vector per query, in order.
    pub fn gauss_serve(
        &mut self,
        tcoords: &[f32],
        scoords: &[f32],
        d: usize,
        inv_h2: f32,
        queries: &[Vec<f32>],
    ) -> Vec<Vec<f32>> {
        let ((out, calls), rust_secs) = obs::timed("coord.serve", || {
            QueryBatcher::run_slate(
                self.policy.batch,
                &self.engine,
                queries,
                tcoords,
                scoords,
                d,
                inv_h2,
            )
        });
        self.metrics.note_serve(
            queries.len() as u64,
            calls as u64,
            self.engine.csb.nnz as u64 * queries.len() as u64,
            rust_secs,
        );
        out
    }
}

/// Pack one block into the single-block artifact and execute.
fn run_tsne_single(
    registry: &ArtifactRegistry,
    name: &str,
    csb: &HierCsb,
    t: usize,
    y: &[f32],
    d: usize,
    tile: usize,
) -> anyhow::Result<Tensor> {
    let b = &csb.blocks[t];
    let (yt, tv) = pack_coords(y, d, b.rows.lo as usize, b.rows.len(), tile);
    let (ys, sv) = pack_coords(y, d, b.cols.lo as usize, b.cols.len(), tile);
    let p = pack_dense(csb, t, tile);
    let outs = registry.run(
        name,
        &[
            Tensor::new(vec![tile, d], yt),
            Tensor::new(vec![tile, d], ys),
            Tensor::new(vec![tile, tile], p),
            Tensor::new(vec![tile], tv),
            Tensor::new(vec![tile], sv),
        ],
    )?;
    Ok(outs
        .into_iter()
        .next()
        .expect("PJRT artifact executed but returned no output tensor"))
}

/// Pack up to `batch` blocks into the batched artifact and execute;
/// returns per-block force tensors.
#[allow(clippy::too_many_arguments)]
fn run_tsne_batch(
    registry: &ArtifactRegistry,
    name: &str,
    group: &[u32],
    csb: &HierCsb,
    y: &[f32],
    d: usize,
    tile: usize,
    batch: usize,
) -> anyhow::Result<Vec<Tensor>> {
    let mut yt = vec![0.0f32; batch * tile * d];
    let mut ys = vec![0.0f32; batch * tile * d];
    let mut p = vec![0.0f32; batch * tile * tile];
    let mut tv = vec![0.0f32; batch * tile];
    let mut sv = vec![0.0f32; batch * tile];
    for (s, &t) in group.iter().enumerate() {
        let b = &csb.blocks[t as usize];
        let (cyt, ctv) = pack_coords(y, d, b.rows.lo as usize, b.rows.len(), tile);
        let (cys, csv) = pack_coords(y, d, b.cols.lo as usize, b.cols.len(), tile);
        yt[s * tile * d..(s + 1) * tile * d].copy_from_slice(&cyt);
        ys[s * tile * d..(s + 1) * tile * d].copy_from_slice(&cys);
        p[s * tile * tile..(s + 1) * tile * tile]
            .copy_from_slice(&pack_dense(csb, t as usize, tile));
        tv[s * tile..(s + 1) * tile].copy_from_slice(&ctv);
        sv[s * tile..(s + 1) * tile].copy_from_slice(&csv);
    }
    let outs = registry.run(
        name,
        &[
            Tensor::new(vec![batch, tile, d], yt),
            Tensor::new(vec![batch, tile, d], ys),
            Tensor::new(vec![batch, tile, tile], p),
            Tensor::new(vec![batch, tile], tv),
            Tensor::new(vec![batch, tile], sv),
        ],
    )?;
    let f = &outs[0];
    let mut per_block = Vec::with_capacity(group.len());
    for s in 0..group.len() {
        per_block.push(Tensor::new(
            vec![tile, d],
            f.data[s * tile * d..(s + 1) * tile * d].to_vec(),
        ));
    }
    Ok(per_block)
}

/// Copy a coordinate span into a zero-padded `tile x d` tensor + mask.
fn pack_coords(y: &[f32], d: usize, lo: usize, len: usize, tile: usize) -> (Vec<f32>, Vec<f32>) {
    let mut out = vec![0.0f32; tile * d];
    out[..len * d].copy_from_slice(&y[lo * d..(lo + len) * d]);
    let mut mask = vec![0.0f32; tile];
    mask[..len].fill(1.0);
    (out, mask)
}

/// Densify a block's values into a zero-padded `tile x tile` tensor.
fn pack_dense(csb: &HierCsb, t: usize, tile: usize) -> Vec<f32> {
    let b = &csb.blocks[t];
    let mut out = vec![0.0f32; tile * tile];
    if let Some(vals) = csb.dense_slice(t) {
        let w = b.cols.len();
        for r in 0..b.rows.len() {
            out[r * tile..r * tile + w].copy_from_slice(&vals[r * w..(r + 1) * w]);
        }
    } else {
        csb.for_each_nz(t, |r, c, v| out[r * tile + c] = v);
    }
    out
}

/// Add a (padded) block force tensor into the global force buffer.
fn accumulate_force(b: &LeafBlock, f_block: &Tensor, d: usize, force: &mut [f32]) {
    let tile = f_block.shape[0];
    debug_assert_eq!(f_block.shape[1], d);
    let r0 = b.rows.lo as usize;
    for r in 0..b.rows.len().min(tile) {
        for k in 0..d {
            force[(r0 + r) * d + k] += f_block.data[r * d + k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::knn::exact::knn_graph;
    use crate::order::Pipeline;
    use crate::sparse::csr::Csr;
    use crate::util::rng::Rng;

    fn engine(n: usize) -> (Csr, Engine) {
        let ds = SynthSpec::blobs(n, 2, 4, 31).generate();
        let g = knn_graph(&ds, 6, 2);
        let a = Csr::from_knn(&g, n).symmetrized();
        let r = Pipeline::dual_tree(2).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        let csb = HierCsb::build(&r.reordered, tree, tree, 64);
        (r.reordered, Engine::new(csb, 4))
    }

    #[test]
    fn rust_only_coordinator_matches_engine() {
        let (_, eng) = engine(400);
        let eng2 = Engine::new(eng.csb.clone(), 4);
        let mut co = Coordinator::rust_only(eng);
        let mut rng = Rng::new(7);
        let y: Vec<f32> = (0..400 * 2).map(|_| rng.normal() as f32).collect();
        let mut f1 = vec![0.0f32; 800];
        let mut f2 = vec![0.0f32; 800];
        co.tsne_attr(&y, 2, &mut f1);
        eng2.tsne_attr(&y, 2, &mut f2);
        for (a, b) in f1.iter().zip(&f2) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        assert_eq!(co.metrics.iterations, 1);
        assert_eq!(co.metrics.pjrt_blocks, 0);
    }

    #[test]
    fn metrics_accumulate_over_iterations() {
        let (_, eng) = engine(200);
        let mut co = Coordinator::rust_only(eng);
        let y = vec![0.5f32; 400];
        let mut f = vec![0.0f32; 400];
        co.tsne_attr(&y, 2, &mut f);
        co.tsne_attr(&y, 2, &mut f);
        assert_eq!(co.metrics.iterations, 2);
        assert!(co.metrics.nnz_processed > 0);
    }

    #[test]
    fn gauss_serve_batches_whole_query_groups() {
        let ds = SynthSpec::blobs(250, 2, 3, 31).generate();
        let g = knn_graph(&ds, 6, 2);
        let a = Csr::from_knn(&g, 250).symmetrized();
        let r = Pipeline::dual_tree(2).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        let csb = HierCsb::build_with(&r.reordered, tree, tree, 32, 0.25);
        let eng = Engine::new(csb, 2);
        let eng2 = Engine::new(eng.csb.clone(), 2);
        let mut co = Coordinator::rust_only(eng);
        let coords = ds.permuted(&r.perm).raw().to_vec();
        let mut rng = Rng::new(3);
        let queries: Vec<Vec<f32>> = (0..10)
            .map(|_| (0..250).map(|_| rng.f32()).collect())
            .collect();
        let got = co.gauss_serve(&coords, &coords, 2, 0.8, &queries);
        assert_eq!(got.len(), 10);
        assert_eq!(co.metrics.batched_queries, 10);
        // default policy batch = 8 → two whole-batch engine calls, and
        // serving must not masquerade as iteration steps
        assert_eq!(co.metrics.serve_calls, 2);
        assert_eq!(co.metrics.iterations, 0);
        for (q, batched) in queries.iter().zip(&got) {
            let mut want = vec![0.0f32; 250];
            eng2.gauss_apply(&coords, &coords, 2, 0.8, q, &mut want);
            for (g, w) in batched.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
            }
        }
    }

    // PJRT-path equivalence is covered by rust/tests/coordinator_pjrt.rs
    // (needs built artifacts).
}
