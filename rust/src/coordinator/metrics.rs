//! Throughput and routing counters for the coordinator.
//!
//! [`Metrics`] keeps the per-coordinator-instance numbers callers and
//! tests rely on (instances are independent; the scheduler tests assert
//! exact counts).  Every update is simultaneously mirrored into the
//! global `obs` counter registry (the `coord.*` names) so one registry
//! snapshot carries the coordinator's story alongside every other
//! subsystem.  Phase timing runs through [`crate::obs::timed`] — the
//! ad-hoc stopwatch this module used to carry is gone.

use crate::obs::{counters, Counter};

/// Accumulated per-run metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub iterations: u64,
    pub rust_blocks: u64,
    pub pjrt_single_calls: u64,
    pub pjrt_batched_calls: u64,
    pub pjrt_blocks: u64,
    /// Queries served through the multi-RHS batched path (`gauss_serve`).
    pub batched_queries: u64,
    /// Whole-batch engine calls made by `gauss_serve` (distinct from
    /// `iterations`, which counts t-SNE steps).
    pub serve_calls: u64,
    pub nnz_processed: u64,
    pub rust_seconds: f64,
    pub pjrt_seconds: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// One interaction iteration over `nnz` stored interactions.
    pub fn note_iteration(&mut self, nnz: u64) {
        self.iterations += 1;
        self.nnz_processed += nnz;
        counters::add(Counter::CoordNnzProcessed, nnz);
    }

    /// Rust-phase outcome: `blocks` applied in `secs` seconds.
    pub fn note_rust(&mut self, blocks: u64, secs: f64) {
        self.rust_blocks += blocks;
        self.rust_seconds += secs;
        counters::add(Counter::CoordRustBlocks, blocks);
        counters::add(Counter::CoordRustNs, (secs * 1e9) as u64);
    }

    /// PJRT-phase outcome: call/block counts plus the leader-phase time.
    pub fn note_pjrt(&mut self, single_calls: u64, batched_calls: u64, blocks: u64, secs: f64) {
        self.pjrt_single_calls += single_calls;
        self.pjrt_batched_calls += batched_calls;
        self.pjrt_blocks += blocks;
        self.pjrt_seconds += secs;
        counters::add(Counter::CoordPjrtSingleCalls, single_calls);
        counters::add(Counter::CoordPjrtBatchedCalls, batched_calls);
        counters::add(Counter::CoordPjrtBlocks, blocks);
        counters::add(Counter::CoordPjrtNs, (secs * 1e9) as u64);
    }

    /// Serve-path outcome: `queries` answered in `calls` whole-batch
    /// engine calls over `nnz` edge visits, spending `secs` on the Rust
    /// side (the serve path has no PJRT leg).  The call latency also
    /// lands in the serve tier's end-to-end histogram (`serve.e2e`), so
    /// coordinator-served batches show up in the same latency report as
    /// daemon-served requests.
    pub fn note_serve(&mut self, queries: u64, calls: u64, nnz: u64, secs: f64) {
        self.batched_queries += queries;
        self.serve_calls += calls;
        self.nnz_processed += nnz;
        self.rust_seconds += secs;
        counters::add(Counter::CoordBatchedQueries, queries);
        counters::add(Counter::CoordServeCalls, calls);
        counters::add(Counter::CoordNnzProcessed, nnz);
        counters::add(Counter::CoordRustNs, (secs * 1e9) as u64);
        crate::obs::hist::record(crate::obs::hist::Stage::EndToEnd, (secs * 1e6) as u64);
    }

    /// Interactions (edges) per second over everything processed so far.
    pub fn edges_per_second(&self) -> f64 {
        let t = self.rust_seconds + self.pjrt_seconds;
        if t > 0.0 {
            self.nnz_processed as f64 / t
        } else {
            0.0
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "iters={} rust_blocks={} pjrt_calls={}(+{} batched) pjrt_blocks={} \
             batched_queries={}/{} edges={} rust={:.3}s pjrt={:.3}s ({:.2e} edges/s)",
            self.iterations,
            self.rust_blocks,
            self.pjrt_single_calls,
            self.pjrt_batched_calls,
            self.pjrt_blocks,
            self.batched_queries,
            self.serve_calls,
            self.nnz_processed,
            self.rust_seconds,
            self.pjrt_seconds,
            self.edges_per_second(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn note_helpers_accumulate_per_instance() {
        let mut m = Metrics::new();
        m.note_iteration(10);
        m.note_rust(3, 0.5);
        m.note_pjrt(1, 2, 17, 0.25);
        m.note_serve(8, 1, 80, 0.1);
        assert_eq!(m.iterations, 1);
        assert_eq!(m.nnz_processed, 90);
        assert_eq!(m.rust_blocks, 3);
        assert_eq!(m.pjrt_single_calls, 1);
        assert_eq!(m.pjrt_batched_calls, 2);
        assert_eq!(m.pjrt_blocks, 17);
        assert_eq!(m.batched_queries, 8);
        assert_eq!(m.serve_calls, 1);
        assert!((m.rust_seconds - 0.6).abs() < 1e-12);
        assert!((m.pjrt_seconds - 0.25).abs() < 1e-12);
    }

    #[test]
    fn edges_per_second_zero_when_unused() {
        let m = Metrics::new();
        assert_eq!(m.edges_per_second(), 0.0);
        assert!(m.summary().contains("iters=0"));
    }

    #[test]
    fn summary_format_stable() {
        let mut m = Metrics::new();
        m.note_iteration(42);
        let s = m.summary();
        assert!(s.contains("iters=1"));
        assert!(s.contains("edges=42"));
        assert!(s.contains("rust=0.000s"));
        assert!(s.contains("edges/s"));
    }
}
