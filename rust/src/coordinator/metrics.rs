//! Throughput and routing counters for the coordinator.

use std::time::Instant;

/// Accumulated per-run metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub iterations: u64,
    pub rust_blocks: u64,
    pub pjrt_single_calls: u64,
    pub pjrt_batched_calls: u64,
    pub pjrt_blocks: u64,
    /// Queries served through the multi-RHS batched path (`gauss_serve`).
    pub batched_queries: u64,
    /// Whole-batch engine calls made by `gauss_serve` (distinct from
    /// `iterations`, which counts t-SNE steps).
    pub serve_calls: u64,
    pub nnz_processed: u64,
    pub rust_seconds: f64,
    pub pjrt_seconds: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Time a closure into one of the phase accumulators.
    pub fn time_phase<T>(acc: &mut f64, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let v = f();
        *acc += t0.elapsed().as_secs_f64();
        v
    }

    /// Interactions (edges) per second over everything processed so far.
    pub fn edges_per_second(&self) -> f64 {
        let t = self.rust_seconds + self.pjrt_seconds;
        if t > 0.0 {
            self.nnz_processed as f64 / t
        } else {
            0.0
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "iters={} rust_blocks={} pjrt_calls={}(+{} batched) pjrt_blocks={} \
             batched_queries={}/{} edges={} rust={:.3}s pjrt={:.3}s ({:.2e} edges/s)",
            self.iterations,
            self.rust_blocks,
            self.pjrt_single_calls,
            self.pjrt_batched_calls,
            self.pjrt_blocks,
            self.batched_queries,
            self.serve_calls,
            self.nnz_processed,
            self.rust_seconds,
            self.pjrt_seconds,
            self.edges_per_second(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_phase_accumulates() {
        let mut acc = 0.0;
        let v = Metrics::time_phase(&mut acc, || 41 + 1);
        assert_eq!(v, 42);
        assert!(acc >= 0.0);
    }

    #[test]
    fn edges_per_second_zero_when_unused() {
        let m = Metrics::new();
        assert_eq!(m.edges_per_second(), 0.0);
        assert!(m.summary().contains("iters=0"));
    }
}
