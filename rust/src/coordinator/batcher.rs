//! Routing and batching policy: decides, per leaf block, which backend runs
//! it and groups PJRT-bound blocks into fixed-shape batches.
//!
//! Policy (tunable via [`BatchPolicy`]):
//! * a block goes to PJRT iff it is stored dense, fits the artifact tile
//!   (≤ `tile` rows/cols), and its population is large enough that the
//!   dispatch overhead amortizes (`min_nnz`);
//! * blocks fitting the half-tile (≤ `tile`/2) are grouped `batch` at a
//!   time for the `*_b8` batched artifact; the remainder run on the
//!   single-block `m256` artifact;
//! * everything else runs on the fused Rust path.

use crate::csb::hier::HierCsb;

/// Where a block executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Fused Rust kernel (sparse blocklets, odd shapes).
    Rust,
    /// Single-block PJRT program (tile × tile).
    PjrtSingle,
    /// Batched PJRT program (batch × half-tile × half-tile).
    PjrtBatched,
}

/// Tunables for the routing decision.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Artifact tile size (m256 variants → 256).
    pub tile: usize,
    /// Batched-artifact batch size (b8 variants → 8).
    pub batch: usize,
    /// Minimum block nnz to justify a PJRT dispatch.
    pub min_nnz: u32,
    /// Disable PJRT entirely (pure-Rust operation).
    pub pjrt_enabled: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            tile: 256,
            batch: 8,
            min_nnz: 512,
            pjrt_enabled: true,
        }
    }
}

/// The routing plan over a [`HierCsb`]'s blocks.
#[derive(Clone, Debug, Default)]
pub struct BatchPlan {
    /// Block indices on the Rust path.
    pub rust: Vec<u32>,
    /// Block indices on the single-block PJRT path.
    pub pjrt_single: Vec<u32>,
    /// Batched PJRT groups (each ≤ `batch` long; short groups are padded
    /// with masked-out slots at dispatch time).
    pub pjrt_batches: Vec<Vec<u32>>,
}

impl BatchPlan {
    /// Build the plan for `csb` under `policy`.
    pub fn build(csb: &HierCsb, policy: &BatchPolicy) -> BatchPlan {
        let mut plan = BatchPlan::default();
        let mut batchable: Vec<u32> = Vec::new();
        for (t, b) in csb.blocks.iter().enumerate() {
            let t = t as u32;
            let dense = b.is_dense();
            if !policy.pjrt_enabled
                || !dense
                || b.nnz < policy.min_nnz
                || b.rows.len() > policy.tile
                || b.cols.len() > policy.tile
            {
                plan.rust.push(t);
            } else if b.rows.len() <= policy.tile / 2 && b.cols.len() <= policy.tile / 2 {
                batchable.push(t);
            } else {
                plan.pjrt_single.push(t);
            }
        }
        for group in batchable.chunks(policy.batch) {
            plan.pjrt_batches.push(group.to_vec());
        }
        plan
    }

    pub fn pjrt_block_count(&self) -> usize {
        self.pjrt_single.len() + self.pjrt_batches.iter().map(Vec::len).sum::<usize>()
    }

    pub fn total_blocks(&self) -> usize {
        self.rust.len() + self.pjrt_block_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::knn::exact::knn_graph;
    use crate::order::Pipeline;
    use crate::sparse::csr::Csr;

    fn csb(n: usize, leaf: usize) -> HierCsb {
        let ds = SynthSpec::blobs(n, 3, 4, 23).generate();
        let g = knn_graph(&ds, 8, 2);
        let a = Csr::from_knn(&g, n).symmetrized();
        let r = Pipeline::dual_tree(3).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        // PJRT-path threshold so dense blocks exist to route
        HierCsb::build_with(&r.reordered, tree, tree, leaf, 0.2)
    }

    #[test]
    fn plan_covers_every_block_once() {
        let m = csb(600, 64);
        let plan = BatchPlan::build(&m, &BatchPolicy::default());
        assert_eq!(plan.total_blocks(), m.blocks.len());
        let mut seen = vec![false; m.blocks.len()];
        let mark = |seen: &mut Vec<bool>, t: u32| {
            assert!(!seen[t as usize], "block {t} routed twice");
            seen[t as usize] = true;
        };
        for &t in &plan.rust {
            mark(&mut seen, t);
        }
        for &t in &plan.pjrt_single {
            mark(&mut seen, t);
        }
        for g in &plan.pjrt_batches {
            for &t in g {
                mark(&mut seen, t);
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn pjrt_disabled_routes_everything_rust() {
        let m = csb(400, 64);
        let plan = BatchPlan::build(
            &m,
            &BatchPolicy {
                pjrt_enabled: false,
                ..Default::default()
            },
        );
        assert_eq!(plan.rust.len(), m.blocks.len());
        assert_eq!(plan.pjrt_block_count(), 0);
    }

    #[test]
    fn min_nnz_filters_small_blocks() {
        let m = csb(500, 64);
        let strict = BatchPlan::build(
            &m,
            &BatchPolicy {
                min_nnz: u32::MAX,
                ..Default::default()
            },
        );
        assert_eq!(strict.pjrt_block_count(), 0);
        let loose = BatchPlan::build(
            &m,
            &BatchPolicy {
                min_nnz: 0,
                ..Default::default()
            },
        );
        // clustered data must produce at least one dense PJRT-eligible block
        assert!(loose.pjrt_block_count() > 0, "{}", m.describe());
    }

    #[test]
    fn batches_respect_batch_size() {
        let m = csb(800, 32);
        let policy = BatchPolicy {
            min_nnz: 0,
            ..Default::default()
        };
        let plan = BatchPlan::build(&m, &policy);
        for g in &plan.pjrt_batches {
            assert!(!g.is_empty() && g.len() <= policy.batch);
            for &t in g {
                let b = &m.blocks[t as usize];
                assert!(b.rows.len() <= policy.tile / 2);
                assert!(b.cols.len() <= policy.tile / 2);
            }
        }
    }
}
