//! Routing and batching policy: decides, per leaf block, which backend runs
//! it and groups PJRT-bound blocks into fixed-shape batches, plus the
//! query-side batcher that turns singleton requests into whole multi-RHS
//! batches for the engine.
//!
//! Policy (tunable via [`BatchPolicy`]):
//! * a block goes to PJRT iff it is stored dense, fits the artifact tile
//!   (≤ `tile` rows/cols), and its population is large enough that the
//!   dispatch overhead amortizes (`min_nnz`);
//! * blocks fitting the half-tile (≤ `tile`/2) are grouped `batch` at a
//!   time for the `*_b8` batched artifact; the remainder run on the
//!   single-block `m256` artifact;
//! * everything else runs on the fused Rust path.

use crate::csb::hier::HierCsb;
use crate::interact::engine::Engine;
use crate::obs::{counters, Counter};

/// Where a block executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Fused Rust kernel (sparse blocklets, odd shapes).
    Rust,
    /// Single-block PJRT program (tile × tile).
    PjrtSingle,
    /// Batched PJRT program (batch × half-tile × half-tile).
    PjrtBatched,
}

/// Tunables for the routing decision.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Artifact tile size (m256 variants → 256).
    pub tile: usize,
    /// Batched-artifact batch size (b8 variants → 8).
    pub batch: usize,
    /// Minimum block nnz to justify a PJRT dispatch.
    pub min_nnz: u32,
    /// Disable PJRT entirely (pure-Rust operation).
    pub pjrt_enabled: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            tile: 256,
            batch: 8,
            min_nnz: 512,
            pjrt_enabled: true,
        }
    }
}

/// The routing plan over a [`HierCsb`]'s blocks.
#[derive(Clone, Debug, Default)]
pub struct BatchPlan {
    /// Block indices on the Rust path.
    pub rust: Vec<u32>,
    /// Block indices on the single-block PJRT path.
    pub pjrt_single: Vec<u32>,
    /// Batched PJRT groups (each ≤ `batch` long; short groups are padded
    /// with masked-out slots at dispatch time).
    pub pjrt_batches: Vec<Vec<u32>>,
}

impl BatchPlan {
    /// Build the plan for `csb` under `policy`.
    pub fn build(csb: &HierCsb, policy: &BatchPolicy) -> BatchPlan {
        let mut plan = BatchPlan::default();
        let mut batchable: Vec<u32> = Vec::new();
        for (t, b) in csb.blocks.iter().enumerate() {
            let t = t as u32;
            let dense = b.is_dense();
            if !policy.pjrt_enabled
                || !dense
                || b.nnz < policy.min_nnz
                || b.rows.len() > policy.tile
                || b.cols.len() > policy.tile
            {
                plan.rust.push(t);
            } else if b.rows.len() <= policy.tile / 2 && b.cols.len() <= policy.tile / 2 {
                batchable.push(t);
            } else {
                plan.pjrt_single.push(t);
            }
        }
        for group in batchable.chunks(policy.batch) {
            plan.pjrt_batches.push(group.to_vec());
        }
        plan
    }

    pub fn pjrt_block_count(&self) -> usize {
        self.pjrt_single.len() + self.pjrt_batches.iter().map(Vec::len).sum::<usize>()
    }

    pub fn total_blocks(&self) -> usize {
        self.rust.len() + self.pjrt_block_count()
    }
}

/// Typed rejection for a query that cannot enter a slate.  The serve tier
/// wraps this in its own reject reason; direct callers get it from
/// [`QueryBatcher::submit`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryReject {
    /// Charge-vector length differs from the engine's source count — the
    /// slate would be shape-mismatched (and every other query in the group
    /// would pay for the panic deep inside the engine).
    ShapeMismatch { expected: usize, got: usize },
}

impl std::fmt::Display for QueryReject {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueryReject::ShapeMismatch { expected, got } => {
                write!(f, "query length {got} != source count {expected}")
            }
        }
    }
}

/// Accumulates single-RHS Gaussian queries and drains them through
/// [`Engine::gauss_apply_multi`] in whole `batch`-sized groups, so the
/// engine always sees multi-RHS work instead of a stream of singleton
/// matvecs.  The kernel weights are then computed once per profile entry
/// per group rather than once per query — the serving-path face of the
/// multi-RHS block kernels.
#[derive(Clone, Debug)]
pub struct QueryBatcher {
    batch: usize,
    pending: Vec<Vec<f32>>,
    /// Expected charge-vector length (None = unvalidated legacy mode).
    expect: Option<usize>,
}

impl QueryBatcher {
    pub fn new(batch: usize) -> QueryBatcher {
        QueryBatcher {
            batch: batch.max(1),
            pending: Vec::new(),
            expect: None,
        }
    }

    /// A batcher that validates every submission against the engine's
    /// source count `n_cols` — the serve-path constructor (malformed
    /// queries are rejected at the door, not deep inside a slate).
    pub fn for_sources(batch: usize, n_cols: usize) -> QueryBatcher {
        QueryBatcher {
            expect: Some(n_cols),
            ..QueryBatcher::new(batch)
        }
    }

    /// Shape check shared by [`QueryBatcher::submit`] and the serve tier's
    /// admission gate.
    pub fn validate(expected: usize, q: &[f32]) -> Result<(), QueryReject> {
        if q.len() != expected {
            return Err(QueryReject::ShapeMismatch {
                expected,
                got: q.len(),
            });
        }
        Ok(())
    }

    /// Enqueue one charge vector (length = source count); returns its
    /// submission slot (results come back in submission order).  A
    /// wrong-dimension query is rejected with a typed reason instead of
    /// poisoning the slate it would have joined.
    pub fn submit(&mut self, x: Vec<f32>) -> Result<usize, QueryReject> {
        if let Some(expected) = self.expect {
            Self::validate(expected, &x)?;
        }
        self.pending.push(x);
        counters::raise(Counter::ServeQueueDepthMax, self.pending.len() as u64);
        Ok(self.pending.len() - 1)
    }

    /// Queries waiting for a flush.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// True when at least one full batch is waiting.
    pub fn ready(&self) -> bool {
        self.pending.len() >= self.batch
    }

    /// Drain all pending queries through [`QueryBatcher::run_slate`].
    /// Returns the per-query potential vectors in submission order and the
    /// number of engine calls made.
    pub fn flush(
        &mut self,
        engine: &Engine,
        tcoords: &[f32],
        scoords: &[f32],
        d: usize,
        inv_h2: f32,
    ) -> (Vec<Vec<f32>>, usize) {
        let queries = std::mem::take(&mut self.pending);
        Self::run_slate(self.batch, engine, &queries, tcoords, scoords, d, inv_h2)
    }

    /// Group a borrowed slate of queries `batch` at a time through one
    /// multi-RHS engine call per group ([`gauss_group`]) — the single home
    /// of the query-grouping policy, shared by [`QueryBatcher::flush`] and
    /// `Coordinator::gauss_serve`.  Returns per-query potentials in slate
    /// order and the number of engine calls made.
    #[allow(clippy::too_many_arguments)]
    pub fn run_slate(
        batch: usize,
        engine: &Engine,
        queries: &[Vec<f32>],
        tcoords: &[f32],
        scoords: &[f32],
        d: usize,
        inv_h2: f32,
    ) -> (Vec<Vec<f32>>, usize) {
        let batch = batch.max(1);
        let mut out = Vec::with_capacity(queries.len());
        let mut calls = 0usize;
        for group in queries.chunks(batch) {
            // Batch occupancy: slots offered vs slots actually filled —
            // occupied/slots is the serve-path utilization ratio.
            counters::add(Counter::ServeBatchSlots, batch as u64);
            counters::add(Counter::ServeBatchOccupied, group.len() as u64);
            out.extend(gauss_group(engine, group, tcoords, scoords, d, inv_h2));
            calls += 1;
        }
        (out, calls)
    }
}

/// Run one whole query group as a single multi-RHS engine call:
/// interleave the group into the row-major `n x k` RHS layout, apply
/// [`Engine::gauss_apply_multi`] once, and de-interleave the potentials
/// (one vector per query, in group order).
pub fn gauss_group(
    engine: &Engine,
    group: &[Vec<f32>],
    tcoords: &[f32],
    scoords: &[f32],
    d: usize,
    inv_h2: f32,
) -> Vec<Vec<f32>> {
    let n_rows = engine.csb.rows;
    let n_cols = engine.csb.cols;
    let k = group.len();
    let mut x = vec![0.0f32; n_cols * k];
    for (j, q) in group.iter().enumerate() {
        assert_eq!(q.len(), n_cols, "query length != source count");
        for (i, &v) in q.iter().enumerate() {
            x[i * k + j] = v;
        }
    }
    let mut y = vec![0.0f32; n_rows * k];
    engine.gauss_apply_multi(tcoords, scoords, d, inv_h2, &x, k, &mut y);
    (0..k)
        .map(|j| (0..n_rows).map(|i| y[i * k + j]).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::knn::exact::knn_graph;
    use crate::order::Pipeline;
    use crate::sparse::csr::Csr;

    fn csb(n: usize, leaf: usize) -> HierCsb {
        let ds = SynthSpec::blobs(n, 3, 4, 23).generate();
        let g = knn_graph(&ds, 8, 2);
        let a = Csr::from_knn(&g, n).symmetrized();
        let r = Pipeline::dual_tree(3).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        // PJRT-path threshold so dense blocks exist to route
        HierCsb::build_with(&r.reordered, tree, tree, leaf, 0.2)
    }

    #[test]
    fn plan_covers_every_block_once() {
        let m = csb(600, 64);
        let plan = BatchPlan::build(&m, &BatchPolicy::default());
        assert_eq!(plan.total_blocks(), m.blocks.len());
        let mut seen = vec![false; m.blocks.len()];
        let mark = |seen: &mut Vec<bool>, t: u32| {
            assert!(!seen[t as usize], "block {t} routed twice");
            seen[t as usize] = true;
        };
        for &t in &plan.rust {
            mark(&mut seen, t);
        }
        for &t in &plan.pjrt_single {
            mark(&mut seen, t);
        }
        for g in &plan.pjrt_batches {
            for &t in g {
                mark(&mut seen, t);
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn pjrt_disabled_routes_everything_rust() {
        let m = csb(400, 64);
        let plan = BatchPlan::build(
            &m,
            &BatchPolicy {
                pjrt_enabled: false,
                ..Default::default()
            },
        );
        assert_eq!(plan.rust.len(), m.blocks.len());
        assert_eq!(plan.pjrt_block_count(), 0);
    }

    #[test]
    fn min_nnz_filters_small_blocks() {
        let m = csb(500, 64);
        let strict = BatchPlan::build(
            &m,
            &BatchPolicy {
                min_nnz: u32::MAX,
                ..Default::default()
            },
        );
        assert_eq!(strict.pjrt_block_count(), 0);
        let loose = BatchPlan::build(
            &m,
            &BatchPolicy {
                min_nnz: 0,
                ..Default::default()
            },
        );
        // clustered data must produce at least one dense PJRT-eligible block
        assert!(loose.pjrt_block_count() > 0, "{}", m.describe());
    }

    #[test]
    fn query_batcher_matches_per_query_path() {
        use crate::util::rng::Rng;
        let n = 300;
        let ds = SynthSpec::blobs(n, 3, 4, 23).generate();
        let g = knn_graph(&ds, 8, 2);
        let a = Csr::from_knn(&g, n).symmetrized();
        let r = Pipeline::dual_tree(3).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        let eng = Engine::new(HierCsb::build_with(&r.reordered, tree, tree, 32, 0.25), 2);
        let coords = ds.permuted(&r.perm).raw().to_vec();
        let inv_h2 = 0.7f32;
        let mut rng = Rng::new(13);
        let queries: Vec<Vec<f32>> = (0..11)
            .map(|_| (0..n).map(|_| rng.f32() - 0.5).collect())
            .collect();
        // batch of 4 → groups 4,4,3
        let mut qb = QueryBatcher::for_sources(4, n);
        for q in &queries {
            qb.submit(q.clone()).expect("valid query rejected");
        }
        assert!(qb.ready());
        assert_eq!(qb.pending_len(), 11);
        let (got, calls) = qb.flush(&eng, &coords, &coords, 3, inv_h2);
        assert_eq!(calls, 3);
        assert_eq!(got.len(), queries.len());
        assert_eq!(qb.pending_len(), 0);
        for (q, batched) in queries.iter().zip(&got) {
            let mut want = vec![0.0f32; n];
            eng.gauss_apply(&coords, &coords, 3, inv_h2, q, &mut want);
            for (g, w) in batched.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
            }
        }
    }

    #[test]
    fn submit_rejects_shape_mismatch_with_typed_reason() {
        let mut qb = QueryBatcher::for_sources(4, 100);
        assert_eq!(qb.submit(vec![0.0; 100]), Ok(0));
        assert_eq!(
            qb.submit(vec![0.0; 99]),
            Err(QueryReject::ShapeMismatch {
                expected: 100,
                got: 99
            })
        );
        // The rejected query never entered the slate.
        assert_eq!(qb.pending_len(), 1);
        // Legacy unvalidated batchers keep accepting anything.
        let mut legacy = QueryBatcher::new(4);
        assert_eq!(legacy.submit(vec![0.0; 7]), Ok(0));
        let msg = QueryReject::ShapeMismatch {
            expected: 100,
            got: 99,
        }
        .to_string();
        assert!(msg.contains("99") && msg.contains("100"), "{msg}");
    }

    #[test]
    fn batches_respect_batch_size() {
        let m = csb(800, 32);
        let policy = BatchPolicy {
            min_nnz: 0,
            ..Default::default()
        };
        let plan = BatchPlan::build(&m, &policy);
        for g in &plan.pjrt_batches {
            assert!(!g.is_empty() && g.len() <= policy.batch);
            for &t in g {
                let b = &m.blocks[t as usize];
                assert!(b.rows.len() <= policy.tile / 2);
                assert!(b.cols.len() <= policy.tile / 2);
            }
        }
    }
}
