//! Layer-3 coordinator: routes each leaf block of the hierarchical matrix
//! to a backend (in-process Rust kernels for sparse blocklets, PJRT block
//! programs for dense cluster pairs), batches PJRT work to amortize
//! dispatch, and owns the leader/worker topology.
//!
//! The PJRT client (`xla` crate) is `Rc`-based — deliberately *not* shared
//! across threads: the **leader** thread owns the [`ArtifactRegistry`] and
//! drains the dense-block queue, while **workers** chew through the sparse
//! blocks with the fused Rust kernels.  Both phases accumulate into the
//! potential vector under target-leaf ownership, so no synchronization is
//! needed beyond the phase boundary.
//!
//! [`ArtifactRegistry`]: crate::runtime::ArtifactRegistry

pub mod batcher;
pub mod metrics;
pub mod scheduler;

pub use batcher::{BatchPlan, QueryBatcher, Route};
pub use metrics::Metrics;
pub use scheduler::Coordinator;
