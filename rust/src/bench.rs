//! Shared infrastructure for the paper-figure benchmark harnesses
//! (`rust/benches/*.rs`): workload construction, ordering application, and
//! result table emission.  Each bench binary regenerates one table/figure;
//! see DESIGN.md §3 for the experiment index.

use crate::data::dataset::Dataset;
use crate::data::synth::SynthSpec;
use crate::knn::exact::knn_graph;
use crate::order::{OrderingKind, Pipeline};
use crate::sparse::csr::Csr;
use crate::obs;
use crate::util::json::{self, arr, num, obj, s, Json};
use crate::util::timer;
use std::io::Write;
use std::path::PathBuf;

/// The two dataset surrogates of §4.2 with the paper's k values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// SIFT-like: D=128, k=30.
    Sift,
    /// GIST-like: D=960, k=90.
    Gist,
}

impl Workload {
    pub fn name(&self) -> &'static str {
        match self {
            Workload::Sift => "SIFT",
            Workload::Gist => "GIST",
        }
    }

    pub fn k(&self) -> usize {
        match self {
            Workload::Sift => 30,
            Workload::Gist => 90,
        }
    }

    pub fn make_dataset(&self, n: usize, seed: u64) -> Dataset {
        match self {
            Workload::Sift => SynthSpec::sift_like(n, seed).generate(),
            Workload::Gist => SynthSpec::gist_like(n, seed).generate(),
        }
    }

    /// Dataset + symmetrized kNN interaction matrix (the Fig. 2 matrices).
    pub fn make(&self, n: usize, seed: u64, threads: usize) -> (Dataset, Csr) {
        let ds = self.make_dataset(n, seed);
        let g = knn_graph(&ds, self.k().min(n - 1), threads);
        let a = Csr::from_knn(&g, n).symmetrized();
        (ds, a)
    }
}

/// Build a pipeline for an ordering kind with bench-standard parameters
/// (fine ordering granularity; blocking granularity is chosen at CSB build).
pub fn pipeline_for(kind: &OrderingKind, seed: u64) -> Pipeline {
    let mut p = Pipeline::new(kind.clone());
    p.seed = seed;
    p
}

/// Resolve a bench record path against the **repo root** (the parent of the
/// crate manifest directory), so JSON records land at the repo root no
/// matter what cwd cargo runs the bench with (`rust/` for `cargo bench`,
/// the workspace root for direct binary invocation — the old `../…`
/// defaults scattered files in the latter case).  Absolute paths pass
/// through untouched.
pub fn repo_root_out(path: &str) -> PathBuf {
    let p = PathBuf::from(path);
    if p.is_absolute() {
        return p;
    }
    // Runtime CARGO_MANIFEST_DIR (set by cargo for run/bench/test), falling
    // back to the compile-time value for bare binary invocations.
    let manifest = std::env::var("CARGO_MANIFEST_DIR")
        .unwrap_or_else(|_| env!("CARGO_MANIFEST_DIR").to_string());
    match PathBuf::from(manifest).parent() {
        Some(root) => root.join(&p),
        None => p,
    }
}

/// Output directory for bench artifacts (tables, rasters, json records).
pub fn out_dir() -> PathBuf {
    let dir = PathBuf::from(
        std::env::var("NNI_BENCH_OUT").unwrap_or_else(|_| "bench_out".into()),
    );
    std::fs::create_dir_all(&dir).ok();
    dir
}

/// Non-zero counter values plus the derived ratios as one JSON object —
/// the drained observability snapshot each bench embeds into its record
/// points (`obs::reset()` at the top of a point makes the values
/// per-point rather than cumulative).
pub fn counters_json() -> Json {
    let snap = obs::counters::snapshot();
    let mut fields: Vec<(&str, Json)> = snap
        .counters
        .iter()
        .filter(|&&(_, v)| v != 0)
        .map(|&(n, v)| (n, num(v as f64)))
        .collect();
    fields.push(("derived.worker_imbalance", num(snap.worker_imbalance())));
    fields.push(("derived.mean_aca_rank", num(snap.mean_aca_rank())));
    fields.push(("derived.dense_fill_ratio", num(snap.dense_fill_ratio())));
    obj(fields)
}

/// Validate one `BENCH_*.json` record: required keys (`bench`, `status`,
/// `points`), point shape, and status/points consistency.  `no_pending`
/// additionally rejects records still pending with no measured points —
/// the CI honesty gate after the smoke refreshes.  Returns a one-line
/// status summary.
pub fn check_record(text: &str, no_pending: bool) -> Result<String, String> {
    let v = json::parse(text)?;
    v.as_obj().ok_or("record is not a JSON object")?;
    let bench = v
        .get("bench")
        .and_then(|b| b.as_str())
        .ok_or("missing string field \"bench\"")?;
    let status = v
        .get("status")
        .and_then(|st| st.as_str())
        .ok_or("missing string field \"status\"")?;
    let points = v
        .get("points")
        .and_then(|p| p.as_arr())
        .ok_or("missing array field \"points\"")?;
    for (i, p) in points.iter().enumerate() {
        if p.as_obj().is_none() {
            return Err(format!("point {i} is not an object"));
        }
    }
    let pending = status.starts_with("pending");
    if pending && !points.is_empty() {
        return Err(format!(
            "status says pending but {} points are recorded (stale status)",
            points.len()
        ));
    }
    if !pending && points.is_empty() {
        return Err(format!("status \"{status}\" but no measured points"));
    }
    if no_pending && pending {
        return Err(format!(
            "bench \"{bench}\" is still pending with no measured points \
             (the smoke refresh did not run or did not save)"
        ));
    }
    Ok(format!("status={status} points={}", points.len()))
}

/// Print the standard bench header (testbed stand-in for Table 2).
pub fn print_header(bench: &str, paper_ref: &str) {
    println!("# {bench}");
    println!("# reproduces: {paper_ref}");
    println!("# testbed: {}", timer::machine_summary());
    println!("#");
}

/// A result table that prints aligned text and saves JSON alongside.
pub struct Table {
    pub name: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    records: Vec<Json>,
}

impl Table {
    pub fn new(name: &str, columns: &[&str]) -> Table {
        Table {
            name: name.into(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
            records: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len());
        self.records.push(obj(self
            .columns
            .iter()
            .zip(&cells)
            .map(|(c, v)| {
                (
                    c.as_str(),
                    v.parse::<f64>().map(num).unwrap_or_else(|_| s(v)),
                )
            })
            .collect()));
        self.rows.push(cells);
    }

    /// Print aligned columns and write `<out_dir>/<name>.json`.
    pub fn finish(&self) {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.columns));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        let path = out_dir().join(format!("{}.json", self.name));
        let doc = obj(vec![
            ("table", s(&self.name)),
            ("testbed", s(&timer::machine_summary())),
            ("rows", arr(self.records.clone())),
        ]);
        if let Ok(mut f) = std::fs::File::create(&path) {
            let _ = writeln!(f, "{doc}");
        }
        println!("\n[saved {}]", path.display());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_params_match_paper() {
        assert_eq!(Workload::Sift.k(), 30);
        assert_eq!(Workload::Gist.k(), 90);
        let ds = Workload::Sift.make_dataset(64, 1);
        assert_eq!(ds.d(), 128);
    }

    #[test]
    fn make_produces_symmetric_profile() {
        let (_, a) = Workload::Sift.make(128, 2, 2);
        assert_eq!(a.rows, 128);
        for i in 0..a.rows {
            let (cols, _) = a.row(i);
            for &j in cols {
                assert!(a.get(j as usize, i) != 0.0);
            }
        }
    }

    #[test]
    fn repo_root_out_resolves_against_workspace_root() {
        let p = repo_root_out("BENCH_test.json");
        assert!(p.ends_with("BENCH_test.json"));
        // the resolved parent is the repo root: it contains the crate dir
        let root = p.parent().unwrap();
        assert!(
            root.join("rust").join("Cargo.toml").exists(),
            "resolved root {root:?} is not the repo root"
        );
        // absolute paths pass through
        let abs = if cfg!(windows) { "C:\\x\\y.json" } else { "/x/y.json" };
        assert_eq!(repo_root_out(abs), PathBuf::from(abs));
    }

    #[test]
    fn check_record_accepts_and_rejects() {
        let pending = r#"{"bench":"x","status":"pending: no toolchain","points":[]}"#;
        assert!(check_record(pending, false).is_ok());
        let e = check_record(pending, true).expect_err("--no-pending must reject");
        assert!(e.contains("pending"), "{e}");
        let measured = r#"{"bench":"x","status":"measured","points":[{"n":1}]}"#;
        assert!(check_record(measured, true).is_ok());
        // inconsistent combinations
        let stale = r#"{"bench":"x","status":"pending: soon","points":[{"n":1}]}"#;
        assert!(check_record(stale, false).is_err());
        let hollow = r#"{"bench":"x","status":"measured","points":[]}"#;
        assert!(check_record(hollow, false).is_err());
        // schema violations
        assert!(check_record("[]", false).is_err());
        assert!(check_record(r#"{"bench":"x","points":[]}"#, false).is_err());
        assert!(check_record(r#"{"bench":"x","status":"measured","points":[3]}"#, false).is_err());
        assert!(check_record("not json", false).is_err());
    }

    #[test]
    fn counters_json_carries_derived_ratios() {
        obs::counters::add(obs::Counter::CgIterations, 1);
        let j = counters_json();
        assert!(j.get("derived.worker_imbalance").is_some());
        assert!(j.get("cg.iterations").is_some());
        assert!(crate::util::json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("nni_test_table", &["set", "k", "score"]);
        t.row(vec!["SIFT".into(), "30".into(), "1.5".into()]);
        t.finish();
        let path = out_dir().join("nni_test_table.json");
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("\"score\":1.5"));
        std::fs::remove_file(path).ok();
    }
}
