//! Shared infrastructure for the paper-figure benchmark harnesses
//! (`rust/benches/*.rs`): workload construction, ordering application, and
//! result table emission.  Each bench binary regenerates one table/figure;
//! see DESIGN.md §3 for the experiment index.

use crate::data::dataset::Dataset;
use crate::data::synth::SynthSpec;
use crate::knn::exact::knn_graph;
use crate::order::{OrderingKind, Pipeline};
use crate::sparse::csr::Csr;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::timer;
use std::io::Write;
use std::path::PathBuf;

/// The two dataset surrogates of §4.2 with the paper's k values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// SIFT-like: D=128, k=30.
    Sift,
    /// GIST-like: D=960, k=90.
    Gist,
}

impl Workload {
    pub fn name(&self) -> &'static str {
        match self {
            Workload::Sift => "SIFT",
            Workload::Gist => "GIST",
        }
    }

    pub fn k(&self) -> usize {
        match self {
            Workload::Sift => 30,
            Workload::Gist => 90,
        }
    }

    pub fn make_dataset(&self, n: usize, seed: u64) -> Dataset {
        match self {
            Workload::Sift => SynthSpec::sift_like(n, seed).generate(),
            Workload::Gist => SynthSpec::gist_like(n, seed).generate(),
        }
    }

    /// Dataset + symmetrized kNN interaction matrix (the Fig. 2 matrices).
    pub fn make(&self, n: usize, seed: u64, threads: usize) -> (Dataset, Csr) {
        let ds = self.make_dataset(n, seed);
        let g = knn_graph(&ds, self.k().min(n - 1), threads);
        let a = Csr::from_knn(&g, n).symmetrized();
        (ds, a)
    }
}

/// Build a pipeline for an ordering kind with bench-standard parameters
/// (fine ordering granularity; blocking granularity is chosen at CSB build).
pub fn pipeline_for(kind: &OrderingKind, seed: u64) -> Pipeline {
    let mut p = Pipeline::new(kind.clone());
    p.seed = seed;
    p
}

/// Resolve a bench record path against the **repo root** (the parent of the
/// crate manifest directory), so JSON records land at the repo root no
/// matter what cwd cargo runs the bench with (`rust/` for `cargo bench`,
/// the workspace root for direct binary invocation — the old `../…`
/// defaults scattered files in the latter case).  Absolute paths pass
/// through untouched.
pub fn repo_root_out(path: &str) -> PathBuf {
    let p = PathBuf::from(path);
    if p.is_absolute() {
        return p;
    }
    // Runtime CARGO_MANIFEST_DIR (set by cargo for run/bench/test), falling
    // back to the compile-time value for bare binary invocations.
    let manifest = std::env::var("CARGO_MANIFEST_DIR")
        .unwrap_or_else(|_| env!("CARGO_MANIFEST_DIR").to_string());
    match PathBuf::from(manifest).parent() {
        Some(root) => root.join(&p),
        None => p,
    }
}

/// Output directory for bench artifacts (tables, rasters, json records).
pub fn out_dir() -> PathBuf {
    let dir = PathBuf::from(
        std::env::var("NNI_BENCH_OUT").unwrap_or_else(|_| "bench_out".into()),
    );
    std::fs::create_dir_all(&dir).ok();
    dir
}

/// Print the standard bench header (testbed stand-in for Table 2).
pub fn print_header(bench: &str, paper_ref: &str) {
    println!("# {bench}");
    println!("# reproduces: {paper_ref}");
    println!("# testbed: {}", timer::machine_summary());
    println!("#");
}

/// A result table that prints aligned text and saves JSON alongside.
pub struct Table {
    pub name: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    records: Vec<Json>,
}

impl Table {
    pub fn new(name: &str, columns: &[&str]) -> Table {
        Table {
            name: name.into(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
            records: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len());
        self.records.push(obj(self
            .columns
            .iter()
            .zip(&cells)
            .map(|(c, v)| {
                (
                    c.as_str(),
                    v.parse::<f64>().map(num).unwrap_or_else(|_| s(v)),
                )
            })
            .collect()));
        self.rows.push(cells);
    }

    /// Print aligned columns and write `<out_dir>/<name>.json`.
    pub fn finish(&self) {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.columns));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        let path = out_dir().join(format!("{}.json", self.name));
        let doc = obj(vec![
            ("table", s(&self.name)),
            ("testbed", s(&timer::machine_summary())),
            ("rows", arr(self.records.clone())),
        ]);
        if let Ok(mut f) = std::fs::File::create(&path) {
            let _ = writeln!(f, "{doc}");
        }
        println!("\n[saved {}]", path.display());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_params_match_paper() {
        assert_eq!(Workload::Sift.k(), 30);
        assert_eq!(Workload::Gist.k(), 90);
        let ds = Workload::Sift.make_dataset(64, 1);
        assert_eq!(ds.d(), 128);
    }

    #[test]
    fn make_produces_symmetric_profile() {
        let (_, a) = Workload::Sift.make(128, 2, 2);
        assert_eq!(a.rows, 128);
        for i in 0..a.rows {
            let (cols, _) = a.row(i);
            for &j in cols {
                assert!(a.get(j as usize, i) != 0.0);
            }
        }
    }

    #[test]
    fn repo_root_out_resolves_against_workspace_root() {
        let p = repo_root_out("BENCH_test.json");
        assert!(p.ends_with("BENCH_test.json"));
        // the resolved parent is the repo root: it contains the crate dir
        let root = p.parent().unwrap();
        assert!(
            root.join("rust").join("Cargo.toml").exists(),
            "resolved root {root:?} is not the repo root"
        );
        // absolute paths pass through
        let abs = if cfg!(windows) { "C:\\x\\y.json" } else { "/x/y.json" };
        assert_eq!(repo_root_out(abs), PathBuf::from(abs));
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("nni_test_table", &["set", "k", "score"]);
        t.row(vec!["SIFT".into(), "30".into(), "1.5".into()]);
        t.finish();
        let path = out_dir().join("nni_test_table.json");
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("\"score\":1.5"));
        std::fs::remove_file(path).ok();
    }
}
