//! Seeded, reproducible pseudo-random generation.
//!
//! SplitMix64 for seeding, xoshiro256** for the stream — the standard
//! combination with good statistical quality and trivial implementation.
//! Every stochastic component in the crate (data synthesis, scattered
//! orderings, property tests) takes an explicit seed so that experiments in
//! EXPERIMENTS.md are exactly re-runnable.

/// xoshiro256** generator seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-thread / per-component use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. `n` must be nonzero.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply rejection-free mapping (Lemire); bias is < 2^-64
        // per draw, negligible for our workloads.
        let x = self.next_u64() as u128;
        ((x * n as u128) >> 64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached second value discarded —
    /// simple and adequate for data synthesis).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct indices from `0..n` (k <= n), unsorted.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Partial Fisher–Yates over an index map.
        let mut map = std::collections::HashMap::new();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below(n - i);
            let vj = *map.get(&j).unwrap_or(&j);
            let vi = *map.get(&i).unwrap_or(&i);
            out.push(vj);
            map.insert(j, vi);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(3);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn sample_distinct_no_dups() {
        let mut r = Rng::new(5);
        for _ in 0..50 {
            let s = r.sample_distinct(100, 30);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 30);
            assert!(s.iter().all(|&x| x < 100));
        }
    }
}
