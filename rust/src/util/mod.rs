//! Substrate utilities built in-tree (the offline environment ships no
//! clap/serde/criterion/proptest — DESIGN.md §5 documents the substitution).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod timer;
