//! Minimal JSON: parse (for `artifacts/manifest.json`) and emit (for bench
//! result records).  Supports the full JSON grammar except `\u` surrogate
//! pairs beyond the BMP (not needed for our machine-generated inputs).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Path lookup: `get("variants")` on objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

/// Parse a JSON document.
pub fn parse(s: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: s.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing bytes at {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at {}", c as char, self.i))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }
    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }
    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at {}", self.i)),
            }
        }
    }
    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at {}", self.i)),
            }
        }
    }
    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            self.i += 4;
                            s.push(char::from_u32(cp).ok_or("surrogate \\u unsupported")?);
                        }
                        _ => return Err("bad escape".into()),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }
    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }
}

fn esc(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => esc(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    esc(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers for emitting result records.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(x: f64) -> Json {
    Json::Num(x)
}
pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}
pub fn arr(xs: Vec<Json>) -> Json {
    Json::Arr(xs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" -1.5e2 ").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap(),
            &Json::Str("x".into())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m":{"x":[1,2.5,"s",true,null]},"n":3}"#;
        let v = parse(src).unwrap();
        let emitted = v.to_string();
        assert_eq!(parse(&emitted).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn unicode_string() {
        let v = parse(r#""αβA""#).unwrap();
        assert_eq!(v, Json::Str("αβA".into()));
    }
}
