//! Declarative command-line argument parsing (clap substitute).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! defaults, and auto-generated `--help`.  Each binary declares its options
//! with [`Args::new`] + [`Args::opt`]/[`Args::flag`] and then calls
//! [`Args::parse`].

use std::collections::BTreeMap;

/// Value class of an option, validated at parse time so a malformed or
/// nonsensical flag fails with a one-line usage error naming the flag
/// instead of a raw panic at first use.
#[derive(Clone, Copy, PartialEq)]
enum Kind {
    Str,
    Flag,
    USize { min: usize },
    U64,
    F64,
}

impl Kind {
    fn placeholder(&self) -> &'static str {
        match self {
            Kind::Str => "<v>",
            Kind::Flag => "",
            Kind::USize { .. } | Kind::U64 => "<int>",
            Kind::F64 => "<num>",
        }
    }
}

#[derive(Clone)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    kind: Kind,
}

/// A declarative option table + parsed values.
pub struct Args {
    about: String,
    specs: Vec<OptSpec>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(about: &str) -> Self {
        Args {
            about: about.to_string(),
            specs: Vec::new(),
            values: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    fn push_spec(mut self, name: &str, help: &str, default: Option<String>, kind: Kind) -> Self {
        self.specs.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default,
            kind,
        });
        self
    }

    /// Declare `--name <value>` with a default (`""` is a valid default and
    /// serves as the usual "unset" sentinel).
    pub fn opt(self, name: &str, default: &str, help: &str) -> Self {
        self.push_spec(name, help, Some(default.into()), Kind::Str)
    }

    /// Declare an unsigned-integer option, validated at parse time.
    pub fn opt_usize(self, name: &str, default: usize, help: &str) -> Self {
        self.push_spec(name, help, Some(default.to_string()), Kind::USize { min: 0 })
    }

    /// Declare an unsigned-integer option with a lower bound, validated at
    /// parse time (`--rhs 0`-style nonsense becomes a usage error instead
    /// of tripping a downstream assert).
    pub fn opt_usize_min(self, name: &str, default: usize, min: usize, help: &str) -> Self {
        self.push_spec(name, help, Some(default.to_string()), Kind::USize { min })
    }

    /// Declare a u64 option (seeds), validated at parse time.
    pub fn opt_u64(self, name: &str, default: u64, help: &str) -> Self {
        self.push_spec(name, help, Some(default.to_string()), Kind::U64)
    }

    /// Declare a float option, validated at parse time.
    pub fn opt_f64(self, name: &str, default: f64, help: &str) -> Self {
        self.push_spec(name, help, Some(default.to_string()), Kind::F64)
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(self, name: &str, help: &str) -> Self {
        self.push_spec(name, help, None, Kind::Flag)
    }

    fn usage(&self) -> String {
        let mut u = format!("{}\n\nOptions:\n", self.about);
        for s in &self.specs {
            let ph = s.kind.placeholder();
            let left = if ph.is_empty() {
                format!("  --{}", s.name)
            } else {
                format!("  --{} {ph}", s.name)
            };
            let def = s
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            u.push_str(&format!("{left:28} {}{def}\n", s.help));
        }
        u
    }

    /// Parse an explicit token list (used by tests); exits on `--help`.
    pub fn parse_from<I: IntoIterator<Item = String>>(mut self, it: I) -> Result<Self, String> {
        let toks: Vec<String> = it.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if t == "--help" || t == "-h" {
                print!("{}", self.usage());
                std::process::exit(0);
            }
            if t == "--bench" {
                // cargo bench passes `--bench` to harness=false targets;
                // accept and ignore it so benches run under `cargo bench`.
                i += 1;
                continue;
            }
            if let Some(body) = t.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n{}", self.usage()))?
                    .clone();
                let val = if spec.kind == Kind::Flag {
                    inline.unwrap_or_else(|| "true".into())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    toks.get(i)
                        .ok_or_else(|| format!("--{name} needs a value"))?
                        .clone()
                };
                self.values.insert(name, val);
            } else {
                self.positional.push(t.clone());
            }
            i += 1;
        }
        self.validate()?;
        Ok(self)
    }

    /// Type/range checks of all user-supplied values (declared defaults are
    /// trusted — they come from the binary itself).
    fn validate(&self) -> Result<(), String> {
        for (name, val) in &self.values {
            let Some(spec) = self.specs.iter().find(|s| &s.name == name) else {
                continue;
            };
            match spec.kind {
                Kind::USize { min } => {
                    let v: usize = val
                        .parse()
                        .map_err(|_| format!("--{name} expects an integer (got '{val}')"))?;
                    if v < min {
                        return Err(format!("--{name} must be at least {min} (got {v})"));
                    }
                }
                Kind::U64 => {
                    val.parse::<u64>()
                        .map_err(|_| format!("--{name} expects an integer (got '{val}')"))?;
                }
                Kind::F64 => {
                    val.parse::<f64>()
                        .map_err(|_| format!("--{name} expects a number (got '{val}')"))?;
                }
                Kind::Str | Kind::Flag => {}
            }
        }
        Ok(())
    }

    /// Parse `std::env::args()` (skipping argv[0]); exits with a message on
    /// error.
    pub fn parse(self) -> Self {
        match self.parse_from(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    }

    fn raw(&self, name: &str) -> Option<String> {
        if let Some(v) = self.values.get(name) {
            return Some(v.clone());
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default.clone())
    }

    /// Get a string option (declared default applies).
    pub fn get(&self, name: &str) -> String {
        self.raw(name)
            .unwrap_or_else(|| panic!("option --{name} missing and has no default"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects a number"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.values.contains_key(name)
    }

    /// Positional arguments in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Comma-separated list of usizes, e.g. `--sizes 1024,2048`.
    pub fn get_usize_list(&self, name: &str) -> Vec<usize> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("--{name}: bad integer '{s}'"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = Args::new("t")
            .opt("n", "100", "size")
            .opt("name", "abc", "label")
            .parse_from(toks(&["--n", "7"]))
            .unwrap();
        assert_eq!(a.get_usize("n"), 7);
        assert_eq!(a.get("name"), "abc");
    }

    #[test]
    fn equals_form_and_flags() {
        let a = Args::new("t")
            .opt("k", "1", "k")
            .flag("par", "parallel")
            .parse_from(toks(&["--k=5", "--par"]))
            .unwrap();
        assert_eq!(a.get_usize("k"), 5);
        assert!(a.get_flag("par"));
    }

    #[test]
    fn positional_collected() {
        let a = Args::new("t")
            .opt("k", "1", "k")
            .parse_from(toks(&["input.bin", "--k", "2", "out.bin"]))
            .unwrap();
        assert_eq!(a.positional(), &["input.bin", "out.bin"]);
    }

    #[test]
    fn unknown_option_errors() {
        let r = Args::new("t").parse_from(toks(&["--bogus"]));
        assert!(r.is_err());
    }

    #[test]
    fn typed_options_validate_at_parse_time() {
        // below the minimum → one-line usage error naming the flag
        let e = Args::new("t")
            .opt_usize_min("rhs", 1, 1, "rhs width")
            .parse_from(toks(&["--rhs", "0"]))
            .err()
            .expect("--rhs 0 must be rejected");
        assert!(e.contains("--rhs"), "{e}");
        // not an integer at all
        let e = Args::new("t")
            .opt_usize_min("leaf-cap", 256, 1, "cap")
            .parse_from(toks(&["--leaf-cap", "many"]))
            .err()
            .expect("--leaf-cap many must be rejected");
        assert!(e.contains("--leaf-cap"), "{e}");
        // malformed float / u64
        let e = Args::new("t")
            .opt_f64("bandwidth", 0.25, "h")
            .parse_from(toks(&["--bandwidth", "wide"]))
            .err()
            .expect("--bandwidth wide must be rejected");
        assert!(e.contains("--bandwidth"), "{e}");
        let e = Args::new("t")
            .opt_u64("seed", 42, "seed")
            .parse_from(toks(&["--seed", "-3"]))
            .err()
            .expect("--seed -3 must be rejected");
        assert!(e.contains("--seed"), "{e}");
        // valid values pass and read back typed
        let a = Args::new("t")
            .opt_usize_min("rhs", 1, 1, "rhs width")
            .opt_f64("bandwidth", 0.25, "h")
            .parse_from(toks(&["--rhs", "8"]))
            .unwrap();
        assert_eq!(a.get_usize("rhs"), 8);
        assert_eq!(a.get_f64("bandwidth"), 0.25);
    }

    #[test]
    fn list_parsing() {
        let a = Args::new("t")
            .opt("sizes", "1,2,3", "sizes")
            .parse_from(toks(&[]))
            .unwrap();
        assert_eq!(a.get_usize_list("sizes"), vec![1, 2, 3]);
    }
}
