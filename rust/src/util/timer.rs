//! Measurement protocol for the benchmark harness (criterion substitute).
//!
//! `cargo bench` targets use [`bench_run`]: warm up, then repeat the
//! workload until both a minimum repetition count and a minimum total time
//! are reached, and report the **robust minimum** (5th percentile) plus the
//! median — the low quantile is the standard estimator for cache-behaviour
//! benchmarks where interference is strictly additive noise.

use std::time::{Duration, Instant};

/// Result of a measured run.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// 5th-percentile iteration time, seconds.
    pub robust_min_s: f64,
    /// Median iteration time, seconds.
    pub median_s: f64,
    /// Number of measured iterations.
    pub iters: usize,
}

impl Measurement {
    /// Throughput in "units per second" for a per-iteration work count.
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.robust_min_s
    }
}

/// Measure `f`, which performs one full iteration of the workload per call.
///
/// * `warmup`: iterations discarded up front (populate caches/branch pred).
/// * `min_iters` / `min_time`: run until both are satisfied.
pub fn bench_run<F: FnMut()>(
    warmup: usize,
    min_iters: usize,
    min_time: Duration,
    mut f: F,
) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(min_iters.max(8));
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed() < min_time {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() >= 10_000 {
            break; // pathological fast-workload guard
        }
    }
    summarize(samples)
}

/// Percentile summary of raw per-iteration samples.  `total_cmp` instead
/// of `partial_cmp().unwrap()`: a NaN sample (e.g. a clock source folding a
/// poisoned measurement in) must not panic the sort — under the IEEE total
/// order positive NaNs sort after every finite time, so the low quantiles
/// stay finite.
pub fn summarize(mut samples: Vec<f64>) -> Measurement {
    assert!(!samples.is_empty(), "summarize needs at least one sample");
    samples.sort_by(|a, b| a.total_cmp(b));
    let q05 = samples[(samples.len() as f64 * 0.05) as usize];
    let med = samples[samples.len() / 2];
    Measurement {
        robust_min_s: q05,
        median_s: med,
        iters: samples.len(),
    }
}

/// Default protocol used by the paper-figure benches.
pub fn bench_default<F: FnMut()>(f: F) -> Measurement {
    bench_run(2, 7, Duration::from_millis(300), f)
}

/// One-shot wall time of `f` in seconds (for coarse pipeline stages).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

/// Machine description printed by every bench header (Table 2 stand-in).
pub fn machine_summary() -> String {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let model = std::fs::read_to_string("/proc/cpuinfo")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("model name"))
                .map(|l| l.split(':').nth(1).unwrap_or("?").trim().to_string())
        })
        .unwrap_or_else(|| "unknown-cpu".into());
    let cache = std::fs::read_to_string("/sys/devices/system/cpu/cpu0/cache/index3/size")
        .map(|s| s.trim().to_string())
        .unwrap_or_else(|_| "?".into());
    format!("cpu='{model}' threads={cores} llc={cache}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_positive_time() {
        let m = bench_run(1, 5, Duration::from_millis(10), || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(m.robust_min_s > 0.0);
        assert!(m.median_s >= m.robust_min_s);
        assert!(m.iters >= 5);
    }

    #[test]
    fn summarize_tolerates_nan_samples() {
        // Regression: `sort_by(partial_cmp().unwrap())` panicked on NaN.
        let m = summarize(vec![0.3, f64::NAN, 0.1, 0.2, 0.25, 0.15, f64::NAN, 0.35]);
        assert!(m.robust_min_s.is_finite());
        assert!(m.median_s.is_finite());
        assert_eq!(m.iters, 8);
        // NaNs sort last: the robust minimum is the true smallest sample
        assert_eq!(m.robust_min_s, 0.1);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, dt) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }

    #[test]
    fn machine_summary_nonempty() {
        assert!(machine_summary().contains("threads="));
    }
}
