//! In-tree property-based testing harness (proptest substitute; DESIGN.md §5).
//!
//! A property test draws `CASES` random inputs from generator closures over
//! a seeded [`Rng`] and asserts an invariant for each.  On failure it
//! retries with *shrunk* sizes (halving the size hint) to report a small
//! counterexample, then panics with the seed so the case is reproducible.
//!
//! ```ignore
//! check(|rng, size| {
//!     let n = 1 + rng.below(size);
//!     let p = rng.permutation(n);
//!     let inv = invert(&p);
//!     prop_assert!(compose(&p, &inv) == identity(n));
//! });
//! ```

use crate::util::rng::Rng;

/// Number of random cases per property.
pub const CASES: usize = 64;

/// Default size hint passed to the property.
pub const SIZE: usize = 200;

/// Outcome of one property evaluation.
pub type PropResult = Result<(), String>;

/// Assert inside a property; returns early with a message on failure.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !($cond) {
            return Err(format!(
                "assertion failed: {} ({}:{})",
                stringify!($cond),
                file!(),
                line!()
            ));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

/// Run a property over `CASES` seeded random cases with shrinking-on-failure.
///
/// The property receives a fresh RNG (derived from the case index so failures
/// reproduce independent of iteration order) and a size hint.
pub fn check<F>(name: &str, prop: F)
where
    F: Fn(&mut Rng, usize) -> PropResult,
{
    check_with(name, CASES, SIZE, prop)
}

/// As [`check`] with explicit case count and size hint.
pub fn check_with<F>(name: &str, cases: usize, size: usize, prop: F)
where
    F: Fn(&mut Rng, usize) -> PropResult,
{
    // Base seed can be pinned via NNI_PROP_SEED to reproduce a failure.
    let base: u64 = std::env::var("NNI_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA5A5_0000);
    for case in 0..cases {
        let seed = base ^ ((case as u64) << 32) ^ 0x5DEECE66D;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, size) {
            // Shrink: halve the size hint until the property passes or we
            // reach a minimal failing size, and report the smallest failure.
            let mut fail_size = size;
            let mut fail_msg = msg;
            let mut s = size / 2;
            while s >= 2 {
                let mut r2 = Rng::new(seed);
                match prop(&mut r2, s) {
                    Err(m) => {
                        fail_size = s;
                        fail_msg = m;
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, \
                 shrunk size {fail_size}): {fail_msg}\n\
                 reproduce with NNI_PROP_SEED={base}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("rev-rev", |rng, size| {
            let n = 1 + rng.below(size);
            let v: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            prop_assert!(v == w);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", |_rng, _size| {
            prop_assert!(1 == 2, "one is not two");
            Ok(())
        });
    }

    #[test]
    fn shrinking_reports_smaller_size() {
        // A property failing for any size >= 4: shrinker should land <= 4.
        let result = std::panic::catch_unwind(|| {
            check("fails-at-4", |rng, size| {
                let n = 1 + rng.below(size);
                prop_assert!(n < 4, "n too big");
                Ok(())
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // The shrunk size must be well below the default SIZE.
        assert!(msg.contains("shrunk size"), "{msg}");
    }
}
