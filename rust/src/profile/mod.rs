//! Sparsity-profile measures: the patch-density score β (Eq. 2, estimated
//! by a Lagrangian quadtree covering) and the numerical γ-score (Eq. 4),
//! plus profile rasters for the Fig. 2 visuals.

pub mod beta;
pub mod gamma;
pub mod render;
