//! The γ-score (Eq. 4): a numerical estimate of the patch-density measure.
//!
//! ```text
//! γ(A; σ) = 1/(σ·nnz) · Σ_{p,q ∈ Inz(A)} exp(−‖p−q‖²/σ²)
//! ```
//!
//! * [`gamma_exact`] — the O(nnz²) double sum (reference; Fig. 1 scale).
//! * [`gamma_fast`]  — grid-aggregated estimator: nonzero positions are
//!   binned into square cells of side σ/2; each cell contributes its count
//!   and centroid, and cell pairs farther than 3σ are truncated
//!   (exp(−9) < 1.3e-4).  Evaluating the Gaussian at centroid distance is
//!   second-order accurate in the cell diameter, so the estimate tracks the
//!   exact score to ~1% while running in O(nnz + cells·neigh).

use crate::par::pool::ThreadPool;
use crate::sparse::csr::Csr;

/// Exact γ-score by the full double sum.  O(nnz²) — use for validation and
/// small matrices only.
pub fn gamma_exact(a: &Csr, sigma: f64) -> f64 {
    let pos = a.nonzero_positions();
    let nnz = pos.len();
    if nnz == 0 {
        return 0.0;
    }
    let inv_s2 = 1.0 / (sigma * sigma);
    let pool = ThreadPool::with_default();
    let chunk = nnz.div_ceil(pool.threads.max(1)).max(1);
    let ranges: Vec<(usize, usize)> = (0..nnz)
        .step_by(chunk)
        .map(|lo| (lo, (lo + chunk).min(nnz)))
        .collect();
    let partials = pool.map(&ranges, |&(lo, hi)| {
        let mut s = 0.0f64;
        for p in lo..hi {
            let (pi, pj) = pos[p];
            for &(qi, qj) in &pos {
                let di = pi as f64 - qi as f64;
                let dj = pj as f64 - qj as f64;
                s += (-(di * di + dj * dj) * inv_s2).exp();
            }
        }
        s
    });
    let total: f64 = partials.iter().sum();
    total / (sigma * nnz as f64)
}

/// Fast grid-aggregated γ-score (see module docs).
pub fn gamma_fast(a: &Csr, sigma: f64) -> f64 {
    let pos = a.nonzero_positions();
    gamma_fast_positions(&pos, sigma)
}

/// Fast γ over an explicit nonzero position list.
pub fn gamma_fast_positions(pos: &[(u32, u32)], sigma: f64) -> f64 {
    let nnz = pos.len();
    if nnz == 0 {
        return 0.0;
    }
    let cell = (sigma * 0.5).max(1.0);
    let inv_s2 = 1.0 / (sigma * sigma);
    // Truncation radius in cells: 3σ / cell.
    let rad = (3.0 * sigma / cell).ceil() as i64;

    // Aggregate cells: map (ci, cj) -> (count, sum_i, sum_j).
    use std::collections::HashMap;
    let mut cells: HashMap<(i64, i64), (f64, f64, f64)> = HashMap::new();
    for &(i, j) in pos {
        let key = ((i as f64 / cell) as i64, (j as f64 / cell) as i64);
        let e = cells.entry(key).or_insert((0.0, 0.0, 0.0));
        e.0 += 1.0;
        e.1 += i as f64;
        e.2 += j as f64;
    }
    // Cell list with centroids.
    let list: Vec<((i64, i64), f64, f64, f64)> = cells
        .iter()
        .map(|(&k, &(c, si, sj))| (k, c, si / c, sj / c))
        .collect();
    let index: HashMap<(i64, i64), usize> = list
        .iter()
        .enumerate()
        .map(|(t, &(k, _, _, _))| (k, t))
        .collect();

    let pool = ThreadPool::with_default();
    let chunk = list.len().div_ceil(pool.threads.max(1)).max(1);
    let ranges: Vec<(usize, usize)> = (0..list.len())
        .step_by(chunk)
        .map(|lo| (lo, (lo + chunk).min(list.len())))
        .collect();
    let partials = pool.map(&ranges, |&(lo, hi)| {
        let mut s = 0.0f64;
        for t in lo..hi {
            let ((ci, cj), cnt, mi, mj) = list[t];
            for di in -rad..=rad {
                for dj in -rad..=rad {
                    if let Some(&u) = index.get(&(ci + di, cj + dj)) {
                        let (_, cnt2, ni, nj) = list[u];
                        let dx = mi - ni;
                        let dy = mj - nj;
                        let w = (-(dx * dx + dy * dy) * inv_s2).exp();
                        s += cnt * cnt2 * w;
                    }
                }
            }
        }
        s
    });
    let total: f64 = partials.iter().sum();
    total / (sigma * nnz as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::util::rng::Rng;

    #[test]
    fn single_nonzero_self_pair() {
        let a = Csr::from_triplets(4, 4, &[1], &[2], &[1.0]);
        // one self-pair: exp(0)=1 → γ = 1/(σ·1)
        let g = gamma_exact(&a, 2.0);
        assert!((g - 0.5).abs() < 1e-12);
    }

    #[test]
    fn exact_vs_fast_within_tolerance() {
        for (n, per_row, seed) in [(60, 4, 1u64), (80, 6, 2), (50, 8, 3)] {
            let a = gen::scattered(n, per_row, seed);
            let sigma = 5.0;
            let ge = gamma_exact(&a, sigma);
            let gf = gamma_fast(&a, sigma);
            let rel = (ge - gf).abs() / ge;
            assert!(rel < 0.05, "n={n}: exact {ge} vs fast {gf} rel {rel}");
        }
    }

    #[test]
    fn exact_vs_fast_on_banded() {
        let a = gen::banded(80, 6, 4);
        let ge = gamma_exact(&a, 4.0);
        let gf = gamma_fast(&a, 4.0);
        assert!((ge - gf).abs() / ge < 0.05, "{ge} vs {gf}");
    }

    #[test]
    fn banded_beats_scattered() {
        // The whole point of the measure: locality-friendly profiles score
        // higher at equal size and nnz.
        let banded = gen::banded(150, 8, 5);
        let scattered = gen::scattered(150, 8, 5);
        let gb = gamma_fast(&banded, 4.0);
        let gs = gamma_fast(&scattered, 4.0);
        assert!(gb > 2.0 * gs, "banded {gb} !>> scattered {gs}");
    }

    #[test]
    fn fig1_monotonicity_block_perm_invariance() {
        // Fig. 1: (a) arrowhead and (b) block-permuted have ~equal γ;
        // (c) row-scrambled drops; (d) fully scrambled drops further.
        let a = gen::block_arrowhead(200, 20, 1);
        let b = gen::permute_blocks(&a, 20, 2);
        let mut rng = Rng::new(3);
        let rp = rng.permutation(200);
        let c = b.permuted(&rp, &(0..200).collect::<Vec<_>>());
        let cp = rng.permutation(200);
        let d = c.permuted(&(0..200).collect::<Vec<_>>(), &cp);
        let s = 10.0;
        let (ga, gb_, gc, gd) = (
            gamma_fast(&a, s),
            gamma_fast(&b, s),
            gamma_fast(&c, s),
            gamma_fast(&d, s),
        );
        assert!((ga - gb_).abs() / ga < 0.1, "a {ga} vs b {gb_}");
        assert!(gc < 0.8 * ga, "c {gc} !< a {ga}");
        assert!(gd < 0.8 * gc, "d {gd} !< c {gc}");
    }

    #[test]
    fn empty_matrix_zero() {
        let a = Csr::from_triplets(5, 5, &[], &[], &[]);
        assert_eq!(gamma_exact(&a, 3.0), 0.0);
        assert_eq!(gamma_fast(&a, 3.0), 0.0);
    }
}
