//! Profile rasters for the Fig. 2 visuals: nonzero density on a g×g grid,
//! written as PGM (inspectable anywhere) and CSV (for plotting).

use crate::sparse::csr::Csr;
use std::io::Write;
use std::path::Path;

/// Density raster: counts of nonzeros per grid cell, row-major g×g.
pub fn density_grid(a: &Csr, g: usize) -> Vec<u32> {
    let mut grid = vec![0u32; g * g];
    let rs = a.rows.max(1) as f64;
    let cs = a.cols.max(1) as f64;
    for i in 0..a.rows {
        let (cols, _) = a.row(i);
        let gi = ((i as f64 / rs) * g as f64) as usize;
        for &j in cols {
            let gj = ((j as f64 / cs) * g as f64) as usize;
            grid[gi.min(g - 1) * g + gj.min(g - 1)] += 1;
        }
    }
    grid
}

/// Write the raster as an 8-bit PGM (dark = dense), log-scaled.
pub fn write_pgm(grid: &[u32], g: usize, path: &Path) -> std::io::Result<()> {
    let max = *grid.iter().max().unwrap_or(&1) as f64;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "P5\n{g} {g}\n255")?;
    let scale = if max > 0.0 { 255.0 / (1.0 + max).ln() } else { 0.0 };
    let bytes: Vec<u8> = grid
        .iter()
        .map(|&c| 255 - ((1.0 + c as f64).ln() * scale) as u8)
        .collect();
    f.write_all(&bytes)
}

/// Write the raster as CSV rows `gi,gj,count` (nonzero cells only).
pub fn write_csv(grid: &[u32], g: usize, path: &Path) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "row_cell,col_cell,count")?;
    for gi in 0..g {
        for gj in 0..g {
            let c = grid[gi * g + gj];
            if c > 0 {
                writeln!(f, "{gi},{gj},{c}")?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn grid_total_equals_nnz() {
        let a = gen::scattered(100, 5, 1);
        let grid = density_grid(&a, 16);
        let total: u32 = grid.iter().sum();
        assert_eq!(total as usize, a.nnz());
    }

    #[test]
    fn banded_mass_on_diagonal() {
        let a = gen::banded(128, 6, 2);
        let g = 16;
        let grid = density_grid(&a, g);
        // band half-width crosses cell boundaries: count the tridiagonal
        // cell band
        let mut band = 0u32;
        for i in 0..g {
            for j in i.saturating_sub(1)..=(i + 1).min(g - 1) {
                band += grid[i * g + j];
            }
        }
        let total: u32 = grid.iter().sum();
        assert!(band as f64 > 0.95 * total as f64);
    }

    #[test]
    fn pgm_and_csv_written() {
        let a = gen::banded(64, 4, 3);
        let grid = density_grid(&a, 8);
        let dir = std::env::temp_dir();
        let pgm = dir.join("nni_test_profile.pgm");
        let csv = dir.join("nni_test_profile.csv");
        write_pgm(&grid, 8, &pgm).unwrap();
        write_csv(&grid, 8, &csv).unwrap();
        assert!(std::fs::read(&pgm).unwrap().starts_with(b"P5"));
        let body = std::fs::read_to_string(&csv).unwrap();
        assert!(body.starts_with("row_cell"));
        std::fs::remove_file(pgm).ok();
        std::fs::remove_file(csv).ok();
    }
}
