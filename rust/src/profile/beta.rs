//! Patch-density measure β (Eq. 2), estimated by a Lagrangian quadtree
//! covering.
//!
//! Exact β maximizes, over all non-overlapping patch coverings {B_ℓ} of the
//! nonzeros,  (1/|{B_ℓ}|) · nnz/area({B_ℓ})  — NP-hard in general (§2.3).
//! Maximizing β is equivalent to minimizing  |cover| · area(cover).  We
//! search coverings drawn from a quadtree decomposition of the index square:
//! for a penalty λ ≥ 0, dynamic programming computes the covering that
//! minimizes  area + λ·count  (each node chooses "one patch = tight
//! bounding box of my nonzeros" or "union of children's coverings"); a
//! sweep over λ traces the count/area Pareto frontier and the best β over
//! the frontier is returned.  The result is a *lower bound* on β restricted
//! to quadtree-aligned patches — exact on constructions like Fig. 1(a).
//!
//! **Deviation from the literal Eq. 2** (documented in DESIGN.md): as
//! printed, Eq. 2 is degenerate — the single whole-matrix patch scores
//! `nnz/area(A)`, identical for *every* ordering, and dominates dense-block
//! coverings for any moderately dense matrix, contradicting the paper's own
//! Fig. 1 ranking.  We therefore impose the qualification the §2.1 principle
//! states: a patch must be **dense** (density ≥ [`DENSE_TAU`]) to be chosen;
//! nodes that cannot split further always qualify.  With this constraint the
//! measure reproduces the Fig. 1 ordering ranking exactly.

use crate::sparse::csr::Csr;

/// Minimum density for a quadtree node to qualify as a single patch.
pub const DENSE_TAU: f64 = 0.5;

/// A patch covering: score plus the chosen patches (row0, col0, rows, cols).
#[derive(Clone, Debug)]
pub struct Covering {
    pub beta: f64,
    pub count: usize,
    pub area: u64,
    pub patches: Vec<(u32, u32, u32, u32)>,
}

struct QNode {
    /// Tight bounding box of nonzeros inside: (imin, imax, jmin, jmax).
    bbox: (u32, u32, u32, u32),
    nnz: u64,
    children: Vec<usize>,
}

/// Estimate β(A) for the matrix in its current ordering.
pub fn beta_estimate(a: &Csr) -> Covering {
    let pos = a.nonzero_positions();
    if pos.is_empty() {
        return Covering {
            beta: 0.0,
            count: 0,
            area: 0,
            patches: Vec::new(),
        };
    }
    // Build the quadtree over the index square [0, side)² with side a power
    // of two ≥ max(rows, cols); leaves at ≥1 nonzero and size 1 or uniform.
    let side = a.rows.max(a.cols).next_power_of_two() as u32;
    let mut nodes: Vec<QNode> = Vec::new();
    build(&pos, 0, 0, side, &mut nodes);

    let nnz = pos.len() as f64;
    // λ sweep (geometric): small λ → many small dense patches; large λ →
    // few big patches. The frontier is small; 40 points suffice.
    let mut best: Option<Covering> = None;
    let mut lambda = 0.25f64;
    for _ in 0..40 {
        let (area, count) = dp_cost(&nodes, 0, lambda);
        let beta = nnz / (count as f64 * area as f64);
        let better = match &best {
            None => true,
            Some(b) => beta > b.beta,
        };
        if better {
            let mut patches = Vec::new();
            collect(&nodes, 0, lambda, &mut patches);
            best = Some(Covering {
                beta,
                count,
                area,
                patches,
            });
        }
        lambda *= 1.6;
    }
    best.unwrap()
}

fn build(pos: &[(u32, u32)], i0: u32, j0: u32, side: u32, nodes: &mut Vec<QNode>) -> usize {
    let mut imin = u32::MAX;
    let mut imax = 0u32;
    let mut jmin = u32::MAX;
    let mut jmax = 0u32;
    for &(i, j) in pos {
        imin = imin.min(i);
        imax = imax.max(i);
        jmin = jmin.min(j);
        jmax = jmax.max(j);
    }
    let id = nodes.len();
    nodes.push(QNode {
        bbox: (imin, imax, jmin, jmax),
        nnz: pos.len() as u64,
        children: Vec::new(),
    });
    let bbox_area =
        (imax - imin + 1) as u64 * (jmax - jmin + 1) as u64;
    // Stop when dense-enough or indivisible (density 1 patches can't
    // improve by splitting).
    if side <= 1 || pos.len() as u64 == bbox_area || pos.len() <= 2 {
        return id;
    }
    let h = side / 2;
    let (ic, jc) = (i0 + h, j0 + h);
    let mut quads: [Vec<(u32, u32)>; 4] = Default::default();
    for &(i, j) in pos {
        let q = ((i >= ic) as usize) * 2 + ((j >= jc) as usize);
        quads[q].push((i, j));
    }
    let mut children = Vec::new();
    for (q, qpos) in quads.iter().enumerate() {
        if qpos.is_empty() {
            continue;
        }
        let qi = i0 + if q >= 2 { h } else { 0 };
        let qj = j0 + if q % 2 == 1 { h } else { 0 };
        children.push(build(qpos, qi, qj, h, nodes));
    }
    nodes[id].children = children;
    id
}

/// DP: minimal (area + λ·count) covering of node's nonzeros; returns
/// (area, count) of the argmin.
fn dp_cost(nodes: &[QNode], id: usize, lambda: f64) -> (u64, usize) {
    let nd = &nodes[id];
    let own_area = (nd.bbox.1 - nd.bbox.0 + 1) as u64 * (nd.bbox.3 - nd.bbox.2 + 1) as u64;
    if nd.children.is_empty() {
        return (own_area, 1);
    }
    let mut child_area = 0u64;
    let mut child_count = 0usize;
    for &c in &nd.children {
        let (a, k) = dp_cost(nodes, c, lambda);
        child_area += a;
        child_count += k;
    }
    // The dense-block qualification: only dense nodes may stop splitting.
    let qualifies = nd.nnz as f64 >= DENSE_TAU * own_area as f64;
    let own_cost = own_area as f64 + lambda;
    let child_cost = child_area as f64 + lambda * child_count as f64;
    if qualifies && own_cost <= child_cost {
        (own_area, 1)
    } else {
        (child_area, child_count)
    }
}

fn collect(nodes: &[QNode], id: usize, lambda: f64, out: &mut Vec<(u32, u32, u32, u32)>) {
    let nd = &nodes[id];
    let own_area = (nd.bbox.1 - nd.bbox.0 + 1) as u64 * (nd.bbox.3 - nd.bbox.2 + 1) as u64;
    let as_patch = |out: &mut Vec<(u32, u32, u32, u32)>| {
        out.push((
            nd.bbox.0,
            nd.bbox.2,
            nd.bbox.1 - nd.bbox.0 + 1,
            nd.bbox.3 - nd.bbox.2 + 1,
        ))
    };
    if nd.children.is_empty() {
        as_patch(out);
        return;
    }
    let mut child_area = 0u64;
    let mut child_count = 0usize;
    for &c in &nd.children {
        let (a, k) = dp_cost(nodes, c, lambda);
        child_area += a;
        child_count += k;
    }
    let qualifies = nd.nnz as f64 >= DENSE_TAU * own_area as f64;
    if qualifies && own_area as f64 + lambda <= child_area as f64 + lambda * child_count as f64 {
        as_patch(out);
    } else {
        for &c in &nd.children {
            collect(nodes, c, lambda, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::util::rng::Rng;

    #[test]
    fn full_dense_block_single_patch() {
        // one 8x8 dense block in a 32x32 matrix → best covering: 1 patch,
        // area 64, β = 64/(1·64) = 1.
        let mut r = Vec::new();
        let mut c = Vec::new();
        for i in 8..16u32 {
            for j in 16..24u32 {
                r.push(i);
                c.push(j);
            }
        }
        let v = vec![1.0f32; r.len()];
        let a = Csr::from_triplets(32, 32, &r, &c, &v);
        let cov = beta_estimate(&a);
        assert_eq!(cov.count, 1);
        assert!((cov.beta - 1.0).abs() < 1e-12, "beta {}", cov.beta);
    }

    #[test]
    fn arrowhead_scores_near_ideal() {
        // Fig. 1(a): 73 full 20x20 blocks in 500² (here 200², 28 blocks):
        // β̂ = nnz/(count·area) with count≈#blocks, area≈nnz.
        let a = gen::block_arrowhead(200, 20, 1);
        let nblocks = 10 + 2 * 9; // diag + first row + first col
        let cov = beta_estimate(&a);
        let ideal = 1.0 / nblocks as f64;
        assert!(
            cov.beta > 0.5 * ideal,
            "beta {} far below ideal {}",
            cov.beta,
            ideal
        );
    }

    #[test]
    fn ordering_monotonicity_matches_fig1() {
        let a = gen::block_arrowhead(200, 20, 1);
        let mut rng = Rng::new(7);
        let rp = rng.permutation(200);
        let id: Vec<usize> = (0..200).collect();
        let c = a.permuted(&rp, &id);
        let cp = rng.permutation(200);
        let d = c.permuted(&id, &cp);
        let ba = beta_estimate(&a).beta;
        let bc = beta_estimate(&c).beta;
        let bd = beta_estimate(&d).beta;
        assert!(ba > bc, "a {ba} !> c {bc}");
        assert!(bc >= bd, "c {bc} !>= d {bd}");
    }

    #[test]
    fn covering_covers_all_nonzeros() {
        let a = gen::scattered(64, 4, 9);
        let cov = beta_estimate(&a);
        for (i, j) in a.nonzero_positions() {
            let inside = cov.patches.iter().any(|&(r0, c0, rh, cw)| {
                i >= r0 && i < r0 + rh && j >= c0 && j < c0 + cw
            });
            assert!(inside, "nonzero ({i},{j}) uncovered");
        }
    }

    #[test]
    fn empty_matrix() {
        let a = Csr::from_triplets(4, 4, &[], &[], &[]);
        let cov = beta_estimate(&a);
        assert_eq!(cov.count, 0);
        assert_eq!(cov.beta, 0.0);
    }
}
