//! `nni` — command-line leader for the hierarchical near-neighbor
//! interaction system.
//!
//! Subcommands:
//! * `info`      — print testbed + artifact registry summary
//! * `synth`     — generate a synthetic dataset to a file
//! * `knn`       — build a kNN graph (exact or ann), report time + recall
//! * `reorder`   — run an ordering pipeline, report γ/β̂ and profile stats
//! * `gamma`     — γ-score of a dataset's interaction matrix per ordering
//! * `spmv`      — time multi-level SpMV vs CSR baselines
//! * `tsne`      — run t-SNE end to end (hybrid PJRT path optional)
//! * `meanshift` — run mean shift, report modes
//! * `krr`       — kernel ridge regression over the full-kernel operator
//! * `update`    — stream delete/insert batches through versioned epochs
//! * `serve`     — fault-tolerant serving daemon: sharded epoch workers
//!   with admission control, deadlines, and deterministic fault injection
//!   (`--load-gen` records p50/p99 + shed/retry counters to
//!   `BENCH_serve.json`; `--smoke` is the CI drill)
//!
//! The `knn`, `reorder`, `tsne`, and `meanshift` commands accept
//! `--knn exact|ann` plus the `--ann-*` tuning knobs (see
//! `knn::ann::AnnParams`); `gamma` and `spmv` always use the exact
//! backend (their outputs are figure reproductions).  `reorder`, `spmv`,
//! and `krr` accept the far-field knobs (`--far off|aca|h2`,
//! `--precision f32|bf16`, `--tol`, `--eta`, `--bandwidth`) of the
//! `hmat` full-kernel subsystem.

use nni::apps::{krr, meanshift, tsne};
use nni::bench::Workload;
use nni::csb::kernel::KernelKind;
use nni::data::dataset::Dataset;
use nni::data::synth::SynthSpec;
use nni::hmat::{FarFieldMode, FullKernelConfig, Precision};
use nni::interact::epoch::{UpdatableEngine, UpdatableKernelEngine, UpdateCfg};
use nni::knn::ann::recall::recall_at_k;
use nni::knn::ann::AnnParams;
use nni::knn::exact::knn_graph;
use nni::knn::KnnBackend;
use nni::obs::{self, counters};
use nni::order::{OrderingKind, Pipeline};
use nni::profile::{beta, gamma};
use nni::runtime::ArtifactRegistry;
use nni::serve::{loadgen, FaultPlan, Payload, Query, ServeConfig, Server};
use nni::sparse::csr::Csr;
use nni::spmv;
use nni::tree::boxtree::BoxTree;
use nni::tree::update::UpdateBatch;
use nni::util::cli::Args;
use nni::util::json::{arr, num, obj, s};
use nni::util::rng::Rng;
use nni::util::timer;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if argv.is_empty() {
        "help".to_string()
    } else {
        argv.remove(0)
    };
    match cmd.as_str() {
        "info" => cmd_info(),
        "synth" => cmd_synth(argv),
        "knn" => cmd_knn(argv),
        "reorder" => cmd_reorder(argv),
        "gamma" => cmd_gamma(argv),
        "spmv" => cmd_spmv(argv),
        "tsne" => cmd_tsne(argv),
        "meanshift" => cmd_meanshift(argv),
        "krr" => cmd_krr(argv),
        "update" => cmd_update(argv),
        "serve" => cmd_serve(argv),
        "stats" => cmd_stats(argv),
        "trace-check" => cmd_trace_check(argv),
        "bench-check" => cmd_bench_check(argv),
        _ => {
            eprintln!(
                "usage: nni <info|synth|knn|reorder|gamma|spmv|tsne|meanshift|krr|update|serve|\
                 stats|trace-check|bench-check> [options]\n\
                 run `nni <cmd> --help` for per-command options"
            );
            std::process::exit(if cmd == "help" { 0 } else { 2 });
        }
    }
}

/// Shared observability option block, threaded through every subcommand:
/// either flag enables span tracing for the run; the files are written by
/// [`obs_end`] when the command body finishes.
fn obs_opts(a: Args) -> Args {
    a.opt("trace-out", "", "write Chrome trace-event JSON here (enables tracing)")
        .opt("metrics-out", "", "write a flat counter-snapshot JSON here")
}

/// Pre-size the span slabs and enable tracing when either obs flag is set
/// (call right after parsing, before any traced work).
fn obs_begin(a: &Args) {
    if !a.get("trace-out").is_empty() || !a.get("metrics-out").is_empty() {
        obs::install(nni::par::pool::default_threads(), obs::DEFAULT_SPAN_CAP);
        obs::set_enabled(true);
    }
}

/// Write the requested trace/metrics files at the end of a command.
fn obs_end(a: &Args) {
    let trace = a.get("trace-out");
    if !trace.is_empty() {
        match obs::export::write_trace(&trace) {
            Ok(()) => println!("trace -> {trace}"),
            Err(e) => eprintln!("trace write failed ({trace}): {e}"),
        }
    }
    let metrics = a.get("metrics-out");
    if !metrics.is_empty() {
        match obs::export::write_metrics(&metrics) {
            Ok(()) => println!("metrics -> {metrics}"),
            Err(e) => eprintln!("metrics write failed ({metrics}): {e}"),
        }
    }
}

/// Shared `--knn`/`--ann-*` option block for profile-building commands.
fn knn_opts(a: Args) -> Args {
    a.opt("knn", "exact", "knn backend: exact|ann")
        .opt_usize_min("ann-trees", 8, 1, "ann: projection trees")
        .opt_usize_min("ann-leaf", 64, 1, "ann: leaf bucket capacity")
        .opt_usize("ann-iters", 10, "ann: max NN-descent passes")
}

/// Shared `--build-threads` knob: worker count of the ordering-pipeline
/// build side (PCA, tree construction, CSB assembly) — results are
/// bit-identical across counts.
fn build_opts(a: Args) -> Args {
    a.opt_usize(
        "build-threads",
        0,
        "build-side workers (PCA/tree/CSB; 0 = follow --threads)",
    )
}

/// Build-side worker count: explicit `--build-threads`, else `--threads`
/// (either may be 0 = machine default) — same fallback as the app configs,
/// so capping `--threads` also caps the build phase.
fn resolve_build_threads(a: &Args) -> usize {
    let bt = a.get_usize("build-threads");
    if bt != 0 {
        bt
    } else {
        a.get_usize("threads")
    }
}

/// Shared `--kernel` knob: apply-side micro-kernel dispatch.  `scalar`
/// pins the bit-exact reference path (deterministic down to the bit across
/// thread counts *and* machines); `auto`/`simd` use AVX2+FMA when the CPU
/// has it (tolerance-equal to scalar; see EXPERIMENTS.md §Kernel dispatch).
fn kernel_opts(a: Args) -> Args {
    a.opt("kernel", "auto", "apply kernel: auto|simd|scalar (scalar = bit-exact)")
}

/// Resolve the `--kernel` choice (usage error on bad values).
fn kernel_kind(a: &Args) -> KernelKind {
    KernelKind::parse(&a.get("kernel")).unwrap_or_else(die)
}

/// One-line dispatch report for the perf commands.
fn kernel_line(kind: KernelKind) -> String {
    let (dispatch, fallback) = kind.resolve();
    match fallback {
        Some(why) => format!(
            "kernel: requested={} dispatch={} (fallback: {why})",
            kind.label(),
            dispatch.label()
        ),
        None => format!("kernel: requested={} dispatch={}", kind.label(), dispatch.label()),
    }
}

/// Shared far-field option block (`hmat` full-kernel subsystem).  The
/// default differs per command: `krr` is *about* the full kernel (aca),
/// the figure-reproduction commands opt in (off).
fn far_opts(a: Args, default: &'static str) -> Args {
    a.opt("far", default, "far field: off|aca|h2 (aca/h2 = full-kernel mode)")
        .opt("precision", "f32", "far-field factor storage: f32|bf16 (h2 only)")
        .opt_f64("tol", 1e-3, "ACA relative tolerance per far block")
        .opt_f64("eta", 1.0, "admissibility parameter (bigger = more far field)")
        .opt_f64("bandwidth", 0.0, "gaussian bandwidth h (0 = median-distance auto)")
}

/// Resolve the `--far` choice (usage error on bad values).
fn far_mode(a: &Args) -> FarFieldMode {
    FarFieldMode::parse(&a.get("far")).unwrap_or_else(|e| die(format!("--far: {e}")))
}

/// Resolve the `--precision` choice (usage error on bad values).
fn precision(a: &Args) -> Precision {
    Precision::parse(&a.get("precision")).unwrap_or_else(|e| die(format!("--precision: {e}")))
}

/// Resolve the full-kernel config from the `far_opts` block (`None` when
/// `--far off`): bandwidth auto-resolves via the median heuristic.
fn full_kernel_cfg(a: &Args, ds: &Dataset, block_cap: usize) -> Option<(FullKernelConfig, f64)> {
    if far_mode(a) == FarFieldMode::Off {
        return None;
    }
    let h = if a.get_f64("bandwidth") > 0.0 {
        a.get_f64("bandwidth")
    } else {
        krr::suggest_bandwidth(ds, a.get_u64("seed"))
    };
    let cfg = FullKernelConfig::new((1.0 / (h * h)) as f32)
        .with_eta(a.get_f64("eta") as f32)
        .with_tol(a.get_f64("tol") as f32)
        .with_block_cap(block_cap)
        .with_far(far_mode(a))
        .with_precision(precision(a));
    Some((cfg, h))
}

/// Resolve the backend selected by the `--knn`/`--ann-*` options.
fn knn_backend(a: &Args) -> KnnBackend {
    match a.get("knn").to_ascii_lowercase().as_str() {
        "exact" => KnnBackend::Exact,
        "ann" => KnnBackend::Ann(AnnParams {
            trees: a.get_usize("ann-trees"),
            leaf_cap: a.get_usize("ann-leaf"),
            descent_iters: a.get_usize("ann-iters"),
            seed: a.get_u64("seed"),
            ..AnnParams::default()
        }),
        other => {
            eprintln!("unknown knn backend '{other}' (exact|ann)");
            std::process::exit(2);
        }
    }
}

fn workload(name: &str) -> Workload {
    match name.to_ascii_lowercase().as_str() {
        "sift" => Workload::Sift,
        "gist" => Workload::Gist,
        other => {
            eprintln!("unknown workload '{other}' (sift|gist)");
            std::process::exit(2);
        }
    }
}

fn ordering(name: &str) -> OrderingKind {
    match name.to_ascii_lowercase().as_str() {
        "rand" | "scattered" => OrderingKind::Scattered,
        "rcm" => OrderingKind::Rcm,
        "1d" | "pca1d" => OrderingKind::Pca1d,
        "2dlex" => OrderingKind::Lex { d: 2 },
        "3dlex" => OrderingKind::Lex { d: 3 },
        "2ddt" => OrderingKind::DualTree { d: 2 },
        "3ddt" | "dualtree" => OrderingKind::DualTree { d: 3 },
        "morton" => OrderingKind::Morton { d: 3 },
        other => {
            eprintln!("unknown ordering '{other}'");
            std::process::exit(2);
        }
    }
}

fn cmd_info() {
    println!("nni — hierarchical near-neighbor interactions");
    println!("testbed: {}", timer::machine_summary());
    match ArtifactRegistry::open_default() {
        Ok(reg) => {
            println!("pjrt: {} platform", reg.runtime().platform());
            let mut names: Vec<&String> = reg.variants.keys().collect();
            names.sort();
            println!("artifacts ({}):", names.len());
            for n in names {
                println!("  {n}");
            }
        }
        Err(e) => println!("artifacts: unavailable ({e:#})"),
    }
}

fn cmd_synth(argv: Vec<String>) {
    let a = obs_opts(
        Args::new("generate a synthetic dataset")
            .opt("workload", "sift", "sift|gist")
            .opt_usize_min("n", 4096, 1, "number of points")
            .opt_u64("seed", 42, "rng seed")
            .opt("out", "dataset.nnid", "output path"),
    )
    .parse_from(argv)
    .unwrap_or_else(die);
    obs_begin(&a);
    let ds = workload(&a.get("workload")).make_dataset(a.get_usize("n"), a.get_u64("seed"));
    ds.save(Path::new(&a.get("out"))).expect("write dataset");
    println!("wrote {} points (d={}) to {}", ds.n(), ds.d(), a.get("out"));
    obs_end(&a);
}

fn load_or_synth(a: &Args) -> Dataset {
    let input = a.get("input");
    if !input.is_empty() {
        return Dataset::load(Path::new(&input)).expect("load dataset");
    }
    workload(&a.get("workload")).make_dataset(a.get_usize("n"), a.get_u64("seed"))
}

fn cmd_knn(argv: Vec<String>) {
    let a = obs_opts(knn_opts(
        Args::new("build a kNN graph and measure backend quality")
            .opt("input", "", "dataset file (else synthesize)")
            .opt("workload", "sift", "sift|gist")
            .opt_usize_min("n", 4096, 1, "points when synthesizing")
            .opt_usize_min("k", 10, 1, "neighbors")
            .opt_u64("seed", 42, "rng seed")
            .opt_usize("threads", 0, "0 = all cores")
            .opt_usize("recall-sample", 256, "recall queries vs exact (0 = skip)"),
    ))
    .parse_from(argv)
    .unwrap_or_else(die);
    obs_begin(&a);
    let ds = load_or_synth(&a);
    if ds.n() < 2 {
        die::<()>("knn needs at least 2 points".into());
    }
    let k = a.get_usize("k").clamp(1, ds.n() - 1);
    let backend = knn_backend(&a);
    let (g, t) = timer::time_once(|| backend.build(&ds, k, a.get_usize("threads")));
    println!(
        "knn backend={} n={} d={} k={}  build {t:.2}s",
        backend.label(),
        ds.n(),
        ds.d(),
        k
    );
    let sample = a.get_usize("recall-sample");
    if sample > 0 {
        let rep = recall_at_k(&ds, &g, sample, a.get_u64("seed"), a.get_usize("threads"));
        println!(
            "recall@{k} = {:.4} over {} queries (kth-dist ratio {:.3})",
            rep.recall, rep.sampled, rep.dist_ratio
        );
    }
    obs_end(&a);
}

fn cmd_reorder(argv: Vec<String>) {
    let opts = kernel_opts(build_opts(knn_opts(
        Args::new("ordering pipeline report")
            .opt("input", "", "dataset file (else synthesize)")
            .opt("workload", "sift", "sift|gist")
            .opt_usize_min("n", 4096, 1, "points when synthesizing")
            .opt_usize("k", 0, "neighbors (0 = workload default)")
            .opt("ordering", "3ddt", "rand|rcm|1d|2dlex|3dlex|3ddt|morton")
            .opt_usize_min("leaf-cap", 256, 1, "tree leaf capacity")
            .opt_usize_min("rhs", 1, 1, "multi-RHS width: >1 times batched spmm vs k scalar spmv")
            .opt_u64("seed", 42, "rng seed")
            .opt_usize("threads", 0, "0 = all cores"),
    )));
    let a = obs_opts(far_opts(opts, "off")).parse_from(argv).unwrap_or_else(die);
    obs_begin(&a);
    // validate the kernel, far-mode, and precision choices up front —
    // before the expensive kNN build
    let kernel = kernel_kind(&a);
    let _ = far_mode(&a);
    let _ = precision(&a);
    let ds = load_or_synth(&a);
    let k = if a.get_usize("k") == 0 {
        workload(&a.get("workload")).k()
    } else {
        a.get_usize("k")
    };
    let backend = knn_backend(&a);
    let (g, t_knn) =
        timer::time_once(|| backend.build(&ds, k.min(ds.n() - 1), a.get_usize("threads")));
    let m = Csr::from_knn(&g, ds.n()).symmetrized();
    let kind = ordering(&a.get("ordering"));
    let build_threads = resolve_build_threads(&a);
    let pipe = Pipeline::new(kind.clone())
        .with_seed(a.get_u64("seed"))
        .with_build_threads(build_threads);
    let (r, t_order) = timer::time_once(|| pipe.run(&ds, &m));
    let sigma = k as f64 / 2.0;
    let gm = gamma::gamma_fast(&r.reordered, sigma);
    let bt = beta::beta_estimate(&r.reordered);
    println!(
        "ordering={} knn={} n={} k={} nnz={}",
        kind.label(),
        backend.label(),
        ds.n(),
        k,
        m.nnz()
    );
    println!("knn: {t_knn:.2}s  reorder: {t_order:.2}s");
    println!("gamma(sigma={sigma}) = {gm:.2}");
    println!("beta-hat = {:.5} ({} patches, area {})", bt.beta, bt.count, bt.area);
    println!("bandwidth = {}", r.reordered.bandwidth());
    let threads = a.get_usize("threads");
    if let Some(eng) = r.engine_with(a.get_usize("leaf-cap"), 0.6, build_threads, threads, kernel) {
        let csb = &eng.csb;
        println!("csb: {}", csb.describe());
        // coverage/fill from the global observability snapshot — the same
        // numbers `--metrics-out` exports, instead of a second recompute
        let snap = counters::snapshot();
        let (covered, total) = (snap.get("csb.covered_area"), snap.get("csb.total_area"));
        println!(
            "coverage: stored blocks span {covered} of {total} entries ({:.2}%); \
             the rest is the dropped far field (--far aca compresses it)",
            snap.covered_fraction() * 100.0
        );
        println!(
            "fill: dense blocks {:.1}% occupied over {} tree levels",
            snap.dense_fill_ratio() * 100.0,
            snap.levels.len()
        );
        println!("{}", kernel_line(kernel));
        let k = a.get_usize("rhs");
        if k > 1 {
            let n = ds.n();
            let x1 = vec![1.0f32; n];
            let mut y1 = vec![0.0f32; n];
            let xk = vec![1.0f32; n * k];
            let mut yk = vec![0.0f32; n * k];
            let m_scalar = timer::bench_default(|| {
                for _ in 0..k {
                    spmv::multilevel::spmv_ml_seq(csb, &x1, &mut y1);
                }
            });
            let m_spmm =
                timer::bench_default(|| spmv::multilevel::spmm_ml_seq(csb, &xk, &mut yk, k));
            // the engine path: precompiled schedule + dispatched kernel
            let m_eng = timer::bench_default(|| eng.spmm(&xk, &mut yk, k));
            println!(
                "multi-rhs k={k}: scalar {:.3} ms  batched {:.3} ms  ({:.2}x)  engine({}) {:.3} ms",
                m_scalar.robust_min_s * 1e3,
                m_spmm.robust_min_s * 1e3,
                m_scalar.robust_min_s / m_spmm.robust_min_s,
                eng.dispatch().label(),
                m_eng.robust_min_s * 1e3
            );
        }
    }
    if let Some((cfg, h)) = full_kernel_cfg(&a, &ds, a.get_usize("leaf-cap")) {
        let (fk, t_fk) =
            timer::time_once(|| r.full_kernel_engine(&ds, &cfg, build_threads, threads, kernel));
        match fk {
            Some(fk) => {
                println!("full-kernel (h={h:.4}): {}", fk.describe());
                println!(
                    "full-kernel build {t_fk:.2}s, stored {} bytes (near + far factors)",
                    fk.stored_bytes()
                );
                if cfg.far == FarFieldMode::H2 {
                    let snap = counters::snapshot();
                    println!(
                        "h2: basis_ranks={} transfer_bytes={} coupling_blocks={} \
                         f32_bytes={} bf16_bytes={}",
                        snap.get("hmat.h2.basis_ranks"),
                        snap.get("hmat.h2.transfer_bytes"),
                        snap.get("hmat.h2.coupling_blocks"),
                        snap.get("hmat.h2.f32_bytes"),
                        snap.get("hmat.h2.bf16_bytes")
                    );
                }
            }
            None => println!("full-kernel: unavailable (ordering carries no tree)"),
        }
    }
    obs_end(&a);
}

fn cmd_gamma(argv: Vec<String>) {
    let a = obs_opts(
        Args::new("gamma scores across orderings (Table 1 row)")
            .opt("workload", "sift", "sift|gist")
            .opt_usize_min("n", 4096, 1, "points")
            .opt_u64("seed", 42, "rng seed")
            .opt_usize("threads", 0, "0 = all cores"),
    )
    .parse_from(argv)
    .unwrap_or_else(die);
    obs_begin(&a);
    let wl = workload(&a.get("workload"));
    let (ds, m) = wl.make(a.get_usize("n"), a.get_u64("seed"), a.get_usize("threads"));
    let sigma = wl.k() as f64 / 2.0;
    print!("{} k={}  ", wl.name(), wl.k());
    for kind in OrderingKind::table1_set() {
        let r = Pipeline::new(kind.clone()).with_seed(a.get_u64("seed")).run(&ds, &m);
        let gm = gamma::gamma_fast(&r.reordered, sigma);
        print!("{}={gm:.1}  ", kind.label());
    }
    println!();
    obs_end(&a);
}

fn cmd_spmv(argv: Vec<String>) {
    let opts = kernel_opts(build_opts(
        Args::new("multi-level SpMV timing")
            .opt("workload", "sift", "sift|gist")
            .opt_usize_min("n", 8192, 1, "points")
            .opt_u64("seed", 42, "rng seed")
            .opt_usize("threads", 0, "0 = all cores")
            .opt_usize_min("leaf-cap", 2048, 1, "block capacity (SpMV sweet spot: ~64x nnz/row)")
            .opt_usize_min("block-cap", 256, 1, "full-kernel tree-cut capacity (--far aca)")
            .opt_usize_min("rhs", 1, 1, "multi-RHS width: >1 also times batched spmm paths"),
    ));
    let a = obs_opts(far_opts(opts, "off")).parse_from(argv).unwrap_or_else(die);
    obs_begin(&a);
    // validate the kernel, far-mode, and precision choices up front —
    // before the expensive kNN build
    let kind = kernel_kind(&a);
    let _ = far_mode(&a);
    let _ = precision(&a);
    let wl = workload(&a.get("workload"));
    let threads = if a.get_usize("threads") == 0 {
        nni::par::pool::default_threads()
    } else {
        a.get_usize("threads")
    };
    let (ds, m) = wl.make(a.get_usize("n"), a.get_u64("seed"), threads);
    let build_threads = resolve_build_threads(&a);
    let r = Pipeline::dual_tree(3)
        .with_build_threads(build_threads)
        .run(&ds, &m);
    let eng = r
        .engine_with(a.get_usize("leaf-cap"), 0.6, build_threads, threads, kind)
        .expect("dual-tree ordering carries a tree");
    let csb = &eng.csb;
    println!("{}", csb.describe());
    println!("{}", kernel_line(kind));
    let x = vec![1.0f32; ds.n()];
    let mut y = vec![0.0f32; ds.n()];
    let m_seq = timer::bench_default(|| spmv::csr::spmv_seq(&r.reordered, &x, &mut y));
    let m_ml = timer::bench_default(|| spmv::multilevel::spmv_ml_seq(csb, &x, &mut y));
    let m_mlp = timer::bench_default(|| spmv::multilevel::spmv_ml_par(csb, &x, &mut y, threads));
    let m_eng = timer::bench_default(|| eng.spmv(&x, &mut y));
    println!("csr seq      : {:.3} ms", m_seq.robust_min_s * 1e3);
    println!("ml  seq      : {:.3} ms", m_ml.robust_min_s * 1e3);
    println!("ml  par({threads:>2}) : {:.3} ms", m_mlp.robust_min_s * 1e3);
    println!(
        "engine({:>6}): {:.3} ms (precompiled schedule, {} dispatch)",
        kind.label(),
        m_eng.robust_min_s * 1e3,
        eng.dispatch().label()
    );
    let k = a.get_usize("rhs");
    if k > 1 {
        let xk = vec![1.0f32; ds.n() * k];
        let mut yk = vec![0.0f32; ds.n() * k];
        let m_loop = timer::bench_default(|| {
            for _ in 0..k {
                spmv::multilevel::spmv_ml_seq(csb, &x, &mut y);
            }
        });
        let m_mm = timer::bench_default(|| spmv::multilevel::spmm_ml_seq(csb, &xk, &mut yk, k));
        let m_mmp =
            timer::bench_default(|| spmv::multilevel::spmm_ml_par(csb, &xk, &mut yk, k, threads));
        let m_emm = timer::bench_default(|| eng.spmm(&xk, &mut yk, k));
        println!("{k} x ml seq  : {:.3} ms", m_loop.robust_min_s * 1e3);
        println!(
            "spmm seq k={k:<2}: {:.3} ms ({:.2}x vs scalar loop)",
            m_mm.robust_min_s * 1e3,
            m_loop.robust_min_s / m_mm.robust_min_s
        );
        println!("spmm par({threads:>2}) : {:.3} ms", m_mmp.robust_min_s * 1e3);
        println!(
            "engine spmm  : {:.3} ms ({:.2}x vs scalar-kernel spmm seq)",
            m_emm.robust_min_s * 1e3,
            m_mm.robust_min_s / m_emm.robust_min_s
        );
    }
    // Full-kernel mode: the same spmv surface over the *untruncated*
    // Gaussian matrix (near dense blocks + ACA far field).  Deliberately
    // NOT --leaf-cap: the sparse-SpMV sweet spot (2048) would cut the
    // tree so coarse that nearly everything lands in the near field.
    if let Some((cfg, h)) = full_kernel_cfg(&a, &ds, a.get_usize("block-cap")) {
        let (fk, t_fk) =
            timer::time_once(|| r.full_kernel_engine(&ds, &cfg, build_threads, threads, kind));
        let fk = fk.expect("dual-tree ordering carries a tree");
        println!("full-kernel (h={h:.4}): {}", fk.describe());
        let mut yf = vec![0.0f32; ds.n()];
        let m_fk = timer::bench_default(|| fk.spmv(&x, &mut yf));
        println!(
            "full spmv    : {:.3} ms (build {t_fk:.2}s, {} stored bytes; dense would be {} bytes)",
            m_fk.robust_min_s * 1e3,
            fk.stored_bytes(),
            (ds.n() as u64 * ds.n() as u64) * 4
        );
    }
    obs_end(&a);
}

fn cmd_tsne(argv: Vec<String>) {
    let a = obs_opts(kernel_opts(build_opts(knn_opts(
        Args::new("t-SNE end to end")
            .opt("input", "", "dataset file (else synthesize)")
            .opt("workload", "sift", "sift|gist")
            .opt_usize_min("n", 2048, 1, "points when synthesizing")
            .opt_u64("seed", 42, "rng seed")
            .opt_usize_min("iters", 400, 1, "iterations")
            .opt_f64("perplexity", 30.0, "perplexity")
            .opt_usize_min("k", 90, 1, "neighbors in P")
            .opt_usize("threads", 0, "0 = all cores")
            .opt("out", "", "embedding output path (.nnid)")
            .flag("pjrt", "route dense blocks to the PJRT artifacts"),
    ))))
    .parse_from(argv)
    .unwrap_or_else(die);
    obs_begin(&a);
    let ds = load_or_synth(&a);
    let cfg = tsne::TsneConfig {
        iters: a.get_usize("iters"),
        perplexity: a.get_f64("perplexity"),
        k: a.get_usize("k").min(ds.n() - 1),
        threads: a.get_usize("threads"),
        build_threads: a.get_usize("build-threads"),
        seed: a.get_u64("seed"),
        use_pjrt: a.get_flag("pjrt"),
        knn: knn_backend(&a),
        kernel: kernel_kind(&a),
        ..Default::default()
    };
    let registry = if cfg.use_pjrt {
        Some(ArtifactRegistry::open_default().expect("artifacts"))
    } else {
        None
    };
    let res = tsne::run(&ds, &cfg, registry);
    for e in &res.log {
        println!(
            "iter {:>5}  KL {:.4}  |grad| {:.3e}  t {:.1}s",
            e.iter, e.kl, e.grad_norm, e.seconds
        );
    }
    println!("{}", res.metrics_summary);
    let out = a.get("out");
    if !out.is_empty() {
        res.embedding.save(Path::new(&out)).expect("write embedding");
        println!("embedding -> {out}");
    }
    obs_end(&a);
}

fn cmd_meanshift(argv: Vec<String>) {
    let a = obs_opts(kernel_opts(build_opts(knn_opts(
        Args::new("mean shift mode finding")
            .opt("input", "", "dataset file (else synthesize blobs)")
            .opt_usize_min("n", 2000, 1, "points when synthesizing")
            .opt_usize_min("blobs", 5, 1, "planted modes when synthesizing")
            .opt_usize_min("d", 3, 1, "dimension when synthesizing")
            .opt_f64("bandwidth", 0.25, "kernel bandwidth")
            .opt_usize_min("k", 32, 1, "profile neighbors")
            .opt_usize_min("iters", 60, 1, "max iterations")
            .opt_usize("refresh", 5, "profile refresh cadence")
            .opt_u64("seed", 42, "rng seed")
            .opt_usize("threads", 0, "0 = all cores")
            .flag(
                "incremental",
                "refresh by incremental tree/CSB patching (delete+reinsert of displaced targets)",
            ),
    ))))
    .parse_from(argv)
    .unwrap_or_else(die);
    obs_begin(&a);
    let input = a.get("input");
    let ds = if input.is_empty() {
        SynthSpec::blobs(
            a.get_usize("n"),
            a.get_usize("d"),
            a.get_usize("blobs"),
            a.get_u64("seed"),
        )
        .generate()
    } else {
        Dataset::load(Path::new(&input)).expect("load dataset")
    };
    let cfg = meanshift::MeanShiftConfig {
        bandwidth: a.get_f64("bandwidth"),
        k: a.get_usize("k").min(ds.n() - 1),
        max_iters: a.get_usize("iters"),
        refresh_every: a.get_usize("refresh"),
        threads: a.get_usize("threads"),
        build_threads: a.get_usize("build-threads"),
        knn: knn_backend(&a),
        kernel: kernel_kind(&a),
        incremental: a.get_flag("incremental"),
        ..Default::default()
    };
    let res = meanshift::run(&ds, &cfg);
    println!(
        "{} modes after {} iterations over {} points",
        res.modes.len(),
        res.iterations,
        ds.n()
    );
    for (m, c) in res.modes.iter().enumerate().take(12) {
        let count = res.assignment.iter().filter(|&&x| x == m).count();
        println!("mode {m}: {count} points @ {:?}", &c[..c.len().min(4)]);
    }
    obs_end(&a);
}

fn cmd_krr(argv: Vec<String>) {
    let opts = kernel_opts(build_opts(
        Args::new("kernel ridge regression over the compressed full-kernel operator")
            .opt("input", "", "dataset file (else synthesize)")
            .opt("workload", "sift", "sift|gist")
            .opt_usize_min("n", 4096, 2, "points when synthesizing")
            .opt_f64("lambda", 1.0, "ridge regularization")
            .opt_usize_min("block-cap", 256, 1, "tree-cut block capacity")
            .opt_usize_min("leaf-cap", 16, 1, "ordering-tree leaf capacity")
            .opt_f64("cg-tol", 1e-6, "CG relative-residual stop")
            .opt_usize_min("cg-iters", 500, 1, "CG iteration cap")
            .opt_u64("seed", 42, "rng seed")
            .opt_usize("threads", 0, "0 = all cores")
            .flag("precond", "precondition CG with the H2-skeleton Nystrom operator")
            .flag("verify", "solve plain and preconditioned, check agreement (--far h2)"),
    ));
    let a = obs_opts(far_opts(opts, "aca")).parse_from(argv).unwrap_or_else(die);
    obs_begin(&a);
    let kernel = kernel_kind(&a);
    let far = far_mode(&a);
    let ds = load_or_synth(&a);
    if ds.n() < 2 {
        die::<()>("krr needs at least 2 points".into());
    }
    // Demo target: a smooth function of the leading principal coordinate
    // (the regression problem KRR is meant to smooth).
    let y = krr::synthetic_targets(&ds, a.get_u64("seed"));
    let verify = a.get_flag("verify");
    if verify && far != FarFieldMode::H2 {
        die::<()>("--verify: needs --far h2 (the preconditioner rides the H2 skeletons)".into());
    }
    let cfg = krr::KrrConfig {
        bandwidth: a.get_f64("bandwidth"),
        lambda: a.get_f64("lambda"),
        far,
        precision: precision(&a),
        precond: a.get_flag("precond") || verify,
        tol: a.get_f64("tol"),
        eta: a.get_f64("eta"),
        block_cap: a.get_usize("block-cap"),
        leaf_cap: a.get_usize("leaf-cap"),
        cg_tol: a.get_f64("cg-tol"),
        cg_max_iters: a.get_usize("cg-iters"),
        threads: a.get_usize("threads"),
        build_threads: a.get_usize("build-threads"),
        kernel,
        seed: a.get_u64("seed"),
    };
    let (res, t) = timer::time_once(|| krr::run(&ds, &y, &cfg));
    println!(
        "krr n={} d={} far={} precision={} h={:.4} lambda={}",
        ds.n(),
        ds.d(),
        far.label(),
        cfg.precision.label(),
        res.bandwidth,
        cfg.lambda
    );
    println!("engine: {}", res.summary);
    println!("{}", kernel_line(kernel));
    println!(
        "cg: {} iterations, rel residual {:.3e}, train rmse {:.4}  ({t:.2}s total)",
        res.iterations, res.rel_residual, res.train_rmse
    );
    if verify {
        // Same system through plain CG — the preconditioned solve must
        // reach the same answer in no more iterations.
        let plain = krr::run(
            &ds,
            &y,
            &krr::KrrConfig {
                precond: false,
                ..cfg.clone()
            },
        );
        let n2: f64 = plain.alpha.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let d2: f64 = plain
            .alpha
            .iter()
            .zip(&res.alpha)
            .map(|(&p, &q)| (p as f64 - q as f64) * (p as f64 - q as f64))
            .sum();
        let rel = d2.sqrt() / n2.sqrt().max(1e-12);
        if res.iterations > plain.iterations {
            die::<()>(format!(
                "verify FAILED: preconditioned CG took {} iterations vs {} plain",
                res.iterations, plain.iterations
            ));
        }
        if rel > 2e-2 {
            die::<()>(format!("verify FAILED: solutions disagree (rel {rel:.3e})"));
        }
        println!(
            "verify OK: pcg {} <= cg {} iterations, solutions agree (rel {rel:.3e})",
            res.iterations, plain.iterations
        );
    }
    obs_end(&a);
}

/// `nni update`: exercise the incremental-update subsystem — build an
/// updatable engine over synthetic blobs, stream seeded delete/insert
/// batches through versioned epochs, and report the `update.*` reuse
/// counters.  `--far aca` switches from the near-field profile engine to
/// the full-kernel operator (near Gaussian rows + ACA far factors lifted
/// across epochs).  With `--verify`, every published epoch is checked
/// arena-for-arena against a from-scratch build over the same post-update
/// data — the invariant the differential fuzz harness enforces in CI.
fn cmd_update(argv: Vec<String>) {
    let opts = kernel_opts(build_opts(
        Args::new("stream delete/insert batches through versioned epochs")
            .opt_usize_min("n", 2000, 64, "points when synthesizing blobs")
            .opt_usize_min("blobs", 5, 1, "planted clusters")
            .opt_usize_min("d", 3, 1, "dimension")
            .opt_usize_min("rounds", 4, 1, "update batches to apply")
            .opt_usize("deletes", 24, "deletions per batch")
            .opt_usize("inserts", 24, "insertions per batch")
            .opt_usize_min("k", 8, 1, "profile neighbors (near-field mode)")
            .opt_usize_min("leaf-cap", 16, 1, "tree leaf capacity")
            .opt_usize_min("block-cap", 64, 1, "CSB/tree-cut block capacity")
            .opt_u64("seed", 42, "rng seed")
            .opt_usize("threads", 0, "0 = all cores")
            .flag("verify", "check each epoch against a from-scratch build"),
    ));
    let a = obs_opts(far_opts(opts, "off")).parse_from(argv).unwrap_or_else(die);
    obs_begin(&a);
    let ds = SynthSpec::blobs(
        a.get_usize("n"),
        a.get_usize("d"),
        a.get_usize("blobs"),
        a.get_u64("seed"),
    )
    .generate();
    let ucfg = UpdateCfg {
        leaf_cap: a.get_usize("leaf-cap"),
        block_cap: a.get_usize("block-cap"),
        build_threads: resolve_build_threads(&a),
        threads: a.get_usize("threads"),
        kernel: kernel_kind(&a),
        ..UpdateCfg::default()
    };
    let mut rng = Rng::new(a.get_u64("seed") ^ 0x5eed);
    let rounds = a.get_usize("rounds");
    let (n_del, n_ins) = (a.get_usize("deletes"), a.get_usize("inserts"));
    let verify = a.get_flag("verify");
    println!(
        "update n={} d={} rounds={rounds} batch=-{n_del}/+{n_ins} verify={verify}",
        ds.n(),
        ds.d()
    );
    match full_kernel_cfg(&a, &ds, a.get_usize("block-cap")) {
        Some((kcfg, h)) => {
            println!("mode: full-kernel (h={h:.4})");
            run_kernel_updates(ds, ucfg, kcfg, rounds, n_del, n_ins, &mut rng, verify);
        }
        None => {
            println!("mode: near-field profile (k={})", a.get_usize("k"));
            run_near_updates(ds, ucfg, a.get_usize("k"), rounds, n_del, n_ins, &mut rng, verify);
        }
    }
    let snap = counters::snapshot();
    println!("update counters:");
    for (name, v) in snap.counters.iter().filter(|(n, _)| n.starts_with("update.")) {
        println!("  {name:<28} {v}");
    }
    obs_end(&a);
}

/// Bit-exact float-slice equality (the arena comparison of `--verify`).
fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Seeded interior delete/insert batch against the current epoch's data.
/// Deletions avoid the hull and insertions pull existing points toward the
/// box center, so the root box persists across rounds and the updates
/// exercise the subtree-rebuild path rather than the full-rebuild fallback.
fn update_batch(ds: &Dataset, rng: &mut Rng, n_del: usize, n_ins: usize) -> UpdateBatch {
    let d = ds.d();
    let mut lo = vec![f32::INFINITY; d];
    let mut hi = vec![f32::NEG_INFINITY; d];
    for i in 0..ds.n() {
        for (a, &x) in ds.row(i).iter().enumerate() {
            lo[a] = lo[a].min(x);
            hi[a] = hi[a].max(x);
        }
    }
    let on_hull = |row: &[f32]| row.iter().enumerate().any(|(a, &x)| x == lo[a] || x == hi[a]);
    let n_del = n_del.min(ds.n() / 2);
    let mut deletes = Vec::new();
    let mut attempts = 0;
    while deletes.len() < n_del && attempts < 64 * n_del.max(1) {
        attempts += 1;
        let i = rng.below(ds.n());
        if !on_hull(ds.row(i)) && !deletes.contains(&i) {
            deletes.push(i);
        }
    }
    let mut inserts = Vec::with_capacity(n_ins * d);
    for _ in 0..n_ins {
        let i = rng.below(ds.n());
        for (a, &x) in ds.row(i).iter().enumerate() {
            inserts.push(0.9 * x + 0.1 * (0.5 * (lo[a] + hi[a])));
        }
    }
    UpdateBatch { deletes, inserts }
}

#[allow(clippy::too_many_arguments)]
fn run_near_updates(
    ds: Dataset,
    ucfg: UpdateCfg,
    k: usize,
    rounds: usize,
    n_del: usize,
    n_ins: usize,
    rng: &mut Rng,
    verify: bool,
) {
    let bt = ucfg.build_threads;
    let profile = move |d: &Dataset, _t: &BoxTree| {
        Csr::from_knn(&knn_graph(d, k.min(d.n() - 1), bt), d.n()).symmetrized()
    };
    let dim = ds.d();
    let (upd, t0) = timer::time_once(|| UpdatableEngine::build(ds, ucfg, profile));
    let stale = upd.acquire();
    let n0 = stale.value.engine.csb.rows;
    println!("epoch v0: n={n0}  build {t0:.3}s  ({})", stale.value.engine.csb.describe());
    let x0: Vec<f32> = (0..n0).map(|_| rng.f32() - 0.5).collect();
    let mut y0 = vec![0.0f32; n0];
    stale.value.engine.spmv(&x0, &mut y0);
    for _ in 0..rounds {
        let cur = upd.acquire();
        let b = update_batch(&cur.value.ds, rng, n_del, n_ins);
        let (nd, ni) = (b.deletes.len(), b.inserts.len() / dim);
        drop(cur);
        let (e, t) = timer::time_once(|| upd.update(&b));
        println!("epoch v{}: -{nd} +{ni} -> n={}  patch {t:.3}s", e.version, e.value.engine.csb.rows);
        if verify {
            let fresh = UpdatableEngine::build(e.value.ds.clone(), ucfg, profile);
            let f = fresh.acquire();
            let ok = f.value.engine.csb.blocks == e.value.engine.csb.blocks
                && f.value.engine.csb.sp_rows == e.value.engine.csb.sp_rows
                && f.value.engine.csb.sp_ptr == e.value.engine.csb.sp_ptr
                && f.value.engine.csb.sp_col == e.value.engine.csb.sp_col
                && bits_eq(&f.value.engine.csb.dense, &e.value.engine.csb.dense)
                && bits_eq(&f.value.engine.csb.sp_val, &e.value.engine.csb.sp_val);
            if !ok {
                die::<()>(format!("verify FAILED: epoch v{} differs from from-scratch", e.version));
            }
            println!("  verify: arenas bit-identical to from-scratch build");
        }
    }
    // The stale v0 handle still answers from its snapshot after every
    // publish — the reader-side half of the epoch contract.
    let mut y1 = vec![0.0f32; n0];
    stale.value.engine.spmv(&x0, &mut y1);
    if !bits_eq(&y0, &y1) {
        die::<()>("stale epoch handle drifted from its snapshot".into());
    }
    println!(
        "stale v0 handle after {rounds} publishes: bit-stable (n={n0} vs current n={})",
        upd.acquire().value.engine.csb.rows
    );
}

#[allow(clippy::too_many_arguments)]
fn run_kernel_updates(
    ds: Dataset,
    ucfg: UpdateCfg,
    kcfg: FullKernelConfig,
    rounds: usize,
    n_del: usize,
    n_ins: usize,
    rng: &mut Rng,
    verify: bool,
) {
    let dim = ds.d();
    let (upd, t0) =
        timer::time_once(|| UpdatableKernelEngine::build(ds, ucfg, kcfg.clone()));
    let stale = upd.acquire();
    let n0 = stale.value.engine.n();
    println!("epoch v0: n={n0}  build {t0:.3}s  ({})", stale.value.engine.describe());
    let x0: Vec<f32> = (0..n0).map(|_| rng.f32() - 0.5).collect();
    let mut y0 = vec![0.0f32; n0];
    stale.value.engine.spmv(&x0, &mut y0);
    for _ in 0..rounds {
        let cur = upd.acquire();
        let b = update_batch(&cur.value.ds, rng, n_del, n_ins);
        let (nd, ni) = (b.deletes.len(), b.inserts.len() / dim);
        drop(cur);
        let (e, t) = timer::time_once(|| upd.update(&b));
        println!("epoch v{}: -{nd} +{ni} -> n={}  patch {t:.3}s", e.version, e.value.engine.n());
        if verify {
            let fresh = UpdatableKernelEngine::build(e.value.ds.clone(), ucfg, kcfg.clone());
            let f = fresh.acquire();
            let ok = f.value.engine.far.bits_eq(&e.value.engine.far)
                && f.value.engine.near.csb.blocks == e.value.engine.near.csb.blocks
                && bits_eq(&f.value.engine.near.csb.dense, &e.value.engine.near.csb.dense)
                && bits_eq(&f.value.engine.near.csb.sp_val, &e.value.engine.near.csb.sp_val);
            if !ok {
                die::<()>(format!("verify FAILED: epoch v{} differs from from-scratch", e.version));
            }
            println!("  verify: near arenas + far factors bit-identical to from-scratch build");
        }
    }
    let mut y1 = vec![0.0f32; n0];
    stale.value.engine.spmv(&x0, &mut y1);
    if !bits_eq(&y0, &y1) {
        die::<()>("stale epoch handle drifted from its snapshot".into());
    }
    println!(
        "stale v0 handle after {rounds} publishes: bit-stable (n={n0} vs current n={})",
        upd.acquire().value.engine.n()
    );
}

/// `nni stats`: run a small end-to-end pipeline (tree + PCA + CSB + apply
/// engine + ACA far field) with tracing on, then print the human
/// observability report.  `--trace-out`/`--metrics-out` also work here, so
/// this doubles as the quickest way to get a Perfetto-loadable trace.
/// `nni serve`: the fault-tolerant serving tier — one engine built once,
/// queries answered from sharded epoch workers behind admission control
/// and per-request deadlines.  `--load-gen` drives the daemon with the
/// seeded generator (optionally against an `--inject` fault script) and
/// records p50/p99 latency plus the shed/retry counters to
/// `BENCH_serve.json`; without it, a line protocol on stdin serves
/// interactive queries until EOF.
fn cmd_serve(argv: Vec<String>) {
    let opts = kernel_opts(build_opts(
        Args::new("serve kNN/potential/KRR queries from sharded epoch workers")
            .opt_usize_min("n", 4096, 64, "points when synthesizing blobs")
            .opt_usize_min("blobs", 5, 1, "planted clusters")
            .opt_usize_min("d", 8, 1, "dimension")
            .opt_usize_min("leaf-cap", 16, 1, "tree leaf capacity")
            .opt_usize_min("block-cap", 64, 1, "CSB/tree-cut block capacity")
            .opt_u64("seed", 42, "rng seed (data, load stream, fault script)")
            .opt_usize("threads", 0, "0 = all cores")
            .opt_usize_min("shards", 4, 1, "shard workers (top-level subtree owners)")
            .opt_usize_min("queue-cap", 256, 1, "admission queue bound (beyond it = shed)")
            .opt_usize_min("batch", 8, 1, "max queries per dispatch slate")
            .opt_u64("budget-us", 50_000, "default per-request deadline budget, us")
            .opt_usize("max-retries", 2, "retries per shard task before the scalar fallback")
            .opt_u64("retry-base-us", 100, "exponential backoff base (retry a waits base<<a us)")
            .opt_usize_min("poison-after", 1, 1, "contained panics per epoch that poison a shard")
            .opt(
                "inject",
                "",
                "fault script: panic:S:SEQ | slow:S:US:FROM[:N] | malformed:AT | \
                 oversized:AT | update:AT:DEL:INS (comma-separated)",
            )
            .flag("load-gen", "drive with the seeded load generator and write the bench record")
            .opt_usize_min("requests", 64, 1, "load-gen requests per point")
            .opt_usize("knn-every", 4, "every i-th load-gen request is a kNN lookup (0 = none)")
            .flag(
                "smoke",
                "CI drill: small run, injected panic + slow shard by default, virtual time, \
                 exit nonzero on any lost request or unconsumed panic script",
            )
            .flag("virtual-time", "charge injected latency/backoff virtually (deterministic deadlines)")
            .opt_usize("stats-interval", 0, "print a counters + latency-quantile line every SECS (0 = off)")
            .opt("flight-out", "", "also write flight-recorder auto-dumps to this path")
            .opt("out", "BENCH_serve.json", "bench record path (load-gen mode)"),
    ));
    let a = obs_opts(far_opts(opts, "aca")).parse_from(argv).unwrap_or_else(die);
    obs_begin(&a);
    let flight_out = a.get("flight-out");
    if !flight_out.is_empty() {
        obs::flight::set_dump_path(Some(flight_out));
    }
    let smoke = a.get_flag("smoke");
    let n = if smoke { a.get_usize("n").min(1024) } else { a.get_usize("n") };
    let ds = SynthSpec::blobs(n, a.get_usize("d"), a.get_usize("blobs"), a.get_u64("seed"))
        .generate();
    let ucfg = UpdateCfg {
        leaf_cap: a.get_usize("leaf-cap"),
        block_cap: a.get_usize("block-cap"),
        build_threads: resolve_build_threads(&a),
        threads: a.get_usize("threads"),
        kernel: kernel_kind(&a),
        ..UpdateCfg::default()
    };
    let (kcfg, h) = full_kernel_cfg(&a, &ds, a.get_usize("block-cap"))
        .unwrap_or_else(|| die("serve needs the full-kernel operator: --far aca|h2".into()));
    let scfg = ServeConfig {
        shards: a.get_usize("shards"),
        queue_cap: a.get_usize("queue-cap"),
        batch: a.get_usize("batch"),
        default_budget_us: a.get_u64("budget-us"),
        max_retries: a.get_usize("max-retries") as u32,
        retry_base_us: a.get_u64("retry-base-us"),
        poison_after: a.get_usize("poison-after") as u32,
        oversize_factor: 4,
        real_time: !(a.get_flag("virtual-time") || smoke),
    };
    let spec = if a.get("inject").is_empty() && smoke {
        // the CI drill: one contained worker panic + one slow shard
        "panic:0:1, slow:1:2000:2:1".to_string()
    } else {
        a.get("inject")
    };
    let plan = FaultPlan::parse(a.get_u64("seed"), &spec)
        .unwrap_or_else(|e| die(format!("--inject: {e}")));
    let t_build = std::time::Instant::now();
    let engine = Arc::new(UpdatableKernelEngine::build(ds, ucfg, kcfg));
    println!(
        "serve n={n} h={h:.4} shards={} queue={} batch={} budget={}us build={:.2}s faults=[{spec}]",
        scfg.shards,
        scfg.queue_cap,
        scfg.batch,
        scfg.default_budget_us,
        t_build.elapsed().as_secs_f64(),
    );
    let (_e, spans) = engine.acquire_sharded(scfg.shards);
    for sp in &spans {
        println!(
            "  shard {}: leaves [{}, {}) rows [{}, {})",
            sp.shard, sp.leaf_lo, sp.leaf_hi, sp.row_lo, sp.row_hi
        );
    }
    drop((_e, spans));
    spawn_stats_printer(a.get_usize("stats-interval"));
    if a.get_flag("load-gen") {
        serve_load_gen(&a, engine, scfg, plan, smoke);
    } else {
        serve_stdin(engine, scfg, plan);
    }
    obs_end(&a);
}

/// `--stats-interval SECS`: a detached printer thread emitting one
/// counters + latency-quantile line per tick (reads the global serve
/// counters and the `serve.e2e` histogram; dies with the process).
fn spawn_stats_printer(secs: usize) {
    if secs == 0 {
        return;
    }
    std::thread::Builder::new()
        .name("nni-serve-stats".into())
        .spawn(move || loop {
            std::thread::sleep(std::time::Duration::from_secs(secs as u64));
            let snap = counters::snapshot();
            let e2e = nni::obs::hist::stage_snapshot(nni::obs::hist::Stage::EndToEnd);
            println!(
                "[stats] admitted={} ok_hist_n={} shed={} deadline_missed={} retried={} \
                 flight_events={} e2e p50={}us p99={}us max={}us",
                snap.get("serve.admitted"),
                e2e.count,
                snap.get("serve.shed"),
                snap.get("serve.deadline_missed"),
                snap.get("serve.retried"),
                snap.get("flight.events"),
                e2e.quantile(50.0),
                e2e.quantile(99.0),
                e2e.max,
            );
        })
        .expect("serve: spawn stats printer");
}

/// Load-generator mode of `nni serve`: one bench point per shard width,
/// every point asserted lossless before `BENCH_serve.json` is written.
fn serve_load_gen(
    a: &Args,
    engine: Arc<UpdatableKernelEngine>,
    scfg: ServeConfig,
    plan: FaultPlan,
    smoke: bool,
) {
    use std::io::Write;
    let requests = if smoke { a.get_usize("requests").min(32) } else { a.get_usize("requests") };
    let lcfg = loadgen::LoadGenCfg {
        requests,
        knn_every: a.get_usize("knn-every"),
        ..loadgen::LoadGenCfg::default()
    };
    let mut widths = vec![1, 2, scfg.shards];
    widths.sort_unstable();
    widths.dedup();
    let mut points = Vec::new();
    for &w in &widths {
        obs::reset();
        let server =
            Server::start(engine.clone(), ServeConfig { shards: w, ..scfg }, plan.clone());
        let rep = loadgen::run(&server, &plan, &lcfg);
        let stats = server.shutdown();
        println!(
            "shards={w}: sent={} ok={} shed={} degraded={} lost={} p50={}us p99={}us \
             retried={} contained={}",
            rep.sent,
            rep.ok,
            rep.shed,
            rep.degraded,
            rep.lost,
            rep.p50_us,
            rep.p99_us,
            stats.retried,
            stats.panics_contained,
        );
        if rep.lost != 0 {
            die::<()>(format!(
                "serve: {} request(s) lost/hung at shards={w} — the serving contract is broken",
                rep.lost
            ));
        }
        if smoke && stats.panics_contained != plan.panic_count() {
            die::<()>(format!(
                "serve smoke: contained {} panic(s), plan scripted {}",
                stats.panics_contained,
                plan.panic_count()
            ));
        }
        points.push(obj(vec![
            ("shards", num(w as f64)),
            ("requests", num(rep.sent as f64)),
            ("ok", num(rep.ok as f64)),
            ("shed", num(rep.shed as f64)),
            ("degraded", num(rep.degraded as f64)),
            ("lost", num(rep.lost as f64)),
            ("p50_us", num(rep.p50_us as f64)),
            ("p99_us", num(rep.p99_us as f64)),
            ("max_us", num(rep.max_us as f64)),
            ("retried", num(stats.retried as f64)),
            ("panics_contained", num(stats.panics_contained as f64)),
            ("deadline_missed", num(stats.shed_deadline as f64)),
            ("epoch_switches", num(stats.epoch_switches as f64)),
            ("counters", nni::bench::counters_json()),
        ]));
    }
    let doc = obj(vec![
        ("bench", s("serve")),
        ("status", s("measured")),
        ("seed", num(plan.seed as f64)),
        ("requests", num(requests as f64)),
        ("faults", num(plan.faults.len() as f64)),
        ("testbed", s(&timer::machine_summary())),
        (
            "expected_shape",
            s("zero lost at every shard width and ok+shed == sent (the serving contract); \
               every scripted panic contained + retried; p50/p99 flat or falling with \
               shard width on a fault-free plan"),
        ),
        ("points", arr(points)),
    ]);
    let out = nni::bench::repo_root_out(&a.get("out"));
    let mut f = std::fs::File::create(&out)
        .unwrap_or_else(|e| die(format!("write {}: {e}", out.display())));
    writeln!(f, "{doc}").unwrap_or_else(|e| die(format!("write {}: {e}", out.display())));
    println!("[saved {}]", out.display());
}

/// Daemon mode of `nni serve`: a line protocol on stdin until EOF —
///   `knn <point> <k>` | `gauss` | `krr` | `update <ndel> <nins>` |
///   `stats` | `dump` | `quit`
/// (`gauss`/`krr` use a seeded random charge vector of the current
/// epoch's length; responses print epoch version, latency, and the
/// degraded/retry flags; `dump` prints an on-demand flight-recorder
/// forensic dump).
fn serve_stdin(engine: Arc<UpdatableKernelEngine>, scfg: ServeConfig, plan: FaultPlan) {
    use std::io::BufRead;
    let server = Server::start(engine, scfg, plan);
    println!("ready — knn <point> <k> | gauss | krr | update <ndel> <nins> | stats | dump | quit");
    let stdin = std::io::stdin();
    let mut rng = Rng::new(0x5e11e);
    let mut line = String::new();
    loop {
        line.clear();
        match stdin.lock().read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        let (n, d) = server.shape();
        let submitted = match parts.as_slice() {
            [] => continue,
            ["quit"] | ["exit"] => break,
            ["stats"] => {
                println!("{:?}", server.stats());
                continue;
            }
            ["dump"] => {
                println!("{}", obs::flight::dump_json("stdin"));
                continue;
            }
            ["update", ndel, nins] => {
                match (ndel.parse::<usize>(), nins.parse::<usize>()) {
                    (Ok(ndel), Ok(nins)) => {
                        let deletes: Vec<usize> = (0..ndel.min(n.saturating_sub(16))).collect();
                        let inserts: Vec<f32> =
                            (0..nins * d).map(|_| rng.f32() * 2.0 - 1.0).collect();
                        println!("epoch -> v{}", server.update(&UpdateBatch { deletes, inserts }));
                    }
                    _ => println!("usage: update <ndel> <nins>"),
                }
                continue;
            }
            ["knn", p, k] => match (p.parse::<u32>(), k.parse::<usize>()) {
                (Ok(point), Ok(k)) => server.submit(Query::Knn { point, k }),
                _ => {
                    println!("usage: knn <point> <k>");
                    continue;
                }
            },
            ["gauss"] | ["krr"] => {
                let charges: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
                if parts[0] == "gauss" {
                    server.submit(Query::Gauss { charges })
                } else {
                    server.submit(Query::Krr { alpha: charges })
                }
            }
            _ => {
                println!("unknown command");
                continue;
            }
        };
        match submitted {
            Err(reason) => println!("shed: {reason}"),
            Ok(pending) => match pending.wait() {
                None => println!("lost (daemon fault — this violates the serving contract)"),
                Some(r) => match r.result {
                    Ok(Payload::Knn(nb)) => {
                        println!("epoch v{} {}us knn: {nb:?}", r.epoch, r.elapsed_us)
                    }
                    Ok(Payload::Potentials(y)) => {
                        let sum: f64 = y.iter().map(|&v| v as f64).sum();
                        println!(
                            "epoch v{} {}us potentials: n={} sum={sum:.4} degraded={} retries={}",
                            r.epoch,
                            r.elapsed_us,
                            y.len(),
                            r.degraded,
                            r.retries
                        );
                    }
                    Err(reason) => println!("shed: {reason}"),
                },
            },
        }
    }
    println!("serve done: {:?}", server.shutdown());
}

fn cmd_stats(argv: Vec<String>) {
    let opts = kernel_opts(build_opts(
        Args::new("exercise every subsystem and print the observability report")
            .opt("workload", "sift", "sift|gist")
            .opt_usize_min("n", 2048, 64, "points")
            .opt_usize_min("rhs", 4, 1, "multi-RHS width of the timed applies")
            .opt_usize_min("leaf-cap", 256, 1, "CSB block capacity")
            .opt_usize_min("block-cap", 256, 1, "full-kernel tree-cut capacity")
            .opt_usize_min("applies", 8, 1, "engine spmm calls to record")
            .opt_u64("seed", 42, "rng seed")
            .opt_usize("threads", 0, "0 = all cores"),
    ));
    let a = obs_opts(far_opts(opts, "aca")).parse_from(argv).unwrap_or_else(die);
    // stats is *about* the observability layer: tracing is always on here
    obs::install(nni::par::pool::default_threads(), obs::DEFAULT_SPAN_CAP);
    obs::set_enabled(true);
    let kernel = kernel_kind(&a);
    let wl = workload(&a.get("workload"));
    let n = a.get_usize("n");
    let threads = a.get_usize("threads");
    let build_threads = resolve_build_threads(&a);
    let (ds, m) = wl.make(n, a.get_u64("seed"), threads);
    let r = Pipeline::dual_tree(3)
        .with_seed(a.get_u64("seed"))
        .with_build_threads(build_threads)
        .run(&ds, &m);
    let eng = r
        .engine_with(a.get_usize("leaf-cap"), 0.6, build_threads, threads, kernel)
        .expect("dual-tree ordering carries a tree");
    let k = a.get_usize("rhs");
    let xk = vec![1.0f32; n * k];
    let mut yk = vec![0.0f32; n * k];
    for _ in 0..a.get_usize("applies") {
        eng.spmm(&xk, &mut yk, k);
    }
    if let Some((cfg, _h)) = full_kernel_cfg(&a, &ds, a.get_usize("block-cap")) {
        if let Some(fk) = r.full_kernel_engine(&ds, &cfg, build_threads, threads, kernel) {
            let x = vec![1.0f32; n];
            let mut y = vec![0.0f32; n];
            fk.spmv(&x, &mut y);
        }
    }
    // Serving tier: a small daemon round-trip with one contained panic
    // and one typed shed, so the serve.* counters are exercised and show
    // up in the (non-zero-only) report below.
    {
        let sds = SynthSpec::blobs(512, 3, 4, a.get_u64("seed")).generate();
        let ucfg = UpdateCfg {
            leaf_cap: 16,
            block_cap: 64,
            build_threads,
            threads,
            kernel,
            ..UpdateCfg::default()
        };
        let upd = Arc::new(UpdatableKernelEngine::build(sds, ucfg, FullKernelConfig::new(1.0)));
        let plan = FaultPlan::parse(a.get_u64("seed"), "panic:0:1, malformed:2")
            .expect("static fault spec");
        let server = Server::start(
            upd,
            ServeConfig { shards: 2, real_time: false, ..ServeConfig::default() },
            plan.clone(),
        );
        loadgen::run(
            &server,
            &plan,
            &loadgen::LoadGenCfg { requests: 8, ..loadgen::LoadGenCfg::default() },
        );
        server.shutdown();
    }
    println!("nni stats — {} n={n} rhs={k}", wl.name());
    print!("{}", obs::export::human_report(&counters::snapshot()));
    obs_end(&a);
}

/// `nni trace-check`: validate emitted Chrome traces (the CI gate behind
/// the reorder trace smoke) — parse, per-event shape, and presence of the
/// required subsystem prefixes.
fn cmd_trace_check(argv: Vec<String>) {
    let a = Args::new("validate Chrome trace-event JSON emitted via --trace-out")
        .opt(
            "require",
            "tree,csb,hmat,apply,interact,serve",
            "comma-separated span-name prefixes that must appear",
        )
        .parse_from(argv)
        .unwrap_or_else(die);
    if a.positional().is_empty() {
        die::<()>("trace-check needs at least one trace file".into());
    }
    let require = a.get("require");
    let required: Vec<&str> =
        require.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    for f in a.positional() {
        let text =
            std::fs::read_to_string(f).unwrap_or_else(|e| die(format!("{f}: {e}")));
        match obs::export::check_trace(&text, &required) {
            Ok(events) => println!("{f}: ok ({events} events; subsystems {require})"),
            Err(e) => die::<()>(format!("{f}: {e}")),
        }
    }
}

/// `nni bench-check`: validate `BENCH_*.json` records (the CI honesty
/// gate) — schema plus, with `--no-pending`, rejection of records the
/// smoke refresh should have measured but did not.
fn cmd_bench_check(argv: Vec<String>) {
    let a = Args::new("validate BENCH_*.json bench records")
        .flag("no-pending", "fail records still pending with no measured points")
        .parse_from(argv)
        .unwrap_or_else(die);
    if a.positional().is_empty() {
        die::<()>("bench-check needs at least one BENCH_*.json".into());
    }
    for f in a.positional() {
        let text =
            std::fs::read_to_string(f).unwrap_or_else(|e| die(format!("{f}: {e}")));
        match nni::bench::check_record(&text, a.get_flag("no-pending")) {
            Ok(status) => println!("{f}: ok ({status})"),
            Err(e) => die::<()>(format!("{f}: {e}")),
        }
    }
}

fn die<T>(e: String) -> T {
    eprintln!("{e}");
    std::process::exit(2);
}
