//! Iterative near-neighbor interaction engines (§1, §3): the non-stationary
//! setting where matrix *values* (and, for mean shift, the profile) change
//! across iterations while the hierarchical ordering persists.

pub mod engine;
pub mod epoch;
