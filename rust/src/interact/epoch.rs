//! Versioned epoch publication of incrementally updated engines.
//!
//! The engines are immutable by design — the precompiled schedule, packed
//! panels, and per-worker scratch all assume a frozen block structure.
//! Incremental updates therefore never mutate a live engine: an update
//! builds a **new epoch** off to the side (reusing untouched subtrees,
//! arenas, and factors via the `tree::update` → `csb::update` →
//! `hmat::update` chain) and publishes it atomically.  Readers hold
//! `Arc<Epoch<_>>` handles: a handle acquired before a publish keeps
//! applying against its snapshot — bit-stable answers for the epoch it
//! saw — and the old engine's memory is reclaimed when the last such
//! handle drops (`update.epochs_reclaimed` counts the drain).
//!
//! Lifecycle: **build → patch → publish → drain → reclaim.**
//!
//! Two concrete updatables:
//!
//! * [`UpdatableEngine`] — the near-field profile engine ([`Engine`]),
//!   parameterized by a profile closure (e.g. symmetrized kNN); the CSB
//!   arenas are patched by [`csb::update::update_par`] and the schedule is
//!   recompiled by `Engine::with_kernel` (cheap — it walks the block list).
//! * [`UpdatableKernelEngine`] — the full-kernel operator
//!   ([`FullKernelEngine`]); near Gaussian rows and far factors (ACA
//!   block factors or H² leaf bases, per the configured representation)
//!   of untouched pairs are lifted by [`hmat::update`] / [`hmat::h2`].
//!
//! Both produce engines **bit-identical** to a from-scratch build over the
//! post-update data (tree layout equivalence → profile equality → arena
//! equality), which is what the differential fuzz harness
//! (`rust/tests/update_fuzz.rs`) checks.

use crate::csb::hier::{HierCsb, Span};
use crate::csb::kernel::KernelKind;
use crate::csb::update::{update_par, SideDelta};
use crate::data::dataset::Dataset;
use crate::hmat::{FullKernelConfig, FullKernelEngine};
use crate::interact::engine::Engine;
use crate::obs::{self, counters, Counter};
use crate::sparse::csr::Csr;
use crate::tree::boxtree::BoxTree;
use crate::tree::update::{update_tree, UpdateBatch};
use std::sync::{Arc, RwLock};

/// One immutable published state.  Dropping the last handle to an epoch
/// reclaims it (counted — the observable end of the drain).
pub struct Epoch<T> {
    /// Monotonic version, starting at 0 for the initial build.
    pub version: u64,
    pub value: T,
}

impl<T> Drop for Epoch<T> {
    fn drop(&mut self) {
        counters::add(Counter::UpdateEpochsReclaimed, 1);
    }
}

/// Atomic single-writer/multi-reader publication point.
///
/// `acquire` hands out a snapshot handle (an `Arc` clone — O(1), no data
/// copy); `publish` swaps in a new epoch.  In-flight readers are never
/// blocked by a publish and never observe a half-built state: they keep
/// the `Arc` they acquired.
pub struct EpochPublisher<T> {
    current: RwLock<Arc<Epoch<T>>>,
}

impl<T> EpochPublisher<T> {
    /// Wrap the initial build as version 0 (counted as a publish).
    pub fn new(value: T) -> EpochPublisher<T> {
        counters::add(Counter::UpdateEpochsPublished, 1);
        EpochPublisher {
            current: RwLock::new(Arc::new(Epoch { version: 0, value })),
        }
    }

    /// Snapshot handle to the current epoch.
    pub fn acquire(&self) -> Arc<Epoch<T>> {
        self.current.read().unwrap().clone()
    }

    /// Current version without taking a handle.
    pub fn version(&self) -> u64 {
        self.current.read().unwrap().version
    }

    /// Atomically replace the current epoch; returns the new version.
    /// Recorded in the flight ring as an `epoch_switch` event (`aux` =
    /// new version) — the initial build in [`EpochPublisher::new`] is
    /// not a switch and is not recorded.
    pub fn publish(&self, value: T) -> u64 {
        let mut cur = self.current.write().unwrap();
        let version = cur.version + 1;
        *cur = Arc::new(Epoch { version, value });
        counters::add(Counter::UpdateEpochsPublished, 1);
        crate::obs::flight::record(crate::obs::flight::Kind::EpochSwitch, -1, 0, version);
        version
    }
}

/// Build/update parameters shared by the updatable engines.
#[derive(Clone, Copy, Debug)]
pub struct UpdateCfg {
    /// Tree leaf capacity (`BoxTree::build_par`).
    pub leaf_cap: usize,
    /// Tree depth cap — must stay fixed across updates (the clean-subtree
    /// equivalence argument needs the same split policy on both sides).
    pub max_depth: u32,
    /// CSB blocking capacity (0 = `LEAF_POINTS`).
    pub block_cap: usize,
    /// Dense-storage threshold of the CSB build.
    pub dense_threshold: f64,
    /// Structure build/update parallelism (0 = machine default).
    pub build_threads: usize,
    /// Apply parallelism of published engines (0 = machine default).
    pub threads: usize,
    /// Kernel dispatch of published engines.
    pub kernel: KernelKind,
}

impl Default for UpdateCfg {
    fn default() -> Self {
        UpdateCfg {
            leaf_cap: 16,
            max_depth: 32,
            block_cap: 0,
            dense_threshold: 0.6,
            build_threads: 0,
            threads: 0,
            kernel: KernelKind::Auto,
        }
    }
}

/// Everything one near-field epoch owns: the engine plus the structures
/// the *next* incremental update patches against.
pub struct EngineEpoch {
    pub engine: Engine,
    pub tree: BoxTree,
    /// Backing data in external (insertion) order.
    pub ds: Dataset,
    /// Tree-ordered profile CSR (the `a_old` of the next CSB patch).
    pub profile: Csr,
}

/// An incrementally updatable near-field engine: a profile closure + an
/// epoch publisher.  `update` rebuilds only touched subtrees and leaf
/// blocks and publishes the result as a new epoch.
///
/// The profile closure receives the **tree-ordered** dataset and its tree
/// and must return a tree-ordered CSR (rows = cols = tree positions).  It
/// must be a deterministic function of its inputs — that is what carries
/// the tree layer's layout equivalence into profile equality, and with it
/// the bit-identity of incremental vs from-scratch arenas.
pub struct UpdatableEngine<F: Fn(&Dataset, &BoxTree) -> Csr> {
    cfg: UpdateCfg,
    profile: F,
    epochs: EpochPublisher<EngineEpoch>,
}

impl<F: Fn(&Dataset, &BoxTree) -> Csr> UpdatableEngine<F> {
    /// From-scratch build of epoch 0.
    pub fn build(ds: Dataset, cfg: UpdateCfg, profile: F) -> UpdatableEngine<F> {
        obs::span!("epoch.build");
        let tree = BoxTree::build_par(&ds, cfg.leaf_cap, cfg.max_depth, cfg.build_threads);
        let a = profile(&ds.permuted(&tree.perm), &tree);
        let csb = HierCsb::build_with_par(
            &a,
            &tree,
            &tree,
            cfg.block_cap,
            cfg.dense_threshold,
            cfg.build_threads,
        );
        let engine = Engine::with_kernel(csb, cfg.threads, cfg.kernel);
        UpdatableEngine {
            cfg,
            profile,
            epochs: EpochPublisher::new(EngineEpoch {
                engine,
                tree,
                ds,
                profile: a,
            }),
        }
    }

    /// Snapshot handle to the current epoch.
    pub fn acquire(&self) -> Arc<Epoch<EngineEpoch>> {
        self.epochs.acquire()
    }

    /// Current published version.
    pub fn version(&self) -> u64 {
        self.epochs.version()
    }

    /// Apply a delete/insert batch: rebuild touched subtrees, re-derive
    /// the profile, patch the CSB arenas (reusing clean leaf blocks),
    /// recompile the schedule, and publish the result as a new epoch.
    /// Existing handles keep answering from their snapshot.  Returns a
    /// handle to the new epoch.
    pub fn update(&self, batch: &UpdateBatch) -> Arc<Epoch<EngineEpoch>> {
        obs::span!("epoch.update");
        let cur = self.epochs.acquire();
        let cfg = &self.cfg;
        let tu = update_tree(&cur.value.tree, &cur.value.ds, batch, cfg.max_depth, cfg.build_threads);
        let a_new = (self.profile)(&tu.ds.permuted(&tu.tree.perm), &tu.tree);
        let csb = if tu.full_rebuild {
            HierCsb::build_with_par(
                &a_new,
                &tu.tree,
                &tu.tree,
                cfg.block_cap,
                cfg.dense_threshold,
                cfg.build_threads,
            )
        } else {
            let delta = SideDelta::from_update(&cur.value.tree, &tu);
            update_par(
                &cur.value.engine.csb,
                &cur.value.profile,
                &a_new,
                &tu.tree,
                &delta,
                &tu.tree,
                &delta,
                cfg.block_cap,
                cfg.build_threads,
            )
        };
        let engine = Engine::with_kernel(csb, cfg.threads, cfg.kernel);
        self.epochs.publish(EngineEpoch {
            engine,
            tree: tu.tree,
            ds: tu.ds,
            profile: a_new,
        });
        self.epochs.acquire()
    }
}

/// Everything one full-kernel epoch owns.
pub struct KernelEpoch {
    pub engine: FullKernelEngine,
    pub tree: BoxTree,
    /// Backing data in external (insertion) order.
    pub ds: Dataset,
    /// Tree-ordered coordinates (the Gaussian's space).
    pub coords: Vec<f32>,
}

/// An incrementally updatable full-kernel operator: near Gaussian rows and
/// far factors (ACA or H², per the configured representation) of untouched
/// pairs are lifted from the previous epoch (`hmat::update`,
/// `hmat::h2::H2Field::update`); everything else regenerates.
pub struct UpdatableKernelEngine {
    cfg: UpdateCfg,
    kcfg: FullKernelConfig,
    epochs: EpochPublisher<KernelEpoch>,
}

impl UpdatableKernelEngine {
    /// From-scratch build of epoch 0.  The tree is built over `ds` itself
    /// (ordering space = kernel space), `kcfg.block_cap` follows
    /// `cfg.block_cap`.
    pub fn build(ds: Dataset, cfg: UpdateCfg, kcfg: FullKernelConfig) -> UpdatableKernelEngine {
        obs::span!("epoch.build");
        let kcfg = kcfg.with_block_cap(cfg.block_cap);
        let tree = BoxTree::build_par(&ds, cfg.leaf_cap, cfg.max_depth, cfg.build_threads);
        let coords = ds.permuted(&tree.perm).raw().to_vec();
        let engine = FullKernelEngine::build(
            &tree,
            &coords,
            ds.d(),
            &kcfg,
            cfg.build_threads,
            cfg.threads,
            cfg.kernel,
        );
        UpdatableKernelEngine {
            cfg,
            kcfg,
            epochs: EpochPublisher::new(KernelEpoch {
                engine,
                tree,
                ds,
                coords,
            }),
        }
    }

    pub fn acquire(&self) -> Arc<Epoch<KernelEpoch>> {
        self.epochs.acquire()
    }

    pub fn version(&self) -> u64 {
        self.epochs.version()
    }

    /// Apply a delete/insert batch and publish the updated operator as a
    /// new epoch (see [`UpdatableEngine::update`] for the lifecycle).
    pub fn update(&self, batch: &UpdateBatch) -> Arc<Epoch<KernelEpoch>> {
        obs::span!("epoch.update");
        let cur = self.epochs.acquire();
        let cfg = &self.cfg;
        let tu = update_tree(&cur.value.tree, &cur.value.ds, batch, cfg.max_depth, cfg.build_threads);
        let coords = tu.ds.permuted(&tu.tree.perm).raw().to_vec();
        let engine = if tu.full_rebuild {
            FullKernelEngine::build(
                &tu.tree,
                &coords,
                tu.ds.d(),
                &self.kcfg,
                cfg.build_threads,
                cfg.threads,
                cfg.kernel,
            )
        } else {
            let delta = SideDelta::from_update(&cur.value.tree, &tu);
            cur.value.engine.update(
                &cur.value.tree,
                &tu.tree,
                &delta,
                &coords,
                tu.ds.d(),
                &self.kcfg,
                cfg.build_threads,
                cfg.threads,
                cfg.kernel,
            )
        };
        self.epochs.publish(KernelEpoch {
            engine,
            tree: tu.tree,
            ds: tu.ds,
            coords,
        });
        self.epochs.acquire()
    }

    /// Shard-scoped acquire for the serving tier: one snapshot handle plus
    /// the contiguous target-leaf shards of **that** epoch's block
    /// structure.  The shard map is a pure function of the snapshot (tree
    /// top-level subtrees × CSB target leaves), so every worker handed the
    /// same epoch sees the same ownership — and a new epoch publishes a new
    /// map atomically with the engine it describes.
    pub fn acquire_sharded(&self, shards: usize) -> (Arc<Epoch<KernelEpoch>>, Vec<ShardSpan>) {
        let e = self.epochs.acquire();
        let spans = shard_spans(&e.value.tree, &e.value.engine.near.csb.tgt_leaves, shards);
        (e, spans)
    }

    /// Restart a crashed shard worker from the **current** snapshot: a
    /// fresh handle plus the worker's span under the current epoch.  Stale
    /// handles held by in-flight requests keep answering bit-stably from
    /// their own snapshot (the epoch contract); only the restarted worker
    /// moves forward.  Counted as `serve.shard_restarts`.
    pub fn restart_shard(
        &self,
        shards: usize,
        shard: usize,
    ) -> (Arc<Epoch<KernelEpoch>>, ShardSpan) {
        counters::add(Counter::ServeShardRestarts, 1);
        crate::obs::flight::record(crate::obs::flight::Kind::Restart, shard as i64, 0, 0);
        let (e, spans) = self.acquire_sharded(shards);
        let span = spans[shard.min(spans.len() - 1)].clone();
        (e, span)
    }
}

/// One serving shard's slice of an epoch: a contiguous run of CSB target
/// leaves (each leaf is a node of the tree's blocking cut, so a run is one
/// or more whole top-level subtrees) and the tree-position row range those
/// leaves cover.  Shards partition `[0, n)`; trailing shards may be empty
/// when there are more workers than subtrees.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardSpan {
    pub shard: usize,
    /// Target-leaf index range `[leaf_lo, leaf_hi)` into `csb.tgt_leaves`.
    pub leaf_lo: usize,
    pub leaf_hi: usize,
    /// Tree-position row range `[row_lo, row_hi)` covered by those leaves.
    pub row_lo: usize,
    pub row_hi: usize,
}

impl ShardSpan {
    pub fn rows(&self) -> usize {
        self.row_hi - self.row_lo
    }

    pub fn is_empty(&self) -> bool {
        self.leaf_lo == self.leaf_hi
    }
}

/// Partition `tgt_leaves` into `shards` contiguous groups, balanced by row
/// count and aligned to top-level subtree boundaries (the tree's depth-1
/// cut) wherever the blocking cut permits: a worker owns whole subtrees, so
/// locality-correlated query load stays shard-local.  A target leaf wider
/// than a depth-1 subtree (tiny trees) forms its own atom.  Deterministic:
/// a pure function of `(tree, tgt_leaves, shards)`.
pub fn shard_spans(tree: &BoxTree, tgt_leaves: &[Span], shards: usize) -> Vec<ShardSpan> {
    let shards = shards.max(1);
    let n = tgt_leaves.last().map(|s| s.hi as usize).unwrap_or(0);
    let subs: Vec<Span> = tree
        .level_cut(1)
        .iter()
        .map(|&id| {
            let nd = &tree.nodes[id as usize];
            Span { lo: nd.lo, hi: nd.hi }
        })
        .collect();
    // Atoms: maximal runs of consecutive target leaves inside one subtree.
    let mut atoms: Vec<(usize, usize)> = Vec::new();
    let (mut i, mut si) = (0usize, 0usize);
    while i < tgt_leaves.len() {
        while si < subs.len() && subs[si].hi <= tgt_leaves[i].lo {
            si += 1;
        }
        let j0 = i;
        if si < subs.len() && tgt_leaves[i].lo >= subs[si].lo && tgt_leaves[i].hi <= subs[si].hi {
            while i < tgt_leaves.len() && tgt_leaves[i].hi <= subs[si].hi {
                i += 1;
            }
        } else {
            i += 1;
        }
        atoms.push((j0, i));
    }
    let atom_rows =
        |a: &(usize, usize)| (tgt_leaves[a.1 - 1].hi - tgt_leaves[a.0].lo) as usize;
    // Contiguous greedy assignment: each shard takes atoms until it reaches
    // its share of the remaining rows.
    let mut out = Vec::with_capacity(shards);
    let (mut a, mut rows_done) = (0usize, 0usize);
    for s in 0..shards {
        let target = (n - rows_done).div_ceil(shards - s).max(1);
        let leaf_lo = if a < atoms.len() { atoms[a].0 } else { tgt_leaves.len() };
        let mut leaf_hi = leaf_lo;
        let mut rows = 0usize;
        while a < atoms.len() {
            let ar = atom_rows(&atoms[a]);
            if rows > 0 && rows + ar > target {
                break;
            }
            rows += ar;
            leaf_hi = atoms[a].1;
            a += 1;
            if rows >= target {
                break;
            }
        }
        let row_lo = if leaf_lo < tgt_leaves.len() {
            tgt_leaves[leaf_lo].lo as usize
        } else {
            n
        };
        let row_hi = if leaf_hi > leaf_lo { tgt_leaves[leaf_hi - 1].hi as usize } else { row_lo };
        rows_done += rows;
        out.push(ShardSpan {
            shard: s,
            leaf_lo,
            leaf_hi,
            row_lo,
            row_hi,
        });
    }
    // Defensive: any unassigned tail folds into the last shard (cannot
    // happen with the targets above, but the invariant must hold).
    if a < atoms.len() {
        let last = out.last_mut().expect("shards >= 1");
        last.leaf_hi = tgt_leaves.len();
        last.row_hi = n;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::knn::exact::knn_graph;
    use crate::util::rng::Rng;

    fn knn_profile(ds: &Dataset, _tree: &BoxTree) -> Csr {
        let g = knn_graph(ds, 6, 2);
        Csr::from_knn(&g, ds.n()).symmetrized()
    }

    fn cfg() -> UpdateCfg {
        UpdateCfg {
            leaf_cap: 8,
            max_depth: 24,
            block_cap: 32,
            build_threads: 2,
            threads: 2,
            kernel: KernelKind::Scalar,
            ..UpdateCfg::default()
        }
    }

    fn batch(ds: &Dataset, seed: u64, n_del: usize, n_ins: usize) -> UpdateBatch {
        let d = ds.d();
        let mut rng = Rng::new(seed);
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        for i in 0..ds.n() {
            for (a, &x) in ds.row(i).iter().enumerate() {
                lo[a] = lo[a].min(x);
                hi[a] = hi[a].max(x);
            }
        }
        let on_hull = |row: &[f32]| row.iter().enumerate().any(|(a, &x)| x == lo[a] || x == hi[a]);
        let mut deletes = Vec::new();
        while deletes.len() < n_del {
            let i = rng.below(ds.n());
            if !on_hull(ds.row(i)) && !deletes.contains(&i) {
                deletes.push(i);
            }
        }
        let mut inserts = Vec::new();
        for _ in 0..n_ins {
            let i = rng.below(ds.n());
            for (a, &x) in ds.row(i).iter().enumerate() {
                inserts.push(0.9 * x + 0.1 * (0.5 * (lo[a] + hi[a])));
            }
        }
        UpdateBatch { deletes, inserts }
    }

    #[test]
    fn update_publishes_bitidentical_engine() {
        let ds = SynthSpec::blobs(400, 3, 4, 71).generate();
        let upd = UpdatableEngine::build(ds.clone(), cfg(), knn_profile);
        assert_eq!(upd.version(), 0);
        let b = batch(&ds, 72, 10, 10);
        let e1 = upd.update(&b);
        assert_eq!(e1.version, 1);
        assert_eq!(upd.version(), 1);
        // From-scratch over the post-update data must agree arena-for-arena.
        let fresh = UpdatableEngine::build(e1.value.ds.clone(), cfg(), knn_profile);
        let f = fresh.acquire();
        assert_eq!(f.value.engine.csb.blocks, e1.value.engine.csb.blocks);
        assert_eq!(f.value.engine.csb.sp_ptr, e1.value.engine.csb.sp_ptr);
        assert_eq!(f.value.engine.csb.sp_col, e1.value.engine.csb.sp_col);
        assert!(f
            .value
            .engine
            .csb
            .dense
            .iter()
            .zip(&e1.value.engine.csb.dense)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(f
            .value
            .engine
            .csb
            .sp_val
            .iter()
            .zip(&e1.value.engine.csb.sp_val)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn stale_handle_answers_from_snapshot() {
        let ds = SynthSpec::blobs(400, 3, 4, 73).generate();
        let upd = UpdatableEngine::build(ds.clone(), cfg(), knn_profile);
        let stale = upd.acquire();
        let n0 = stale.value.engine.csb.rows;
        let mut x = vec![0.0f32; n0];
        let mut rng = Rng::new(5);
        for v in x.iter_mut() {
            *v = rng.f32() - 0.5;
        }
        let mut y_before = vec![0.0f32; n0];
        stale.value.engine.spmv(&x, &mut y_before);

        let b = batch(&ds, 74, 15, 3); // shrinks n: new epoch has fewer rows
        let e1 = upd.update(&b);
        assert_ne!(e1.value.engine.csb.rows, n0);

        // The stale handle still sees (and answers from) the old snapshot,
        // bit-for-bit, after the publish.
        assert_eq!(stale.version, 0);
        assert_eq!(stale.value.engine.csb.rows, n0);
        let mut y_after = vec![0.0f32; n0];
        stale.value.engine.spmv(&x, &mut y_after);
        assert_eq!(
            y_before, y_after,
            "stale epoch handle must answer from its snapshot"
        );
    }

    #[test]
    fn drain_reclaims_epochs() {
        let ds = SynthSpec::blobs(300, 2, 3, 75).generate();
        let upd = UpdatableEngine::build(ds.clone(), cfg(), knn_profile);
        let stale = upd.acquire();
        let published = counters::get(Counter::UpdateEpochsPublished);
        let _e1 = upd.update(&batch(&ds, 76, 5, 5));
        assert!(counters::get(Counter::UpdateEpochsPublished) > published);
        // The old epoch survives while `stale` holds it...
        let reclaimed = counters::get(Counter::UpdateEpochsReclaimed);
        drop(stale);
        // ...and is reclaimed on the last drop (publisher released it at
        // publish time, so this drop was the drain's end).
        assert!(
            counters::get(Counter::UpdateEpochsReclaimed) > reclaimed,
            "dropping the last stale handle must reclaim the epoch"
        );
    }

    #[test]
    fn shard_spans_partition_rows_at_any_width() {
        let ds = SynthSpec::blobs(500, 3, 4, 81).generate();
        let mut c = cfg();
        c.block_cap = 32;
        let upd = UpdatableKernelEngine::build(ds, c, FullKernelConfig::new(0.8));
        for shards in [1usize, 2, 3, 8, 64] {
            let (e, spans) = upd.acquire_sharded(shards);
            let leaves = &e.value.engine.near.csb.tgt_leaves;
            assert_eq!(spans.len(), shards);
            // Contiguous cover of both the leaf list and the row range.
            let mut leaf = 0usize;
            let mut row = 0usize;
            for sp in &spans {
                assert_eq!(sp.leaf_lo, leaf);
                assert_eq!(sp.row_lo, row);
                assert!(sp.leaf_hi >= sp.leaf_lo);
                leaf = sp.leaf_hi;
                row = sp.row_hi;
            }
            assert_eq!(leaf, leaves.len());
            assert_eq!(row, e.value.engine.n());
            // The same epoch must always produce the same map.
            let (e2, spans2) = upd.acquire_sharded(shards);
            assert_eq!(e2.version, e.version);
            assert_eq!(spans2, spans);
        }
        // Restart-from-snapshot hands back the worker's current span.
        let restarts = counters::get(Counter::ServeShardRestarts);
        let (e, span) = upd.restart_shard(4, 2);
        assert_eq!(span.shard, 2);
        assert_eq!(e.version, upd.version());
        assert!(counters::get(Counter::ServeShardRestarts) > restarts);
    }

    #[test]
    fn kernel_engine_updates_bitidentical() {
        use crate::hmat::FarFieldMode;
        for far in [FarFieldMode::Aca, FarFieldMode::H2] {
            let ds = SynthSpec::blobs(400, 3, 4, 77).generate();
            let mut c = cfg();
            c.block_cap = 64;
            let kcfg = FullKernelConfig::new(0.8).with_far(far);
            let upd = UpdatableKernelEngine::build(ds.clone(), c, kcfg.clone());
            let e1 = upd.update(&batch(&ds, 78, 8, 8));
            let fresh = UpdatableKernelEngine::build(e1.value.ds.clone(), c, kcfg);
            let f = fresh.acquire();
            assert!(
                f.value.engine.far.bits_eq(&e1.value.engine.far),
                "epoch far field differs from fresh build (far={})",
                far.label()
            );
            assert!(f
                .value
                .engine
                .near
                .csb
                .dense
                .iter()
                .zip(&e1.value.engine.near.csb.dense)
                .all(|(a, b)| a.to_bits() == b.to_bits()));
            // And the published operator applies identically (scalar kernel).
            let n = f.value.engine.n();
            let mut rng = Rng::new(9);
            let x: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
            let mut ya = vec![0.0f32; n];
            let mut yb = vec![0.0f32; n];
            f.value.engine.spmv(&x, &mut ya);
            e1.value.engine.spmv(&x, &mut yb);
            assert_eq!(ya, yb, "spmv differs (far={})", far.label());
        }
    }
}
