//! The multi-level interaction engine.
//!
//! Holds the hierarchical block structure (profile + stationary values) and
//! per-iteration recomputes the non-stationary kernel values *fused* with
//! the block multiply — the paper's key operational point: after the
//! dual-tree reorder, every iteration touches the matrix block by block and
//! the vectors segment by segment, whatever the kernel.
//!
//! Three iteration kernels, matching the case studies and the L1 Pallas
//! kernels (`python/compile/kernels/`):
//!
//! * [`Engine::tsne_attr`]   — attractive force, values `p_ij/(1+‖y_i−y_j‖²)`;
//! * [`Engine::gauss_apply`] — Gaussian matvec, values `exp(−‖t−s‖²·inv_h2)`;
//! * [`Engine::meanshift_step`] — Gaussian numerator/denominator sums.
//!
//! Parallelism: target-leaf ownership (one worker owns all writes to a
//! potential segment), identical to `spmv::multilevel`.

use crate::csb::hier::HierCsb;
use crate::par::pool::ThreadPool;

/// The engine: block structure + thread pool.
pub struct Engine {
    pub csb: HierCsb,
    pub pool: ThreadPool,
}

impl Engine {
    pub fn new(csb: HierCsb, threads: usize) -> Engine {
        Engine {
            csb,
            pool: ThreadPool::new_or_default(threads),
        }
    }

    /// Generic per-target-leaf parallel driver with exclusive row-segment
    /// ownership. `f(tleaf, out_segment)` computes all of that leaf's
    /// blocks into its own slice of `out` (`stride` f32 per row).
    fn per_target<F>(&self, out: &mut [f32], stride: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        assert_eq!(out.len(), self.csb.rows * stride);
        out.fill(0.0);
        struct SendPtr(*mut f32);
        unsafe impl Send for SendPtr {}
        unsafe impl Sync for SendPtr {}
        let op = SendPtr(out.as_mut_ptr());
        let opr = &op;
        let leaves = &self.csb.tgt_leaves;
        self.pool.for_each_chunked(leaves.len(), 4, |tl| {
            let sp = leaves[tl];
            // SAFETY: target-leaf row spans are disjoint.
            let seg: &mut [f32] = unsafe {
                std::slice::from_raw_parts_mut(
                    opr.0.add(sp.lo as usize * stride),
                    sp.len() * stride,
                )
            };
            f(tl, seg);
        });
    }

    /// t-SNE attractive force (§3.1).
    ///
    /// * `y`: embedding coordinates, tree-ordered row-major `n x d`
    ///   (targets and sources coincide);
    /// * stored block values are the joint probabilities `p_ij`;
    /// * `force`: output `n x d`, overwritten.
    ///
    /// `F_i = Σ_j p_ij · (1 + ‖y_i − y_j‖²)^{-1} · (y_i − y_j)`.
    pub fn tsne_attr(&self, y: &[f32], d: usize, force: &mut [f32]) {
        assert_eq!(y.len(), self.csb.cols * d);
        let csb = &self.csb;
        self.per_target(force, d, |tl, seg| {
            for &t in &csb.by_target[tl] {
                let b = &csb.blocks[t as usize];
                let r0 = b.rows.lo as usize;
                let c0 = b.cols.lo as usize;
                csb.for_each_nz(t as usize, |r, c, p| {
                    let yi = &y[(r0 + r) * d..(r0 + r + 1) * d];
                    let yj = &y[(c0 + c) * d..(c0 + c + 1) * d];
                    let mut d2 = 0.0f32;
                    for k in 0..d {
                        let t = yi[k] - yj[k];
                        d2 += t * t;
                    }
                    let w = p / (1.0 + d2);
                    let out = &mut seg[r * d..(r + 1) * d];
                    for k in 0..d {
                        out[k] += w * (yi[k] - yj[k]);
                    }
                });
            }
        });
    }

    /// Gaussian interaction matvec (stationary profile, coordinate-derived
    /// values): `y_out_i = Σ_j exp(−‖t_i − s_j‖²·inv_h2) · x_j` over the
    /// stored profile.  `tcoords`/`scoords` are tree-ordered `n x d`.
    pub fn gauss_apply(
        &self,
        tcoords: &[f32],
        scoords: &[f32],
        d: usize,
        inv_h2: f32,
        x: &[f32],
        y_out: &mut [f32],
    ) {
        assert_eq!(tcoords.len(), self.csb.rows * d);
        assert_eq!(scoords.len(), self.csb.cols * d);
        assert_eq!(x.len(), self.csb.cols);
        let csb = &self.csb;
        self.per_target(y_out, 1, |tl, seg| {
            for &t in &csb.by_target[tl] {
                let b = &csb.blocks[t as usize];
                let r0 = b.rows.lo as usize;
                let c0 = b.cols.lo as usize;
                csb.for_each_nz(t as usize, |r, c, _| {
                    let ti = &tcoords[(r0 + r) * d..(r0 + r + 1) * d];
                    let sj = &scoords[(c0 + c) * d..(c0 + c + 1) * d];
                    let mut d2 = 0.0f32;
                    for k in 0..d {
                        let t = ti[k] - sj[k];
                        d2 += t * t;
                    }
                    seg[r] += (-d2 * inv_h2).exp() * x[c0 + c];
                });
            }
        });
    }

    /// Mean-shift partial sums (§3.2): returns `(num, den)` with
    /// `num_i = Σ_j w_ij s_j` (`n x d`) and `den_i = Σ_j w_ij`.
    pub fn meanshift_step(
        &self,
        tcoords: &[f32],
        scoords: &[f32],
        d: usize,
        inv_h2: f32,
    ) -> (Vec<f32>, Vec<f32>) {
        let n = self.csb.rows;
        let mut num = vec![0.0f32; n * d];
        let mut den = vec![0.0f32; n];
        // Fuse both outputs into one pass: compute into num, accumulate den
        // in a second buffer owned by the same target leaf.
        struct SendPtr(*mut f32);
        unsafe impl Send for SendPtr {}
        unsafe impl Sync for SendPtr {}
        let dp = SendPtr(den.as_mut_ptr());
        let dpr = &dp;
        let csb = &self.csb;
        self.per_target(&mut num, d, |tl, seg| {
            let sp = csb.tgt_leaves[tl];
            // SAFETY: disjoint target spans (same ownership as `seg`).
            let den_seg: &mut [f32] = unsafe {
                std::slice::from_raw_parts_mut(dpr.0.add(sp.lo as usize), sp.len())
            };
            for &t in &csb.by_target[tl] {
                let b = &csb.blocks[t as usize];
                let r0 = b.rows.lo as usize;
                let c0 = b.cols.lo as usize;
                csb.for_each_nz(t as usize, |r, c, _| {
                    let ti = &tcoords[(r0 + r) * d..(r0 + r + 1) * d];
                    let sj = &scoords[(c0 + c) * d..(c0 + c + 1) * d];
                    let mut d2 = 0.0f32;
                    for k in 0..d {
                        let t = ti[k] - sj[k];
                        d2 += t * t;
                    }
                    let w = (-d2 * inv_h2).exp();
                    let out = &mut seg[r * d..(r + 1) * d];
                    for k in 0..d {
                        out[k] += w * sj[k];
                    }
                    den_seg[r] += w;
                });
            }
        });
        (num, den)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::knn::exact::knn_graph;
    use crate::order::Pipeline;
    use crate::sparse::csr::Csr;
    use crate::util::rng::Rng;

    /// Engine + the reordered CSR (values = P) + tree-ordered coords.
    fn setup(n: usize, d: usize) -> (Csr, Engine, Vec<f32>) {
        let ds = SynthSpec::blobs(n, d, 4, 17).generate();
        let g = knn_graph(&ds, 6, 2);
        let a = Csr::from_knn(&g, n).symmetrized();
        let r = Pipeline::dual_tree(d).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        let csb = HierCsb::build(&r.reordered, tree, tree, 32);
        let reordered_ds = ds.permuted(&r.perm);
        let coords = reordered_ds.raw().to_vec();
        (r.reordered, Engine::new(csb, 4), coords)
    }

    /// Dense reference for the attractive force over a CSR profile.
    fn tsne_ref(a: &Csr, y: &[f32], d: usize) -> Vec<f32> {
        let n = a.rows;
        let mut f = vec![0.0f32; n * d];
        for i in 0..n {
            let (cols, vals) = a.row(i);
            for (&j, &p) in cols.iter().zip(vals) {
                let j = j as usize;
                let mut d2 = 0.0f32;
                for k in 0..d {
                    let t = y[i * d + k] - y[j * d + k];
                    d2 += t * t;
                }
                let w = p / (1.0 + d2);
                for k in 0..d {
                    f[i * d + k] += w * (y[i * d + k] - y[j * d + k]);
                }
            }
        }
        f
    }

    #[test]
    fn tsne_attr_matches_reference() {
        let (a, eng, _) = setup(300, 2);
        let mut rng = Rng::new(3);
        let y: Vec<f32> = (0..300 * 2).map(|_| rng.normal() as f32).collect();
        let want = tsne_ref(&a, &y, 2);
        let mut got = vec![0.0f32; 300 * 2];
        eng.tsne_attr(&y, 2, &mut got);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
        }
    }

    #[test]
    fn gauss_apply_matches_direct() {
        let (a, eng, coords) = setup(250, 3);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..250).map(|_| rng.f32()).collect();
        let inv_h2 = 0.7f32;
        // direct over the CSR profile
        let mut want = vec![0.0f32; 250];
        for i in 0..250 {
            let (cols, _) = a.row(i);
            for &j in cols {
                let j = j as usize;
                let mut d2 = 0.0f32;
                for k in 0..3 {
                    let t = coords[i * 3 + k] - coords[j * 3 + k];
                    d2 += t * t;
                }
                want[i] += (-d2 * inv_h2).exp() * x[j];
            }
        }
        let mut got = vec![0.0f32; 250];
        eng.gauss_apply(&coords, &coords, 3, inv_h2, &x, &mut got);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()));
        }
    }

    #[test]
    fn meanshift_step_matches_direct() {
        let (a, eng, coords) = setup(200, 3);
        let inv_h2 = 0.5f32;
        let (num, den) = eng.meanshift_step(&coords, &coords, 3, inv_h2);
        for i in [0usize, 57, 199] {
            let (cols, _) = a.row(i);
            let mut wn = [0.0f32; 3];
            let mut wd = 0.0f32;
            for &j in cols {
                let j = j as usize;
                let mut d2 = 0.0f32;
                for k in 0..3 {
                    let t = coords[i * 3 + k] - coords[j * 3 + k];
                    d2 += t * t;
                }
                let w = (-d2 * inv_h2).exp();
                for k in 0..3 {
                    wn[k] += w * coords[j * 3 + k];
                }
                wd += w;
            }
            assert!((den[i] - wd).abs() < 1e-4 * (1.0 + wd.abs()));
            for k in 0..3 {
                assert!((num[i * 3 + k] - wn[k]).abs() < 1e-3 * (1.0 + wn[k].abs()));
            }
        }
    }

    #[test]
    fn thread_count_invariance() {
        let (_, eng1, coords) = setup(300, 2);
        let eng4 = Engine::new(eng1.csb.clone(), 8);
        let mut rng = Rng::new(5);
        let y: Vec<f32> = (0..300 * 2).map(|_| rng.normal() as f32).collect();
        let _ = coords;
        let mut f1 = vec![0.0f32; 600];
        let mut f4 = vec![0.0f32; 600];
        eng1.tsne_attr(&y, 2, &mut f1);
        eng4.tsne_attr(&y, 2, &mut f4);
        assert_eq!(f1, f4);
    }
}
