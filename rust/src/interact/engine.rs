//! The multi-level interaction engine.
//!
//! Holds the hierarchical block structure (profile + stationary values) and
//! per-iteration recomputes the non-stationary kernel values *fused* with
//! the block multiply — the paper's key operational point: after the
//! dual-tree reorder, every iteration touches the matrix block by block and
//! the vectors segment by segment, whatever the kernel.
//!
//! Three iteration kernels, matching the case studies and the L1 Pallas
//! kernels (`python/compile/kernels/`):
//!
//! * [`Engine::tsne_attr`]   — attractive force, values `p_ij/(1+‖y_i−y_j‖²)`;
//! * [`Engine::gauss_apply`] — Gaussian matvec, values `exp(−‖t−s‖²·inv_h2)`;
//! * [`Engine::meanshift_step`] — Gaussian numerator/denominator sums.
//!
//! Parallelism: target-leaf ownership (one worker owns all writes to a
//! potential segment), identical to `spmv::multilevel`.
//!
//! Batched execution: all three kernels are multi-RHS under the hood.  A
//! dense block's weights are materialized once ([`BlockScratch`]) and fed
//! to the register-blocked micro-GEMM
//! ([`crate::csb::hier::dense_gemm_acc`]) over every output column at
//! once — d embedding dimensions for t-SNE, d+1 fused columns for mean
//! shift (the ones column yields the denominator), k simultaneous queries
//! for [`Engine::gauss_apply_multi`] — instead of looping scalar matvecs.

use crate::csb::hier::{dense_gemm_acc, HierCsb};
use crate::par::pool::{SendPtr, ThreadPool};

/// The engine: block structure + thread pool.
pub struct Engine {
    pub csb: HierCsb,
    pub pool: ThreadPool,
}

impl Engine {
    pub fn new(csb: HierCsb, threads: usize) -> Engine {
        Engine {
            csb,
            pool: ThreadPool::new_or_default(threads),
        }
    }

    /// Generic per-target-leaf parallel driver with exclusive row-segment
    /// ownership. `f(tleaf, out_segment)` computes all of that leaf's
    /// blocks into its own slice of `out` (`stride` f32 per row).
    fn per_target<F>(&self, out: &mut [f32], stride: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        assert_eq!(out.len(), self.csb.rows * stride);
        out.fill(0.0);
        let op = SendPtr(out.as_mut_ptr());
        let opr = &op;
        let leaves = &self.csb.tgt_leaves;
        self.pool.for_each_chunked(leaves.len(), 4, |tl| {
            let sp = leaves[tl];
            // SAFETY: target-leaf row spans are disjoint.
            let seg: &mut [f32] = unsafe {
                std::slice::from_raw_parts_mut(
                    opr.0.add(sp.lo as usize * stride),
                    sp.len() * stride,
                )
            };
            f(tl, seg);
        });
    }

    /// t-SNE attractive force (§3.1), batched.
    ///
    /// * `y`: embedding coordinates, tree-ordered row-major `n x d`
    ///   (targets and sources coincide);
    /// * stored block values are the joint probabilities `p_ij`;
    /// * `force`: output `n x d`, overwritten.
    ///
    /// `F_i = Σ_j p_ij · (1 + ‖y_i − y_j‖²)^{-1} · (y_i − y_j)`.
    ///
    /// Dense blocks run the multi-RHS micro-GEMM over the block-local
    /// augmented RHS `[y − c | 1]` (see [`tsne_block`]); sparse blocklets
    /// keep the fused scalar loop.
    pub fn tsne_attr(&self, y: &[f32], d: usize, force: &mut [f32]) {
        assert_eq!(y.len(), self.csb.cols * d);
        let csb = &self.csb;
        self.per_target(force, d, |tl, seg| {
            let mut scratch = BlockScratch::default();
            for &t in &csb.by_target[tl] {
                tsne_block(csb, t as usize, y, d, &mut scratch, seg);
            }
        });
    }

    /// Gaussian interaction matvec (stationary profile, coordinate-derived
    /// values): `y_out_i = Σ_j exp(−‖t_i − s_j‖²·inv_h2) · x_j` over the
    /// stored profile.  `tcoords`/`scoords` are tree-ordered `n x d`.
    pub fn gauss_apply(
        &self,
        tcoords: &[f32],
        scoords: &[f32],
        d: usize,
        inv_h2: f32,
        x: &[f32],
        y_out: &mut [f32],
    ) {
        self.gauss_apply_multi(tcoords, scoords, d, inv_h2, x, 1, y_out);
    }

    /// Multi-query Gaussian interaction: `k` simultaneous charge vectors
    /// (`x`: `cols x k` row-major) against one stored profile, producing
    /// `y_out`: `rows x k`.
    ///
    /// The kernel values `exp(−‖t_i − s_j‖²·inv_h2)` are computed **once
    /// per profile entry** and applied to all `k` queries: dense blocks
    /// materialize the masked weight block and run the micro-GEMM, sparse
    /// blocklets run row-wise k-wide AXPYs.  The per-query win over `k`
    /// scalar [`Engine::gauss_apply`] calls approaches `k` when the
    /// transcendental dominates.
    #[allow(clippy::too_many_arguments)]
    pub fn gauss_apply_multi(
        &self,
        tcoords: &[f32],
        scoords: &[f32],
        d: usize,
        inv_h2: f32,
        x: &[f32],
        k: usize,
        y_out: &mut [f32],
    ) {
        assert!(k >= 1, "gauss_apply_multi needs at least one query");
        assert_eq!(tcoords.len(), self.csb.rows * d);
        assert_eq!(scoords.len(), self.csb.cols * d);
        assert_eq!(x.len(), self.csb.cols * k);
        let csb = &self.csb;
        self.per_target(y_out, k, |tl, seg| {
            let mut scratch = BlockScratch::default();
            for &t in &csb.by_target[tl] {
                let b = &csb.blocks[t as usize];
                let r0 = b.rows.lo as usize;
                let c0 = b.cols.lo as usize;
                debug_assert_eq!(seg.len(), b.rows.len() * k, "block must span its target leaf");
                // k = 1 stays on the fused pass over stored nonzeros:
                // materializing the masked weight block only pays off once
                // the GEMM amortizes it across multiple RHS columns.
                if k > 1 && csb.dense_slice(t as usize).is_some() {
                    let w = &mut scratch.w;
                    let (rn, cn) =
                        gauss_weights_dense(csb, t as usize, tcoords, scoords, d, inv_h2, w);
                    dense_gemm_acc(&scratch.w, rn, cn, &x[c0 * k..(c0 + cn) * k], k, seg);
                } else {
                    csb.for_each_nz(t as usize, |r, c, _| {
                        let ti = &tcoords[(r0 + r) * d..(r0 + r + 1) * d];
                        let sj = &scoords[(c0 + c) * d..(c0 + c + 1) * d];
                        let mut d2 = 0.0f32;
                        for kk in 0..d {
                            let t = ti[kk] - sj[kk];
                            d2 += t * t;
                        }
                        let w = (-d2 * inv_h2).exp();
                        let xr = &x[(c0 + c) * k..(c0 + c + 1) * k];
                        let out = &mut seg[r * k..(r + 1) * k];
                        for (o, &xv) in out.iter_mut().zip(xr) {
                            *o += w * xv;
                        }
                    });
                }
            }
        });
    }

    /// Mean-shift partial sums (§3.2): returns `(num, den)` with
    /// `num_i = Σ_j w_ij s_j` (`n x d`) and `den_i = Σ_j w_ij`.
    ///
    /// The two outputs are `d + 1` fused RHS columns of one batched block
    /// product: dense blocks run the micro-GEMM against the augmented
    /// source matrix `[s | 1]`, whose last column yields the denominator
    /// row sums for free.
    pub fn meanshift_step(
        &self,
        tcoords: &[f32],
        scoords: &[f32],
        d: usize,
        inv_h2: f32,
    ) -> (Vec<f32>, Vec<f32>) {
        let n = self.csb.rows;
        let mut num = vec![0.0f32; n * d];
        let mut den = vec![0.0f32; n];
        // Augmented sources [s | 1]: cols x (d+1), shared by all workers.
        let ka = d + 1;
        let sa = augment_ones(scoords, self.csb.cols, d);
        // Fuse both outputs into one pass: compute into num, accumulate den
        // in a second buffer owned by the same target leaf.
        let dp = SendPtr(den.as_mut_ptr());
        let dpr = &dp;
        let csb = &self.csb;
        self.per_target(&mut num, d, |tl, seg| {
            let sp = csb.tgt_leaves[tl];
            // SAFETY: disjoint target spans (same ownership as `seg`).
            let den_seg: &mut [f32] = unsafe {
                std::slice::from_raw_parts_mut(dpr.0.add(sp.lo as usize), sp.len())
            };
            let mut scratch = BlockScratch::default();
            for &t in &csb.by_target[tl] {
                let b = &csb.blocks[t as usize];
                let r0 = b.rows.lo as usize;
                let c0 = b.cols.lo as usize;
                debug_assert_eq!(seg.len(), b.rows.len() * d, "block must span its target leaf");
                if csb.dense_slice(t as usize).is_some() {
                    let w = &mut scratch.w;
                    let (rn, cn) =
                        gauss_weights_dense(csb, t as usize, tcoords, scoords, d, inv_h2, w);
                    scratch.out.clear();
                    scratch.out.resize(rn * ka, 0.0);
                    dense_gemm_acc(
                        &scratch.w,
                        rn,
                        cn,
                        &sa[c0 * ka..(c0 + cn) * ka],
                        ka,
                        &mut scratch.out,
                    );
                    for r in 0..rn {
                        let row = &scratch.out[r * ka..(r + 1) * ka];
                        let out = &mut seg[r * d..(r + 1) * d];
                        for (o, &v) in out.iter_mut().zip(&row[..d]) {
                            *o += v;
                        }
                        den_seg[r] += row[d];
                    }
                } else {
                    csb.for_each_nz(t as usize, |r, c, _| {
                        let ti = &tcoords[(r0 + r) * d..(r0 + r + 1) * d];
                        let sj = &scoords[(c0 + c) * d..(c0 + c + 1) * d];
                        let mut d2 = 0.0f32;
                        for k in 0..d {
                            let t = ti[k] - sj[k];
                            d2 += t * t;
                        }
                        let w = (-d2 * inv_h2).exp();
                        let out = &mut seg[r * d..(r + 1) * d];
                        for k in 0..d {
                            out[k] += w * sj[k];
                        }
                        den_seg[r] += w;
                    });
                }
            }
        });
        (num, den)
    }
}

/// Reusable per-worker scratch of the batched block kernels: the
/// materialized weight block, the micro-GEMM output panel, and the
/// block-local RHS panel.  One scratch per target-leaf task keeps the
/// buffers hot across that leaf's blocks without cross-thread sharing.
#[derive(Default)]
pub struct BlockScratch {
    /// Materialized (masked) kernel weights, row-major block shape.
    pub w: Vec<f32>,
    /// GEMM output panel, `block_rows x k` row-major.
    pub out: Vec<f32>,
    /// Block-local augmented RHS panel, `block_cols x k` row-major.
    pub xs: Vec<f32>,
}

/// Augment a row-major `n x d` coordinate array with a trailing ones
/// column → `n x (d+1)`.  The ones column turns row sums into one more RHS
/// column of the same block product (used by the mean-shift batched
/// kernel; the t-SNE kernel builds a block-local shifted variant).
pub fn augment_ones(x: &[f32], n: usize, d: usize) -> Vec<f32> {
    assert_eq!(x.len(), n * d);
    let ka = d + 1;
    let mut out = vec![1.0f32; n * ka];
    for i in 0..n {
        out[i * ka..i * ka + d].copy_from_slice(&x[i * d..(i + 1) * d]);
    }
    out
}

/// Per-block fused t-SNE attractive kernel, shared by [`Engine::tsne_attr`]
/// and the coordinator's Rust phase (identical op order on both paths, so
/// the hybrid and pure-engine results match bit-for-bit on Rust-routed
/// blocks).
///
/// Dense blocks materialize `w_ij = p_ij/(1+‖y_i−y_j‖²)` once and run the
/// multi-RHS micro-GEMM against the block-local augmented RHS
/// `[y_j − c | 1]` (`block_cols x (d+1)`), where `c` is the block's first
/// source coordinate: column `d` of the product is the weight row sum
/// `rs`, giving `F_i = rs·(y_i − c) − (W·(y − c))_i` without a second
/// pass.  The shift by `c` keeps both terms at cluster-radius magnitude —
/// the unshifted `rs·y_i − (W·y)_i` form cancels catastrophically when a
/// dense cluster sits far from the embedding origin.  Sparse blocklets run
/// the fused scalar loop.
///
/// `seg` is the target-leaf output segment (`block_rows x d`); blocks span
/// exactly one target leaf, so block-local rows index it directly.
pub fn tsne_block(
    csb: &HierCsb,
    t: usize,
    y: &[f32],
    d: usize,
    scratch: &mut BlockScratch,
    seg: &mut [f32],
) {
    let b = &csb.blocks[t];
    let r0 = b.rows.lo as usize;
    let c0 = b.cols.lo as usize;
    let ka = d + 1;
    debug_assert_eq!(seg.len(), b.rows.len() * d, "block must span its target leaf");
    if let Some(dvals) = csb.dense_slice(t) {
        let rn = b.rows.len();
        let cn = b.cols.len();
        scratch.w.clear();
        scratch.w.resize(rn * cn, 0.0);
        for r in 0..rn {
            let yi = &y[(r0 + r) * d..(r0 + r + 1) * d];
            let wrow = &mut scratch.w[r * cn..(r + 1) * cn];
            let prow = &dvals[r * cn..(r + 1) * cn];
            for (c, (wv, &p)) in wrow.iter_mut().zip(prow).enumerate() {
                if p != 0.0 {
                    let yj = &y[(c0 + c) * d..(c0 + c + 1) * d];
                    let mut d2 = 0.0f32;
                    for k in 0..d {
                        let t = yi[k] - yj[k];
                        d2 += t * t;
                    }
                    *wv = p / (1.0 + d2);
                }
            }
        }
        // Reference point: the block's first source coordinate (points in
        // a dense block are near-neighbors, so every |y_j − c| is small).
        let cref = &y[c0 * d..(c0 + 1) * d];
        scratch.xs.clear();
        scratch.xs.resize(cn * ka, 0.0);
        for j in 0..cn {
            let yj = &y[(c0 + j) * d..(c0 + j + 1) * d];
            let xrow = &mut scratch.xs[j * ka..(j + 1) * ka];
            for k in 0..d {
                xrow[k] = yj[k] - cref[k];
            }
            xrow[d] = 1.0;
        }
        scratch.out.clear();
        scratch.out.resize(rn * ka, 0.0);
        dense_gemm_acc(&scratch.w, rn, cn, &scratch.xs, ka, &mut scratch.out);
        for r in 0..rn {
            let yi = &y[(r0 + r) * d..(r0 + r + 1) * d];
            let row = &scratch.out[r * ka..(r + 1) * ka];
            let rs = row[d];
            let out = &mut seg[r * d..(r + 1) * d];
            for k in 0..d {
                out[k] += rs * (yi[k] - cref[k]) - row[k];
            }
        }
    } else {
        csb.for_each_nz(t, |r, c, p| {
            let yi = &y[(r0 + r) * d..(r0 + r + 1) * d];
            let yj = &y[(c0 + c) * d..(c0 + c + 1) * d];
            let mut d2 = 0.0f32;
            for k in 0..d {
                let t = yi[k] - yj[k];
                d2 += t * t;
            }
            let w = p / (1.0 + d2);
            let out = &mut seg[r * d..(r + 1) * d];
            for k in 0..d {
                out[k] += w * (yi[k] - yj[k]);
            }
        });
    }
}

/// Materialize the masked Gaussian weight block of dense block `t` into
/// `w` (row-major `rows x cols`): `w_rc = exp(−‖t_r − s_c‖²·inv_h2)` where
/// the stored profile has an entry, 0 elsewhere.  Returns (rows, cols).
///
/// Must only be called for dense-stored blocks (the caller dispatches).
fn gauss_weights_dense(
    csb: &HierCsb,
    t: usize,
    tcoords: &[f32],
    scoords: &[f32],
    d: usize,
    inv_h2: f32,
    w: &mut Vec<f32>,
) -> (usize, usize) {
    let b = &csb.blocks[t];
    let r0 = b.rows.lo as usize;
    let c0 = b.cols.lo as usize;
    let rn = b.rows.len();
    let cn = b.cols.len();
    let dvals = csb.dense_slice(t).expect("gauss_weights_dense on sparse block");
    w.clear();
    w.resize(rn * cn, 0.0);
    for r in 0..rn {
        let ti = &tcoords[(r0 + r) * d..(r0 + r + 1) * d];
        let wrow = &mut w[r * cn..(r + 1) * cn];
        let prow = &dvals[r * cn..(r + 1) * cn];
        for (c, (wv, &p)) in wrow.iter_mut().zip(prow).enumerate() {
            if p != 0.0 {
                let sj = &scoords[(c0 + c) * d..(c0 + c + 1) * d];
                let mut d2 = 0.0f32;
                for k in 0..d {
                    let t = ti[k] - sj[k];
                    d2 += t * t;
                }
                *wv = (-d2 * inv_h2).exp();
            }
        }
    }
    (rn, cn)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::knn::exact::knn_graph;
    use crate::order::Pipeline;
    use crate::sparse::csr::Csr;
    use crate::util::rng::Rng;

    /// Engine + the reordered CSR (values = P) + tree-ordered coords.
    fn setup(n: usize, d: usize) -> (Csr, Engine, Vec<f32>) {
        let ds = SynthSpec::blobs(n, d, 4, 17).generate();
        let g = knn_graph(&ds, 6, 2);
        let a = Csr::from_knn(&g, n).symmetrized();
        let r = Pipeline::dual_tree(d).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        let csb = HierCsb::build(&r.reordered, tree, tree, 32);
        let reordered_ds = ds.permuted(&r.perm);
        let coords = reordered_ds.raw().to_vec();
        (r.reordered, Engine::new(csb, 4), coords)
    }

    /// Dense reference for the attractive force over a CSR profile.
    fn tsne_ref(a: &Csr, y: &[f32], d: usize) -> Vec<f32> {
        let n = a.rows;
        let mut f = vec![0.0f32; n * d];
        for i in 0..n {
            let (cols, vals) = a.row(i);
            for (&j, &p) in cols.iter().zip(vals) {
                let j = j as usize;
                let mut d2 = 0.0f32;
                for k in 0..d {
                    let t = y[i * d + k] - y[j * d + k];
                    d2 += t * t;
                }
                let w = p / (1.0 + d2);
                for k in 0..d {
                    f[i * d + k] += w * (y[i * d + k] - y[j * d + k]);
                }
            }
        }
        f
    }

    #[test]
    fn tsne_attr_matches_reference() {
        let (a, eng, _) = setup(300, 2);
        let mut rng = Rng::new(3);
        let y: Vec<f32> = (0..300 * 2).map(|_| rng.normal() as f32).collect();
        let want = tsne_ref(&a, &y, 2);
        let mut got = vec![0.0f32; 300 * 2];
        eng.tsne_attr(&y, 2, &mut got);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
        }
    }

    #[test]
    fn gauss_apply_matches_direct() {
        let (a, eng, coords) = setup(250, 3);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..250).map(|_| rng.f32()).collect();
        let inv_h2 = 0.7f32;
        // direct over the CSR profile
        let mut want = vec![0.0f32; 250];
        for i in 0..250 {
            let (cols, _) = a.row(i);
            for &j in cols {
                let j = j as usize;
                let mut d2 = 0.0f32;
                for k in 0..3 {
                    let t = coords[i * 3 + k] - coords[j * 3 + k];
                    d2 += t * t;
                }
                want[i] += (-d2 * inv_h2).exp() * x[j];
            }
        }
        let mut got = vec![0.0f32; 250];
        eng.gauss_apply(&coords, &coords, 3, inv_h2, &x, &mut got);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()));
        }
    }

    #[test]
    fn meanshift_step_matches_direct() {
        let (a, eng, coords) = setup(200, 3);
        let inv_h2 = 0.5f32;
        let (num, den) = eng.meanshift_step(&coords, &coords, 3, inv_h2);
        for i in [0usize, 57, 199] {
            let (cols, _) = a.row(i);
            let mut wn = [0.0f32; 3];
            let mut wd = 0.0f32;
            for &j in cols {
                let j = j as usize;
                let mut d2 = 0.0f32;
                for k in 0..3 {
                    let t = coords[i * 3 + k] - coords[j * 3 + k];
                    d2 += t * t;
                }
                let w = (-d2 * inv_h2).exp();
                for k in 0..3 {
                    wn[k] += w * coords[j * 3 + k];
                }
                wd += w;
            }
            assert!((den[i] - wd).abs() < 1e-4 * (1.0 + wd.abs()));
            for k in 0..3 {
                assert!((num[i * 3 + k] - wn[k]).abs() < 1e-3 * (1.0 + wn[k].abs()));
            }
        }
    }

    /// Engine with a low dense threshold so the batched dense-block path
    /// is actually exercised (clustered blobs → dense diagonal blocks).
    fn setup_dense(n: usize, d: usize) -> (Csr, Engine, Vec<f32>) {
        let ds = SynthSpec::blobs(n, d, 4, 17).generate();
        let g = knn_graph(&ds, 6, 2);
        let a = Csr::from_knn(&g, n).symmetrized();
        let r = Pipeline::dual_tree(d).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        let csb = HierCsb::build_with(&r.reordered, tree, tree, 32, 0.25);
        assert!(csb.dense_fraction() > 0.0, "test needs dense blocks: {}", csb.describe());
        let coords = ds.permuted(&r.perm).raw().to_vec();
        (r.reordered, Engine::new(csb, 4), coords)
    }

    #[test]
    fn gauss_apply_multi_matches_per_query() {
        let (_, eng, coords) = setup_dense(300, 3);
        let n = 300;
        let mut rng = Rng::new(6);
        let k = 5;
        let x: Vec<f32> = (0..n * k).map(|_| rng.f32() - 0.5).collect();
        let inv_h2 = 0.6f32;
        let mut got = vec![0.0f32; n * k];
        eng.gauss_apply_multi(&coords, &coords, 3, inv_h2, &x, k, &mut got);
        for j in 0..k {
            let xj: Vec<f32> = (0..n).map(|i| x[i * k + j]).collect();
            let mut want = vec![0.0f32; n];
            eng.gauss_apply(&coords, &coords, 3, inv_h2, &xj, &mut want);
            for i in 0..n {
                let g = got[i * k + j];
                let w = want[i];
                assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()), "q{j} row{i}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn batched_dense_path_matches_sparse_path() {
        // The same profile stored all-dense vs all-sparse must produce the
        // same kernels: exercises micro-GEMM vs fused scalar consistency.
        let ds = SynthSpec::blobs(250, 2, 3, 9).generate();
        let g = knn_graph(&ds, 6, 2);
        let a = Csr::from_knn(&g, 250).symmetrized();
        let r = Pipeline::dual_tree(2).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        let dense_eng = Engine::new(HierCsb::build_with(&r.reordered, tree, tree, 32, 0.0), 2);
        let sparse_eng = Engine::new(HierCsb::build_with(&r.reordered, tree, tree, 32, 1.1), 2);
        let coords = ds.permuted(&r.perm).raw().to_vec();
        let mut rng = Rng::new(11);
        let y: Vec<f32> = (0..250 * 2).map(|_| rng.normal() as f32).collect();
        let mut f1 = vec![0.0f32; 500];
        let mut f2 = vec![0.0f32; 500];
        dense_eng.tsne_attr(&y, 2, &mut f1);
        sparse_eng.tsne_attr(&y, 2, &mut f2);
        for (a, b) in f1.iter().zip(&f2) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
        let (n1, d1) = dense_eng.meanshift_step(&coords, &coords, 2, 0.5);
        let (n2, d2) = sparse_eng.meanshift_step(&coords, &coords, 2, 0.5);
        for (a, b) in n1.iter().zip(&n2).chain(d1.iter().zip(&d2)) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn thread_count_invariance() {
        let (_, eng1, coords) = setup(300, 2);
        let eng4 = Engine::new(eng1.csb.clone(), 8);
        let mut rng = Rng::new(5);
        let y: Vec<f32> = (0..300 * 2).map(|_| rng.normal() as f32).collect();
        let _ = coords;
        let mut f1 = vec![0.0f32; 600];
        let mut f4 = vec![0.0f32; 600];
        eng1.tsne_attr(&y, 2, &mut f1);
        eng4.tsne_attr(&y, 2, &mut f4);
        assert_eq!(f1, f4);
    }
}
