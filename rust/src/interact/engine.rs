//! The multi-level interaction engine.
//!
//! Holds the hierarchical block structure (profile + stationary values) and
//! per-iteration recomputes the non-stationary kernel values *fused* with
//! the block multiply — the paper's key operational point: after the
//! dual-tree reorder, every iteration touches the matrix block by block and
//! the vectors segment by segment, whatever the kernel.
//!
//! Three iteration kernels, matching the case studies and the L1 Pallas
//! kernels (`python/compile/kernels/`):
//!
//! * [`Engine::tsne_attr`]   — attractive force, values `p_ij/(1+‖y_i−y_j‖²)`;
//! * [`Engine::gauss_apply`] — Gaussian matvec, values `exp(−‖t−s‖²·inv_h2)`;
//! * [`Engine::meanshift_step`] — Gaussian numerator/denominator sums.
//!
//! Execution model (the precompiled apply side):
//!
//! * **Schedule** — the multilevel traversal is flattened once into an
//!   [`ApplySchedule`] (target-leaf-owned flat task lists, heaviest leaf
//!   first) at construction; every apply walks that schedule instead of
//!   re-deriving traversal state.  Ownership: one worker owns all writes
//!   to a potential segment, identical to `spmv::multilevel`.
//! * **Kernel dispatch** — block products run through `csb::kernel`:
//!   `--kernel scalar` pins the golden reference (bit-identical across
//!   thread counts and to the pre-SIMD engine); `auto`/`simd` route dense
//!   blocks to the AVX2 panel GEMM and DCSR blocklets to the AVX2
//!   broadcast-FMA kernel when the CPU supports it.
//! * **Scratch** — each worker owns a reusable [`BlockScratch`] slot on
//!   the engine (weight block, panel, GEMM panel, RHS panel), so
//!   steady-state applies are allocation-free
//!   (`rust/tests/alloc_steady_state.rs` counts).
//!
//! Batched execution: all three kernels are multi-RHS under the hood.  A
//! dense block's weights are materialized once ([`BlockScratch`]) and fed
//! to the dispatched micro-GEMM over every output column at once — d
//! embedding dimensions for t-SNE, d+1 fused columns for mean shift (the
//! ones column yields the denominator), k simultaneous queries for
//! [`Engine::gauss_apply_multi`] — instead of looping scalar matvecs.

use crate::csb::hier::HierCsb;
use crate::csb::kernel::{dense_gemm_acc, Dispatch, KernelKind};
use crate::csb::panel::AlignedF32;
use crate::obs::{self, counters, Counter};
use crate::par::pool::{SendPtr, ThreadPool};
use crate::spmv::multilevel::ApplySchedule;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

/// The engine: block structure + thread pool + precompiled schedule +
/// kernel dispatch + per-worker scratch.
pub struct Engine {
    pub csb: HierCsb,
    pub pool: ThreadPool,
    /// Kernel selection as requested (CLI `--kernel`).
    pub kernel: KernelKind,
    /// Why a non-scalar request resolved to the scalar kernel (`None`
    /// when the SIMD path is live or scalar was requested) — surfaced in
    /// bench records and CLI output.
    pub dispatch_fallback: Option<&'static str>,
    dispatch: Dispatch,
    schedule: ApplySchedule,
    /// One reusable kernel scratch per pool worker; worker `w` locks slot
    /// `w` only, so the locks are uncontended.
    scratch: Vec<Mutex<BlockScratch>>,
    /// Apply-level shared buffers (mean shift's augmented sources).
    shared: Mutex<SharedScratch>,
    /// Per-worker busy nanoseconds of the current apply call (engine-owned
    /// so the traced imbalance measurement stays allocation-free); folded
    /// into the `obs` counters and zeroed at the end of each call.
    worker_ns: Vec<AtomicU64>,
}

impl Engine {
    /// Engine with automatic kernel dispatch (SIMD when available).
    pub fn new(csb: HierCsb, threads: usize) -> Engine {
        Engine::with_kernel(csb, threads, KernelKind::Auto)
    }

    /// Engine with an explicit kernel choice (`Scalar` pins the bit-exact
    /// reference path for determinism-sensitive runs).
    pub fn with_kernel(csb: HierCsb, threads: usize, kernel: KernelKind) -> Engine {
        obs::span!("interact.engine.build");
        let pool = ThreadPool::new_or_default(threads);
        let (dispatch, dispatch_fallback) = kernel.resolve();
        let schedule = ApplySchedule::build(&csb);
        let scratch = (0..pool.threads)
            .map(|_| Mutex::new(BlockScratch::default()))
            .collect();
        // Pre-size the span slabs here — the engine build is the last
        // allocation point before the (allocation-free) apply steady state.
        obs::install(pool.threads, obs::DEFAULT_SPAN_CAP);
        let worker_ns = (0..pool.threads).map(|_| AtomicU64::new(0)).collect();
        Engine {
            csb,
            pool,
            kernel,
            dispatch_fallback,
            dispatch,
            schedule,
            scratch,
            shared: Mutex::new(SharedScratch::default()),
            worker_ns,
        }
    }

    /// The concrete kernel this engine runs.
    pub fn dispatch(&self) -> Dispatch {
        self.dispatch
    }

    /// The precompiled apply schedule.
    pub fn schedule(&self) -> &ApplySchedule {
        &self.schedule
    }

    /// Worker `w`'s reusable kernel scratch (uncontended by construction:
    /// only worker `w` of this engine's pool locks slot `w`).
    pub fn worker_scratch(&self, w: usize) -> MutexGuard<'_, BlockScratch> {
        self.scratch[w].lock().unwrap()
    }

    /// Generic schedule-driven parallel driver with exclusive row-segment
    /// ownership.  `f(scratch, tleaf, block_ids, out_segment)` computes
    /// one task's blocks into its own slice of `out` (`stride` f32 per
    /// row), with that worker's reusable scratch.
    ///
    /// `gemm_k` is the RHS width of the kernel's block products (`stride`
    /// for plain SpMM, `d + 1` for the augmented t-SNE/mean-shift GEMMs) —
    /// used only to feed the schedule-static profile counters, one
    /// `fetch_add` per quantity per call.  Per-task spans and per-worker
    /// busy-time (the imbalance measure) are recorded only while tracing
    /// is enabled; all of it is allocation-free.
    fn per_target<F>(&self, out: &mut [f32], stride: usize, gemm_k: usize, f: F)
    where
        F: Fn(&mut BlockScratch, usize, &[u32], &mut [f32]) + Sync,
    {
        assert_eq!(out.len(), self.csb.rows * stride);
        out.fill(0.0);
        let op = SendPtr(out.as_mut_ptr());
        let opr = &op;
        let leaves = &self.csb.tgt_leaves;
        let sched = &self.schedule;
        let traced = obs::enabled();
        self.pool.for_each_chunked_worker(sched.tasks.len(), 1, |w, ti| {
            obs::span!("apply.task");
            let t0 = if traced { Some(Instant::now()) } else { None };
            let task = sched.tasks[ti];
            let sp = leaves[task.tleaf as usize];
            // SAFETY: target-leaf row spans are disjoint, and each leaf is
            // owned by exactly one schedule task.
            let seg: &mut [f32] = unsafe {
                std::slice::from_raw_parts_mut(
                    opr.0.add(sp.lo as usize * stride),
                    sp.len() * stride,
                )
            };
            let mut scratch = self.scratch[w].lock().unwrap();
            f(&mut *scratch, task.tleaf as usize, sched.blocks_of(&task), seg);
            if let Some(t0) = t0 {
                self.worker_ns[w].fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
        });
        if traced {
            self.fold_worker_ns(sched.tasks.len());
        }
        counters::add(Counter::ApplyCalls, 1);
        counters::add(Counter::ApplyTasks, sched.tasks.len() as u64);
        counters::add(Counter::ApplyGemmFlops, sched.flops(gemm_k));
        counters::add(Counter::ApplyPanelBytes, sched.panel_bytes);
        counters::add(Counter::ApplySparseNnz, sched.sparse_nnz);
    }

    /// Fold the per-worker busy times of one traced apply call into the
    /// global imbalance counters and zero the slots for the next call.
    fn fold_worker_ns(&self, tasks: usize) {
        let mut total = 0u64;
        let mut max = 0u64;
        for slot in &self.worker_ns {
            let v = slot.swap(0, Ordering::Relaxed);
            total += v;
            max = max.max(v);
        }
        if total > 0 {
            counters::add(Counter::ApplyWorkerNsTotal, total);
            counters::add(Counter::ApplyWorkerNsMax, max);
            counters::raise(Counter::ApplyWorkers, self.pool.threads.min(tasks).max(1) as u64);
        }
    }

    /// Schedule-driven parallel SpMM with this engine's kernel dispatch:
    /// `Y = A X` over the stored block values (`x`: `cols x k`, `y`:
    /// `rows x k`, row-major; y overwritten).  With the scalar kernel this
    /// is bit-exact with `spmv::multilevel::spmm_ml_seq` at any thread
    /// count.
    pub fn spmm(&self, x: &[f32], y: &mut [f32], k: usize) {
        assert!(k >= 1, "spmm needs at least one RHS column");
        assert_eq!(x.len(), self.csb.cols * k);
        obs::span!("apply.spmm");
        let csb = &self.csb;
        let dispatch = self.dispatch;
        self.per_target(y, k, k, |_scratch, _tl, blocks, seg| {
            for &t in blocks {
                csb.block_matmul_seg_with(t as usize, x, seg, k, dispatch);
            }
        });
    }

    /// Schedule-driven parallel SpMV (`k = 1` [`Engine::spmm`]).
    pub fn spmv(&self, x: &[f32], y: &mut [f32]) {
        self.spmm(x, y, 1);
    }

    /// t-SNE attractive force (§3.1), batched.
    ///
    /// * `y`: embedding coordinates, tree-ordered row-major `n x d`
    ///   (targets and sources coincide);
    /// * stored block values are the joint probabilities `p_ij`;
    /// * `force`: output `n x d`, overwritten.
    ///
    /// `F_i = Σ_j p_ij · (1 + ‖y_i − y_j‖²)^{-1} · (y_i − y_j)`.
    ///
    /// Dense blocks run the dispatched multi-RHS micro-GEMM over the
    /// block-local augmented RHS `[y − c | 1]` (see [`tsne_block`]);
    /// sparse blocklets keep the fused scalar loop.
    pub fn tsne_attr(&self, y: &[f32], d: usize, force: &mut [f32]) {
        assert_eq!(y.len(), self.csb.cols * d);
        obs::span!("apply.tsne_attr");
        let csb = &self.csb;
        let dispatch = self.dispatch;
        self.per_target(force, d, d + 1, |scratch, _tl, blocks, seg| {
            for &t in blocks {
                tsne_block(csb, t as usize, y, d, dispatch, scratch, seg);
            }
        });
    }

    /// Gaussian interaction matvec (stationary profile, coordinate-derived
    /// values): `y_out_i = Σ_j exp(−‖t_i − s_j‖²·inv_h2) · x_j` over the
    /// stored profile.  `tcoords`/`scoords` are tree-ordered `n x d`.
    pub fn gauss_apply(
        &self,
        tcoords: &[f32],
        scoords: &[f32],
        d: usize,
        inv_h2: f32,
        x: &[f32],
        y_out: &mut [f32],
    ) {
        self.gauss_apply_multi(tcoords, scoords, d, inv_h2, x, 1, y_out);
    }

    /// Multi-query Gaussian interaction: `k` simultaneous charge vectors
    /// (`x`: `cols x k` row-major) against one stored profile, producing
    /// `y_out`: `rows x k`.
    ///
    /// The kernel values `exp(−‖t_i − s_j‖²·inv_h2)` are computed **once
    /// per profile entry** and applied to all `k` queries: dense blocks
    /// materialize the masked weight block and run the dispatched
    /// micro-GEMM, sparse blocklets run row-wise k-wide AXPYs.  The
    /// per-query win over `k` scalar [`Engine::gauss_apply`] calls
    /// approaches `k` when the transcendental dominates.
    #[allow(clippy::too_many_arguments)]
    pub fn gauss_apply_multi(
        &self,
        tcoords: &[f32],
        scoords: &[f32],
        d: usize,
        inv_h2: f32,
        x: &[f32],
        k: usize,
        y_out: &mut [f32],
    ) {
        assert!(k >= 1, "gauss_apply_multi needs at least one query");
        assert_eq!(tcoords.len(), self.csb.rows * d);
        assert_eq!(scoords.len(), self.csb.cols * d);
        assert_eq!(x.len(), self.csb.cols * k);
        obs::span!("apply.gauss");
        let csb = &self.csb;
        let dispatch = self.dispatch;
        self.per_target(y_out, k, k, |scratch, _tl, blocks, seg| {
            for &t in blocks {
                let b = &csb.blocks[t as usize];
                let r0 = b.rows.lo as usize;
                let c0 = b.cols.lo as usize;
                debug_assert_eq!(seg.len(), b.rows.len() * k, "block must span its target leaf");
                // k = 1 stays on the fused pass over stored nonzeros:
                // materializing the masked weight block only pays off once
                // the GEMM amortizes it across multiple RHS columns.
                if k > 1 && csb.dense_slice(t as usize).is_some() {
                    let (rn, cn) = gauss_weights_dense(
                        csb,
                        t as usize,
                        tcoords,
                        scoords,
                        d,
                        inv_h2,
                        &mut scratch.w,
                    );
                    gemm_dispatch(
                        &scratch.w,
                        rn,
                        cn,
                        &x[c0 * k..(c0 + cn) * k],
                        k,
                        seg,
                        dispatch,
                        &mut scratch.wp,
                    );
                } else {
                    csb.for_each_nz(t as usize, |r, c, _| {
                        let ti = &tcoords[(r0 + r) * d..(r0 + r + 1) * d];
                        let sj = &scoords[(c0 + c) * d..(c0 + c + 1) * d];
                        let mut d2 = 0.0f32;
                        for kk in 0..d {
                            let t = ti[kk] - sj[kk];
                            d2 += t * t;
                        }
                        let w = (-d2 * inv_h2).exp();
                        let xr = &x[(c0 + c) * k..(c0 + c + 1) * k];
                        let out = &mut seg[r * k..(r + 1) * k];
                        for (o, &xv) in out.iter_mut().zip(xr) {
                            *o += w * xv;
                        }
                    });
                }
            }
        });
    }

    /// Mean-shift partial sums (§3.2): returns `(num, den)` with
    /// `num_i = Σ_j w_ij s_j` (`n x d`) and `den_i = Σ_j w_ij`.
    ///
    /// Allocating wrapper around [`Engine::meanshift_step_into`].
    pub fn meanshift_step(
        &self,
        tcoords: &[f32],
        scoords: &[f32],
        d: usize,
        inv_h2: f32,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut num = Vec::new();
        let mut den = Vec::new();
        self.meanshift_step_into(tcoords, scoords, d, inv_h2, &mut num, &mut den);
        (num, den)
    }

    /// Mean-shift partial sums into caller-owned buffers (resized to
    /// `rows x d` / `rows`; allocation-free once warm — the per-iteration
    /// hot path of the mean-shift loop).
    ///
    /// The two outputs are `d + 1` fused RHS columns of one batched block
    /// product: dense blocks run the dispatched micro-GEMM against the
    /// augmented source matrix `[s | 1]`, whose last column yields the
    /// denominator row sums for free.
    pub fn meanshift_step_into(
        &self,
        tcoords: &[f32],
        scoords: &[f32],
        d: usize,
        inv_h2: f32,
        num: &mut Vec<f32>,
        den: &mut Vec<f32>,
    ) {
        let n = self.csb.rows;
        obs::span!("apply.meanshift");
        num.clear();
        num.resize(n * d, 0.0);
        den.clear();
        den.resize(n, 0.0);
        // Augmented sources [s | 1]: cols x (d+1), shared by all workers
        // (engine-owned buffer, refilled in place each call).
        let ka = d + 1;
        let mut sh = self.shared.lock().unwrap();
        fill_augment_ones(scoords, self.csb.cols, d, &mut sh.sa);
        let sa: &[f32] = &sh.sa;
        // Fuse both outputs into one pass: compute into num, accumulate den
        // in a second buffer owned by the same target leaf.
        let dp = SendPtr(den.as_mut_ptr());
        let dpr = &dp;
        let csb = &self.csb;
        let dispatch = self.dispatch;
        self.per_target(num, d, d + 1, |scratch, tl, blocks, seg| {
            let sp = csb.tgt_leaves[tl];
            // SAFETY: disjoint target spans (same ownership as `seg`).
            let den_seg: &mut [f32] =
                unsafe { std::slice::from_raw_parts_mut(dpr.0.add(sp.lo as usize), sp.len()) };
            for &t in blocks {
                let b = &csb.blocks[t as usize];
                let r0 = b.rows.lo as usize;
                let c0 = b.cols.lo as usize;
                debug_assert_eq!(seg.len(), b.rows.len() * d, "block must span its target leaf");
                if csb.dense_slice(t as usize).is_some() {
                    let (rn, cn) = gauss_weights_dense(
                        csb,
                        t as usize,
                        tcoords,
                        scoords,
                        d,
                        inv_h2,
                        &mut scratch.w,
                    );
                    scratch.out.clear();
                    scratch.out.resize(rn * ka, 0.0);
                    gemm_dispatch(
                        &scratch.w,
                        rn,
                        cn,
                        &sa[c0 * ka..(c0 + cn) * ka],
                        ka,
                        &mut scratch.out,
                        dispatch,
                        &mut scratch.wp,
                    );
                    for r in 0..rn {
                        let row = &scratch.out[r * ka..(r + 1) * ka];
                        let out = &mut seg[r * d..(r + 1) * d];
                        for (o, &v) in out.iter_mut().zip(&row[..d]) {
                            *o += v;
                        }
                        den_seg[r] += row[d];
                    }
                } else {
                    csb.for_each_nz(t as usize, |r, c, _| {
                        let ti = &tcoords[(r0 + r) * d..(r0 + r + 1) * d];
                        let sj = &scoords[(c0 + c) * d..(c0 + c + 1) * d];
                        let mut d2 = 0.0f32;
                        for k in 0..d {
                            let t = ti[k] - sj[k];
                            d2 += t * t;
                        }
                        let w = (-d2 * inv_h2).exp();
                        let out = &mut seg[r * d..(r + 1) * d];
                        for k in 0..d {
                            out[k] += w * sj[k];
                        }
                        den_seg[r] += w;
                    });
                }
            }
        });
    }
}

/// Reusable per-worker scratch of the batched block kernels: the
/// materialized weight block, its panel-packed copy (SIMD dispatch), the
/// micro-GEMM output panel, and the block-local RHS panel.  One scratch
/// per pool worker, owned by the [`Engine`], keeps the buffers hot across
/// every apply of the engine's lifetime — steady-state applies allocate
/// nothing.
#[derive(Default)]
pub struct BlockScratch {
    /// Materialized (masked) kernel weights, row-major block shape.
    pub w: Vec<f32>,
    /// Tile-major panel packing of `w` (only the SIMD dispatch uses it).
    pub wp: AlignedF32,
    /// GEMM output panel, `block_rows x k` row-major.
    pub out: Vec<f32>,
    /// Block-local augmented RHS panel, `block_cols x k` row-major.
    pub xs: Vec<f32>,
}

/// Engine-owned buffers shared across one apply (not per-worker).
#[derive(Default)]
struct SharedScratch {
    /// Mean shift's augmented source matrix `[s | 1]`.
    sa: Vec<f32>,
}

/// Run the dense micro-GEMM `y += w · x` under `dispatch`: the scalar path
/// consumes the row-major weight block directly; the SIMD path packs it
/// into `wp` (tile-major panel, buffer reused across blocks) first — the
/// pack is a linear copy, negligible against the transcendental weight
/// fill that precedes it.
#[allow(clippy::too_many_arguments)]
fn gemm_dispatch(
    w: &[f32],
    rn: usize,
    cn: usize,
    x: &[f32],
    k: usize,
    y: &mut [f32],
    dispatch: Dispatch,
    wp: &mut AlignedF32,
) {
    match dispatch {
        Dispatch::Scalar => dense_gemm_acc(w, rn, cn, x, k, y),
        Dispatch::Avx2 => gemm_avx2(w, rn, cn, x, k, y, wp),
    }
}

#[cfg(target_arch = "x86_64")]
fn gemm_avx2(
    w: &[f32],
    rn: usize,
    cn: usize,
    x: &[f32],
    k: usize,
    y: &mut [f32],
    wp: &mut AlignedF32,
) {
    use crate::csb::panel::{pack_panel, panel_len};
    // Same guard as `HierCsb::block_matmul_seg_avx2`: a hand-built
    // Dispatch::Avx2 must not reach the target-feature kernel on an
    // unsupported CPU (the probe is cached by std).
    if crate::csb::kernel::detect() != Dispatch::Avx2 {
        return dense_gemm_acc(w, rn, cn, x, k, y);
    }
    let panel = wp.reset_zeroed(panel_len(rn, cn));
    pack_panel(w, rn, cn, panel);
    // SAFETY: the detect() guard above confirmed AVX2+FMA.
    unsafe { crate::csb::kernel::avx2::panel_gemm_acc(panel, rn, cn, x, k, y) };
}

#[cfg(not(target_arch = "x86_64"))]
fn gemm_avx2(
    w: &[f32],
    rn: usize,
    cn: usize,
    x: &[f32],
    k: usize,
    y: &mut [f32],
    _wp: &mut AlignedF32,
) {
    // `kernel::detect()` never yields Avx2 on this target; backstop only.
    dense_gemm_acc(w, rn, cn, x, k, y)
}

/// Augment a row-major `n x d` coordinate array with a trailing ones
/// column → `n x (d+1)`.  The ones column turns row sums into one more RHS
/// column of the same block product (used by the mean-shift batched
/// kernel; the t-SNE kernel builds a block-local shifted variant).
pub fn augment_ones(x: &[f32], n: usize, d: usize) -> Vec<f32> {
    let mut out = Vec::new();
    fill_augment_ones(x, n, d, &mut out);
    out
}

/// [`augment_ones`] into a reusable buffer (allocation-free once warm).
pub fn fill_augment_ones(x: &[f32], n: usize, d: usize, out: &mut Vec<f32>) {
    assert_eq!(x.len(), n * d);
    let ka = d + 1;
    out.clear();
    out.resize(n * ka, 1.0);
    for i in 0..n {
        out[i * ka..i * ka + d].copy_from_slice(&x[i * d..(i + 1) * d]);
    }
}

/// Per-block fused t-SNE attractive kernel, shared by [`Engine::tsne_attr`]
/// and the coordinator's Rust phase (identical op order on both paths
/// under a given dispatch, so the hybrid and pure-engine results match
/// bit-for-bit on Rust-routed blocks).
///
/// Dense blocks materialize `w_ij = p_ij/(1+‖y_i−y_j‖²)` once and run the
/// dispatched multi-RHS micro-GEMM against the block-local augmented RHS
/// `[y_j − c | 1]` (`block_cols x (d+1)`), where `c` is the block's first
/// source coordinate: column `d` of the product is the weight row sum
/// `rs`, giving `F_i = rs·(y_i − c) − (W·(y − c))_i` without a second
/// pass.  The shift by `c` keeps both terms at cluster-radius magnitude —
/// the unshifted `rs·y_i − (W·y)_i` form cancels catastrophically when a
/// dense cluster sits far from the embedding origin.  Sparse blocklets run
/// the fused scalar loop (the transcendental-free weight is cheaper than a
/// gather into SIMD lanes at typical blocklet sizes).
///
/// `seg` is the target-leaf output segment (`block_rows x d`); blocks span
/// exactly one target leaf, so block-local rows index it directly.
pub fn tsne_block(
    csb: &HierCsb,
    t: usize,
    y: &[f32],
    d: usize,
    dispatch: Dispatch,
    scratch: &mut BlockScratch,
    seg: &mut [f32],
) {
    let b = &csb.blocks[t];
    let r0 = b.rows.lo as usize;
    let c0 = b.cols.lo as usize;
    let ka = d + 1;
    debug_assert_eq!(seg.len(), b.rows.len() * d, "block must span its target leaf");
    if let Some(dvals) = csb.dense_slice(t) {
        let rn = b.rows.len();
        let cn = b.cols.len();
        scratch.w.clear();
        scratch.w.resize(rn * cn, 0.0);
        for r in 0..rn {
            let yi = &y[(r0 + r) * d..(r0 + r + 1) * d];
            let wrow = &mut scratch.w[r * cn..(r + 1) * cn];
            let prow = &dvals[r * cn..(r + 1) * cn];
            for (c, (wv, &p)) in wrow.iter_mut().zip(prow).enumerate() {
                if p != 0.0 {
                    let yj = &y[(c0 + c) * d..(c0 + c + 1) * d];
                    let mut d2 = 0.0f32;
                    for k in 0..d {
                        let t = yi[k] - yj[k];
                        d2 += t * t;
                    }
                    *wv = p / (1.0 + d2);
                }
            }
        }
        // Reference point: the block's first source coordinate (points in
        // a dense block are near-neighbors, so every |y_j − c| is small).
        let cref = &y[c0 * d..(c0 + 1) * d];
        scratch.xs.clear();
        scratch.xs.resize(cn * ka, 0.0);
        for j in 0..cn {
            let yj = &y[(c0 + j) * d..(c0 + j + 1) * d];
            let xrow = &mut scratch.xs[j * ka..(j + 1) * ka];
            for k in 0..d {
                xrow[k] = yj[k] - cref[k];
            }
            xrow[d] = 1.0;
        }
        scratch.out.clear();
        scratch.out.resize(rn * ka, 0.0);
        gemm_dispatch(
            &scratch.w,
            rn,
            cn,
            &scratch.xs,
            ka,
            &mut scratch.out,
            dispatch,
            &mut scratch.wp,
        );
        for r in 0..rn {
            let yi = &y[(r0 + r) * d..(r0 + r + 1) * d];
            let row = &scratch.out[r * ka..(r + 1) * ka];
            let rs = row[d];
            let out = &mut seg[r * d..(r + 1) * d];
            for k in 0..d {
                out[k] += rs * (yi[k] - cref[k]) - row[k];
            }
        }
    } else {
        csb.for_each_nz(t, |r, c, p| {
            let yi = &y[(r0 + r) * d..(r0 + r + 1) * d];
            let yj = &y[(c0 + c) * d..(c0 + c + 1) * d];
            let mut d2 = 0.0f32;
            for k in 0..d {
                let t = yi[k] - yj[k];
                d2 += t * t;
            }
            let w = p / (1.0 + d2);
            let out = &mut seg[r * d..(r + 1) * d];
            for k in 0..d {
                out[k] += w * (yi[k] - yj[k]);
            }
        });
    }
}

/// Materialize the masked Gaussian weight block of dense block `t` into
/// `w` (row-major `rows x cols`): `w_rc = exp(−‖t_r − s_c‖²·inv_h2)` where
/// the stored profile has an entry, 0 elsewhere.  Returns (rows, cols).
///
/// Must only be called for dense-stored blocks (the caller dispatches).
fn gauss_weights_dense(
    csb: &HierCsb,
    t: usize,
    tcoords: &[f32],
    scoords: &[f32],
    d: usize,
    inv_h2: f32,
    w: &mut Vec<f32>,
) -> (usize, usize) {
    let b = &csb.blocks[t];
    let r0 = b.rows.lo as usize;
    let c0 = b.cols.lo as usize;
    let rn = b.rows.len();
    let cn = b.cols.len();
    let dvals = csb.dense_slice(t).expect("gauss_weights_dense on sparse block");
    w.clear();
    w.resize(rn * cn, 0.0);
    for r in 0..rn {
        let ti = &tcoords[(r0 + r) * d..(r0 + r + 1) * d];
        let wrow = &mut w[r * cn..(r + 1) * cn];
        let prow = &dvals[r * cn..(r + 1) * cn];
        for (c, (wv, &p)) in wrow.iter_mut().zip(prow).enumerate() {
            if p != 0.0 {
                let sj = &scoords[(c0 + c) * d..(c0 + c + 1) * d];
                let mut d2 = 0.0f32;
                for k in 0..d {
                    let t = ti[k] - sj[k];
                    d2 += t * t;
                }
                *wv = (-d2 * inv_h2).exp();
            }
        }
    }
    (rn, cn)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::knn::exact::knn_graph;
    use crate::order::Pipeline;
    use crate::sparse::csr::Csr;
    use crate::util::rng::Rng;

    /// Engine + the reordered CSR (values = P) + tree-ordered coords.
    fn setup(n: usize, d: usize) -> (Csr, Engine, Vec<f32>) {
        let ds = SynthSpec::blobs(n, d, 4, 17).generate();
        let g = knn_graph(&ds, 6, 2);
        let a = Csr::from_knn(&g, n).symmetrized();
        let r = Pipeline::dual_tree(d).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        let csb = HierCsb::build(&r.reordered, tree, tree, 32);
        let reordered_ds = ds.permuted(&r.perm);
        let coords = reordered_ds.raw().to_vec();
        (r.reordered, Engine::new(csb, 4), coords)
    }

    /// Dense reference for the attractive force over a CSR profile.
    fn tsne_ref(a: &Csr, y: &[f32], d: usize) -> Vec<f32> {
        let n = a.rows;
        let mut f = vec![0.0f32; n * d];
        for i in 0..n {
            let (cols, vals) = a.row(i);
            for (&j, &p) in cols.iter().zip(vals) {
                let j = j as usize;
                let mut d2 = 0.0f32;
                for k in 0..d {
                    let t = y[i * d + k] - y[j * d + k];
                    d2 += t * t;
                }
                let w = p / (1.0 + d2);
                for k in 0..d {
                    f[i * d + k] += w * (y[i * d + k] - y[j * d + k]);
                }
            }
        }
        f
    }

    #[test]
    fn tsne_attr_matches_reference() {
        let (a, eng, _) = setup(300, 2);
        let mut rng = Rng::new(3);
        let y: Vec<f32> = (0..300 * 2).map(|_| rng.normal() as f32).collect();
        let want = tsne_ref(&a, &y, 2);
        let mut got = vec![0.0f32; 300 * 2];
        eng.tsne_attr(&y, 2, &mut got);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
        }
    }

    #[test]
    fn gauss_apply_matches_direct() {
        let (a, eng, coords) = setup(250, 3);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..250).map(|_| rng.f32()).collect();
        let inv_h2 = 0.7f32;
        // direct over the CSR profile
        let mut want = vec![0.0f32; 250];
        for i in 0..250 {
            let (cols, _) = a.row(i);
            for &j in cols {
                let j = j as usize;
                let mut d2 = 0.0f32;
                for k in 0..3 {
                    let t = coords[i * 3 + k] - coords[j * 3 + k];
                    d2 += t * t;
                }
                want[i] += (-d2 * inv_h2).exp() * x[j];
            }
        }
        let mut got = vec![0.0f32; 250];
        eng.gauss_apply(&coords, &coords, 3, inv_h2, &x, &mut got);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()));
        }
    }

    #[test]
    fn meanshift_step_matches_direct() {
        let (a, eng, coords) = setup(200, 3);
        let inv_h2 = 0.5f32;
        let (num, den) = eng.meanshift_step(&coords, &coords, 3, inv_h2);
        for i in [0usize, 57, 199] {
            let (cols, _) = a.row(i);
            let mut wn = [0.0f32; 3];
            let mut wd = 0.0f32;
            for &j in cols {
                let j = j as usize;
                let mut d2 = 0.0f32;
                for k in 0..3 {
                    let t = coords[i * 3 + k] - coords[j * 3 + k];
                    d2 += t * t;
                }
                let w = (-d2 * inv_h2).exp();
                for k in 0..3 {
                    wn[k] += w * coords[j * 3 + k];
                }
                wd += w;
            }
            assert!((den[i] - wd).abs() < 1e-4 * (1.0 + wd.abs()));
            for k in 0..3 {
                assert!((num[i * 3 + k] - wn[k]).abs() < 1e-3 * (1.0 + wn[k].abs()));
            }
        }
    }

    /// Engine with a low dense threshold so the batched dense-block path
    /// is actually exercised (clustered blobs → dense diagonal blocks).
    fn setup_dense(n: usize, d: usize) -> (Csr, Engine, Vec<f32>) {
        let ds = SynthSpec::blobs(n, d, 4, 17).generate();
        let g = knn_graph(&ds, 6, 2);
        let a = Csr::from_knn(&g, n).symmetrized();
        let r = Pipeline::dual_tree(d).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        let csb = HierCsb::build_with(&r.reordered, tree, tree, 32, 0.25);
        assert!(csb.dense_fraction() > 0.0, "test needs dense blocks: {}", csb.describe());
        let coords = ds.permuted(&r.perm).raw().to_vec();
        (r.reordered, Engine::new(csb, 4), coords)
    }

    #[test]
    fn gauss_apply_multi_matches_per_query() {
        let (_, eng, coords) = setup_dense(300, 3);
        let n = 300;
        let mut rng = Rng::new(6);
        let k = 5;
        let x: Vec<f32> = (0..n * k).map(|_| rng.f32() - 0.5).collect();
        let inv_h2 = 0.6f32;
        let mut got = vec![0.0f32; n * k];
        eng.gauss_apply_multi(&coords, &coords, 3, inv_h2, &x, k, &mut got);
        for j in 0..k {
            let xj: Vec<f32> = (0..n).map(|i| x[i * k + j]).collect();
            let mut want = vec![0.0f32; n];
            eng.gauss_apply(&coords, &coords, 3, inv_h2, &xj, &mut want);
            for i in 0..n {
                let g = got[i * k + j];
                let w = want[i];
                assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()), "q{j} row{i}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn batched_dense_path_matches_sparse_path() {
        // The same profile stored all-dense vs all-sparse must produce the
        // same kernels: exercises micro-GEMM vs fused scalar consistency.
        let ds = SynthSpec::blobs(250, 2, 3, 9).generate();
        let g = knn_graph(&ds, 6, 2);
        let a = Csr::from_knn(&g, 250).symmetrized();
        let r = Pipeline::dual_tree(2).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        let dense_eng = Engine::new(HierCsb::build_with(&r.reordered, tree, tree, 32, 0.0), 2);
        let sparse_eng = Engine::new(HierCsb::build_with(&r.reordered, tree, tree, 32, 1.1), 2);
        let coords = ds.permuted(&r.perm).raw().to_vec();
        let mut rng = Rng::new(11);
        let y: Vec<f32> = (0..250 * 2).map(|_| rng.normal() as f32).collect();
        let mut f1 = vec![0.0f32; 500];
        let mut f2 = vec![0.0f32; 500];
        dense_eng.tsne_attr(&y, 2, &mut f1);
        sparse_eng.tsne_attr(&y, 2, &mut f2);
        for (a, b) in f1.iter().zip(&f2) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
        let (n1, d1) = dense_eng.meanshift_step(&coords, &coords, 2, 0.5);
        let (n2, d2) = sparse_eng.meanshift_step(&coords, &coords, 2, 0.5);
        for (a, b) in n1.iter().zip(&n2).chain(d1.iter().zip(&d2)) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn thread_count_invariance() {
        let (_, eng1, coords) = setup(300, 2);
        let eng4 = Engine::new(eng1.csb.clone(), 8);
        let mut rng = Rng::new(5);
        let y: Vec<f32> = (0..300 * 2).map(|_| rng.normal() as f32).collect();
        let _ = coords;
        let mut f1 = vec![0.0f32; 600];
        let mut f4 = vec![0.0f32; 600];
        eng1.tsne_attr(&y, 2, &mut f1);
        eng4.tsne_attr(&y, 2, &mut f4);
        assert_eq!(f1, f4);
    }

    #[test]
    fn engine_spmm_matches_multilevel_reference() {
        let (a, eng, _) = setup_dense(300, 3);
        // a scalar-pinned engine must reproduce spmm_ml_seq bit-for-bit at
        // any thread count; the auto engine must agree within tolerance.
        let scalar = Engine::with_kernel(eng.csb.clone(), 8, KernelKind::Scalar);
        let mut rng = Rng::new(15);
        for k in [1usize, 4] {
            let x: Vec<f32> = (0..a.cols * k).map(|_| rng.f32() - 0.5).collect();
            let mut y_ref = vec![0.0f32; a.rows * k];
            crate::spmv::multilevel::spmm_ml_seq(&scalar.csb, &x, &mut y_ref, k);
            let mut y = vec![0.0f32; a.rows * k];
            scalar.spmm(&x, &mut y, k);
            assert_eq!(y, y_ref, "scalar engine spmm k={k}");
            eng.spmm(&x, &mut y, k);
            for (g, w) in y.iter().zip(&y_ref) {
                assert!((g - w).abs() < 1e-5 * (1.0 + w.abs()), "auto engine k={k}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn meanshift_step_into_reuses_buffers() {
        let (_, eng, coords) = setup_dense(250, 3);
        let (num1, den1) = eng.meanshift_step(&coords, &coords, 3, 0.5);
        let mut num = Vec::new();
        let mut den = Vec::new();
        eng.meanshift_step_into(&coords, &coords, 3, 0.5, &mut num, &mut den);
        assert_eq!(num, num1);
        assert_eq!(den, den1);
        // second call into the same (now-sized) buffers: same result
        eng.meanshift_step_into(&coords, &coords, 3, 0.5, &mut num, &mut den);
        assert_eq!(num, num1);
        assert_eq!(den, den1);
    }
}
