//! Lexicographic grid ordering of the first d principal components —
//! the paper's "2D lex" / "3D lex" comparison points (§4.3).
//!
//! Coordinates are quantized to `bins` cells per axis; points sort by the
//! tuple of cell indices (axis 0 major), breaking ties inside a cell by the
//! continuous first coordinate.  Plain float lexicographic sorting would
//! degenerate to a 1-D sort (ties on real-valued leading coordinates are
//! measure-zero); the grid is what makes the trailing axes matter — the
//! same convention the paper's profile figures show.

use crate::data::dataset::Dataset;
use crate::tree::morton::quantize;

/// Lexicographic ordering permutation over the `d = embedded.d()` axes.
pub fn order(embedded: &Dataset, bins: u32) -> Vec<usize> {
    let d = embedded.d();
    let n = embedded.n();
    let mut lo = vec![f32::INFINITY; d];
    let mut hi = vec![f32::NEG_INFINITY; d];
    for i in 0..n {
        for a in 0..d {
            lo[a] = lo[a].min(embedded.row(i)[a]);
            hi[a] = hi[a].max(embedded.row(i)[a]);
        }
    }
    let bits = 32 - (bins.max(2) - 1).leading_zeros(); // ceil(log2 bins)
    let mut keyed: Vec<(Vec<u32>, f32, usize)> = (0..n)
        .map(|i| {
            let r = embedded.row(i);
            let cells: Vec<u32> = (0..d).map(|a| quantize(r[a], lo[a], hi[a], bits)).collect();
            (cells, r[0], i)
        })
        .collect();
    keyed.sort_by(|x, y| {
        x.0.cmp(&y.0)
            .then(x.1.partial_cmp(&y.1).unwrap_or(std::cmp::Ordering::Equal))
            .then(x.2.cmp(&y.2))
    });
    keyed.into_iter().map(|(_, _, i)| i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::order::is_permutation;

    #[test]
    fn is_perm() {
        let ds = crate::data::synth::SynthSpec::blobs(150, 3, 3, 1).generate();
        let p = order(&ds, 16);
        assert!(is_permutation(&p));
    }

    #[test]
    fn groups_by_leading_axis_cell() {
        // Points in two x-bands: all of band 0 precede band 1.
        let mut xs = Vec::new();
        for i in 0..10 {
            xs.extend_from_slice(&[0.0, i as f32]);
        }
        for i in 0..10 {
            xs.extend_from_slice(&[100.0, i as f32]);
        }
        let ds = Dataset::new(20, 2, xs);
        let p = order(&ds, 8);
        assert!(p[..10].iter().all(|&i| i < 10));
        assert!(p[10..].iter().all(|&i| i >= 10));
    }

    #[test]
    fn second_axis_matters_within_cell() {
        // Same x for everyone: order must follow y (axis 1) by cells.
        let mut xs = Vec::new();
        for i in [5.0f32, 1.0, 9.0, 3.0] {
            xs.extend_from_slice(&[0.0, i]);
        }
        let ds = Dataset::new(4, 2, xs);
        let p = order(&ds, 8);
        let ys: Vec<f32> = p.iter().map(|&i| ds.row(i)[1]).collect();
        let mut sorted = ys.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(ys, sorted);
    }
}
