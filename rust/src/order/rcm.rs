//! Reverse Cuthill–McKee ordering [George 1971] — the classic
//! bandwidth-envelope reducer the paper compares against ("rCM").
//!
//! BFS from a pseudo-peripheral vertex with neighbors visited in ascending
//! degree; the final ordering is the reverse of the visit order.
//! Disconnected components are processed in sequence (each from its own
//! pseudo-peripheral start).

use crate::sparse::csr::Csr;

/// Adjacency = symmetrized profile of `a` (pattern only).
fn adjacency(a: &Csr) -> Vec<Vec<u32>> {
    let n = a.rows;
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
    for i in 0..n {
        let (cols, _) = a.row(i);
        for &j in cols {
            if j as usize != i {
                adj[i].push(j);
                adj[j as usize].push(i as u32);
            }
        }
    }
    for l in adj.iter_mut() {
        l.sort_unstable();
        l.dedup();
    }
    adj
}

/// BFS returning (levels, last visited vertex, eccentricity).
fn bfs(adj: &[Vec<u32>], start: u32, mark: &mut [u32], stamp: u32) -> (Vec<u32>, u32, u32) {
    let mut order = vec![start];
    mark[start as usize] = stamp;
    let mut depth = vec![0u32];
    let mut head = 0usize;
    while head < order.len() {
        let u = order[head];
        let du = depth[head];
        head += 1;
        for &v in &adj[u as usize] {
            if mark[v as usize] != stamp {
                mark[v as usize] = stamp;
                order.push(v);
                depth.push(du + 1);
            }
        }
    }
    let ecc = *depth.last().unwrap();
    let last = *order.last().unwrap();
    (order, last, ecc)
}

/// Pseudo-peripheral vertex of the component containing `seed`:
/// iterate "BFS to the farthest vertex" until the eccentricity stops
/// growing (George–Liu heuristic).
fn pseudo_peripheral(adj: &[Vec<u32>], seed: u32, mark: &mut [u32], stamp: &mut u32) -> u32 {
    let mut u = seed;
    let mut best_ecc = 0;
    for _ in 0..8 {
        *stamp += 1;
        let (_, far, ecc) = bfs(adj, u, mark, *stamp);
        if ecc <= best_ecc {
            break;
        }
        best_ecc = ecc;
        u = far;
    }
    u
}

/// Compute the rCM permutation (new position k holds original index
/// `perm[k]`).
pub fn reverse_cuthill_mckee(a: &Csr) -> Vec<usize> {
    let n = a.rows;
    let adj = adjacency(a);
    let deg: Vec<usize> = adj.iter().map(|l| l.len()).collect();
    let mut visited = vec![false; n];
    let mut mark = vec![0u32; n];
    let mut stamp = 0u32;
    let mut order: Vec<usize> = Vec::with_capacity(n);

    // Components in ascending-minimum-degree order of their seed.
    let mut seeds: Vec<usize> = (0..n).collect();
    seeds.sort_unstable_by_key(|&i| deg[i]);

    for seed in seeds {
        if visited[seed] {
            continue;
        }
        let start = pseudo_peripheral(&adj, seed as u32, &mut mark, &mut stamp);
        // Cuthill–McKee BFS with degree-sorted neighbor expansion.
        let mut queue = std::collections::VecDeque::new();
        visited[start as usize] = true;
        queue.push_back(start);
        while let Some(u) = queue.pop_front() {
            order.push(u as usize);
            let mut nbrs: Vec<u32> = adj[u as usize]
                .iter()
                .copied()
                .filter(|&v| !visited[v as usize])
                .collect();
            nbrs.sort_unstable_by_key(|&v| deg[v as usize]);
            for v in nbrs {
                if !visited[v as usize] {
                    visited[v as usize] = true;
                    queue.push_back(v);
                }
            }
        }
    }
    order.reverse();
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::order::is_permutation;
    use crate::sparse::gen;

    #[test]
    fn path_graph_gets_bandwidth_one() {
        // 0-1-2-...-9 path: rCM must recover a banded ordering.
        let n = 10;
        let mut r = Vec::new();
        let mut c = Vec::new();
        for i in 0..n - 1 {
            r.push(i as u32);
            c.push(i as u32 + 1);
            r.push(i as u32 + 1);
            c.push(i as u32);
        }
        let v = vec![1.0f32; r.len()];
        let a = Csr::from_triplets(n, n, &r, &c, &v);
        let perm = reverse_cuthill_mckee(&a);
        assert!(is_permutation(&perm));
        let pos = crate::order::invert(&perm);
        let b = a.permuted(&pos, &pos);
        assert_eq!(b.bandwidth(), 1);
    }

    #[test]
    fn shuffled_band_recovers_small_bandwidth() {
        use crate::util::rng::Rng;
        let a = gen::banded(200, 6, 1);
        let mut rng = Rng::new(2);
        let p = rng.permutation(200);
        let shuffled = a.permuted(&p, &p);
        assert!(shuffled.bandwidth() > 50);
        let perm = reverse_cuthill_mckee(&shuffled);
        let pos = crate::order::invert(&perm);
        let back = shuffled.permuted(&pos, &pos);
        assert!(
            back.bandwidth() <= 16,
            "rCM bandwidth {} too large",
            back.bandwidth()
        );
    }

    #[test]
    fn handles_disconnected_components() {
        // two disjoint edges + isolated vertex
        let a = Csr::from_triplets(
            5,
            5,
            &[0, 1, 3, 4],
            &[1, 0, 4, 3],
            &[1.0, 1.0, 1.0, 1.0],
        );
        let perm = reverse_cuthill_mckee(&a);
        assert!(is_permutation(&perm));
        assert_eq!(perm.len(), 5);
    }

    #[test]
    fn empty_matrix_identity_like() {
        let a = Csr::from_triplets(4, 4, &[], &[], &[]);
        let perm = reverse_cuthill_mckee(&a);
        assert!(is_permutation(&perm));
    }
}
