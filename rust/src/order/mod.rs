//! Matrix orderings (§4.3): scattered, reverse Cuthill–McKee, 1-D PCA sort,
//! 2-D/3-D lexicographic, Morton, and the paper's hierarchical dual-tree
//! ordering — all behind one [`Pipeline`] API.
//!
//! Conventions: a permutation `perm` lists original indices in their new
//! order (`new position k holds original perm[k]`); `pos = invert(perm)`
//! maps original index to new position.  Row and column orderings are the
//! same permutation here (the case-study matrices are self-interactions;
//! the API keeps (πt, πs) separate where it matters).

pub mod dualtree;
pub mod lex;
pub mod pca1d;
pub mod rcm;

use crate::csb::hier::HierCsb;
use crate::csb::kernel::KernelKind;
use crate::data::dataset::Dataset;
use crate::embed::pca;
use crate::hmat::{FullKernelConfig, FullKernelEngine};
use crate::interact::engine::Engine;
use crate::knn::KnnBackend;
use crate::sparse::csr::Csr;
use crate::tree::boxtree::BoxTree;
use crate::util::rng::Rng;

/// Invert a permutation: `invert(perm)[perm[k]] == k`.
pub fn invert(perm: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; perm.len()];
    for (k, &p) in perm.iter().enumerate() {
        inv[p] = k;
    }
    inv
}

/// Compose: apply `first`, then `second` (both as "new holds original").
pub fn compose(first: &[usize], second: &[usize]) -> Vec<usize> {
    second.iter().map(|&k| first[k]).collect()
}

/// Check that `perm` is a permutation of `0..n`.
pub fn is_permutation(perm: &[usize]) -> bool {
    let n = perm.len();
    let mut seen = vec![false; n];
    perm.iter().all(|&p| {
        if p < n && !seen[p] {
            seen[p] = true;
            true
        } else {
            false
        }
    })
}

/// The ordering schemes of Fig. 2 / Fig. 3 (plus Morton for ablations).
#[derive(Clone, Debug, PartialEq)]
pub enum OrderingKind {
    /// Random permutation — the paper's "scattered" base case.
    Scattered,
    /// Reverse Cuthill–McKee on the symmetrized profile.
    Rcm,
    /// Sort by the most dominant PCA coordinate ("1D").
    Pca1d,
    /// Lexicographic grid sort of the first `d` principal components
    /// ("2D lex" / "3D lex").
    Lex { d: usize },
    /// The paper's method: hierarchical dual-tree ordering in a `d`-D
    /// embedding ("3D DT").
    DualTree { d: usize },
    /// Morton curve in a `d`-D embedding (ablation).
    Morton { d: usize },
}

impl OrderingKind {
    /// Paper-style short label (matches Table 1 / Fig. 3 legends).
    pub fn label(&self) -> String {
        match self {
            OrderingKind::Scattered => "rand".into(),
            OrderingKind::Rcm => "rCM".into(),
            OrderingKind::Pca1d => "1D".into(),
            OrderingKind::Lex { d } => format!("{d}D lex"),
            OrderingKind::DualTree { d } => format!("{d}D DT"),
            OrderingKind::Morton { d } => format!("{d}D morton"),
        }
    }

    /// The six orderings of Table 1, in the paper's column order.
    pub fn table1_set() -> Vec<OrderingKind> {
        vec![
            OrderingKind::Scattered,
            OrderingKind::Rcm,
            OrderingKind::Pca1d,
            OrderingKind::Lex { d: 2 },
            OrderingKind::Lex { d: 3 },
            OrderingKind::DualTree { d: 3 },
        ]
    }
}

/// Everything the rest of the system needs about a computed ordering.
#[derive(Clone, Debug)]
pub struct OrderResult {
    pub kind: OrderingKind,
    /// New position k holds original index perm[k].
    pub perm: Vec<usize>,
    /// Original index i sits at new position pos[i].
    pub pos: Vec<usize>,
    /// The reordered interaction matrix A(π, π).
    pub reordered: Csr,
    /// Hierarchy (dual-tree orderings only) — in *reordered* coordinates.
    pub tree: Option<BoxTree>,
    /// Low-dimensional embedding in the *original* index order (kept for
    /// engines that need coordinates, e.g. mean shift re-clustering).
    pub embedded: Option<Dataset>,
}

impl OrderResult {
    /// Build the apply engine over this ordering: hierarchical CSB storage
    /// (arena fill + packed panels, parallel and bit-deterministic) plus
    /// the kernel-dispatched [`Engine`] with its precompiled schedule.
    /// `None` when the ordering carries no tree (non-hierarchical
    /// orderings cannot block adaptively).
    pub fn engine_with(
        &self,
        block_cap: usize,
        dense_threshold: f64,
        build_threads: usize,
        threads: usize,
        kernel: KernelKind,
    ) -> Option<Engine> {
        let tree = self.tree.as_ref()?;
        let csb = HierCsb::build_with_par(
            &self.reordered,
            tree,
            tree,
            block_cap,
            dense_threshold,
            build_threads,
        );
        Some(Engine::with_kernel(csb, threads, kernel))
    }

    /// Build the **full-kernel** Gaussian operator over this ordering:
    /// near field as dense `HierCsb` blocks, far field ACA-compressed
    /// (`hmat`).  `ds` supplies the coordinates the Gaussian lives in
    /// (original index order — typically the raw features, not the
    /// ordering embedding); `None` when the ordering carries no tree.
    pub fn full_kernel_engine(
        &self,
        ds: &Dataset,
        cfg: &FullKernelConfig,
        build_threads: usize,
        threads: usize,
        kernel: KernelKind,
    ) -> Option<FullKernelEngine> {
        let tree = self.tree.as_ref()?;
        assert_eq!(ds.n(), self.perm.len(), "dataset must match the ordering");
        let coords = ds.permuted(&self.perm);
        Some(FullKernelEngine::build(
            tree,
            coords.raw(),
            ds.d(),
            cfg,
            build_threads,
            threads,
            kernel,
        ))
    }
}

/// Ordering pipeline: embedding (when needed) → ordering → reordered matrix.
#[derive(Clone, Debug)]
pub struct Pipeline {
    pub kind: OrderingKind,
    /// Leaf capacity for tree builds.
    pub leaf_cap: usize,
    /// Subspace-iteration count for PCA.
    pub pca_iters: usize,
    /// Grid bins per axis for lexicographic orderings.
    pub lex_bins: u32,
    /// Seed (scattered ordering and PCA init).
    pub seed: u64,
    /// kNN backend used by [`Pipeline::run_points`] to build the
    /// interaction profile (exact or approximate).
    pub knn: KnnBackend,
    /// Worker threads of the *build side* (PCA Gram accumulation, tree
    /// construction): 0 = machine default (`NNI_THREADS`-respecting).
    /// The build is bit-identical across thread counts.
    pub build_threads: usize,
}

impl Pipeline {
    pub fn new(kind: OrderingKind) -> Self {
        Pipeline {
            kind,
            leaf_cap: 16,
            pca_iters: 10,
            lex_bins: 32,
            seed: 0xC0FFEE,
            knn: KnnBackend::Exact,
            build_threads: 0,
        }
    }

    /// Shorthand for the paper's method with a `d`-dimensional embedding.
    pub fn dual_tree(d: usize) -> Self {
        Pipeline::new(OrderingKind::DualTree { d })
    }

    pub fn with_leaf_cap(mut self, cap: usize) -> Self {
        self.leaf_cap = cap;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Select the kNN backend used by [`Pipeline::run_points`].
    pub fn with_knn(mut self, backend: KnnBackend) -> Self {
        self.knn = backend;
        self
    }

    /// Set the build-side worker count (0 = machine default).  Results are
    /// bit-identical across thread counts.
    pub fn with_build_threads(mut self, threads: usize) -> Self {
        self.build_threads = threads;
        self
    }

    /// Embedding dimension this ordering needs (0 = none).
    fn embed_dim(&self) -> usize {
        match self.kind {
            OrderingKind::Scattered | OrderingKind::Rcm => 0,
            OrderingKind::Pca1d => 1,
            OrderingKind::Lex { d }
            | OrderingKind::DualTree { d }
            | OrderingKind::Morton { d } => d,
        }
    }

    /// Run the full pipeline from raw points: build the symmetrized kNN
    /// interaction profile with the configured [`KnnBackend`], then order.
    ///
    /// `threads`: worker count for the kNN build (0 → machine default);
    /// also used for the build side (PCA, tree) unless
    /// [`Pipeline::with_build_threads`] set an explicit count.
    pub fn run_points(&self, ds: &Dataset, k: usize, threads: usize) -> OrderResult {
        let g = self.knn.build(ds, k, threads);
        let a = Csr::from_knn(&g, ds.n()).symmetrized();
        if self.build_threads == 0 && threads != 0 {
            self.clone().with_build_threads(threads).run(ds, &a)
        } else {
            self.run(ds, &a)
        }
    }

    /// Run the pipeline on dataset `ds` with interaction profile `a`.
    ///
    /// When the data is already low-dimensional (ds.d() <= embed dim), the
    /// embedding step is skipped, as in the paper (§2.4).
    pub fn run(&self, ds: &Dataset, a: &Csr) -> OrderResult {
        assert_eq!(ds.n(), a.rows);
        assert_eq!(a.rows, a.cols, "pipeline expects a self-interaction matrix");
        let ed = self.embed_dim();
        let embedded: Option<Dataset> = if ed > 0 {
            if ds.d() <= ed {
                Some(ds.clone())
            } else {
                let p = pca::pca_par(ds, ed, self.pca_iters, self.seed, self.build_threads);
                Some(p.project(ds, ed))
            }
        } else {
            None
        };

        let (perm, tree) = match &self.kind {
            OrderingKind::Scattered => {
                let mut rng = Rng::new(self.seed);
                (rng.permutation(ds.n()), None)
            }
            OrderingKind::Rcm => (rcm::reverse_cuthill_mckee(a), None),
            OrderingKind::Pca1d => (pca1d::order(embedded.as_ref().unwrap()), None),
            OrderingKind::Lex { .. } => (
                lex::order(embedded.as_ref().unwrap(), self.lex_bins),
                None,
            ),
            OrderingKind::Morton { .. } => (
                crate::tree::morton::morton_order(embedded.as_ref().unwrap(), 16),
                None,
            ),
            OrderingKind::DualTree { .. } => {
                let (perm, tree) = dualtree::order_par(
                    embedded.as_ref().unwrap(),
                    self.leaf_cap,
                    self.build_threads,
                );
                (perm, Some(tree))
            }
        };
        debug_assert!(is_permutation(&perm));
        let pos = invert(&perm);
        let reordered = a.permuted(&pos, &pos);
        OrderResult {
            kind: self.kind.clone(),
            perm,
            pos,
            reordered,
            tree,
            embedded,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::knn::exact::knn_graph;

    fn setup(n: usize) -> (Dataset, Csr) {
        let ds = SynthSpec::blobs(n, 3, 4, 5).generate();
        let g = knn_graph(&ds, 6, 2);
        let a = Csr::from_knn(&g, n).symmetrized();
        (ds, a)
    }

    #[test]
    fn invert_compose_identity() {
        let mut rng = Rng::new(1);
        let p = rng.permutation(100);
        let inv = invert(&p);
        let id = compose(&p, &inv);
        assert!(id.iter().enumerate().all(|(k, &v)| k == v));
    }

    #[test]
    fn all_kinds_produce_permutations() {
        let (ds, a) = setup(200);
        for kind in [
            OrderingKind::Scattered,
            OrderingKind::Rcm,
            OrderingKind::Pca1d,
            OrderingKind::Lex { d: 2 },
            OrderingKind::Lex { d: 3 },
            OrderingKind::DualTree { d: 3 },
            OrderingKind::Morton { d: 2 },
        ] {
            let r = Pipeline::new(kind.clone()).with_leaf_cap(16).run(&ds, &a);
            assert!(is_permutation(&r.perm), "{kind:?}");
            assert_eq!(r.reordered.nnz(), a.nnz(), "{kind:?}");
        }
    }

    #[test]
    fn reorder_preserves_matvec() {
        let (ds, a) = setup(150);
        let r = Pipeline::dual_tree(3).with_leaf_cap(16).run(&ds, &a);
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..150).map(|_| rng.f32()).collect();
        // x in reordered coordinates: x'[k] = x[perm[k]]
        let xp: Vec<f32> = r.perm.iter().map(|&p| x[p]).collect();
        let y = a.matvec_ref(&x);
        let yp = r.reordered.matvec_ref(&xp);
        for k in 0..150 {
            assert!((yp[k] - y[r.perm[k]]).abs() < 1e-4);
        }
    }

    #[test]
    fn dualtree_carries_tree() {
        let (ds, a) = setup(300);
        let r = Pipeline::dual_tree(3).with_leaf_cap(32).run(&ds, &a);
        let t = r.tree.unwrap();
        assert_eq!(t.n(), 300);
        // The tree's own permutation is relative to the embedded data;
        // combined with the pipeline it must describe the same reorder.
        assert_eq!(t.perm, r.perm);
    }

    #[test]
    fn low_dim_data_skips_embedding() {
        // 2-D data with a 3-D dual tree: embedding step must pass through.
        let ds = SynthSpec::blobs(100, 2, 3, 8).generate();
        let g = knn_graph(&ds, 4, 1);
        let a = Csr::from_knn(&g, 100).symmetrized();
        let r = Pipeline::dual_tree(3).with_leaf_cap(16).run(&ds, &a);
        assert_eq!(r.embedded.as_ref().unwrap().d(), 2);
    }

    #[test]
    fn run_points_matches_manual_exact_build() {
        let (ds, a) = setup(200);
        let manual = Pipeline::dual_tree(3).run(&ds, &a);
        let auto = Pipeline::dual_tree(3).run_points(&ds, 6, 2);
        assert_eq!(manual.perm, auto.perm);
        assert_eq!(manual.reordered.nnz(), auto.reordered.nnz());
    }

    #[test]
    fn run_points_ann_backend_produces_permutation() {
        let ds = SynthSpec::blobs(300, 3, 4, 6).generate();
        let r = Pipeline::dual_tree(3)
            .with_knn(KnnBackend::ann_default())
            .run_points(&ds, 5, 2);
        assert!(is_permutation(&r.perm));
        assert!(r.tree.is_some());
    }

    #[test]
    fn engine_with_follows_tree_availability() {
        let (ds, a) = setup(300);
        let dt = Pipeline::dual_tree(3).run(&ds, &a);
        let eng = dt
            .engine_with(32, 0.6, 2, 2, KernelKind::Scalar)
            .expect("dual-tree ordering carries a tree");
        assert_eq!(eng.csb.rows, 300);
        assert_eq!(eng.kernel, KernelKind::Scalar);
        let sc = Pipeline::new(OrderingKind::Scattered).run(&ds, &a);
        assert!(sc.engine_with(32, 0.6, 2, 2, KernelKind::Auto).is_none());
    }

    #[test]
    fn full_kernel_engine_follows_tree_availability() {
        let (ds, a) = setup(300);
        let cfg = crate::hmat::FullKernelConfig::new(0.5).with_block_cap(64);
        let dt = Pipeline::dual_tree(3).run(&ds, &a);
        let eng = dt
            .full_kernel_engine(&ds, &cfg, 2, 2, KernelKind::Scalar)
            .expect("dual-tree ordering carries a tree");
        assert_eq!(eng.n(), 300);
        assert_eq!(eng.dim, ds.d());
        let sc = Pipeline::new(OrderingKind::Scattered).run(&ds, &a);
        assert!(sc.full_kernel_engine(&ds, &cfg, 2, 2, KernelKind::Scalar).is_none());
    }

    #[test]
    fn rcm_reduces_bandwidth_vs_scattered() {
        let (ds, a) = setup(400);
        let sc = Pipeline::new(OrderingKind::Scattered).run(&ds, &a);
        let rc = Pipeline::new(OrderingKind::Rcm).run(&ds, &a);
        assert!(
            rc.reordered.bandwidth() < sc.reordered.bandwidth(),
            "rCM {} !< scattered {}",
            rc.reordered.bandwidth(),
            sc.reordered.bandwidth()
        );
    }
}
