//! 1-D ordering: sort by the most dominant principal coordinate — the
//! baseline the paper relates to Fiedler/spectral envelope methods (§5).

use crate::data::dataset::Dataset;

/// Sort points ascending by their first embedding coordinate.
/// `embedded` must have d >= 1; ties break by index (stable).
pub fn order(embedded: &Dataset) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..embedded.n()).collect();
    idx.sort_by(|&a, &b| {
        embedded.row(a)[0]
            .partial_cmp(&embedded.row(b)[0])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::order::is_permutation;

    #[test]
    fn sorts_by_first_coordinate() {
        let ds = Dataset::new(4, 2, vec![3.0, 0.0, 1.0, 9.0, 2.0, -1.0, 0.0, 5.0]);
        let p = order(&ds);
        assert_eq!(p, vec![3, 1, 2, 0]);
        assert!(is_permutation(&p));
    }

    #[test]
    fn stable_on_ties() {
        let ds = Dataset::new(3, 1, vec![1.0, 1.0, 0.0]);
        assert_eq!(order(&ds), vec![2, 0, 1]);
    }
}
