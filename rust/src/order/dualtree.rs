//! The paper's ordering (§2.4): hierarchical partitioning of the embedded
//! data by an adaptive 2^d tree; the pre-order leaf walk is the permutation
//! ("3D dual tree" in the figures — "dual" because the same construction
//! orders the source tree (columns) and the target tree (rows); for the
//! self-interaction case studies the two trees coincide).

use crate::data::dataset::Dataset;
use crate::tree::boxtree::BoxTree;

/// Build the hierarchy and return (permutation, tree).
///
/// `leaf_cap` controls the finest cluster granularity; the tree's interior
/// levels provide the multi-level blocking consumed by `csb::hier`.
pub fn order(embedded: &Dataset, leaf_cap: usize) -> (Vec<usize>, BoxTree) {
    order_par(embedded, leaf_cap, 1)
}

/// [`order`] with an explicit build-side worker count (0 = machine
/// default).  Bit-identical to the sequential build for every `threads`.
pub fn order_par(embedded: &Dataset, leaf_cap: usize, threads: usize) -> (Vec<usize>, BoxTree) {
    let tree = BoxTree::build_par(embedded, leaf_cap, 32, threads);
    (tree.perm.clone(), tree)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::order::is_permutation;

    #[test]
    fn perm_matches_tree() {
        let ds = crate::data::synth::SynthSpec::blobs(200, 3, 4, 9).generate();
        let (p, t) = order(&ds, 16);
        assert!(is_permutation(&p));
        assert_eq!(p, t.perm);
    }

    #[test]
    fn clusters_contiguous_in_order() {
        // well-separated blobs: each label must occupy a contiguous run
        let ds = crate::data::synth::SynthSpec::blobs(300, 2, 3, 4).generate();
        let labels = ds.labels.clone().unwrap();
        let (p, _) = order(&ds, 8);
        let seq: Vec<u32> = p.iter().map(|&i| labels[i]).collect();
        // count label transitions; for contiguous clusters it's k-1 = 2
        let transitions = seq.windows(2).filter(|w| w[0] != w[1]).count();
        assert!(transitions <= 4, "labels fragmented: {transitions} transitions");
    }
}
