//! Hierarchical partitioning of embedded data (§2.4): adaptive 2^d trees
//! (binary/quad/octree for d = 1/2/3) and Morton codes.

pub mod boxtree;
pub mod morton;
pub mod update;
