//! Morton (Z-order) codes — an alternative space-filling-curve ordering
//! used by the ablation benches to separate "hierarchical blocking" from
//! "locality-preserving curve" effects.

/// Interleave the low 21 bits of up to 3 coordinates into a 63-bit code.
pub fn morton3(x: u32, y: u32, z: u32) -> u64 {
    part1by2(x as u64) | (part1by2(y as u64) << 1) | (part1by2(z as u64) << 2)
}

/// Interleave the low 31 bits of 2 coordinates.
pub fn morton2(x: u32, y: u32) -> u64 {
    part1by1(x as u64) | (part1by1(y as u64) << 1)
}

#[inline]
fn part1by1(mut v: u64) -> u64 {
    v &= 0x0000_0000_FFFF_FFFF;
    v = (v | (v << 16)) & 0x0000_FFFF_0000_FFFF;
    v = (v | (v << 8)) & 0x00FF_00FF_00FF_00FF;
    v = (v | (v << 4)) & 0x0F0F_0F0F_0F0F_0F0F;
    v = (v | (v << 2)) & 0x3333_3333_3333_3333;
    v = (v | (v << 1)) & 0x5555_5555_5555_5555;
    v
}

#[inline]
fn part1by2(mut v: u64) -> u64 {
    v &= 0x1F_FFFF;
    v = (v | (v << 32)) & 0x1F00_0000_00FF_FF;
    v = (v | (v << 16)) & 0x1F00_00FF_0000_FF;
    v = (v | (v << 8)) & 0x100F_00F0_0F00_F00F;
    v = (v | (v << 4)) & 0x10C3_0C30_C30C_30C3;
    v = (v | (v << 2)) & 0x1249_2492_4924_9249;
    v
}

/// Quantize a coordinate into `bits`-bit grid over `[lo, hi]`.
pub fn quantize(x: f32, lo: f32, hi: f32, bits: u32) -> u32 {
    let levels = (1u64 << bits) as f32;
    if hi <= lo {
        return 0;
    }
    let t = ((x - lo) / (hi - lo) * levels).floor();
    (t.max(0.0) as u32).min((1u32 << bits) - 1)
}

/// Morton ordering permutation of points in up to 3 dims (padded with 0).
pub fn morton_order(points: &crate::data::dataset::Dataset, bits: u32) -> Vec<usize> {
    let d = points.d().min(3);
    let n = points.n();
    let mut lo = vec![f32::INFINITY; d];
    let mut hi = vec![f32::NEG_INFINITY; d];
    for i in 0..n {
        for a in 0..d {
            lo[a] = lo[a].min(points.row(i)[a]);
            hi[a] = hi[a].max(points.row(i)[a]);
        }
    }
    let mut keyed: Vec<(u64, usize)> = (0..n)
        .map(|i| {
            let r = points.row(i);
            let q: Vec<u32> = (0..d).map(|a| quantize(r[a], lo[a], hi[a], bits)).collect();
            let code = match d {
                1 => q[0] as u64,
                2 => morton2(q[0], q[1]),
                _ => morton3(q[0], q[1], q[2]),
            };
            (code, i)
        })
        .collect();
    keyed.sort_unstable();
    keyed.into_iter().map(|(_, i)| i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn morton2_basic() {
        assert_eq!(morton2(0, 0), 0);
        assert_eq!(morton2(1, 0), 1);
        assert_eq!(morton2(0, 1), 2);
        assert_eq!(morton2(1, 1), 3);
        assert_eq!(morton2(2, 0), 4);
    }

    #[test]
    fn morton3_basic() {
        assert_eq!(morton3(0, 0, 0), 0);
        assert_eq!(morton3(1, 0, 0), 1);
        assert_eq!(morton3(0, 1, 0), 2);
        assert_eq!(morton3(0, 0, 1), 4);
        assert_eq!(morton3(1, 1, 1), 7);
    }

    #[test]
    fn quantize_bounds() {
        assert_eq!(quantize(0.0, 0.0, 1.0, 4), 0);
        assert_eq!(quantize(1.0, 0.0, 1.0, 4), 15);
        assert_eq!(quantize(0.5, 0.0, 1.0, 4), 8);
        assert_eq!(quantize(5.0, 0.0, 1.0, 4), 15); // clamp
    }

    #[test]
    fn morton_order_is_permutation() {
        let ds = crate::data::synth::SynthSpec::blobs(200, 3, 3, 3).generate();
        let p = morton_order(&ds, 10);
        let mut seen = vec![false; 200];
        for i in p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn morton_groups_nearby_points() {
        // Two tight far-apart blobs: ordering must not interleave them.
        let mut xs = Vec::new();
        for i in 0..50 {
            xs.extend_from_slice(&[0.0 + (i as f32) * 1e-4, 0.0]);
        }
        for i in 0..50 {
            xs.extend_from_slice(&[100.0 + (i as f32) * 1e-4, 100.0]);
        }
        let ds = crate::data::dataset::Dataset::new(100, 2, xs);
        let p = morton_order(&ds, 12);
        let first_half: std::collections::HashSet<usize> = p[..50].iter().copied().collect();
        let all_low = first_half.iter().all(|&i| i < 50);
        let all_high = first_half.iter().all(|&i| i >= 50);
        assert!(all_low || all_high, "blobs interleaved in morton order");
    }
}
