//! Adaptive 2^d tree over points in a low-dimensional embedding space.
//!
//! This is the paper's hierarchical-clustering component: with a 3-D
//! embedding it is an adaptive octree; d = 2 a quadtree; d = 1 a binary
//! interval tree.  Each node owns a contiguous span of the *reordered*
//! point sequence; the pre-order walk of the leaves IS the hierarchical
//! ordering permutation, and the internal levels supply the multi-level
//! blocking used by the CSB storage and the multi-level interaction
//! scheduler.

use crate::data::dataset::Dataset;
use crate::obs::{self, counters, Counter};
use crate::par::pool::{SendPtr, ThreadPool};

/// One tree node.
#[derive(Clone, Debug)]
pub struct Node {
    /// Depth (root = 0).
    pub level: u32,
    /// Contiguous span `[lo, hi)` of tree-ordered positions.
    pub lo: u32,
    pub hi: u32,
    /// Child node ids (empty for leaves). Up to 2^d.
    pub children: Vec<u32>,
    /// Parent id (root points to itself).
    pub parent: u32,
    /// Box center in the embedding space.
    pub center: Vec<f32>,
    /// Box half-width (same along every axis: boxes stay cubical).
    pub half: f32,
}

impl Node {
    pub fn is_leaf(&self) -> bool {
        self.children.is_empty()
    }
    pub fn len(&self) -> usize {
        (self.hi - self.lo) as usize
    }
    pub fn is_empty(&self) -> bool {
        self.hi == self.lo
    }
}

/// Adaptive 2^d tree.
#[derive(Clone, Debug)]
pub struct BoxTree {
    /// Embedding dimension.
    pub d: usize,
    /// Nodes in creation (pre-)order; node 0 is the root.
    pub nodes: Vec<Node>,
    /// Ordering permutation: tree position `k` holds original index
    /// `perm[k]`.
    pub perm: Vec<usize>,
    /// Inverse: original index `i` sits at tree position `pos[i]`.
    pub pos: Vec<usize>,
    /// Leaf node id for each tree position.
    pub leaf_at: Vec<u32>,
    /// Maximum leaf population used during construction.
    pub leaf_cap: usize,
}

impl BoxTree {
    /// Build over `ds` (points in the embedding space, d = ds.d()).
    ///
    /// * `leaf_cap`: split nodes with more points than this;
    /// * `max_depth`: hard depth cap (guards degenerate duplicates).
    pub fn build(ds: &Dataset, leaf_cap: usize, max_depth: u32) -> BoxTree {
        obs::span!("tree.build");
        let n = ds.n();
        let d = ds.d();
        assert!(d >= 1 && d <= 8, "embedding dimension out of range");
        assert!(leaf_cap >= 1);
        let mut tree = BoxTree {
            d,
            nodes: vec![root_node(ds)],
            perm: (0..n).collect(),
            pos: vec![0; n],
            leaf_at: vec![0; n],
            leaf_cap,
        };
        build_rec(
            ds,
            d,
            leaf_cap,
            max_depth,
            &mut tree.nodes,
            0,
            &mut tree.perm,
            &mut tree.leaf_at,
        );
        for (k, &p) in tree.perm.iter().enumerate() {
            tree.pos[p] = k;
        }
        tree.publish_counters();
        tree
    }

    /// Task-parallel build, **bit-identical** to [`BoxTree::build`]: the top
    /// of the tree is split serially (FIFO) until at least `threads`
    /// independent subtrees exist; each subtree then builds concurrently
    /// inside its pre-reserved `perm`/`leaf_at` span (spans are fixed by the
    /// serial phase, so no synchronization on the arrays).  A renumbering
    /// pass places every subtree's nodes at the ids the sequential DFS
    /// would have assigned (a subtree's descendants always occupy one
    /// contiguous id block), so node layout, `perm`, `pos`, and `leaf_at`
    /// come out identical regardless of thread count.
    ///
    /// `threads = 0` means the machine default (`NNI_THREADS`-respecting).
    pub fn build_par(ds: &Dataset, leaf_cap: usize, max_depth: u32, threads: usize) -> BoxTree {
        let threads = ThreadPool::new_or_default(threads).threads;
        if threads <= 1 {
            return Self::build(ds, leaf_cap, max_depth);
        }
        obs::span!("tree.build_par");
        let n = ds.n();
        let d = ds.d();
        assert!(d >= 1 && d <= 8, "embedding dimension out of range");
        assert!(leaf_cap >= 1);

        // Serial top: split until >= threads (x4 for balance) subtrees.
        let skel_span = obs::trace::SpanGuard::enter("tree.skeleton");
        let mut skel: Vec<Node> = vec![root_node(ds)];
        let mut perm: Vec<usize> = (0..n).collect();
        let needs = |nd: &Node| nd.len() > leaf_cap && nd.level < max_depth;
        let mut queue: std::collections::VecDeque<u32> = std::collections::VecDeque::new();
        if needs(&skel[0]) {
            queue.push_back(0);
        }
        let target = threads * 4;
        while queue.len() < target {
            let Some(v) = queue.pop_front() else { break };
            if split_node(ds, d, &mut skel, v, &mut perm) {
                let children = skel[v as usize].children.clone();
                for c in children {
                    if needs(&skel[c as usize]) {
                        queue.push_back(c);
                    }
                }
            }
            // degenerate split → the node stays a (skeleton) leaf
        }
        let frontier: Vec<u32> = queue.into_iter().collect();
        let mut fidx: Vec<Option<usize>> = vec![None; skel.len()];
        for (i, &v) in frontier.iter().enumerate() {
            fidx[v as usize] = Some(i);
        }
        drop(skel_span);

        // Count pass: build each frontier subtree into a local arena; its
        // perm/leaf_at writes stay inside the pre-reserved span.
        let subtree_span = obs::trace::SpanGuard::enter("tree.subtrees");
        let mut leaf_at = vec![0u32; n];
        let pool = ThreadPool::new(threads);
        let pp = SendPtr(perm.as_mut_ptr());
        let lp = SendPtr(leaf_at.as_mut_ptr());
        let locals: Vec<Vec<Node>> = {
            let slots: Vec<std::sync::Mutex<Vec<Node>>> =
                frontier.iter().map(|_| std::sync::Mutex::new(Vec::new())).collect();
            let ppr = &pp;
            let lpr = &lp;
            let skel_ref = &skel;
            pool.for_each_chunked(frontier.len(), 1, |fi| {
                let f = frontier[fi] as usize;
                // SAFETY: frontier spans are disjoint; this subtree build
                // touches perm/leaf_at only inside skel[f]'s span.
                let perm_all: &mut [usize] = unsafe { std::slice::from_raw_parts_mut(ppr.0, n) };
                let leaf_all: &mut [u32] = unsafe { std::slice::from_raw_parts_mut(lpr.0, n) };
                let mut lnodes = vec![Node {
                    children: Vec::new(),
                    parent: 0,
                    ..skel_ref[f].clone()
                }];
                build_rec(ds, d, leaf_cap, max_depth, &mut lnodes, 0, perm_all, leaf_all);
                *slots[fi].lock().unwrap() = lnodes;
            });
            slots.into_iter().map(|m| m.into_inner().unwrap()).collect()
        };
        drop(subtree_span);

        // Renumber: simulate the sequential DFS id assignment over the
        // skeleton; each frontier subtree's descendants form one block.
        let renumber_span = obs::trace::SpanGuard::enter("tree.renumber");
        let mut skel_global = vec![0u32; skel.len()];
        let mut base = vec![0u32; frontier.len()];
        let mut counter = 1u32; // root is id 0
        assign_ids(&skel, &fidx, &locals, 0, &mut counter, &mut skel_global, &mut base);
        let total = counter as usize;

        // Fill pass: skeleton nodes (serial — the skeleton is tiny) …
        let placeholder = Node {
            level: 0,
            lo: 0,
            hi: 0,
            children: Vec::new(),
            parent: 0,
            center: Vec::new(),
            half: 0.0,
        };
        let mut nodes: Vec<Node> = vec![placeholder; total];
        for (sid, nd) in skel.iter().enumerate() {
            let g = skel_global[sid] as usize;
            let mut out = nd.clone();
            out.parent = skel_global[nd.parent as usize];
            out.children = nd.children.iter().map(|&c| skel_global[c as usize]).collect();
            if let Some(fi) = fidx[sid] {
                // frontier node: its children are the first nodes of its
                // descendant block (local ids 1.. map to base + id - 1)
                out.children = locals[fi][0]
                    .children
                    .iter()
                    .map(|&c| base[fi] + c - 1)
                    .collect();
            } else if nd.children.is_empty() {
                for k in nd.lo..nd.hi {
                    leaf_at[k as usize] = g as u32;
                }
            }
            nodes[g] = out;
        }
        // … then subtree nodes + leaf_at remap, parallel over subtrees.
        let np = SendPtr(nodes.as_mut_ptr());
        let lp2 = SendPtr(leaf_at.as_mut_ptr());
        {
            let npr = &np;
            let lpr = &lp2;
            let skel_ref = &skel;
            pool.for_each_chunked(frontier.len(), 1, |fi| {
                let f = frontier[fi] as usize;
                let b = base[fi];
                let fg = skel_global[f];
                let lnodes = &locals[fi];
                // SAFETY: the id block [b, b + len - 1) and the span
                // [lo, hi) are owned exclusively by this subtree.
                let nodes_all: &mut [Node] =
                    unsafe { std::slice::from_raw_parts_mut(npr.0, total) };
                let leaf_all: &mut [u32] = unsafe { std::slice::from_raw_parts_mut(lpr.0, n) };
                for (li, ln) in lnodes.iter().enumerate().skip(1) {
                    let mut out = ln.clone();
                    out.parent = if ln.parent == 0 { fg } else { b + ln.parent - 1 };
                    out.children = ln.children.iter().map(|&c| b + c - 1).collect();
                    nodes_all[(b + li as u32 - 1) as usize] = out;
                }
                let (lo, hi) = (skel_ref[f].lo as usize, skel_ref[f].hi as usize);
                for k in lo..hi {
                    let v = leaf_all[k];
                    leaf_all[k] = if v == 0 { fg } else { b + v - 1 };
                }
            });
        }

        let mut pos = vec![0usize; n];
        for (k, &p) in perm.iter().enumerate() {
            pos[p] = k;
        }
        drop(renumber_span);
        let tree = BoxTree {
            d,
            nodes,
            perm,
            pos,
            leaf_at,
            leaf_cap,
        };
        tree.publish_counters();
        tree
    }

    /// Fold this build's shape into the global `obs` counter registry.
    fn publish_counters(&self) {
        counters::add(Counter::TreeBuilds, 1);
        counters::add(Counter::TreeNodes, self.nodes.len() as u64);
        let leaves = self
            .nodes
            .iter()
            .filter(|nd| nd.is_leaf() && !nd.is_empty())
            .count();
        counters::add(Counter::TreeLeaves, leaves as u64);
    }

    pub fn n(&self) -> usize {
        self.perm.len()
    }

    /// All leaf node ids in span (pre-)order.
    pub fn leaves(&self) -> Vec<u32> {
        let mut out: Vec<u32> = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, nd)| nd.is_leaf() && !nd.is_empty())
            .map(|(i, _)| i as u32)
            .collect();
        out.sort_by_key(|&i| self.nodes[i as usize].lo);
        out
    }

    /// Node ids at depth `level` **completing** shallower leaves: returns a
    /// partition of `[0, n)` using nodes of depth == level plus leaves of
    /// depth < level, in span order.  This is the per-level blocking the
    /// multi-level structures consume.
    pub fn level_cut(&self, level: u32) -> Vec<u32> {
        let mut out = Vec::new();
        self.cut_rec(0, level, &mut out);
        out.sort_by_key(|&i| self.nodes[i as usize].lo);
        out
    }

    fn cut_rec(&self, node: u32, level: u32, out: &mut Vec<u32>) {
        let nd = &self.nodes[node as usize];
        if nd.is_empty() {
            return;
        }
        if nd.level == level || nd.is_leaf() {
            out.push(node);
            return;
        }
        for &c in &nd.children {
            self.cut_rec(c, level, out);
        }
    }

    /// Tree height (max node level).
    pub fn height(&self) -> u32 {
        self.nodes.iter().map(|n| n.level).max().unwrap_or(0)
    }

    /// Size-based cut: the shallowest antichain of nodes with ≤ `cap`
    /// points each (descend only while a node exceeds `cap`), in span
    /// order.  This decouples *ordering* granularity (the tree recurses to
    /// small leaves for fine-grained locality) from *blocking* granularity
    /// (CSB blocks of ~cap points for the artifact tile / cache line
    /// economics).
    pub fn cut_by_size(&self, cap: usize) -> Vec<u32> {
        let mut out = Vec::new();
        self.cut_size_rec(0, cap.max(1), &mut out);
        out.sort_by_key(|&i| self.nodes[i as usize].lo);
        out
    }

    fn cut_size_rec(&self, node: u32, cap: usize, out: &mut Vec<u32>) {
        let nd = &self.nodes[node as usize];
        if nd.is_empty() {
            return;
        }
        if nd.len() <= cap || nd.is_leaf() {
            out.push(node);
            return;
        }
        for &c in &nd.children {
            self.cut_size_rec(c, cap, out);
        }
    }
}

/// Root box: cube containing all points.  Degenerate-input guards:
/// an empty dataset gets the origin box (the unguarded fold would leave
/// `lo/hi` at ±∞ → NaN center, infinite half-width), and the half-width
/// floor is *relative* to the coordinate magnitude — an absolute epsilon
/// (the old `1e-12`) is a no-op at f32 magnitudes like 1e6, so
/// all-duplicate data far from the origin stalled every split until
/// `max_depth`.
pub(crate) fn root_node(ds: &Dataset) -> Node {
    let n = ds.n();
    let d = ds.d();
    let mut lo = vec![f32::INFINITY; d];
    let mut hi = vec![f32::NEG_INFINITY; d];
    for i in 0..n {
        for (k, &x) in ds.row(i).iter().enumerate() {
            lo[k] = lo[k].min(x);
            hi[k] = hi[k].max(x);
        }
    }
    if n == 0 {
        lo.fill(0.0);
        hi.fill(0.0);
    }
    let mut center = vec![0.0f32; d];
    let mut half = 0.0f32;
    let mut max_abs = 0.0f32;
    for k in 0..d {
        center[k] = 0.5 * (lo[k] + hi[k]);
        half = half.max(0.5 * (hi[k] - lo[k]));
        max_abs = max_abs.max(lo[k].abs()).max(hi[k].abs());
    }
    let half = half
        .max(max_abs * f32::EPSILON * 4.0)
        .max(f32::MIN_POSITIVE);
    Node {
        level: 0,
        lo: 0,
        hi: n as u32,
        children: Vec::new(),
        parent: 0,
        center,
        half,
    }
}

/// One split step, shared by the sequential recursion, the serial skeleton
/// phase of [`BoxTree::build_par`], and the parallel subtree builds: bucket
/// `nodes[node]`'s span by orthant of its center, rewrite `perm` in bucket
/// order, and append the non-empty children to `nodes` (ids consecutive, in
/// orthant-code order — the sequential creation order).  Returns `false`
/// when the node is degenerate (all points in one orthant and the box is at
/// the coordinate resolution) and must become a leaf instead.
pub(crate) fn split_node(
    ds: &Dataset,
    d: usize,
    nodes: &mut Vec<Node>,
    node: u32,
    perm: &mut [usize],
) -> bool {
    let (nlo, nhi, level, half, center) = {
        let nd = &nodes[node as usize];
        (nd.lo as usize, nd.hi as usize, nd.level, nd.half, nd.center.clone())
    };
    let nchild = 1usize << d;

    // Bucket points by orthant of the box center.
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); nchild];
    for k in nlo..nhi {
        let i = perm[k];
        let row = ds.row(i);
        let mut code = 0usize;
        for a in 0..d {
            if row[a] >= center[a] {
                code |= 1 << a;
            }
        }
        buckets[code].push(i);
    }

    // Degenerate: everything in one orthant and the box can no longer
    // separate.  The threshold is relative to the center magnitude (f32
    // resolution at the coordinates), with the old absolute floor kept for
    // near-origin data.
    if buckets.iter().filter(|b| !b.is_empty()).count() == 1 {
        let scale = center.iter().fold(0.0f32, |m, &c| m.max(c.abs()));
        if half <= (scale * f32::EPSILON * 8.0).max(1e-9) {
            return false;
        }
    }

    // Rewrite the span in bucket order and create non-empty children.
    let mut cursor = nlo;
    let child_half = half * 0.5;
    let mut created: Vec<u32> = Vec::new();
    for (code, bucket) in buckets.iter().enumerate() {
        if bucket.is_empty() {
            continue;
        }
        let clo = cursor;
        for &i in bucket {
            perm[cursor] = i;
            cursor += 1;
        }
        let mut ccenter = center.clone();
        for a in 0..d {
            ccenter[a] += if code & (1 << a) != 0 {
                child_half
            } else {
                -child_half
            };
        }
        let id = nodes.len() as u32;
        nodes.push(Node {
            level: level + 1,
            lo: clo as u32,
            hi: cursor as u32,
            children: Vec::new(),
            parent: node,
            center: ccenter,
            half: child_half,
        });
        created.push(id);
    }
    nodes[node as usize].children = created;
    true
}

/// Depth-first build of `nodes[node]`'s subtree (the sequential reference
/// recursion; also runs per frontier subtree in [`BoxTree::build_par`],
/// against a *local* arena).  `perm`/`leaf_at` are global-position indexed;
/// `leaf_at` receives arena-local node ids.
#[allow(clippy::too_many_arguments)]
pub(crate) fn build_rec(
    ds: &Dataset,
    d: usize,
    leaf_cap: usize,
    max_depth: u32,
    nodes: &mut Vec<Node>,
    node: u32,
    perm: &mut [usize],
    leaf_at: &mut [u32],
) {
    let (nlo, nhi, level) = {
        let nd = &nodes[node as usize];
        (nd.lo as usize, nd.hi as usize, nd.level)
    };
    if nhi - nlo <= leaf_cap || level >= max_depth || !split_node(ds, d, nodes, node, perm) {
        for k in nlo..nhi {
            leaf_at[k] = node;
        }
        return;
    }
    let children = nodes[node as usize].children.clone();
    for c in children {
        build_rec(ds, d, leaf_cap, max_depth, nodes, c, perm, leaf_at);
    }
}

/// Simulate the sequential DFS id assignment over the serial-phase skeleton:
/// processing a node allocates its children consecutively, then descends
/// child by child; reaching a frontier node reserves one contiguous block
/// for its whole descendant set (`locals[fi].len() - 1`, the local arena
/// minus the frontier node itself).
fn assign_ids(
    skel: &[Node],
    fidx: &[Option<usize>],
    locals: &[Vec<Node>],
    v: usize,
    counter: &mut u32,
    skel_global: &mut [u32],
    base: &mut [u32],
) {
    if let Some(fi) = fidx[v] {
        base[fi] = *counter;
        *counter += (locals[fi].len() - 1) as u32;
        return;
    }
    for &c in &skel[v].children {
        skel_global[c as usize] = *counter;
        *counter += 1;
    }
    for &c in &skel[v].children {
        assign_ids(skel, fidx, locals, c as usize, counter, skel_global, base);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn tree_for(n: usize, d: usize, k: usize, cap: usize, seed: u64) -> (Dataset, BoxTree) {
        let ds = SynthSpec::blobs(n, d, k, seed).generate();
        let t = BoxTree::build(&ds, cap, 24);
        (ds, t)
    }

    #[test]
    fn perm_is_permutation() {
        let (_, t) = tree_for(500, 3, 4, 16, 1);
        let mut seen = vec![false; 500];
        for &p in &t.perm {
            assert!(!seen[p]);
            seen[p] = true;
        }
        // pos is the inverse
        for i in 0..500 {
            assert_eq!(t.perm[t.pos[i]], i);
        }
    }

    #[test]
    fn leaves_partition_span() {
        let (_, t) = tree_for(777, 2, 5, 20, 2);
        let leaves = t.leaves();
        let mut expect = 0u32;
        for &l in &leaves {
            let nd = &t.nodes[l as usize];
            assert_eq!(nd.lo, expect, "gap before leaf {l}");
            assert!(nd.len() <= 20 || nd.level == 24);
            expect = nd.hi;
        }
        assert_eq!(expect, 777);
    }

    #[test]
    fn level_cut_partitions() {
        let (_, t) = tree_for(600, 3, 3, 8, 3);
        for level in 0..=t.height() {
            let cut = t.level_cut(level);
            let mut expect = 0u32;
            for &c in &cut {
                let nd = &t.nodes[c as usize];
                assert_eq!(nd.lo, expect);
                expect = nd.hi;
            }
            assert_eq!(expect, 600, "level {level}");
        }
    }

    #[test]
    fn children_nested_in_parent_box() {
        let (_, t) = tree_for(400, 3, 4, 10, 4);
        for nd in &t.nodes {
            for &c in &nd.children {
                let ch = &t.nodes[c as usize];
                assert_eq!(ch.parent, t.nodes.iter().position(|x| std::ptr::eq(x, nd)).unwrap() as u32);
                for a in 0..t.d {
                    assert!(
                        (ch.center[a] - nd.center[a]).abs() <= nd.half * 0.5 + 1e-6,
                        "child box escapes parent"
                    );
                }
            }
        }
    }

    #[test]
    fn points_inside_leaf_boxes() {
        let (ds, t) = tree_for(300, 2, 4, 12, 5);
        for k in 0..ds.n() {
            let leaf = &t.nodes[t.leaf_at[k] as usize];
            assert!(k as u32 >= leaf.lo && (k as u32) < leaf.hi);
            let i = t.perm[k];
            for a in 0..t.d {
                // loose containment (boxes shrink by exact halving)
                assert!(
                    (ds.row(i)[a] - leaf.center[a]).abs() <= leaf.half * (1.0 + 1e-3) + 1e-5,
                    "point {i} outside its leaf box"
                );
            }
        }
    }

    #[test]
    fn duplicate_points_terminate() {
        // All identical points: max_depth guard must stop recursion.
        let ds = Dataset::new(64, 2, vec![0.5; 128]);
        let t = BoxTree::build(&ds, 4, 10);
        assert!(t.height() <= 10);
        let leaves = t.leaves();
        let total: usize = leaves.iter().map(|&l| t.nodes[l as usize].len()).sum();
        assert_eq!(total, 64);
    }

    #[test]
    fn empty_dataset_yields_finite_root() {
        // Regression: the unguarded min/max fold left lo/hi at ±∞ → NaN
        // center and infinite half-width on n = 0.
        let ds = Dataset::new(0, 3, Vec::new());
        for t in [BoxTree::build(&ds, 4, 10), BoxTree::build_par(&ds, 4, 10, 4)] {
            assert_eq!(t.nodes.len(), 1);
            assert!(t.nodes[0].center.iter().all(|c| c.is_finite()));
            assert!(t.nodes[0].half.is_finite() && t.nodes[0].half > 0.0);
            assert!(t.perm.is_empty() && t.pos.is_empty() && t.leaf_at.is_empty());
            assert!(t.leaves().is_empty());
        }
    }

    #[test]
    fn duplicates_far_from_origin_terminate_immediately() {
        // Regression: with an absolute epsilon, all-duplicate points at f32
        // magnitudes like 1e6 stalled (half >> 1e-9, splits produce a
        // single-child chain down to max_depth).  The relative threshold
        // must stop at the root.
        let ds = Dataset::new(64, 2, vec![1.0e6; 128]);
        let t = BoxTree::build(&ds, 4, 32);
        assert!(
            t.nodes.len() <= 2,
            "degenerate split chain: {} nodes",
            t.nodes.len()
        );
        assert!(t.height() <= 1);
        let total: usize = t.leaves().iter().map(|&l| t.nodes[l as usize].len()).sum();
        assert_eq!(total, 64);
        assert!(t.leaf_at.iter().all(|&l| (l as usize) < t.nodes.len()));
    }

    #[test]
    fn build_par_matches_sequential_build() {
        let shapes = [(900usize, 3usize, 8usize, 1u64), (500, 2, 16, 2), (64, 1, 4, 3)];
        for (n, d, cap, seed) in shapes {
            let ds = SynthSpec::blobs(n, d, 4, seed).generate();
            let seq = BoxTree::build(&ds, cap, 24);
            for threads in [1usize, 2, 8] {
                let par = BoxTree::build_par(&ds, cap, 24, threads);
                assert_eq!(seq.perm, par.perm, "perm n={n} threads={threads}");
                assert_eq!(seq.pos, par.pos);
                assert_eq!(seq.leaf_at, par.leaf_at, "leaf_at n={n} threads={threads}");
                assert_eq!(seq.nodes.len(), par.nodes.len());
                for (a, b) in seq.nodes.iter().zip(&par.nodes) {
                    assert_eq!(a.level, b.level);
                    assert_eq!(a.lo, b.lo);
                    assert_eq!(a.hi, b.hi);
                    assert_eq!(a.children, b.children);
                    assert_eq!(a.parent, b.parent);
                    assert_eq!(a.half.to_bits(), b.half.to_bits());
                    assert!(a
                        .center
                        .iter()
                        .zip(&b.center)
                        .all(|(p, q)| p.to_bits() == q.to_bits()));
                }
            }
        }
    }

    #[test]
    fn clustered_data_yields_shallow_big_leaves_far_apart() {
        // sanity on the adaptive property: cluster diameters much smaller
        // than separation → nodes per level stays near the cluster count.
        let (_, t) = tree_for(1000, 2, 4, 64, 7);
        let mid = t.level_cut(t.height() / 2);
        assert!(mid.len() <= 64, "too many mid-level nodes: {}", mid.len());
    }
}
