//! Adaptive 2^d tree over points in a low-dimensional embedding space.
//!
//! This is the paper's hierarchical-clustering component: with a 3-D
//! embedding it is an adaptive octree; d = 2 a quadtree; d = 1 a binary
//! interval tree.  Each node owns a contiguous span of the *reordered*
//! point sequence; the pre-order walk of the leaves IS the hierarchical
//! ordering permutation, and the internal levels supply the multi-level
//! blocking used by the CSB storage and the multi-level interaction
//! scheduler.

use crate::data::dataset::Dataset;

/// One tree node.
#[derive(Clone, Debug)]
pub struct Node {
    /// Depth (root = 0).
    pub level: u32,
    /// Contiguous span `[lo, hi)` of tree-ordered positions.
    pub lo: u32,
    pub hi: u32,
    /// Child node ids (empty for leaves). Up to 2^d.
    pub children: Vec<u32>,
    /// Parent id (root points to itself).
    pub parent: u32,
    /// Box center in the embedding space.
    pub center: Vec<f32>,
    /// Box half-width (same along every axis: boxes stay cubical).
    pub half: f32,
}

impl Node {
    pub fn is_leaf(&self) -> bool {
        self.children.is_empty()
    }
    pub fn len(&self) -> usize {
        (self.hi - self.lo) as usize
    }
    pub fn is_empty(&self) -> bool {
        self.hi == self.lo
    }
}

/// Adaptive 2^d tree.
#[derive(Clone, Debug)]
pub struct BoxTree {
    /// Embedding dimension.
    pub d: usize,
    /// Nodes in creation (pre-)order; node 0 is the root.
    pub nodes: Vec<Node>,
    /// Ordering permutation: tree position `k` holds original index
    /// `perm[k]`.
    pub perm: Vec<usize>,
    /// Inverse: original index `i` sits at tree position `pos[i]`.
    pub pos: Vec<usize>,
    /// Leaf node id for each tree position.
    pub leaf_at: Vec<u32>,
    /// Maximum leaf population used during construction.
    pub leaf_cap: usize,
}

impl BoxTree {
    /// Build over `ds` (points in the embedding space, d = ds.d()).
    ///
    /// * `leaf_cap`: split nodes with more points than this;
    /// * `max_depth`: hard depth cap (guards degenerate duplicates).
    pub fn build(ds: &Dataset, leaf_cap: usize, max_depth: u32) -> BoxTree {
        let n = ds.n();
        let d = ds.d();
        assert!(d >= 1 && d <= 8, "embedding dimension out of range");
        assert!(leaf_cap >= 1);

        // Root box: cube containing all points.
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        for i in 0..n {
            for (k, &x) in ds.row(i).iter().enumerate() {
                lo[k] = lo[k].min(x);
                hi[k] = hi[k].max(x);
            }
        }
        let mut center = vec![0.0f32; d];
        let mut half = 0.0f32;
        for k in 0..d {
            center[k] = 0.5 * (lo[k] + hi[k]);
            half = half.max(0.5 * (hi[k] - lo[k]));
        }
        half = half.max(1e-12);

        let mut tree = BoxTree {
            d,
            nodes: vec![Node {
                level: 0,
                lo: 0,
                hi: n as u32,
                children: Vec::new(),
                parent: 0,
                center,
                half,
            }],
            perm: (0..n).collect(),
            pos: vec![0; n],
            leaf_at: vec![0; n],
            leaf_cap,
        };
        tree.split_recursive(ds, 0, max_depth);
        for (k, &p) in tree.perm.iter().enumerate() {
            tree.pos[p] = k;
        }
        tree
    }

    fn split_recursive(&mut self, ds: &Dataset, node: u32, max_depth: u32) {
        let (nlo, nhi, level, half, center) = {
            let nd = &self.nodes[node as usize];
            (
                nd.lo as usize,
                nd.hi as usize,
                nd.level,
                nd.half,
                nd.center.clone(),
            )
        };
        let count = nhi - nlo;
        if count <= self.leaf_cap || level >= max_depth {
            for k in nlo..nhi {
                self.leaf_at[k] = node;
            }
            return;
        }
        let d = self.d;
        let nchild = 1usize << d;

        // Bucket points by orthant of the box center.
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); nchild];
        for k in nlo..nhi {
            let i = self.perm[k];
            let row = ds.row(i);
            let mut code = 0usize;
            for a in 0..d {
                if row[a] >= center[a] {
                    code |= 1 << a;
                }
            }
            buckets[code].push(i);
        }

        // Degenerate: everything in one orthant and the box can no longer
        // separate (duplicate-heavy data) — make this a leaf.
        if buckets.iter().filter(|b| !b.is_empty()).count() == 1 && half < 1e-9 {
            for k in nlo..nhi {
                self.leaf_at[k] = node;
            }
            return;
        }

        // Rewrite the span in bucket order and create non-empty children.
        let mut cursor = nlo;
        let child_half = half * 0.5;
        let mut created: Vec<u32> = Vec::new();
        for (code, bucket) in buckets.iter().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            let clo = cursor;
            for &i in bucket {
                self.perm[cursor] = i;
                cursor += 1;
            }
            let mut ccenter = center.clone();
            for a in 0..d {
                ccenter[a] += if code & (1 << a) != 0 {
                    child_half
                } else {
                    -child_half
                };
            }
            let id = self.nodes.len() as u32;
            self.nodes.push(Node {
                level: level + 1,
                lo: clo as u32,
                hi: cursor as u32,
                children: Vec::new(),
                parent: node,
                center: ccenter,
                half: child_half,
            });
            created.push(id);
        }
        self.nodes[node as usize].children = created.clone();
        for id in created {
            self.split_recursive(ds, id, max_depth);
        }
    }

    pub fn n(&self) -> usize {
        self.perm.len()
    }

    /// All leaf node ids in span (pre-)order.
    pub fn leaves(&self) -> Vec<u32> {
        let mut out: Vec<u32> = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, nd)| nd.is_leaf() && !nd.is_empty())
            .map(|(i, _)| i as u32)
            .collect();
        out.sort_by_key(|&i| self.nodes[i as usize].lo);
        out
    }

    /// Node ids at depth `level` **completing** shallower leaves: returns a
    /// partition of `[0, n)` using nodes of depth == level plus leaves of
    /// depth < level, in span order.  This is the per-level blocking the
    /// multi-level structures consume.
    pub fn level_cut(&self, level: u32) -> Vec<u32> {
        let mut out = Vec::new();
        self.cut_rec(0, level, &mut out);
        out.sort_by_key(|&i| self.nodes[i as usize].lo);
        out
    }

    fn cut_rec(&self, node: u32, level: u32, out: &mut Vec<u32>) {
        let nd = &self.nodes[node as usize];
        if nd.is_empty() {
            return;
        }
        if nd.level == level || nd.is_leaf() {
            out.push(node);
            return;
        }
        for &c in &nd.children {
            self.cut_rec(c, level, out);
        }
    }

    /// Tree height (max node level).
    pub fn height(&self) -> u32 {
        self.nodes.iter().map(|n| n.level).max().unwrap_or(0)
    }

    /// Size-based cut: the shallowest antichain of nodes with ≤ `cap`
    /// points each (descend only while a node exceeds `cap`), in span
    /// order.  This decouples *ordering* granularity (the tree recurses to
    /// small leaves for fine-grained locality) from *blocking* granularity
    /// (CSB blocks of ~cap points for the artifact tile / cache line
    /// economics).
    pub fn cut_by_size(&self, cap: usize) -> Vec<u32> {
        let mut out = Vec::new();
        self.cut_size_rec(0, cap.max(1), &mut out);
        out.sort_by_key(|&i| self.nodes[i as usize].lo);
        out
    }

    fn cut_size_rec(&self, node: u32, cap: usize, out: &mut Vec<u32>) {
        let nd = &self.nodes[node as usize];
        if nd.is_empty() {
            return;
        }
        if nd.len() <= cap || nd.is_leaf() {
            out.push(node);
            return;
        }
        for &c in &nd.children {
            self.cut_size_rec(c, cap, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn tree_for(n: usize, d: usize, k: usize, cap: usize, seed: u64) -> (Dataset, BoxTree) {
        let ds = SynthSpec::blobs(n, d, k, seed).generate();
        let t = BoxTree::build(&ds, cap, 24);
        (ds, t)
    }

    #[test]
    fn perm_is_permutation() {
        let (_, t) = tree_for(500, 3, 4, 16, 1);
        let mut seen = vec![false; 500];
        for &p in &t.perm {
            assert!(!seen[p]);
            seen[p] = true;
        }
        // pos is the inverse
        for i in 0..500 {
            assert_eq!(t.perm[t.pos[i]], i);
        }
    }

    #[test]
    fn leaves_partition_span() {
        let (_, t) = tree_for(777, 2, 5, 20, 2);
        let leaves = t.leaves();
        let mut expect = 0u32;
        for &l in &leaves {
            let nd = &t.nodes[l as usize];
            assert_eq!(nd.lo, expect, "gap before leaf {l}");
            assert!(nd.len() <= 20 || nd.level == 24);
            expect = nd.hi;
        }
        assert_eq!(expect, 777);
    }

    #[test]
    fn level_cut_partitions() {
        let (_, t) = tree_for(600, 3, 3, 8, 3);
        for level in 0..=t.height() {
            let cut = t.level_cut(level);
            let mut expect = 0u32;
            for &c in &cut {
                let nd = &t.nodes[c as usize];
                assert_eq!(nd.lo, expect);
                expect = nd.hi;
            }
            assert_eq!(expect, 600, "level {level}");
        }
    }

    #[test]
    fn children_nested_in_parent_box() {
        let (_, t) = tree_for(400, 3, 4, 10, 4);
        for nd in &t.nodes {
            for &c in &nd.children {
                let ch = &t.nodes[c as usize];
                assert_eq!(ch.parent, t.nodes.iter().position(|x| std::ptr::eq(x, nd)).unwrap() as u32);
                for a in 0..t.d {
                    assert!(
                        (ch.center[a] - nd.center[a]).abs() <= nd.half * 0.5 + 1e-6,
                        "child box escapes parent"
                    );
                }
            }
        }
    }

    #[test]
    fn points_inside_leaf_boxes() {
        let (ds, t) = tree_for(300, 2, 4, 12, 5);
        for k in 0..ds.n() {
            let leaf = &t.nodes[t.leaf_at[k] as usize];
            assert!(k as u32 >= leaf.lo && (k as u32) < leaf.hi);
            let i = t.perm[k];
            for a in 0..t.d {
                // loose containment (boxes shrink by exact halving)
                assert!(
                    (ds.row(i)[a] - leaf.center[a]).abs() <= leaf.half * (1.0 + 1e-3) + 1e-5,
                    "point {i} outside its leaf box"
                );
            }
        }
    }

    #[test]
    fn duplicate_points_terminate() {
        // All identical points: max_depth guard must stop recursion.
        let ds = Dataset::new(64, 2, vec![0.5; 128]);
        let t = BoxTree::build(&ds, 4, 10);
        assert!(t.height() <= 10);
        let leaves = t.leaves();
        let total: usize = leaves.iter().map(|&l| t.nodes[l as usize].len()).sum();
        assert_eq!(total, 64);
    }

    #[test]
    fn clustered_data_yields_shallow_big_leaves_far_apart() {
        // sanity on the adaptive property: cluster diameters much smaller
        // than separation → nodes per level stays near the cluster count.
        let (_, t) = tree_for(1000, 2, 4, 64, 7);
        let mid = t.level_cut(t.height() / 2);
        assert!(mid.len() <= 64, "too many mid-level nodes: {}", mid.len());
    }
}
