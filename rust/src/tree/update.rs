//! Incremental insert/delete for [`BoxTree`]: rebuild only the touched
//! subtrees, **bit-identical** to a from-scratch [`BoxTree::build`] over
//! the updated point set.
//!
//! Identity rests on two invariants of the sequential build:
//!
//! * **Ascending spans.** `build` starts from the identity permutation and
//!   `split_node` buckets stably, so every node's span lists points in
//!   ascending original-index order, and the final layout of a subtree is a
//!   deterministic function of (its box, its member set).  Deletions
//!   compact indices monotonically and insertions append past the
//!   survivors, so a preserved subtree's remapped span is exactly what the
//!   from-scratch build would produce.
//! * **Contiguous descendant blocks.** The sequential DFS appends all of a
//!   node's descendants while it recurses, so a preserved subtree's node
//!   ids shift as one block; the renumbering pass here is the same
//!   simulation [`BoxTree::build_par`] already uses for its frontier
//!   subtrees.
//!
//! Nodes whose member set is untouched are **clean** (copied verbatim with
//! index shifts); touched internal nodes whose children all survive with
//! the same orthant occupancy are **scaffold** (renumbered, recursed);
//! everything else is **dirty**, and the minimal antichain of dirty nodes
//! (the *frontier*) is rebuilt from scratch — in parallel, one subtree per
//! task, exactly like the parallel build.  A batch that changes the root
//! bounding box (or empties/initializes the tree) falls back to a full
//! rebuild, reported via `update.full_rebuilds`.

use super::boxtree::{build_rec, root_node, BoxTree, Node};
use crate::data::dataset::Dataset;
use crate::obs::{self, counters, Counter};
use crate::par::pool::{SendPtr, ThreadPool};

/// One batch of point updates against the tree's *external* (original)
/// index space: `deletes` are indices into the current dataset (duplicates
/// ignored), `inserts` is a row-major `n_ins x d` coordinate block.
#[derive(Clone, Debug, Default)]
pub struct UpdateBatch {
    pub deletes: Vec<usize>,
    pub inserts: Vec<f32>,
}

impl UpdateBatch {
    pub fn is_empty(&self) -> bool {
        self.deletes.is_empty() && self.inserts.is_empty()
    }
}

/// Result of an incremental tree update.  The new external index space is
/// *survivors in old order, then inserts in batch order*; the maps carry
/// `u32::MAX` for deleted (resp. freshly inserted) points.
pub struct TreeUpdate {
    /// Updated tree, bit-identical to `BoxTree::build(&ds, leaf_cap,
    /// max_depth)`.
    pub tree: BoxTree,
    /// Updated dataset in the new external order.
    pub ds: Dataset,
    /// Old external index → new external index (`u32::MAX` = deleted).
    pub old_to_new: Vec<u32>,
    /// New external index → old external index (`u32::MAX` = inserted).
    pub new_to_old: Vec<u32>,
    /// New node id → old node id (`u32::MAX` = node of a rebuilt subtree).
    /// Preserved nodes (clean and scaffold) and frontier roots map — a
    /// frontier root keeps its box even though its subtree was rebuilt.
    pub node_map: Vec<u32>,
    /// New node id → whole subtree preserved verbatim (member set,
    /// structure, and within-span order unchanged up to index remapping).
    pub clean: Vec<bool>,
    /// The batch moved the root bounding box (or emptied/initialized the
    /// tree) and the whole structure was rebuilt from scratch.
    pub full_rebuild: bool,
}

impl TreeUpdate {
    /// New tree position → old tree position (`u32::MAX` for inserted
    /// points).  This is the row/column map the CSB and profile reuse
    /// paths consume.
    pub fn pos_map(&self, old: &BoxTree) -> Vec<u32> {
        self.tree
            .perm
            .iter()
            .map(|&e| {
                let o = self.new_to_old[e];
                if o == u32::MAX {
                    u32::MAX
                } else {
                    old.pos[o as usize] as u32
                }
            })
            .collect()
    }
}

/// Apply `batch` to `(old, old_ds)`.  `max_depth` must equal the value the
/// tree was originally built with (it is not stored on [`BoxTree`]); the
/// clean-subtree equivalence argument needs the same split policy on both
/// sides.  `threads = 0` means the machine default.
pub fn update_tree(
    old: &BoxTree,
    old_ds: &Dataset,
    batch: &UpdateBatch,
    max_depth: u32,
    threads: usize,
) -> TreeUpdate {
    obs::span!("tree.update");
    let d = old.d;
    assert_eq!(old_ds.d(), d, "dataset dimension mismatch");
    assert_eq!(old_ds.n(), old.n(), "dataset size mismatch");
    assert_eq!(batch.inserts.len() % d.max(1), 0, "insert block not a multiple of d");
    let n_old = old.n();
    let n_ins = batch.inserts.len() / d;

    let mut dels = batch.deletes.clone();
    dels.sort_unstable();
    dels.dedup();
    if let Some(&last) = dels.last() {
        assert!(last < n_old, "delete index {last} out of range (n = {n_old})");
    }
    counters::add(Counter::UpdateBatches, 1);
    counters::add(Counter::UpdateDeletes, dels.len() as u64);
    counters::add(Counter::UpdateInserts, n_ins as u64);

    // New external order: survivors (old order) then inserts (batch order).
    let n_surv = n_old - dels.len();
    let n_new = n_surv + n_ins;
    let mut old_to_new = vec![u32::MAX; n_old];
    let mut new_to_old = vec![u32::MAX; n_new];
    let mut xs: Vec<f32> = Vec::with_capacity(n_new * d);
    {
        let mut di = 0usize;
        let mut cursor = 0u32;
        for i in 0..n_old {
            if di < dels.len() && dels[di] == i {
                di += 1;
                continue;
            }
            old_to_new[i] = cursor;
            new_to_old[cursor as usize] = i as u32;
            xs.extend_from_slice(old_ds.row(i));
            cursor += 1;
        }
    }
    xs.extend_from_slice(&batch.inserts);
    let ds = Dataset::new(n_new, d, xs);

    if dels.is_empty() && n_ins == 0 {
        // No-op batch: the old tree is the answer, every node clean.
        let nn = old.nodes.len();
        return TreeUpdate {
            tree: old.clone(),
            ds,
            old_to_new,
            new_to_old,
            node_map: (0..nn as u32).collect(),
            clean: vec![true; nn],
            full_rebuild: false,
        };
    }

    let full = |ds: Dataset, old_to_new: Vec<u32>, new_to_old: Vec<u32>| -> TreeUpdate {
        counters::add(Counter::UpdateFullRebuilds, 1);
        counters::add(Counter::UpdatePointsRebuilt, ds.n() as u64);
        let tree = BoxTree::build_par(&ds, old.leaf_cap, max_depth, threads);
        let nn = tree.nodes.len();
        TreeUpdate {
            node_map: vec![u32::MAX; nn],
            clean: vec![false; nn],
            tree,
            ds,
            old_to_new,
            new_to_old,
            full_rebuild: true,
        }
    };

    // The incremental path needs a stable root box: growing (insert outside
    // the hull) or shrinking (delete a hull point) the bounding cube moves
    // every box in the tree, so nothing is reusable.
    if n_old == 0 || n_new == 0 {
        return full(ds, old_to_new, new_to_old);
    }
    let new_root = root_node(&ds);
    let old_root = &old.nodes[0];
    let same_box = new_root.half.to_bits() == old_root.half.to_bits()
        && new_root
            .center
            .iter()
            .zip(&old_root.center)
            .all(|(a, b)| a.to_bits() == b.to_bits());
    if !same_box {
        return full(ds, old_to_new, new_to_old);
    }

    // ---- Delta pass: per-node delete/insert counts over the old tree ----
    let nn_old = old.nodes.len();
    let mut del_cnt = vec![0u32; nn_old];
    let mut ins_cnt = vec![0u32; nn_old];
    let mut touched = vec![false; nn_old];
    let mut new_orthant = vec![false; nn_old];
    for &e in &dels {
        let mut v = old.leaf_at[old.pos[e]] as usize;
        loop {
            del_cnt[v] += 1;
            touched[v] = true;
            if v == 0 {
                break;
            }
            v = old.nodes[v].parent as usize;
        }
    }
    for t in 0..n_ins {
        let row = &batch.inserts[t * d..t * d + d];
        let (term, missing) = route(old, d, row);
        if missing {
            new_orthant[term] = true;
        }
        let mut v = term;
        loop {
            ins_cnt[v] += 1;
            touched[v] = true;
            if v == 0 {
                break;
            }
            v = old.nodes[v].parent as usize;
        }
    }

    // ---- Classification: dirty set and its minimal antichain ----
    // A touched leaf is dirty.  A touched internal node is dirty when the
    // update changes a split decision its subtree depends on: the node
    // would collapse to a leaf (new population <= leaf_cap), an insert
    // lands in an orthant with no existing child, or a child empties out.
    // Everything else touched is scaffold: same children, same boxes, only
    // spans and ids shift.
    let leaf_cap = old.leaf_cap;
    let new_len = |v: usize| -> i64 {
        old.nodes[v].len() as i64 - del_cnt[v] as i64 + ins_cnt[v] as i64
    };
    let mut dirty = vec![false; nn_old];
    for v in 0..nn_old {
        if !touched[v] {
            continue;
        }
        let nd = &old.nodes[v];
        dirty[v] = nd.is_leaf()
            || new_len(v) <= leaf_cap as i64
            || new_orthant[v]
            || nd.children.iter().any(|&c| new_len(c as usize) == 0);
    }
    let mut frontier: Vec<u32> = Vec::new();
    let mut fidx = vec![u32::MAX; nn_old];
    for v in 0..nn_old {
        if !dirty[v] {
            continue;
        }
        let mut anc = v;
        let mut topmost = true;
        while anc != 0 {
            anc = old.nodes[anc].parent as usize;
            if dirty[anc] {
                topmost = false;
                break;
            }
        }
        if topmost {
            fidx[v] = frontier.len() as u32;
            frontier.push(v as u32);
        }
    }
    debug_assert!(!frontier.is_empty(), "non-empty batch must dirty some node");

    // Route each insert to the frontier subtree that will absorb it (the
    // first frontier node on its root→terminal path).  Batch order keeps
    // the appended new indices ascending inside each frontier list.
    let mut ins_of: Vec<Vec<u32>> = vec![Vec::new(); frontier.len()];
    for t in 0..n_ins {
        let row = &batch.inserts[t * d..t * d + d];
        let mut v = 0usize;
        let f = loop {
            if fidx[v] != u32::MAX {
                break fidx[v] as usize;
            }
            match route_child(old, d, v, row) {
                Some(c) => v = c,
                None => unreachable!("insert path ended before reaching a frontier node"),
            }
        };
        ins_of[f].push((n_surv + t) as u32);
    }

    // Contiguous-descendant-block DP: end[v] = one past the last id in v's
    // subtree (children ids are always greater than the parent's, so a
    // reverse scan sees every child before its parent).
    let mut end = vec![0u32; nn_old];
    for v in (0..nn_old).rev() {
        let nd = &old.nodes[v];
        end[v] = match nd.children.last() {
            None => v as u32 + 1,
            Some(&c) => end[c as usize],
        };
    }

    let mut cx = Patcher {
        old,
        ds: &ds,
        d,
        leaf_cap,
        old_to_new: &old_to_new,
        touched: &touched,
        fidx: &fidx,
        frontier: &frontier,
        ins_of: &ins_of,
        end: &end,
        new_lo: vec![0u32; nn_old],
        new_hi: vec![0u32; nn_old],
        new_perm: vec![0usize; n_new],
        new_leaf: vec![0u32; n_new],
        locals: Vec::new(),
        new_id: vec![0u32; nn_old],
        fbase: vec![0u32; frontier.len()],
        cbase: vec![0u32; nn_old],
        nodes: Vec::new(),
        node_map: Vec::new(),
        clean: Vec::new(),
    };

    // Span pass: new [lo, hi) for every preserved node, and the new
    // permutation content for clean subtrees and frontier spans (both in
    // the order the sequential build would produce — see module docs).
    let mut cursor = 0u32;
    cx.spans(0, &mut cursor);
    assert_eq!(cursor as usize, n_new, "span pass must cover the new point set");

    // Parallel frontier rebuilds, one subtree per task (the PR 3 unit of
    // work): each build_rec works inside its pre-reserved perm/leaf_at
    // span against a local node arena.
    {
        let rebuild_span = obs::trace::SpanGuard::enter("tree.update_subtrees");
        let pool = ThreadPool::new_or_default(threads);
        let pp = SendPtr(cx.new_perm.as_mut_ptr());
        let lp = SendPtr(cx.new_leaf.as_mut_ptr());
        let slots: Vec<std::sync::Mutex<Vec<Node>>> =
            frontier.iter().map(|_| std::sync::Mutex::new(Vec::new())).collect();
        {
            let ppr = &pp;
            let lpr = &lp;
            let cxr = &cx;
            let dsr = &ds;
            pool.for_each_chunked(frontier.len(), 1, |fi| {
                let v = frontier[fi] as usize;
                // SAFETY: frontier spans are disjoint; each rebuild touches
                // perm/leaf_at only inside its own [new_lo, new_hi).
                let perm_all: &mut [usize] =
                    unsafe { std::slice::from_raw_parts_mut(ppr.0, n_new) };
                let leaf_all: &mut [u32] = unsafe { std::slice::from_raw_parts_mut(lpr.0, n_new) };
                let onode = &cxr.old.nodes[v];
                let mut lnodes = vec![Node {
                    level: onode.level,
                    lo: cxr.new_lo[v],
                    hi: cxr.new_hi[v],
                    children: Vec::new(),
                    parent: 0,
                    center: onode.center.clone(),
                    half: onode.half,
                }];
                build_rec(dsr, d, leaf_cap, max_depth, &mut lnodes, 0, perm_all, leaf_all);
                *slots[fi].lock().unwrap() = lnodes;
            });
        }
        cx.locals = slots.into_iter().map(|m| m.into_inner().unwrap()).collect();
        drop(rebuild_span);
    }

    // Renumber: simulate the sequential DFS id assignment (clean subtrees
    // and rebuilt subtrees each take one contiguous descendant block).
    let mut counter = 1u32;
    cx.assign(0, &mut counter);
    let total = counter as usize;

    // Emit pass: preserved nodes serially (clean subtrees are block
    // copies with index shifts), rebuilt subtrees spliced like build_par.
    cx.nodes = vec![
        Node {
            level: 0,
            lo: 0,
            hi: 0,
            children: Vec::new(),
            parent: 0,
            center: Vec::new(),
            half: 0.0,
        };
        total
    ];
    cx.node_map = vec![u32::MAX; total];
    cx.clean = vec![false; total];
    cx.emit(0, 0);
    for (fi, &fv) in frontier.iter().enumerate() {
        let b = cx.fbase[fi];
        let fg = cx.new_id[fv as usize];
        for (li, ln) in cx.locals[fi].iter().enumerate().skip(1) {
            let mut out = ln.clone();
            out.parent = if ln.parent == 0 { fg } else { b + ln.parent - 1 };
            out.children = ln.children.iter().map(|&c| b + c - 1).collect();
            cx.nodes[(b + li as u32 - 1) as usize] = out;
        }
        let (lo, hi) = (cx.new_lo[fv as usize] as usize, cx.new_hi[fv as usize] as usize);
        for k in lo..hi {
            let v = cx.new_leaf[k];
            cx.new_leaf[k] = if v == 0 { fg } else { b + v - 1 };
        }
    }

    let rebuilt_points: u64 = frontier
        .iter()
        .map(|&f| (cx.new_hi[f as usize] - cx.new_lo[f as usize]) as u64)
        .sum();
    counters::add(Counter::UpdateSubtreesRebuilt, frontier.len() as u64);
    counters::add(Counter::UpdatePointsRebuilt, rebuilt_points);

    let mut pos = vec![0usize; n_new];
    for (k, &p) in cx.new_perm.iter().enumerate() {
        pos[p] = k;
    }
    let tree = BoxTree {
        d,
        nodes: cx.nodes,
        perm: cx.new_perm,
        pos,
        leaf_at: cx.new_leaf,
        leaf_cap,
    };
    TreeUpdate {
        tree,
        ds,
        old_to_new,
        new_to_old,
        node_map: cx.node_map,
        clean: cx.clean,
        full_rebuild: false,
    }
}

/// Orthant descent step: the child of `v` whose orthant contains `row`
/// (`None` when that orthant has no child).  Matches `split_node`'s
/// bucketing (`>= center` sets the bit); the child's code is recovered from
/// its center offset, which is exact because boxes halve exactly.
fn route_child(tree: &BoxTree, d: usize, v: usize, row: &[f32]) -> Option<usize> {
    let nd = &tree.nodes[v];
    let mut code = 0usize;
    for a in 0..d {
        if row[a] >= nd.center[a] {
            code |= 1 << a;
        }
    }
    for &c in &nd.children {
        let ch = &tree.nodes[c as usize];
        let mut ccode = 0usize;
        for a in 0..d {
            if ch.center[a] > nd.center[a] {
                ccode |= 1 << a;
            }
        }
        if ccode == code {
            return Some(c as usize);
        }
    }
    None
}

/// Descend to the node that would absorb `row`: the old leaf containing it,
/// or (`missing = true`) the deepest internal node on the path when the
/// point's orthant has no child there yet.
fn route(tree: &BoxTree, d: usize, row: &[f32]) -> (usize, bool) {
    let mut v = 0usize;
    loop {
        if tree.nodes[v].is_leaf() {
            return (v, false);
        }
        match route_child(tree, d, v, row) {
            Some(c) => v = c,
            None => return (v, true),
        }
    }
}

/// Working state of the patching passes (old ids index `new_lo`/`new_hi`/
/// `new_id`/`cbase`; new ids index `nodes`/`node_map`/`clean`).
struct Patcher<'a> {
    old: &'a BoxTree,
    ds: &'a Dataset,
    d: usize,
    leaf_cap: usize,
    old_to_new: &'a [u32],
    touched: &'a [bool],
    fidx: &'a [u32],
    frontier: &'a [u32],
    ins_of: &'a [Vec<u32>],
    end: &'a [u32],
    new_lo: Vec<u32>,
    new_hi: Vec<u32>,
    new_perm: Vec<usize>,
    new_leaf: Vec<u32>,
    locals: Vec<Vec<Node>>,
    new_id: Vec<u32>,
    fbase: Vec<u32>,
    cbase: Vec<u32>,
    nodes: Vec<Node>,
    node_map: Vec<u32>,
    clean: Vec<bool>,
}

impl Patcher<'_> {
    fn spans(&mut self, v: usize, cursor: &mut u32) {
        self.new_lo[v] = *cursor;
        let nd = &self.old.nodes[v];
        if self.fidx[v] != u32::MAX {
            // Frontier: survivors of the old span (ascending after the
            // monotone remap) then the routed inserts (ascending, all past
            // the survivors) — the identity permutation restricted to the
            // new span, which is what the from-scratch build starts from.
            for k in nd.lo..nd.hi {
                let m = self.old_to_new[self.old.perm[k as usize]];
                if m != u32::MAX {
                    self.new_perm[*cursor as usize] = m as usize;
                    *cursor += 1;
                }
            }
            for &e in &self.ins_of[self.fidx[v] as usize] {
                self.new_perm[*cursor as usize] = e as usize;
                *cursor += 1;
            }
        } else if !self.touched[v] {
            // Clean: the old final layout remapped — identical to what the
            // from-scratch build produces on the same member set.
            for k in nd.lo..nd.hi {
                let m = self.old_to_new[self.old.perm[k as usize]];
                debug_assert!(m != u32::MAX, "clean subtree contains a deleted point");
                self.new_perm[*cursor as usize] = m as usize;
                *cursor += 1;
            }
        } else {
            for c in nd.children.clone() {
                self.spans(c as usize, cursor);
            }
        }
        self.new_hi[v] = *cursor;
    }

    fn assign(&mut self, v: usize, counter: &mut u32) {
        if self.fidx[v] != u32::MAX {
            let fi = self.fidx[v] as usize;
            self.fbase[fi] = *counter;
            *counter += (self.locals[fi].len() - 1) as u32;
            return;
        }
        if !self.touched[v] {
            let nd = &self.old.nodes[v];
            if let Some(&first) = nd.children.first() {
                self.cbase[v] = *counter;
                *counter += self.end[v] - first;
            }
            return;
        }
        let children = self.old.nodes[v].children.clone();
        for &c in &children {
            self.new_id[c as usize] = *counter;
            *counter += 1;
        }
        for &c in &children {
            self.assign(c as usize, counter);
        }
    }

    fn emit(&mut self, v: usize, parent_new: u32) {
        let g = self.new_id[v];
        let nd = &self.old.nodes[v];
        if self.fidx[v] != u32::MAX {
            let fi = self.fidx[v] as usize;
            let b = self.fbase[fi];
            self.nodes[g as usize] = Node {
                level: nd.level,
                lo: self.new_lo[v],
                hi: self.new_hi[v],
                children: self.locals[fi][0].children.iter().map(|&c| b + c - 1).collect(),
                parent: parent_new,
                center: nd.center.clone(),
                half: nd.half,
            };
            // The frontier root keeps its box (same orthant path), so it
            // maps — but its subtree was rebuilt, so it is not clean.
            self.node_map[g as usize] = v as u32;
            return;
        }
        if !self.touched[v] {
            // Clean subtree: block copy of [v] ∪ [first_child, end) with a
            // uniform span shift and the block id remap.
            let first = nd.children.first().copied().unwrap_or(0);
            let shift = self.new_lo[v] as i64 - nd.lo as i64;
            let map_id = |x: u32| -> u32 {
                if x as usize == v {
                    g
                } else {
                    self.cbase[v] + (x - first)
                }
            };
            for x in std::iter::once(v as u32)
                .chain(if nd.is_leaf() { first..first } else { first..self.end[v] })
            {
                let o = &self.old.nodes[x as usize];
                let gx = map_id(x);
                self.nodes[gx as usize] = Node {
                    level: o.level,
                    lo: (o.lo as i64 + shift) as u32,
                    hi: (o.hi as i64 + shift) as u32,
                    children: o.children.iter().map(|&c| map_id(c)).collect(),
                    parent: if x as usize == v { parent_new } else { map_id(o.parent) },
                    center: o.center.clone(),
                    half: o.half,
                };
                self.node_map[gx as usize] = x;
                self.clean[gx as usize] = true;
            }
            for k in self.new_lo[v]..self.new_hi[v] {
                let old_k = (k as i64 - shift) as usize;
                self.new_leaf[k as usize] = map_id(self.old.leaf_at[old_k]);
            }
            return;
        }
        // Scaffold: same children (all preserved, none emptied, no new
        // orthant), shifted spans, renumbered ids.
        let children = nd.children.clone();
        self.nodes[g as usize] = Node {
            level: nd.level,
            lo: self.new_lo[v],
            hi: self.new_hi[v],
            children: children.iter().map(|&c| self.new_id[c as usize]).collect(),
            parent: parent_new,
            center: nd.center.clone(),
            half: nd.half,
        };
        self.node_map[g as usize] = v as u32;
        for &c in &children {
            self.emit(c as usize, g);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::util::rng::Rng;

    fn assert_tree_eq(a: &BoxTree, b: &BoxTree, what: &str) {
        assert_eq!(a.perm, b.perm, "{what}: perm");
        assert_eq!(a.pos, b.pos, "{what}: pos");
        assert_eq!(a.leaf_at, b.leaf_at, "{what}: leaf_at");
        assert_eq!(a.nodes.len(), b.nodes.len(), "{what}: node count");
        for (i, (x, y)) in a.nodes.iter().zip(&b.nodes).enumerate() {
            assert_eq!(x.level, y.level, "{what}: node {i} level");
            assert_eq!(x.lo, y.lo, "{what}: node {i} lo");
            assert_eq!(x.hi, y.hi, "{what}: node {i} hi");
            assert_eq!(x.children, y.children, "{what}: node {i} children");
            assert_eq!(x.parent, y.parent, "{what}: node {i} parent");
            assert_eq!(x.half.to_bits(), y.half.to_bits(), "{what}: node {i} half");
            assert!(
                x.center.iter().zip(&y.center).all(|(p, q)| p.to_bits() == q.to_bits()),
                "{what}: node {i} center"
            );
        }
    }

    fn expected_ds(ds: &Dataset, batch: &UpdateBatch) -> Dataset {
        let d = ds.d();
        let mut dels = batch.deletes.clone();
        dels.sort_unstable();
        dels.dedup();
        let mut xs = Vec::new();
        for i in 0..ds.n() {
            if dels.binary_search(&i).is_err() {
                xs.extend_from_slice(ds.row(i));
            }
        }
        xs.extend_from_slice(&batch.inserts);
        let n = xs.len() / d;
        Dataset::new(n, d, xs)
    }

    fn check(ds: &Dataset, batch: &UpdateBatch, leaf_cap: usize, what: &str) -> TreeUpdate {
        let old = BoxTree::build(ds, leaf_cap, 24);
        let tu = update_tree(&old, ds, batch, 24, 2);
        let want_ds = expected_ds(ds, batch);
        assert_eq!(tu.ds.n(), want_ds.n(), "{what}: ds size");
        assert!(
            tu.ds.raw().iter().zip(want_ds.raw()).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{what}: ds payload"
        );
        let want = BoxTree::build(&want_ds, leaf_cap, 24);
        assert_tree_eq(&tu.tree, &want, what);
        // node_map / clean consistency: a clean node maps to an old node
        // with the same population and box.
        for (g, (&m, &cl)) in tu.node_map.iter().zip(&tu.clean).enumerate() {
            if cl {
                assert_ne!(m, u32::MAX, "{what}: clean node {g} unmapped");
                let o = &old.nodes[m as usize];
                let nnd = &tu.tree.nodes[g];
                assert_eq!(o.len(), nnd.len(), "{what}: clean node {g} population");
                assert_eq!(o.half.to_bits(), nnd.half.to_bits());
            }
        }
        tu
    }

    fn interior_batch(ds: &Dataset, seed: u64, n_del: usize, n_ins: usize) -> UpdateBatch {
        // deletes avoid the bbox hull so the incremental path stays live;
        // inserts jitter existing points inward.
        let d = ds.d();
        let mut rng = Rng::new(seed);
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        for i in 0..ds.n() {
            for (a, &x) in ds.row(i).iter().enumerate() {
                lo[a] = lo[a].min(x);
                hi[a] = hi[a].max(x);
            }
        }
        let on_hull = |row: &[f32]| row.iter().enumerate().any(|(a, &x)| x == lo[a] || x == hi[a]);
        let mut deletes = Vec::new();
        while deletes.len() < n_del {
            let i = rng.below(ds.n());
            if !on_hull(ds.row(i)) {
                deletes.push(i);
            }
        }
        let mut inserts = Vec::new();
        for _ in 0..n_ins {
            let i = rng.below(ds.n());
            for (a, &x) in ds.row(i).iter().enumerate() {
                let t = 0.9 * x + 0.1 * (0.5 * (lo[a] + hi[a]));
                inserts.push(t);
            }
        }
        UpdateBatch { deletes, inserts }
    }

    #[test]
    fn incremental_matches_from_scratch() {
        for (n, d, seed) in [(400usize, 2usize, 11u64), (700, 3, 12), (250, 2, 13)] {
            let ds = SynthSpec::blobs(n, d, 4, seed).generate();
            for (n_del, n_ins) in [(20, 0), (0, 25), (15, 15)] {
                let batch = interior_batch(&ds, seed * 31 + n_del as u64, n_del, n_ins);
                let tu = check(&ds, &batch, 12, &format!("n={n} d={d} del={n_del} ins={n_ins}"));
                assert!(!tu.full_rebuild, "interior batch must not force a full rebuild");
            }
        }
    }

    #[test]
    fn chained_updates_stay_identical() {
        let mut ds = SynthSpec::blobs(500, 3, 3, 21).generate();
        let mut tree = BoxTree::build(&ds, 10, 24);
        for step in 0..4 {
            let batch = interior_batch(&ds, 100 + step, 12, 12);
            let tu = update_tree(&tree, &ds, &batch, 24, 1 + (step as usize % 3));
            let want = BoxTree::build(&tu.ds, 10, 24);
            assert_tree_eq(&tu.tree, &want, &format!("chain step {step}"));
            ds = tu.ds;
            tree = tu.tree;
        }
    }

    #[test]
    fn empty_batch_is_identity() {
        let ds = SynthSpec::blobs(200, 2, 3, 5).generate();
        let old = BoxTree::build(&ds, 8, 24);
        let tu = update_tree(&old, &ds, &UpdateBatch::default(), 24, 2);
        assert!(!tu.full_rebuild);
        assert_tree_eq(&tu.tree, &old, "empty batch");
        assert!(tu.clean.iter().all(|&c| c));
        assert!(tu.node_map.iter().enumerate().all(|(i, &m)| m == i as u32));
    }

    #[test]
    fn hull_change_forces_full_rebuild() {
        let ds = SynthSpec::blobs(300, 2, 3, 7).generate();
        let old = BoxTree::build(&ds, 8, 24);
        // insert far outside the bounding box
        let batch = UpdateBatch {
            deletes: vec![],
            inserts: vec![1.0e3, -1.0e3],
        };
        let tu = update_tree(&old, &ds, &batch, 24, 2);
        assert!(tu.full_rebuild);
        let want = BoxTree::build(&tu.ds, 8, 24);
        assert_tree_eq(&tu.tree, &want, "hull grow");
    }

    #[test]
    fn duplicate_deletes_are_deduped() {
        let ds = SynthSpec::blobs(150, 2, 2, 9).generate();
        let mut batch = interior_batch(&ds, 77, 6, 0);
        let dup = batch.deletes[0];
        batch.deletes.push(dup);
        batch.deletes.push(dup);
        check(&ds, &batch, 8, "duplicate deletes");
    }

    #[test]
    fn pos_map_tracks_survivors() {
        let ds = SynthSpec::blobs(180, 2, 3, 15).generate();
        let old = BoxTree::build(&ds, 8, 24);
        let batch = interior_batch(&ds, 16, 10, 10);
        let tu = update_tree(&old, &ds, &batch, 24, 1);
        let pm = tu.pos_map(&old);
        for (p_new, &p_old) in pm.iter().enumerate() {
            let e_new = tu.tree.perm[p_new];
            let e_old = tu.new_to_old[e_new];
            if e_old == u32::MAX {
                assert_eq!(p_old, u32::MAX);
            } else {
                assert_eq!(old.perm[p_old as usize], e_old as usize);
                // same coordinates on both sides
                let a = tu.ds.row(e_new);
                let b = ds.row(e_old as usize);
                assert!(a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
            }
        }
    }
}
