//! Fault-tolerant serving tier: a persistent daemon over one
//! [`UpdatableKernelEngine`](crate::interact::epoch::UpdatableKernelEngine).
//!
//! Topology: an admission [`Gate`](admission::Gate) (bounded queue,
//! explicit load shedding) feeds a dispatcher that coalesces requests
//! into slates, acquires one epoch snapshot per slate, and fans
//! near-field work to shard workers — each owning a contiguous run of
//! top-level subtrees.  The dispatcher merges the disjoint row partials
//! and applies the far field once, so answers are bit-identical across
//! shard counts and epoch-consistent under mid-stream updates.
//!
//! Degradation ladder (robustness contract):
//! 1. **full** — SIMD near field on healthy shards;
//! 2. **scalar-kernel shard** — a panicking shard is retried with
//!    backoff, then rescued with the scalar fallback; repeated panics
//!    poison it (fallback until the next epoch heals it), answers are
//!    flagged `degraded`;
//! 3. **shed** — typed rejection ([`wire::RejectReason`]) for queue
//!    overflow, malformed/oversized queries, blown deadlines, and shards
//!    that fail even the fallback.  The daemon never blocks unboundedly
//!    and never panics outward.
//!
//! Determinism: [`faults::FaultPlan`] scripts worker panics, artificial
//! shard latency, bad client queries, and mid-stream epoch updates
//! against seeded sequence numbers, so every failure drill in
//! `tests/serve_faults.rs` replays exactly.
//!
//! Observability: every stage records into the serve-tier deep
//! observability layer — lock-free latency histograms per stage
//! ([`crate::obs::hist`]), request-scoped tracing (flow-tagged spans on
//! the dispatcher track and one Chrome-trace track per shard), and the
//! fault flight recorder ([`crate::obs::flight`]) that auto-dumps
//! forensics on panic containment, shard poisoning, and deadline sheds.

pub mod admission;
pub mod faults;
pub mod loadgen;
pub mod server;
pub mod shard;
pub mod wire;

pub use faults::FaultPlan;
pub use server::{Pending, Server, ServeStats, StatsSnapshot};
pub use wire::{Payload, Query, RejectReason, Request, Response, ServeConfig};
