//! Admission control: a bounded queue with explicit load shedding.
//!
//! The gate is a `sync_channel` of `queue_cap` slots plus
//! a screening pass.  `try_admit` never blocks: a full queue is an
//! immediate typed [`RejectReason::QueueFull`] — the "never block
//! unboundedly" half of the robustness contract — and shape/size
//! screening runs *before* the queue so malformed or oversized payloads
//! are bounced without occupying a slot.

use crate::coordinator::batcher::QueryBatcher;
use crate::serve::wire::{Query, RejectReason, Request, Response};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::time::Instant;

/// An admitted request in flight: the wire request plus its response
/// channel and admission timestamps — a monotonic [`Instant`] for
/// real-time latency accounting and the same moment on the
/// `obs::trace::now_us` timebase, which the dispatcher uses to record
/// the admission-wait histogram and the retroactive `serve.admit` span.
pub struct Job {
    pub req: Request,
    pub reply: Sender<Response>,
    pub submitted: Instant,
    pub submitted_us: u64,
}

/// The bounded admission queue.
pub struct Gate {
    tx: SyncSender<Job>,
    cap: usize,
}

impl Gate {
    /// Gate + the dispatcher's receiving end.
    pub fn new(cap: usize) -> (Gate, Receiver<Job>) {
        let cap = cap.max(1);
        let (tx, rx) = sync_channel(cap);
        (Gate { tx, cap }, rx)
    }

    /// Admit without blocking; a full queue sheds with a typed reason and
    /// hands the job back so the caller can deliver the rejection.
    pub fn try_admit(&self, job: Job) -> Result<(), (Job, RejectReason)> {
        match self.tx.try_send(job) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(job)) => {
                let cap = self.cap;
                Err((job, RejectReason::QueueFull { depth: cap, cap }))
            }
            Err(TrySendError::Disconnected(job)) => Err((job, RejectReason::ShuttingDown)),
        }
    }
}

/// Shape/size screening against the current epoch's index space `n`.
/// Order matters: the oversize ceiling (`oversize_factor * n`) is checked
/// first so a hostile giant payload is rejected by length alone; the
/// exact-shape check reuses the batcher's typed validation.
pub fn screen(query: &Query, n: usize, oversize_factor: usize) -> Result<(), RejectReason> {
    match query {
        Query::Gauss { .. } | Query::Krr { .. } => {
            let q = query.charges().expect("apply query carries charges");
            let max = n * oversize_factor.max(1);
            if q.len() > max {
                return Err(RejectReason::Oversized { len: q.len(), max });
            }
            QueryBatcher::validate(n, q).map_err(RejectReason::Malformed)
        }
        Query::Knn { point, .. } => {
            if (*point as usize) < n {
                Ok(())
            } else {
                Err(RejectReason::BadPoint { point: *point, n })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::wire::Query;
    use std::sync::mpsc::channel;

    fn job(id: u64) -> Job {
        let (reply, _rx) = channel();
        Job {
            req: Request { id, query: Query::Knn { point: 0, k: 1 }, budget_us: 1000 },
            reply,
            submitted: Instant::now(),
            submitted_us: crate::obs::trace::now_us(),
        }
    }

    #[test]
    fn gate_sheds_when_full_without_blocking() {
        let (gate, rx) = Gate::new(2);
        gate.try_admit(job(0)).expect("slot 0");
        gate.try_admit(job(1)).expect("slot 1");
        let (_, reason) = gate.try_admit(job(2)).expect_err("queue full");
        assert_eq!(reason, RejectReason::QueueFull { depth: 2, cap: 2 });
        drop(rx);
        let (_, reason) = gate.try_admit(job(3)).expect_err("disconnected");
        assert_eq!(reason, RejectReason::ShuttingDown);
    }

    #[test]
    fn screen_orders_oversize_before_shape() {
        let n = 8;
        // way over the ceiling: Oversized, not Malformed
        let big = Query::Gauss { charges: vec![0.0; n * 5] };
        assert!(matches!(screen(&big, n, 4), Err(RejectReason::Oversized { .. })));
        // wrong but under the ceiling: Malformed
        let wrong = Query::Gauss { charges: vec![0.0; n + 1] };
        assert!(matches!(screen(&wrong, n, 4), Err(RejectReason::Malformed(_))));
        let ok = Query::Krr { alpha: vec![0.0; n] };
        assert!(screen(&ok, n, 4).is_ok());
        assert!(matches!(
            screen(&Query::Knn { point: 8, k: 2 }, n, 4),
            Err(RejectReason::BadPoint { .. })
        ));
        assert!(screen(&Query::Knn { point: 7, k: 2 }, n, 4).is_ok());
    }
}
