//! Deterministic load generator: the client side of the fault harness.
//!
//! Drives a [`Server`] with a seeded query stream (Gauss/KRR apply
//! slates interleaved with kNN lookups), executes the **client-side**
//! faults of the plan at their scripted request indices (malformed query,
//! oversized query, mid-stream epoch update), and accounts for every
//! request: answered, shed (typed), or — the bug detector — lost/hung.
//! `nni serve --load-gen` feeds the report into `BENCH_serve.json`
//! (p50/p99 latency plus the shed/retry counters).

use crate::obs::hist::Hist;
use crate::serve::faults::{Fault, FaultPlan};
use crate::serve::server::{Server, StatsSnapshot};
use crate::serve::wire::Query;
use crate::tree::update::UpdateBatch;
use crate::util::rng::Rng;
use std::time::{Duration, Instant};

/// Load-generator knobs.
#[derive(Clone, Copy, Debug)]
pub struct LoadGenCfg {
    /// Requests to send (client-side faults count toward this).
    pub requests: usize,
    /// Every `knn_every`-th request is a kNN lookup (0 = apply-only).
    pub knn_every: usize,
    /// Neighbors per kNN lookup.
    pub k: usize,
    /// Per-request wait bound; expiry marks the request **lost** — the
    /// one outcome the serving contract forbids.
    pub timeout: Duration,
}

impl Default for LoadGenCfg {
    fn default() -> Self {
        LoadGenCfg { requests: 64, knn_every: 4, k: 8, timeout: Duration::from_secs(30) }
    }
}

/// What happened to a request stream.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadReport {
    pub sent: usize,
    pub ok: usize,
    /// Shed with a typed reason (admission or dispatch side).
    pub shed: usize,
    /// Answered, but some owning shard ran the scalar fallback.
    pub degraded: usize,
    /// Neither answered nor shed within the timeout — must stay 0.
    pub lost: usize,
    /// Wall-clock latency quantiles over answered requests, µs — read
    /// from a log-linear [`Hist`], so each is within one bucket
    /// (relative error `<= 1/32`) of the exact nearest-rank value.
    pub p50_us: u64,
    pub p99_us: u64,
    /// Exact (the histogram tracks max exactly).
    pub max_us: u64,
    pub stats: StatsSnapshot,
}

/// Nearest-rank percentile of an ascending-sorted sample (`0` if empty).
/// Kept as the **exact oracle** the histogram quantiles are pinned
/// against (see `histogram_quantile_tracks_exact_oracle`).
pub fn percentile(sorted_us: &[u64], p: f64) -> u64 {
    if sorted_us.is_empty() {
        return 0;
    }
    let rank = ((p / 100.0) * sorted_us.len() as f64).ceil() as usize;
    sorted_us[rank.clamp(1, sorted_us.len()) - 1]
}

/// Drive `server` with `cfg.requests` seeded requests, executing the
/// plan's client-side faults, serially (submit, then wait) — so slate
/// sequence numbers, and with them the worker-side fault script, are
/// deterministic regardless of shard count.
pub fn run(server: &Server, plan: &FaultPlan, cfg: &LoadGenCfg) -> LoadReport {
    let mut rng = Rng::new(plan.seed ^ 0x6c6f_6164);
    // Latencies go straight into a local log-linear histogram (boxed:
    // the bucket array is ~15 KiB) — the same machinery the serve
    // stages use, so the report's quantiles carry the same 1/32 bound.
    let lat = Box::new(Hist::new());
    let mut rep = LoadReport::default();
    for i in 0..cfg.requests {
        let (n, d) = server.shape();
        // A scripted bad query replaces request i's normal payload.
        let mut query = None;
        for f in plan.client_faults_at(i) {
            match f {
                Fault::MalformedQuery { .. } => {
                    query = Some(Query::Gauss { charges: vec![0.0; n + 1] });
                }
                Fault::OversizedQuery { .. } => {
                    let max = n * server.config().oversize_factor.max(1);
                    query = Some(Query::Gauss { charges: vec![0.0; max + 1] });
                }
                _ => {}
            }
        }
        let query = query.unwrap_or_else(|| {
            if cfg.knn_every > 0 && i % cfg.knn_every == cfg.knn_every - 1 {
                Query::Knn { point: rng.below(n) as u32, k: cfg.k }
            } else {
                let charges: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
                if i % 2 == 0 {
                    Query::Gauss { charges }
                } else {
                    Query::Krr { alpha: charges }
                }
            }
        });
        rep.sent += 1;
        let t0 = Instant::now();
        match server.submit(query) {
            Err(_) => rep.shed += 1, // typed admission shed — accounted
            Ok(pending) => match pending.wait_timeout(cfg.timeout) {
                Err(_) => rep.lost += 1,
                Ok(resp) => {
                    lat.record(t0.elapsed().as_micros() as u64);
                    if resp.result.is_ok() {
                        rep.ok += 1;
                        if resp.degraded {
                            rep.degraded += 1;
                        }
                    } else {
                        rep.shed += 1;
                    }
                }
            },
        }
        // Mid-stream epoch updates publish after request i completes;
        // later requests are screened and served against the new epoch.
        for f in plan.client_faults_at(i) {
            if let Fault::EpochUpdate { n_del, n_ins, .. } = f {
                let n_del = (*n_del).min(n.saturating_sub(16));
                let deletes: Vec<usize> = (0..n_del).collect();
                let inserts: Vec<f32> =
                    (0..n_ins * d).map(|_| rng.f32() * 2.0 - 1.0).collect();
                server.update(&UpdateBatch { deletes, inserts });
            }
        }
    }
    let snap = lat.snapshot();
    rep.p50_us = snap.quantile(50.0);
    rep.p99_us = snap.quantile(99.0);
    rep.max_us = snap.max;
    rep.stats = server.stats();
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::csb::kernel::KernelKind;
    use crate::data::synth::SynthSpec;
    use crate::hmat::FullKernelConfig;
    use crate::interact::epoch::{UpdatableKernelEngine, UpdateCfg};
    use crate::serve::wire::ServeConfig;
    use std::sync::Arc;

    #[test]
    fn histogram_quantile_tracks_exact_oracle() {
        use crate::obs::hist::bucket_index;
        // Seeded values spanning six orders of magnitude: every histogram
        // quantile must land in the same bucket as the exact nearest-rank
        // oracle, i.e. within one bucket width (relative error <= 1/32).
        let mut rng = Rng::new(0x0b5e);
        let h = Box::new(Hist::new());
        let mut exact: Vec<u64> = Vec::new();
        for _ in 0..5000 {
            let scale = 1u64 << (rng.below(20) + 1);
            let v = rng.below(scale as usize) as u64;
            h.record(v);
            exact.push(v);
        }
        exact.sort_unstable();
        let snap = h.snapshot();
        assert_eq!(snap.count, 5000);
        assert_eq!(snap.max, *exact.last().unwrap());
        for p in [50.0, 90.0, 99.0, 99.9] {
            let want = percentile(&exact, p);
            let got = snap.quantile(p);
            assert_eq!(
                bucket_index(got),
                bucket_index(want),
                "p{p}: estimate {got} must share a bucket with exact {want}"
            );
        }
    }

    #[test]
    fn percentile_is_nearest_rank() {
        assert_eq!(percentile(&[], 50.0), 0);
        assert_eq!(percentile(&[7], 50.0), 7);
        assert_eq!(percentile(&[1, 2, 3, 4], 50.0), 2);
        assert_eq!(percentile(&[1, 2, 3, 4], 99.0), 4);
        assert_eq!(percentile(&[1, 2, 3, 4], 100.0), 4);
    }

    #[test]
    fn loadgen_accounts_for_every_request() {
        let ds = SynthSpec::blobs(260, 3, 4, 31).generate();
        let cfg = UpdateCfg {
            leaf_cap: 8,
            block_cap: 32,
            build_threads: 1,
            threads: 1,
            kernel: KernelKind::Scalar,
            ..UpdateCfg::default()
        };
        let upd = Arc::new(UpdatableKernelEngine::build(ds, cfg, FullKernelConfig::new(0.8)));
        let plan = FaultPlan::parse(11, "malformed:2, oversized:5, update:7:4:4").expect("spec");
        let server = Server::start(
            upd,
            ServeConfig { shards: 2, real_time: false, ..ServeConfig::default() },
            plan.clone(),
        );
        let report = run(
            &server,
            &plan,
            &LoadGenCfg { requests: 12, ..LoadGenCfg::default() },
        );
        assert_eq!(report.sent, 12);
        assert_eq!(report.lost, 0, "no request may be lost or hung");
        assert_eq!(report.shed, 2, "exactly the two scripted bad queries");
        assert_eq!(report.ok, 10);
        assert_eq!(report.ok + report.shed + report.lost, report.sent);
        assert_eq!(report.stats.shed_malformed, 1);
        assert_eq!(report.stats.shed_oversized, 1);
        assert_eq!(report.stats.epoch_switches, 1, "mid-stream update published");
        let stats = server.shutdown();
        assert_eq!(stats.responded_ok, 10);
    }
}
