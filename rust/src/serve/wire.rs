//! Wire types of the serving tier: queries, requests, responses, typed
//! rejection reasons, and the daemon configuration.
//!
//! Everything a client sees lives here.  The contract the fault tests
//! lean on: a request either gets **exactly one** [`Response`] (possibly
//! a typed shed) or is rejected synchronously at admission — never
//! silently dropped, never left hanging.

use crate::coordinator::batcher::QueryReject;

/// One query against the served operator.
#[derive(Clone, Debug)]
pub enum Query {
    /// k nearest neighbors of indexed point `point` (external/insertion
    /// id), served from the near-field Gaussian profile of the current
    /// epoch (weights are monotone in distance, so top-k by stored weight
    /// = nearest among the dual-tree near candidates).
    Knn { point: u32, k: usize },
    /// Gaussian potentials: `y = K·q` for a charge vector `q` of length
    /// n (insertion order), via the sharded near field + coordinator far
    /// field.
    Gauss { charges: Vec<f32> },
    /// KRR prediction at the indexed points: `y = K·alpha` — the same
    /// apply slate as [`Query::Gauss`] with the solved coefficients as
    /// charges (arXiv 1803.10274's serving mode).
    Krr { alpha: Vec<f32> },
}

impl Query {
    /// Charge vector of the apply-slate queries (`None` for kNN).
    pub(crate) fn charges(&self) -> Option<&[f32]> {
        match self {
            Query::Gauss { charges } => Some(charges),
            Query::Krr { alpha } => Some(alpha),
            Query::Knn { .. } => None,
        }
    }
}

/// One submitted request: a query plus its latency budget.  Ids are
/// assigned by the server at submission (monotonic per daemon).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub query: Query,
    /// Latency budget in µs; blowing it sheds the request with
    /// [`RejectReason::DeadlineExceeded`] instead of blocking the slate.
    pub budget_us: u64,
}

/// Why a request was shed instead of answered.  Every variant is a
/// deliberate admission/deadline decision — the daemon never blocks
/// unboundedly and never panics outward.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// Bounded admission queue is full — load shed at the door.
    QueueFull { depth: usize, cap: usize },
    /// Query shape does not match the served epoch (see
    /// [`QueryReject`]).
    Malformed(QueryReject),
    /// Query exceeds the configured size ceiling — rejected before any
    /// buffer is allocated for it.
    Oversized { len: usize, max: usize },
    /// kNN point id outside the current epoch's index space.
    BadPoint { point: u32, n: usize },
    /// The request's latency budget was exhausted before a result was
    /// ready (injected shard latency and retry backoff are charged
    /// against the budget).
    DeadlineExceeded { budget_us: u64, elapsed_us: u64 },
    /// A shard kept failing after every retry and the scalar fallback —
    /// the request is shed rather than the daemon torn down.
    ShardFailed { shard: usize, attempts: u32 },
    /// The daemon is draining for shutdown.
    ShuttingDown,
}

impl RejectReason {
    /// Compact reason code carried in the `aux` field of flight-recorder
    /// `Shed` events — must stay in sync with
    /// `crate::obs::flight::reason_name`.
    pub(crate) fn flight_code(&self) -> u64 {
        match self {
            RejectReason::QueueFull { .. } => 1,
            RejectReason::Malformed(_) => 2,
            RejectReason::Oversized { .. } => 3,
            RejectReason::BadPoint { .. } => 4,
            RejectReason::DeadlineExceeded { .. } => 5,
            RejectReason::ShardFailed { .. } => 6,
            RejectReason::ShuttingDown => 7,
        }
    }
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::QueueFull { depth, cap } => {
                write!(f, "admission queue full ({depth}/{cap})")
            }
            RejectReason::Malformed(e) => write!(f, "malformed query: {e}"),
            RejectReason::Oversized { len, max } => {
                write!(f, "oversized query ({len} > max {max})")
            }
            RejectReason::BadPoint { point, n } => {
                write!(f, "point id {point} outside index space [0, {n})")
            }
            RejectReason::DeadlineExceeded { budget_us, elapsed_us } => {
                write!(f, "deadline exceeded ({elapsed_us}us > budget {budget_us}us)")
            }
            RejectReason::ShardFailed { shard, attempts } => {
                write!(f, "shard {shard} failed after {attempts} attempts")
            }
            RejectReason::ShuttingDown => write!(f, "daemon shutting down"),
        }
    }
}

/// Result payload of an answered query.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// `(neighbor id, kernel weight)` descending by weight (ties broken
    /// by ascending id) — ids in external/insertion order.
    Knn(Vec<(u32, f32)>),
    /// Potentials/predictions in external/insertion order.
    Potentials(Vec<f32>),
}

/// One response — exactly one per admitted request.
#[derive(Clone, Debug)]
pub struct Response {
    /// Echo of the request id assigned at submission.
    pub id: u64,
    /// Epoch version the answer was computed against.
    pub epoch: u64,
    pub result: Result<Payload, RejectReason>,
    /// True when any owning shard ran in the scalar-kernel fallback
    /// (poisoned-shard degradation) — the answer is still complete.
    pub degraded: bool,
    /// Transient shard failures retried while serving this request.
    pub retries: u32,
    /// Latency charged against the budget (virtual when
    /// [`ServeConfig::real_time`] is off — injected latency + backoff).
    pub elapsed_us: u64,
}

/// Daemon configuration.  Defaults are sized for tests and the smoke
/// load generator; `nni serve` exposes each knob.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Shard workers (each owns a contiguous run of top-level subtrees).
    pub shards: usize,
    /// Admission queue bound — beyond it requests are shed, never queued.
    pub queue_cap: usize,
    /// Max queries coalesced into one dispatch slate.
    pub batch: usize,
    /// Default per-request latency budget.
    pub default_budget_us: u64,
    /// Transient-failure retries per shard task (then scalar fallback).
    pub max_retries: u32,
    /// Exponential backoff base: retry `a` waits `retry_base_us << a`.
    pub retry_base_us: u64,
    /// Consecutive contained panics before a shard is poisoned (forced
    /// into the scalar fallback until the next epoch heals it).
    pub poison_after: u32,
    /// Oversize ceiling as a multiple of the epoch's point count.
    pub oversize_factor: usize,
    /// Sleep injected latencies/backoffs for real (`nni serve`); tests
    /// keep this off so deadline accounting is purely virtual and the
    /// shed/retry counters are machine-independent.
    pub real_time: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            shards: 4,
            queue_cap: 256,
            batch: 8,
            default_budget_us: 50_000,
            max_retries: 2,
            retry_base_us: 100,
            poison_after: 3,
            oversize_factor: 4,
            real_time: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reject_reasons_render() {
        let r = RejectReason::QueueFull { depth: 8, cap: 8 };
        assert!(r.to_string().contains("queue full"));
        let d = RejectReason::DeadlineExceeded { budget_us: 10, elapsed_us: 25 };
        assert!(d.to_string().contains("deadline"));
        let m = RejectReason::Malformed(QueryReject::ShapeMismatch { expected: 4, got: 3 });
        assert!(m.to_string().contains("3"));
    }

    #[test]
    fn flight_codes_round_trip_reason_names() {
        use crate::obs::flight::reason_name;
        let cases: Vec<(RejectReason, &str)> = vec![
            (RejectReason::QueueFull { depth: 1, cap: 1 }, "queue_full"),
            (
                RejectReason::Malformed(QueryReject::ShapeMismatch { expected: 4, got: 3 }),
                "malformed",
            ),
            (RejectReason::Oversized { len: 9, max: 4 }, "oversized"),
            (RejectReason::BadPoint { point: 9, n: 4 }, "bad_point"),
            (RejectReason::DeadlineExceeded { budget_us: 1, elapsed_us: 2 }, "deadline"),
            (RejectReason::ShardFailed { shard: 0, attempts: 3 }, "shard_failed"),
            (RejectReason::ShuttingDown, "shutdown"),
        ];
        for (r, name) in cases {
            assert_eq!(reason_name(r.flight_code()), name, "{r:?}");
        }
    }

    #[test]
    fn charges_only_for_apply_queries() {
        assert!(Query::Knn { point: 0, k: 3 }.charges().is_none());
        assert_eq!(Query::Gauss { charges: vec![1.0] }.charges(), Some(&[1.0][..]));
        assert_eq!(Query::Krr { alpha: vec![2.0] }.charges(), Some(&[2.0][..]));
    }
}
