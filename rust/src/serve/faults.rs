//! Deterministic, seeded fault injection for the serving tier.
//!
//! A [`FaultPlan`] is a replayable script of failures keyed on the
//! dispatcher's global slate sequence number (`seq`) — every apply slate
//! fans out to all shards, so `(shard, seq)` addresses the same task no
//! matter how many worker threads run.  [`FaultState`] is the armed form:
//! worker-side faults (panic, latency) are checked inside the shard loop,
//! client-side faults (malformed/oversized query, mid-stream epoch
//! update) are executed by the load generator / test driver at the given
//! request index.
//!
//! Panic faults fire **once** (an `AtomicBool` latch), so the retry
//! ladder observes exactly one transient failure per injected panic —
//! which is what makes the `serve.retried`/`serve.panics_contained`
//! counter assertions exact.

use crate::obs::flight::{self, Kind};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Once;

/// Marker prefix of injected panic payloads — the quiet panic hook (and
/// the pool containment test) filters on it so test logs stay readable.
pub const INJECTED_PANIC: &str = "injected";

/// One scripted failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Shard `shard` panics on the slate with sequence number `seq`
    /// (fires once; the retry succeeds).
    PanicOnTask { shard: usize, seq: u64 },
    /// Shard `shard` reports `delay_us` of artificial latency on every
    /// slate with `from_seq <= seq < from_seq + count`.
    SlowShard { shard: usize, delay_us: u64, from_seq: u64, count: u64 },
    /// Client submits a shape-mismatched query as request `at`.
    MalformedQuery { at: usize },
    /// Client submits a query above the oversize ceiling as request `at`.
    OversizedQuery { at: usize },
    /// Client applies a delete/insert epoch update after request `at`
    /// completes (mid-stream publish; in-flight slates keep their
    /// snapshot).
    EpochUpdate { at: usize, n_del: usize, n_ins: usize },
}

/// A replayable script of failures plus the seed driving the load
/// generator's query stream.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub seed: u64,
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, faults: Vec::new() }
    }

    pub fn with(mut self, f: Fault) -> FaultPlan {
        self.faults.push(f);
        self
    }

    /// Parse a comma-separated CLI spec:
    /// `panic:SHARD:SEQ`, `slow:SHARD:DELAY_US:FROM[:COUNT]`,
    /// `malformed:AT`, `oversized:AT`, `update:AT:NDEL:NINS`.
    pub fn parse(seed: u64, spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::new(seed);
        for item in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let parts: Vec<&str> = item.split(':').collect();
            let usage = || format!("bad fault spec '{item}'");
            let arg = |i: usize| -> Result<u64, String> {
                parts.get(i).ok_or_else(usage)?.parse::<u64>().map_err(|_| usage())
            };
            let fault = match parts[0] {
                "panic" if parts.len() == 3 => Fault::PanicOnTask {
                    shard: arg(1)? as usize,
                    seq: arg(2)?,
                },
                "slow" if parts.len() == 4 || parts.len() == 5 => Fault::SlowShard {
                    shard: arg(1)? as usize,
                    delay_us: arg(2)?,
                    from_seq: arg(3)?,
                    count: if parts.len() == 5 { arg(4)? } else { 1 },
                },
                "malformed" if parts.len() == 2 => {
                    Fault::MalformedQuery { at: arg(1)? as usize }
                }
                "oversized" if parts.len() == 2 => {
                    Fault::OversizedQuery { at: arg(1)? as usize }
                }
                "update" if parts.len() == 4 => Fault::EpochUpdate {
                    at: arg(1)? as usize,
                    n_del: arg(2)? as usize,
                    n_ins: arg(3)? as usize,
                },
                _ => return Err(usage()),
            };
            plan.faults.push(fault);
        }
        Ok(plan)
    }

    /// Number of injected worker panics (each is contained + retried once).
    pub fn panic_count(&self) -> u64 {
        self.faults
            .iter()
            .filter(|f| matches!(f, Fault::PanicOnTask { .. }))
            .count() as u64
    }

    /// Client-side faults at request index `at`.
    pub fn client_faults_at(&self, at: usize) -> impl Iterator<Item = &Fault> {
        self.faults.iter().filter(move |f| match f {
            Fault::MalformedQuery { at: a }
            | Fault::OversizedQuery { at: a }
            | Fault::EpochUpdate { at: a, .. } => *a == at,
            _ => false,
        })
    }
}

/// An armed [`FaultPlan`]: shared by the dispatcher and every shard
/// worker, with a fire-once latch per panic fault.
pub struct FaultState {
    plan: FaultPlan,
    fired: Vec<AtomicBool>,
}

impl FaultState {
    pub fn arm(plan: FaultPlan) -> FaultState {
        let fired = plan.faults.iter().map(|_| AtomicBool::new(false)).collect();
        FaultState { plan, fired }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Panic here if the plan scripts a (not yet fired) panic for this
    /// `(shard, seq)` task.  Called **inside** the worker's
    /// `catch_unwind`, so the panic is contained, counted, and retried.
    /// The injection itself lands in the flight recorder (`fault` event,
    /// `aux` = fault index in the plan) *before* the panic unwinds, so a
    /// forensic dump shows cause before effect.
    pub fn maybe_panic(&self, shard: usize, seq: u64) {
        for (i, f) in self.plan.faults.iter().enumerate() {
            if let Fault::PanicOnTask { shard: s, seq: q } = f {
                if *s == shard && *q == seq && !self.fired[i].swap(true, Ordering::Relaxed) {
                    flight::record(Kind::Fault, shard as i64, seq, i as u64);
                    panic!("{INJECTED_PANIC} fault: shard {shard} slate {seq}");
                }
            }
        }
    }

    /// Artificial latency scripted for this `(shard, seq)` task, in µs.
    /// A nonzero total is recorded as a flight `fault` event (`aux` =
    /// injected µs).
    pub fn latency_us(&self, shard: usize, seq: u64) -> u64 {
        let total: u64 = self
            .plan
            .faults
            .iter()
            .map(|f| match f {
                Fault::SlowShard { shard: s, delay_us, from_seq, count }
                    if *s == shard && seq >= *from_seq && seq < from_seq + count =>
                {
                    *delay_us
                }
                _ => 0,
            })
            .sum();
        if total > 0 {
            flight::record(Kind::Fault, shard as i64, seq, total);
        }
        total
    }
}

/// Install (once, process-wide) a panic hook that suppresses injected
/// fault panics — they are scripted, contained, and counted, so their
/// default backtrace spam would only obscure real failures — while
/// forwarding everything else to the previous hook.
pub fn quiet_injected_panics() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .map(|s| s.starts_with(INJECTED_PANIC))
                .or_else(|| {
                    info.payload()
                        .downcast_ref::<&str>()
                        .map(|s| s.starts_with(INJECTED_PANIC))
                })
                .unwrap_or(false);
            if !injected {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_every_kind() {
        let p = FaultPlan::parse(7, "panic:0:2, slow:1:2000:3:2, malformed:4, oversized:5, update:6:8:8")
            .expect("valid spec");
        assert_eq!(p.seed, 7);
        assert_eq!(p.faults.len(), 5);
        assert_eq!(p.faults[0], Fault::PanicOnTask { shard: 0, seq: 2 });
        assert_eq!(
            p.faults[1],
            Fault::SlowShard { shard: 1, delay_us: 2000, from_seq: 3, count: 2 }
        );
        assert_eq!(p.panic_count(), 1);
        assert_eq!(p.client_faults_at(4).count(), 1);
        assert_eq!(p.client_faults_at(0).count(), 0);
        assert!(FaultPlan::parse(0, "panic:0").is_err());
        assert!(FaultPlan::parse(0, "explode:1:2").is_err());
        assert_eq!(FaultPlan::parse(0, "").expect("empty ok").faults.len(), 0);
    }

    #[test]
    fn panic_fault_fires_exactly_once() {
        quiet_injected_panics();
        let st = FaultState::arm(FaultPlan::new(0).with(Fault::PanicOnTask { shard: 1, seq: 3 }));
        // wrong shard / wrong seq: no fire
        st.maybe_panic(0, 3);
        st.maybe_panic(1, 2);
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| st.maybe_panic(1, 3)));
        assert!(hit.is_err(), "scripted panic must fire");
        // latch: the retry of the same task succeeds
        st.maybe_panic(1, 3);
    }

    #[test]
    fn latency_matches_window() {
        let st = FaultState::arm(
            FaultPlan::new(0).with(Fault::SlowShard { shard: 2, delay_us: 500, from_seq: 1, count: 2 }),
        );
        assert_eq!(st.latency_us(2, 0), 0);
        assert_eq!(st.latency_us(2, 1), 500);
        assert_eq!(st.latency_us(2, 2), 500);
        assert_eq!(st.latency_us(2, 3), 0);
        assert_eq!(st.latency_us(0, 1), 0);
    }
}
