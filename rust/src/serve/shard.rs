//! Shard workers: each owns a contiguous run of top-level subtrees (a
//! [`ShardSpan`] of the epoch's CSB target leaves) and computes
//! **near-field row partials** for apply slates — per target leaf, the
//! same `by_target` block walk the engine's own schedule performs, into a
//! shard-local buffer.  The coordinator merges the disjoint row ranges
//! and applies the far field once on the merged buffer, which keeps the
//! sharded answer bit-identical regardless of shard count (each output
//! row's accumulation chain is unchanged).
//!
//! Robustness: every task body runs under `catch_unwind` with the fault
//! hooks *inside*, so a scripted (or real) panic surfaces as a
//! [`ShardResult::Panicked`] message — the worker thread itself never
//! dies; the dispatcher owns the retry/restart/poison ladder.

use crate::csb::kernel::Dispatch;
use crate::hmat::FullKernelEngine;
use crate::interact::epoch::{Epoch, KernelEpoch, ShardSpan};
use crate::obs::trace::SpanGuard;
use crate::obs::{counters, hist, trace, Counter};
use crate::serve::faults::FaultState;
use std::panic::AssertUnwindSafe;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

/// Trace track (worker slot) of a shard worker: one Chrome-trace track
/// per shard, above the engine pool's slots and the dispatcher's track
/// 31 (`serve::server::DISPATCH_TRACK`); shards past 32 fold.
pub(crate) fn shard_track(shard: usize) -> usize {
    32 + shard % 32
}

/// One unit of work fanned out by the dispatcher.  Tasks carry their
/// epoch handle, so a slate stays epoch-consistent even if an update
/// publishes mid-flight (the PR 7 bit-stability contract).
pub enum ShardTask {
    /// Near-field row partial of an apply slate (`k` RHS columns,
    /// tree-ordered interleaved `x`).
    Apply {
        seq: u64,
        epoch: Arc<Epoch<KernelEpoch>>,
        span: ShardSpan,
        x: Arc<Vec<f32>>,
        k: usize,
        /// Max remaining budget across the slate's requests — the
        /// deadline propagated into the fan-out: injected latency at or
        /// beyond it makes computing pointless for every request.
        budget_us: u64,
        attempt: u32,
        /// Scalar-kernel fallback (poisoned shard or post-retry rescue).
        fallback: bool,
        /// Request flow id (request id + 1, 0 = none) tagged onto the
        /// shard's `serve.shard.compute` span so the exporter can tie
        /// the request's stages across tracks with flow events.
        flow: u64,
    },
    /// kNN lookup of one tree position owned by this shard.
    Knn {
        seq: u64,
        epoch: Arc<Epoch<KernelEpoch>>,
        span: ShardSpan,
        /// Index of the job within the slate (echoed back for matching).
        job: usize,
        pos: usize,
        k: usize,
        budget_us: u64,
        attempt: u32,
        fallback: bool,
        flow: u64,
    },
    Stop,
}

/// What a shard sends back — exactly one message per received task.
pub enum ShardResult {
    Near {
        seq: u64,
        shard: usize,
        /// `span.rows() * k` partial rows (tree order, interleaved).
        rows: Vec<f32>,
        charged_us: u64,
        fallback: bool,
    },
    Knn {
        seq: u64,
        shard: usize,
        job: usize,
        neighbors: Vec<(u32, f32)>,
        charged_us: u64,
        fallback: bool,
    },
    /// The task body panicked; contained, worker alive, dispatcher
    /// decides (retry → fallback → shed).
    Panicked { seq: u64, shard: usize, attempt: u32, charged_us: u64, knn_job: Option<usize> },
    /// Injected latency ≥ the propagated budget: skip the compute, every
    /// request in the slate will miss its deadline anyway.
    DeadlineSkip { seq: u64, shard: usize, latency_us: u64, knn_job: Option<usize> },
}

/// Near-field row partial of `span` for `k` interleaved RHS columns:
/// zeroed local buffer, then per target leaf the ascending `by_target`
/// block walk — the same per-row accumulation chain as the engine's
/// full near apply, so merged partials are bit-identical across shard
/// maps.  `fallback` pins the scalar kernel (the degradation ladder's
/// middle rung; with a scalar-dispatch engine it is bit-identical).
pub fn near_partial(
    eng: &FullKernelEngine,
    span: &ShardSpan,
    x: &[f32],
    k: usize,
    fallback: bool,
) -> Vec<f32> {
    let csb = &eng.near.csb;
    let mut out = vec![0.0f32; span.rows() * k];
    let d = if fallback { Dispatch::Scalar } else { eng.near.dispatch() };
    for tl in span.leaf_lo..span.leaf_hi {
        let sp = &csb.tgt_leaves[tl];
        let seg =
            &mut out[(sp.lo as usize - span.row_lo) * k..(sp.hi as usize - span.row_lo) * k];
        for &t in &csb.by_target[tl] {
            csb.block_matmul_seg_with(t as usize, x, seg, k, d);
        }
    }
    out
}

/// k nearest neighbors of tree position `pos` from the near-field
/// Gaussian profile: candidates are the stored nonzeros of `pos`'s row
/// (the dual-tree near field), ranked by weight descending (Gaussian
/// weight is monotone decreasing in distance), ties by ascending tree
/// position; `pos` itself excluded.  Returns external ids via `perm`.
pub fn knn_lookup(epoch: &KernelEpoch, span: &ShardSpan, pos: usize, k: usize) -> Vec<(u32, f32)> {
    let csb = &epoch.engine.near.csb;
    // The target leaf containing `pos` (leaves are sorted, disjoint).
    let leaves = &csb.tgt_leaves[span.leaf_lo..span.leaf_hi];
    let tl = match leaves.binary_search_by(|s| {
        if (s.hi as usize) <= pos {
            std::cmp::Ordering::Less
        } else if (s.lo as usize) > pos {
            std::cmp::Ordering::Greater
        } else {
            std::cmp::Ordering::Equal
        }
    }) {
        Ok(i) => span.leaf_lo + i,
        Err(_) => return Vec::new(),
    };
    let mut cand: Vec<(u32, f32)> = Vec::new();
    for &t in &csb.by_target[tl] {
        let b = &csb.blocks[t as usize];
        if (b.rows.lo as usize) > pos || pos >= b.rows.hi as usize {
            continue;
        }
        let local = pos - b.rows.lo as usize;
        csb.for_each_nz(t as usize, |r, c, v| {
            if r == local {
                let col = b.cols.lo as usize + c;
                if col != pos {
                    cand.push((col as u32, v));
                }
            }
        });
    }
    cand.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0)));
    cand.truncate(k);
    cand.into_iter().map(|(p, w)| (epoch.tree.perm[p as usize] as u32, w)).collect()
}

/// The worker loop: one OS thread per shard, alive until [`ShardTask::Stop`].
/// Fault hooks run inside the containment boundary; latency is charged
/// virtually (and slept only when `real_time`).
pub fn worker_loop(
    shard: usize,
    rx: Receiver<ShardTask>,
    tx: Sender<ShardResult>,
    faults: Arc<FaultState>,
    real_time: bool,
) {
    trace::set_worker(shard_track(shard));
    while let Ok(task) = rx.recv() {
        let (seq, attempt, budget_us, knn_job, flow) = match &task {
            ShardTask::Apply { seq, attempt, budget_us, flow, .. } => {
                (*seq, *attempt, *budget_us, None, *flow)
            }
            ShardTask::Knn { seq, attempt, budget_us, job, flow, .. } => {
                (*seq, *attempt, *budget_us, Some(*job), *flow)
            }
            ShardTask::Stop => break,
        };
        // Injected latency first: charged against the propagated budget
        // before any compute.  Retries re-charge it (the slow shard is
        // still slow), which is what the deadline tests script against.
        let latency_us = faults.latency_us(shard, seq);
        if real_time && latency_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(latency_us));
        }
        if latency_us >= budget_us {
            let _ = tx.send(ShardResult::DeadlineSkip { seq, shard, latency_us, knn_job });
            continue;
        }
        let t0 = Instant::now();
        let out = {
            // The span wraps the containment boundary from outside, so a
            // contained panic still closes it when the block ends.
            let _sp = SpanGuard::enter_req("serve.shard.compute", flow);
            std::panic::catch_unwind(AssertUnwindSafe(|| {
                faults.maybe_panic(shard, seq);
                match &task {
                    ShardTask::Apply { epoch, span, x, k, fallback, .. } => ShardResult::Near {
                        seq,
                        shard,
                        rows: near_partial(&epoch.value.engine, span, x, *k, *fallback),
                        charged_us: latency_us,
                        fallback: *fallback,
                    },
                    ShardTask::Knn { epoch, span, job, pos, k, fallback, .. } => {
                        ShardResult::Knn {
                            seq,
                            shard,
                            job: *job,
                            neighbors: knn_lookup(&epoch.value, span, *pos, *k),
                            charged_us: latency_us,
                            fallback: *fallback,
                        }
                    }
                    ShardTask::Stop => unreachable!("handled above"),
                }
            }))
        };
        let busy = t0.elapsed().as_nanos() as u64;
        counters::add(Counter::ServeShardBusyNs, busy);
        counters::raise(Counter::ServeShardBusyNsMax, busy);
        counters::shard_busy_add(shard, busy);
        hist::record_shard(shard, busy / 1_000);
        let msg = match out {
            Ok(r) => r,
            Err(_) => {
                ShardResult::Panicked { seq, shard, attempt, charged_us: latency_us, knn_job }
            }
        };
        if tx.send(msg).is_err() {
            break; // dispatcher gone: shut down quietly
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::hmat::FullKernelConfig;
    use crate::interact::epoch::{UpdatableKernelEngine, UpdateCfg};
    use crate::csb::kernel::KernelKind;

    fn engine() -> UpdatableKernelEngine {
        let ds = SynthSpec::blobs(300, 3, 4, 19).generate();
        let cfg = UpdateCfg {
            leaf_cap: 8,
            block_cap: 32,
            build_threads: 1,
            threads: 1,
            kernel: KernelKind::Scalar,
            ..UpdateCfg::default()
        };
        UpdatableKernelEngine::build(ds, cfg, FullKernelConfig::new(0.8))
    }

    #[test]
    fn sharded_near_plus_far_matches_engine_spmm() {
        let upd = engine();
        for shards in [1usize, 3, 7] {
            let (e, spans) = upd.acquire_sharded(shards);
            let eng = &e.value.engine;
            let n = eng.n();
            let k = 3;
            let x: Vec<f32> = (0..n * k).map(|i| (i % 13) as f32 * 0.1 - 0.6).collect();
            let mut merged = vec![0.0f32; n * k];
            for sp in &spans {
                let part = near_partial(eng, sp, &x, k, false);
                merged[sp.row_lo * k..sp.row_hi * k].copy_from_slice(&part);
            }
            eng.far_apply_acc(&x, k, &mut merged);
            let mut want = vec![0.0f32; n * k];
            eng.gauss_apply_multi(&x, k, &mut want);
            assert!(
                merged.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "sharded near + coordinator far must be bit-identical (shards={shards})"
            );
            // The scalar fallback is bit-identical too when the engine's
            // own dispatch is already scalar (the test engine is).
            let sp = &spans[0];
            let a = near_partial(eng, sp, &x, k, false);
            let b = near_partial(eng, sp, &x, k, true);
            assert!(a.iter().zip(&b).all(|(p, q)| p.to_bits() == q.to_bits()));
        }
    }

    #[test]
    fn knn_lookup_ranks_near_candidates_by_distance() {
        let upd = engine();
        let (e, spans) = upd.acquire_sharded(2);
        let ep = &e.value;
        let n = ep.engine.n();
        for orig in [0usize, n / 2, n - 1] {
            let pos = ep.tree.pos[orig];
            let span = spans
                .iter()
                .find(|s| s.row_lo <= pos && pos < s.row_hi)
                .expect("spans partition rows");
            let got = knn_lookup(ep, span, pos, 5);
            assert!(!got.is_empty(), "near field always has in-leaf neighbors");
            assert!(got.len() <= 5);
            // Descending weight, self excluded, ids in range.
            for w in got.windows(2) {
                assert!(w[0].1 >= w[1].1);
            }
            let dist2 = |a: usize, b: usize| -> f32 {
                ep.ds.row(a).iter().zip(ep.ds.row(b)).map(|(x, y)| (x - y) * (x - y)).sum()
            };
            let mut prev = -1.0f32;
            for &(id, _) in &got {
                assert_ne!(id as usize, orig, "self must be excluded");
                assert!((id as usize) < n);
                let dd = dist2(orig, id as usize);
                // monotone up to f32 weight rounding (equal rounded
                // weights tie-break by id, not by distance)
                assert!(dd >= prev - 1e-3 * prev.abs().max(1.0), "weights must rank by distance");
                prev = prev.max(dd);
            }
        }
    }
}
