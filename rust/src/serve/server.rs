//! The serving daemon: admission gate → dispatcher → shard workers.
//!
//! One [`Server`] owns an [`UpdatableKernelEngine`] (built once) and a
//! fixed set of shard worker threads.  The dispatcher coalesces admitted
//! requests into slates, acquires **one epoch snapshot per slate**
//! (`acquire_sharded`), fans the near-field work to every shard, merges
//! the disjoint row partials, and applies the far field once on the
//! merged buffer — so a slate's answers are epoch-consistent and
//! bit-identical across shard counts, and a mid-stream epoch update only
//! affects slates dispatched after its publish.
//!
//! Failure ladder per shard task (the degradation ladder):
//! 1. contained panic → retry with exponential backoff against the
//!    *same* slate epoch (restart-from-snapshot re-derives the worker's
//!    map via [`UpdatableKernelEngine::restart_shard`]);
//! 2. retries exhausted → one final attempt with the scalar-kernel
//!    fallback; a shard with `poison_after` contained panics in the
//!    current epoch is poisoned — all its tasks run the fallback (and
//!    responses are flagged `degraded`) until the next epoch heals it;
//! 3. fallback also fails → the slate's requests are shed with
//!    [`RejectReason::ShardFailed`] — the daemon itself never dies.
//!
//! Deadlines: each request carries a µs budget.  Injected shard latency
//! and retry backoff are charged against it (virtually unless
//! `real_time`), budgets propagate into the fan-out (a shard skips work
//! no request can still use), and a blown budget sheds the request with
//! a typed reason instead of blocking the slate.

use crate::interact::epoch::{Epoch, KernelEpoch, ShardSpan, UpdatableKernelEngine};
use crate::obs::flight::{self, Kind};
use crate::obs::hist::{self, Stage};
use crate::obs::{counters, trace, Counter};
use crate::serve::admission::{screen, Gate, Job};
use crate::serve::faults::{FaultPlan, FaultState};
use crate::serve::shard::{worker_loop, ShardResult, ShardTask};
use crate::serve::wire::{Payload, Query, RejectReason, Request, Response, ServeConfig};
use crate::tree::update::UpdateBatch;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Trace-track (worker-slot) layout for the serve tier: the dispatcher
/// records on slot 31, shard workers on `32 + shard` (see
/// `crate::serve::shard::shard_track`) — one Chrome-trace track per
/// shard, flow events tying a request's stages across them.
pub(crate) const DISPATCH_TRACK: usize = 31;

/// Per-worker span-slab capacity reserved for the serve tracks (smaller
/// than the engine's build/apply slabs; `install` is monotonic).
const SERVE_SPAN_CAP: usize = 1 << 12;

/// Flight-recorder `seq` used for requests shed before an id was
/// assigned (admission screening).
const NO_REQ_ID: u64 = u64::MAX;

/// Per-daemon counters (atomic, exact): the instance-local mirror of the
/// global `serve.*` observability counters, so tests can assert exact
/// values even when other tests touch the global registry concurrently.
#[derive(Default)]
pub struct ServeStats {
    pub admitted: AtomicU64,
    pub responded_ok: AtomicU64,
    pub shed_queue_full: AtomicU64,
    pub shed_malformed: AtomicU64,
    pub shed_oversized: AtomicU64,
    pub shed_bad_point: AtomicU64,
    pub shed_deadline: AtomicU64,
    pub shed_shard_failed: AtomicU64,
    pub shed_shutdown: AtomicU64,
    pub retried: AtomicU64,
    pub panics_contained: AtomicU64,
    pub degraded_responses: AtomicU64,
    pub epoch_switches: AtomicU64,
}

/// Plain-value copy of [`ServeStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub admitted: u64,
    pub responded_ok: u64,
    pub shed_queue_full: u64,
    pub shed_malformed: u64,
    pub shed_oversized: u64,
    pub shed_bad_point: u64,
    pub shed_deadline: u64,
    pub shed_shard_failed: u64,
    pub shed_shutdown: u64,
    pub retried: u64,
    pub panics_contained: u64,
    pub degraded_responses: u64,
    pub epoch_switches: u64,
}

impl StatsSnapshot {
    /// Requests shed for any reason (admission + dispatch side).
    pub fn shed_total(&self) -> u64 {
        self.shed_queue_full
            + self.shed_malformed
            + self.shed_oversized
            + self.shed_bad_point
            + self.shed_deadline
            + self.shed_shard_failed
            + self.shed_shutdown
    }
}

impl ServeStats {
    pub fn snapshot(&self) -> StatsSnapshot {
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed);
        StatsSnapshot {
            admitted: g(&self.admitted),
            responded_ok: g(&self.responded_ok),
            shed_queue_full: g(&self.shed_queue_full),
            shed_malformed: g(&self.shed_malformed),
            shed_oversized: g(&self.shed_oversized),
            shed_bad_point: g(&self.shed_bad_point),
            shed_deadline: g(&self.shed_deadline),
            shed_shard_failed: g(&self.shed_shard_failed),
            shed_shutdown: g(&self.shed_shutdown),
            retried: g(&self.retried),
            panics_contained: g(&self.panics_contained),
            degraded_responses: g(&self.degraded_responses),
            epoch_switches: g(&self.epoch_switches),
        }
    }

    /// Record a shed with its typed reason — instance counter, the
    /// matching global `serve.*` counters, and one flight-recorder
    /// `Shed` event, all at the same point (so dump counts match the
    /// instance stats exactly).  `id` is the request id, [`NO_REQ_ID`]
    /// when the request was shed before one was assigned.  Deadline
    /// sheds additionally auto-dump the flight recorder, but that is the
    /// dispatcher's job, *at most once per slate*, after every shed
    /// event of the slate has been recorded — dumping per response here
    /// would rewrite the full ring B times for a B-request slate, piling
    /// work onto the serve path exactly when it is already overloaded.
    fn note_shed(&self, id: u64, reason: &RejectReason) {
        counters::add(Counter::ServeShed, 1);
        flight::record(Kind::Shed, -1, id, reason.flight_code());
        let cell = match reason {
            RejectReason::QueueFull { .. } => &self.shed_queue_full,
            RejectReason::Malformed(_) => &self.shed_malformed,
            RejectReason::Oversized { .. } => &self.shed_oversized,
            RejectReason::BadPoint { .. } => &self.shed_bad_point,
            RejectReason::DeadlineExceeded { .. } => {
                counters::add(Counter::ServeDeadlineMissed, 1);
                &self.shed_deadline
            }
            RejectReason::ShardFailed { .. } => &self.shed_shard_failed,
            RejectReason::ShuttingDown => &self.shed_shutdown,
        };
        cell.fetch_add(1, Ordering::Relaxed);
    }
}

/// Handle to one in-flight request.
pub struct Pending {
    rx: Receiver<Response>,
}

impl Pending {
    /// Block until the response arrives.  `None` only if the daemon
    /// dropped the channel without responding — which the fault tests
    /// treat as a lost request (it must never happen).
    pub fn wait(self) -> Option<Response> {
        self.rx.recv().ok()
    }

    /// Bounded wait — the "no request hangs" probe.
    pub fn wait_timeout(self, d: Duration) -> Result<Response, RecvTimeoutError> {
        self.rx.recv_timeout(d)
    }
}

/// The daemon handle.  Dropping without [`Server::shutdown`] also shuts
/// down cleanly (channel teardown), but `shutdown` returns final stats.
pub struct Server {
    engine: Arc<UpdatableKernelEngine>,
    cfg: ServeConfig,
    gate: Option<Gate>,
    stats: Arc<ServeStats>,
    next_id: AtomicU64,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Build the worker topology over an already-built engine and start
    /// serving.  `plan` arms the deterministic fault script (empty plan =
    /// fault-free).
    pub fn start(
        engine: Arc<UpdatableKernelEngine>,
        cfg: ServeConfig,
        plan: FaultPlan,
    ) -> Server {
        crate::serve::faults::quiet_injected_panics();
        // Reserve the dispatcher + shard trace tracks (monotonic; no-op
        // when already reserved) and publish the shard count for the
        // serve.shard_imbalance derived metric.
        crate::obs::install(DISPATCH_TRACK + 1 + cfg.shards.clamp(1, 32), SERVE_SPAN_CAP);
        counters::raise(Counter::ServeShardWorkers, cfg.shards.max(1) as u64);
        let faults = Arc::new(FaultState::arm(plan));
        let stats = Arc::new(ServeStats::default());
        let (gate, jobs_rx) = Gate::new(cfg.queue_cap);
        let (results_tx, results_rx) = channel();
        let mut task_txs = Vec::with_capacity(cfg.shards.max(1));
        let mut workers = Vec::with_capacity(cfg.shards.max(1));
        for shard in 0..cfg.shards.max(1) {
            let (tx, rx) = channel();
            task_txs.push(tx);
            let rtx = results_tx.clone();
            let f = faults.clone();
            let rt = cfg.real_time;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("nni-serve-shard-{shard}"))
                    .spawn(move || worker_loop(shard, rx, rtx, f, rt))
                    .expect("serve: spawn shard worker"),
            );
        }
        drop(results_tx); // dispatcher detects full worker loss as disconnect
        let d = Dispatcher {
            engine: engine.clone(),
            cfg,
            faults,
            stats: stats.clone(),
            task_txs,
            workers,
            results_rx,
        };
        let dispatcher = std::thread::Builder::new()
            .name("nni-serve-dispatch".into())
            .spawn(move || d.run(jobs_rx))
            .expect("serve: spawn dispatcher");
        Server {
            engine,
            cfg,
            gate: Some(gate),
            stats,
            next_id: AtomicU64::new(0),
            dispatcher: Some(dispatcher),
        }
    }

    /// Submit with the daemon's default budget.
    pub fn submit(&self, query: Query) -> Result<Pending, RejectReason> {
        self.submit_with_budget(query, self.cfg.default_budget_us)
    }

    /// Admission path: screen (shape/size against the current epoch),
    /// then the bounded queue — both non-blocking, both shed typed.
    pub fn submit_with_budget(
        &self,
        query: Query,
        budget_us: u64,
    ) -> Result<Pending, RejectReason> {
        let n = self.engine.acquire().value.engine.n();
        if let Err(reason) = screen(&query, n, self.cfg.oversize_factor) {
            self.stats.note_shed(NO_REQ_ID, &reason);
            return Err(reason);
        }
        let gate = match &self.gate {
            Some(g) => g,
            None => {
                let reason = RejectReason::ShuttingDown;
                self.stats.note_shed(NO_REQ_ID, &reason);
                return Err(reason);
            }
        };
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = channel();
        let job = Job {
            req: Request { id, query, budget_us },
            reply,
            submitted: Instant::now(),
            submitted_us: trace::now_us(),
        };
        match gate.try_admit(job) {
            Ok(()) => {
                self.stats.admitted.fetch_add(1, Ordering::Relaxed);
                counters::add(Counter::ServeAdmitted, 1);
                flight::record(Kind::Admit, -1, id, 0);
                Ok(Pending { rx })
            }
            Err((_job, reason)) => {
                self.stats.note_shed(id, &reason);
                Err(reason)
            }
        }
    }

    /// Publish a delete/insert batch as a new epoch (mid-stream updates:
    /// in-flight slates keep their snapshot).  Returns the new version.
    pub fn update(&self, batch: &UpdateBatch) -> u64 {
        self.engine.update(batch).version
    }

    /// Live stats (exact, instance-local).
    pub fn stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }

    /// Current epoch version.
    pub fn epoch_version(&self) -> u64 {
        self.engine.version()
    }

    /// `(n, d)` of the current epoch — what the load generator sizes
    /// queries against.
    pub fn shape(&self) -> (usize, usize) {
        let e = self.engine.acquire();
        (e.value.engine.n(), e.value.ds.d())
    }

    /// The daemon's configuration (by value; it is `Copy`).
    pub fn config(&self) -> ServeConfig {
        self.cfg
    }

    /// Drain, stop the workers, and return final stats.
    pub fn shutdown(mut self) -> StatsSnapshot {
        self.gate = None; // close admission; dispatcher drains then exits
        if let Some(h) = self.dispatcher.take() {
            h.join().expect("serve: dispatcher thread must exit cleanly");
        }
        self.stats.snapshot()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.gate = None;
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

/// Dispatcher-owned state (runs on its own thread).
struct Dispatcher {
    engine: Arc<UpdatableKernelEngine>,
    cfg: ServeConfig,
    faults: Arc<FaultState>,
    stats: Arc<ServeStats>,
    task_txs: Vec<Sender<ShardTask>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    results_rx: Receiver<ShardResult>,
}

/// Failure outcome of collecting one fanned-out sub-slate (`None` in the
/// collect loop means every shard reported a usable partial).
enum Collect {
    DeadlineSkip { latency_us: u64 },
    Failed { shard: usize, attempts: u32 },
}

/// Attribute a deadline miss to the stage that ate the largest share of
/// the budget: bumps exactly one `deadline.miss.*` counter.  The stage
/// shares are the admission wait, the shard compute charge (virtual
/// under `real_time: false`), the far apply, and the job's own merge
/// slice (the delta since the previous job's delivery, so slate
/// position adds no systematic skew) — an attribution heuristic, not an
/// exact decomposition, since the charge mixes injected latency and
/// backoff.
fn attribute_miss(wait_us: u64, compute_us: u64, far_us: u64, merge_us: u64) {
    let mut best = Counter::DeadlineMissAdmission;
    let mut top = wait_us;
    for (c, v) in [
        (Counter::DeadlineMissCompute, compute_us),
        (Counter::DeadlineMissFar, far_us),
        (Counter::DeadlineMissMerge, merge_us),
    ] {
        if v > top {
            best = c;
            top = v;
        }
    }
    counters::add(best, 1);
}

impl Dispatcher {
    fn run(mut self, jobs: Receiver<Job>) {
        trace::set_worker(DISPATCH_TRACK);
        let shards = self.task_txs.len();
        let mut seq = 0u64;
        let mut last_version: Option<u64> = None;
        // Contained panics per shard within the current epoch; reaching
        // `poison_after` poisons the shard (scalar fallback) until the
        // next epoch heals it.
        let mut contained = vec![0u32; shards];
        let mut poisoned = vec![false; shards];
        while let Ok(first) = jobs.recv() {
            let t_coalesce0 = trace::now_us();
            let mut slate = vec![first];
            while slate.len() < self.cfg.batch.max(1) {
                match jobs.try_recv() {
                    Ok(j) => slate.push(j),
                    Err(_) => break,
                }
            }
            let t_coalesce1 = trace::now_us();
            let first_id = slate[0].req.id;
            hist::record(Stage::SlateCoalesce, t_coalesce1.saturating_sub(t_coalesce0));
            trace::record_closed("serve.slate", t_coalesce0, t_coalesce1, first_id + 1);
            flight::record(Kind::Slate, -1, first_id, slate.len() as u64);
            let (epoch, spans) = self.engine.acquire_sharded(shards);
            if last_version != Some(epoch.version) {
                if last_version.is_some() {
                    self.stats.epoch_switches.fetch_add(1, Ordering::Relaxed);
                    counters::add(Counter::ServeEpochSwitches, 1);
                    // heal: a new epoch rebuilt the crashed state
                    contained.fill(0);
                    poisoned.fill(false);
                }
                last_version = Some(epoch.version);
            }
            self.process_slate(seq, slate, &epoch, &spans, &mut contained, &mut poisoned);
            seq += 1;
        }
        for tx in &self.task_txs {
            let _ = tx.send(ShardTask::Stop);
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }

    fn respond(&self, job: &Job, epoch: u64, result: Result<Payload, RejectReason>, degraded: bool, retries: u32, elapsed_us: u64) {
        if let Err(reason) = &result {
            self.stats.note_shed(job.req.id, reason);
        } else {
            self.stats.responded_ok.fetch_add(1, Ordering::Relaxed);
            hist::record(Stage::EndToEnd, elapsed_us);
            if degraded {
                self.stats.degraded_responses.fetch_add(1, Ordering::Relaxed);
                counters::add(Counter::ServeDegraded, 1);
            }
        }
        // A dropped receiver just means the client stopped listening —
        // the response was still produced, nothing is lost server-side.
        let _ = job.reply.send(Response {
            id: job.req.id,
            epoch,
            result,
            degraded,
            retries,
            elapsed_us,
        });
    }

    /// Handle one contained panic inside a collect loop: count, maybe
    /// poison, and either re-dispatch (retry → fallback) or give up.
    /// Returns the follow-up task to send, or `None` when the ladder is
    /// exhausted.
    #[allow(clippy::too_many_arguments)]
    fn retry_ladder(
        &self,
        seq: u64,
        shard: usize,
        attempt: u32,
        contained: &mut [u32],
        poisoned: &mut [bool],
        charge: &mut u64,
        rebuild: impl Fn(u32, bool) -> ShardTask,
    ) -> Option<ShardTask> {
        self.stats.panics_contained.fetch_add(1, Ordering::Relaxed);
        counters::add(Counter::ServePanicsContained, 1);
        flight::record(Kind::Panic, shard as i64, seq, attempt as u64);
        contained[shard] += 1;
        if contained[shard] >= self.cfg.poison_after && !poisoned[shard] {
            poisoned[shard] = true;
            flight::record(Kind::Poison, shard as i64, seq, contained[shard] as u64);
            flight::trigger_dump("poison");
        } else {
            flight::trigger_dump("panic");
        }
        // max_retries plain attempts, then one scalar-fallback rescue
        if attempt > self.cfg.max_retries {
            return None;
        }
        let backoff = self.cfg.retry_base_us << attempt.min(16);
        *charge += backoff;
        if self.cfg.real_time {
            std::thread::sleep(Duration::from_micros(backoff));
        }
        self.stats.retried.fetch_add(1, Ordering::Relaxed);
        counters::add(Counter::ServeRetried, 1);
        // Restart-from-snapshot: re-derive the worker's map under the
        // *current* epoch (counts `serve.shard_restarts`).  The retry
        // task itself keeps the slate's epoch handle — the slate must
        // stay epoch-consistent for bit-identical merges; the restarted
        // state serves the *next* slate.
        let _ = self.engine.restart_shard(self.task_txs.len(), shard);
        let fallback = attempt >= self.cfg.max_retries || poisoned[shard];
        Some(rebuild(attempt + 1, fallback))
    }

    #[allow(clippy::too_many_arguments)]
    fn process_slate(
        &self,
        seq: u64,
        slate: Vec<Job>,
        epoch: &Arc<Epoch<KernelEpoch>>,
        spans: &[ShardSpan],
        contained: &mut [u32],
        poisoned: &mut [bool],
    ) {
        let n = epoch.value.engine.n();
        let version = epoch.version;
        // Pickup: the admission wait of every request in the slate ends
        // here.  Record it per request (histogram + retroactive
        // "serve.admit" span on the dispatch track, flow-tagged).
        let picked_us = trace::now_us();
        for job in &slate {
            let wait = picked_us.saturating_sub(job.submitted_us);
            hist::record(Stage::AdmissionWait, wait);
            trace::record_closed("serve.admit", job.submitted_us, picked_us, job.req.id + 1);
        }
        // Re-screen against the slate's epoch: an update published after
        // admission can change n, and a stale-shaped query must shed
        // typed instead of panicking deep in the engine.
        let mut apply_jobs: Vec<Job> = Vec::new();
        let mut knn_jobs: Vec<Job> = Vec::new();
        for job in slate {
            match screen(&job.req.query, n, self.cfg.oversize_factor) {
                Err(reason) => self.respond(&job, version, Err(reason), false, 0, 0),
                Ok(()) => match &job.req.query {
                    Query::Knn { .. } => knn_jobs.push(job),
                    _ => apply_jobs.push(job),
                },
            }
        }

        if !apply_jobs.is_empty() {
            self.apply_slate(seq, picked_us, &apply_jobs, epoch, spans, contained, poisoned);
        }
        for (j, job) in knn_jobs.iter().enumerate() {
            self.knn_one(seq, picked_us, j, job, epoch, spans, contained, poisoned);
        }
    }

    /// Multi-RHS apply sub-slate: fan the near field to every shard,
    /// merge, far-field once, de-interleave per request.
    #[allow(clippy::too_many_arguments)]
    fn apply_slate(
        &self,
        seq: u64,
        picked_us: u64,
        jobs: &[Job],
        epoch: &Arc<Epoch<KernelEpoch>>,
        spans: &[ShardSpan],
        contained: &mut [u32],
        poisoned: &mut [bool],
    ) {
        let eng = &epoch.value.engine;
        let n = eng.n();
        let k = jobs.len();
        let version = epoch.version;
        // Tree-ordered, interleaved RHS: column j of row p is request
        // j's charge for external point perm[p].
        let mut x = vec![0.0f32; n * k];
        for p in 0..n {
            let o = epoch.value.tree.perm[p];
            for (j, job) in jobs.iter().enumerate() {
                x[p * k + j] = job.req.query.charges().expect("screened apply query")[o];
            }
        }
        let x = Arc::new(x);
        let slate_budget = jobs.iter().map(|j| j.req.budget_us).max().unwrap_or(0);
        // Flow id of the sub-slate's shard spans: the slate's first
        // request (the same anchor serve.far/serve.merge use).
        let flow = jobs[0].req.id + 1;
        for (s, tx) in self.task_txs.iter().enumerate() {
            let task = ShardTask::Apply {
                seq,
                epoch: epoch.clone(),
                span: spans[s].clone(),
                x: x.clone(),
                k,
                budget_us: slate_budget,
                attempt: 0,
                fallback: poisoned[s],
                flow,
            };
            tx.send(task).expect("serve: shard task channel closed mid-slate");
        }
        let mut merged = vec![0.0f32; n * k];
        let mut outstanding = self.task_txs.len();
        let mut charge = vec![0u64; self.task_txs.len()];
        let mut retries = 0u32;
        let mut degraded = false;
        let mut outcome: Option<Collect> = None;
        while outstanding > 0 {
            let msg = self
                .results_rx
                .recv()
                .expect("serve: results channel closed with tasks outstanding");
            match msg {
                ShardResult::Near { seq: s, shard, rows, charged_us, fallback } => {
                    debug_assert_eq!(s, seq);
                    let sp = &spans[shard];
                    merged[sp.row_lo * k..sp.row_hi * k].copy_from_slice(&rows);
                    charge[shard] += charged_us;
                    degraded |= fallback;
                    outstanding -= 1;
                }
                ShardResult::Panicked { shard, attempt, charged_us, .. } => {
                    charge[shard] += charged_us;
                    let ep = epoch.clone();
                    let xs = x.clone();
                    let span = spans[shard].clone();
                    match self.retry_ladder(
                        seq,
                        shard,
                        attempt,
                        contained,
                        poisoned,
                        &mut charge[shard],
                        move |attempt, fallback| ShardTask::Apply {
                            seq,
                            epoch: ep.clone(),
                            span: span.clone(),
                            x: xs.clone(),
                            k,
                            budget_us: slate_budget,
                            attempt,
                            fallback,
                            flow,
                        },
                    ) {
                        Some(task) => {
                            retries += 1;
                            self.task_txs[shard]
                                .send(task)
                                .expect("serve: shard task channel closed mid-retry");
                        }
                        None => {
                            outcome = Some(Collect::Failed { shard, attempts: attempt + 1 });
                            outstanding -= 1;
                        }
                    }
                }
                ShardResult::DeadlineSkip { latency_us, shard, .. } => {
                    charge[shard] += latency_us;
                    if !matches!(outcome, Some(Collect::Failed { .. })) {
                        outcome = Some(Collect::DeadlineSkip { latency_us });
                    }
                    outstanding -= 1;
                }
                ShardResult::Knn { .. } => {
                    unreachable!("knn results are collected by knn_one, one slate at a time")
                }
            }
        }
        match outcome {
            Some(Collect::Failed { shard, attempts }) => {
                for job in jobs {
                    self.respond(
                        job,
                        version,
                        Err(RejectReason::ShardFailed { shard, attempts }),
                        false,
                        retries,
                        charge.iter().copied().max().unwrap_or(0),
                    );
                }
            }
            Some(Collect::DeadlineSkip { latency_us }) => {
                // The skipping shard saw latency >= the slate's max
                // budget, so every request here is past its deadline —
                // the compute stage ate the whole budget.
                for job in jobs {
                    counters::add(Counter::DeadlineMissCompute, 1);
                    self.respond(
                        job,
                        version,
                        Err(RejectReason::DeadlineExceeded {
                            budget_us: job.req.budget_us,
                            elapsed_us: latency_us,
                        }),
                        false,
                        retries,
                        latency_us,
                    );
                }
                // One dump for the whole slate, after every shed event
                // above is in the ring.
                flight::trigger_dump("deadline_shed");
            }
            _ => {
                let t_far0 = trace::now_us();
                eng.far_apply_acc(&x, k, &mut merged);
                let t_far1 = trace::now_us();
                let far_us = t_far1.saturating_sub(t_far0);
                hist::record(Stage::FarApply, far_us);
                trace::record_closed("serve.far", t_far0, t_far1, jobs[0].req.id + 1);
                let virtual_us = charge.iter().copied().max().unwrap_or(0);
                let t_merge0 = trace::now_us();
                // Per-job merge charge: the delta since the previous
                // job's delivery, so a job late in the slate is not
                // charged the earlier jobs' de-interleave/send time.
                let mut t_prev = t_merge0;
                let mut deadline_shed = false;
                for (j, job) in jobs.iter().enumerate() {
                    let elapsed_us = if self.cfg.real_time {
                        job.submitted.elapsed().as_micros() as u64
                    } else {
                        virtual_us
                    };
                    if elapsed_us > job.req.budget_us {
                        attribute_miss(
                            picked_us.saturating_sub(job.submitted_us),
                            virtual_us,
                            far_us,
                            trace::now_us().saturating_sub(t_prev),
                        );
                        deadline_shed = true;
                        self.respond(
                            job,
                            version,
                            Err(RejectReason::DeadlineExceeded {
                                budget_us: job.req.budget_us,
                                elapsed_us,
                            }),
                            false,
                            retries,
                            elapsed_us,
                        );
                        t_prev = trace::now_us();
                        continue;
                    }
                    let pos = &epoch.value.tree.pos;
                    let mut y = vec![0.0f32; n];
                    for (i, yi) in y.iter_mut().enumerate() {
                        *yi = merged[pos[i] * k + j];
                    }
                    self.respond(
                        job,
                        version,
                        Ok(Payload::Potentials(y)),
                        degraded,
                        retries,
                        elapsed_us,
                    );
                    t_prev = trace::now_us();
                }
                let t_merge1 = trace::now_us();
                hist::record(Stage::Merge, t_merge1.saturating_sub(t_merge0));
                trace::record_closed("serve.merge", t_merge0, t_merge1, jobs[0].req.id + 1);
                // At most one auto-dump per slate, taken after the merge
                // span closes so the dump's cost is not charged to it.
                if deadline_shed {
                    flight::trigger_dump("deadline_shed");
                }
            }
        }
    }

    /// One kNN request: routed to the single shard owning the point's
    /// tree position, same retry/fallback/deadline ladder.
    #[allow(clippy::too_many_arguments)]
    fn knn_one(
        &self,
        seq: u64,
        picked_us: u64,
        job_idx: usize,
        job: &Job,
        epoch: &Arc<Epoch<KernelEpoch>>,
        spans: &[ShardSpan],
        contained: &mut [u32],
        poisoned: &mut [bool],
    ) {
        let version = epoch.version;
        let (point, kk) = match &job.req.query {
            Query::Knn { point, k } => (*point as usize, *k),
            _ => unreachable!("knn_one only receives knn jobs"),
        };
        let pos = epoch.value.tree.pos[point];
        let shard = match spans.iter().position(|s| s.row_lo <= pos && pos < s.row_hi) {
            Some(s) => s,
            None => {
                // spans partition [0, n): unreachable, but shed typed
                // rather than panic if the invariant ever breaks.
                let reason = RejectReason::BadPoint { point: point as u32, n: epoch.value.engine.n() };
                self.respond(job, version, Err(reason), false, 0, 0);
                return;
            }
        };
        let mk = |attempt: u32, fallback: bool| ShardTask::Knn {
            seq,
            epoch: epoch.clone(),
            span: spans[shard].clone(),
            job: job_idx,
            pos,
            k: kk,
            budget_us: job.req.budget_us,
            attempt,
            fallback,
            flow: job.req.id + 1,
        };
        self.task_txs[shard]
            .send(mk(0, poisoned[shard]))
            .expect("serve: shard task channel closed mid-knn");
        let mut charge_us = 0u64;
        let mut retries = 0u32;
        loop {
            let msg = self
                .results_rx
                .recv()
                .expect("serve: results channel closed with a knn task outstanding");
            match msg {
                ShardResult::Knn { neighbors, charged_us, fallback, .. } => {
                    charge_us += charged_us;
                    let elapsed_us = if self.cfg.real_time {
                        job.submitted.elapsed().as_micros() as u64
                    } else {
                        charge_us
                    };
                    if elapsed_us > job.req.budget_us {
                        attribute_miss(
                            picked_us.saturating_sub(job.submitted_us),
                            charge_us,
                            0,
                            0,
                        );
                        self.respond(
                            job,
                            version,
                            Err(RejectReason::DeadlineExceeded {
                                budget_us: job.req.budget_us,
                                elapsed_us,
                            }),
                            false,
                            retries,
                            elapsed_us,
                        );
                        // knn routes one request per task, so this is
                        // the same at-most-one-dump-per-slate policy.
                        flight::trigger_dump("deadline_shed");
                    } else {
                        self.respond(
                            job,
                            version,
                            Ok(Payload::Knn(neighbors)),
                            fallback,
                            retries,
                            elapsed_us,
                        );
                    }
                    return;
                }
                ShardResult::Panicked { shard: s, attempt, charged_us, .. } => {
                    charge_us += charged_us;
                    match self.retry_ladder(
                        seq,
                        s,
                        attempt,
                        contained,
                        poisoned,
                        &mut charge_us,
                        mk,
                    ) {
                        Some(task) => {
                            retries += 1;
                            self.task_txs[s]
                                .send(task)
                                .expect("serve: shard task channel closed mid-retry");
                        }
                        None => {
                            self.respond(
                                job,
                                version,
                                Err(RejectReason::ShardFailed { shard: s, attempts: attempt + 1 }),
                                false,
                                retries,
                                charge_us,
                            );
                            return;
                        }
                    }
                }
                ShardResult::DeadlineSkip { latency_us, .. } => {
                    charge_us += latency_us;
                    counters::add(Counter::DeadlineMissCompute, 1);
                    self.respond(
                        job,
                        version,
                        Err(RejectReason::DeadlineExceeded {
                            budget_us: job.req.budget_us,
                            elapsed_us: charge_us,
                        }),
                        false,
                        retries,
                        charge_us,
                    );
                    // one request per knn task → one dump, after the
                    // shed event is in the ring
                    flight::trigger_dump("deadline_shed");
                    return;
                }
                ShardResult::Near { .. } => {
                    unreachable!("apply results are fully collected before knn dispatch")
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::csb::kernel::KernelKind;
    use crate::data::synth::SynthSpec;
    use crate::hmat::FullKernelConfig;
    use crate::interact::epoch::UpdateCfg;
    use crate::serve::shard::knn_lookup;
    use crate::tree::update::UpdateBatch;
    use crate::util::rng::Rng;

    fn test_engine(n: usize, seed: u64) -> Arc<UpdatableKernelEngine> {
        let ds = SynthSpec::blobs(n, 3, 4, seed).generate();
        let cfg = UpdateCfg {
            leaf_cap: 8,
            block_cap: 32,
            build_threads: 1,
            threads: 1,
            kernel: KernelKind::Scalar,
            ..UpdateCfg::default()
        };
        Arc::new(UpdatableKernelEngine::build(ds, cfg, FullKernelConfig::new(0.8)))
    }

    fn test_cfg(shards: usize) -> ServeConfig {
        ServeConfig {
            shards,
            real_time: false,
            ..ServeConfig::default()
        }
    }

    /// Reference: what the engine itself computes for one charge vector,
    /// mapped back to external order.
    fn direct_apply(upd: &UpdatableKernelEngine, q: &[f32]) -> Vec<f32> {
        let e = upd.acquire();
        let n = e.value.engine.n();
        let x: Vec<f32> = (0..n).map(|p| q[e.value.tree.perm[p]]).collect();
        let mut y = vec![0.0f32; n];
        e.value.engine.gauss_apply_multi(&x, 1, &mut y);
        (0..n).map(|i| y[e.value.tree.pos[i]]).collect()
    }

    #[test]
    fn serves_gauss_krr_knn_end_to_end() {
        let upd = test_engine(300, 23);
        let n = upd.acquire().value.engine.n();
        let server = Server::start(upd.clone(), test_cfg(3), FaultPlan::default());
        let mut rng = Rng::new(5);
        let q: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
        let want = direct_apply(&upd, &q);

        let r = server
            .submit(Query::Gauss { charges: q.clone() })
            .expect("admitted")
            .wait()
            .expect("responded");
        assert_eq!(r.epoch, 0);
        assert!(!r.degraded);
        assert_eq!(r.retries, 0);
        match &r.result {
            Ok(Payload::Potentials(y)) => {
                assert!(y.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
            }
            other => panic!("unexpected result: {other:?}"),
        }
        // KRR is the same slate with alpha as charges.
        let r2 = server
            .submit(Query::Krr { alpha: q.clone() })
            .expect("admitted")
            .wait()
            .expect("responded");
        assert!(matches!(r2.result, Ok(Payload::Potentials(_))));

        // kNN matches a direct lookup against the same epoch.
        let (e, spans) = upd.acquire_sharded(3);
        let pos = e.value.tree.pos[7];
        let span = spans.iter().find(|s| s.row_lo <= pos && pos < s.row_hi).unwrap();
        let want_knn = knn_lookup(&e.value, span, pos, 4);
        let r3 = server
            .submit(Query::Knn { point: 7, k: 4 })
            .expect("admitted")
            .wait()
            .expect("responded");
        assert_eq!(r3.result, Ok(Payload::Knn(want_knn)));

        let stats = server.shutdown();
        assert_eq!(stats.admitted, 3);
        assert_eq!(stats.responded_ok, 3);
        assert_eq!(stats.shed_total(), 0);
        assert_eq!(stats.panics_contained, 0);
    }

    #[test]
    fn mid_stream_update_switches_epochs() {
        let upd = test_engine(260, 29);
        let n0 = upd.acquire().value.engine.n();
        let server = Server::start(upd.clone(), test_cfg(2), FaultPlan::default());
        let q = vec![0.25f32; n0];
        let r0 = server
            .submit(Query::Gauss { charges: q })
            .expect("admitted")
            .wait()
            .expect("responded");
        assert_eq!(r0.epoch, 0);
        // Delete two interior points: n changes, a stale-shaped query now
        // sheds typed at screening.
        let v = server.update(&UpdateBatch { deletes: vec![3, 5], inserts: vec![] });
        assert_eq!(v, 1);
        let stale = server.submit(Query::Gauss { charges: vec![0.25f32; n0] });
        assert!(matches!(stale, Err(RejectReason::Malformed(_))));
        let n1 = upd.acquire().value.engine.n();
        let r1 = server
            .submit(Query::Gauss { charges: vec![0.25f32; n1] })
            .expect("admitted")
            .wait()
            .expect("responded");
        assert_eq!(r1.epoch, 1);
        let stats = server.shutdown();
        assert_eq!(stats.epoch_switches, 1);
        assert_eq!(stats.shed_malformed, 1);
        assert_eq!(stats.responded_ok, 2);
    }
}
