//! # nni — Rapid Near-Neighbor Interaction via Hierarchical Clustering
//!
//! A full reproduction of Pitsianis et al., *Rapid Near-Neighbor Interaction
//! of High-dimensional Data via Hierarchical Clustering* (2017): matrix
//! reordering for near-neighbor interaction matrices guided by the
//! *block-sparse with dense blocks* profile principle, the patch-density
//! measure β and its numerical estimate γ, a dual-tree hierarchical ordering
//! algorithm, multi-level compressed sparse block storage, and multi-level
//! (sequential and parallel) interaction computation — plus the paper's two
//! case studies (t-SNE attractive force, mean shift) as first-class
//! applications.
//!
//! ## Architecture (three layers)
//!
//! * **Layer 3 (this crate)** — the coordinator: reordering pipeline,
//!   multi-level storage, block scheduling, applications, CLI.
//! * **Layer 2 (python/compile, build-time only)** — JAX block programs
//!   lowered AOT to HLO text in `artifacts/`.
//! * **Layer 1 (python/compile/kernels)** — Pallas dense cluster-pair
//!   kernels called by Layer 2.
//!
//! The [`runtime`] module loads the artifacts through PJRT (`xla` crate,
//! behind the `pjrt` cargo feature) so the request path never touches
//! Python; default builds ship a stub and run pure Rust.
//!
//! ## kNN backends
//!
//! The paper takes the kNN interaction graph as given; this crate builds
//! it, behind [`knn::KnnBackend`]:
//!
//! * `Exact` — [`knn::exact`], blocked brute force, O(n²·d): ground truth
//!   for figure reproductions and recall oracles.
//! * `Ann(params)` — [`knn::ann`], a randomized PCA-projection forest
//!   seeding NN-descent refinement, near-linear in n: the scaling path for
//!   datasets beyond the paper's 2^17 ceiling (recall@10 ≈ 0.97 on
//!   clustered data at default parameters).
//!
//! The backend threads uniformly through [`order::Pipeline::run_points`],
//! both applications, the `nni` CLI (`--knn exact|ann`), and the
//! `ann_vs_exact` bench.
//!
//! ## Full-kernel mode
//!
//! [`hmat`] lifts the kNN truncation: an η-admissibility partition plus
//! per-block ACA compression turns the discarded far field into low-rank
//! factors, and [`hmat::FullKernelEngine`] fuses them with the near-field
//! [`interact::engine::Engine`] into one operator serving the **full**
//! Gaussian kernel matrix — the substrate of [`apps::krr`] (kernel ridge
//! regression) and the `krr` CLI subcommand.

pub mod util;
pub mod obs;
pub mod par;
pub mod data;
pub mod embed;
pub mod knn;
pub mod sparse;
pub mod tree;
pub mod order;
pub mod profile;
pub mod csb;
pub mod spmv;
pub mod interact;
pub mod hmat;
pub mod runtime;
pub mod coordinator;
pub mod apps;
pub mod bench;
pub mod serve;

/// Convenient re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::csb::hier::HierCsb;
    pub use crate::csb::kernel::KernelKind;
    pub use crate::data::dataset::Dataset;
    pub use crate::data::synth::SynthSpec;
    pub use crate::hmat::{FarFieldMode, FullKernelConfig, FullKernelEngine};
    pub use crate::knn::ann::{knn_graph_ann, AnnParams};
    pub use crate::knn::exact::knn_graph;
    pub use crate::knn::KnnBackend;
    pub use crate::order::{OrderingKind, Pipeline};
    pub use crate::profile::gamma::{gamma_exact, gamma_fast};
    pub use crate::sparse::csr::Csr;
    pub use crate::util::rng::Rng;
}
