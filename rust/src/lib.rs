//! # nni — Rapid Near-Neighbor Interaction via Hierarchical Clustering
//!
//! A full reproduction of Pitsianis et al., *Rapid Near-Neighbor Interaction
//! of High-dimensional Data via Hierarchical Clustering* (2017): matrix
//! reordering for near-neighbor interaction matrices guided by the
//! *block-sparse with dense blocks* profile principle, the patch-density
//! measure β and its numerical estimate γ, a dual-tree hierarchical ordering
//! algorithm, multi-level compressed sparse block storage, and multi-level
//! (sequential and parallel) interaction computation — plus the paper's two
//! case studies (t-SNE attractive force, mean shift) as first-class
//! applications.
//!
//! ## Architecture (three layers)
//!
//! * **Layer 3 (this crate)** — the coordinator: reordering pipeline,
//!   multi-level storage, block scheduling, applications, CLI.
//! * **Layer 2 (python/compile, build-time only)** — JAX block programs
//!   lowered AOT to HLO text in `artifacts/`.
//! * **Layer 1 (python/compile/kernels)** — Pallas dense cluster-pair
//!   kernels called by Layer 2.
//!
//! The [`runtime`] module loads the artifacts through PJRT (`xla` crate) so
//! the request path never touches Python.

pub mod util;
pub mod par;
pub mod data;
pub mod embed;
pub mod knn;
pub mod sparse;
pub mod tree;
pub mod order;
pub mod profile;
pub mod csb;
pub mod spmv;
pub mod interact;
pub mod runtime;
pub mod coordinator;
pub mod apps;
pub mod bench;

/// Convenient re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::csb::hier::HierCsb;
    pub use crate::data::dataset::Dataset;
    pub use crate::data::synth::SynthSpec;
    pub use crate::knn::exact::knn_graph;
    pub use crate::order::{OrderingKind, Pipeline};
    pub use crate::profile::gamma::{gamma_exact, gamma_fast};
    pub use crate::sparse::csr::Csr;
    pub use crate::util::rng::Rng;
}
