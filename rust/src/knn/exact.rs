//! Exact blocked brute-force kNN.
//!
//! The paper takes the kNN graph as given input; we build it exactly so the
//! interaction-matrix profile is unambiguous.  Complexity O(n²·d) with cache
//! blocking and a bounded max-heap per query; parallel over query blocks.
//! For the sizes in the paper's experiments (≤ 2^17 points) this is minutes
//! at worst and is run once per dataset (results can be cached to disk).

use crate::data::dataset::Dataset;
use crate::par::pool::ThreadPool;

/// kNN graph: for each target `i`, `k` source neighbors and distances.
#[derive(Clone, Debug)]
pub struct KnnGraph {
    pub n: usize,
    pub k: usize,
    /// Row-major `n x k` neighbor indices (sorted by ascending distance).
    pub idx: Vec<u32>,
    /// Matching squared distances.
    pub dist2: Vec<f32>,
}

impl KnnGraph {
    #[inline]
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.idx[i * self.k..(i + 1) * self.k]
    }

    #[inline]
    pub fn distances(&self, i: usize) -> &[f32] {
        &self.dist2[i * self.k..(i + 1) * self.k]
    }
}

/// Bounded max-heap of (dist2, idx) keeping the k smallest.
struct KBest {
    k: usize,
    // binary max-heap by dist2
    heap: Vec<(f32, u32)>,
}

impl KBest {
    fn new(k: usize) -> Self {
        KBest {
            k,
            heap: Vec::with_capacity(k),
        }
    }

    #[inline]
    fn bound(&self) -> f32 {
        if self.heap.len() < self.k {
            f32::INFINITY
        } else {
            self.heap[0].0
        }
    }

    #[inline]
    fn push(&mut self, d: f32, i: u32) {
        if self.heap.len() < self.k {
            self.heap.push((d, i));
            // sift up
            let mut c = self.heap.len() - 1;
            while c > 0 {
                let p = (c - 1) / 2;
                if self.heap[p].0 < self.heap[c].0 {
                    self.heap.swap(p, c);
                    c = p;
                } else {
                    break;
                }
            }
        } else if d < self.heap[0].0 {
            self.heap[0] = (d, i);
            // sift down
            let n = self.heap.len();
            let mut p = 0;
            loop {
                let (l, r) = (2 * p + 1, 2 * p + 2);
                let mut m = p;
                if l < n && self.heap[l].0 > self.heap[m].0 {
                    m = l;
                }
                if r < n && self.heap[r].0 > self.heap[m].0 {
                    m = r;
                }
                if m == p {
                    break;
                }
                self.heap.swap(p, m);
                p = m;
            }
        }
    }

    fn into_sorted(mut self) -> Vec<(f32, u32)> {
        self.heap
            .sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        self.heap
    }
}

/// Exact kNN graph of `ds` against itself, excluding self-matches.
///
/// `threads`: worker count (0 → machine default).
pub fn knn_graph(ds: &Dataset, k: usize, threads: usize) -> KnnGraph {
    knn_graph_cross(ds, ds, k, threads, true)
}

/// Exact kNN of `targets` against `sources`.
/// `exclude_same_index`: skip j == i (self) — used for self-graphs.
pub fn knn_graph_cross(
    targets: &Dataset,
    sources: &Dataset,
    k: usize,
    threads: usize,
    exclude_same_index: bool,
) -> KnnGraph {
    assert_eq!(targets.d(), sources.d());
    let n = targets.n();
    let m = sources.n();
    assert!(k >= 1 && k <= m - exclude_same_index as usize, "k out of range");
    let pool = ThreadPool::new_or_default(threads);

    let kidx = std::sync::Mutex::new(vec![0u32; n * k]);
    let kd2 = std::sync::Mutex::new(vec![0.0f32; n * k]);
    // Process queries in blocks; write each block's rows under the lock
    // (contention negligible: one lock per 64 queries).
    const QB: usize = 64;
    let nblocks = n.div_ceil(QB);
    pool.for_each_chunked(nblocks, 1, |b| {
        let lo = b * QB;
        let hi = (lo + QB).min(n);
        let mut rows_idx = vec![0u32; (hi - lo) * k];
        let mut rows_d2 = vec![0.0f32; (hi - lo) * k];
        for i in lo..hi {
            let q = targets.row(i);
            let mut best = KBest::new(k);
            let d = targets.d();
            for j in 0..m {
                if exclude_same_index && j == i {
                    continue;
                }
                let s = sources.row(j);
                // Early-exit distance: abort accumulation past the bound.
                let bound = best.bound();
                let mut acc = 0.0f32;
                let mut t = 0;
                while t + 4 <= d {
                    let a0 = q[t] - s[t];
                    let a1 = q[t + 1] - s[t + 1];
                    let a2 = q[t + 2] - s[t + 2];
                    let a3 = q[t + 3] - s[t + 3];
                    acc += a0 * a0 + a1 * a1 + a2 * a2 + a3 * a3;
                    if acc > bound {
                        break;
                    }
                    t += 4;
                }
                if acc <= bound {
                    while t < d {
                        let a = q[t] - s[t];
                        acc += a * a;
                        t += 1;
                    }
                    best.push(acc, j as u32);
                }
            }
            let sorted = best.into_sorted();
            let off = (i - lo) * k;
            for (slot, (d2v, jj)) in sorted.into_iter().enumerate() {
                rows_idx[off + slot] = jj;
                rows_d2[off + slot] = d2v;
            }
        }
        kidx.lock().unwrap()[lo * k..hi * k].copy_from_slice(&rows_idx);
        kd2.lock().unwrap()[lo * k..hi * k].copy_from_slice(&rows_d2);
    });

    KnnGraph {
        n,
        k,
        idx: kidx.into_inner().unwrap(),
        dist2: kd2.into_inner().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::util::rng::Rng;

    fn brute_reference(ds: &Dataset, i: usize, k: usize) -> Vec<u32> {
        let mut all: Vec<(f32, u32)> = (0..ds.n())
            .filter(|&j| j != i)
            .map(|j| (ds.sqdist(i, j), j as u32))
            .collect();
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        all.truncate(k);
        all.into_iter().map(|(_, j)| j).collect()
    }

    #[test]
    fn matches_naive_reference() {
        let ds = SynthSpec::blobs(120, 5, 3, 11).generate();
        let g = knn_graph(&ds, 7, 2);
        for i in [0usize, 17, 63, 119] {
            let want = brute_reference(&ds, i, 7);
            // Compare as sets with matching distances (ties may reorder).
            let got: Vec<u32> = g.neighbors(i).to_vec();
            let wd: Vec<f32> = want.iter().map(|&j| ds.sqdist(i, j as usize)).collect();
            let gd: Vec<f32> = got.iter().map(|&j| ds.sqdist(i, j as usize)).collect();
            for (a, b) in wd.iter().zip(&gd) {
                assert!((a - b).abs() <= 1e-6 * (1.0 + a.abs()), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn distances_sorted_and_no_self() {
        let ds = SynthSpec::blobs(200, 4, 4, 5).generate();
        let g = knn_graph(&ds, 10, 4);
        for i in 0..ds.n() {
            let dd = g.distances(i);
            for w in dd.windows(2) {
                assert!(w[0] <= w[1]);
            }
            assert!(!g.neighbors(i).contains(&(i as u32)));
        }
    }

    #[test]
    fn parallel_equals_sequential() {
        let ds = SynthSpec::blobs(150, 6, 3, 9).generate();
        let a = knn_graph(&ds, 5, 1);
        let b = knn_graph(&ds, 5, 8);
        assert_eq!(a.idx, b.idx);
    }

    #[test]
    fn cross_knn_nearest_blob_center() {
        // targets = blob centers ± eps must find sources in own blob.
        let src = SynthSpec::blobs(300, 3, 3, 21).generate();
        let mut rng = Rng::new(1);
        let pick: Vec<usize> = (0..20).map(|_| rng.below(300)).collect();
        let tgt = src.select(&pick);
        let g = knn_graph_cross(&tgt, &src, 3, 2, false);
        for (ti, &si) in pick.iter().enumerate() {
            // nearest neighbor of a copied point is itself (distance 0)
            assert_eq!(g.neighbors(ti)[0], si as u32);
            assert_eq!(g.distances(ti)[0], 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "k out of range")]
    fn rejects_k_too_large() {
        let ds = SynthSpec::blobs(10, 2, 2, 1).generate();
        knn_graph(&ds, 10, 1);
    }
}
