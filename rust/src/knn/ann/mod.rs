//! Approximate kNN (`knn::ann`): randomized PCA-projection forest +
//! NN-descent refinement, near-linear in n.
//!
//! The exact backend's O(n²·d) scan is the hardest scaling wall between
//! the paper's 2^17-point experiments and production sizes; this subsystem
//! replaces it with a two-stage construction, both stages parallel over a
//! [`ThreadPool`]:
//!
//! 1. **Forest seeding** ([`forest`]) — project onto the top principal
//!    axes (reusing [`embed::pca`](crate::embed::pca)'s subspace
//!    iteration) and build [`AnnParams::trees`] randomized trees, each
//!    splitting by a median cut along a jittered principal direction.
//!    Points sharing a leaf bucket seed each other's candidate lists.
//! 2. **NN-descent** ([`descent`]) — neighbors-of-neighbors passes over
//!    true full-dimensional distances, double-buffered for thread-count
//!    determinism, stopping early when the update rate drops below
//!    [`AnnParams::delta`].
//!
//! [`recall`] measures recall@k against [`knn::exact`](crate::knn::exact)
//! on query subsamples; with [`AnnParams::default`] the system lands at
//! recall@10 ≈ 0.97 on clustered SIFT-like data (enforced ≥ 0.90 by the
//! `knn_backends` integration test).

pub mod descent;
pub mod forest;
pub mod recall;

use crate::data::dataset::Dataset;
use crate::knn::exact::KnnGraph;
use crate::par::pool::ThreadPool;

/// Tunables of the approximate backend.
#[derive(Clone, Debug, PartialEq)]
pub struct AnnParams {
    /// Number of randomized projection trees.
    pub trees: usize,
    /// Leaf bucket capacity (candidate group size).
    pub leaf_cap: usize,
    /// Projection dimension (top principal axes; clamped to the data dim).
    pub proj_dim: usize,
    /// PCA subspace-iteration count.
    pub pca_iters: usize,
    /// Maximum NN-descent passes.
    pub descent_iters: usize,
    /// Early-termination threshold on the per-pass update rate.
    pub delta: f64,
    /// Distance evaluations per point per pass (0 = auto: 12·k).
    pub max_candidates: usize,
    /// Reverse-neighbor sample cap per point (0 = auto: k).
    pub reverse_cap: usize,
    /// Seed for axis jitter and candidate padding.
    pub seed: u64,
}

impl Default for AnnParams {
    fn default() -> Self {
        AnnParams {
            trees: 8,
            leaf_cap: 64,
            proj_dim: 8,
            pca_iters: 6,
            descent_iters: 10,
            delta: 0.002,
            max_candidates: 0,
            reverse_cap: 0,
            seed: 0xA11CE,
        }
    }
}

/// Insert `(d, j)` into a best-list sorted ascending by `(dist2, idx)` and
/// bounded at `k` entries; no-op when worse than the current kth.
pub(crate) fn insert_best(best: &mut Vec<(f32, u32)>, k: usize, d: f32, j: u32) {
    if best.len() == k {
        let (wd, wj) = best[k - 1];
        if d > wd || (d == wd && j >= wj) {
            return;
        }
    }
    let pos = best.partition_point(|&(bd, bj)| bd < d || (bd == d && bj < j));
    best.insert(pos, (d, j));
    if best.len() > k {
        best.pop();
    }
}

/// Approximate self-kNN graph of `ds` (no self matches), same contract as
/// [`knn::exact::knn_graph`](crate::knn::exact::knn_graph).
///
/// `threads`: worker count (0 → machine default).
pub fn knn_graph_ann(ds: &Dataset, k: usize, params: &AnnParams, threads: usize) -> KnnGraph {
    let n = ds.n();
    assert!(k >= 1 && k <= n - 1, "k out of range");
    let pool = ThreadPool::new_or_default(threads);
    let f = forest::PcaForest::build(ds, params, &pool);
    let seeded = forest::seed_graph(ds, &f, k, params, &pool);
    descent::refine(ds, seeded, params, &pool)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn insert_best_keeps_k_smallest_sorted() {
        let mut best = Vec::new();
        for (d, j) in [(5.0, 1), (1.0, 2), (3.0, 3), (0.5, 4), (3.0, 0)] {
            insert_best(&mut best, 3, d, j);
        }
        assert_eq!(best, vec![(0.5, 4), (1.0, 2), (3.0, 0)]);
        // equal-distance, larger index than the kth: rejected
        insert_best(&mut best, 3, 3.0, 9);
        assert_eq!(best.len(), 3);
        assert_eq!(best[2], (3.0, 0));
    }

    #[test]
    fn deterministic_for_seed() {
        let ds = SynthSpec::blobs(300, 4, 3, 3).generate();
        let p = AnnParams::default();
        let a = knn_graph_ann(&ds, 5, &p, 2);
        let b = knn_graph_ann(&ds, 5, &p, 2);
        assert_eq!(a.idx, b.idx);
        let mut p2 = p.clone();
        p2.seed = 1234;
        let c = knn_graph_ann(&ds, 5, &p2, 2);
        // different forest jitter is allowed to change rows (usually does
        // on at least one point); only require validity
        assert_eq!(c.n, 300);
    }

    #[test]
    #[should_panic(expected = "k out of range")]
    fn rejects_k_too_large() {
        let ds = SynthSpec::blobs(10, 2, 2, 1).generate();
        knn_graph_ann(&ds, 10, &AnnParams::default(), 1);
    }

    #[test]
    fn tiny_inputs_work() {
        let ds = SynthSpec::blobs(4, 2, 1, 2).generate();
        let g = knn_graph_ann(&ds, 3, &AnnParams::default(), 1);
        for i in 0..4 {
            let mut nb = g.neighbors(i).to_vec();
            nb.sort_unstable();
            nb.dedup();
            assert_eq!(nb.len(), 3);
            assert!(!nb.contains(&(i as u32)));
        }
    }
}
