//! Randomized PCA-projection forest — the candidate generator of
//! [`knn::ann`](crate::knn::ann).
//!
//! The data is projected once onto its top principal axes (reusing the
//! blocked subspace iteration of [`embed::pca`](crate::embed::pca); when
//! the ambient dimension is already small the raw coordinates are used, as
//! in [`order::Pipeline`](crate::order::Pipeline)).  Each tree then splits
//! its point set recursively by a **median cut along a jittered principal
//! direction**: the split axis cycles through the dominant principal axes
//! by depth (the same "split where the variance lives" idea as the
//! [`tree::boxtree`](crate::tree::boxtree) orthant splits), and a small
//! random rotation decorrelates the trees so their leaf buckets overlap
//! differently.  Points sharing a leaf bucket become mutual neighbor
//! candidates; the buckets also answer cross-set queries by routing a
//! projected query point down each tree.

use crate::data::dataset::Dataset;
use crate::embed::pca::{pca, Pca};
use crate::knn::ann::{insert_best, AnnParams};
use crate::knn::exact::KnnGraph;
use crate::par::pool::ThreadPool;
use crate::util::rng::Rng;

/// Hard recursion guard.  Median splits halve the set, so depth ≈ log2 n;
/// the guard only binds on duplicate-heavy data where splits degenerate.
const MAX_DEPTH: u32 = 48;

/// One node of a projection tree.
#[derive(Clone, Debug)]
enum Node {
    Split {
        /// Split direction in the projected space (length = proj dim).
        dir: Vec<f32>,
        thr: f32,
        left: u32,
        right: u32,
    },
    Leaf {
        bucket: u32,
    },
}

/// A single randomized projection tree.
#[derive(Clone, Debug)]
pub struct ProjTree {
    nodes: Vec<Node>,
    root: u32,
    /// Leaf buckets of original point indices.
    pub buckets: Vec<Vec<u32>>,
    /// Bucket ordinal containing each build point.
    pub bucket_of: Vec<u32>,
}

impl ProjTree {
    fn build(proj: &[f32], p: usize, n: usize, leaf_cap: usize, rng: &mut Rng) -> ProjTree {
        let mut t = ProjTree {
            nodes: Vec::new(),
            root: 0,
            buckets: Vec::new(),
            bucket_of: vec![0; n],
        };
        let ids: Vec<u32> = (0..n as u32).collect();
        t.root = t.build_rec(proj, p, ids, leaf_cap, 0, rng);
        t
    }

    fn build_rec(
        &mut self,
        proj: &[f32],
        p: usize,
        ids: Vec<u32>,
        leaf_cap: usize,
        depth: u32,
        rng: &mut Rng,
    ) -> u32 {
        if ids.len() <= leaf_cap || depth >= MAX_DEPTH {
            return self.make_leaf(ids);
        }
        // Jittered principal axis: cycle the dominant axes by depth, mix in
        // a small random rotation so trees decorrelate.
        let axis = (depth as usize) % p;
        let mut dir = vec![0.0f32; p];
        dir[axis] = 1.0;
        let jitter = 0.3 / (p as f64).sqrt();
        for v in dir.iter_mut() {
            *v += (jitter * rng.normal()) as f32;
        }
        let mut keyed: Vec<(f32, u32)> = ids
            .iter()
            .map(|&i| {
                let row = &proj[i as usize * p..(i as usize + 1) * p];
                let mut s = 0.0f32;
                for (w, x) in dir.iter().zip(row) {
                    s += w * x;
                }
                (s, i)
            })
            .collect();
        let mid = keyed.len() / 2;
        keyed.select_nth_unstable_by(mid, |a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
        });
        let thr = keyed[mid].0;
        let mut left = Vec::with_capacity(mid);
        let mut right = Vec::with_capacity(keyed.len() - mid);
        for &(key, i) in &keyed {
            if key < thr {
                left.push(i);
            } else {
                right.push(i);
            }
        }
        drop(keyed);
        if left.is_empty() || right.is_empty() {
            // All keys coincide (duplicate-heavy span): cannot separate.
            return self.make_leaf(ids);
        }
        let l = self.build_rec(proj, p, left, leaf_cap, depth + 1, rng);
        let r = self.build_rec(proj, p, right, leaf_cap, depth + 1, rng);
        let id = self.nodes.len() as u32;
        self.nodes.push(Node::Split {
            dir,
            thr,
            left: l,
            right: r,
        });
        id
    }

    fn make_leaf(&mut self, ids: Vec<u32>) -> u32 {
        let bucket = self.buckets.len() as u32;
        for &i in &ids {
            self.bucket_of[i as usize] = bucket;
        }
        self.buckets.push(ids);
        let id = self.nodes.len() as u32;
        self.nodes.push(Node::Leaf { bucket });
        id
    }

    /// Route a projected query point to its leaf bucket's members.
    pub fn route(&self, q: &[f32]) -> &[u32] {
        let mut cur = self.root;
        loop {
            match &self.nodes[cur as usize] {
                Node::Leaf { bucket } => return &self.buckets[*bucket as usize],
                Node::Split {
                    dir,
                    thr,
                    left,
                    right,
                } => {
                    let mut s = 0.0f32;
                    for (w, x) in dir.iter().zip(q) {
                        s += w * x;
                    }
                    cur = if s < *thr { *left } else { *right };
                }
            }
        }
    }
}

/// The forest: shared projection model + `trees` randomized trees.
/// The build-time n×p projection is dropped after construction (only the
/// buckets and split planes are needed afterwards), so the resident cost
/// is O(n) bucket indices, not O(n·p) coordinates.
pub struct PcaForest {
    /// Projection model (None when the raw dimension is already ≤ proj_dim
    /// — the embedding step passes through, as in the ordering pipeline).
    pca: Option<Pca>,
    /// Projected dimension.
    pub p: usize,
    pub trees: Vec<ProjTree>,
}

impl PcaForest {
    /// Build over `ds`; tree construction is parallel over trees.
    pub fn build(ds: &Dataset, params: &AnnParams, pool: &ThreadPool) -> PcaForest {
        let p = params.proj_dim.clamp(1, ds.d());
        let (model, proj) = if ds.d() <= p {
            (None, ds.raw().to_vec())
        } else {
            let pc = pca(ds, p, params.pca_iters.max(1), params.seed);
            let projected = pc.project(ds, p).raw().to_vec();
            (Some(pc), projected)
        };
        let n = ds.n();
        let leaf_cap = params.leaf_cap.max(2);
        let tree_ids: Vec<u64> = (0..params.trees.max(1) as u64).collect();
        let trees: Vec<ProjTree> = pool
            .map(&tree_ids, |&t| {
                let mut rng =
                    Rng::new(params.seed ^ t.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0x51ED);
                Some(ProjTree::build(&proj, p, n, leaf_cap, &mut rng))
            })
            .into_iter()
            .map(|t| t.expect("tree built"))
            .collect();
        PcaForest {
            pca: model,
            p,
            trees,
        }
    }

    /// Project arbitrary same-dimension rows with the forest's embedding.
    pub fn project_dataset(&self, ds: &Dataset) -> Vec<f32> {
        match &self.pca {
            None => {
                assert_eq!(ds.d(), self.p, "dimension mismatch for raw projection");
                ds.raw().to_vec()
            }
            Some(pc) => pc.project(ds, self.p).raw().to_vec(),
        }
    }

    /// Collect the self-candidates of build point `i`: the union of its
    /// leaf buckets across trees, sorted, deduplicated, self removed.
    pub fn self_candidates(&self, i: usize, out: &mut Vec<u32>) {
        out.clear();
        for t in &self.trees {
            out.extend_from_slice(&t.buckets[t.bucket_of[i] as usize]);
        }
        out.sort_unstable();
        out.dedup();
        if let Ok(pos) = out.binary_search(&(i as u32)) {
            out.remove(pos);
        }
    }
}

/// Squared distance between rows of two datasets.
#[inline]
fn sqdist_cross(a: &Dataset, i: usize, b: &Dataset, j: usize) -> f32 {
    let mut s = 0.0f32;
    for (x, y) in a.row(i).iter().zip(b.row(j)) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// Ensure at least `k` distinct candidates, none equal to `exclude`:
/// deterministic pseudo-random probes first, then a linear sweep backstop
/// (only reached on tiny or degenerate inputs).
fn pad_candidates(cand: &mut Vec<u32>, exclude: Option<u32>, m: usize, k: usize, seed: u64) {
    if cand.len() >= k {
        return;
    }
    let mut rng = Rng::new(seed);
    let mut tries = 0usize;
    while cand.len() < k && tries < 4 * k {
        let j = rng.below(m) as u32;
        if Some(j) != exclude && !cand.contains(&j) {
            cand.push(j);
        }
        tries += 1;
    }
    let mut j = 0u32;
    while cand.len() < k && (j as usize) < m {
        if Some(j) != exclude && !cand.contains(&j) {
            cand.push(j);
        }
        j += 1;
    }
}

/// Per-point seed derivation for the padding RNG.
#[inline]
fn pad_seed(seed: u64, i: usize) -> u64 {
    seed ^ (i as u64).wrapping_mul(0x2545_F491_4F6C_DD1D)
}

/// Shared row-filling driver for forest-seeded graphs: per point, `collect`
/// must leave at least `k` distinct valid candidates in its `Vec` argument
/// (clearing it first); `dist` scores one candidate.  The k best per row
/// are written in blocks under a lock, as in `knn::exact` (contention: one
/// lock per 64 points).
fn fill_rows<C, D>(n: usize, k: usize, pool: &ThreadPool, collect: C, dist: D) -> KnnGraph
where
    C: Fn(usize, &mut Vec<u32>) + Sync,
    D: Fn(usize, u32) -> f32 + Sync,
{
    let kidx = std::sync::Mutex::new(vec![0u32; n * k]);
    let kd2 = std::sync::Mutex::new(vec![0.0f32; n * k]);
    const QB: usize = 64;
    let nblocks = n.div_ceil(QB);
    pool.for_each_chunked(nblocks, 1, |b| {
        let lo = b * QB;
        let hi = (lo + QB).min(n);
        let mut rows_idx = vec![0u32; (hi - lo) * k];
        let mut rows_d2 = vec![0.0f32; (hi - lo) * k];
        let mut cand: Vec<u32> = Vec::new();
        for i in lo..hi {
            collect(i, &mut cand);
            let mut best: Vec<(f32, u32)> = Vec::with_capacity(k + 1);
            for &j in &cand {
                insert_best(&mut best, k, dist(i, j), j);
            }
            let off = (i - lo) * k;
            for (slot, &(d, j)) in best.iter().enumerate() {
                rows_idx[off + slot] = j;
                rows_d2[off + slot] = d;
            }
        }
        kidx.lock().unwrap()[lo * k..hi * k].copy_from_slice(&rows_idx);
        kd2.lock().unwrap()[lo * k..hi * k].copy_from_slice(&rows_d2);
    });
    KnnGraph {
        n,
        k,
        idx: kidx.into_inner().unwrap(),
        dist2: kd2.into_inner().unwrap(),
    }
}

/// Initial kNN graph from forest candidates: the k best bucket-mates per
/// point (padded to k on degenerate buckets).
pub fn seed_graph(
    ds: &Dataset,
    forest: &PcaForest,
    k: usize,
    params: &AnnParams,
    pool: &ThreadPool,
) -> KnnGraph {
    let n = ds.n();
    fill_rows(
        n,
        k,
        pool,
        |i, cand| {
            forest.self_candidates(i, cand);
            pad_candidates(cand, Some(i as u32), n, k, pad_seed(params.seed, i));
        },
        |i, j| ds.sqdist(i, j as usize),
    )
}

/// Approximate cross kNN of `targets` against a **prebuilt** source forest:
/// each target routes down every tree and the union of the reached buckets
/// is its candidate set.  No descent pass — the migrating-target use case
/// (mean shift) refreshes the profile every few iterations, so bucket
/// quality is what matters, and Gaussian weights make distant misses
/// negligible.  The forest depends only on the sources, so callers with
/// stationary sources (mean shift) build it once and reuse it here.
pub fn knn_cross_with_forest(
    targets: &Dataset,
    sources: &Dataset,
    forest: &PcaForest,
    k: usize,
    params: &AnnParams,
    threads: usize,
    exclude_same_index: bool,
) -> KnnGraph {
    assert_eq!(targets.d(), sources.d());
    let n = targets.n();
    let m = sources.n();
    assert!(
        k >= 1 && k <= m - exclude_same_index as usize,
        "k out of range"
    );
    let pool = ThreadPool::new_or_default(threads);
    let tproj = forest.project_dataset(targets);
    let p = forest.p;
    fill_rows(
        n,
        k,
        &pool,
        |i, cand| {
            cand.clear();
            let q = &tproj[i * p..(i + 1) * p];
            for t in &forest.trees {
                cand.extend_from_slice(t.route(q));
            }
            cand.sort_unstable();
            cand.dedup();
            let exclude = if exclude_same_index && i < m {
                if let Ok(pos) = cand.binary_search(&(i as u32)) {
                    cand.remove(pos);
                }
                Some(i as u32)
            } else {
                None
            };
            pad_candidates(cand, exclude, m, k, pad_seed(params.seed, i));
        },
        |i, j| sqdist_cross(targets, i, sources, j as usize),
    )
}

/// As [`knn_cross_with_forest`], building the source forest first — the
/// one-shot entry point used by [`KnnBackend`](crate::knn::KnnBackend).
pub fn knn_cross_ann(
    targets: &Dataset,
    sources: &Dataset,
    k: usize,
    params: &AnnParams,
    threads: usize,
    exclude_same_index: bool,
) -> KnnGraph {
    let pool = ThreadPool::new_or_default(threads);
    let forest = PcaForest::build(sources, params, &pool);
    knn_cross_with_forest(
        targets,
        sources,
        &forest,
        k,
        params,
        threads,
        exclude_same_index,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn params_small() -> AnnParams {
        AnnParams {
            trees: 4,
            leaf_cap: 16,
            ..AnnParams::default()
        }
    }

    #[test]
    fn buckets_partition_points() {
        let ds = SynthSpec::blobs(500, 3, 4, 3).generate();
        let pool = ThreadPool::new(2);
        let f = PcaForest::build(&ds, &params_small(), &pool);
        assert_eq!(f.trees.len(), 4);
        for t in &f.trees {
            let total: usize = t.buckets.iter().map(Vec::len).sum();
            assert_eq!(total, 500);
            for (b, bucket) in t.buckets.iter().enumerate() {
                for &i in bucket {
                    assert_eq!(t.bucket_of[i as usize], b as u32);
                }
            }
        }
    }

    #[test]
    fn route_reaches_own_bucket() {
        // Routing a build point's own projection must reach the bucket that
        // contains it (split keys are deterministic functions of proj).
        let ds = SynthSpec::blobs(300, 4, 3, 7).generate();
        let pool = ThreadPool::new(2);
        let f = PcaForest::build(&ds, &params_small(), &pool);
        let proj = f.project_dataset(&ds);
        let p = f.p;
        for i in [0usize, 37, 299] {
            let q = &proj[i * p..(i + 1) * p];
            for t in &f.trees {
                let members = t.route(q);
                assert!(members.contains(&(i as u32)), "point {i} missed its bucket");
            }
        }
    }

    #[test]
    fn seed_graph_has_full_valid_rows() {
        let ds = SynthSpec::blobs(200, 3, 4, 5).generate();
        let pool = ThreadPool::new(4);
        let f = PcaForest::build(&ds, &params_small(), &pool);
        let g = seed_graph(&ds, &f, 8, &params_small(), &pool);
        for i in 0..200 {
            let nb = g.neighbors(i);
            let mut sorted = nb.to_vec();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8, "row {i} has duplicates");
            assert!(!nb.contains(&(i as u32)));
            for w in g.distances(i).windows(2) {
                assert!(w[0] <= w[1]);
            }
        }
    }

    #[test]
    fn duplicate_points_terminate_and_fill() {
        // All-identical points: splits degenerate, the leaf guard fires,
        // and padding still delivers k distinct neighbors.
        let ds = Dataset::new(64, 3, vec![0.5; 192]);
        let pool = ThreadPool::new(2);
        let f = PcaForest::build(&ds, &params_small(), &pool);
        let g = seed_graph(&ds, &f, 5, &params_small(), &pool);
        for i in 0..64 {
            let mut nb = g.neighbors(i).to_vec();
            nb.sort_unstable();
            nb.dedup();
            assert_eq!(nb.len(), 5);
            assert!(!nb.contains(&(i as u32)));
        }
    }

    #[test]
    fn cross_query_finds_copied_points() {
        let src = SynthSpec::blobs(300, 3, 3, 21).generate();
        let mut rng = Rng::new(1);
        let pick: Vec<usize> = (0..20).map(|_| rng.below(300)).collect();
        let tgt = src.select(&pick);
        let g = knn_cross_ann(&tgt, &src, 3, &params_small(), 2, false);
        for (ti, &si) in pick.iter().enumerate() {
            assert_eq!(g.neighbors(ti)[0], si as u32, "target {ti}");
            assert_eq!(g.distances(ti)[0], 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "k out of range")]
    fn cross_rejects_large_k() {
        let ds = SynthSpec::blobs(10, 2, 2, 1).generate();
        knn_cross_ann(&ds, &ds, 10, &params_small(), 1, true);
    }
}
