//! Recall harness: measure an approximate kNN graph against the exact
//! backend on a subsample of query points.
//!
//! The exact oracle is [`knn::exact::knn_graph_cross`] restricted to the
//! sampled queries, so the cost is O(sample·n·d) rather than O(n²·d) —
//! cheap enough to run inside benches at every size.
//!
//! [`knn::exact::knn_graph_cross`]: crate::knn::exact::knn_graph_cross

use crate::data::dataset::Dataset;
use crate::knn::exact::{knn_graph_cross, KnnGraph};
use crate::util::rng::Rng;

/// Result of a recall measurement.
#[derive(Clone, Debug)]
pub struct RecallReport {
    pub k: usize,
    /// Number of sampled query points.
    pub sampled: usize,
    /// Fraction of true k-nearest neighbors present in the approximate
    /// rows (recall@k).
    pub recall: f64,
    /// Mean ratio of the approximate kth-neighbor distance to the exact
    /// kth-neighbor distance over the sample (1.0 = perfect).
    pub dist_ratio: f64,
}

/// recall@k of `approx` (a self-graph over `ds`) on `sample` random
/// queries, exact neighbors recomputed as the oracle.
pub fn recall_at_k(
    ds: &Dataset,
    approx: &KnnGraph,
    sample: usize,
    seed: u64,
    threads: usize,
) -> RecallReport {
    assert_eq!(approx.n, ds.n());
    let n = ds.n();
    let k = approx.k;
    let sample = sample.clamp(1, n);
    let mut rng = Rng::new(seed);
    let picks = rng.sample_distinct(n, sample);
    let queries = ds.select(&picks);
    // k+1 cross neighbors (the query itself shows up at distance 0), the
    // self match is dropped per row below.
    let kq = (k + 1).min(n);
    let truth = knn_graph_cross(&queries, ds, kq, threads, false);

    let mut hits = 0usize;
    let mut total = 0usize;
    let mut ratio = 0.0f64;
    for (qi, &orig) in picks.iter().enumerate() {
        let mut exact_pairs: Vec<(f32, u32)> = truth
            .distances(qi)
            .iter()
            .zip(truth.neighbors(qi))
            .filter(|&(_, &j)| j as usize != orig)
            .map(|(&d, &j)| (d, j))
            .collect();
        exact_pairs.truncate(k);
        let mut approx_sorted = approx.neighbors(orig).to_vec();
        approx_sorted.sort_unstable();
        for &(_, j) in &exact_pairs {
            if approx_sorted.binary_search(&j).is_ok() {
                hits += 1;
            }
        }
        total += exact_pairs.len();
        let exact_kth = exact_pairs.last().map(|&(d, _)| d as f64).unwrap_or(0.0);
        let approx_kth = approx.distances(orig).last().copied().unwrap_or(0.0) as f64;
        ratio += if exact_kth > 0.0 {
            (approx_kth / exact_kth).sqrt()
        } else {
            1.0
        };
    }
    RecallReport {
        k,
        sampled: sample,
        recall: hits as f64 / total.max(1) as f64,
        dist_ratio: ratio / sample as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::knn::exact::knn_graph;

    #[test]
    fn exact_graph_scores_perfect_recall() {
        let ds = SynthSpec::blobs(300, 4, 3, 5).generate();
        let g = knn_graph(&ds, 5, 2);
        let rep = recall_at_k(&ds, &g, 64, 1, 2);
        assert_eq!(rep.sampled, 64);
        assert!(rep.recall > 0.999, "recall {}", rep.recall);
        assert!((rep.dist_ratio - 1.0).abs() < 1e-4, "ratio {}", rep.dist_ratio);
    }

    #[test]
    fn corrupted_graph_scores_below_one() {
        let ds = SynthSpec::blobs(300, 4, 3, 6).generate();
        let mut g = knn_graph(&ds, 5, 2);
        // Break half the rows: replace the nearest neighbor with a far index.
        for i in 0..150 {
            let row = i * g.k;
            g.idx[row] = ((i + 150) % 300) as u32;
            g.dist2[row] = f32::MAX;
        }
        let rep = recall_at_k(&ds, &g, 128, 2, 2);
        assert!(rep.recall < 0.99, "corruption not detected: {}", rep.recall);
    }
}
