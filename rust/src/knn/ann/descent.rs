//! NN-descent refinement (after Dong, Charikar & Li, WWW '11): the
//! neighbor-of-a-neighbor join.  Each pass proposes, for every point, the
//! current neighbors, a capped sample of *reverse* neighbors, and the
//! neighbors of both, then keeps the k best by true (full-dimensional)
//! distance.
//!
//! The implementation is **double-buffered**: pass t+1 is a pure function
//! of pass t's graph, so rows can be computed in parallel with no locks and
//! the result is identical for every thread count (the property tests rely
//! on this).  The price is one extra n×k buffer per pass.
//!
//! Termination: after each pass the update rate (changed neighbor slots /
//! n·k) is measured; refinement stops early once it falls below
//! [`AnnParams::delta`] — on clustered data this converges in 3–5 passes.

use crate::data::dataset::Dataset;
use crate::knn::ann::{insert_best, AnnParams};
use crate::knn::exact::KnnGraph;
use crate::par::pool::ThreadPool;

/// Refine `g` in place over up to `params.descent_iters` passes.
pub fn refine(ds: &Dataset, mut g: KnnGraph, params: &AnnParams, pool: &ThreadPool) -> KnnGraph {
    let n = g.n;
    let k = g.k;
    if n < 3 || k == 0 || params.descent_iters == 0 {
        return g;
    }
    let max_cand = if params.max_candidates == 0 {
        12 * k
    } else {
        params.max_candidates
    };
    let rev_cap = if params.reverse_cap == 0 {
        k
    } else {
        params.reverse_cap
    };

    for _pass in 0..params.descent_iters {
        // Reverse-neighbor sample, capped per point (deterministic: rows
        // are scanned in index order).
        let mut rev: Vec<Vec<u32>> = vec![Vec::new(); n];
        for i in 0..n {
            for &j in g.neighbors(i) {
                let r = &mut rev[j as usize];
                if r.len() < rev_cap {
                    r.push(i as u32);
                }
            }
        }

        let rows: Vec<usize> = (0..n).collect();
        let new_rows: Vec<(Vec<u32>, Vec<f32>, usize)> = pool.map(&rows, |&i| {
            let old_idx = g.neighbors(i);
            let old_d2 = g.distances(i);
            // Candidate pool: N(i) ∪ Rev(i) ∪ N(u) for u in both, bounded
            // so a pass costs O(max_cand) distance evaluations per point.
            let mut cand: Vec<u32> = Vec::with_capacity(4 * max_cand);
            cand.extend_from_slice(old_idx);
            cand.extend_from_slice(&rev[i]);
            let base_len = cand.len();
            for t in 0..base_len {
                if cand.len() >= 4 * max_cand {
                    break;
                }
                let u = cand[t] as usize;
                cand.extend_from_slice(g.neighbors(u));
            }
            cand.sort_unstable();
            cand.dedup();
            if let Ok(pos) = cand.binary_search(&(i as u32)) {
                cand.remove(pos);
            }
            // Seed with the old row (distances already known); evaluate
            // only genuinely new candidates, capped at max_cand.
            let mut best: Vec<(f32, u32)> =
                old_d2.iter().zip(old_idx).map(|(&d, &j)| (d, j)).collect();
            let mut old_sorted = old_idx.to_vec();
            old_sorted.sort_unstable();
            let mut evals = 0usize;
            for &j in &cand {
                if evals >= max_cand {
                    break;
                }
                if old_sorted.binary_search(&j).is_ok() {
                    continue;
                }
                evals += 1;
                insert_best(&mut best, k, ds.sqdist(i, j as usize), j);
            }
            // Changed slots = k − |new ∩ old| (both index sets sorted).
            let mut new_sorted: Vec<u32> = best.iter().map(|&(_, j)| j).collect();
            new_sorted.sort_unstable();
            let mut common = 0usize;
            let (mut a, mut b) = (0usize, 0usize);
            while a < old_sorted.len() && b < new_sorted.len() {
                match old_sorted[a].cmp(&new_sorted[b]) {
                    std::cmp::Ordering::Less => a += 1,
                    std::cmp::Ordering::Greater => b += 1,
                    std::cmp::Ordering::Equal => {
                        common += 1;
                        a += 1;
                        b += 1;
                    }
                }
            }
            let idx_row: Vec<u32> = best.iter().map(|&(_, j)| j).collect();
            let d2_row: Vec<f32> = best.iter().map(|&(d, _)| d).collect();
            (idx_row, d2_row, k - common)
        });

        let mut idx = vec![0u32; n * k];
        let mut dist2 = vec![0.0f32; n * k];
        let mut changed = 0usize;
        for (i, (ri, rd, ch)) in new_rows.iter().enumerate() {
            idx[i * k..(i + 1) * k].copy_from_slice(ri);
            dist2[i * k..(i + 1) * k].copy_from_slice(rd);
            changed += ch;
        }
        g = KnnGraph { n, k, idx, dist2 };
        if (changed as f64) < params.delta * (n * k) as f64 {
            break;
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::knn::ann::forest::{seed_graph, PcaForest};
    use crate::knn::exact::knn_graph;

    fn overlap(a: &KnnGraph, b: &KnnGraph) -> f64 {
        let mut hits = 0usize;
        for i in 0..a.n {
            let mut e = b.neighbors(i).to_vec();
            e.sort_unstable();
            for &j in a.neighbors(i) {
                if e.binary_search(&j).is_ok() {
                    hits += 1;
                }
            }
        }
        hits as f64 / (a.n * a.k) as f64
    }

    #[test]
    fn descent_improves_forest_seed() {
        let ds = SynthSpec::blobs(600, 6, 4, 13).generate();
        let pool = ThreadPool::new(4);
        // Deliberately weak forest (2 trees) so descent has work to do.
        let params = AnnParams {
            trees: 2,
            leaf_cap: 24,
            ..AnnParams::default()
        };
        let f = PcaForest::build(&ds, &params, &pool);
        let seeded = seed_graph(&ds, &f, 6, &params, &pool);
        let refined = refine(&ds, seeded.clone(), &params, &pool);
        let exact = knn_graph(&ds, 6, 4);
        let before = overlap(&seeded, &exact);
        let after = overlap(&refined, &exact);
        assert!(
            after >= before,
            "descent regressed recall: {before:.3} -> {after:.3}"
        );
        assert!(after > 0.9, "refined recall too low: {after:.3}");
    }

    #[test]
    fn rows_stay_valid_after_refinement() {
        let ds = SynthSpec::blobs(250, 4, 3, 9).generate();
        let pool = ThreadPool::new(2);
        let params = AnnParams {
            trees: 3,
            leaf_cap: 16,
            ..AnnParams::default()
        };
        let f = PcaForest::build(&ds, &params, &pool);
        let g = refine(&ds, seed_graph(&ds, &f, 7, &params, &pool), &params, &pool);
        for i in 0..250 {
            let nb = g.neighbors(i);
            let mut sorted = nb.to_vec();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 7, "row {i} duplicates");
            assert!(!nb.contains(&(i as u32)), "row {i} self loop");
            for w in g.distances(i).windows(2) {
                assert!(w[0] <= w[1], "row {i} unsorted");
            }
        }
    }

    #[test]
    fn thread_count_invariance() {
        let ds = SynthSpec::blobs(300, 5, 4, 17).generate();
        let params = AnnParams {
            trees: 3,
            leaf_cap: 16,
            ..AnnParams::default()
        };
        let p1 = ThreadPool::new(1);
        let p8 = ThreadPool::new(8);
        let f1 = PcaForest::build(&ds, &params, &p1);
        let f8 = PcaForest::build(&ds, &params, &p8);
        let a = refine(&ds, seed_graph(&ds, &f1, 5, &params, &p1), &params, &p1);
        let b = refine(&ds, seed_graph(&ds, &f8, 5, &params, &p8), &params, &p8);
        assert_eq!(a.idx, b.idx);
        assert_eq!(a.dist2, b.dist2);
    }
}
