//! k-nearest-neighbor graph construction (the interaction matrix profile,
//! Eq. 1: `a_ij != 0` iff `s_j ∈ kNN(t_i)`).
//!
//! Two backends build the same [`exact::KnnGraph`] structure:
//!
//! * [`exact`] — blocked brute force, O(n²·d).  Ground truth: the right
//!   choice up to a few tens of thousands of points, for paper-figure
//!   reproductions, and as the oracle for recall measurement.
//! * [`ann`] — approximate, near-linear in n: a randomized PCA-projection
//!   forest seeds candidate lists that NN-descent refines.  The right
//!   choice beyond ~10⁴ points; recall@10 ≈ 0.97 on clustered data with
//!   default [`ann::AnnParams`] (measured by [`ann::recall`]).
//!
//! [`KnnBackend`] selects between them uniformly everywhere a profile is
//! built: the ordering pipeline ([`order::Pipeline`]), both applications
//! (`apps::tsne`, `apps::meanshift`), the `nni` CLI (`knn` subcommand and
//! `--knn` flags), and the `ann_vs_exact` bench.
//!
//! [`order::Pipeline`]: crate::order::Pipeline

pub mod ann;
pub mod exact;

use crate::data::dataset::Dataset;
use self::ann::AnnParams;
use self::exact::KnnGraph;

/// Uniform backend selector for kNN graph construction.
#[derive(Clone, Debug, PartialEq, Default)]
pub enum KnnBackend {
    /// Blocked brute force (`knn::exact`), O(n²·d).
    #[default]
    Exact,
    /// PCA-projection forest + NN-descent (`knn::ann`), near-linear.
    Ann(AnnParams),
}

impl KnnBackend {
    /// The approximate backend with default parameters.
    pub fn ann_default() -> KnnBackend {
        KnnBackend::Ann(AnnParams::default())
    }

    /// Short label for logs and CLI output.
    pub fn label(&self) -> &'static str {
        match self {
            KnnBackend::Exact => "exact",
            KnnBackend::Ann(_) => "ann",
        }
    }

    /// Self-kNN graph of `ds` (no self matches).
    ///
    /// `threads`: worker count (0 → machine default).
    pub fn build(&self, ds: &Dataset, k: usize, threads: usize) -> KnnGraph {
        match self {
            KnnBackend::Exact => exact::knn_graph(ds, k, threads),
            KnnBackend::Ann(p) => ann::knn_graph_ann(ds, k, p, threads),
        }
    }

    /// Cross kNN of `targets` against `sources` (the mean-shift profile).
    /// The approximate backend routes targets through a forest built on the
    /// sources (see [`ann::forest::knn_cross_ann`]).
    pub fn build_cross(
        &self,
        targets: &Dataset,
        sources: &Dataset,
        k: usize,
        threads: usize,
        exclude_same_index: bool,
    ) -> KnnGraph {
        match self {
            KnnBackend::Exact => {
                exact::knn_graph_cross(targets, sources, k, threads, exclude_same_index)
            }
            KnnBackend::Ann(p) => {
                ann::forest::knn_cross_ann(targets, sources, k, p, threads, exclude_same_index)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn labels_and_default() {
        assert_eq!(KnnBackend::default(), KnnBackend::Exact);
        assert_eq!(KnnBackend::Exact.label(), "exact");
        assert_eq!(KnnBackend::ann_default().label(), "ann");
    }

    #[test]
    fn both_backends_share_the_graph_contract() {
        let ds = SynthSpec::blobs(200, 3, 3, 4).generate();
        for backend in [KnnBackend::Exact, KnnBackend::ann_default()] {
            let g = backend.build(&ds, 6, 2);
            assert_eq!(g.n, 200);
            assert_eq!(g.k, 6);
            assert_eq!(g.idx.len(), 1200);
        }
    }
}
