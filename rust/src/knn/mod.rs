//! k-nearest-neighbor graph construction (the interaction matrix profile,
//! Eq. 1: `a_ij != 0` iff `s_j ∈ kNN(t_i)`).

pub mod exact;
