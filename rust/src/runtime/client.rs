//! Thin, typed wrapper over the `xla` crate's PJRT CPU client.
//!
//! Interchange is **HLO text** (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md).
//!
//! The `xla` crate is an external dependency the offline toolchain cannot
//! fetch, so the PJRT backend is gated behind the `pjrt` cargo feature.
//! The default build compiles a stub whose [`Runtime::cpu`] fails with a
//! descriptive error: [`ArtifactRegistry`](crate::runtime::ArtifactRegistry)
//! then fails to open, and every caller (coordinator, CLI, tests) already
//! degrades to the pure-Rust path.  Enabling `--features pjrt` requires
//! adding the `xla` dependency to Cargo.toml.

/// A host tensor: f32 data + shape (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn scalar(x: f32) -> Tensor {
        Tensor {
            shape: vec![],
            data: vec![x],
        }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }
}

#[cfg(feature = "pjrt")]
mod backend {
    //! Real PJRT backing via the external `xla` crate.

    use super::Tensor;
    use anyhow::{anyhow, Context, Result};
    use std::path::Path;

    impl Tensor {
        fn to_literal(&self) -> Result<xla::Literal> {
            if self.shape.is_empty() {
                return Ok(xla::Literal::scalar(self.data[0]));
            }
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(&self.data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
        }

        fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
            let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("literal data: {e:?}"))?;
            Ok(Tensor::new(dims, data))
        }
    }

    /// The PJRT CPU runtime.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
            Ok(Runtime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load and compile one HLO-text artifact.
        pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
            Ok(Executable {
                exe,
                name: path
                    .file_name()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_default(),
            })
        }
    }

    /// A compiled block program.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl Executable {
        /// Execute with host tensors; returns the tuple outputs as tensors.
        ///
        /// The AOT pipeline lowers every program with `return_tuple=True`,
        /// so the single result literal is always a tuple.
        pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            let lits: Vec<xla::Literal> = inputs
                .iter()
                .map(|t| t.to_literal())
                .collect::<Result<_>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&lits)
                .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch {}: {e:?}", self.name))?;
            let parts = out
                .to_tuple()
                .map_err(|e| anyhow!("untuple {}: {e:?}", self.name))?;
            parts
                .iter()
                .map(Tensor::from_literal)
                .collect::<Result<Vec<_>>>()
                .context("decode outputs")
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    //! Stub compiled when the `pjrt` feature is off: construction fails
    //! cleanly so every consumer degrades to the pure-Rust path.

    use super::Tensor;
    use anyhow::{anyhow, Result};
    use std::path::Path;

    /// Placeholder PJRT runtime; never constructible in this build.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            Err(anyhow!(
                "PJRT runtime unavailable: built without the `pjrt` feature \
                 (the offline toolchain ships no `xla` crate; see \
                 rust/src/runtime/client.rs)"
            ))
        }

        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
            Err(anyhow!("PJRT runtime unavailable: cannot load {path:?}"))
        }
    }

    /// Placeholder compiled program; never constructible in this build.
    pub struct Executable {
        pub name: String,
    }

    impl Executable {
        pub fn run(&self, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            Err(anyhow!("PJRT executable '{}' unavailable", self.name))
        }
    }
}

pub use backend::{Executable, Runtime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.rank(), 2);
        let s = Tensor::scalar(5.0);
        assert_eq!(s.rank(), 0);
    }

    #[test]
    #[should_panic]
    fn tensor_rejects_mismatched_len() {
        Tensor::new(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn stub_runtime_fails_cleanly() {
        let err = Runtime::cpu().err().unwrap();
        assert!(format!("{err}").contains("pjrt"));
    }

    // PJRT-backed tests live in rust/tests/runtime_golden.rs (they need the
    // artifacts directory, which is built by `make artifacts`).
}
