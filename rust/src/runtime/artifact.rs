//! Manifest-driven artifact registry.
//!
//! `artifacts/manifest.json` (written by `python -m compile.aot`) maps each
//! variant name to its HLO file, input signature, and golden tensors.  The
//! registry compiles variants lazily and caches the executables so the
//! coordinator can look them up by name on the hot path.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::runtime::client::{Executable, Runtime, Tensor};
use crate::util::json::{self, Json};

/// Input/output signature entry.
#[derive(Clone, Debug)]
pub struct VariantMeta {
    pub name: String,
    pub file: PathBuf,
    /// Input shapes (f32 only in this system).
    pub inputs: Vec<Vec<usize>>,
    pub golden: Option<Golden>,
}

/// Golden input/output tensor files for integration checks.
#[derive(Clone, Debug)]
pub struct Golden {
    pub inputs: Vec<(PathBuf, Vec<usize>)>,
    pub outputs: Vec<(PathBuf, Vec<usize>)>,
}

/// Registry of AOT artifacts.
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    pub variants: HashMap<String, VariantMeta>,
    runtime: Runtime,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl ArtifactRegistry {
    /// Open `dir` (usually `artifacts/`) and parse its manifest.
    pub fn open(dir: &Path) -> Result<ArtifactRegistry> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts`"))?;
        let doc = json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut variants = HashMap::new();
        let vars = doc
            .get("variants")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing 'variants'"))?;
        for (name, entry) in vars {
            let file = dir.join(
                entry
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("{name}: missing file"))?,
            );
            let inputs = entry
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing inputs"))?
                .iter()
                .map(|inp| {
                    inp.get("shape")
                        .and_then(Json::as_arr)
                        .map(|a| a.iter().filter_map(Json::as_usize).collect())
                        .ok_or_else(|| anyhow!("{name}: bad input shape"))
                })
                .collect::<Result<Vec<Vec<usize>>>>()?;
            let golden = entry.get("golden").map(|g| parse_golden(dir, g)).transpose()?;
            variants.insert(
                name.clone(),
                VariantMeta {
                    name: name.clone(),
                    file,
                    inputs,
                    golden,
                },
            );
        }
        Ok(ArtifactRegistry {
            dir: dir.to_path_buf(),
            variants,
            runtime: Runtime::cpu()?,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Default artifacts directory: `$NNI_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<ArtifactRegistry> {
        let dir = std::env::var("NNI_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(Path::new(&dir))
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Get (compiling and caching on first use) a variant executable.
    pub fn get(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .variants
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact variant '{name}'"))?;
        let exe = std::sync::Arc::new(self.runtime.load_hlo_text(&meta.file)?);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute a variant after validating input shapes against the manifest.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let meta = self
            .variants
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact variant '{name}'"))?;
        if inputs.len() != meta.inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            ));
        }
        for (k, (t, want)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if &t.shape != want {
                return Err(anyhow!(
                    "{name}: input {k} shape {:?} != manifest {:?}",
                    t.shape,
                    want
                ));
            }
        }
        self.get(name)?.run(inputs)
    }

    /// Load a golden tensor file (raw little-endian f32).
    pub fn load_golden_tensor(path: &Path, shape: &[usize]) -> Result<Tensor> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        let n: usize = shape.iter().product::<usize>().max(1);
        if bytes.len() != n * 4 {
            return Err(anyhow!(
                "{path:?}: {} bytes != {} f32s",
                bytes.len(),
                n
            ));
        }
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }
}

fn parse_golden(dir: &Path, g: &Json) -> Result<Golden> {
    let gdir = dir.join("golden");
    let side = |key: &str| -> Result<Vec<(PathBuf, Vec<usize>)>> {
        g.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("golden missing {key}"))?
            .iter()
            .map(|e| {
                let f = gdir.join(
                    e.get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("golden entry missing file"))?,
                );
                let shape = e
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default();
                Ok((f, shape))
            })
            .collect()
    };
    Ok(Golden {
        inputs: side("inputs")?,
        outputs: side("outputs")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_tensor_size_check() {
        let dir = std::env::temp_dir();
        let p = dir.join("nni_golden_test.bin");
        std::fs::write(&p, [0u8; 12]).unwrap();
        assert!(ArtifactRegistry::load_golden_tensor(&p, &[3]).is_ok());
        assert!(ArtifactRegistry::load_golden_tensor(&p, &[4]).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn missing_manifest_is_context_error() {
        let err = ArtifactRegistry::open(Path::new("/nonexistent-dir-xyz"))
            .err()
            .unwrap();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
