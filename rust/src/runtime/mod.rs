//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text; see DESIGN.md §Layer-2) and executes them on the request path
//! without any Python.

pub mod artifact;
pub mod client;

pub use artifact::{ArtifactRegistry, Golden, VariantMeta};
pub use client::{Executable, Runtime, Tensor};
