//! t-SNE (van der Maaten & Hinton 2008) with the paper's hierarchical
//! near-neighbor interaction engine for the attractive force (§3.1).
//!
//! Pipeline: perplexity-calibrated sparse joint probabilities P over the
//! kNN graph of the *feature-space* data → dual-tree reorder of P (profile
//! fixed across iterations) → per-iteration attractive force through the
//! [`Coordinator`] (Rust + PJRT hybrid) and exact repulsive force — the
//! paper accelerates the attractive term; the repulsive term follows the
//! reference algorithm.
//!
//! The attractive force is multi-RHS under the hood: dense blocks of P run
//! the batched micro-GEMM over the d embedding columns (plus a fused
//! row-sum column) via `interact::engine::tsne_block`, so raising
//! [`TsneConfig::d`] widens the per-block GEMM instead of adding scalar
//! matvec passes.
//!
//! [`Coordinator`]: crate::coordinator::Coordinator

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::Coordinator;
use crate::csb::hier::HierCsb;
use crate::csb::kernel::KernelKind;
use crate::data::dataset::Dataset;
use crate::interact::engine::Engine;
use crate::knn::exact::KnnGraph;
use crate::knn::KnnBackend;
use crate::obs::{self, counters, Counter};
use crate::order::Pipeline;
use crate::par::pool::ThreadPool;
use crate::runtime::ArtifactRegistry;
use crate::sparse::csr::Csr;
use crate::util::rng::Rng;

/// t-SNE hyper-parameters.
#[derive(Clone, Debug)]
pub struct TsneConfig {
    /// Output dimension (2 or 3 — must match an AOT artifact for the PJRT
    /// path).
    pub d: usize,
    pub perplexity: f64,
    /// Neighbors in the sparse P profile (default 3·perplexity).
    pub k: usize,
    pub iters: usize,
    pub early_exaggeration: f32,
    pub exaggeration_iters: usize,
    pub learning_rate: f32,
    pub momentum_start: f32,
    pub momentum_final: f32,
    pub threads: usize,
    /// Build-side workers of the reorder (PCA, tree, CSB assembly):
    /// 0 = follow `threads`.  Bit-identical across counts.
    pub build_threads: usize,
    pub seed: u64,
    /// Leaf capacity of the dual-tree reorder.
    pub leaf_cap: usize,
    /// Use the PJRT artifact path for dense blocks.
    pub use_pjrt: bool,
    /// kNN backend for the sparse P profile (exact or approximate).
    pub knn: KnnBackend,
    /// Apply kernel (`Scalar` pins the bit-exact reference path).
    pub kernel: KernelKind,
}

impl Default for TsneConfig {
    fn default() -> Self {
        TsneConfig {
            d: 2,
            perplexity: 30.0,
            k: 90,
            iters: 500,
            early_exaggeration: 12.0,
            exaggeration_iters: 100,
            learning_rate: 200.0,
            momentum_start: 0.5,
            momentum_final: 0.8,
            threads: 0,
            build_threads: 0,
            seed: 42,
            leaf_cap: 256,
            use_pjrt: false,
            knn: KnnBackend::Exact,
            kernel: KernelKind::Auto,
        }
    }
}

/// Per-logging-step record of the run.
#[derive(Clone, Debug)]
pub struct TsneLogEntry {
    pub iter: usize,
    pub kl: f64,
    pub grad_norm: f64,
    pub seconds: f64,
}

/// Result: embedding (original point order) + loss curve + metrics summary.
pub struct TsneResult {
    pub embedding: Dataset,
    pub log: Vec<TsneLogEntry>,
    pub metrics_summary: String,
}

/// Conditional-to-joint P matrix via perplexity calibration (binary search
/// on the Gaussian precision per point, as in the reference algorithm).
pub fn joint_probabilities(g: &KnnGraph, perplexity: f64, pool: &ThreadPool) -> Csr {
    let n = g.n;
    let k = g.k;
    let target_h = perplexity.ln();
    let rows: Vec<usize> = (0..n).collect();
    let cond: Vec<Vec<f32>> = pool.map(&rows, |&i| {
        let d2 = g.distances(i);
        // binary search beta (precision) so that entropy(P_i) = ln(perp)
        let mut beta = 1.0f64;
        let (mut lo, mut hi) = (f64::MIN_POSITIVE, f64::MAX);
        let mut p = vec![0.0f64; k];
        for _ in 0..64 {
            let mut sum = 0.0f64;
            for (t, &dd) in d2.iter().enumerate() {
                p[t] = (-(dd as f64 - d2[0] as f64) * beta).exp();
                sum += p[t];
            }
            // entropy H = ln(sum) + beta * <d2>_P  (up to the shift)
            let mut h = 0.0f64;
            for (t, &dd) in d2.iter().enumerate() {
                h += p[t] / sum * (dd as f64 - d2[0] as f64);
            }
            let h = sum.ln() + beta * h;
            if (h - target_h).abs() < 1e-5 {
                break;
            }
            if h > target_h {
                lo = beta;
                beta = if hi == f64::MAX { beta * 2.0 } else { 0.5 * (beta + hi) };
            } else {
                hi = beta;
                beta = 0.5 * (beta + lo.max(f64::MIN_POSITIVE));
            }
        }
        let sum: f64 = p.iter().sum();
        p.iter().map(|&x| (x / sum) as f32).collect()
    });
    // symmetrize: P_ij = (P(j|i) + P(i|j)) / (2N)
    let mut r = Vec::with_capacity(2 * n * k);
    let mut c = Vec::with_capacity(2 * n * k);
    let mut v = Vec::with_capacity(2 * n * k);
    let scale = 1.0 / (2.0 * n as f64);
    for i in 0..n {
        for (t, &j) in g.neighbors(i).iter().enumerate() {
            let p = (cond[i][t] as f64 * scale) as f32;
            r.push(i as u32);
            c.push(j);
            v.push(p);
            r.push(j);
            c.push(i as u32);
            v.push(p);
        }
    }
    Csr::from_triplets(n, n, &r, &c, &v)
}

/// Exact repulsive force and the partition constant Z:
/// `F_i = Σ_j q̃_ij² (y_i − y_j) / Z`, `Z = Σ_{i≠j} q̃_ij`.
pub fn repulsive_exact(y: &[f32], n: usize, d: usize, pool: &ThreadPool, out: &mut [f32]) -> f64 {
    let rows: Vec<usize> = (0..n).collect();
    let per_row: Vec<(Vec<f32>, f64)> = pool.map(&rows, |&i| {
        let yi = &y[i * d..(i + 1) * d];
        let mut f = vec![0.0f32; d];
        let mut z = 0.0f64;
        for j in 0..n {
            if j == i {
                continue;
            }
            let yj = &y[j * d..(j + 1) * d];
            let mut d2 = 0.0f32;
            for k in 0..d {
                let t = yi[k] - yj[k];
                d2 += t * t;
            }
            let q = 1.0 / (1.0 + d2);
            let q2 = q * q;
            for k in 0..d {
                f[k] += q2 * (yi[k] - yj[k]);
            }
            z += q as f64;
        }
        (f, z)
    });
    let mut z_total = 0.0f64;
    for (i, (f, z)) in per_row.iter().enumerate() {
        out[i * d..(i + 1) * d].copy_from_slice(f);
        z_total += z;
    }
    // normalize by Z
    let zf = (1.0 / z_total) as f32;
    for v in out.iter_mut() {
        *v *= zf;
    }
    z_total
}

/// KL divergence Σ p log(p/q) over the sparse P profile (tree order).
fn kl_divergence(csb: &HierCsb, y: &[f32], d: usize, z: f64) -> f64 {
    let mut kl = 0.0f64;
    for t in 0..csb.blocks.len() {
        let b = &csb.blocks[t];
        let r0 = b.rows.lo as usize;
        let c0 = b.cols.lo as usize;
        csb.for_each_nz(t, |r, c, p| {
            if p <= 0.0 {
                return;
            }
            let yi = &y[(r0 + r) * d..(r0 + r + 1) * d];
            let yj = &y[(c0 + c) * d..(c0 + c + 1) * d];
            let mut d2 = 0.0f32;
            for k in 0..d {
                let t = yi[k] - yj[k];
                d2 += t * t;
            }
            let q = (1.0 / (1.0 + d2)) as f64 / z;
            kl += p as f64 * (p as f64 / q.max(1e-300)).ln();
        });
    }
    kl
}

/// Run t-SNE end to end.  `registry` enables PJRT dense-block dispatch.
pub fn run(ds: &Dataset, cfg: &TsneConfig, registry: Option<ArtifactRegistry>) -> TsneResult {
    let n = ds.n();
    let d = cfg.d;
    let pool = ThreadPool::new_or_default(cfg.threads);

    // 1. kNN (either backend) + perplexity-calibrated joint P.
    let g = cfg.knn.build(ds, cfg.k, pool.threads);
    let p = joint_probabilities(&g, cfg.perplexity, &pool);

    // 2. Hierarchical reorder of the (fixed) profile, built in parallel
    // (bit-identical to the sequential build at any worker count).
    let build_threads = if cfg.build_threads != 0 {
        cfg.build_threads
    } else {
        pool.threads
    };
    let pipe = Pipeline::dual_tree(3)
        .with_seed(cfg.seed)
        .with_build_threads(build_threads)
        .run(ds, &p);
    let tree = pipe.tree.as_ref().unwrap();
    // Lower dense threshold on the PJRT path: densified blocks are exactly
    // what the AOT artifacts consume (zero-padding is free on the MXU).
    let dense_thr = if cfg.use_pjrt { 0.25 } else { 0.6 };
    let csb = HierCsb::build_with_par(
        &pipe.reordered,
        tree,
        tree,
        cfg.leaf_cap,
        dense_thr,
        build_threads,
    );
    let engine = Engine::with_kernel(csb, pool.threads, cfg.kernel);
    let mut coord = Coordinator::new(
        engine,
        if cfg.use_pjrt { registry } else { None },
        BatchPolicy {
            pjrt_enabled: cfg.use_pjrt,
            ..Default::default()
        },
    );

    // 3. Initialize Y (tree order) ~ N(0, 1e-4).
    let mut rng = Rng::new(cfg.seed);
    let mut y: Vec<f32> = (0..n * d).map(|_| 1e-2 * rng.normal() as f32).collect();
    let mut vel = vec![0.0f32; n * d];
    let mut gains = vec![1.0f32; n * d];
    let mut attr = vec![0.0f32; n * d];
    let mut rep = vec![0.0f32; n * d];
    let mut log = Vec::new();

    let t_start = std::time::Instant::now();
    for it in 0..cfg.iters {
        obs::span!("tsne.iter");
        counters::add(Counter::TsneIterations, 1);
        let exag = if it < cfg.exaggeration_iters {
            cfg.early_exaggeration
        } else {
            1.0
        };
        let momentum = if it < cfg.exaggeration_iters {
            cfg.momentum_start
        } else {
            cfg.momentum_final
        };

        {
            obs::span!("tsne.attr");
            coord.tsne_attr(&y, d, &mut attr);
        }
        let z = {
            obs::span!("tsne.repulsive");
            repulsive_exact(&y, n, d, &pool, &mut rep)
        };

        // gradient = 4 (exag * attr - rep); gains + momentum update
        let mut grad_norm = 0.0f64;
        for t in 0..n * d {
            let grad = 4.0 * (exag * attr[t] - rep[t]);
            grad_norm += (grad * grad) as f64;
            let same_sign = grad.signum() == vel[t].signum();
            gains[t] = if same_sign {
                (gains[t] * 0.8).max(0.01)
            } else {
                gains[t] + 0.2
            };
            vel[t] = momentum * vel[t] - cfg.learning_rate * gains[t] * grad;
            y[t] += vel[t];
        }
        // re-center (KL is translation invariant; keeps coordinates bounded)
        for k in 0..d {
            let mean: f32 = (0..n).map(|i| y[i * d + k]).sum::<f32>() / n as f32;
            for i in 0..n {
                y[i * d + k] -= mean;
            }
        }

        if it % 50 == 0 || it + 1 == cfg.iters {
            let kl = kl_divergence(&coord.engine.csb, &y, d, z);
            log.push(TsneLogEntry {
                iter: it,
                kl,
                grad_norm: grad_norm.sqrt(),
                seconds: t_start.elapsed().as_secs_f64(),
            });
        }
    }

    // Scatter the embedding back to the original point order.
    let y_orig = crate::csb::layout::rows_from_tree_order(&y, d, &pipe.perm);
    let mut embedding = Dataset::new(n, d, y_orig);
    embedding.labels = ds.labels.clone();
    TsneResult {
        embedding,
        log,
        metrics_summary: coord.metrics.summary(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::knn::exact::knn_graph;

    #[test]
    fn joint_p_is_symmetric_and_normalized() {
        let ds = SynthSpec::blobs(120, 4, 3, 3).generate();
        let pool = ThreadPool::new(2);
        let g = knn_graph(&ds, 10, 2);
        let p = joint_probabilities(&g, 5.0, &pool);
        // symmetric
        for i in 0..p.rows {
            let (cols, _) = p.row(i);
            for &j in cols {
                let a = p.get(i, j as usize);
                let b = p.get(j as usize, i);
                assert!((a - b).abs() < 1e-7, "P asym at ({i},{j})");
            }
        }
        // sums to ~1
        let total: f64 = p.val.iter().map(|&x| x as f64).sum();
        assert!((total - 1.0).abs() < 1e-3, "sum P = {total}");
    }

    #[test]
    fn repulsive_force_pushes_apart() {
        // two points: repulsive force on each points away from the other
        let y = vec![0.0f32, 0.0, 1.0, 0.0];
        let pool = ThreadPool::new(1);
        let mut out = vec![0.0f32; 4];
        let z = repulsive_exact(&y, 2, 2, &pool, &mut out);
        assert!(z > 0.0);
        assert!(out[0] < 0.0); // point 0 pushed in -x
        assert!(out[2] > 0.0); // point 1 pushed in +x
    }

    #[test]
    fn tsne_separates_blobs_and_kl_decreases() {
        let ds = SynthSpec::blobs(240, 6, 3, 7).generate();
        let cfg = TsneConfig {
            iters: 220,
            exaggeration_iters: 60,
            k: 20,
            perplexity: 10.0,
            threads: 4,
            ..Default::default()
        };
        let res = run(&ds, &cfg, None);
        // KL decreases over the run (compare first/last after exaggeration)
        let post: Vec<&TsneLogEntry> =
            res.log.iter().filter(|e| e.iter >= 100).collect();
        assert!(post.len() >= 2);
        assert!(
            post.last().unwrap().kl < post[0].kl + 1e-9,
            "KL not decreasing: {:?}",
            res.log
        );
        // class separation in the embedding: same-label mean distance <
        // cross-label mean distance
        let e = &res.embedding;
        let labels = e.labels.as_ref().unwrap();
        let (mut same, mut diff, mut ns, mut nd) = (0.0f64, 0.0f64, 0usize, 0usize);
        for i in 0..e.n() {
            for j in (i + 1)..e.n().min(i + 40) {
                let dd = e.sqdist(i, j) as f64;
                if labels[i] == labels[j] {
                    same += dd;
                    ns += 1;
                } else {
                    diff += dd;
                    nd += 1;
                }
            }
        }
        assert!(
            same / ns as f64 * 1.5 < diff / nd as f64,
            "no separation: same {} diff {}",
            same / ns as f64,
            diff / nd as f64
        );
    }
}
