//! The paper's case studies (§3) as first-class applications — t-SNE with
//! hierarchically-reordered attractive-force interactions, mean shift
//! with cadenced re-clustering — plus kernel ridge regression over the
//! full-kernel (near + compressed far field) operator.

pub mod krr;
pub mod meanshift;
pub mod tsne;
