//! The paper's case studies (§3) as first-class applications: t-SNE with
//! hierarchically-reordered attractive-force interactions, and mean shift
//! with cadenced re-clustering.

pub mod meanshift;
pub mod tsne;
