//! Mean shift (Fukunaga–Hostetler 1975; Comaniciu–Meer 2002) with the
//! paper's hierarchical interaction engine (§3.2).
//!
//! Sources are stationary; the target means migrate, so the interaction
//! profile changes across iterations.  Following the paper ("the data
//! clustering on the target set needs not to be updated as frequently"),
//! the kNN profile + target tree + CSB structure are rebuilt every
//! `refresh_every` iterations; in between, only values are recomputed
//! (fused with the multiply by the engine).
//!
//! Each step is one batched d+1-column block product
//! ([`Engine::meanshift_step`]): dense blocks multiply the materialized
//! Gaussian weights against the augmented sources `[s | 1]`, so the
//! numerator coordinates and the denominator row sums come out of a single
//! micro-GEMM pass per block.

use crate::csb::hier::HierCsb;
use crate::csb::kernel::KernelKind;
use crate::csb::update::{update_par, SideDelta};
use crate::data::dataset::Dataset;
use crate::interact::engine::Engine;
use crate::knn::ann::forest::{knn_cross_with_forest, PcaForest};
use crate::knn::exact::KnnGraph;
use crate::knn::KnnBackend;
use crate::obs::{self, counters, Counter};
use crate::order::invert;
use crate::par::pool::ThreadPool;
use crate::sparse::csr::Csr;
use crate::tree::boxtree::BoxTree;
use crate::tree::update::{update_tree, UpdateBatch};

/// Mean-shift configuration.
#[derive(Clone, Debug)]
pub struct MeanShiftConfig {
    /// Gaussian kernel bandwidth h (weights exp(−‖t−s‖²/(2h²))).
    pub bandwidth: f64,
    /// Neighbors per target in the interaction profile.
    pub k: usize,
    pub max_iters: usize,
    /// Convergence: stop when the max shift norm < tol.
    pub tol: f64,
    /// Profile/tree refresh cadence (iterations).
    pub refresh_every: usize,
    /// Mode merge radius (defaults to bandwidth when 0).
    pub merge_radius: f64,
    pub threads: usize,
    /// Build-side workers of the per-refresh rebuild (target tree + CSB
    /// assembly): 0 = follow `threads`.  Bit-identical across counts.
    pub build_threads: usize,
    pub leaf_cap: usize,
    /// kNN backend for the target→source profile (exact or approximate).
    pub knn: KnnBackend,
    /// Apply kernel (`Scalar` pins the bit-exact reference path).
    pub kernel: KernelKind,
    /// Incremental profile refresh: instead of rebuilding the target tree
    /// + profile + CSB from scratch every `refresh_every` iterations,
    /// delete + reinsert only the targets displaced beyond `tol` since
    /// their last (re)insertion (`tree::update`), recompute kNN only for
    /// those rows, and patch the CSB arenas (`csb::update`).  Near
    /// convergence most targets sit still, so refreshes get cheaper as
    /// the iteration proceeds.
    pub incremental: bool,
}

impl MeanShiftConfig {
    /// Build-side worker count: explicit `build_threads`, else `threads`
    /// (either may be 0 = machine default).
    fn resolved_build_threads(&self) -> usize {
        if self.build_threads != 0 {
            self.build_threads
        } else {
            self.threads
        }
    }
}

impl Default for MeanShiftConfig {
    fn default() -> Self {
        MeanShiftConfig {
            bandwidth: 0.2,
            k: 32,
            max_iters: 60,
            tol: 1e-5,
            refresh_every: 5,
            merge_radius: 0.0,
            threads: 0,
            build_threads: 0,
            leaf_cap: 128,
            knn: KnnBackend::Exact,
            kernel: KernelKind::Auto,
            incremental: false,
        }
    }
}

/// Result: converged means, mode centers, and per-point mode assignment.
pub struct MeanShiftResult {
    /// Final target positions (original point order).
    pub means: Dataset,
    /// Distinct mode centers.
    pub modes: Vec<Vec<f32>>,
    /// Mode index per point.
    pub assignment: Vec<usize>,
    pub iterations: usize,
}

/// The cross-interaction structure rebuilt on each refresh.
struct Structure {
    engine: Engine,
    /// Target permutation (tree order) used for this structure.
    tperm: Vec<usize>,
    /// Source coordinates in source-tree order (fixed).
    scoords: Vec<f32>,
}

/// Target→source kNN with the configured backend.  The ANN path reuses
/// the cached source forest (sources are stationary across refreshes);
/// (Ann, None) would rebuild it per call, and `run()` always passes the
/// cache for the Ann backend, so in practice that arm is the exact path.
fn cross_knn(
    targets_ordered: &Dataset,
    sources_ordered: &Dataset,
    cfg: &MeanShiftConfig,
    src_forest: Option<&PcaForest>,
) -> KnnGraph {
    match (&cfg.knn, src_forest) {
        (KnnBackend::Ann(p), Some(f)) => knn_cross_with_forest(
            targets_ordered,
            sources_ordered,
            f,
            cfg.k,
            p,
            cfg.threads,
            false,
        ),
        _ => cfg
            .knn
            .build_cross(targets_ordered, sources_ordered, cfg.k, cfg.threads, false),
    }
}

fn build_structure(
    targets: &Dataset,
    sources_ordered: &Dataset,
    stree: &BoxTree,
    cfg: &MeanShiftConfig,
    src_forest: Option<&PcaForest>,
) -> Structure {
    // Target tree over current means — rebuilt every refresh, so this is
    // the hot build path the parallel construction exists for.
    let build_threads = cfg.resolved_build_threads();
    let ttree = BoxTree::build_par(targets, 16, 32, build_threads);
    let tperm = ttree.perm.clone();
    let tpos = invert(&tperm);
    // kNN of (reordered) targets against (already ordered) sources.
    let targets_ordered = targets.permuted(&tperm);
    let g = cross_knn(&targets_ordered, sources_ordered, cfg, src_forest);
    let a = Csr::from_knn(&g, sources_ordered.n());
    let _ = tpos;
    let csb = HierCsb::build_par(
        &a,
        &ttree_identity(&ttree),
        stree,
        cfg.leaf_cap,
        build_threads,
    );
    Structure {
        engine: Engine::with_kernel(csb, cfg.threads, cfg.kernel),
        tperm,
        scoords: sources_ordered.raw().to_vec(),
    }
}

/// The kNN graph above is built on *already tree-ordered* targets, so the
/// row ordering is the identity over tree positions; reuse the tree but
/// with spans as-is.
fn ttree_identity(t: &BoxTree) -> BoxTree {
    t.clone()
}

/// Incrementally maintained cross-interaction structure (`incremental`
/// mode).  Holds, besides the engine, everything the next refresh patches
/// against: the target tree and its backing dataset (each target's
/// coordinates as of its last (re)insertion), the external-row →
/// original-point mapping, and the tree-ordered profile CSR.
struct IncStructure {
    engine: Engine,
    ttree: BoxTree,
    /// Target dataset backing `ttree`, external (insertion) order.
    tds: Dataset,
    /// External row → original point id (reinsertion moves a point to the
    /// end of the external order, so this drifts from identity).
    orig: Vec<usize>,
    /// Profile CSR: target tree rows × source tree cols.
    a: Csr,
    /// Tree position → original point id (the gather/scatter permutation).
    tperm: Vec<usize>,
    scoords: Vec<f32>,
}

fn build_inc(
    targets: &Dataset,
    sources_ordered: &Dataset,
    stree: &BoxTree,
    cfg: &MeanShiftConfig,
    src_forest: Option<&PcaForest>,
) -> IncStructure {
    let build_threads = cfg.resolved_build_threads();
    let tds = targets.clone();
    let ttree = BoxTree::build_par(&tds, 16, 32, build_threads);
    let targets_ordered = tds.permuted(&ttree.perm);
    let g = cross_knn(&targets_ordered, sources_ordered, cfg, src_forest);
    let a = Csr::from_knn(&g, sources_ordered.n());
    let csb = HierCsb::build_par(&a, &ttree, stree, cfg.leaf_cap, build_threads);
    let engine = Engine::with_kernel(csb, cfg.threads, cfg.kernel);
    let orig: Vec<usize> = (0..tds.n()).collect();
    let tperm = ttree.perm.clone();
    IncStructure {
        engine,
        ttree,
        tds,
        orig,
        a,
        tperm,
        scoords: sources_ordered.raw().to_vec(),
    }
}

/// Incremental refresh: delete + reinsert only the targets displaced more
/// than `tol` since their last (re)insertion, recompute kNN only for those
/// rows (unmoved rows keep their profile — sources are stationary), patch
/// the CSB arenas, and recompile the schedule.  Near convergence most
/// targets sit still, so this degenerates to a no-op; early on, when the
/// hull itself moves, the tree update falls back to a full rebuild and the
/// refresh degrades gracefully to the from-scratch path.
fn refresh_inc(
    s: IncStructure,
    means: &Dataset,
    sources_ordered: &Dataset,
    stree: &BoxTree,
    cfg: &MeanShiftConfig,
    src_forest: Option<&PcaForest>,
) -> IncStructure {
    let d = means.d();
    let build_threads = cfg.resolved_build_threads();
    let eps2 = (cfg.tol * cfg.tol) as f32;
    let mut deletes: Vec<usize> = Vec::new();
    let mut moved: Vec<usize> = Vec::new(); // original ids, batch order
    for ext in 0..s.tds.n() {
        let o = s.orig[ext];
        let mut d2 = 0.0f32;
        for (a, b) in s.tds.row(ext).iter().zip(means.row(o)) {
            let t = a - b;
            d2 += t * t;
        }
        if d2 > eps2 {
            deletes.push(ext);
            moved.push(o);
        }
    }
    if deletes.is_empty() {
        // Nothing drifted beyond tol: the structure is still current.
        return s;
    }
    let mut inserts = Vec::with_capacity(moved.len() * d);
    for &o in &moved {
        inserts.extend_from_slice(means.row(o));
    }
    let batch = UpdateBatch {
        deletes: deletes.clone(),
        inserts,
    };
    let tu = update_tree(&s.ttree, &s.tds, &batch, 32, build_threads);

    // External-row identity after delete-compaction + append.
    let mut orig = Vec::with_capacity(tu.ds.n());
    let mut di = 0usize;
    for (ext, &o) in s.orig.iter().enumerate() {
        if di < deletes.len() && deletes[di] == ext {
            di += 1;
        } else {
            orig.push(o);
        }
    }
    orig.extend_from_slice(&moved);
    debug_assert_eq!(orig.len(), tu.ds.n());

    // Profile: rows of surviving (sub-tol) targets are copied from the old
    // CSR at their old tree position; reinserted rows recompute kNN.
    let tdelta = SideDelta::from_update(&s.ttree, &tu);
    let n_new = tu.tree.n();
    let fresh_pos: Vec<usize> = (0..n_new)
        .filter(|&i| tdelta.pos_map[i] == u32::MAX)
        .collect();
    let a_new = {
        let mut xs = Vec::with_capacity(fresh_pos.len() * d);
        for &i in &fresh_pos {
            xs.extend_from_slice(tu.ds.row(tu.tree.perm[i]));
        }
        let moved_ds = Dataset::new(fresh_pos.len(), d, xs);
        let g = cross_knn(&moved_ds, sources_ordered, cfg, src_forest);
        let a_moved = Csr::from_knn(&g, sources_ordered.n());
        splice_profile(&s.a, &a_moved, &tdelta.pos_map, sources_ordered.n())
    };

    let csb = if tu.full_rebuild {
        HierCsb::build_par(&a_new, &tu.tree, stree, cfg.leaf_cap, build_threads)
    } else {
        let sdelta = SideDelta::identity(stree);
        update_par(
            &s.engine.csb,
            &s.a,
            &a_new,
            &tu.tree,
            &tdelta,
            stree,
            &sdelta,
            cfg.leaf_cap,
            build_threads,
        )
    };
    let engine = Engine::with_kernel(csb, cfg.threads, cfg.kernel);
    let tperm: Vec<usize> = tu.tree.perm.iter().map(|&e| orig[e]).collect();
    IncStructure {
        engine,
        ttree: tu.tree,
        tds: tu.ds,
        orig,
        a: a_new,
        tperm,
        scoords: s.scoords,
    }
}

/// Row-splice of the refreshed profile: rows with an old tree position
/// copy from `a_old`; inserted rows take the next row of `a_fresh` (whose
/// rows are in ascending new-tree-position order).
fn splice_profile(a_old: &Csr, a_fresh: &Csr, pos_map: &[u32], cols: usize) -> Csr {
    let n = pos_map.len();
    let mut ptr = vec![0u32; n + 1];
    let mut fresh_row = vec![usize::MAX; n];
    let mut fi = 0usize;
    for i in 0..n {
        let len = if pos_map[i] == u32::MAX {
            fresh_row[i] = fi;
            fi += 1;
            a_fresh.ptr[fresh_row[i] + 1] - a_fresh.ptr[fresh_row[i]]
        } else {
            let o = pos_map[i] as usize;
            a_old.ptr[o + 1] - a_old.ptr[o]
        };
        ptr[i + 1] = ptr[i] + len;
    }
    assert_eq!(fi, a_fresh.rows, "every fresh row must be consumed");
    let nnz = ptr[n] as usize;
    let mut col = Vec::with_capacity(nnz);
    let mut val = Vec::with_capacity(nnz);
    for i in 0..n {
        let (src, lo, hi) = if pos_map[i] == u32::MAX {
            let f = fresh_row[i];
            (a_fresh, a_fresh.ptr[f] as usize, a_fresh.ptr[f + 1] as usize)
        } else {
            let o = pos_map[i] as usize;
            (a_old, a_old.ptr[o] as usize, a_old.ptr[o + 1] as usize)
        };
        col.extend_from_slice(&src.col[lo..hi]);
        val.extend_from_slice(&src.val[lo..hi]);
    }
    Csr {
        rows: n,
        cols,
        ptr,
        col,
        val,
    }
}

/// Run mean shift over `data` (sources = initial targets).
pub fn run(data: &Dataset, cfg: &MeanShiftConfig) -> MeanShiftResult {
    let n = data.n();
    let d = data.d();
    let inv_h2 = (1.0 / (2.0 * cfg.bandwidth * cfg.bandwidth)) as f32;

    // Fixed source structure.
    let stree = BoxTree::build_par(data, 16, 32, cfg.resolved_build_threads());
    let sources_ordered = data.permuted(&stree.perm);

    // ANN backend: the source forest depends only on the stationary
    // sources — build it once and reuse it for every profile refresh.
    let src_forest: Option<PcaForest> = match &cfg.knn {
        KnnBackend::Ann(p) => {
            let pool = ThreadPool::new_or_default(cfg.threads);
            Some(PcaForest::build(&sources_ordered, p, &pool))
        }
        KnnBackend::Exact => None,
    };

    // Current means, original order.
    let mut means = data.clone();
    let mut iterations = 0;
    let mut structure: Option<Structure> = None;
    let mut inc: Option<IncStructure> = None;
    // Hoisted per-iteration buffers: the apply loop is allocation-free in
    // steady state (the engine owns its own kernel scratch the same way).
    let mut tcoords: Vec<f32> = Vec::new();
    let mut num: Vec<f32> = Vec::new();
    let mut den: Vec<f32> = Vec::new();
    let mut new_tree: Vec<f32> = Vec::new();

    for it in 0..cfg.max_iters {
        obs::span!("meanshift.iter");
        counters::add(Counter::MeanshiftIterations, 1);
        iterations = it + 1;
        let refresh = it % cfg.refresh_every.max(1) == 0;
        let (engine, tperm, scoords): (&Engine, &[usize], &[f32]) = if cfg.incremental {
            if inc.is_none() || refresh {
                obs::span!("meanshift.refresh");
                inc = Some(match inc.take() {
                    None => build_inc(&means, &sources_ordered, &stree, cfg, src_forest.as_ref()),
                    Some(prev) => {
                        refresh_inc(prev, &means, &sources_ordered, &stree, cfg, src_forest.as_ref())
                    }
                });
            }
            let s = inc.as_ref().unwrap();
            (&s.engine, &s.tperm, &s.scoords)
        } else {
            if structure.is_none() || refresh {
                obs::span!("meanshift.refresh");
                structure = Some(build_structure(
                    &means,
                    &sources_ordered,
                    &stree,
                    cfg,
                    src_forest.as_ref(),
                ));
            }
            let s = structure.as_ref().unwrap();
            (&s.engine, &s.tperm, &s.scoords)
        };

        // tree-ordered target coordinates
        crate::csb::layout::rows_to_tree_order_into(means.raw(), d, tperm, &mut tcoords);
        engine.meanshift_step_into(&tcoords, scoords, d, inv_h2, &mut num, &mut den);

        // shift: m_i <- num_i / den_i  (tree order), then scatter back
        let mut max_shift2 = 0.0f64;
        new_tree.clear();
        new_tree.resize(n * d, 0.0);
        for i in 0..n {
            let dn = den[i].max(1e-30);
            let mut s2 = 0.0f64;
            for k in 0..d {
                let nv = num[i * d + k] / dn;
                let delta = nv - tcoords[i * d + k];
                s2 += (delta as f64) * (delta as f64);
                new_tree[i * d + k] = nv;
            }
            max_shift2 = max_shift2.max(s2);
        }
        // scatter the shifted means straight back into the dataset buffer
        crate::csb::layout::rows_from_tree_order_into(&new_tree, d, tperm, means.raw_mut());
        if max_shift2.sqrt() < cfg.tol {
            break;
        }
    }

    // Mode extraction: greedy merge within merge_radius.
    let radius = if cfg.merge_radius > 0.0 {
        cfg.merge_radius
    } else {
        cfg.bandwidth
    };
    let r2 = (radius * radius) as f32;
    let mut modes: Vec<Vec<f32>> = Vec::new();
    let mut assignment = vec![0usize; n];
    for i in 0..n {
        let row = means.row(i);
        let mut found = None;
        for (m, c) in modes.iter().enumerate() {
            let mut d2 = 0.0f32;
            for k in 0..d {
                let t = row[k] - c[k];
                d2 += t * t;
            }
            if d2 <= r2 {
                found = Some(m);
                break;
            }
        }
        match found {
            Some(m) => assignment[i] = m,
            None => {
                assignment[i] = modes.len();
                modes.push(row.to_vec());
            }
        }
    }

    MeanShiftResult {
        means,
        modes,
        assignment,
        iterations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn finds_blob_modes() {
        let ds = SynthSpec::blobs(300, 2, 3, 77).generate();
        let cfg = MeanShiftConfig {
            bandwidth: 0.25,
            k: 24,
            max_iters: 40,
            refresh_every: 4,
            threads: 4,
            ..Default::default()
        };
        let res = run(&ds, &cfg);
        // 3 well-separated blobs → exactly 3 modes
        assert_eq!(res.modes.len(), 3, "modes: {:?}", res.modes.len());
        // assignment must agree with ground-truth labels up to relabeling
        let labels = ds.labels.as_ref().unwrap();
        let mut map = std::collections::HashMap::new();
        let mut agree = 0usize;
        for i in 0..ds.n() {
            let m = *map.entry(labels[i]).or_insert(res.assignment[i]);
            if m == res.assignment[i] {
                agree += 1;
            }
        }
        assert!(
            agree as f64 > 0.95 * ds.n() as f64,
            "purity {}",
            agree as f64 / ds.n() as f64
        );
    }

    #[test]
    fn converges_within_tol() {
        let ds = SynthSpec::blobs(150, 3, 2, 5).generate();
        let cfg = MeanShiftConfig {
            bandwidth: 0.3,
            k: 20,
            max_iters: 100,
            tol: 1e-4,
            threads: 2,
            ..Default::default()
        };
        let res = run(&ds, &cfg);
        assert!(res.iterations < 100, "did not converge: {}", res.iterations);
        assert_eq!(res.modes.len(), 2);
    }

    #[test]
    fn ann_backend_finds_blob_modes() {
        let ds = SynthSpec::blobs(300, 2, 3, 77).generate();
        let cfg = MeanShiftConfig {
            bandwidth: 0.25,
            k: 24,
            max_iters: 40,
            refresh_every: 4,
            threads: 4,
            knn: KnnBackend::ann_default(),
            ..Default::default()
        };
        let res = run(&ds, &cfg);
        assert_eq!(res.modes.len(), 3, "modes: {:?}", res.modes.len());
    }

    #[test]
    fn incremental_matches_full_rebuild_modes() {
        let ds = SynthSpec::blobs(300, 2, 3, 77).generate();
        let mk = |incremental: bool| MeanShiftConfig {
            bandwidth: 0.25,
            k: 24,
            max_iters: 40,
            refresh_every: 4,
            threads: 2,
            kernel: KernelKind::Scalar,
            incremental,
            ..Default::default()
        };
        let batches_before = counters::get(Counter::UpdateBatches);
        let full = run(&ds, &mk(false));
        let inc = run(&ds, &mk(true));
        // The incremental path must actually route refreshes through the
        // update machinery (the means move early on, so batches are
        // non-empty well before convergence).
        assert!(
            counters::get(Counter::UpdateBatches) > batches_before,
            "incremental run never issued an update batch"
        );
        assert_eq!(inc.modes.len(), full.modes.len(), "mode count");
        // Every full-rebuild mode center has an incremental twin well
        // within the merge radius.
        for c in &full.modes {
            let best = inc
                .modes
                .iter()
                .map(|m| {
                    m.iter()
                        .zip(c)
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum::<f32>()
                })
                .fold(f32::INFINITY, f32::min);
            assert!(
                (best.sqrt() as f64) < 0.5 * 0.25,
                "mode center {c:?} has no incremental twin (nearest at {})",
                best.sqrt()
            );
        }
        // Assignments agree up to relabeling on ≥95% of points.
        let mut map = std::collections::HashMap::new();
        let mut agree = 0usize;
        for i in 0..ds.n() {
            let m = *map.entry(full.assignment[i]).or_insert(inc.assignment[i]);
            if m == inc.assignment[i] {
                agree += 1;
            }
        }
        assert!(
            agree * 100 >= 95 * ds.n(),
            "assignment agreement {}/{}",
            agree,
            ds.n()
        );
    }

    #[test]
    fn identical_points_single_mode() {
        let ds = Dataset::new(40, 2, vec![0.25; 80]);
        let cfg = MeanShiftConfig {
            bandwidth: 0.1,
            k: 8,
            max_iters: 10,
            threads: 1,
            ..Default::default()
        };
        let res = run(&ds, &cfg);
        assert_eq!(res.modes.len(), 1);
        assert!(res.assignment.iter().all(|&a| a == 0));
    }
}
