//! Kernel ridge regression over the compressed full-kernel operator.
//!
//! Solves `(K + λI)·α = y` with `K_ij = exp(−‖x_i − x_j‖²/h²)` over **all**
//! `n²` pairs by conjugate gradients, where every matvec runs through
//! [`FullKernelEngine`] — near field as dense `HierCsb` blocks, far field
//! as ACA low-rank factors — instead of the O(n²) dense matrix.  This is
//! the workload the kNN-truncated pipeline cannot serve: ridge regression
//! needs the *full* kernel (dropping the far field biases the smoother),
//! and the compressed operator delivers it at near-linear storage.
//!
//! The solve runs in f32 (the system's native precision) with f64 scalar
//! accumulation in the CG dot products; `λ` bounds the condition number,
//! so CG converges to the dense-oracle solution within the compression
//! tolerance (`rust/tests/full_kernel.rs` checks against an f64 dense
//! solve).
//!
//! CLI: `nni krr` (see `main.rs`); `--far off` degrades to the truncated
//! near-field baseline for comparison.

use crate::csb::kernel::KernelKind;
use crate::data::dataset::Dataset;
use crate::embed::pca::pca_par;
use crate::hmat::aca::dot64;
use crate::hmat::{FarFieldMode, FullKernelConfig, FullKernelEngine};
use crate::obs::{self, counters, Counter};
use crate::order::dualtree;
use crate::util::rng::Rng;

/// KRR hyper-parameters.
#[derive(Clone, Debug)]
pub struct KrrConfig {
    /// Gaussian bandwidth `h` (0 = auto: median pairwise distance of a
    /// 256-point sample, [`suggest_bandwidth`]).
    pub bandwidth: f64,
    /// Ridge regularization λ (also the CG conditioner — don't set ≪
    /// the ACA tolerance times the kernel norm or compression noise
    /// dominates the solution).
    pub lambda: f64,
    /// Far-field handling (`Off` = truncated near-field baseline).
    pub far: FarFieldMode,
    /// ACA relative tolerance per far block.
    pub tol: f64,
    /// Admissibility parameter η.
    pub eta: f64,
    /// Leaf blocking capacity of the tree cut (0 = `HierCsb` default).
    pub block_cap: usize,
    /// Ordering-tree leaf capacity (fine-grained locality).
    pub leaf_cap: usize,
    /// CG stop: relative residual `‖r‖/‖y‖`.
    pub cg_tol: f64,
    pub cg_max_iters: usize,
    /// Apply-side workers (0 = machine default).
    pub threads: usize,
    /// Build-side workers (0 = follow `threads`).
    pub build_threads: usize,
    pub kernel: KernelKind,
    pub seed: u64,
}

impl Default for KrrConfig {
    fn default() -> Self {
        KrrConfig {
            bandwidth: 0.0,
            lambda: 1.0,
            far: FarFieldMode::Aca,
            tol: 1e-3,
            eta: 1.0,
            block_cap: 0,
            leaf_cap: 16,
            cg_tol: 1e-6,
            cg_max_iters: 500,
            threads: 0,
            build_threads: 0,
            kernel: KernelKind::Auto,
            seed: 42,
        }
    }
}

/// KRR outcome.
#[derive(Clone, Debug)]
pub struct KrrResult {
    /// Dual weights in **original** index order.
    pub alpha: Vec<f32>,
    /// CG iterations spent.
    pub iterations: usize,
    /// Final relative residual `‖y − (K+λI)α‖ / ‖y‖`.
    pub rel_residual: f64,
    /// Training RMSE of the smoother `f = K·α` against `y`.
    pub train_rmse: f64,
    /// Bandwidth actually used (resolves the auto heuristic).
    pub bandwidth: f64,
    /// Engine stats (`FullKernelEngine::describe`).
    pub summary: String,
}

/// Median pairwise distance over a ≤256-point sample — the standard
/// Gaussian-bandwidth default when the caller has no better prior.
pub fn suggest_bandwidth(ds: &Dataset, seed: u64) -> f64 {
    let n = ds.n();
    assert!(n >= 2, "bandwidth heuristic needs at least 2 points");
    let mut rng = Rng::new(seed ^ 0x5EED_BA5E);
    let m = n.min(256);
    let idx = rng.sample_distinct(n, m);
    let mut dists: Vec<f64> = Vec::with_capacity(m * (m - 1) / 2);
    for a in 0..m {
        for b in a + 1..m {
            dists.push((ds.sqdist(idx[a], idx[b]) as f64).sqrt());
        }
    }
    dists.sort_by(|a, b| a.total_cmp(b));
    let med = dists[dists.len() / 2];
    if med > 0.0 {
        med
    } else {
        1.0 // all sampled points identical; any positive h works
    }
}

/// A smooth synthetic regression target for demos/benches: `sin(3·u)` on
/// the leading principal coordinate, plus a little seeded noise.
pub fn synthetic_targets(ds: &Dataset, seed: u64) -> Vec<f32> {
    let p = pca_par(ds, 1, 10, seed, 0);
    let u = p.project(ds, 1);
    // scale to unit-ish range so the sine sweeps a couple of periods
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for i in 0..u.n() {
        lo = lo.min(u.row(i)[0]);
        hi = hi.max(u.row(i)[0]);
    }
    let span = (hi - lo).max(1e-6);
    let mut rng = Rng::new(seed ^ 0x7A66E75);
    (0..u.n())
        .map(|i| {
            let t = (u.row(i)[0] - lo) / span;
            (3.0 * std::f32::consts::TAU * t).sin() + 0.02 * rng.normal() as f32
        })
        .collect()
}

/// Run KRR: order, compress, solve.  `targets` is in original index order
/// (as is the returned `alpha`).
pub fn run(ds: &Dataset, targets: &[f32], cfg: &KrrConfig) -> KrrResult {
    obs::span!("krr.run");
    let n = ds.n();
    assert_eq!(targets.len(), n, "one target per point");
    assert!(n >= 2, "krr needs at least 2 points");
    assert!(cfg.lambda > 0.0, "ridge needs positive lambda");
    let h = if cfg.bandwidth > 0.0 {
        cfg.bandwidth
    } else {
        suggest_bandwidth(ds, cfg.seed)
    };
    let inv_h2 = (1.0 / (h * h)) as f32;

    // Ordering: 3-D PCA embedding (pass-through when already ≤ 3-D) +
    // dual tree.  No kNN profile is needed — the full-kernel engine
    // derives near and far structure from the tree alone.
    let build_threads = if cfg.build_threads != 0 { cfg.build_threads } else { cfg.threads };
    let embedded = if ds.d() <= 3 {
        ds.clone()
    } else {
        pca_par(ds, 3, 10, cfg.seed, build_threads).project(ds, 3)
    };
    let (perm, tree) = dualtree::order_par(&embedded, cfg.leaf_cap, build_threads);
    let coords = ds.permuted(&perm);

    let fk = FullKernelConfig::new(inv_h2)
        .with_eta(cfg.eta as f32)
        .with_tol(cfg.tol as f32)
        .with_block_cap(cfg.block_cap)
        .with_far(cfg.far);
    let eng = FullKernelEngine::build(
        &tree,
        coords.raw(),
        ds.d(),
        &fk,
        build_threads,
        cfg.threads,
        cfg.kernel,
    );

    // Targets into tree order, solve, and back.
    let b: Vec<f32> = perm.iter().map(|&p| targets[p]).collect();
    let (alpha_t, iterations, rel_residual) = {
        obs::span!("krr.cg_solve");
        cg_solve(&eng, &b, cfg.lambda as f32, cfg.cg_tol, cfg.cg_max_iters)
    };

    // Training RMSE of the smoother f = K·α (= (K+λI)α − λα).
    let mut f = vec![0.0f32; n];
    eng.spmv(&alpha_t, &mut f);
    let mse: f64 = f
        .iter()
        .zip(&b)
        .map(|(&fi, &yi)| (fi as f64 - yi as f64) * (fi as f64 - yi as f64))
        .sum::<f64>()
        / n as f64;

    let mut alpha = vec![0.0f32; n];
    for (k, &p) in perm.iter().enumerate() {
        alpha[p] = alpha_t[k];
    }
    KrrResult {
        alpha,
        iterations,
        rel_residual,
        train_rmse: mse.sqrt(),
        bandwidth: h,
        summary: eng.describe(),
    }
}

/// Conjugate gradients on `(K + λI)·α = b` over the compressed operator:
/// f32 vectors, f64 scalars.  Returns (solution, iterations, relative
/// residual).
pub fn cg_solve(
    eng: &FullKernelEngine,
    b: &[f32],
    lambda: f32,
    tol: f64,
    max_iters: usize,
) -> (Vec<f32>, usize, f64) {
    let n = b.len();
    assert_eq!(n, eng.n());
    let bnorm = dot64(b, b).sqrt();
    let mut x = vec![0.0f32; n];
    if bnorm == 0.0 {
        return (x, 0, 0.0);
    }
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut ap = vec![0.0f32; n];
    let mut rs = dot64(&r, &r);
    let mut iters = 0usize;
    while iters < max_iters && rs.sqrt() > tol * bnorm {
        eng.spmv(&p, &mut ap);
        for (a, &pv) in ap.iter_mut().zip(&p) {
            *a += lambda * pv;
        }
        let pap = dot64(&p, &ap);
        if !pap.is_finite() || pap <= 0.0 {
            // K̃ lost positive-definiteness at the f32/ACA noise floor —
            // stop with the best iterate rather than diverge.
            break;
        }
        let step = (rs / pap) as f32;
        for (xi, &pv) in x.iter_mut().zip(&p) {
            *xi += step * pv;
        }
        for (ri, &av) in r.iter_mut().zip(&ap) {
            *ri -= step * av;
        }
        let rs_new = dot64(&r, &r);
        let beta = (rs_new / rs) as f32;
        for (pi, &ri) in p.iter_mut().zip(&r) {
            *pi = ri + beta * *pi;
        }
        rs = rs_new;
        iters += 1;
    }
    counters::add(Counter::CgIterations, iters as u64);
    (x, iters, rs.sqrt() / bnorm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn krr_converges_on_clustered_data() {
        let ds = SynthSpec::blobs(500, 3, 4, 77).generate();
        let y = synthetic_targets(&ds, 1);
        let cfg = KrrConfig {
            lambda: 0.5,
            tol: 1e-4,
            block_cap: 64,
            cg_tol: 1e-6,
            threads: 2,
            kernel: KernelKind::Scalar,
            ..KrrConfig::default()
        };
        let res = run(&ds, &y, &cfg);
        assert!(res.iterations > 0);
        assert!(
            res.rel_residual < 1e-4,
            "CG residual {} after {} iters ({})",
            res.rel_residual,
            res.iterations,
            res.summary
        );
        assert!(res.bandwidth > 0.0);
        // the smoother interpolates a smooth target reasonably under a
        // moderate ridge
        assert!(res.train_rmse < 0.5, "train rmse {}", res.train_rmse);
    }

    #[test]
    fn far_field_changes_the_solution() {
        // The truncated baseline and the full kernel must disagree —
        // otherwise the far field contributed nothing and the workload
        // didn't need this subsystem.
        let ds = SynthSpec::blobs(400, 3, 4, 5).generate();
        let y = synthetic_targets(&ds, 2);
        let base = KrrConfig {
            lambda: 0.5,
            block_cap: 64,
            threads: 2,
            kernel: KernelKind::Scalar,
            ..KrrConfig::default()
        };
        let full = run(&ds, &y, &base);
        let off = run(
            &ds,
            &y,
            &KrrConfig {
                far: FarFieldMode::Off,
                ..base
            },
        );
        let diff: f64 = full
            .alpha
            .iter()
            .zip(&off.alpha)
            .map(|(&a, &b)| (a as f64 - b as f64).abs())
            .sum();
        assert!(diff > 1e-6, "far field had no effect on the solution");
    }

    #[test]
    fn zero_targets_solve_to_zero() {
        let ds = SynthSpec::blobs(200, 3, 3, 9).generate();
        let y = vec![0.0f32; 200];
        let res = run(
            &ds,
            &y,
            &KrrConfig {
                block_cap: 64,
                threads: 2,
                ..KrrConfig::default()
            },
        );
        assert_eq!(res.iterations, 0);
        assert!(res.alpha.iter().all(|&a| a == 0.0));
    }

    #[test]
    fn suggest_bandwidth_positive_and_scale_aware() {
        let small = SynthSpec::blobs(300, 3, 3, 4).generate();
        let h1 = suggest_bandwidth(&small, 7);
        assert!(h1 > 0.0 && h1.is_finite());
        // scaling the data scales the suggestion
        let mut scaled = small.clone();
        for v in scaled.raw_mut() {
            *v *= 10.0;
        }
        let h2 = suggest_bandwidth(&scaled, 7);
        assert!(
            (h2 / h1 - 10.0).abs() < 0.5,
            "bandwidth not scale-aware: {h1} vs {h2}"
        );
    }
}
