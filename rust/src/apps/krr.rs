//! Kernel ridge regression over the compressed full-kernel operator.
//!
//! Solves `(K + λI)·α = y` with `K_ij = exp(−‖x_i − x_j‖²/h²)` over **all**
//! `n²` pairs by conjugate gradients, where every matvec runs through
//! [`FullKernelEngine`] — near field as dense `HierCsb` blocks, far field
//! as ACA low-rank factors — instead of the O(n²) dense matrix.  This is
//! the workload the kNN-truncated pipeline cannot serve: ridge regression
//! needs the *full* kernel (dropping the far field biases the smoother),
//! and the compressed operator delivers it at near-linear storage.
//!
//! The solve runs in f32 (the system's native precision) with f64 scalar
//! accumulation in the CG dot products; `λ` bounds the condition number,
//! so CG converges to the dense-oracle solution within the compression
//! tolerance (`rust/tests/full_kernel.rs` checks against an f64 dense
//! solve).
//!
//! With `--far h2 --precond`, the CG solve is preconditioned by a
//! Nyström approximation built on the H² **leaf skeletons** — the rows
//! the far-field compression itself singled out as spanning the kernel's
//! range.  `M = λI + B·Bᵀ` with `B = K(X,L)·chol(K(L,L))⁻ᵀ` over ≤ 128
//! landmarks `L`; `M⁻¹` applies in O(n·m) via Woodbury, and the
//! preconditioned iteration count drops well below plain CG
//! (`rust/tests/full_kernel.rs` asserts strictly fewer iterations at the
//! same accuracy).
//!
//! CLI: `nni krr` (see `main.rs`); `--far off` degrades to the truncated
//! near-field baseline for comparison.

use crate::csb::kernel::KernelKind;
use crate::data::dataset::Dataset;
use crate::embed::pca::pca_par;
use crate::hmat::aca::{dot64, GaussGen};
use crate::hmat::{FarFieldMode, FullKernelConfig, FullKernelEngine, Precision};
use crate::obs::{self, counters, Counter};
use crate::order::dualtree;
use crate::util::rng::Rng;

/// Landmark cap of the H²-skeleton Nyström preconditioner.
pub const NYSTROM_LANDMARK_CAP: usize = 128;

/// KRR hyper-parameters.
#[derive(Clone, Debug)]
pub struct KrrConfig {
    /// Gaussian bandwidth `h` (0 = auto: median pairwise distance of a
    /// 256-point sample, [`suggest_bandwidth`]).
    pub bandwidth: f64,
    /// Ridge regularization λ (also the CG conditioner — don't set ≪
    /// the ACA tolerance times the kernel norm or compression noise
    /// dominates the solution).
    pub lambda: f64,
    /// Far-field handling (`Off` = truncated near-field baseline).
    pub far: FarFieldMode,
    /// Far-field factor storage precision (H² only).
    pub precision: Precision,
    /// Precondition the CG solve with the H²-skeleton Nyström operator
    /// (requires `far = H2`; silently ignored otherwise).
    pub precond: bool,
    /// ACA relative tolerance per far block.
    pub tol: f64,
    /// Admissibility parameter η.
    pub eta: f64,
    /// Leaf blocking capacity of the tree cut (0 = `HierCsb` default).
    pub block_cap: usize,
    /// Ordering-tree leaf capacity (fine-grained locality).
    pub leaf_cap: usize,
    /// CG stop: relative residual `‖r‖/‖y‖`.
    pub cg_tol: f64,
    pub cg_max_iters: usize,
    /// Apply-side workers (0 = machine default).
    pub threads: usize,
    /// Build-side workers (0 = follow `threads`).
    pub build_threads: usize,
    pub kernel: KernelKind,
    pub seed: u64,
}

impl Default for KrrConfig {
    fn default() -> Self {
        KrrConfig {
            bandwidth: 0.0,
            lambda: 1.0,
            far: FarFieldMode::Aca,
            precision: Precision::F32,
            precond: false,
            tol: 1e-3,
            eta: 1.0,
            block_cap: 0,
            leaf_cap: 16,
            cg_tol: 1e-6,
            cg_max_iters: 500,
            threads: 0,
            build_threads: 0,
            kernel: KernelKind::Auto,
            seed: 42,
        }
    }
}

/// KRR outcome.
#[derive(Clone, Debug)]
pub struct KrrResult {
    /// Dual weights in **original** index order.
    pub alpha: Vec<f32>,
    /// CG iterations spent.
    pub iterations: usize,
    /// Final relative residual `‖y − (K+λI)α‖ / ‖y‖`.
    pub rel_residual: f64,
    /// Training RMSE of the smoother `f = K·α` against `y`.
    pub train_rmse: f64,
    /// Bandwidth actually used (resolves the auto heuristic).
    pub bandwidth: f64,
    /// Engine stats (`FullKernelEngine::describe`).
    pub summary: String,
}

/// Median pairwise distance over a ≤256-point sample — the standard
/// Gaussian-bandwidth default when the caller has no better prior.
pub fn suggest_bandwidth(ds: &Dataset, seed: u64) -> f64 {
    let n = ds.n();
    assert!(n >= 2, "bandwidth heuristic needs at least 2 points");
    let mut rng = Rng::new(seed ^ 0x5EED_BA5E);
    let m = n.min(256);
    let idx = rng.sample_distinct(n, m);
    let mut dists: Vec<f64> = Vec::with_capacity(m * (m - 1) / 2);
    for a in 0..m {
        for b in a + 1..m {
            dists.push((ds.sqdist(idx[a], idx[b]) as f64).sqrt());
        }
    }
    dists.sort_by(|a, b| a.total_cmp(b));
    let med = dists[dists.len() / 2];
    if med > 0.0 {
        med
    } else {
        1.0 // all sampled points identical; any positive h works
    }
}

/// A smooth synthetic regression target for demos/benches: `sin(3·u)` on
/// the leading principal coordinate, plus a little seeded noise.
pub fn synthetic_targets(ds: &Dataset, seed: u64) -> Vec<f32> {
    let p = pca_par(ds, 1, 10, seed, 0);
    let u = p.project(ds, 1);
    // scale to unit-ish range so the sine sweeps a couple of periods
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for i in 0..u.n() {
        lo = lo.min(u.row(i)[0]);
        hi = hi.max(u.row(i)[0]);
    }
    let span = (hi - lo).max(1e-6);
    let mut rng = Rng::new(seed ^ 0x7A66E75);
    (0..u.n())
        .map(|i| {
            let t = (u.row(i)[0] - lo) / span;
            (3.0 * std::f32::consts::TAU * t).sin() + 0.02 * rng.normal() as f32
        })
        .collect()
}

/// Run KRR: order, compress, solve.  `targets` is in original index order
/// (as is the returned `alpha`).
pub fn run(ds: &Dataset, targets: &[f32], cfg: &KrrConfig) -> KrrResult {
    obs::span!("krr.run");
    let n = ds.n();
    assert_eq!(targets.len(), n, "one target per point");
    assert!(n >= 2, "krr needs at least 2 points");
    assert!(cfg.lambda > 0.0, "ridge needs positive lambda");
    let h = if cfg.bandwidth > 0.0 {
        cfg.bandwidth
    } else {
        suggest_bandwidth(ds, cfg.seed)
    };
    let inv_h2 = (1.0 / (h * h)) as f32;

    // Ordering: 3-D PCA embedding (pass-through when already ≤ 3-D) +
    // dual tree.  No kNN profile is needed — the full-kernel engine
    // derives near and far structure from the tree alone.
    let build_threads = if cfg.build_threads != 0 { cfg.build_threads } else { cfg.threads };
    let embedded = if ds.d() <= 3 {
        ds.clone()
    } else {
        pca_par(ds, 3, 10, cfg.seed, build_threads).project(ds, 3)
    };
    let (perm, tree) = dualtree::order_par(&embedded, cfg.leaf_cap, build_threads);
    let coords = ds.permuted(&perm);

    let fk = FullKernelConfig::new(inv_h2)
        .with_eta(cfg.eta as f32)
        .with_tol(cfg.tol as f32)
        .with_block_cap(cfg.block_cap)
        .with_far(cfg.far)
        .with_precision(cfg.precision);
    let eng = FullKernelEngine::build(
        &tree,
        coords.raw(),
        ds.d(),
        &fk,
        build_threads,
        cfg.threads,
        cfg.kernel,
    );

    let pre = if cfg.precond {
        eng.far.as_h2().and_then(|h2| {
            NystromPrecond::build(
                coords.raw(),
                ds.d(),
                inv_h2,
                &h2.landmarks(NYSTROM_LANDMARK_CAP),
                cfg.lambda,
            )
        })
    } else {
        None
    };

    // Targets into tree order, solve, and back.
    let b: Vec<f32> = perm.iter().map(|&p| targets[p]).collect();
    let (alpha_t, iterations, rel_residual) = {
        obs::span!("krr.cg_solve");
        match &pre {
            Some(p) => pcg_solve(&eng, &b, cfg.lambda as f32, cfg.cg_tol, cfg.cg_max_iters, p),
            None => cg_solve(&eng, &b, cfg.lambda as f32, cfg.cg_tol, cfg.cg_max_iters),
        }
    };

    // Training RMSE of the smoother f = K·α (= (K+λI)α − λα).
    let mut f = vec![0.0f32; n];
    eng.spmv(&alpha_t, &mut f);
    let mse: f64 = f
        .iter()
        .zip(&b)
        .map(|(&fi, &yi)| (fi as f64 - yi as f64) * (fi as f64 - yi as f64))
        .sum::<f64>()
        / n as f64;

    let mut alpha = vec![0.0f32; n];
    for (k, &p) in perm.iter().enumerate() {
        alpha[p] = alpha_t[k];
    }
    KrrResult {
        alpha,
        iterations,
        rel_residual,
        train_rmse: mse.sqrt(),
        bandwidth: h,
        summary: eng.describe(),
    }
}

/// Conjugate gradients on `(K + λI)·α = b` over the compressed operator:
/// f32 vectors, f64 scalars.  Returns (solution, iterations, relative
/// residual).
pub fn cg_solve(
    eng: &FullKernelEngine,
    b: &[f32],
    lambda: f32,
    tol: f64,
    max_iters: usize,
) -> (Vec<f32>, usize, f64) {
    let n = b.len();
    assert_eq!(n, eng.n());
    let bnorm = dot64(b, b).sqrt();
    let mut x = vec![0.0f32; n];
    if bnorm == 0.0 {
        return (x, 0, 0.0);
    }
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut ap = vec![0.0f32; n];
    let mut rs = dot64(&r, &r);
    let mut iters = 0usize;
    while iters < max_iters && rs.sqrt() > tol * bnorm {
        eng.spmv(&p, &mut ap);
        for (a, &pv) in ap.iter_mut().zip(&p) {
            *a += lambda * pv;
        }
        let pap = dot64(&p, &ap);
        if !pap.is_finite() || pap <= 0.0 {
            // K̃ lost positive-definiteness at the f32/ACA noise floor —
            // stop with the best iterate rather than diverge.
            break;
        }
        let step = (rs / pap) as f32;
        for (xi, &pv) in x.iter_mut().zip(&p) {
            *xi += step * pv;
        }
        for (ri, &av) in r.iter_mut().zip(&ap) {
            *ri -= step * av;
        }
        let rs_new = dot64(&r, &r);
        let beta = (rs_new / rs) as f32;
        for (pi, &ri) in p.iter_mut().zip(&r) {
            *pi = ri + beta * *pi;
        }
        rs = rs_new;
        iters += 1;
    }
    counters::add(Counter::CgIterations, iters as u64);
    (x, iters, rs.sqrt() / bnorm)
}

/// Nyström preconditioner `M = λI + B·Bᵀ ≈ λI + K` over a landmark set,
/// applied through the Woodbury identity:
/// `M⁻¹·r = (r − B·G⁻¹·Bᵀ·r)/λ` with `G = λI + BᵀB` (`m x m`).  All
/// internals in f64; build is O(n·m²) once, apply is O(n·m) per
/// iteration — negligible next to the compressed spmv for m ≤ 128.
pub struct NystromPrecond {
    m: usize,
    lambda: f64,
    /// `B = K(X,L)·chol(K(L,L))⁻ᵀ`, row-major `n x m`.
    b: Vec<f64>,
    /// Lower Cholesky factor of `G = λI + BᵀB`.
    lg: Vec<f64>,
}

impl NystromPrecond {
    /// Build over tree-ordered `coords` and landmark indices (typically
    /// [`crate::hmat::h2::H2Field::landmarks`]).  `None` when the
    /// landmark Gram matrix is numerically singular — the caller falls
    /// back to plain CG.
    pub fn build(
        coords: &[f32],
        d: usize,
        inv_h2: f32,
        landmarks: &[u32],
        lambda: f64,
    ) -> Option<NystromPrecond> {
        let m = landmarks.len();
        if m == 0 || !(lambda > 0.0) {
            return None;
        }
        let n = coords.len() / d;
        let gen = GaussGen { coords, d, inv_h2 };
        // Landmark Gram with a trace-scaled jitter for the Cholesky.
        let mut amm = vec![0.0f64; m * m];
        let mut tr = 0.0f64;
        for a in 0..m {
            for c in 0..m {
                amm[a * m + c] = gen.entry_f64(landmarks[a] as usize, landmarks[c] as usize);
            }
            tr += amm[a * m + a];
        }
        let jitter = 1e-6 * tr / m as f64;
        for a in 0..m {
            amm[a * m + a] += jitter;
        }
        let lc = chol(&amm, m)?;
        // Row i of B solves the lower-triangular system Lc·bᵢ = cᵢ.
        let mut b = vec![0.0f64; n * m];
        let mut c = vec![0.0f64; m];
        for i in 0..n {
            for (a, &l) in landmarks.iter().enumerate() {
                c[a] = gen.entry_f64(i, l as usize);
            }
            for a in 0..m {
                let mut s = c[a];
                for t in 0..a {
                    s -= lc[a * m + t] * b[i * m + t];
                }
                b[i * m + a] = s / lc[a * m + a];
            }
        }
        let mut g = vec![0.0f64; m * m];
        for i in 0..n {
            let row = &b[i * m..(i + 1) * m];
            for a in 0..m {
                for t in a..m {
                    g[a * m + t] += row[a] * row[t];
                }
            }
        }
        for a in 0..m {
            for t in 0..a {
                g[a * m + t] = g[t * m + a];
            }
            g[a * m + a] += lambda;
        }
        let lg = chol(&g, m)?;
        Some(NystromPrecond { m, lambda, b, lg })
    }

    /// `z = M⁻¹·r`.
    pub fn apply(&self, r: &[f32]) -> Vec<f32> {
        let m = self.m;
        let n = r.len();
        let mut t = vec![0.0f64; m];
        for i in 0..n {
            let row = &self.b[i * m..(i + 1) * m];
            let ri = r[i] as f64;
            for a in 0..m {
                t[a] += row[a] * ri;
            }
        }
        // G⁻¹·t through the Cholesky factor: Lg·y = t, Lgᵀ·u = y.
        let mut y = vec![0.0f64; m];
        for a in 0..m {
            let mut s = t[a];
            for c in 0..a {
                s -= self.lg[a * m + c] * y[c];
            }
            y[a] = s / self.lg[a * m + a];
        }
        let mut u = vec![0.0f64; m];
        for a in (0..m).rev() {
            let mut s = y[a];
            for c in a + 1..m {
                s -= self.lg[c * m + a] * u[c];
            }
            u[a] = s / self.lg[a * m + a];
        }
        (0..n)
            .map(|i| {
                let row = &self.b[i * m..(i + 1) * m];
                let bu: f64 = row.iter().zip(&u).map(|(&bv, &uv)| bv * uv).sum();
                ((r[i] as f64 - bu) / self.lambda) as f32
            })
            .collect()
    }
}

/// Lower Cholesky of a symmetric positive-definite `m x m` matrix;
/// `None` when a pivot is non-positive.
fn chol(a: &[f64], m: usize) -> Option<Vec<f64>> {
    let mut l = vec![0.0f64; m * m];
    for i in 0..m {
        for j in 0..=i {
            let mut s = a[i * m + j];
            for k in 0..j {
                s -= l[i * m + k] * l[j * m + k];
            }
            if i == j {
                if !(s > 0.0) {
                    return None;
                }
                l[i * m + i] = s.sqrt();
            } else {
                l[i * m + j] = s / l[j * m + j];
            }
        }
    }
    Some(l)
}

/// Preconditioned conjugate gradients on `(K + λI)·α = b` — same
/// operator, vectors, and stopping rule as [`cg_solve`] (true residual
/// norm, so iteration counts are directly comparable), plus one
/// `M⁻¹`-apply per iteration.
pub fn pcg_solve(
    eng: &FullKernelEngine,
    b: &[f32],
    lambda: f32,
    tol: f64,
    max_iters: usize,
    pre: &NystromPrecond,
) -> (Vec<f32>, usize, f64) {
    let n = b.len();
    assert_eq!(n, eng.n());
    let bnorm = dot64(b, b).sqrt();
    let mut x = vec![0.0f32; n];
    if bnorm == 0.0 {
        return (x, 0, 0.0);
    }
    let mut r = b.to_vec();
    let mut z = pre.apply(&r);
    let mut p = z.clone();
    let mut ap = vec![0.0f32; n];
    let mut rz = dot64(&r, &z);
    let mut rn2 = dot64(&r, &r);
    let mut iters = 0usize;
    while iters < max_iters && rn2.sqrt() > tol * bnorm {
        eng.spmv(&p, &mut ap);
        for (a, &pv) in ap.iter_mut().zip(&p) {
            *a += lambda * pv;
        }
        let pap = dot64(&p, &ap);
        if !pap.is_finite() || pap <= 0.0 {
            break;
        }
        let step = (rz / pap) as f32;
        for (xi, &pv) in x.iter_mut().zip(&p) {
            *xi += step * pv;
        }
        for (ri, &av) in r.iter_mut().zip(&ap) {
            *ri -= step * av;
        }
        rn2 = dot64(&r, &r);
        iters += 1;
        if rn2.sqrt() <= tol * bnorm {
            break;
        }
        z = pre.apply(&r);
        let rz_new = dot64(&r, &z);
        if !rz_new.is_finite() || rz_new <= 0.0 {
            break;
        }
        let beta = (rz_new / rz) as f32;
        for (pi, &zi) in p.iter_mut().zip(&z) {
            *pi = zi + beta * *pi;
        }
        rz = rz_new;
    }
    counters::add(Counter::CgIterations, iters as u64);
    (x, iters, rn2.sqrt() / bnorm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn krr_converges_on_clustered_data() {
        let ds = SynthSpec::blobs(500, 3, 4, 77).generate();
        let y = synthetic_targets(&ds, 1);
        let cfg = KrrConfig {
            lambda: 0.5,
            tol: 1e-4,
            block_cap: 64,
            cg_tol: 1e-6,
            threads: 2,
            kernel: KernelKind::Scalar,
            ..KrrConfig::default()
        };
        let res = run(&ds, &y, &cfg);
        assert!(res.iterations > 0);
        assert!(
            res.rel_residual < 1e-4,
            "CG residual {} after {} iters ({})",
            res.rel_residual,
            res.iterations,
            res.summary
        );
        assert!(res.bandwidth > 0.0);
        // the smoother interpolates a smooth target reasonably under a
        // moderate ridge
        assert!(res.train_rmse < 0.5, "train rmse {}", res.train_rmse);
    }

    #[test]
    fn far_field_changes_the_solution() {
        // The truncated baseline and the full kernel must disagree —
        // otherwise the far field contributed nothing and the workload
        // didn't need this subsystem.
        let ds = SynthSpec::blobs(400, 3, 4, 5).generate();
        let y = synthetic_targets(&ds, 2);
        let base = KrrConfig {
            lambda: 0.5,
            block_cap: 64,
            threads: 2,
            kernel: KernelKind::Scalar,
            ..KrrConfig::default()
        };
        let full = run(&ds, &y, &base);
        let off = run(
            &ds,
            &y,
            &KrrConfig {
                far: FarFieldMode::Off,
                ..base
            },
        );
        let diff: f64 = full
            .alpha
            .iter()
            .zip(&off.alpha)
            .map(|(&a, &b)| (a as f64 - b as f64).abs())
            .sum();
        assert!(diff > 1e-6, "far field had no effect on the solution");
    }

    #[test]
    fn h2_preconditioner_cuts_cg_iterations() {
        let ds = SynthSpec::blobs(600, 3, 4, 7).generate();
        let y = synthetic_targets(&ds, 3);
        let base = KrrConfig {
            lambda: 1.0,
            block_cap: 64,
            threads: 2,
            kernel: KernelKind::Scalar,
            far: FarFieldMode::H2,
            cg_tol: 1e-6,
            ..KrrConfig::default()
        };
        let plain = run(&ds, &y, &base);
        let pre = run(
            &ds,
            &y,
            &KrrConfig {
                precond: true,
                ..base
            },
        );
        assert!(plain.iterations > 0 && pre.iterations > 0);
        assert!(
            pre.iterations < plain.iterations,
            "preconditioner did not help: {} vs {}",
            pre.iterations,
            plain.iterations
        );
        // same system, same stopping rule — solutions must agree
        let n2: f64 = dot64(&plain.alpha, &plain.alpha);
        let d2: f64 = plain
            .alpha
            .iter()
            .zip(&pre.alpha)
            .map(|(&a, &b)| (a as f64 - b as f64).powi(2))
            .sum();
        assert!(
            d2.sqrt() <= 2e-2 * n2.sqrt().max(1e-12),
            "PCG solution drifted: rel {}",
            d2.sqrt() / n2.sqrt().max(1e-12)
        );
    }

    #[test]
    fn zero_targets_solve_to_zero() {
        let ds = SynthSpec::blobs(200, 3, 3, 9).generate();
        let y = vec![0.0f32; 200];
        let res = run(
            &ds,
            &y,
            &KrrConfig {
                block_cap: 64,
                threads: 2,
                ..KrrConfig::default()
            },
        );
        assert_eq!(res.iterations, 0);
        assert!(res.alpha.iter().all(|&a| a == 0.0));
    }

    #[test]
    fn suggest_bandwidth_positive_and_scale_aware() {
        let small = SynthSpec::blobs(300, 3, 3, 4).generate();
        let h1 = suggest_bandwidth(&small, 7);
        assert!(h1 > 0.0 && h1.is_finite());
        // scaling the data scales the suggestion
        let mut scaled = small.clone();
        for v in scaled.raw_mut() {
            *v *= 10.0;
        }
        let h2 = suggest_bandwidth(&scaled, 7);
        assert!(
            (h2 / h1 - 10.0).abs() < 0.5,
            "bandwidth not scale-aware: {h1} vs {h2}"
        );
    }
}
